from repro.data.synthetic import SyntheticVision, synthetic_lm_batch, \
    markov_lm_batch
from repro.data.partition import lda_partition
