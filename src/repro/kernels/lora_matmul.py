"""Pallas TPU kernels: fused LoRA matmuls.

Single-adapter (the FLoCoRA client forward hot loop):
  y = x@W + s*(x@a)@b.  The low-rank correction distributes over the K
(contraction) grid axis:  (x@a)@b = sum_k (x_k @ a_k) @ b, so each
(bm, bn, bk) step adds  x_k@w_k + s*(x_k@a_k)@b_n  into the fp32 output
block — no scratch, one epilogue-free accumulation loop, and the rank-r
side chain (r <= 128, one MXU pass) rides along with the dense matmul
instead of a separate XLA fusion with its own HBM round-trip.

Tiling: (M/bm, N/bn, K/bk) grid, K innermost; x (bm,bk), w (bk,bn),
a (bk,r), b (r,bn) tiles in VMEM; all matmul dims multiples of 128 for
the MXU (wrapper pads r up to 128 with zeros when needed).

Multi-adapter (the serving hot loop, multi-tenant read path):
  y[m] = x[m]@W + s * (x[m] @ A[ids[m]]) @ B[ids[m]] — every request row
gathers a DIFFERENT adapter from a stacked per-rank-bucket slab via a
per-row adapter-id vector. Two variants:

  * ``multi_lora_matmul_pallas`` — fp adapter stacks (the
    dequant-then-matmul baseline's second program);
  * ``multi_lora_matmul_q_pallas`` — adapter stacks in the PACKED WIRE
    FORMAT (uint32 little-endian words + per-channel fp32 scale/zp
    sidecars, exactly what ``core/flat.py`` rows / ``quant_pack`` emit):
    unpack + dequant FUSE into the matmul, so an uplinked adapter is
    servable without ever materializing an fp32 copy — the TensorRT-LLM
    weight-only-quant idiom. The gather moves packed words (4-8x fewer
    bytes than fp32) and dequantizes only the M gathered adapters, not
    the whole E-slot staged slab.

Both tile a (M/bm, N/bn) grid, full K per block (adapters quantize over
K per channel row, so K rides whole); the per-row gathers are static-
unrolled dynamic slices on the leading E dim of the VMEM-resident slab.
Off-TPU the jitted wrappers (ops.py) lower to bit-identical jnp twins
inside the same program, matching the quant_pack/dequant_agg pattern.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _lora_matmul_kernel(x_ref, w_ref, a_ref, b_ref, out_ref, *, s: float):
    kk = pl.program_id(2)
    x = x_ref[...]
    acc = jnp.dot(x, w_ref[...], preferred_element_type=jnp.float32)
    h = jnp.dot(x, a_ref[...], preferred_element_type=jnp.float32)
    acc = acc + s * jnp.dot(h.astype(b_ref.dtype), b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(kk == 0)
    def _init():
        out_ref[...] = acc

    @pl.when(kk > 0)
    def _acc():
        out_ref[...] += acc


def lora_matmul_pallas(x: Array, w: Array, a: Array, b: Array, s: float, *,
                       block_m: int = 256, block_n: int = 256,
                       block_k: int = 512,
                       interpret: bool = False) -> Array:
    """x (M, K); w (K, N); a (K, r); b (r, N). Returns bf16 (M, N)."""
    m, k = x.shape
    n = w.shape[1]
    r = a.shape[1]
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    grid = (m // bm, n // bn, k // bk)
    out = pl.pallas_call(
        functools.partial(_lora_matmul_kernel, s=s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk, r), lambda i, j, kk: (kk, 0)),
            pl.BlockSpec((r, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, w, a, b)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Batched multi-adapter kernels (the multi-tenant serving read path)
# ---------------------------------------------------------------------------

def _gather_rows(ref, ids_ref, bm: int):
    """Static-unrolled per-row gather on the leading (adapter-slot) dim:
    rows of the block pick DIFFERENT adapters. ``ids`` rides as a
    (bm, 1) int32 block; each scalar drives one dynamic slice."""
    return jnp.concatenate(
        [ref[pl.ds(ids_ref[m, 0], 1)] for m in range(bm)], axis=0)


def _multi_lora_matmul_kernel(ids_ref, x_ref, w_ref, a_ref, b_ref,
                              out_ref, *, s: float):
    x = x_ref[...]                                        # (bm, K)
    acc = jnp.dot(x, w_ref[...], preferred_element_type=jnp.float32)
    bm = x.shape[0]
    am = _gather_rows(a_ref, ids_ref, bm)                 # (bm, K, R)
    bmat = _gather_rows(b_ref, ids_ref, bm)               # (bm, R, bn)
    h = jax.lax.dot_general(x, am, (((1,), (1,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32)
    y = jax.lax.dot_general(h.astype(bmat.dtype), bmat,
                            (((1,), (1,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32)
    out_ref[...] = acc + s * y


def multi_lora_matmul_pallas(x: Array, w: Array, a_stack: Array,
                             b_stack: Array, ids: Array, s: float, *,
                             block_m: int = 8, block_n: int = 256,
                             interpret: bool = False) -> Array:
    """x (M, K); w (K, N); a_stack (E, K, R); b_stack (E, R, N);
    ids (M,) int32 adapter slots. Returns fp32 (M, N)."""
    m, k = x.shape
    n = w.shape[1]
    e, _, r = a_stack.shape
    bm, bn = min(block_m, m), min(block_n, n)
    assert m % bm == 0 and n % bn == 0
    grid = (m // bm, n // bn)
    out = pl.pallas_call(
        functools.partial(_multi_lora_matmul_kernel, s=s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((e, k, r), lambda i, j: (0, 0, 0)),
            pl.BlockSpec((e, r, bn), lambda i, j: (0, 0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(ids.reshape(m, 1).astype(jnp.int32), x, w, a_stack, b_stack)
    return out.astype(x.dtype)


def _unpack_block(words: Array, bits: int):
    """(..., Nw) uint32 -> (..., Nw*per) fp32 levels, little-endian
    (broadcasted-iota shifts — the TPU-safe twin of ref.unpack_words)."""
    per = 32 // bits
    shifts = (jax.lax.broadcasted_iota(
        jnp.uint32, (*words.shape, per), words.ndim) * jnp.uint32(bits))
    msk = jnp.uint32((1 << bits) - 1)
    lv = ((words[..., None] >> shifts) & msk).astype(jnp.float32)
    return lv.reshape(*words.shape[:-1], words.shape[-1] * per)


def _multi_lora_matmul_q_kernel(ids_ref, x_ref, w_ref, aq_ref, as_ref,
                                az_ref, bq_ref, bs_ref, bz_ref, out_ref,
                                *, s: float, bits: int, k: int, r: int):
    x = x_ref[...].astype(jnp.float32)                    # (bm, K)
    acc = jnp.dot(x, w_ref[...].astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    bm = x.shape[0]
    aw = _gather_rows(aq_ref, ids_ref, bm)                # (bm, R, KW)
    asc = _gather_rows(as_ref, ids_ref, bm)               # (bm, R)
    azp = _gather_rows(az_ref, ids_ref, bm)
    bw = _gather_rows(bq_ref, ids_ref, bm)                # (bm, bn, RW)
    bsc = _gather_rows(bs_ref, ids_ref, bm)               # (bm, bn)
    bzp = _gather_rows(bz_ref, ids_ref, bm)
    # dequant fused into the matmul: only the bm GATHERED adapters'
    # words unpack, and only transiently in VMEM — fp32 never lands
    adeq = (_unpack_block(aw, bits)[..., :k] - azp[..., None]) \
        * asc[..., None]                                  # (bm, R, K)
    bdeq = (_unpack_block(bw, bits)[..., :r] - bzp[..., None]) \
        * bsc[..., None]                                  # (bm, bn, R)
    h = jax.lax.dot_general(x, adeq, (((1,), (2,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32)
    y = jax.lax.dot_general(h, bdeq, (((1,), (2,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32)
    out_ref[...] = acc + s * y


def multi_lora_matmul_q_pallas(x: Array, w: Array, aq: Array, a_scale: Array,
                               a_zp: Array, bq: Array, b_scale: Array,
                               b_zp: Array, ids: Array, s: float,
                               bits: int, *, block_m: int = 8,
                               block_n: int = 256,
                               interpret: bool = False) -> Array:
    """Wire-format adapter slabs (channel-first rows, compact words):

      aq (E, R, KW) uint32  — A rows: R channels x K levels each;
      a_scale/a_zp (E, R)   — fp32 sidecars (padded bucket rows: 0/0);
      bq (E, N, RW) uint32  — B rows: N channels x R levels each;
      b_scale/b_zp (E, N).

    KW*per >= K and RW*per >= R (compact word counts; tails are zero
    levels by the codec's packing contract). Returns fp32 (M, N)."""
    m, k = x.shape
    n = w.shape[1]
    e, r, kw = aq.shape
    rw = bq.shape[2]
    per = 32 // bits
    assert kw * per >= k and rw * per >= r
    bm, bn = min(block_m, m), min(block_n, n)
    assert m % bm == 0 and n % bn == 0
    grid = (m // bm, n // bn)
    out = pl.pallas_call(
        functools.partial(_multi_lora_matmul_q_kernel, s=s, bits=bits,
                          k=k, r=r),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((e, r, kw), lambda i, j: (0, 0, 0)),
            pl.BlockSpec((e, r), lambda i, j: (0, 0)),
            pl.BlockSpec((e, r), lambda i, j: (0, 0)),
            pl.BlockSpec((e, bn, rw), lambda i, j: (0, j, 0)),
            pl.BlockSpec((e, bn), lambda i, j: (0, j)),
            pl.BlockSpec((e, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(ids.reshape(m, 1).astype(jnp.int32), x, w, aq, a_scale, a_zp,
      bq, b_scale, b_zp)
    return out.astype(x.dtype)
