"""FLoCoRA core: LoRA adapters, affine message quantization, aggregation.

Public API re-exports.
"""
from repro.core.flocora import FLoCoRAConfig, RankSchedule, broadcast, \
    client_uplink, client_wire_bytes, fleet_tcc_bytes, server_downlink, \
    server_round, round_wire_bytes, tcc
from repro.core.aggregation import Aggregator, FedAvgAggregator, \
    FedBuffAggregator, ErrorFeedbackFedAvg, SVDRecombinationAggregator, \
    bucket_by_rank, fedavg_hetero, fedavg_packed
from repro.core.messages import PackedLeaf, pack_message, unpack_message, \
    packed_wire_bytes, message_wire_bytes, message_rank, message_to_wire, \
    message_from_wire, message_density, parse_wire_header
from repro.core.sparse import SparseLeaf, SparsityConfig, is_sparse_leaf, \
    sparse_leaf_wire_bytes, sparsify_leaf
from repro.core.lora import LoRAConfig, dense_lora_init, dense_lora_apply, \
    dense_merge, conv_lora_init, conv_lora_apply, conv_merge, linear_init, \
    linear_apply, linear_logical, adapter_rank, is_adapter_pair, \
    pad_adapter, slice_adapter, truncate_adapter, resize_adapter, \
    resize_tree_rank, tree_ranks, tree_max_rank, svd_energy_rank
from repro.core.quant import QuantConfig, affine_qparams, quantize, \
    dequantize, quant_dequant, pack_levels, unpack_levels
from repro.core import messages, aggregation
