"""Jit'd public wrappers for the Pallas kernels.

Pad-to-alignment, channel-first reshaping from arbitrary tensors, and
backend dispatch: on TPU the kernels compile natively; on CPU (this
container) they run in interpret mode — same kernel body, Python
execution, used by the test-suite oracles.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.dequant_agg import dequant_agg_pallas
from repro.kernels.lora_matmul import lora_matmul_pallas
from repro.kernels.quant_pack import quant_pack_pallas

Array = jax.Array


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: Array, mult: int, axis: int) -> Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@partial(jax.jit, static_argnames=("bits", "block_c"))
def quant_pack(x2d: Array, bits: int, block_c: int = 8):
    """x2d: (C, N) channel-first fp32 view of a message tensor."""
    per = 32 // bits
    lane = per * 128
    xp = _pad_to(_pad_to(x2d, block_c, 0), lane, 1)
    packed, scale, zp = quant_pack_pallas(xp, bits, n_valid=x2d.shape[1],
                                          block_c=block_c,
                                          interpret=_interpret())
    c = x2d.shape[0]
    return packed[:c], scale[:c], zp[:c]


@partial(jax.jit, static_argnames=("bits", "block_c"))
def dequant_agg(packed: Array, scale: Array, zp: Array, weights: Array,
                bits: int, block_c: int = 8) -> Array:
    kp = _pad_to(packed, block_c, 1)
    sp = _pad_to(scale, block_c, 1)
    zpp = _pad_to(zp, block_c, 1)
    out = dequant_agg_pallas(kp, sp, jnp.where(sp > 0, zpp, 0.0), weights,
                             bits, block_c=block_c,
                             interpret=_interpret())
    return out[: packed.shape[1]]


@partial(jax.jit, static_argnames=("s",))
def lora_matmul(x: Array, w: Array, a: Array, b: Array, s: float) -> Array:
    """Fused y = x@w + s*(x@a)@b. Pads r to 128 lanes; picks MXU-aligned
    blocks that divide the (padded) problem."""
    m, k = x.shape
    n = w.shape[1]
    r = a.shape[1]
    rp = max(128, ((r + 127) // 128) * 128)
    ap = _pad_to(a, rp, 1)
    bp = _pad_to(b, rp, 0)

    def blk(dim, target):
        t = min(target, dim)
        while dim % t:
            t //= 2
        return max(t, 1)

    bm, bn, bk = blk(m, 256), blk(n, 256), blk(k, 512)
    return lora_matmul_pallas(x, w, ap, bp, s, block_m=bm, block_n=bn,
                              block_k=bk, interpret=_interpret())


# convenience: channel-first 2D view of an arbitrary message tensor
def to_channel_first_2d(x: Array) -> Array:
    """(..., C) -> (C, prod(...)) — matches the codec's last-axis-channel
    convention."""
    xm = jnp.moveaxis(x, -1, 0)
    return xm.reshape(xm.shape[0], -1)
