"""Jit'd public wrappers for the Pallas kernels.

Pad-to-alignment, channel-first reshaping from arbitrary tensors, and
backend dispatch: on TPU the kernels compile natively; on CPU (this
container) they run in interpret mode — same kernel body, Python
execution, used by the test-suite oracles.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.dequant_agg import dequant_agg_pallas, \
    dequant_agg_rows_pallas
from repro.kernels.lora_matmul import lora_matmul_pallas
from repro.kernels.quant_pack import quant_pack_pallas

Array = jax.Array


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def lane_levels(bits: int) -> int:
    """Kernel column alignment in LEVELS: 32/bits levels per uint32 word
    x 128 lanes. The single source of truth for the codecs' payload
    padding (per-leaf ``messages._pack_rows`` and the flat layout's
    ``n_max`` must agree on it, or byte identity breaks)."""
    return (32 // bits) * 128


def _pad_to(x: Array, mult: int, axis: int) -> Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@partial(jax.jit, static_argnames=("bits", "block_c"))
def quant_pack(x2d: Array, bits: int, block_c: int = 8):
    """x2d: (C, N) channel-first fp32 view of a message tensor."""
    per = 32 // bits
    lane = per * 128
    xp = _pad_to(_pad_to(x2d, block_c, 0), lane, 1)
    packed, scale, zp = quant_pack_pallas(xp, bits, n_valid=x2d.shape[1],
                                          block_c=block_c,
                                          interpret=_interpret())
    c = x2d.shape[0]
    return packed[:c], scale[:c], zp[:c]


def _quant_pack_rows_jnp(x2d: Array, nv: Array, bits: int):
    """Bit-identical jnp twin of the ragged-row quant_pack kernel (same
    formulas elementwise, exact min/max reductions, same little-endian
    word packing)."""
    qmax = (1 << bits) - 1
    col = jax.lax.broadcasted_iota(jnp.int32, x2d.shape, 1)
    valid = col < nv[:, None]
    big = jnp.float32(3.4e38)
    x = x2d.astype(jnp.float32)
    xmin = jnp.minimum(jnp.min(jnp.where(valid, x, big), axis=1), 0.0)
    xmax = jnp.maximum(jnp.max(jnp.where(valid, x, -big), axis=1), 0.0)
    rng = xmax - xmin
    scale = jnp.where(rng > 0, rng * jnp.float32(1.0 / qmax), 1.0)
    zp = jnp.clip(jnp.round(-xmin / scale), 0, qmax)
    q = jnp.round(x / scale[:, None]) + zp[:, None]
    q = jnp.where(valid, jnp.clip(q, 0, qmax), 0).astype(jnp.uint32)
    return ref.pack_words(q, bits), scale, zp


@partial(jax.jit, static_argnames=("bits", "block_c"))
def quant_pack_rows(x2d: Array, n_valid: Array, bits: int,
                    block_c: int = 8):
    """Ragged-row variant for the flat-tree codec: ``n_valid`` is a (C,)
    int32 vector of per-row true lengths (rows are different leaves'
    channels, so their valid widths differ). Columns must already be
    padded to the kernel lane multiple (core/flat.py sizes the buffer).
    One launch packs the WHOLE message.

    Off-TPU this lowers to the bit-identical jnp twin INSIDE the same
    jitted program (still one dispatch): the interpret-mode grid walk
    scales with C_total and would tax exactly the per-message overhead
    the flat codec removes."""
    nv = jnp.asarray(n_valid, jnp.int32)
    if _interpret():
        return _quant_pack_rows_jnp(x2d, nv, bits)
    xp = _pad_to(x2d, block_c, 0)
    packed, scale, zp = quant_pack_pallas(xp, bits,
                                          n_valid=_pad_to(nv, block_c, 0),
                                          block_c=block_c)
    c = x2d.shape[0]
    return packed[:c], scale[:c], zp[:c]


@partial(jax.jit, static_argnames=("bits", "block_c"))
def dequant_agg_rows(packed: Array, scale: Array, zp: Array,
                     weights: Array, n_valid: Array, bits: int,
                     block_c: int = 8) -> Array:
    """Flat-tree cohort aggregate: packed (K, C, Nw), sidecars (K, C),
    per-row lengths (C,). ONE launch unpacks + dequantizes + reduces the
    whole K-client message set; row tails come back as exact zeros.
    Off-TPU: the bit-identical jnp twin inside the same program."""
    nv = jnp.asarray(n_valid, jnp.int32)
    w = weights.astype(jnp.float32)
    zpz = jnp.where(scale > 0, zp, 0.0)
    if _interpret():
        lv = ref.unpack_words(packed, bits).astype(jnp.float32)
        deq = (lv - zpz[..., None]) * scale[..., None]
        out = jnp.einsum("k,kcn->cn", w, deq)
        col = jax.lax.broadcasted_iota(jnp.int32, out.shape, 1)
        return jnp.where(col < nv[:, None], out, 0.0)
    kp = _pad_to(packed, block_c, 1)
    sp = _pad_to(scale, block_c, 1)
    out = dequant_agg_rows_pallas(kp, sp, _pad_to(zpz, block_c, 1), w,
                                  _pad_to(nv, block_c, 0), bits,
                                  block_c=block_c)
    return out[: packed.shape[1]]


@partial(jax.jit, static_argnames=("bits", "block_c"))
def dequant_agg(packed: Array, scale: Array, zp: Array, weights: Array,
                bits: int, block_c: int = 8,
                n_valid: Array | None = None) -> Array:
    """``n_valid`` (optional (C,) vector) masks each row's tail to exact
    zero — the flat-tree codec aggregates every leaf of a K-client
    cohort in one launch and slices the rows apart afterwards."""
    kp = _pad_to(packed, block_c, 1)
    sp = _pad_to(scale, block_c, 1)
    zpp = _pad_to(zp, block_c, 1)
    nvp = None if n_valid is None else \
        _pad_to(jnp.asarray(n_valid, jnp.int32), block_c, 0)
    out = dequant_agg_pallas(kp, sp, jnp.where(sp > 0, zpp, 0.0), weights,
                             bits, n_valid=nvp, block_c=block_c,
                             interpret=_interpret())
    return out[: packed.shape[1]]


@partial(jax.jit, static_argnames=("s",))
def lora_matmul(x: Array, w: Array, a: Array, b: Array, s: float) -> Array:
    """Fused y = x@w + s*(x@a)@b. Pads r to 128 lanes; picks MXU-aligned
    blocks that divide the (padded) problem."""
    m, k = x.shape
    n = w.shape[1]
    r = a.shape[1]
    rp = max(128, ((r + 127) // 128) * 128)
    ap = _pad_to(a, rp, 1)
    bp = _pad_to(b, rp, 0)

    def blk(dim, target):
        t = min(target, dim)
        while dim % t:
            t //= 2
        return max(t, 1)

    bm, bn, bk = blk(m, 256), blk(n, 256), blk(k, 512)
    return lora_matmul_pallas(x, w, ap, bp, s, block_m=bm, block_n=bn,
                              block_k=bk, interpret=_interpret())


# ---------------------------------------------------------------------------
# Channel-first 2D views (the CANONICAL helpers — the codec's last-axis-
# channel convention; every kernel caller reshapes through these)
# ---------------------------------------------------------------------------

def to_channel_first_2d(x: Array, per_stack: bool = False) -> Array:
    """(..., C) -> (C, prod(...)): the channel-first 2D view matching the
    per-channel qparam groups. ``per_stack`` keeps a leading stack dim's
    slices as separate qparam rows ((s*C, n) for an (s, n, C) tensor)."""
    if per_stack and x.ndim >= 3:
        s = int(np.prod(x.shape[:-2]))
        x3 = jnp.swapaxes(x.reshape(s, x.shape[-2], x.shape[-1]), -1, -2)
        return x3.reshape(s * x.shape[-1], x.shape[-2])
    xm = jnp.moveaxis(x, -1, 0)
    return xm.reshape(x.shape[-1], -1)


def from_channel_first_2d(x2d: Array, shape: tuple,
                          per_stack: bool = False) -> Array:
    """Inverse of :func:`to_channel_first_2d` for a target ``shape``."""
    if per_stack and len(shape) >= 3:
        s = int(np.prod(shape[:-2]))
        x3 = x2d.reshape(s, shape[-1], shape[-2])
        return jnp.swapaxes(x3, -1, -2).reshape(shape)
    x = x2d.reshape((shape[-1],) + tuple(shape[:-1]))
    return jnp.moveaxis(x, 0, -1)
