import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ must precede every other import (jax locks device count on first init)
"""§Perf hillclimbing driver: named variants per target cell, each a
hypothesis -> change pair; lower+compile, record roofline terms under the
variant tag, compare against baseline. See EXPERIMENTS.md §Perf for the
hypothesis/result log.

    PYTHONPATH=src python -m repro.launch.perf_iter --cell \
        nemotron-4-340b:train_4k --variant int8_base [--mesh single]
"""
import argparse
import dataclasses
import sys


def variants_for(arch: str, shape: str) -> dict:
    from repro.launch.steps import CellPlan, plan_for
    from repro.models.moe import MoESpec
    base = plan_for(arch, shape)
    v: dict[str, "CellPlan"] = {}

    def p(**kw):
        return dataclasses.replace(base, **kw)

    # universal levers
    v["int8_base"] = p(quantize_base=True)
    v["xent2048"] = p(cfg_updates={"xent_chunk": 2048})
    v["kvchunk4096"] = p(cfg_updates={"kv_chunk": 4096})
    v["no_remat"] = p(cfg_updates={"remat": False})
    if base.microbatch > 1:
        v["micro_half"] = p(microbatch=base.microbatch // 2)
        v["micro_half_int8"] = p(microbatch=base.microbatch // 2,
                                 quantize_base=True)
    v["int8_xent2048"] = p(quantize_base=True,
                           cfg_updates={"xent_chunk": 2048})
    v["combo_min"] = p(quantize_base=True, microbatch=2,
                       cfg_updates={"xent_chunk": 2048})
    v["combo_nem"] = p(quantize_base=True, microbatch=8,
                       cfg_updates={"xent_chunk": 256, "kv_chunk": 512})
    v["combo_nem2"] = p(quantize_base=True, microbatch=4,
                        cfg_updates={"xent_chunk": 256})
    if not base.seq_parallel:
        v["sp_on"] = p(seq_parallel=True)
    else:
        v["sp_off"] = p(seq_parallel=False)

    if arch == "deepseek-v2-236b":
        moe = MoESpec(d_model=5120, d_ff=1536, n_experts=160, top_k=6,
                      n_shared=2, mlp_kind="swiglu", capacity_factor=1.0)
        v["cap1.0"] = p(cfg_updates={"moe": moe})
        v["cap1.0_int8"] = p(quantize_base=True, cfg_updates={"moe": moe})
    if arch == "llama4-maverick-400b-a17b":
        moe = MoESpec(d_model=5120, d_ff=8192, n_experts=128, top_k=1,
                      n_shared=1, mlp_kind="swiglu", capacity_factor=1.0)
        v["cap1.0"] = p(cfg_updates={"moe": moe})
    return v


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="arch:shape")
    ap.add_argument("--variant", required=True,
                    help="variant name or 'list'")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi"])
    args = ap.parse_args()
    arch, shape = args.cell.split(":")
    vs = variants_for(arch, shape)
    if args.variant == "list":
        print("\n".join(vs))
        return 0
    from repro.launch import dryrun_lib
    plan = vs[args.variant]
    rec = dryrun_lib.run_cell(arch, shape, multi_pod=args.mesh == "multi",
                              plan=plan, tag=args.variant)
    if rec["status"] != "ok":
        print(rec.get("error", rec["status"]))
        return 1
    t = rec["roofline"]
    print(f"{arch} x {shape} [{args.variant}]: "
          f"peak={rec['memory']['peak_bytes'] / 2**30:.2f}GiB "
          f"tc={t['t_compute_s']:.3e} tm={t['t_memory_s']:.3e} "
          f"tcoll={t['t_collective_s']:.3e} dom={t['dominant']} "
          f"useful={rec['useful_flops_ratio']:.3f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
