"""gemma3-4b [dense]: 34L d_model=2560 8H (GQA kv=4) d_ff=10240
vocab=262144 — 5:1 local:global sliding window (1024), dual rope bases,
qk-norm, geglu [hf:google/gemma-3-4b-pt]."""
from repro.core.lora import LoRAConfig
from repro.models.lm import LMConfig


def full() -> LMConfig:
    return LMConfig(
        name="gemma3-4b", n_layers=34, d_model=2560, n_heads=8,
        n_kv_heads=4, head_dim=256, d_ff=10240, vocab=262144,
        mlp_kind="geglu", qk_norm=True, embed_scale=True,
        window=1024, window_pattern=6,
        rope_base=1e4, rope_base_global=1e6,
        pad_heads_to=16,              # 8 -> 16 so heads shard 16-way
        lora=LoRAConfig(rank=32, alpha=512.0), head_mode="lora")


def smoke() -> LMConfig:
    return LMConfig(
        name="gemma3-4b-smoke", n_layers=7, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=192, vocab=512,
        mlp_kind="geglu", qk_norm=True, embed_scale=True,
        window=8, window_pattern=3, rope_base=1e4, rope_base_global=1e5,
        lora=LoRAConfig(rank=4, alpha=64.0), head_mode="lora")
