"""Procedural datasets (the container is offline — no CIFAR-10 download).

SyntheticVision: a learnable CIFAR-like task. Each class has a fixed
random 32x32x3 template (low-frequency, via blurred noise); samples are
template + per-sample noise + random shift/flip. A small CNN separates
the classes easily, so FL convergence dynamics (FedAvg vs FLoCoRA vs
quantized) are observable; absolute CIFAR-10 accuracies are NOT claimed
(EXPERIMENTS.md §Repro-validity).

markov_lm_batch: token stream from a random sparse Markov chain (per-state
support of 8 next-tokens with Zipf weights) — gives an LM a learnable
structure with a known entropy floor well below ln(V).
"""
from __future__ import annotations

import dataclasses

import numpy as np

Array = np.ndarray


@dataclasses.dataclass
class SyntheticVision:
    n_classes: int = 10
    image: int = 32
    seed: int = 0
    noise: float = 0.35

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        raw = rng.normal(size=(self.n_classes, self.image, self.image, 3))
        # cheap low-pass: box-blur twice so templates have spatial structure
        for _ in range(2):
            raw = (raw + np.roll(raw, 1, 1) + np.roll(raw, -1, 1)
                   + np.roll(raw, 1, 2) + np.roll(raw, -1, 2)) / 5.0
        self.templates = (raw / raw.std()).astype(np.float32)

    def sample(self, rng: np.random.Generator, labels: Array) -> Array:
        """labels: (N,) -> images (N, 32, 32, 3) float32."""
        t = self.templates[labels]
        shift = rng.integers(-2, 3, size=(len(labels), 2))
        out = np.empty_like(t)
        for i in range(len(labels)):
            out[i] = np.roll(t[i], tuple(shift[i]), axis=(0, 1))
        flip = rng.random(len(labels)) < 0.5
        out[flip] = out[flip, :, ::-1]
        out += rng.normal(scale=self.noise, size=out.shape).astype(np.float32)
        return out

    def batch(self, rng: np.random.Generator, labels_pool: Array,
              batch_size: int) -> dict:
        idx = rng.integers(0, len(labels_pool), size=batch_size)
        y = labels_pool[idx]
        return {"x": self.sample(rng, y), "y": y.astype(np.int32)}


_MARKOV_CACHE: dict = {}


def _markov_tables(vocab: int, seed: int, support: int = 8):
    key = (vocab, seed, support)
    if key not in _MARKOV_CACHE:
        rng = np.random.default_rng(seed)
        nxt = rng.integers(0, vocab, size=(vocab, support))
        w = (1.0 / np.arange(1, support + 1)) ** 1.2
        w = w / w.sum()
        _MARKOV_CACHE[key] = (nxt, w)
    return _MARKOV_CACHE[key]


def markov_lm_batch(rng: np.random.Generator, vocab: int, batch: int,
                    seq: int, seed: int = 0) -> dict:
    """{'tokens': (batch, seq+1) int32} from a sparse Markov chain."""
    nxt, w = _markov_tables(vocab, seed)
    toks = np.empty((batch, seq + 1), np.int32)
    toks[:, 0] = rng.integers(0, vocab, size=batch)
    choices = rng.choice(len(w), p=w, size=(batch, seq))
    for t in range(seq):
        toks[:, t + 1] = nxt[toks[:, t], choices[:, t]]
    return {"tokens": toks}


def synthetic_lm_batch(rng: np.random.Generator, vocab: int, batch: int,
                       seq: int) -> dict:
    """Uniform random tokens — used only for shape/throughput benchmarks."""
    return {"tokens": rng.integers(0, vocab, size=(batch, seq + 1)
                                   ).astype(np.int32)}
