from repro.roofline.analysis import collective_bytes, roofline_terms, \
    HW, model_flops
