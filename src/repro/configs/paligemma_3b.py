"""paligemma-3b [vlm]: 18L d_model=2048 8H (MQA kv=1) d_ff=16384
vocab=257216 — SigLIP frontend STUBBED (precomputed patch embeddings,
256 tokens); prefix-LM mask over the image prefix [arXiv:2407.07726]."""
from repro.core.lora import LoRAConfig
from repro.models.lm import LMConfig

N_PATCHES = 256


def full() -> LMConfig:
    return LMConfig(
        name="paligemma-3b", n_layers=18, d_model=2048, n_heads=8,
        n_kv_heads=1, head_dim=256, d_ff=16384, vocab=257216,
        mlp_kind="geglu", embed_scale=True,
        prefix_lm=True, prefix_len=N_PATCHES,
        pad_heads_to=16,
        lora=LoRAConfig(rank=32, alpha=512.0), head_mode="lora")


def smoke() -> LMConfig:
    return LMConfig(
        name="paligemma-3b-smoke", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=1, head_dim=16, d_ff=192, vocab=512,
        mlp_kind="geglu", embed_scale=True, prefix_lm=True, prefix_len=8,
        lora=LoRAConfig(rank=4, alpha=64.0), head_mode="lora")
