from repro.fl.client import ClientConfig, make_local_trainer
from repro.fl.server import ServerConfig, FLServer
from repro.fl.elastic import elastic_restore
