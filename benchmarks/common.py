"""Shared benchmark utilities: timing + the FL experiment harness used by
the Table II/IV and Fig 2/3 reproductions (synthetic CIFAR-like data —
offline container; see EXPERIMENTS.md §Repro-validity)."""
from __future__ import annotations

import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.flocora import FLoCoRAConfig
from repro.core.lora import LoRAConfig
from repro.data import SyntheticVision, lda_partition
from repro.fl import ClientConfig, FLServer, ServerConfig
from repro.models.resnet import ResNetConfig, init as rinit, loss_fn, \
    apply as rapply


def time_us(fn: Callable, *args, iters: int = 20, warmup: int = 3) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def fl_experiment(arch: str = "resnet8", rank: int = 32,
                  alpha: Optional[float] = None, mode: str = "flocora",
                  quant_bits: Optional[int] = None, rounds: int = 10,
                  n_clients: int = 40, clients_per_round: int = 4,
                  n_train: int = 4000, lda_alpha: float = 0.5,
                  local_epochs: int = 1, seed: int = 0,
                  stem_mode: str = "dense", fc_mode: str = "dense",
                  norms_trained: bool = True, eval_every: int = 2,
                  error_feedback: bool = False, dp=None) -> dict:
    """One FL run on the synthetic vision task; returns history + TCC."""
    rng = np.random.default_rng(seed)
    sv = SyntheticVision(seed=0)
    y = rng.integers(0, 10, n_train)
    x = sv.sample(rng, y).astype(np.float32)
    parts = lda_partition(y, n_clients, alpha=lda_alpha, seed=seed)
    data = [{"x": x[p], "y": y[p].astype(np.int32)} for p in parts]
    yt = rng.integers(0, 10, 1000)
    xt = jnp.asarray(sv.sample(rng, yt))

    a = alpha if alpha is not None else 16.0 * rank
    cfg = ResNetConfig(arch=arch, mode=mode,
                       lora=LoRAConfig(rank=rank, alpha=a),
                       stem_mode=stem_mode, fc_mode=fc_mode,
                       norms_trained=norms_trained)
    model = rinit(jax.random.PRNGKey(seed), cfg)
    pred = jax.jit(lambda f, t, xx: jnp.argmax(rapply(f, t, cfg, xx), -1))

    def eval_fn(f, t):
        p = np.asarray(pred(f, t, xt))
        return {"test_acc": float((p == yt).mean())}

    srv = FLServer(
        model, lambda f, t, b: loss_fn(f, t, cfg, b), data,
        ServerConfig(rounds=rounds, n_clients=n_clients,
                     clients_per_round=clients_per_round, seed=seed,
                     eval_every=eval_every),
        ClientConfig(local_epochs=local_epochs, batch_size=32, lr=0.01,
                     momentum=0.9),
        FLoCoRAConfig(rank=rank, alpha=a, quant_bits=quant_bits,
                      error_feedback=error_feedback, dp=dp),
        eval_fn)
    hist = srv.run()
    accs = [h["test_acc"] for h in hist if "test_acc" in h]
    return {"history": hist,
            "final_acc": accs[-1] if accs else None,
            "best_acc": max(accs) if accs else None,
            "round_bytes": srv.round_bytes_per_client,
            "tcc_bytes": rounds * srv.round_bytes_per_client}
