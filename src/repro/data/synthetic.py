"""Procedural datasets (the container is offline — no CIFAR-10 download).

SyntheticVision: a learnable CIFAR-like task. Each class has a fixed
random 32x32x3 template (low-frequency, via blurred noise); samples are
template + per-sample noise + random shift/flip. A small CNN separates
the classes easily, so FL convergence dynamics (FedAvg vs FLoCoRA vs
quantized) are observable; absolute CIFAR-10 accuracies are NOT claimed
(EXPERIMENTS.md §Repro-validity).

markov_lm_batch: token stream from a random sparse Markov chain (per-state
support of 8 next-tokens with Zipf weights) — gives an LM a learnable
structure with a known entropy floor well below ln(V).
"""
from __future__ import annotations

import dataclasses

import numpy as np

Array = np.ndarray


@dataclasses.dataclass
class SyntheticVision:
    n_classes: int = 10
    image: int = 32
    seed: int = 0
    noise: float = 0.35

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        raw = rng.normal(size=(self.n_classes, self.image, self.image, 3))
        # cheap low-pass: box-blur twice so templates have spatial structure
        for _ in range(2):
            raw = (raw + np.roll(raw, 1, 1) + np.roll(raw, -1, 1)
                   + np.roll(raw, 1, 2) + np.roll(raw, -1, 2)) / 5.0
        self.templates = (raw / raw.std()).astype(np.float32)

    def sample(self, rng: np.random.Generator, labels: Array) -> Array:
        """labels: (N,) -> images (N, 32, 32, 3) float32."""
        t = self.templates[labels]
        shift = rng.integers(-2, 3, size=(len(labels), 2))
        out = np.empty_like(t)
        for i in range(len(labels)):
            out[i] = np.roll(t[i], tuple(shift[i]), axis=(0, 1))
        flip = rng.random(len(labels)) < 0.5
        out[flip] = out[flip, :, ::-1]
        out += rng.normal(scale=self.noise, size=out.shape).astype(np.float32)
        return out

    def batch(self, rng: np.random.Generator, labels_pool: Array,
              batch_size: int) -> dict:
        idx = rng.integers(0, len(labels_pool), size=batch_size)
        y = labels_pool[idx]
        return {"x": self.sample(rng, y), "y": y.astype(np.int32)}


# per-seed template banks for lazy fleet shards: client_shard() is
# called once per (seed, cid) on demand by a Population, so the heavy
# template construction must not repeat per client
_VISION_CACHE: dict = {}


def _vision_for(seed: int, n_classes: int) -> SyntheticVision:
    key = (seed, n_classes)
    if key not in _VISION_CACHE:
        _VISION_CACHE[key] = SyntheticVision(n_classes=n_classes,
                                             seed=seed)
    return _VISION_CACHE[key]


def client_shard(seed: int, cid: int, n: int = 64, n_classes: int = 10,
                 classes_per_client: int = 3) -> dict:
    """One client's synthetic-vision shard, generated ON DEMAND as a
    pure function of ``(seed, cid)`` — the lazy-population twin of the
    eager ``lda_partition`` + ``SyntheticVision.sample`` setup.

    Non-IIDness: each client draws labels from ``classes_per_client``
    dominant classes (chosen by a keyed rng, so the skew is
    deterministic per client), with Zipf-ish weights. Two calls with the
    same key return bit-identical arrays; a million-client fleet never
    materializes more shards than its engine keeps resident.
    """
    if n < 1 or not 1 <= classes_per_client <= n_classes:
        raise ValueError("need n >= 1 and 1 <= classes_per_client <= "
                         "n_classes")
    rng = np.random.default_rng([seed, 0xD5, cid])
    sv = _vision_for(seed, n_classes)
    classes = rng.choice(n_classes, size=classes_per_client,
                         replace=False)
    w = (1.0 / np.arange(1, classes_per_client + 1)) ** 1.2
    y = rng.choice(classes, p=w / w.sum(), size=n).astype(np.int32)
    return {"x": sv.sample(rng, y), "y": y}


def linear_shard(seed: int, cid: int, n: int = 24, d: int = 16,
                 n_classes: int = 10) -> dict:
    """A tiny linear-classification shard keyed by ``(seed, cid)`` — the
    cheap shard generator for million-client fleet simulations (the
    1M-client ``--fleet`` benchmark dispatches thousands of shards; a
    32x32x3 vision shard per dispatch would dominate the wall clock).
    Every client's labels come from the SAME hidden linear teacher
    (keyed by seed alone), so the fleet shares a learnable task."""
    if n < 1 or d < 1 or n_classes < 2:
        raise ValueError("need n, d >= 1 and n_classes >= 2")
    teacher = np.random.default_rng([seed, 0xD6])
    w_true = teacher.normal(size=(d, n_classes)).astype(np.float32)
    rng = np.random.default_rng([seed, 0xD7, cid])
    x = rng.normal(size=(n, d)).astype(np.float32)
    logits = x @ w_true + 0.1 * rng.normal(size=(n, n_classes))
    return {"x": x, "y": np.argmax(logits, axis=1).astype(np.int32)}


_MARKOV_CACHE: dict = {}


def _markov_tables(vocab: int, seed: int, support: int = 8):
    key = (vocab, seed, support)
    if key not in _MARKOV_CACHE:
        rng = np.random.default_rng(seed)
        nxt = rng.integers(0, vocab, size=(vocab, support))
        w = (1.0 / np.arange(1, support + 1)) ** 1.2
        w = w / w.sum()
        _MARKOV_CACHE[key] = (nxt, w)
    return _MARKOV_CACHE[key]


def markov_lm_batch(rng: np.random.Generator, vocab: int, batch: int,
                    seq: int, seed: int = 0) -> dict:
    """{'tokens': (batch, seq+1) int32} from a sparse Markov chain."""
    nxt, w = _markov_tables(vocab, seed)
    toks = np.empty((batch, seq + 1), np.int32)
    toks[:, 0] = rng.integers(0, vocab, size=batch)
    choices = rng.choice(len(w), p=w, size=(batch, seq))
    for t in range(seq):
        toks[:, t + 1] = nxt[toks[:, t], choices[:, t]]
    return {"tokens": toks}


def synthetic_lm_batch(rng: np.random.Generator, vocab: int, batch: int,
                       seq: int) -> dict:
    """Uniform random tokens — used only for shape/throughput benchmarks."""
    return {"tokens": rng.integers(0, vocab, size=(batch, seq + 1)
                                   ).astype(np.int32)}
