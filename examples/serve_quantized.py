"""Serving with quantized FLoCoRA adapters: the server ships int8/int4
adapter messages to an edge inference node, which dequantizes, MERGES
them into the frozen base (W* = W + (α/r)·AB — zero added latency,
paper §II-C) and serves via the shared ``serve.generate()`` loop.

Then the OTHER deployment shape: one base hosting MANY tenants'
adapters, where merging is impossible. The multi-tenant engine keeps
every adapter in its packed wire form (``serve.AdapterCache``) and
serves mixed-rank request batches through the fused
gather+dequant+matmul kernel — validated here against the merged
``dense_merge`` oracle.

    PYTHONPATH=src python examples/serve_quantized.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import messages
from repro.core.lora import LoRAConfig
from repro.core.quant import QuantConfig
from repro.models import lm as LM
from repro import serve


def main():
    cfg = LM.LMConfig(name="edge-lm", n_layers=4, d_model=128, n_heads=4,
                      n_kv_heads=2, head_dim=32, d_ff=512, vocab=512,
                      lora=LoRAConfig(rank=8, alpha=128.0),
                      head_mode="lora")
    params = LM.init(jax.random.PRNGKey(0), cfg)
    frozen, train = params["frozen"], params["train"]
    # pretend the adapters were trained: give them nonzero values
    train = jax.tree.map(
        lambda x: x + 0.02 * jax.random.normal(jax.random.PRNGKey(1),
                                               x.shape, x.dtype), train)

    # --- the wire: server -> edge, int4 ---------------------------------
    qcfg = QuantConfig(bits=4)
    wire_bytes = messages.message_wire_bytes(train, qcfg)
    fp_bytes = messages.message_wire_bytes(train, QuantConfig())
    print(f"adapter download: {wire_bytes / 1e3:.1f} KB int4 "
          f"(vs {fp_bytes / 1e3:.1f} KB fp32, "
          f"{fp_bytes / wire_bytes:.1f}x)")
    train_edge = messages.roundtrip(train, qcfg)   # what the edge decodes

    # --- generate with the dequantized adapters (merged, single tenant) -
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (2, 12)), jnp.int32)
    toks, timing = serve.generate(frozen, train_edge, cfg, prompt, gen=9,
                                  max_seq=32)
    print("generated:", np.asarray(toks))
    print(f"  prefill {timing['prefill_s']:.2f}s, "
          f"{timing['decode_steps']} decode steps "
          f"{timing['decode_s']:.2f}s")

    # --- multi-tenant: many adapters, one base, no merging --------------
    # a fleet of 8 clients uplinks rank-4/rank-8 adapters for a 2-layer
    # (d, d) chain; the engine serves a mixed batch straight from the
    # packed wire bytes (dequant fused into the matmul)
    weights, store = serve.make_store(n_clients=8, d_model=cfg.d_model,
                                      n_layers=2, ranks=(4, 8), bits=4,
                                      seed=0)
    cache = serve.AdapterCache(capacity_bytes=1 << 20, qcfg=store.qcfg)
    engine = serve.AdapterServingEngine(weights, scale=0.5,
                                        qcfg=store.qcfg, cache=cache,
                                        fetch=store.fetch)
    cids = [0, 1, 2, 3, 4, 5, 6, 7]          # even: rank 4, odd: rank 8
    engine.admit(cids)
    x = jnp.asarray(rng.standard_normal((8, cfg.d_model)) * 0.5,
                    jnp.float32)
    y = engine.step(x, cids)
    y_oracle = engine.oracle_step(x, cids)    # per-row merged dense
    err = float(jnp.max(jnp.abs(y - y_oracle)))
    print(f"multi-tenant fused serving vs merged oracle "
          f"(8 tenants, ranks 4+8): maxerr={err:.2e}")
    print(f"  cache: {cache.stats()}")


if __name__ == "__main__":
    main()
