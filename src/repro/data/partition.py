"""Non-IID client partitioning: Latent Dirichlet Allocation split
(Hsu et al. 2019), the paper's setting with alpha = 0.5 (ResNet-8 runs)
and alpha = 1.0 (ResNet-18 runs)."""
from __future__ import annotations

import numpy as np


def lda_partition(labels: np.ndarray, n_clients: int, alpha: float,
                  seed: int = 0, min_size: int = 2,
                  max_retries: int = 1000) -> list[np.ndarray]:
    """Returns per-client index arrays. Each class's examples are split
    across clients by a Dirichlet(alpha) draw.

    The ``min_size`` retry loop is BOUNDED: adversarially small alpha
    concentrates whole classes on single clients, and when
    ``n_clients * min_size`` approaches (or exceeds) ``len(labels)`` no
    draw may ever satisfy the floor. After ``max_retries`` rejected
    draws the last draw is repaired deterministically — starved clients
    steal indices from the largest buckets — so the call always
    terminates with every index assigned exactly once."""
    if n_clients * min_size > len(labels):
        raise ValueError(
            f"min_size={min_size} infeasible: {n_clients} clients need "
            f"{n_clients * min_size} samples, have {len(labels)}")
    rng = np.random.default_rng(seed)
    n_classes = int(labels.max()) + 1
    for _ in range(max(1, max_retries)):
        buckets: list[list[int]] = [[] for _ in range(n_clients)]
        for c in range(n_classes):
            idx = np.where(labels == c)[0]
            rng.shuffle(idx)
            props = rng.dirichlet(np.full(n_clients, alpha))
            cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
            for b, part in zip(buckets, np.split(idx, cuts)):
                b.extend(part.tolist())
        sizes = [len(b) for b in buckets]
        if min(sizes) >= min_size:
            break
    else:
        # repair the final draw: move tail indices from the fullest
        # buckets onto starved clients until everyone meets the floor
        for i in sorted(range(n_clients), key=lambda j: len(buckets[j])):
            while len(buckets[i]) < min_size:
                donor = max(range(n_clients), key=lambda j: len(buckets[j]))
                buckets[i].append(buckets[donor].pop())
    out = []
    for b in buckets:
        arr = np.asarray(b, np.int64)
        rng.shuffle(arr)
        out.append(arr)
    return out
