"""Multi-tenant adapter serving (src/repro/serve/ + the batched
multi-adapter kernels).

The acceptance contract:
  * the Pallas multi-adapter kernels (fp and packed-wire-format) are
    BIT-IDENTICAL to their jnp twins in interpret mode;
  * the fused wire-format serving path matches the per-row merged
    ``dense_merge`` oracle to fp32 tolerance across bits {4, 8} x rank
    buckets x ragged batch sizes, WITHOUT ever materializing an fp32
    adapter tree;
  * rank-bucket padding (rank 6 served in the pow2-8 bucket) is
    bit-exact vs serving at the true rank;
  * the cache evicts by LRU / clock second-chance, counts hits, misses
    and evictions, and accounts capacity in MEASURED wire bytes
    (``message_wire_bytes``);
  * a steady-state decode step compiles 0 new programs (the
    jax.monitoring backend-compile event, as in test_flat_codec.py);
  * ``serve.generate()`` reproduces the hand-rolled prefill+decode loop
    it replaced, token for token;
  * the workload simulator is deterministic and serves every request.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import messages
from repro.core.quant import QuantConfig
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.kernels.lora_matmul import (multi_lora_matmul_pallas,
                                       multi_lora_matmul_q_pallas)
from repro.kernels.ops import (_multi_lora_matmul_jnp,
                               _multi_lora_matmul_q_jnp)
from repro import serve

# backend-compile counter: shared process-wide hook in repro.obs.compile
from repro.obs.compile import count_compiles  # noqa: E402


# -- helpers ----------------------------------------------------------------

def _rand_slabs(rng, e, k, n, r):
    a = jnp.asarray(rng.standard_normal((e, k, r)) * 0.2, jnp.float32)
    b = jnp.asarray(rng.standard_normal((e, r, n)) * 0.2, jnp.float32)
    return a, b


def _pack_rows(mat2d, bits):
    """Channel-first rows (C, L) -> compact packed (C, ceil(L/per))
    uint32 + fp32 scale/zp, via the reference codec. Zero-padding L to
    a word multiple is qparam-neutral: the rowwise range already clamps
    to include 0."""
    per = 32 // bits
    mat2d = np.asarray(mat2d)
    pad = (-mat2d.shape[1]) % per
    xp = np.pad(mat2d, ((0, 0), (0, pad)))
    words, scale, zp = kref.quant_pack_ref(
        jnp.asarray(xp, jnp.float32), bits)
    return (np.asarray(words), np.asarray(scale, np.float32),
            np.asarray(zp, np.float32))


def _pack_slabs(rng, e, k, n, r, bits):
    """Random fp stacks + their packed wire-format slabs + the exact
    dequantized stacks the packed kernel must reproduce."""
    a, b = _rand_slabs(rng, e, k, n, r)
    per = 32 // bits
    kw, rw = -(-k // per), -(-r // per)
    aq = np.zeros((e, r, kw), np.uint32)
    a_s = np.zeros((e, r), np.float32)
    a_z = np.zeros((e, r), np.float32)
    bq = np.zeros((e, n, rw), np.uint32)
    b_s = np.zeros((e, n), np.float32)
    b_z = np.zeros((e, n), np.float32)
    adeq = np.zeros((e, k, r), np.float32)
    bdeq = np.zeros((e, r, n), np.float32)
    for i in range(e):
        w, s_, z = _pack_rows(np.asarray(a[i]).T, bits)   # rows = r chans
        aq[i], a_s[i], a_z[i] = w, s_, z
        lv = np.asarray(kref.unpack_words(jnp.asarray(w), bits))[:, :k]
        adeq[i] = ((lv - z[:, None]) * s_[:, None]).T
        w, s_, z = _pack_rows(np.asarray(b[i]).T, bits)   # rows = n chans
        bq[i], b_s[i], b_z[i] = w, s_, z
        lv = np.asarray(kref.unpack_words(jnp.asarray(w), bits))[:, :r]
        bdeq[i] = ((lv - z[:, None]) * s_[:, None]).T
    return ((jnp.asarray(aq), jnp.asarray(a_s), jnp.asarray(a_z),
             jnp.asarray(bq), jnp.asarray(b_s), jnp.asarray(b_z)),
            jnp.asarray(adeq), jnp.asarray(bdeq))


def _adapter_msg(rng, d, n_layers, r, qcfg, flat=False):
    tree = {"layers": [
        {"a": jnp.asarray(rng.standard_normal((d, r)) * 0.1, jnp.float32),
         "b": jnp.asarray(rng.standard_normal((r, d)) * 0.1, jnp.float32)}
        for _ in range(n_layers)]}
    return messages.pack_message(tree, qcfg, flat=flat)


def _mini_engine(n_clients=8, d=64, n_layers=2, ranks=(4, 8), bits=4,
                 capacity=1 << 20, policy="lru", path="fused"):
    weights, store = serve.make_store(n_clients=n_clients, d_model=d,
                                      n_layers=n_layers, ranks=ranks,
                                      bits=bits, seed=0)
    cache = serve.AdapterCache(capacity_bytes=capacity, qcfg=store.qcfg,
                               policy=policy)
    eng = serve.AdapterServingEngine(weights, scale=0.5, qcfg=store.qcfg,
                                     cache=cache, fetch=store.fetch,
                                     path=path)
    return eng, store


# -- kernel bit-parity vs jnp twins (interpret mode) ------------------------

def test_multi_lora_matmul_pallas_matches_twin():
    rng = np.random.default_rng(0)
    m, k, n, r, e = 16, 64, 128, 8, 5
    x = jnp.asarray(rng.standard_normal((m, k)) * 0.5, jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, n)) * 0.2, jnp.float32)
    a, b = _rand_slabs(rng, e, k, n, r)
    ids = jnp.asarray(rng.integers(0, e, m), jnp.int32)
    got = multi_lora_matmul_pallas(x, w, a, b, ids, 0.5, block_m=4,
                                   block_n=64, interpret=True)
    want = _multi_lora_matmul_jnp(x, w, a, b, ids, 0.5)
    assert jnp.array_equal(got, want), "pallas kernel != jnp twin"


@pytest.mark.parametrize("bits", [4, 8])
def test_multi_lora_matmul_q_pallas_matches_twin(bits):
    rng = np.random.default_rng(bits)
    m, k, n, r, e = 8, 64, 128, 8, 5
    x = jnp.asarray(rng.standard_normal((m, k)) * 0.5, jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, n)) * 0.2, jnp.float32)
    packed, _, _ = _pack_slabs(rng, e, k, n, r, bits)
    ids = jnp.asarray(rng.integers(0, e, m), jnp.int32)
    got = multi_lora_matmul_q_pallas(x, w, *packed, ids, 0.5, bits,
                                     block_m=4, block_n=64,
                                     interpret=True)
    want = _multi_lora_matmul_q_jnp(x, w, *packed, ids, 0.5, bits)
    assert jnp.array_equal(got, want), "packed pallas kernel != jnp twin"


@pytest.mark.parametrize("bits", [4, 8])
def test_packed_kernel_equals_fp_kernel_on_dequant(bits):
    """The fused dequant IS the codec's dequant: feeding the packed
    slabs through the q-kernel equals feeding their exact dequantized
    stacks through the fp kernel, to fp32 tolerance."""
    rng = np.random.default_rng(10 + bits)
    m, k, n, r, e = 8, 32, 64, 4, 3
    x = jnp.asarray(rng.standard_normal((m, k)) * 0.5, jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, n)) * 0.2, jnp.float32)
    packed, adeq, bdeq = _pack_slabs(rng, e, k, n, r, bits)
    ids = jnp.asarray(rng.integers(0, e, m), jnp.int32)
    got = kops.multi_lora_matmul_packed(x, w, *packed, ids, 0.5, bits)
    want = kops.multi_lora_matmul(x, w, adeq, bdeq, ids, 0.5)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=1e-5)


# -- engine vs the merged dense oracle --------------------------------------

@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("batch", [5, 8, 13])
def test_engine_fused_matches_dense_merge_oracle(bits, batch):
    eng, store = _mini_engine(n_clients=16, bits=bits)
    rng = np.random.default_rng(batch)
    cids = [int(c) for c in rng.integers(0, 16, batch)]  # mixed ranks
    eng.admit(cids)
    x = jnp.asarray(rng.standard_normal((batch, 64)) * 0.5, jnp.float32)
    y = eng.step(x, cids)
    y_oracle = eng.oracle_step(x, cids)
    np.testing.assert_allclose(y, y_oracle, atol=5e-5, rtol=1e-4)


def test_engine_dequant_baseline_matches_fused():
    eng, store = _mini_engine(path="fused")
    eng2 = serve.AdapterServingEngine(eng.weights, eng.scale, eng.qcfg,
                                      eng.cache, path="dequant")
    cids = [0, 1, 2, 3, 4, 5]
    eng.admit(cids)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((6, 64)) * 0.5, jnp.float32)
    np.testing.assert_allclose(eng.step(x, cids), eng2.step(x, cids),
                               atol=2e-5, rtol=1e-5)


def test_rank_bucket_padding_is_exact():
    """A rank-6 adapter served from the pow2-8 bucket slab: the padded
    A rows carry scale=0 sidecars, so their dequantized lanes are
    EXACTLY zero and contribute nothing — the output matches serving
    the compact rank-6 slab up to the dot reduction order of the
    differently-shaped program (~1 ulp)."""
    bits, d, r = 4, 32, 6
    qcfg = QuantConfig(bits=bits)
    rng = np.random.default_rng(7)
    weights = [jnp.asarray(rng.standard_normal((d, d)) * 0.05,
                           jnp.float32)]
    cache = serve.AdapterCache(capacity_bytes=1 << 20, qcfg=qcfg)
    msgs = {c: _adapter_msg(rng, d, 1, r, qcfg, flat=(c == 0))
            for c in range(3)}
    eng = serve.AdapterServingEngine(weights, 0.5, qcfg, cache,
                                     fetch=msgs.__getitem__,
                                     slab_slots=1)
    cids = [0, 1, 2, 0]
    eng.admit(cids)
    x = jnp.asarray(rng.standard_normal((4, d)) * 0.5, jnp.float32)
    y = eng.step(x, cids)

    # reference: compact rank-6 slabs, no bucket padding
    per = 32 // bits
    rw = -(-r // per)
    pairs = [cache.peek(c).pairs[0] for c in range(3)]
    aq = jnp.stack([jnp.asarray(p.aq) for p in pairs])
    a_s = jnp.stack([jnp.asarray(p.a_scale) for p in pairs])
    a_z = jnp.stack([jnp.asarray(p.a_zp) for p in pairs])
    bq = jnp.stack([jnp.asarray(p.bq[:, :rw]) for p in pairs])
    b_s = jnp.stack([jnp.asarray(p.b_scale) for p in pairs])
    b_z = jnp.stack([jnp.asarray(p.b_zp) for p in pairs])
    ids = jnp.asarray([0, 1, 2, 0], jnp.int32)
    want = kops.multi_lora_matmul_packed(x, weights[0], aq, a_s, a_z,
                                         bq, b_s, b_z, ids, 0.5, bits)
    np.testing.assert_allclose(y, want, atol=1e-6, rtol=1e-6)

    # the padded lanes really are exact zeros, not just small
    from repro.serve.engine import _dequant_stacks
    staged = eng.cache.stage([0, 1, 2], min_slots=1)[8]
    a_stack, _ = _dequant_stacks(staged.layers[0], bits, d, 8)
    assert np.all(np.asarray(a_stack)[:, :, r:] == 0.0)


def test_fused_path_never_materializes_fp32_adapters(monkeypatch):
    """The serving path must not call the codec's unpack or the pair's
    dequant — dequant lives INSIDE the fused matmul."""
    eng, store = _mini_engine()

    def boom(*a, **kw):
        raise AssertionError("fp32 adapter materialization on the "
                             "serving path")

    monkeypatch.setattr(messages, "unpack_message", boom)
    monkeypatch.setattr(serve.PackedPair, "dequant", boom)
    cids = [0, 1, 2, 3]
    eng.admit(cids)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((4, 64)) * 0.5, jnp.float32)
    jax.block_until_ready(eng.step(x, cids))


# -- adapter cache ----------------------------------------------------------

def _msgs(n, d=32, r=4, bits=4, seed=0):
    qcfg = QuantConfig(bits=bits)
    rng = np.random.default_rng(seed)
    return qcfg, {c: _adapter_msg(rng, d, 2, r, qcfg, flat=(c % 2 == 0))
                  for c in range(n)}


def test_cache_bytes_are_measured_wire_bytes():
    qcfg, msgs = _msgs(2)
    rng = np.random.default_rng(1)
    fp_tree = {"layers": [
        {"a": jnp.zeros((32, 4), jnp.float32),
         "b": jnp.zeros((4, 32), jnp.float32)} for _ in range(2)]}
    want = messages.message_wire_bytes(fp_tree, qcfg)
    cache = serve.AdapterCache(capacity_bytes=1 << 20, qcfg=qcfg)
    for c, m in msgs.items():
        assert serve.wire_bytes_of(m, qcfg) == want
        cache.put(c, m)
    assert cache.nbytes == 2 * want


def test_cache_lru_evicts_least_recent():
    qcfg, msgs = _msgs(3)
    one = serve.wire_bytes_of(msgs[0], qcfg)
    cache = serve.AdapterCache(capacity_bytes=2 * one, qcfg=qcfg)
    cache.put(0, msgs[0])
    cache.put(1, msgs[1])
    assert cache.lookup(0) is not None      # 0 is now most-recent
    cache.put(2, msgs[2])                   # evicts 1, not 0
    assert 0 in cache and 2 in cache and 1 not in cache
    assert cache.evictions == 1
    assert cache.nbytes <= cache.capacity_bytes


def test_cache_clock_gives_second_chance():
    qcfg, msgs = _msgs(3)
    one = serve.wire_bytes_of(msgs[0], qcfg)
    cache = serve.AdapterCache(capacity_bytes=2 * one, qcfg=qcfg,
                               policy="clock")
    cache.put(0, msgs[0])
    cache.put(1, msgs[1])
    cache.lookup(0)                         # ref bits: 0 set, 1 set(at put)
    cache._entries[1].ref = False           # 1 has not been referenced
    cache.put(2, msgs[2])                   # sweep spares 0, evicts 1
    assert 0 in cache and 1 not in cache


def test_cache_pinned_entries_survive_eviction():
    qcfg, msgs = _msgs(4)
    one = serve.wire_bytes_of(msgs[0], qcfg)
    cache = serve.AdapterCache(capacity_bytes=2 * one, qcfg=qcfg)
    cache.put(0, msgs[0])
    cache.put(1, msgs[1])
    cache.pin(0)
    cache.pin(0)                            # refcounted
    cache.put(2, msgs[2])                   # would evict LRU=0; skips it
    assert 0 in cache and 1 not in cache
    cache.unpin(0)
    cache.unpin(0)
    cache.put(3, msgs[3])                   # now 0 is evictable again
    assert 0 not in cache
    with pytest.raises(KeyError):
        cache.pin(99)


def test_cache_counters_and_hit_rate():
    qcfg, msgs = _msgs(2)
    cache = serve.AdapterCache(capacity_bytes=1 << 20, qcfg=qcfg)
    assert cache.lookup(0) is None
    cache.put(0, msgs[0])
    assert cache.lookup(0) is not None
    assert (cache.hits, cache.misses) == (1, 1)
    assert cache.hit_rate == 0.5
    cache.peek(1)                           # peek never counts
    assert cache.misses == 1


def test_extract_pairs_flat_and_per_leaf_agree():
    qcfg = QuantConfig(bits=4)
    rng = np.random.default_rng(5)
    tree = {"layers": [
        {"a": jnp.asarray(rng.standard_normal((32, 4)) * 0.1, jnp.float32),
         "b": jnp.asarray(rng.standard_normal((4, 32)) * 0.1, jnp.float32)}
        for _ in range(2)]}
    r1, p1 = serve.extract_pairs(
        messages.pack_message(tree, qcfg, flat=False), 4)
    r2, p2 = serve.extract_pairs(
        messages.pack_message(tree, qcfg, flat=True), 4)
    assert r1 == r2 == 4
    for q1, q2 in zip(p1, p2):
        np.testing.assert_array_equal(q1.aq, q2.aq)
        np.testing.assert_array_equal(q1.bq, q2.bq)
        np.testing.assert_array_equal(q1.a_scale, q2.a_scale)
        np.testing.assert_array_equal(q1.b_zp, q2.b_zp)


def test_cache_rejects_unpacked_messages():
    qcfg = QuantConfig(bits=4)
    cache = serve.AdapterCache(capacity_bytes=1 << 20, qcfg=qcfg)
    fp_tree = {"a": jnp.zeros((8, 2), jnp.float32),
               "b": jnp.zeros((2, 8), jnp.float32)}
    with pytest.raises(ValueError, match="wire form"):
        cache.put(0, fp_tree)


def test_stage_groups_by_pow2_bucket():
    eng, store = _mini_engine(n_clients=8, ranks=(4, 8))
    eng.admit(list(range(8)))
    staged = eng.cache.stage(list(range(8)))
    assert sorted(staged) == [4, 8]
    assert set(staged[4].slots) == {0, 2, 4, 6}
    assert set(staged[8].slots) == {1, 3, 5, 7}
    assert staged[4].layers[0].aq.shape[1] == 4   # rb rows
    assert staged[8].layers[0].aq.shape[1] == 8
    with pytest.raises(KeyError):
        eng.cache.stage([99])


# -- compile stability ------------------------------------------------------

def test_steady_state_decode_compiles_nothing():
    eng, store = _mini_engine(n_clients=16)
    cids = [0, 1, 2, 3, 8, 9, 10, 11]       # both rank buckets
    eng.admit(cids)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((8, 64)) * 0.5, jnp.float32)
    for _ in range(2):                       # warm every program + eager op
        jax.block_until_ready(eng.step(x, cids))
    # same batch width, different resident clients: still no compiles
    alt = [4, 5, 6, 7, 12, 13, 14, 15]
    eng.admit(alt)
    jax.block_until_ready(eng.step(x, alt))
    with count_compiles() as c:
        for _ in range(5):
            jax.block_until_ready(eng.step(x, cids))
        jax.block_until_ready(eng.step(x, alt))
    assert c.count == 0, f"steady-state decode compiled {c.count} programs"


# -- generate() -------------------------------------------------------------

def test_generate_matches_manual_loop():
    from repro.models import lm as LM
    from repro.core.lora import LoRAConfig
    cfg = LM.LMConfig(name="t", n_layers=2, d_model=64, n_heads=2,
                      n_kv_heads=1, head_dim=32, d_ff=128, vocab=64,
                      lora=LoRAConfig(rank=4, alpha=8.0))
    params = LM.init(jax.random.PRNGKey(0), cfg)
    frozen, train = params["frozen"], params["train"]
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (2, 6)), jnp.int32)
    gen = 5
    toks, timing = serve.generate(frozen, train, cfg, prompt, gen,
                                  max_seq=16)

    logits, caches, pos = jax.jit(
        lambda f, t, tok: LM.prefill(f, t, cfg, tok, max_seq=16))(
        frozen, train, prompt)
    decode = jax.jit(lambda f, t, tok, c, p: LM.decode_step(
        f, t, cfg, tok, c, p))
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    want = [tok]
    for _ in range(gen - 1):
        logits, caches = decode(frozen, train, tok, caches, pos)
        tok = jnp.argmax(logits[:, 0], -1)[:, None].astype(jnp.int32)
        pos = pos + 1
        want.append(tok)
    np.testing.assert_array_equal(toks, jnp.concatenate(want, 1))
    assert toks.shape == (2, gen)
    assert timing["decode_steps"] == gen - 1


# -- simulator --------------------------------------------------------------

def test_simulator_serves_every_request_deterministically():
    eng, store = _mini_engine(n_clients=8, d=32)
    wl = serve.WorkloadConfig(n_requests=12, rate_rps=5000.0,
                              gen_tokens=2, max_batch=4, seed=0)
    rep = serve.simulate(eng, store, wl)
    assert rep["requests"] == 12
    assert rep["hits"] + rep["misses"] == 12
    assert 0.0 <= rep["hit_rate"] <= 1.0
    assert rep["p99_ms"] >= rep["p50_ms"] > 0
    assert rep["requests_per_s"] > 0
    # the trace itself is a pure function of the seed
    from repro.serve.simulator import _draw_requests
    r1 = _draw_requests(store, wl)
    r2 = _draw_requests(store, wl)
    assert [(r.cid, r.t_arrive) for r in r1] == \
        [(r.cid, r.t_arrive) for r in r2]


@pytest.mark.slow
def test_simulator_fleet_scale_with_evictions():
    """1024-adapter store, cache sized to ~16 adapters: the workload
    must finish with real evictions and a sane hit rate on both
    paths."""
    weights, store = serve.make_store(n_clients=1024, d_model=64,
                                      n_layers=2, ranks=(4, 8), bits=4,
                                      seed=0)
    total = sum(store.bytes_of(c) for c in store.cids)
    reports = {}
    for path in ("fused", "dequant"):
        cache = serve.AdapterCache(capacity_bytes=total // 64,
                                   qcfg=store.qcfg, policy="clock")
        eng = serve.AdapterServingEngine(weights, 0.5, store.qcfg, cache,
                                         fetch=store.fetch, path=path)
        wl = serve.WorkloadConfig(n_requests=160, rate_rps=4000.0,
                                  gen_tokens=4, max_batch=8,
                                  zipf_a=1.0, seed=1)
        reports[path] = serve.simulate(eng, store, wl)
    for rep in reports.values():
        assert rep["evictions"] > 0
        assert 0.0 < rep["hit_rate"] < 1.0
        assert rep["requests"] == 160
        # arrivals are seed-deterministic, so the total admission
        # traffic is identical even though batch timing (measured wall
        # clock) differs per path
        assert rep["hits"] + rep["misses"] == 160
