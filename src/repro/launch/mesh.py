"""Production meshes (defined as FUNCTIONS — importing this module never
touches jax device state).

Single pod:  (data=16, model=16)            = 256 chips (TPU v5e pod)
Multi-pod:   (pod=2, data=16, model=16)     = 512 chips; the 'pod' axis
crosses DCN — FLoCoRA's quantized adapter exchange is the only traffic
that ever crosses it (DESIGN.md §3).
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = np.asarray(jax.devices()[:n]).reshape(shape)
    return Mesh(devices, axes)


def make_host_mesh() -> Mesh:
    """1-device mesh for smoke tests / laptop runs (elastic lower bound)."""
    return Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))


def make_client_mesh(n_devices: int | None = None) -> Mesh:
    """1-D mesh over the ``clients`` axis for fleet-scale cohort
    reduction: the flat wire buffer's K client dim shards across it and
    each device folds its shard through the K-tiled dequant-agg kernel
    (``kernels.ops.dequant_agg_rows_sharded`` /
    ``core.flat.fedavg_packed_flat_sharded``)."""
    devs = jax.devices()
    n = len(devs) if n_devices is None else min(int(n_devices), len(devs))
    return Mesh(np.asarray(devs[:n]), ("clients",))
