"""Metrics registry: labeled counters, gauges and histograms.

One place to record what the system actually did — bytes on the wire,
staleness at arrival, cache churn, compile counts — instead of ad-hoc
dicts and plain-int attributes scattered across the engines.

Design constraints, in order:

  * NEAR-ZERO OVERHEAD WHEN DISABLED. The default process-global
    registry starts disabled; every record call checks one bool and
    returns. Hot paths (the serve decode step, the async event loop)
    instrument unconditionally and rely on this.
  * LABELED. A counter is a family keyed by label values —
    ``reg.inc("wire.up_bytes", n, rank=8, density=0.1)`` — so the
    bits x density x rank x staleness knob grid lands in one metric,
    not a name explosion.
  * INJECTABLE. Engines take ``registry=None`` meaning the process
    default (:func:`default_registry`), or an explicit
    :class:`MetricsRegistry` instance for isolated measurement (tests
    construct their own and never see each other's counts).

``dump()`` renders everything as one plain-JSON dict (label sets
serialize as ``"k=v,k=v"`` strings), the "metrics dump" the README's
observability section documents.
"""
from __future__ import annotations

import bisect
import dataclasses
import json
import math
import threading
from typing import Any, Optional

# default histogram bucket upper bounds: pow2-ish ladder wide enough
# for staleness (versions), queue depths and microsecond latencies
DEFAULT_BUCKETS = (0.0, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0,
                   256.0, 1024.0, 4096.0, 16384.0, 65536.0)


def _label_key(labels: dict) -> str:
    """Canonical string form of a label set (sorted, JSON-friendly)."""
    if not labels:
        return ""
    return ",".join(f"{k}={labels[k]}" for k in sorted(labels))


@dataclasses.dataclass
class Counter:
    """Monotonic sum per label set."""
    name: str
    values: dict = dataclasses.field(default_factory=dict)

    def inc(self, value: float = 1.0, **labels) -> None:
        k = _label_key(labels)
        self.values[k] = self.values.get(k, 0.0) + value

    @property
    def total(self) -> float:
        return sum(self.values.values())

    def get(self, **labels) -> float:
        return self.values.get(_label_key(labels), 0.0)


@dataclasses.dataclass
class Gauge:
    """Last-write-wins value per label set."""
    name: str
    values: dict = dataclasses.field(default_factory=dict)

    def set(self, value: float, **labels) -> None:
        self.values[_label_key(labels)] = value

    def get(self, **labels) -> Optional[float]:
        return self.values.get(_label_key(labels))


@dataclasses.dataclass
class _HistState:
    count: int = 0
    sum: float = 0.0
    min: float = math.inf
    max: float = -math.inf
    bucket_counts: Optional[list] = None


@dataclasses.dataclass
class Histogram:
    """Count/sum/min/max plus cumulative-bucket counts per label set.

    ``buckets`` are upper bounds (``le``); observations above the last
    bound land in the implicit +inf bucket."""
    name: str
    buckets: tuple = DEFAULT_BUCKETS
    values: dict = dataclasses.field(default_factory=dict)

    def observe(self, value: float, **labels) -> None:
        k = _label_key(labels)
        st = self.values.get(k)
        if st is None:
            st = _HistState(bucket_counts=[0] * (len(self.buckets) + 1))
            self.values[k] = st
        st.count += 1
        st.sum += value
        st.min = min(st.min, value)
        st.max = max(st.max, value)
        st.bucket_counts[bisect.bisect_left(self.buckets, value)] += 1

    def get(self, **labels) -> Optional[_HistState]:
        return self.values.get(_label_key(labels))

    def mean(self, **labels) -> float:
        st = self.get(**labels)
        if st is None or st.count == 0:
            return float("nan")
        return st.sum / st.count


class MetricsRegistry:
    """Get-or-create store of named metrics. All record paths are
    guarded by ``enabled`` — a disabled registry does one attribute
    check per call and touches nothing."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}
        self._lock = threading.Lock()

    # -- get-or-create -----------------------------------------------------
    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            with self._lock:
                c = self._counters.setdefault(name, Counter(name))
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            with self._lock:
                g = self._gauges.setdefault(name, Gauge(name))
        return g

    def histogram(self, name: str,
                  buckets: tuple = DEFAULT_BUCKETS) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            with self._lock:
                h = self._histograms.setdefault(
                    name, Histogram(name, buckets))
        return h

    # -- record (no-ops when disabled) -------------------------------------
    def inc(self, name: str, value: float = 1.0, **labels) -> None:
        if not self.enabled:
            return
        self.counter(name).inc(value, **labels)

    def set(self, name: str, value: float, **labels) -> None:
        if not self.enabled:
            return
        self.gauge(name).set(value, **labels)

    def observe(self, name: str, value: float, **labels) -> None:
        if not self.enabled:
            return
        self.histogram(name).observe(value, **labels)

    # -- read --------------------------------------------------------------
    def counter_value(self, name: str, **labels) -> float:
        c = self._counters.get(name)
        if c is None:
            return 0.0
        return c.total if not labels else c.get(**labels)

    def dump(self) -> dict:
        """Everything as one plain-JSON dict."""
        out: dict[str, Any] = {"counters": {}, "gauges": {},
                               "histograms": {}}
        for name, c in sorted(self._counters.items()):
            out["counters"][name] = dict(sorted(c.values.items()))
        for name, g in sorted(self._gauges.items()):
            out["gauges"][name] = dict(sorted(g.values.items()))
        for name, h in sorted(self._histograms.items()):
            out["histograms"][name] = {
                k: {"count": st.count, "sum": st.sum,
                    "min": st.min if st.count else None,
                    "max": st.max if st.count else None,
                    "buckets": list(h.buckets),
                    "bucket_counts": list(st.bucket_counts)}
                for k, st in sorted(h.values.items())}
        return out

    def dump_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.dump(), f, indent=1, default=str)

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()


# -- process-global default (disabled until someone opts in) ---------------
_DEFAULT = MetricsRegistry(enabled=False)


def default_registry() -> MetricsRegistry:
    return _DEFAULT


def set_default_registry(reg: MetricsRegistry) -> MetricsRegistry:
    """Swap the process default (returns the previous one, so callers
    can restore it — tests use try/finally around this)."""
    global _DEFAULT
    prev, _DEFAULT = _DEFAULT, reg
    return prev


def get_registry(reg: Optional[MetricsRegistry]) -> MetricsRegistry:
    """Injection helper: an explicit instance wins, None means the
    process default."""
    return _DEFAULT if reg is None else reg
