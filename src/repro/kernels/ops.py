"""Jit'd public wrappers for the Pallas kernels.

Pad-to-alignment, channel-first reshaping from arbitrary tensors, and
backend dispatch: on TPU the kernels compile natively; on CPU (this
container) they run in interpret mode — same kernel body, Python
execution, used by the test-suite oracles.
"""
from __future__ import annotations

import functools
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.kernels import ref
from repro.kernels.dequant_agg import dequant_agg_pallas, \
    dequant_agg_rows_pallas, pick_block_k
from repro.kernels.lora_matmul import lora_matmul_pallas, \
    multi_lora_matmul_pallas, multi_lora_matmul_q_pallas
from repro.kernels.quant_pack import quant_pack_pallas

Array = jax.Array


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def lane_levels(bits: int) -> int:
    """Kernel column alignment in LEVELS: 32/bits levels per uint32 word
    x 128 lanes. The single source of truth for the codecs' payload
    padding (per-leaf ``messages._pack_rows`` and the flat layout's
    ``n_max`` must agree on it, or byte identity breaks)."""
    return (32 // bits) * 128


def _pad_to(x: Array, mult: int, axis: int) -> Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@partial(jax.jit, static_argnames=("bits", "block_c"))
def quant_pack(x2d: Array, bits: int, block_c: int = 8):
    """x2d: (C, N) channel-first fp32 view of a message tensor."""
    per = 32 // bits
    lane = per * 128
    xp = _pad_to(_pad_to(x2d, block_c, 0), lane, 1)
    packed, scale, zp = quant_pack_pallas(xp, bits, n_valid=x2d.shape[1],
                                          block_c=block_c,
                                          interpret=_interpret())
    c = x2d.shape[0]
    return packed[:c], scale[:c], zp[:c]


def _quant_pack_rows_jnp(x2d: Array, nv: Array, bits: int):
    """Bit-identical jnp twin of the ragged-row quant_pack kernel (same
    formulas elementwise, exact min/max reductions, same little-endian
    word packing)."""
    qmax = (1 << bits) - 1
    col = jax.lax.broadcasted_iota(jnp.int32, x2d.shape, 1)
    valid = col < nv[:, None]
    big = jnp.float32(3.4e38)
    x = x2d.astype(jnp.float32)
    xmin = jnp.minimum(jnp.min(jnp.where(valid, x, big), axis=1), 0.0)
    xmax = jnp.maximum(jnp.max(jnp.where(valid, x, -big), axis=1), 0.0)
    rng = xmax - xmin
    scale = jnp.where(rng > 0, rng * jnp.float32(1.0 / qmax), 1.0)
    zp = jnp.clip(jnp.round(-xmin / scale), 0, qmax)
    q = jnp.round(x / scale[:, None]) + zp[:, None]
    q = jnp.where(valid, jnp.clip(q, 0, qmax), 0).astype(jnp.uint32)
    return ref.pack_words(q, bits), scale, zp


@partial(jax.jit, static_argnames=("bits", "block_c"))
def quant_pack_rows(x2d: Array, n_valid: Array, bits: int,
                    block_c: int = 8):
    """Ragged-row variant for the flat-tree codec: ``n_valid`` is a (C,)
    int32 vector of per-row true lengths (rows are different leaves'
    channels, so their valid widths differ). Columns must already be
    padded to the kernel lane multiple (core/flat.py sizes the buffer).
    One launch packs the WHOLE message.

    Off-TPU this lowers to the bit-identical jnp twin INSIDE the same
    jitted program (still one dispatch): the interpret-mode grid walk
    scales with C_total and would tax exactly the per-message overhead
    the flat codec removes."""
    nv = jnp.asarray(n_valid, jnp.int32)
    if _interpret():
        return _quant_pack_rows_jnp(x2d, nv, bits)
    xp = _pad_to(x2d, block_c, 0)
    packed, scale, zp = quant_pack_pallas(xp, bits,
                                          n_valid=_pad_to(nv, block_c, 0),
                                          block_c=block_c)
    c = x2d.shape[0]
    return packed[:c], scale[:c], zp[:c]


@partial(jax.jit, static_argnames=("bits", "block_c", "block_k"))
def dequant_agg_rows(packed: Array, scale: Array, zp: Array,
                     weights: Array, n_valid: Array, bits: int,
                     block_c: int = 8,
                     block_k: int | None = None) -> Array:
    """Flat-tree cohort aggregate: packed (K, C, Nw), sidecars (K, C),
    per-row lengths (C,). ONE launch unpacks + dequantizes + reduces the
    whole K-client message set; row tails come back as exact zeros.
    ``block_k`` (default: VMEM-budget auto-pick) tiles the client dim so
    fleet-scale cohorts stream through a bounded working set.
    Off-TPU: the jnp twin inside the same program, K-chunked via scan
    past one tile so time stays linear in K and memory flat."""
    nv = jnp.asarray(n_valid, jnp.int32)
    w = weights.astype(jnp.float32)
    zpz = jnp.where(scale > 0, zp, 0.0)
    k, c, nw = packed.shape
    bk = pick_block_k(k, nw, bits, block_c) if block_k is None \
        else int(block_k)
    if _interpret():
        if k <= bk:
            lv = ref.unpack_words(packed, bits).astype(jnp.float32)
            deq = (lv - zpz[..., None]) * scale[..., None]
            out = jnp.einsum("k,kcn->cn", w, deq)
        else:
            nt = -(-k // bk)
            pc = _pad_to(packed, bk, 0).reshape(nt, bk, c, nw)
            sc = _pad_to(scale, bk, 0).reshape(nt, bk, c)
            zc = _pad_to(zpz, bk, 0).reshape(nt, bk, c)
            wc = _pad_to(w, bk, 0).reshape(nt, bk)

            def fold(acc, xs):
                p, s, z, wt = xs
                lv = ref.unpack_words(p, bits).astype(jnp.float32)
                deq = (lv - z[..., None]) * s[..., None]
                return acc + jnp.einsum("k,kcn->cn", wt, deq), None

            out, _ = jax.lax.scan(
                fold, jnp.zeros((c, nw * (32 // bits)), jnp.float32),
                (pc, sc, zc, wc))
        col = jax.lax.broadcasted_iota(jnp.int32, out.shape, 1)
        return jnp.where(col < nv[:, None], out, 0.0)
    return dequant_agg_rows_pallas(packed, scale, zpz, w, nv, bits,
                                   block_c=block_c, block_k=bk)


# -- mesh-sharded cohort reduction (the scale-out layer) --------------------

CLIENT_AXIS = "clients"


@functools.lru_cache(maxsize=None)
def _sharded_agg_fn(mesh: Mesh, axis: str, bits: int, block_c: int,
                    block_k: int | None):
    from jax.experimental.shard_map import shard_map

    spec = P(axis)

    @partial(shard_map, mesh=mesh,
             in_specs=(spec, spec, spec, spec, P()), out_specs=P(),
             check_rep=False)
    def _local(p, s, z, w, nv):
        part = dequant_agg_rows(p, s, z, w, nv, bits, block_c=block_c,
                                block_k=block_k)
        return jax.lax.psum(part, axis)

    return jax.jit(_local)


def dequant_agg_rows_sharded(packed: Array, scale: Array, zp: Array,
                             weights: Array, n_valid: Array, bits: int,
                             mesh: Mesh, axis: str = CLIENT_AXIS,
                             block_c: int = 8,
                             block_k: int | None = None) -> Array:
    """``dequant_agg_rows`` with the K client dim sharded over ``axis``
    of ``mesh`` (``launch.mesh.make_client_mesh``): every device folds
    its local client shard through the K-tiled kernel and ONE psum
    combines the partial sums, so aggregate reduction bandwidth scales
    with the device count. K pads to the axis size with zero-weight
    phantom clients (exact-zero contributions)."""
    n_sh = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    k = packed.shape[0]
    if k % n_sh:
        packed = _pad_to(packed, n_sh, 0)
        scale = _pad_to(scale, n_sh, 0)
        zp = _pad_to(zp, n_sh, 0)
        weights = _pad_to(weights.astype(jnp.float32), n_sh, 0)
    fn = _sharded_agg_fn(mesh, axis, bits, block_c, block_k)
    return fn(packed, scale, zp, weights.astype(jnp.float32),
              jnp.asarray(n_valid, jnp.int32))


@partial(jax.jit, static_argnames=("bits", "block_c"))
def dequant_agg(packed: Array, scale: Array, zp: Array, weights: Array,
                bits: int, block_c: int = 8,
                n_valid: Array | None = None) -> Array:
    """``n_valid`` (optional (C,) vector) masks each row's tail to exact
    zero — the flat-tree codec aggregates every leaf of a K-client
    cohort in one launch and slices the rows apart afterwards."""
    nvp = None if n_valid is None else jnp.asarray(n_valid, jnp.int32)
    return dequant_agg_pallas(packed, scale,
                              jnp.where(scale > 0, zp, 0.0), weights,
                              bits, n_valid=nvp, block_c=block_c,
                              interpret=_interpret())


@partial(jax.jit, static_argnames=("s",))
def lora_matmul(x: Array, w: Array, a: Array, b: Array, s: float) -> Array:
    """Fused y = x@w + s*(x@a)@b. Pads r to 128 lanes; picks MXU-aligned
    blocks that divide the (padded) problem."""
    m, k = x.shape
    n = w.shape[1]
    r = a.shape[1]
    rp = max(128, ((r + 127) // 128) * 128)
    ap = _pad_to(a, rp, 1)
    bp = _pad_to(b, rp, 0)

    def blk(dim, target):
        t = min(target, dim)
        while dim % t:
            t //= 2
        return max(t, 1)

    bm, bn, bk = blk(m, 256), blk(n, 256), blk(k, 512)
    return lora_matmul_pallas(x, w, ap, bp, s, block_m=bm, block_n=bn,
                              block_k=bk, interpret=_interpret())


# -- batched multi-adapter serving matmuls (the multi-tenant read path) -----

def _blk(dim: int, target: int) -> int:
    t = min(target, dim)
    while dim % t:
        t //= 2
    return max(t, 1)


def _multi_lora_matmul_jnp(x: Array, w: Array, a_stack: Array,
                           b_stack: Array, ids: Array, s: float) -> Array:
    """Bit-identical jnp twin of the multi-adapter kernel (same gather
    semantics, same batched dot_generals, fp32 accumulation)."""
    acc = jnp.dot(x, w, preferred_element_type=jnp.float32)
    am = jnp.take(a_stack, ids, axis=0)                   # (M, K, R)
    bm = jnp.take(b_stack, ids, axis=0)                   # (M, R, N)
    h = jax.lax.dot_general(x, am, (((1,), (1,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32)
    y = jax.lax.dot_general(h.astype(bm.dtype), bm,
                            (((1,), (1,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32)
    return (acc + s * y).astype(x.dtype)


@partial(jax.jit, static_argnames=("s",))
def multi_lora_matmul(x: Array, w: Array, a_stack: Array, b_stack: Array,
                      ids: Array, s: float) -> Array:
    """Batched multi-adapter  y[m] = x[m]@w + s*(x[m]@A[ids[m]])@B[ids[m]].

    ``a_stack`` (E, K, R) / ``b_stack`` (E, R, N) are a rank bucket's
    staged adapter slab; ``ids`` (M,) int32 picks each request row's
    slot. Off-TPU this lowers to the bit-identical jnp twin inside the
    same jitted program (the per-row gather walk would tax interpret
    mode with exactly the per-request overhead batching removes)."""
    ids = jnp.asarray(ids, jnp.int32)
    if _interpret():
        return _multi_lora_matmul_jnp(x, w, a_stack, b_stack, ids, s)
    m, k = x.shape
    n = w.shape[1]
    r = a_stack.shape[2]
    rp = max(128, ((r + 127) // 128) * 128)
    ap = _pad_to(a_stack, rp, 2)
    bp = _pad_to(b_stack, rp, 1)
    mp = -(-m // 8) * 8
    xp = _pad_to(x, 8, 0)
    idp = _pad_to(ids, 8, 0)
    out = multi_lora_matmul_pallas(xp, w, ap, bp, idp, s,
                                   block_m=8, block_n=_blk(n, 256))
    return out[:m] if mp != m else out


def _multi_lora_matmul_q_jnp(x: Array, w: Array, aq: Array, a_scale: Array,
                             a_zp: Array, bq: Array, b_scale: Array,
                             b_zp: Array, ids: Array, s: float,
                             bits: int) -> Array:
    """Bit-identical jnp twin of the fused wire-format kernel: gather
    PACKED words by row id, unpack + dequant + matmul in one program —
    the fp32 adapter values exist only as a transient inside the jit."""
    k = x.shape[1]
    r = a_scale.shape[1]
    xf = x.astype(jnp.float32)
    acc = jnp.dot(xf, w.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    aw = jnp.take(aq, ids, axis=0)                        # (M, R, KW)
    asc = jnp.take(a_scale, ids, axis=0)
    azp = jnp.take(a_zp, ids, axis=0)
    bw = jnp.take(bq, ids, axis=0)                        # (M, N, RW)
    bsc = jnp.take(b_scale, ids, axis=0)
    bzp = jnp.take(b_zp, ids, axis=0)
    la = ref.unpack_words(aw, bits)[..., :k].astype(jnp.float32)
    adeq = (la - azp[..., None]) * asc[..., None]         # (M, R, K)
    lb = ref.unpack_words(bw, bits)[..., :r].astype(jnp.float32)
    bdeq = (lb - bzp[..., None]) * bsc[..., None]         # (M, N, R)
    h = jax.lax.dot_general(xf, adeq, (((1,), (2,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32)
    y = jax.lax.dot_general(h, bdeq, (((1,), (2,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32)
    return (acc + s * y).astype(x.dtype)


@partial(jax.jit, static_argnames=("s", "bits"))
def multi_lora_matmul_packed(x: Array, w: Array, aq: Array, a_scale: Array,
                             a_zp: Array, bq: Array, b_scale: Array,
                             b_zp: Array, ids: Array, s: float,
                             bits: int) -> Array:
    """The FUSED wire-format serving matmul: adapters stay in the packed
    uint32 wire form (channel-first rows + fp32 scale/zp sidecars, the
    ``quant_pack``/``core/flat.py`` layout) and dequant fuses into the
    matmul — an uplinked adapter serves without ever materializing an
    fp32 adapter tree. Slab layout: aq (E, R, KW), sidecars (E, R);
    bq (E, N, RW), sidecars (E, N); compact word counts (KW*per >= K,
    RW*per >= R, zero tails). Rank-bucket padding rides rows with
    scale=0 sidecars (exact-zero contributions)."""
    ids = jnp.asarray(ids, jnp.int32)
    if _interpret():
        return _multi_lora_matmul_q_jnp(x, w, aq, a_scale, a_zp, bq,
                                        b_scale, b_zp, ids, s, bits)
    m = x.shape[0]
    n = w.shape[1]
    xp = _pad_to(x, 8, 0)
    idp = _pad_to(ids, 8, 0)
    out = multi_lora_matmul_q_pallas(xp, w, aq, a_scale, a_zp, bq,
                                     b_scale, b_zp, idp, s, bits,
                                     block_m=8, block_n=_blk(n, 256))
    return out[:m] if out.shape[0] != m else out


# ---------------------------------------------------------------------------
# Channel-first 2D views (the CANONICAL helpers — the codec's last-axis-
# channel convention; every kernel caller reshapes through these)
# ---------------------------------------------------------------------------

def to_channel_first_2d(x: Array, per_stack: bool = False) -> Array:
    """(..., C) -> (C, prod(...)): the channel-first 2D view matching the
    per-channel qparam groups. ``per_stack`` keeps a leading stack dim's
    slices as separate qparam rows ((s*C, n) for an (s, n, C) tensor)."""
    if per_stack and x.ndim >= 3:
        s = int(np.prod(x.shape[:-2]))
        x3 = jnp.swapaxes(x.reshape(s, x.shape[-2], x.shape[-1]), -1, -2)
        return x3.reshape(s * x.shape[-1], x.shape[-2])
    xm = jnp.moveaxis(x, -1, 0)
    return xm.reshape(x.shape[-1], -1)


def from_channel_first_2d(x2d: Array, shape: tuple,
                          per_stack: bool = False) -> Array:
    """Inverse of :func:`to_channel_first_2d` for a target ``shape``."""
    if per_stack and len(shape) >= 3:
        s = int(np.prod(shape[:-2]))
        x3 = x2d.reshape(s, shape[-1], shape[-2])
        return jnp.swapaxes(x3, -1, -2).reshape(shape)
    x = x2d.reshape((shape[-1],) + tuple(shape[:-1]))
    return jnp.moveaxis(x, 0, -1)
