"""Deliverable (f): per-architecture smoke tests — a REDUCED config of
the same family runs one forward/train step on CPU; shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY
from repro.models import encdec as ED
from repro.models import lm as LM

ARCHS = sorted(REGISTRY)


def _batch(rng, cfg, kind):
    if kind == "encdec":
        return {"src_embed": jnp.asarray(
                    rng.normal(size=(2, 16, cfg.d_model)), jnp.bfloat16),
                "tgt_tokens": jnp.asarray(
                    rng.integers(0, cfg.vocab, (2, 17)), jnp.int32)}
    b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 17)),
                               jnp.int32)}
    if cfg.prefix_lm:
        b["prefix_embed"] = jnp.asarray(
            rng.normal(size=(2, cfg.prefix_len, cfg.d_model)), jnp.bfloat16)
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    e = REGISTRY[arch]
    cfg = e.smoke()
    mod = ED if e.kind == "encdec" else LM
    rng = np.random.default_rng(0)
    p = mod.init(jax.random.PRNGKey(0), cfg)
    batch = _batch(rng, cfg, e.kind)

    loss, metrics = jax.jit(
        lambda f, t, b: mod.loss_fn(f, t, cfg, b))(
        p["frozen"], p["train"], batch)
    assert np.isfinite(float(loss)), f"{arch}: loss NaN"
    assert float(loss) > 0

    # one SGD step on the trainable tree only
    grads = jax.jit(jax.grad(
        lambda t: mod.loss_fn(p["frozen"], t, cfg, batch)[0]))(p["train"])
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat), \
        f"{arch}: non-finite grads"
    assert any(float(jnp.max(jnp.abs(g))) > 0 for g in flat), \
        f"{arch}: all-zero grads"
    # frozen tree must receive no gradient (it is not differentiated)
    new_train = jax.tree.map(lambda p_, g: p_ - 0.01 * g.astype(p_.dtype),
                             p["train"], grads)
    loss2, _ = jax.jit(
        lambda f, t, b: mod.loss_fn(f, t, cfg, b))(
        p["frozen"], new_train, batch)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if REGISTRY[a].kind == "lm"])
def test_smoke_decode_shapes(arch):
    e = REGISTRY[arch]
    cfg = e.smoke()
    p = LM.init(jax.random.PRNGKey(0), cfg)
    caches = LM.cache_init(cfg, 2, 24)
    tok = jnp.zeros((2, 1), jnp.int32)
    logits, new_caches = jax.jit(
        lambda f, t, tok, c: LM.decode_step(f, t, cfg, tok, c,
                                            jnp.asarray(5, jnp.int32)))(
        p["frozen"], p["train"], tok, caches)
    assert logits.shape == (2, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert jax.tree.structure(caches) == jax.tree.structure(new_caches)
