"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU; shape/dtype
sweeps + hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:         # optional dep: property tests skip, rest runs
    given = settings = st = None

from repro.kernels import ops, ref


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("shape", [(8, 2048), (16, 512), (5, 100), (1, 64)])
def test_quant_pack_matches_ref(bits, shape):
    per = 32 // bits
    x = jax.random.normal(jax.random.fold_in(jax.random.PRNGKey(0), bits),
                          shape) * 2.0
    p, s, z = ops.quant_pack(x, bits)
    # reconstruct and compare against the direct jnp reference
    pad_n = (-shape[1]) % (per * 128)
    pr, sr, zr = ref.quant_pack_ref(
        jnp.pad(x, ((0, 0), (0, pad_n))), bits)
    lv = ref.unpack_words(p, bits)[:, : shape[1]]
    lvr = ref.unpack_words(pr, bits)[: shape[0], : shape[1]]
    rec = (lv.astype(jnp.float32) - z[:, None]) * s[:, None]
    recr = (lvr.astype(jnp.float32) - zr[: shape[0], None]) \
        * sr[: shape[0], None]
    np.testing.assert_allclose(np.asarray(rec), np.asarray(recr),
                               atol=1e-5)
    # and the quantization bound holds
    err = float(jnp.max(jnp.abs(rec - x)))
    assert err <= float(jnp.max(s)) / 2 + 1e-5


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("k", [1, 3, 7])
def test_dequant_agg_matches_ref(bits, k):
    key = jax.random.PRNGKey(k)
    c, n = 16, 32 * (32 // bits)
    xs = jax.random.normal(key, (k, c, n))
    packs, ss, zs = [], [], []
    for i in range(k):
        p, s, z = ref.quant_pack_ref(xs[i], bits)
        packs.append(p)
        ss.append(s)
        zs.append(z)
    packed = jnp.stack(packs)
    sc = jnp.stack(ss)
    zp = jnp.stack(zs)
    w = jax.random.uniform(key, (k,)) + 0.1
    out = ops.dequant_agg(packed, sc, zp, w, bits)
    outr = ref.dequant_agg_ref(packed, sc, zp, w, bits)
    np.testing.assert_allclose(np.asarray(out), np.asarray(outr),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("m,k,n,r", [
    (128, 256, 192, 8), (64, 128, 128, 32), (256, 512, 256, 128),
    (8, 128, 128, 4),
])
def test_lora_matmul_matches_ref(m, k, n, r):
    key = jax.random.PRNGKey(0)
    x = (jax.random.normal(key, (m, k)) * 0.5).astype(jnp.bfloat16)
    w = (jax.random.normal(jax.random.fold_in(key, 1), (k, n)) * 0.1
         ).astype(jnp.bfloat16)
    a = (jax.random.normal(jax.random.fold_in(key, 2), (k, r)) * 0.1
         ).astype(jnp.bfloat16)
    b = (jax.random.normal(jax.random.fold_in(key, 3), (r, n)) * 0.1
         ).astype(jnp.bfloat16)
    y = ops.lora_matmul(x, w, a, b, 2.0).astype(jnp.float32)
    yr = ref.lora_matmul_ref(x, w, a, b, 2.0).astype(jnp.float32)
    scale = float(jnp.max(jnp.abs(yr))) + 1e-6
    assert float(jnp.max(jnp.abs(y - yr))) / scale < 2e-2   # bf16 tol


def test_lora_matmul_zero_adapter_is_base():
    key = jax.random.PRNGKey(0)
    x = (jax.random.normal(key, (64, 128))).astype(jnp.bfloat16)
    w = (jax.random.normal(jax.random.fold_in(key, 1), (128, 128)) * 0.1
         ).astype(jnp.bfloat16)
    a = (jax.random.normal(jax.random.fold_in(key, 2), (128, 8)) * 0.1
         ).astype(jnp.bfloat16)
    b = jnp.zeros((8, 128), jnp.bfloat16)
    y = ops.lora_matmul(x, w, a, b, 16.0).astype(jnp.float32)
    yr = (x.astype(jnp.float32) @ w.astype(jnp.float32))
    scale = float(jnp.max(jnp.abs(yr))) + 1e-6
    assert float(jnp.max(jnp.abs(y - yr))) / scale < 1e-2


if st is not None:
    @settings(max_examples=20, deadline=None)
    @given(bits=st.sampled_from([2, 4, 8]), c=st.integers(1, 24),
           n=st.integers(2, 200), seed=st.integers(0, 2**31 - 1))
    def test_property_quant_pack_sweep(bits, c, n, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(c, n)) * rng.uniform(0.01, 10),
                        jnp.float32)
        p, s, z = ops.quant_pack(x, bits)
        lv = ref.unpack_words(p, bits)[:, :n]
        rec = (lv.astype(jnp.float32) - z[:, None]) * s[:, None]
        err = np.asarray(jnp.abs(rec - x))
        assert (err <= np.asarray(s)[:, None] / 2 + 1e-4).all()


if st is None:
    def test_property_quant_pack_sweep():
        pytest.skip("hypothesis not installed")
