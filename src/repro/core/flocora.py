"""FLoCoRA high-level API (paper §III, Fig. 1).

One communication round:
  (1) server broadcasts global adapter tree  Δ̄_t L        (quantized)
  (2) each sampled client k trains locally   Δ^k_{t+1} L
  (3) client uploads its adapter tree                       (quantized)
  (4) server FedAvg-aggregates:  Δ̄_{t+1} L = Σ_k (n_k/n) Δ^k_{t+1} L

The base model W_initial is exchanged exactly once (round 0) and never
updated — that is the whole trick. ``server_round``/``broadcast`` are the
jittable pieces; orchestration (sampling, stragglers, faults) lives in
``repro.fl``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import aggregation, lora, messages
from repro.core.quant import QuantConfig

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class RankSchedule:
    """Per-client LoRA rank profile with optional round-wise annealing.

    ``client_ranks[cid]`` is client cid's base adapter rank (phones get
    r=4, workstations r=32, ...). With ``anneal_every > 0`` every
    client's rank is multiplied by ``anneal_factor`` each
    ``anneal_every`` rounds (floored at ``min_rank``) — late-training
    updates concentrate in fewer directions, so the wire shrinks as the
    run converges.

    The server holds the global adapters at ``max_rank``; broadcast
    truncates (slice) and uplinks arrive at each client's rank. The
    effective alpha/r scale is the SERVER config's and is shared by all
    clients, so mixed-rank products stay directly comparable."""
    client_ranks: tuple[int, ...]
    anneal_every: int = 0
    anneal_factor: float = 0.5
    min_rank: int = 2

    def __post_init__(self):
        if not self.client_ranks:
            raise ValueError("RankSchedule needs at least one client rank")
        if any(r < 1 for r in self.client_ranks):
            raise ValueError(f"ranks must be >= 1: {self.client_ranks}")
        if self.anneal_every < 0:
            raise ValueError("anneal_every must be >= 0")
        if not 0.0 < self.anneal_factor <= 1.0:
            raise ValueError("anneal_factor must be in (0, 1]")
        if self.min_rank < 1:
            raise ValueError("min_rank must be >= 1 (rank-0 adapters "
                             "cannot be packed)")

    @classmethod
    def uniform(cls, rank: int, n_clients: int, **kw) -> "RankSchedule":
        return cls(client_ranks=(rank,) * n_clients, **kw)

    @classmethod
    def tiered(cls, tiers: tuple[int, ...], n_clients: int,
               **kw) -> "RankSchedule":
        """Round-robin assignment of rank tiers over client ids."""
        ranks = tuple(tiers[i % len(tiers)] for i in range(n_clients))
        return cls(client_ranks=ranks, **kw)

    @property
    def n_clients(self) -> int:
        return len(self.client_ranks)

    @property
    def max_rank(self) -> int:
        return max(self.client_ranks)

    def rank_for(self, cid: int, rnd: int = 0) -> int:
        """Client cid's rank at round ``rnd``. The ``min_rank`` floor
        only applies to annealed shrinkage — a configured base rank
        below ``min_rank`` is honored as-is."""
        r = self.client_ranks[cid]
        if self.anneal_every > 0:
            r = max(self.min_rank,
                    int(r * self.anneal_factor ** (rnd // self.anneal_every)))
        return min(r, self.client_ranks[cid])

    def ranks_at(self, rnd: int) -> tuple[int, ...]:
        return tuple(self.rank_for(c, rnd) for c in
                     range(len(self.client_ranks)))


@dataclasses.dataclass(frozen=True)
class FLoCoRAConfig:
    rank: int = 32
    alpha: float = 512.0            # paper default: alpha = 16 * r
    quant_bits: Optional[int] = None  # None | 8 | 4 | 2
    error_feedback: bool = False    # beyond-paper EF on the client uplink
    head_mode: str = "dense"        # 'dense' (paper) | 'lora' | 'frozen'
    # heterogeneous fleets: per-client rank profile (None = every client
    # trains at `rank`, the paper's uniform setting)
    rank_schedule: Optional[RankSchedule] = None

    def __post_init__(self):
        if self.rank_schedule is not None \
                and self.rank_schedule.max_rank > self.rank:
            raise ValueError(
                f"rank_schedule max rank {self.rank_schedule.max_rank} "
                f"exceeds the server rank {self.rank}")

    @property
    def qcfg(self) -> QuantConfig:
        return QuantConfig(bits=self.quant_bits)

    @property
    def scale(self) -> float:
        return self.alpha / self.rank



def server_downlink(global_trainable: Any, cfg: FLoCoRAConfig,
                    rank: Optional[int] = None) -> Any:
    """Step (1), wire form: the packed message the server broadcasts
    (uint32 payloads + fp32 sidecars; fp tree when quantization is off).

    ``rank`` truncates/pads the global adapters to the receiving
    client's rank before packing (slice truncation: after an SVD
    recombination the components are energy-ordered, and a fresh
    zero-product adapter keeps its nonzero down-projection)."""
    if rank is not None:
        global_trainable = lora.resize_tree_rank(global_trainable, rank,
                                                 method="slice")
    if not cfg.qcfg.enabled:
        return global_trainable
    return messages.pack_message(global_trainable, cfg.qcfg)


def broadcast(global_trainable: Any, cfg: FLoCoRAConfig,
              rank: Optional[int] = None) -> Any:
    """Step (1): what clients reconstruct from the server message."""
    return messages.unpack_message(
        server_downlink(global_trainable, cfg, rank))


def client_uplink(trainable: Any, cfg: FLoCoRAConfig,
                  ef_residual: Optional[Any] = None
                  ) -> tuple[Any, Optional[Any]]:
    """Step (3): one client's WIRE message (packed payloads when
    quantization is on; the raw fp tree otherwise).

    With error feedback enabled, the client compensates its own previous
    quantization error (beyond-paper option); pass the stored residual
    (``None`` initializes a zero residual). Returns (message, residual)."""
    if cfg.error_feedback and cfg.qcfg.enabled:
        if ef_residual is None:
            ef_residual = aggregation.ef_init(trainable)
        return aggregation.ef_encode_packed(trainable, ef_residual,
                                            cfg.qcfg)
    if not cfg.qcfg.enabled:
        return trainable, ef_residual
    return messages.pack_message(trainable, cfg.qcfg), ef_residual


def server_round(stacked_client_trainables: Any, weights: Array,
                 cfg: FLoCoRAConfig) -> Any:
    """Steps (3)+(4) fused: dequantize each client message and FedAvg.

    `stacked_client_trainables` leaves have a leading K (clients) dim and
    hold the *raw* client fp trees; quantization happens inside so the
    whole round jits into one program (and, on TPU, lowers onto the fused
    dequant+reduce Pallas kernel)."""
    return aggregation.fedavg_quantized(stacked_client_trainables, weights,
                                        cfg.qcfg)


def round_wire_bytes(trainable: Any, cfg: FLoCoRAConfig,
                     rank: Optional[int] = None) -> dict:
    """Per-round, PER-CLIENT message accounting (both directions equal).
    With heterogeneous ranks the size depends on the client's rank."""
    one_way = client_wire_bytes(trainable, cfg, rank)
    return {"down_bytes": one_way, "up_bytes": one_way,
            "round_bytes": 2 * one_way}


def client_wire_bytes(trainable: Any, cfg: FLoCoRAConfig,
                      rank: Optional[int] = None) -> int:
    """One direction of one round for a client at ``rank`` (static
    accounting over the resized adapter shapes)."""
    if rank is not None:
        trainable = lora.resize_tree_rank(trainable, rank, method="slice")
    return messages.message_wire_bytes(trainable, cfg.qcfg)


def tcc(trainable: Any, cfg: FLoCoRAConfig, rounds: int) -> int:
    """Paper Eq. 2: total communication cost for one client, R rounds."""
    return messages.tcc_bytes(trainable, cfg.qcfg, rounds)


def fleet_tcc_bytes(trainable: Any, cfg: FLoCoRAConfig, rounds: int) -> int:
    """Fleet-level TCC: heterogeneous uplinks+downlinks summed over every
    client and round of the schedule (replaces Eq. 2's uniform
    ``2 * one_way * rounds`` when a rank profile is set)."""
    sched = cfg.rank_schedule
    if sched is None:
        return messages.tcc_bytes(trainable, cfg.qcfg, rounds)
    by_rank: dict[int, int] = {}
    total = 0
    for rnd in range(rounds):
        for r in sched.ranks_at(rnd):
            if r not in by_rank:
                by_rank[r] = client_wire_bytes(trainable, cfg, r)
            total += 2 * by_rank[r]
    return total
