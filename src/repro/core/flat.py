"""Flat-tree wire codec: ONE fused kernel launch per message.

The per-leaf packed codec (``core/messages.py``) pays a per-leaf tax
everywhere: one ``quant_pack`` pallas_call per quantizable leaf on the
client, one ``dequant_agg`` call per leaf on the server, one device->host
sync per leaf at serialization — with a distinct compiled program per
(leaf shape x bits). Weight-only-quant inference stacks (TensorRT-LLM
style) fuse the whole packed tensor set into one launch over a flat
buffer; this module does the same for a FLoCoRA message:

  * :class:`TreeLayout` — a STATIC row map, computed once per
    (tree-structure, bits, per_stack) signature and cached: every
    quantizable leaf's channel-2D view is assigned a row range in a
    single ``(C_total, Nw_max)`` uint32 payload with a per-row valid-
    length vector and fp32 ``scale``/``zp`` sidecars of length
    ``C_total``;
  * :class:`FlatPackedMessage` — the wire leaf: the flat payload + the
    layout + the fp passthrough leaves. Serializes through the same v3
    header to byte-IDENTICAL per-leaf buffers (``message_wire_bytes``
    does not move), in one device->host transfer;
  * :func:`pack_flat` / ``FlatPackedMessage.unpack`` /
    :func:`fedavg_packed_flat` — pack, decode, and K-client aggregate,
    each ONE jitted program containing ONE ragged-row kernel launch
    (``quant_pack_rows`` / ``dequant_agg(n_valid=...)``), regardless of
    how many leaves the adapter tree has. Per-message dispatches drop
    from O(#leaves) to O(1) and compile count from O(#leaf-shapes x
    bits) to O(bits).

The per-leaf :class:`~repro.core.messages.PackedLeaf` path stays as the
byte/numerics oracle the flat path is tested against
(tests/test_flat_codec.py).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.utils.tree import _path_str

Array = jax.Array


# ---------------------------------------------------------------------------
# Host-side word/bit ops (shared with PackedLeaf.to_wire — no device pass)
# ---------------------------------------------------------------------------

def strip_row_padding(words: np.ndarray, bits: int,
                      n_valid: int) -> np.ndarray:
    """(C, Nw) uint32 kernel-layout words -> the exact wire payload:
    the first ``n_valid`` levels of every row packed contiguously
    little-endian, ``ceil(C * n_valid * bits / 8)`` uint8 bytes.

    Pure vectorized numpy. The input may be WIDER than the row needs
    (a flat-buffer slice carries the layout-wide ``Nw_max``); only the
    compact word width is ever touched, and when each row's payload is
    byte-aligned (``n_valid * bits % 8 == 0`` — every bits=8 row and
    most 2/4-bit rows) the wire bytes are a direct byte view of the
    words, no bit unpack/repack at all."""
    nbits = n_valid * bits
    nww = (nbits + 31) // 32
    w = np.ascontiguousarray(np.asarray(words, dtype="<u4")[:, :nww])
    u8 = w.view(np.uint8).reshape(w.shape[0], -1)
    if nbits % 8 == 0:
        # rows start on byte boundaries: the kernel's zero tail past
        # n_valid levels means the first nbits/8 bytes ARE the wire form
        return u8[:, : nbits // 8].reshape(-1).copy()
    b = np.unpackbits(u8, axis=1, bitorder="little")[:, :nbits]
    return np.packbits(b.reshape(-1), bitorder="little")


def rows_from_wire(payload_u8: np.ndarray, bits: int, channels: int,
                   n_valid: int, nw: int) -> np.ndarray:
    """Inverse of :func:`strip_row_padding`: wire bytes -> (channels, nw)
    uint32 kernel-layout words with the canonical zero tail."""
    nbits = n_valid * bits
    if nbits % 8 == 0:
        u8 = np.zeros((channels, nw * 4), np.uint8)
        u8[:, : nbits // 8] = np.asarray(
            payload_u8, np.uint8)[: channels * (nbits // 8)].reshape(
                channels, nbits // 8)
        return u8.view("<u4").reshape(channels, nw)
    b = np.unpackbits(np.asarray(payload_u8, np.uint8),
                      bitorder="little")[: channels * nbits]
    full = np.zeros((channels, nw * 32), np.uint8)
    full[:, :nbits] = b.reshape(channels, nbits)
    by = np.packbits(full, axis=1, bitorder="little")
    return np.ascontiguousarray(by).view("<u4").reshape(channels, nw)


# ---------------------------------------------------------------------------
# Static layout (computed once per tree signature, cached)
# ---------------------------------------------------------------------------

_lane = kops.lane_levels      # kernel column alignment (single source)


@dataclasses.dataclass(frozen=True)
class LeafSpec:
    """Static row-map entry for one leaf of the message tree."""
    path: str                 # flatten-order path string (wire entry name)
    shape: tuple              # original tensor shape
    dtype_str: str            # original dtype (str: keeps the spec hashable)
    quantized: bool           # >= 2-D leaves quantize; vectors travel fp
    row_start: int = 0        # first row in the flat buffer
    rows: int = 0             # channel count C_i
    n_valid: int = 0          # true levels per row

    @property
    def dtype(self):
        return jnp.dtype(self.dtype_str)


@dataclasses.dataclass(frozen=True)
class TreeLayout:
    """Row map of a whole message tree inside one flat packed buffer."""
    treedef: Any              # jax treedef of the message tree
    leaves: tuple             # tuple[LeafSpec, ...] in flatten order
    bits: int
    per_stack: bool
    c_total: int              # total channel rows across quantized leaves
    n_max: int                # padded column count (kernel lane multiple)

    @property
    def nw_max(self) -> int:
        return self.n_max * self.bits // 32

    def leaf_nw(self, spec: LeafSpec) -> int:
        """spec's own lane-padded word count (the per-leaf kernel's
        payload width — what ``PackedLeaf`` for this leaf would hold)."""
        lane = _lane(self.bits)
        n_pad = ((spec.n_valid + lane - 1) // lane) * lane
        return n_pad * self.bits // 32

    def n_valid_vec(self) -> np.ndarray:
        nv = np.zeros((self.c_total,), np.int32)
        for s in self.leaves:
            if s.quantized:
                nv[s.row_start: s.row_start + s.rows] = s.n_valid
        return nv


def _channels_of(shape: tuple, per_stack: bool) -> int:
    if per_stack and len(shape) >= 3:
        return int(np.prod(shape[:-2])) * shape[-1]
    return shape[-1]


_LAYOUT_CACHE: dict = {}


def layout_for(tree: Any, bits: int,
               per_stack: bool = False) -> Optional[TreeLayout]:
    """The (cached) flat layout of ``tree``'s message, or None when the
    tree has no quantizable leaf. Key: (treedef, leaf shapes/dtypes,
    bits, per_stack) — one layout per tree SIGNATURE, however many
    messages flow through it."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    sig = (treedef, bits, per_stack,
           tuple((tuple(x.shape), str(jnp.dtype(x.dtype)))
                 for _, x in flat))
    got = _LAYOUT_CACHE.get(sig)
    if got is not None:
        return got
    specs, row, n_big = [], 0, 0
    for path, x in flat:
        shape = tuple(int(d) for d in x.shape)
        dts = str(jnp.dtype(x.dtype))
        if len(shape) < 2:        # paper rule: vectors travel fp32
            specs.append(LeafSpec(_path_str(path), shape, dts, False))
            continue
        c = _channels_of(shape, per_stack)
        n = int(np.prod(shape)) // c
        specs.append(LeafSpec(_path_str(path), shape, dts, True,
                              row_start=row, rows=c, n_valid=n))
        row += c
        n_big = max(n_big, n)
    if row == 0:
        _LAYOUT_CACHE[sig] = None
        return None
    lane = _lane(bits)
    n_max = ((n_big + lane - 1) // lane) * lane
    layout = TreeLayout(treedef, tuple(specs), bits, per_stack, row, n_max)
    _LAYOUT_CACHE[sig] = layout
    return layout


# ---------------------------------------------------------------------------
# The three fused programs (ONE jit + ONE kernel launch each)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("layout",))
def _pack_flat_impl(leaves: tuple, layout: TreeLayout):
    fp = [x for x, s in zip(leaves, layout.leaves) if not s.quantized]
    if kops._interpret():
        # Off-TPU lowering: the SAME single program (one dispatch, one
        # compile), but each leaf's rows are quantized at their compact
        # width and written into the word buffer — the rectangular
        # (C_total, N_max) fp32 intermediate would be padding-dominated
        # on a CPU. Words are bit-identical to the kernel's.
        payload = jnp.zeros((layout.c_total, layout.nw_max), jnp.uint32)
        per = 32 // layout.bits
        scales, zps = [], []
        for x, spec in zip(leaves, layout.leaves):
            if not spec.quantized:
                continue
            x2d = kops.to_channel_first_2d(
                x, layout.per_stack).astype(jnp.float32)
            x2d = jnp.pad(x2d, ((0, 0), (0, (-spec.n_valid) % per)))
            nv = jnp.full((spec.rows,), spec.n_valid, jnp.int32)
            pk, s, z = kops._quant_pack_rows_jnp(x2d, nv, layout.bits)
            payload = jax.lax.dynamic_update_slice(
                payload, pk, (spec.row_start, 0))
            scales.append(s)
            zps.append(z)
        return payload, jnp.concatenate(scales), jnp.concatenate(zps), \
            tuple(fp)
    rows = []
    for x, spec in zip(leaves, layout.leaves):
        if spec.quantized:
            x2d = kops.to_channel_first_2d(
                x, layout.per_stack).astype(jnp.float32)
            rows.append(jnp.pad(
                x2d, ((0, 0), (0, layout.n_max - x2d.shape[1]))))
    flat = jnp.concatenate(rows, axis=0)
    nv = jnp.asarray(layout.n_valid_vec())
    payload, scale, zp = kops.quant_pack_rows(flat, nv, layout.bits)
    return payload, scale, zp, tuple(fp)


@partial(jax.jit, static_argnames=("layout",))
def _unpack_flat_impl(payload, scale, zp, fp_leaves: tuple,
                      layout: TreeLayout):
    interp = kops._interpret()
    per = 32 // layout.bits
    if not interp:
        lv = kref.unpack_words(payload, layout.bits).astype(jnp.float32)
        x = (lv - zp[:, None]) * scale[:, None]
    out, fpi = [], 0
    for spec in layout.leaves:
        if spec.quantized:
            r0, r1 = spec.row_start, spec.row_start + spec.rows
            if interp:      # compact per-leaf slices, same single program
                nw = (spec.n_valid + per - 1) // per
                lw = kref.unpack_words(
                    payload[r0:r1, :nw],
                    layout.bits)[:, : spec.n_valid].astype(jnp.float32)
                x2d = (lw - zp[r0:r1, None]) * scale[r0:r1, None]
            else:
                x2d = x[r0:r1, : spec.n_valid]
            out.append(kops.from_channel_first_2d(
                x2d, spec.shape, layout.per_stack).astype(spec.dtype))
        else:
            out.append(fp_leaves[fpi])
            fpi += 1
    return jax.tree_util.tree_unflatten(layout.treedef, out)


def _chunk_k(layout: TreeLayout, budget_bytes: int = 8 << 20) -> int:
    """Client-chunk size for the off-TPU aggregate: the largest pow2
    count of clients whose compact fp32 unpack/contribution
    intermediates (~3 buffers per leaf) fit the working-set budget —
    the CPU analogue of the kernel's ``pick_block_k`` VMEM tiling, so
    fleet cohorts stream through a bounded footprint instead of
    materializing the K-client fp32 stack."""
    per_client = 12 * sum(s.rows * s.n_valid
                          for s in layout.leaves if s.quantized)
    bk = max(1, budget_bytes // max(per_client, 1))
    return int(min(1 << (int(bk).bit_length() - 1), 256))


def _deq_compact(Pl, S, Z, wf, spec: LeafSpec, bits: int):
    """Weighted reduce of one leaf's already-compact ``(K, rows, nw)``
    word stack -> the leaf's (rows, n_valid) 2D mean contribution.
    (S, Z) stay full-width ``(K, C_total)``; the leaf's row window is
    sliced here."""
    r0, r1 = spec.row_start, spec.row_start + spec.rows
    lv = kref.unpack_words(Pl, bits)[..., : spec.n_valid].astype(jnp.float32)
    deq = (lv - Z[:, r0:r1, None]) * S[:, r0:r1, None]
    return jnp.einsum("k,kcn->cn", wf, deq)


@partial(jax.jit, static_argnames=("layout",))
def _fedavg_flat_impl(payloads: tuple, scales: tuple, zps: tuple,
                      fps: tuple, weights, layout: TreeLayout):
    w = weights / jnp.sum(weights)
    wf = w.astype(jnp.float32)
    interp = kops._interpret()
    qspecs = tuple(s for s in layout.leaves if s.quantized)
    if not interp:
        agg = kops.dequant_agg_rows(jnp.stack(payloads),
                                    jnp.stack(scales), jnp.stack(zps),
                                    wf, jnp.asarray(layout.n_valid_vec()),
                                    layout.bits)
        x2ds = {s.path: agg[s.row_start: s.row_start + s.rows,
                            : s.n_valid] for s in qspecs}
    else:
        # off-TPU: same single program; each leaf's row/word slice
        # unpacks + reduces at its compact width (see _pack_flat_impl),
        # K-chunked through one scan so a fleet-scale cohort streams a
        # bounded working set — the jnp twin of the kernel's K tiling.
        # Each client's payload is sliced to the leaf's compact row/word
        # window BEFORE the K-stack: the concat then moves only real
        # wire bytes, not the (C_total, Nw_max) padding (~60x on LoRA
        # layouts, where most rows are rank-width), which keeps the
        # cohort aggregate linear in K on memcpy-bound hosts.
        k = len(payloads)
        bk = _chunk_k(layout)
        per = 32 // layout.bits
        S = jnp.stack(scales)
        Z = jnp.stack(zps)

        def leaf_stack(s):
            r0, r1 = s.row_start, s.row_start + s.rows
            nw = (s.n_valid + per - 1) // per
            return jnp.stack([p[r0:r1, :nw] for p in payloads])

        Pls = {s.path: leaf_stack(s) for s in qspecs}
        if k <= bk:
            x2ds = {s.path: _deq_compact(Pls[s.path], S, Z, wf, s,
                                         layout.bits)
                    for s in qspecs}
        else:
            nt = -(-k // bk)
            pad = nt * bk - k

            def padk(x):         # zero weight => exact-zero contribution
                return jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))

            Plc = tuple(
                padk(Pls[s.path]).reshape(nt, bk, *Pls[s.path].shape[1:])
                for s in qspecs)
            Sc = padk(S).reshape(nt, bk, *S.shape[1:])
            Zc = padk(Z).reshape(nt, bk, *Z.shape[1:])
            wc = padk(wf).reshape(nt, bk)

            def fold(accs, xs):
                pls, s_, z, wt = xs
                return tuple(
                    a + _deq_compact(pl, s_, z, wt, spec, layout.bits)
                    for a, pl, spec in zip(accs, pls, qspecs)), None

            init = tuple(jnp.zeros((s.rows, s.n_valid), jnp.float32)
                         for s in qspecs)
            accs, _ = jax.lax.scan(fold, init, (Plc, Sc, Zc, wc))
            x2ds = {s.path: a for s, a in zip(qspecs, accs)}
    out, fpi = [], 0
    for spec in layout.leaves:
        if spec.quantized:
            out.append(kops.from_channel_first_2d(
                x2ds[spec.path], spec.shape,
                layout.per_stack).astype(spec.dtype))
        else:
            x = jnp.stack([f[fpi].astype(jnp.float32) for f in fps])
            wr = w.reshape((-1,) + (1,) * (x.ndim - 1)).astype(jnp.float32)
            out.append(jnp.sum(x * wr, axis=0).astype(spec.dtype))
            fpi += 1
    return jax.tree_util.tree_unflatten(layout.treedef, out)


# ---------------------------------------------------------------------------
# Streaming fold (O(1)-memory FedBuff arrivals) + sharded cohort reduce
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("layout",))
def _fold_flat_impl(acc, fp_accs: tuple, payload, scale, zp,
                    fp_leaves: tuple, w, layout: TreeLayout):
    """Fold ONE client's flat message into the running fp32 sum: the
    ``(C_total, N_max)`` accumulator gains ``w * dequant(payload)`` in a
    single fused pass (K=1 ``dequant_agg_rows``), fp passthrough leaves
    gain ``w * leaf``. ``w`` stays a weak python float so steady-state
    folds never retrace — one compiled program per layout."""
    wf = jnp.asarray(w, jnp.float32)
    contrib = kops.dequant_agg_rows(
        payload[None], scale[None], zp[None], wf[None],
        jnp.asarray(layout.n_valid_vec()), layout.bits)
    fp_out = tuple(a + wf * x.astype(jnp.float32)
                   for a, x in zip(fp_accs, fp_leaves))
    return acc + contrib, fp_out


@partial(jax.jit, static_argnames=("layout",))
def _flat_mean_from_sum_impl(acc, fp_accs: tuple, inv_w,
                             layout: TreeLayout):
    """Running weighted sum -> the aggregated fp tree: slice each leaf's
    rows off the flat accumulator, scale by ``1/total_weight``, restore
    shape/dtype. O(message), independent of how many clients folded."""
    out, fpi = [], 0
    for spec in layout.leaves:
        if spec.quantized:
            r0, r1 = spec.row_start, spec.row_start + spec.rows
            x2d = acc[r0:r1, : spec.n_valid] * inv_w
            out.append(kops.from_channel_first_2d(
                x2d, spec.shape, layout.per_stack).astype(spec.dtype))
        else:
            out.append((fp_accs[fpi] * inv_w).astype(spec.dtype))
            fpi += 1
    return jax.tree_util.tree_unflatten(layout.treedef, out)


# ---------------------------------------------------------------------------
# The wire leaf
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class FlatPackedMessage:
    """A whole quantized message as ONE flat packed buffer.

    ``payload`` is the ``(C_total, Nw_max)`` uint32 word buffer (rows =
    every quantizable leaf's channels, stacked in flatten order, each
    row zero-padded past its leaf's true length); ``scale``/``zp`` are
    the fp32 sidecars of length ``C_total``; ``fp_leaves`` carries the
    unquantized (1-D) leaves in flatten order. ``layout`` is the static
    row map."""
    payload: Array            # (C_total, Nw_max) uint32
    scale: Array              # (C_total,) fp32
    zp: Array                 # (C_total,) fp32
    fp_leaves: tuple          # fp passthrough leaves, flatten order
    layout: TreeLayout        # static

    def tree_flatten(self):
        return ((self.payload, self.scale, self.zp, self.fp_leaves),
                (self.layout,))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    @property
    def bits(self) -> int:
        return self.layout.bits

    @property
    def per_stack(self) -> bool:
        return self.layout.per_stack

    def shape_tree(self) -> Any:
        """Shape/dtype-only view with the ORIGINAL tree structure, for
        shape walks (adapter-pair/rank detection) that never touch a
        payload."""
        return jax.tree_util.tree_unflatten(
            self.layout.treedef,
            [jax.ShapeDtypeStruct(s.shape, s.dtype)
             for s in self.layout.leaves])

    def replace_dtypes(self, like: Any) -> "FlatPackedMessage":
        """Advertise ``like``'s leaf dtypes (EF packs an fp32-compensated
        tree but the wire must carry the original adapter dtypes)."""
        dts = [str(jnp.dtype(x.dtype)) for x in jax.tree.leaves(like)]
        specs = tuple(dataclasses.replace(s, dtype_str=d)
                      for s, d in zip(self.layout.leaves, dts))
        layout = dataclasses.replace(self.layout, leaves=specs)
        fp = tuple(x.astype(jnp.dtype(d)) for x, d in zip(
            self.fp_leaves,
            [d for s, d in zip(self.layout.leaves, dts)
             if not s.quantized]))
        return FlatPackedMessage(self.payload, self.scale, self.zp, fp,
                                 layout)

    # -- decode -------------------------------------------------------------
    def unpack(self) -> Any:
        """-> fp tree (original structure/dtypes); one jitted program."""
        return _unpack_flat_impl(self.payload, self.scale, self.zp,
                                 self.fp_leaves, self.layout)

    def as_tree(self) -> Any:
        """-> the equivalent per-leaf ``PackedLeaf`` tree (row/col slices
        of the flat buffer; bit-identical payloads). The escape hatch for
        consumers that walk message trees (SVD recombination, mixed
        per-leaf/flat buffers)."""
        from repro.core.messages import PackedLeaf
        lo = self.layout
        out, fpi = [], 0
        for spec in lo.leaves:
            if spec.quantized:
                r0, r1 = spec.row_start, spec.row_start + spec.rows
                out.append(PackedLeaf(
                    self.payload[r0:r1, : lo.leaf_nw(spec)],
                    self.scale[r0:r1], self.zp[r0:r1], spec.shape,
                    spec.dtype, lo.bits, lo.per_stack))
            else:
                out.append(self.fp_leaves[fpi])
                fpi += 1
        return jax.tree_util.tree_unflatten(lo.treedef, out)

    # -- serialization (the actual bytes on the wire) -----------------------
    def to_wire_entries(self) -> list:
        """[(path, buffers)] byte-IDENTICAL to the per-leaf codec's
        ``message_to_wire`` body, from ONE device->host transfer."""
        lo = self.layout
        words = np.asarray(jax.device_get(self.payload))
        scale = np.asarray(jax.device_get(self.scale), np.float32)
        zp = np.asarray(jax.device_get(self.zp), np.float32)
        out, fpi = [], 0
        for spec in lo.leaves:
            if spec.quantized:
                r0, r1 = spec.row_start, spec.row_start + spec.rows
                out.append((spec.path, {
                    "payload": strip_row_padding(words[r0:r1], lo.bits,
                                                 spec.n_valid),
                    "scale": scale[r0:r1], "zp": zp[r0:r1]}))
            else:
                out.append((spec.path, {
                    "payload": np.asarray(self.fp_leaves[fpi],
                                          np.float32)}))
                fpi += 1
        return out

    @classmethod
    def from_wire_entries(cls, entries: list,
                          layout: TreeLayout) -> "FlatPackedMessage":
        """Rebuild the flat kernel-layout buffer from serialized wire
        buffers (inverse of :meth:`to_wire_entries`)."""
        bufs = dict(entries)
        payload = np.zeros((layout.c_total, layout.nw_max), np.uint32)
        scale = np.zeros((layout.c_total,), np.float32)
        zp = np.zeros((layout.c_total,), np.float32)
        fp = []
        for spec in layout.leaves:
            b = bufs[spec.path]
            if spec.quantized:
                r0, r1 = spec.row_start, spec.row_start + spec.rows
                payload[r0:r1] = rows_from_wire(
                    b["payload"], layout.bits, spec.rows, spec.n_valid,
                    layout.nw_max)
                scale[r0:r1] = np.asarray(b["scale"], np.float32)
                zp[r0:r1] = np.asarray(b["zp"], np.float32)
            else:
                fp.append(jnp.asarray(b["payload"]).reshape(
                    spec.shape).astype(spec.dtype))
        return cls(jnp.asarray(payload), jnp.asarray(scale),
                   jnp.asarray(zp), tuple(fp), layout)

    def wire_bytes(self) -> int:
        """Real serialized size (measured from the buffers)."""
        return sum(b.nbytes for _, bufs in self.to_wire_entries()
                   for b in bufs.values())


def is_flat_message(t: Any) -> bool:
    return isinstance(t, FlatPackedMessage)


# ---------------------------------------------------------------------------
# Codec entry points
# ---------------------------------------------------------------------------

def pack_flat(tree: Any, bits: int, per_stack: bool = False) -> Any:
    """Trainable tree -> :class:`FlatPackedMessage` in one fused launch
    (falls back to the tree itself when nothing is quantizable, matching
    the per-leaf codec's passthrough)."""
    layout = layout_for(tree, bits, per_stack)
    if layout is None:
        return tree
    payload, scale, zp, fp = _pack_flat_impl(
        tuple(jax.tree.leaves(tree)), layout)
    return FlatPackedMessage(payload, scale, zp, fp, layout)


def fedavg_packed_flat(msgs: list, weights) -> Any:
    """Weighted mean over K flat messages sharing one layout: unpack +
    dequant + reduce of the WHOLE cohort in one fused kernel launch."""
    lo = msgs[0].layout
    return _fedavg_flat_impl(
        tuple(m.payload for m in msgs), tuple(m.scale for m in msgs),
        tuple(m.zp for m in msgs), tuple(m.fp_leaves for m in msgs),
        jnp.asarray(weights, jnp.float32), lo)


def fedavg_packed_flat_sharded(msgs: list, weights, mesh,
                               axis: str = kops.CLIENT_AXIS) -> Any:
    """:func:`fedavg_packed_flat` with the client dim sharded over
    ``axis`` of ``mesh`` (``launch.mesh.make_client_mesh``): each device
    reduces its local client shard through the K-tiled kernel and ONE
    psum combines the partials, so cohort-reduction bandwidth scales
    with the device count. Numerically a weighted sum in a different
    association order — fp32-tolerance equal to the single-device path."""
    lo = msgs[0].layout
    w = jnp.asarray(weights, jnp.float32)
    wn = w / jnp.sum(w)
    agg = kops.dequant_agg_rows_sharded(
        jnp.stack([m.payload for m in msgs]),
        jnp.stack([m.scale for m in msgs]),
        jnp.stack([m.zp for m in msgs]),
        wn, jnp.asarray(lo.n_valid_vec()), lo.bits, mesh, axis=axis)
    n_fp = len(msgs[0].fp_leaves)
    fp_sums = tuple(
        jnp.tensordot(wn, jnp.stack(
            [m.fp_leaves[i].astype(jnp.float32) for m in msgs]), axes=1)
        for i in range(n_fp))
    return _flat_mean_from_sum_impl(agg, fp_sums, 1.0, lo)
