from repro.models import layers, attention, moe, ssm, lm, encdec, resnet
