"""LoRA adapter correctness: merge equivalence, zero-init identity,
conv decomposition (Huh et al.) against a dense-merged oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lora
from repro.core.lora import LoRAConfig


def test_dense_zero_init_is_identity():
    cfg = LoRAConfig(rank=8, alpha=128)
    ad = lora.dense_lora_init(jax.random.PRNGKey(0), 32, 48, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32))
    y = lora.dense_lora_apply(x, ad["a"], ad["b"], cfg.scale, jnp.float32)
    assert float(jnp.max(jnp.abs(y))) == 0.0   # b zeros -> adapter silent


def test_dense_merge_equivalence():
    cfg = LoRAConfig(rank=4, alpha=64)
    k = jax.random.PRNGKey(0)
    w = jax.random.normal(k, (32, 48))
    a = jax.random.normal(jax.random.fold_in(k, 1), (32, 4)) * 0.2
    b = jax.random.normal(jax.random.fold_in(k, 2), (4, 48)) * 0.2
    x = jax.random.normal(jax.random.fold_in(k, 3), (8, 32))
    y1 = x @ w + lora.dense_lora_apply(x, a, b, cfg.scale, jnp.float32)
    y2 = x @ lora.dense_merge(w, a, b, cfg.scale)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)


def test_conv_merge_equivalence():
    """conv(x, P) + (α/r)·conv1x1(conv(x, B), A) == conv(x, P_merged)."""
    cfg = LoRAConfig(rank=3, alpha=12)
    k = jax.random.PRNGKey(0)
    p = jax.random.normal(k, (3, 3, 5, 7)) * 0.3          # HWIO
    ad = lora.conv_lora_init(jax.random.fold_in(k, 1), 3, 3, 5, 7, cfg)
    ad = {"b": ad["b"],
          "a": jax.random.normal(jax.random.fold_in(k, 2),
                                 ad["a"].shape) * 0.2}
    x = jax.random.normal(jax.random.fold_in(k, 3), (2, 8, 8, 5))
    dn = jax.lax.conv_dimension_numbers(x.shape, p.shape,
                                        ("NHWC", "HWIO", "NHWC"))
    base = jax.lax.conv_general_dilated(x, p, (1, 1), "SAME",
                                        dimension_numbers=dn)
    y1 = base + lora.conv_lora_apply(x, ad["b"], ad["a"], cfg.scale,
                                     (1, 1), "SAME")
    pm = lora.conv_merge(p, ad["b"], ad["a"], cfg.scale)
    y2 = jax.lax.conv_general_dilated(x, pm, (1, 1), "SAME",
                                      dimension_numbers=dn)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)


def test_conv_merge_strided():
    """Merge must also hold under stride (B conv takes the stride)."""
    cfg = LoRAConfig(rank=2, alpha=8)
    k = jax.random.PRNGKey(0)
    p = jax.random.normal(k, (3, 3, 4, 6)) * 0.3
    ad = {"b": jax.random.normal(jax.random.fold_in(k, 1), (3, 3, 4, 2)),
          "a": jax.random.normal(jax.random.fold_in(k, 2), (1, 1, 2, 6))}
    x = jax.random.normal(jax.random.fold_in(k, 3), (2, 9, 9, 4))
    dn = jax.lax.conv_dimension_numbers(x.shape, p.shape,
                                        ("NHWC", "HWIO", "NHWC"))
    y1 = jax.lax.conv_general_dilated(x, p, (2, 2), "SAME",
                                      dimension_numbers=dn) \
        + lora.conv_lora_apply(x, ad["b"], ad["a"], cfg.scale, (2, 2),
                               "SAME")
    pm = lora.conv_merge(p, ad["b"], ad["a"], cfg.scale)
    y2 = jax.lax.conv_general_dilated(x, pm, (2, 2), "SAME",
                                      dimension_numbers=dn)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("mode", ["lora", "dense", "frozen"])
def test_linear_modes(mode):
    cfg = LoRAConfig(rank=4, alpha=64)
    fz, tr = lora.linear_init(jax.random.PRNGKey(0), 16, 24, mode, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 16))
    y = lora.linear_apply(fz, tr, x, cfg.scale, jnp.float32)
    assert y.shape == (3, 24)
    if mode == "lora":
        assert "w" in fz and "a" in tr and "b" in tr
    elif mode == "dense":
        assert not fz and "w" in tr
    else:
        assert "w" in fz and not tr


def test_int8_frozen_base_close_and_smaller():
    """Beyond-paper: symmetric int8 frozen base ~= bf16 base."""
    import jax.numpy as jnp
    from repro.core.lora import quantize_frozen_tree, frozen_weight
    from repro.utils.tree import tree_bytes
    k = jax.random.PRNGKey(0)
    w = (jax.random.normal(k, (3, 32, 48)) * 0.3).astype(jnp.bfloat16)
    fz = {"w": w}
    fq = quantize_frozen_tree(fz)
    assert fq["w_q8"].dtype == jnp.int8
    assert fq["w_s"].shape == (3, 48)
    deq = frozen_weight(fq, jnp.float32)
    err = float(jnp.max(jnp.abs(deq - w.astype(jnp.float32))))
    scale = float(jnp.max(jnp.abs(w.astype(jnp.float32))))
    assert err < scale / 64          # < 2 int8 steps
    assert tree_bytes(fq) < tree_bytes(fz) * 0.6
