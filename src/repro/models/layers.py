"""Shared neural-net layers for the model zoo (functional, pytree params).

Conventions:
  * activations: (batch, seq, d_model) NSD; attention heads (B, S, H, Dh);
  * weights for linears: (d_in, d_out) — output channel is the LAST axis
    (matches the message codec's per-channel quantization rule);
  * every linear is a mixed-mode FLoCoRA linear: (frozen, trainable) dicts
    via repro.core.lora.linear_init/apply;
  * attention never materializes (Sq, Skv) for long sequences: causal/
    bidir/prefix paths use an online-softmax scan over KV chunks; sliding
    window uses exact blocked local attention (band of 2W per query block).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.lora import LoRAConfig, linear_init, linear_apply, \
    linear_logical
from repro.utils.pcontext import constrain as pconstrain

Array = jax.Array

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, stack: tuple[int, ...] = ()) -> dict:
    return {"scale": jnp.ones((*stack, d), jnp.float32)}


def rmsnorm_apply(p: dict, x: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return y.astype(x.dtype)


def groupnorm_init(c: int) -> dict:
    return {"scale": jnp.ones((c,), jnp.float32),
            "bias": jnp.zeros((c,), jnp.float32)}


def groupnorm_apply(p: dict, x: Array, groups: int = 32,
                    eps: float = 1e-5) -> Array:
    """x: (N, H, W, C). GroupNorm over (H, W, C//G)."""
    n, h, w, c = x.shape
    g = min(groups, c)
    xf = x.astype(jnp.float32).reshape(n, h, w, g, c // g)
    mean = jnp.mean(xf, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xf, axis=(1, 2, 4), keepdims=True)
    xf = (xf - mean) * jax.lax.rsqrt(var + eps)
    xf = xf.reshape(n, h, w, c)
    return (xf * p["scale"] + p["bias"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------

def rope_for_positions(positions: Array, dim: int, base: float = 10000.0
                       ) -> tuple[Array, Array]:
    """cos/sin for given integer positions ((S,) or (B, S)) — computed
    directly (never materializes a max-length table; a 500k-decode step
    only ever computes one position). Returns (..., dim//2) fp32."""
    inv = 1.0 / (base ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    freqs = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(freqs), jnp.sin(freqs)


def apply_rope(x: Array, cos: Array, sin: Array) -> Array:
    """x: (B, S, H, Dh); cos/sin: (S, Dh//2) or (B, S, Dh//2)."""
    if cos.ndim == 2:
        c, si = cos[None, :, None, :], sin[None, :, None, :]
    else:
        c, si = cos[:, :, None, :], sin[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * c - x2 * si, x2 * c + x1 * si], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention cores (no projections — those live in the block)
# ---------------------------------------------------------------------------

def _repeat_kv(k: Array, n_rep: int) -> Array:
    """(B, S, Hkv, D) -> (B, S, Hkv*n_rep, D)."""
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)
                            ).reshape(b, s, h * n_rep, d)


def attention_chunked(q: Array, k: Array, v: Array, *,
                      causal: bool = True,
                      prefix_len: Optional[Array] = None,
                      kv_chunk: int = 1024,
                      q_offset: int = 0,
                      scale: Optional[float] = None) -> Array:
    """Online-softmax attention, scanning KV in chunks (flash-style in
    pure JAX — the memory high-water is (B, H, Sq, kv_chunk)).

    q: (B, Sq, H, D); k, v: (B, Skv, Hkv, D) with H % Hkv == 0.
    prefix_len: (B,) — bidirectional attention within [0, prefix_len)
    (PaliGemma-style prefix-LM); combined with causal elsewhere.
    q_offset: absolute position of q[0] (prefill continuation / decode).
    """
    b, sq, h, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    dv = v.shape[-1]                     # may differ from d (MLA)
    k = _repeat_kv(k, h // hkv)
    v = _repeat_kv(v, h // hkv)
    q = pconstrain(q, "heads")
    k = pconstrain(k, "heads")
    v = pconstrain(v, "heads")
    sc = scale if scale is not None else d ** -0.5
    qf = (q * sc).astype(jnp.bfloat16)

    n_chunks = -(-skv // kv_chunk)
    pad = n_chunks * kv_chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = pconstrain(
        k.reshape(b, n_chunks, kv_chunk, h, d).transpose(1, 0, 2, 3, 4),
        "kv_chunks")
    vc = pconstrain(
        v.reshape(b, n_chunks, kv_chunk, h, dv).transpose(1, 0, 2, 3, 4),
        "kv_chunks")

    q_pos = q_offset + jnp.arange(sq)

    def body(carry, xs):
        m, l, acc = carry
        kch, vch, cidx = xs
        kv_pos = cidx * kv_chunk + jnp.arange(kv_chunk)
        # scores: (B, H, Sq, C)
        s_ = jnp.einsum("bqhd,bchd->bhqc", qf, kch.astype(jnp.bfloat16),
                        preferred_element_type=jnp.float32)
        valid = (kv_pos < skv)[None, :]
        if causal:
            ok = q_pos[:, None] >= kv_pos[None, :]
            if prefix_len is not None:
                both_prefix = (q_pos[None, :, None] < prefix_len[:, None, None]) \
                    & (kv_pos[None, None, :] < prefix_len[:, None, None])
                ok = ok[None] | both_prefix
                mask = ok & valid
                s_ = jnp.where(mask[:, None], s_, -jnp.inf)
            else:
                mask = ok & valid
                s_ = jnp.where(mask[None, None], s_, -jnp.inf)
        else:
            s_ = jnp.where(valid[None, None], s_, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s_, axis=-1))
        # guard fully-masked rows (m_new == -inf)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s_ - m_safe[..., None])
        p = jnp.where(jnp.isfinite(s_), p, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqc,bchd->bhqd", p.astype(jnp.bfloat16),
            vch.astype(jnp.bfloat16), preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, h, sq, dv), jnp.float32)
    body = jax.checkpoint(body,
                          policy=jax.checkpoint_policies.nothing_saveable)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (kc, vc, jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l, 1e-20)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)   # (B, Sq, H, D)


def local_attention_blocked(q: Array, k: Array, v: Array, *,
                            window: int,
                            scale: Optional[float] = None) -> Array:
    """Exact causal sliding-window attention (window W), O(S·2W).

    Each query block of length W attends to its own and the previous
    block — covers every key within the causal window [pos-W+1, pos].
    q: (B, S, H, D); k, v: (B, S, Hkv, D). S % W need not hold (padded).
    """
    b, s, h, d = q.shape
    hkv = k.shape[2]
    k = _repeat_kv(k, h // hkv)
    v = _repeat_kv(v, h // hkv)
    sc = scale if scale is not None else d ** -0.5
    w = window
    nb = -(-s // w)
    pad = nb * w - s
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    q = pconstrain(q, "heads")
    k = pconstrain(k, "heads")
    v = pconstrain(v, "heads")
    qb = q.reshape(b, nb, w, h, d)
    kb = k.reshape(b, nb, w, h, d)
    vb = v.reshape(b, nb, w, h, d)
    # band of [previous block, current block]: (B, nb, 2W, H, D)
    kprev = jnp.concatenate([jnp.zeros_like(kb[:, :1]), kb[:, :-1]], axis=1)
    vprev = jnp.concatenate([jnp.zeros_like(vb[:, :1]), vb[:, :-1]], axis=1)
    kband = jnp.concatenate([kprev, kb], axis=2)
    vband = jnp.concatenate([vprev, vb], axis=2)

    s_ = jnp.einsum("bnqhd,bnkhd->bnhqk",
                    (qb * sc).astype(jnp.bfloat16), kband.astype(jnp.bfloat16),
                    preferred_element_type=jnp.float32)
    qpos = jnp.arange(w)[:, None]            # within-block query pos
    kpos = jnp.arange(2 * w)[None, :] - w    # band pos relative to block start
    ok = (kpos <= qpos) & (kpos > qpos - w)  # causal & within window
    blk = jnp.arange(nb)
    first = (blk == 0)[None, :, None, None, None]
    pad_keys = (kpos < 0)[None, None, None]
    ok = ok[None, None, None] & ~(first & pad_keys)
    s_ = jnp.where(ok, s_, -jnp.inf)
    p = jax.nn.softmax(s_, axis=-1)
    o = jnp.einsum("bnhqk,bnkhd->bnqhd", p.astype(jnp.bfloat16),
                   vband.astype(jnp.bfloat16),
                   preferred_element_type=jnp.float32)
    o = o.reshape(b, nb * w, h, d)[:, :s]
    return o.astype(q.dtype)


def decode_attention(q: Array, k_cache: Array, v_cache: Array,
                     length: Array, *,
                     scale: Optional[float] = None) -> Array:
    """Single-token decode over a (possibly seq-sharded) KV cache.

    q: (B, 1, H, D); caches: (B, Smax, Hkv, D); length: () or (B,) —
    number of valid cache entries (the new token is already written).
    """
    b, _, h, d = q.shape
    smax, hkv = k_cache.shape[1], k_cache.shape[2]
    rep = h // hkv
    sc = scale if scale is not None else d ** -0.5
    qh = (q[:, 0] * sc).reshape(b, hkv, rep, d)
    s_ = jnp.einsum("bgrd,bsgd->bgrs", qh.astype(jnp.bfloat16),
                    k_cache.astype(jnp.bfloat16),
                    preferred_element_type=jnp.float32)
    pos = jnp.arange(smax)
    ln = jnp.broadcast_to(jnp.asarray(length), (b,))
    mask = pos[None, :] < ln[:, None]
    s_ = jnp.where(mask[:, None, None, :], s_, -jnp.inf)
    p = jax.nn.softmax(s_, axis=-1)
    o = jnp.einsum("bgrs,bsgd->bgrd", p.astype(jnp.bfloat16),
                   v_cache.astype(jnp.bfloat16),
                   preferred_element_type=jnp.float32)
    return o.reshape(b, 1, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MLPSpec:
    kind: str          # 'swiglu' | 'sqrelu' | 'gelu'
    d_model: int
    d_ff: int


def mlp_init(key: Array, spec: MLPSpec, mode: str, lora: LoRAConfig,
             stack: tuple[int, ...] = ()) -> tuple[dict, dict]:
    ks = jax.random.split(key, 3)
    fz, tr = {}, {}
    names = ["wi", "wg", "wo"] if spec.kind in ("swiglu", "geglu") \
        else ["wi", "wo"]
    dims = {"wi": (spec.d_model, spec.d_ff), "wg": (spec.d_model, spec.d_ff),
            "wo": (spec.d_ff, spec.d_model)}
    for i, nm in enumerate(names):
        f, t = linear_init(ks[i], *dims[nm], mode, lora, stack)
        if f:
            fz[nm] = f
        if t:
            tr[nm] = t
    return fz, tr


def mlp_logical(spec: MLPSpec, mode: str, stack: bool) -> tuple[dict, dict]:
    fz, tr = {}, {}
    names = ["wi", "wg", "wo"] if spec.kind in ("swiglu", "geglu") \
        else ["wi", "wo"]
    dims = {"wi": ("fsdp", "mlp"), "wg": ("fsdp", "mlp"),
            "wo": ("mlp", "fsdp")}
    for nm in names:
        f, t = linear_logical(*dims[nm], mode, stack)
        if f:
            fz[nm] = f
        if t:
            tr[nm] = t
    return fz, tr


def mlp_apply(fz: dict, tr: dict, spec: MLPSpec, x: Array,
              lora_scale: float) -> Array:
    g = lambda nm, xx: linear_apply(fz.get(nm, {}), tr.get(nm, {}), xx,
                                    lora_scale)
    if spec.kind == "swiglu":
        h = jax.nn.silu(g("wg", x).astype(jnp.float32)).astype(x.dtype) \
            * g("wi", x)
    elif spec.kind == "geglu":
        h = jax.nn.gelu(g("wg", x).astype(jnp.float32),
                        approximate=True).astype(x.dtype) * g("wi", x)
    elif spec.kind == "sqrelu":
        h = jax.nn.relu(g("wi", x))
        h = (h * h)
    elif spec.kind == "gelu":
        h = jax.nn.gelu(g("wi", x).astype(jnp.float32)).astype(x.dtype)
    else:
        raise ValueError(spec.kind)
    return g("wo", h)


# ---------------------------------------------------------------------------
# Chunked softmax cross-entropy (never materializes (B, S, V))
# ---------------------------------------------------------------------------

def chunked_xent(x: Array, head_fz: dict, head_tr: dict, labels: Array,
                 lora_scale: float, chunk: int = 512,
                 mask: Optional[Array] = None) -> Array:
    """Mean next-token cross entropy. x: (B, S, d); labels: (B, S).

    Scans over sequence chunks; per chunk computes logits (B, c, V),
    logsumexp and the label logit, then discards the logits. This keeps
    live memory at (B, chunk, V) instead of (B, S, V)."""
    b, s, d = x.shape
    n = -(-s // chunk)
    pad = n * chunk - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        if mask is not None:
            mask = jnp.pad(mask, ((0, 0), (0, pad)))
    if mask is None:
        mask = jnp.ones((b, n * chunk), bool) if not pad else \
            jnp.pad(jnp.ones((b, s), bool), ((0, 0), (0, pad)))
    xc = x.reshape(b, n, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, n, chunk).transpose(1, 0, 2)
    mc = mask.reshape(b, n, chunk).transpose(1, 0, 2)

    def body(carry, xs):
        tot, cnt = carry
        xch, lch, mch = xs
        logits = linear_apply(head_fz, head_tr, xch, lora_scale,
                              compute_dtype=jnp.bfloat16).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        lab = jnp.take_along_axis(logits, lch[..., None], axis=-1)[..., 0]
        nll = (lse - lab) * mch
        return (tot + jnp.sum(nll), cnt + jnp.sum(mch)), None

    body = jax.checkpoint(body,
                          policy=jax.checkpoint_policies.nothing_saveable)
    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (xc, lc, mc))
    return tot / jnp.maximum(cnt, 1.0)
