"""Fault-tolerant checkpointing (no orbax in the container).

Format: one ``.npz`` per checkpoint holding every leaf keyed by its tree
path, plus a JSON manifest (step, tree structure, dtypes, user metadata).
Writes are ATOMIC: payload goes to ``<dir>/tmp.<pid>`` and is renamed into
place only after fsync — a killed process never leaves a half-written
checkpoint visible (restart safety on preemption).

Checkpoints are stored *logically* (host numpy, unsharded): a restart may
restore onto a different mesh shape — the trainer re-device_puts leaves
with its own NamedShardings (elastic scaling; see repro.fl.elastic).

CheckpointManager adds retention (keep_n) and best-effort resume:
``manager.restore_latest()`` scans for the newest complete step.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Optional

import jax
import numpy as np

from repro.utils.tree import flatten_with_names


def _to_host(tree: Any) -> dict[str, np.ndarray]:
    return {name: np.asarray(jax.device_get(leaf))
            for name, leaf in flatten_with_names(tree)}


def save(directory: str, step: int, trees: dict[str, Any],
         metadata: Optional[dict] = None) -> str:
    """trees: {'train': ..., 'opt': ..., ...} — each an arbitrary pytree."""
    os.makedirs(directory, exist_ok=True)
    payload: dict[str, np.ndarray] = {}
    structure: dict[str, Any] = {}
    for group, tree in trees.items():
        flat = _to_host(tree)
        structure[group] = jax.tree_util.tree_structure(tree)
        for name, arr in flat.items():
            payload[f"{group}::{name}"] = arr
    base = os.path.join(directory, f"ckpt_{step:08d}")
    fd, tmp = tempfile.mkstemp(dir=directory, prefix="tmp.")
    os.close(fd)
    with open(tmp, "wb") as f:
        np.savez(f, **payload)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, base + ".npz")
    man = {"step": step, "groups": sorted(trees.keys()),
           "metadata": metadata or {}}
    fd, tmp = tempfile.mkstemp(dir=directory, prefix="tmp.")
    os.close(fd)
    with open(tmp, "w") as f:
        json.dump(man, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, base + ".json")
    return base


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for fn in os.listdir(directory):
        if fn.startswith("ckpt_") and fn.endswith(".json"):
            base = fn[:-5]
            if os.path.exists(os.path.join(directory, base + ".npz")):
                steps.append(int(base.split("_")[1]))
    return max(steps) if steps else None


def restore(directory: str, step: int, like: dict[str, Any],
            shardings: Optional[dict[str, Any]] = None
            ) -> tuple[dict[str, Any], dict]:
    """Restore trees with the structure of `like` (values replaced).

    `shardings`: optional parallel tree of NamedShardings per group —
    leaves are device_put with them (elastic restart onto any mesh)."""
    base = os.path.join(directory, f"ckpt_{step:08d}")
    with open(base + ".json") as f:
        man = json.load(f)
    data = np.load(base + ".npz")
    out = {}
    for group, tree in like.items():
        flat = flatten_with_names(tree)
        leaves = []
        for name, ref in flat:
            arr = data[f"{group}::{name}"]
            if shardings is not None and group in shardings:
                sh_flat = dict(flatten_with_names(shardings[group]))
                leaves.append(jax.device_put(arr, sh_flat[name]))
            else:
                leaves.append(jax.numpy.asarray(arr, dtype=ref.dtype)
                              if hasattr(ref, "dtype") else arr)
        treedef = jax.tree_util.tree_structure(tree)
        out[group] = jax.tree_util.tree_unflatten(treedef, leaves)
    return out, man


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3):
        self.directory = directory
        self.keep_n = keep_n

    def save(self, step: int, trees: dict, metadata: Optional[dict] = None):
        save(self.directory, step, trees, metadata)
        self._gc()

    def restore_latest(self, like: dict, shardings=None):
        step = latest_step(self.directory)
        if step is None:
            return None
        trees, man = restore(self.directory, step, like, shardings)
        return step, trees, man

    def _gc(self):
        steps = sorted(
            int(fn[5:-5]) for fn in os.listdir(self.directory)
            if fn.startswith("ckpt_") and fn.endswith(".json"))
        for s in steps[: -self.keep_n] if self.keep_n else []:
            for ext in (".npz", ".json"):
                p = os.path.join(self.directory, f"ckpt_{s:08d}{ext}")
                if os.path.exists(p):
                    os.remove(p)
