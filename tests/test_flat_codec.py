"""Flat-tree wire codec (core/flat.py) vs the per-leaf oracle.

The acceptance contract of the fused codec:
  * wire serialization is byte-IDENTICAL to the per-leaf PackedLeaf
    codec (entry names, buffer contents, measured byte totals) — the
    accounting ``message_wire_bytes`` does not move by a single byte;
  * the flat payload holds the SAME words as every per-leaf kernel
    launch would produce (bit-identity via ``as_tree``), including
    per_stack and degenerate constant-channel leaves;
  * decode and K-client aggregation match the per-leaf path to fp32
    tolerance;
  * DISPATCH/COMPILE BOUNDS: packing + aggregating the quickstart
    ResNet-8 adapter tree is O(1) jitted programs on the flat path
    (one fused kernel launch each), while the per-leaf oracle compiles
    one program per leaf shape — counted via the jax.monitoring
    backend-compile event;
  * PackedLeaf.to_wire's vectorized host-side padding strip is
    byte-identical to the old unpack-and-repack jnp path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregation, flat, messages, quant
from repro.core.aggregation import FedAvgAggregator, FedBuffAggregator
from repro.core.flocora import FLoCoRAConfig
from repro.core.quant import QuantConfig
from repro.kernels import ref as kref

# backend-compile counter: the process-wide jax.monitoring hook lives in
# repro.obs.compile; the ``count_compiles`` fixture (tests/conftest.py)
# hands tests the context-manager class
from repro.obs.compile import count_compiles  # noqa: E402


def _tree(key, scale=1.0):
    ks = jax.random.split(key, 5)
    return {"a": jax.random.normal(ks[0], (6, 8)) * scale,
            "b": jax.random.normal(ks[1], (4, 3, 5)) * scale,
            "odd": jax.random.normal(ks[2], (7, 3)) * scale,
            # degenerate channels: one constant, one all-zero
            "const": jnp.concatenate([jnp.full((5, 2), 3.0),
                                      jnp.zeros((5, 1))], axis=1),
            "norm": jax.random.normal(ks[3], (7,)) * scale}


def _block(x):
    return jax.block_until_ready(jax.tree.leaves(
        x, is_leaf=messages.is_wire_leaf)[0])


# ---------------------------------------------------------------------------
# byte identity with the per-leaf oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("per_stack", [False, True])
def test_flat_wire_byte_identical_to_per_leaf(bits, per_stack):
    """Same entry names, same buffer bytes, same measured totals — and
    both equal the static accounting."""
    t = _tree(jax.random.PRNGKey(bits))
    cfg = QuantConfig(bits=bits, per_stack=per_stack)
    per = messages.pack_message(t, cfg)
    fl = messages.pack_message(t, cfg, flat=True)
    assert isinstance(fl, flat.FlatPackedMessage)
    wp, wf = messages.message_to_wire(per), messages.message_to_wire(fl)
    assert [n for n, _ in wp] == [n for n, _ in wf]
    for (name, bp), (_, bf) in zip(wp, wf):
        assert set(bp) == set(bf), name
        for k in bp:
            np.testing.assert_array_equal(bp[k], bf[k]), (name, k)
    assert messages.packed_wire_bytes(fl) == \
        messages.packed_wire_bytes(per) == \
        messages.message_wire_bytes(t, cfg)


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_flat_payload_words_bit_identical(bits):
    """as_tree re-exposes the per-leaf kernel payloads as slices of the
    flat buffer — bit-for-bit, sidecars included."""
    t = _tree(jax.random.PRNGKey(7))
    cfg = QuantConfig(bits=bits)
    per = messages.pack_message(t, cfg)
    at = messages.pack_message(t, cfg, flat=True).as_tree()
    for k in ("a", "b", "odd", "const"):
        np.testing.assert_array_equal(np.asarray(at[k].payload),
                                      np.asarray(per[k].payload))
        np.testing.assert_array_equal(np.asarray(at[k].scale),
                                      np.asarray(per[k].scale))
        np.testing.assert_array_equal(np.asarray(at[k].zp),
                                      np.asarray(per[k].zp))
    np.testing.assert_array_equal(np.asarray(at["norm"]),
                                  np.asarray(t["norm"]))


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_flat_unpack_matches_per_leaf(bits):
    t = _tree(jax.random.PRNGKey(1), 2.0)
    cfg = QuantConfig(bits=bits)
    up = messages.unpack_message(messages.pack_message(t, cfg))
    uf = messages.unpack_message(messages.pack_message(t, cfg, flat=True))
    for k in t:
        np.testing.assert_allclose(np.asarray(up[k]), np.asarray(uf[k]),
                                   atol=1e-6)
        assert uf[k].dtype == t[k].dtype


def test_to_wire_strip_matches_jnp_repack():
    """Satellite: PackedLeaf.to_wire's host-side numpy word/bit strip is
    byte-identical to the old unpack-everything-and-repack jnp path."""
    for bits in (2, 4, 8):
        t = {"a": jax.random.normal(jax.random.PRNGKey(0), (6, 37)),
             "b": jax.random.normal(jax.random.PRNGKey(1), (3, 5, 9))}
        msg = messages.pack_message(t, QuantConfig(bits=bits))
        for leaf in (msg["a"], msg["b"]):
            lv = kref.unpack_words(leaf.payload,
                                   bits)[:, :leaf.n_per_channel]
            old = np.asarray(quant.pack_levels(
                lv.reshape(-1).astype(jnp.uint8), bits))
            np.testing.assert_array_equal(old, leaf.to_wire()["payload"])


def test_flat_serialization_roundtrip():
    """to_wire -> from_wire rebuilds the flat buffer bit-exactly (zero
    row tails included), through the v3 header."""
    t = _tree(jax.random.PRNGKey(3))
    fl = messages.pack_message(t, QuantConfig(bits=4), flat=True)
    wire = messages.message_to_wire(fl)
    hdr = messages.parse_wire_header(wire[0][1]["header"])
    assert hdr["bits"] == 4 and hdr["density"] == 1.0
    back = messages.message_from_wire(wire, fl)
    np.testing.assert_array_equal(np.asarray(back.payload),
                                  np.asarray(fl.payload))
    np.testing.assert_array_equal(np.asarray(back.scale),
                                  np.asarray(fl.scale))
    np.testing.assert_array_equal(np.asarray(back.zp), np.asarray(fl.zp))
    for a, b in zip(back.fp_leaves, fl.fp_leaves):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_unpack_decodes_nested_flat_messages():
    """A container OF flat messages (not just a top-level one) decodes
    leaf-wise through unpack_message."""
    cfg = QuantConfig(bits=8)
    t1, t2 = _tree(jax.random.PRNGKey(0)), _tree(jax.random.PRNGKey(1))
    nested = {"clients": [messages.pack_message(t1, cfg, flat=True),
                          messages.pack_message(t2, cfg, flat=True)]}
    out = messages.unpack_message(nested)
    for got, src in zip(out["clients"], (t1, t2)):
        ref = messages.unpack_message(messages.pack_message(src, cfg))
        for k in src:
            np.testing.assert_allclose(np.asarray(got[k]),
                                       np.asarray(ref[k]), atol=1e-6)


def test_pack_flat_passthrough_without_quantizable_leaves():
    t = {"n1": jnp.ones((5,)), "n2": jnp.zeros((3,))}
    out = messages.pack_message(t, QuantConfig(bits=8), flat=True)
    assert out is t          # nothing to pack: same passthrough as per-leaf


# ---------------------------------------------------------------------------
# aggregation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [2, 4, 8])
def test_flat_fedavg_matches_per_leaf(bits):
    trees = [_tree(jax.random.PRNGKey(i)) for i in range(5)]
    w = jnp.asarray([1.0, 2.0, 3.0, 1.5, 0.5])
    cfg = QuantConfig(bits=bits)
    ref = aggregation.fedavg_packed(
        [messages.pack_message(t, cfg) for t in trees], w)
    got = aggregation.fedavg_packed(
        [messages.pack_message(t, cfg, flat=True) for t in trees], w)
    for k in ref:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(ref[k]),
                                   rtol=1e-5, atol=1e-6)
        assert got[k].dtype == ref[k].dtype


def test_mixed_flat_and_per_leaf_buffer():
    """A buffer mixing flat and per-leaf messages (e.g. a FedBuff buffer
    spanning a codec rollout) aggregates through as_tree, exactly."""
    trees = [_tree(jax.random.PRNGKey(i)) for i in range(3)]
    w = jnp.asarray([1.0, 2.0, 1.5])
    cfg = QuantConfig(bits=4)
    ref = aggregation.fedavg_packed(
        [messages.pack_message(t, cfg) for t in trees], w)
    mixed = [messages.pack_message(trees[0], cfg, flat=True),
             messages.pack_message(trees[1], cfg),
             messages.pack_message(trees[2], cfg, flat=True)]
    got = aggregation.fedavg_packed(mixed, w)
    for k in ref:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(ref[k]),
                                   rtol=1e-5, atol=1e-6)


def test_flat_fedbuff_add_flush():
    """The async flush path: buffered flat messages aggregate in one
    rank-bucketed fused pass."""
    trees = [_tree(jax.random.PRNGKey(i)) for i in range(3)]
    cfg = QuantConfig(bits=8)
    agg = FedBuffAggregator(half_life=4.0)
    for i, t in enumerate(trees):
        agg.add(messages.pack_message(t, cfg, flat=True),
                n_k=10.0, staleness=float(i))
    got = agg.flush()
    w = jnp.asarray([10.0 * 2.0 ** (-i / 4.0) for i in range(3)])
    ref = aggregation.fedavg_packed(
        [messages.pack_message(t, cfg) for t in trees], w)
    for k in ref:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(ref[k]),
                                   rtol=1e-5, atol=1e-6)


def test_flat_hetero_rank_buckets():
    """Mixed-rank flat messages bucket by (shape-walked) rank and equal
    the per-leaf hetero aggregation."""
    from repro.core import lora

    def adapters(key, r):
        ks = jax.random.split(key, 2)
        return {"l": {"a": jax.random.normal(ks[0], (16, r)),
                      "b": jax.random.normal(ks[1], (r, 12)) * 0.1}}

    msgs_fp = [adapters(jax.random.PRNGKey(i), r)
               for i, r in enumerate((4, 8, 4, 8))]
    w = jnp.asarray([1.0, 2.0, 1.5, 0.5])
    cfg = QuantConfig(bits=8)
    per = [messages.pack_message(t, cfg) for t in msgs_fp]
    fl = [messages.pack_message(t, cfg, flat=True) for t in msgs_fp]
    assert [messages.message_rank(m) for m in fl] == [4, 8, 4, 8]
    ref = FedAvgAggregator(cfg, r_target=8).aggregate(per, w)
    got = FedAvgAggregator(cfg, r_target=8).aggregate(fl, w)
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_flat_ef_uplink_preserves_dtype():
    from repro.core import flocora
    cfg = FLoCoRAConfig(quant_bits=8, error_feedback=True)
    x = {"w": (jax.random.normal(jax.random.PRNGKey(0), (4, 64))
               ).astype(jnp.bfloat16),
         "norm": jnp.ones((5,), jnp.bfloat16)}
    msg, _ = flocora.client_uplink(x, cfg, None)
    assert isinstance(msg, flat.FlatPackedMessage)
    out = messages.unpack_message(msg)
    assert out["w"].dtype == jnp.bfloat16
    assert out["norm"].dtype == jnp.bfloat16
    agg = FedAvgAggregator(cfg.qcfg).aggregate([msg, msg], jnp.ones(2))
    assert agg["w"].dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# dispatch/compile bounds (the acceptance measurement)
# ---------------------------------------------------------------------------

def _quickstart_adapters(rank=6):
    """The quickstart model: frozen ResNet-8 + LoRA adapters. Rank 6 is
    unused elsewhere in the suite, so this tree's shape signature is
    guaranteed cold in the process-wide compile cache."""
    from repro.core.lora import LoRAConfig
    from repro.models.resnet import ResNetConfig, init as rinit
    cfg = ResNetConfig(arch="resnet8",
                       lora=LoRAConfig(rank=rank, alpha=16.0 * rank))
    return rinit(jax.random.PRNGKey(0), cfg)["train"]


def test_flat_codec_dispatch_and_compile_bounds():
    """ACCEPTANCE: over the quickstart ResNet-8 adapter tree the flat
    path packs and aggregates in O(1) jitted programs (== fused kernel
    launches: each program contains exactly one pallas_call by
    construction, so <= 2 launches per message is implied by <= 2
    programs), while the per-leaf oracle compiles one program per leaf
    shape. Steady state recompiles nothing."""
    train = _quickstart_adapters()
    qcfg = QuantConfig(bits=4)
    n_shapes = len({tuple(x.shape) for x in jax.tree.leaves(train)
                    if x.ndim >= 2})
    assert n_shapes >= 5            # the bound below is meaningful

    with count_compiles() as c_per:
        _block(messages.pack_message(train, qcfg))
    with count_compiles() as c_flat:
        _block(messages.pack_message(train, qcfg, flat=True))
    assert c_flat.count <= 2, c_flat.count
    assert c_per.count >= n_shapes, (c_per.count, n_shapes)

    k = 4
    trees = [jax.tree.map(lambda x, i=i: x + 0.01 * i, train)
             for i in range(k)]
    w = jnp.ones((k,))
    msgs_p = [messages.pack_message(t, qcfg) for t in trees]
    msgs_f = [messages.pack_message(t, qcfg, flat=True) for t in trees]
    with count_compiles() as a_per:
        _block(aggregation.fedavg_packed(msgs_p, w))
    with count_compiles() as a_flat:
        _block(aggregation.fedavg_packed(msgs_f, w))
    assert a_flat.count <= 2, a_flat.count
    assert a_per.count >= n_shapes, (a_per.count, n_shapes)

    # steady state: the flat codec re-dispatches the SAME two programs
    with count_compiles() as steady:
        _block(messages.pack_message(train, qcfg, flat=True))
        _block(aggregation.fedavg_packed(msgs_f, w))
    assert steady.count == 0, steady.count

    # decode is one fused program too
    with count_compiles() as c_up:
        _block(messages.unpack_message(msgs_f[0]))
    assert c_up.count <= 2, c_up.count


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_ragged_row_kernels_match_jnp_twins(bits):
    """The TPU pallas bodies (ragged quant_pack, K-resident flat
    dequant_agg) are bit-identical to the jnp twins the CPU path lowers
    to — exercised in interpret mode on small shapes."""
    from repro.kernels import ops as kops
    from repro.kernels.quant_pack import quant_pack_pallas
    from repro.kernels.dequant_agg import dequant_agg_rows_pallas
    lane = (32 // bits) * 128
    c, n, k = 16, 2 * lane, 3
    rng = np.random.default_rng(bits)
    x = jnp.asarray(rng.normal(size=(c, n)).astype(np.float32))
    nv = jnp.asarray(rng.choice([1, 7, lane, n], size=c).astype(np.int32))
    pk, sk, zk = quant_pack_pallas(x, bits, n_valid=nv, block_c=8,
                                   interpret=True)
    pj, sj, zj = kops._quant_pack_rows_jnp(x, nv, bits)
    np.testing.assert_array_equal(np.asarray(pk), np.asarray(pj))
    np.testing.assert_array_equal(np.asarray(sk), np.asarray(sj))
    np.testing.assert_array_equal(np.asarray(zk), np.asarray(zj))

    P = jnp.stack([pk] * k)
    S = jnp.stack([sk] * k) * jnp.asarray([1.0, 0.5, 2.0])[:, None]
    Z = jnp.stack([zk] * k)
    w = jnp.asarray([0.2, 0.5, 0.3])
    got = dequant_agg_rows_pallas(P, S, Z, w, nv, bits, block_c=8,
                                  interpret=True)
    ref_out = np.asarray(kops.dequant_agg_rows(P, S, Z, w, nv, bits))
    np.testing.assert_allclose(np.asarray(got), ref_out, rtol=1e-6,
                               atol=1e-6)
    # tails past each row's n_valid are exact zeros in both
    for row_i in range(c):
        assert not np.any(ref_out[row_i, int(nv[row_i]):])


def test_flat_layout_cached_per_signature():
    t1 = _tree(jax.random.PRNGKey(0))
    t2 = _tree(jax.random.PRNGKey(1))
    l1 = flat.layout_for(t1, 4, False)
    l2 = flat.layout_for(t2, 4, False)
    assert l1 is l2                    # same signature -> same object
    assert flat.layout_for(t1, 8, False) is not l1   # bits key
    nv = l1.n_valid_vec()
    assert nv.shape == (l1.c_total,) and nv.min() > 0
