"""Dry-run core: AOT lower + compile one (arch x shape x mesh) cell,
extract memory/cost/collective analysis, append to a JSON cache.

Import AFTER the XLA device-count flag is set (dryrun.py does this in its
first two lines; tests set a smaller count in their own subprocess)."""
from __future__ import annotations

import json
import os
import time
import traceback
from typing import Any, Optional

import numpy as np

import jax

from repro.configs import registry
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_production_mesh
from repro.roofline.analysis import collective_bytes, roofline_terms, HW
from repro.roofline.hlo_cost import analyze_hlo
from repro.utils.sharding import num_chips

RESULTS_DIR = os.environ.get("DRYRUN_RESULTS", "results/dryrun")


def _active_params(entry, cfg) -> tuple[int, int]:
    """(total_params, active_params_per_token) — active discounts MoE
    experts to top_k(+shared) and subtracts the embedding gather."""
    from repro.models import encdec as ED
    from repro.models import lm as LM
    from repro.utils.tree import tree_size
    mod = ED if entry.kind == "encdec" else LM
    shapes = jax.eval_shape(
        lambda k: {g: t for g, t in mod.init(k, cfg).items()
                   if g in ("frozen", "train")}, jax.random.PRNGKey(0))
    total = tree_size(shapes["frozen"]) + tree_size(shapes["train"])
    active = total
    emb = cfg.vocab * cfg.d_model
    active -= emb                      # embedding gather is not a matmul
    moe = getattr(cfg, "moe", None)
    if moe is not None:
        n_moe_layers = cfg.n_layers // getattr(cfg, "moe_every", 1)
        per_expert = tree_size(jax.eval_shape(
            lambda k: __import__("repro.models.moe", fromlist=["x"])
            .moe_init(k, moe, "lora",
                      cfg.lora)[0], jax.random.PRNGKey(0))) // moe.n_experts
        inactive = n_moe_layers * per_expert * (moe.n_experts - moe.top_k)
        active -= inactive
    return total, active


def run_cell(arch: str, shape: str, *, multi_pod: bool,
             plan: Optional[steps_lib.CellPlan] = None,
             tag: str = "baseline",
             save: bool = True) -> dict:
    entry = registry.get(arch)
    cell_info = [c for c in registry.cells()
                 if c["arch"] == arch and c["shape"] == shape][0]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec: dict[str, Any] = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "tag": tag, "step": cell_info["step"]}
    if cell_info["skip"]:
        rec.update({"status": "skipped",
                    "skip_reason": cell_info["skip_reason"]})
        if save:
            _append(rec)
        return rec

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        built = steps_lib.build_cell(entry, shape, mesh, plan=plan)
        with mesh:
            jitted = jax.jit(
                built["fn"],
                in_shardings=built["in_shardings"],
                out_shardings=built["out_shardings"],
                donate_argnums=built["donate"] or ())
            lowered = jitted.lower(*built["args"])
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        xla_cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        # loop-aware analysis: xla's cost_analysis counts while bodies
        # once (EXPERIMENTS.md §Roofline methodology)
        la = analyze_hlo(hlo)
        cost = {"flops": la["flops"], "bytes accessed": la["bytes"]}
        coll = {"total": la["collective_total"], "n_ops": 0,
                **la["collectives"]}
        chips = num_chips(mesh)
        terms = roofline_terms(cost, coll, chips=chips)
        terms["xla_raw_flops"] = float(xla_cost.get("flops", 0.0))
        terms["xla_raw_bytes"] = float(xla_cost.get("bytes accessed", 0.0))

        cfg = built["cfg"]
        total, active = _active_params(entry, cfg)
        info = registry.SHAPES[shape]
        if cell_info["step"] == "train":
            tokens = info["batch"] * info["seq"]
            mf = 6.0 * active * tokens
        elif cell_info["step"] == "prefill":
            tokens = info["batch"] * info["seq"]
            mf = 2.0 * active * tokens
        else:
            tokens = info["batch"]
            mf = 2.0 * active * tokens
        hlo_flops_global = terms["hlo_flops_per_chip"] * chips
        rec.update({
            "status": "ok",
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "chips": chips,
            "memory": {k: int(v) for k, v in {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "peak_bytes": (mem.argument_size_in_bytes
                               + mem.temp_size_in_bytes),
            }.items()},
            "roofline": terms,
            "collectives": {k: float(v) for k, v in coll.items()},
            "model_flops_global": mf,
            "hlo_flops_global": hlo_flops_global,
            "useful_flops_ratio": (mf / hlo_flops_global
                                   if hlo_flops_global else None),
            "params_total": total,
            "params_active": active,
            "plan": {
                "microbatch": (plan or steps_lib.plan_for(arch, shape)
                               ).microbatch,
                "seq_parallel": (plan or steps_lib.plan_for(arch, shape)
                                 ).seq_parallel,
            },
        })
    except Exception as e:  # record failures — they are actionable bugs
        rec.update({"status": "error", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-2000:]})
    if save:
        _append(rec)
    return rec


def _append(rec: dict):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    key = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}__{rec['tag']}"
    path = os.path.join(RESULTS_DIR, key + ".json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)


def load_all(results_dir: Optional[str] = None) -> list[dict]:
    d = results_dir or RESULTS_DIR
    if not os.path.isdir(d):
        return []
    out = []
    for fn in sorted(os.listdir(d)):
        if fn.endswith(".json"):
            with open(os.path.join(d, fn)) as f:
                out.append(json.load(f))
    return out


def run_fl_round(arch: str, *, bits, multi_pod: bool = True,
                 clients_per_pod: int = 16, tag: str = "fl_round",
                 save: bool = True) -> dict:
    """Lower+compile the hierarchical multi-pod FL server round and
    record the CROSS-POD wire bytes (the paper's compression expressed
    in the collective schedule)."""
    from repro.launch.fl_round import build_fl_round
    entry = registry.get(arch)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec: dict[str, Any] = {"arch": arch, "shape": f"fl_round_b{bits}",
                           "mesh": mesh_name, "tag": tag,
                           "step": "fl_round"}
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        built = build_fl_round(entry, mesh, clients_per_pod=clients_per_pod,
                               bits=bits)
        with mesh:
            jitted = jax.jit(built["fn"],
                             in_shardings=built["in_shardings"])
            compiled = jitted.lower(*built["args"]).compile()
        hlo = compiled.as_text()
        la = analyze_hlo(hlo)
        mem = compiled.memory_analysis()
        # cross-pod traffic: collectives whose replica group spans pods
        # (group size == n_pods across the pod axis); approximate with
        # per-kind totals + u8 share
        import re
        u8 = sum(
            1 for l in hlo.splitlines()
            if re.search(r"u8\[[\d,]*\][^=]*all-gather", l))
        rec.update({
            "status": "ok",
            "compile_s": round(time.time() - t0, 1),
            "collectives": {k: float(v) for k, v in
                            la["collectives"].items()},
            "collective_total": la["collective_total"],
            "memory": {"peak_bytes": int(mem.argument_size_in_bytes
                                         + mem.temp_size_in_bytes)},
            "u8_allgather_ops": u8,
            "bits": bits,
        })
    except Exception as e:
        rec.update({"status": "error", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-2000:]})
    if save:
        _append(rec)
    return rec
