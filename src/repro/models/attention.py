"""Attention blocks: GQA (with sliding-window / prefix variants) and
DeepSeek-style MLA. Projections are FLoCoRA mixed-mode linears.

Head padding: when the true head count does not divide the tensor-model
axis (e.g. minitron's 24 heads on a 16-way mesh), configs set
``pad_heads_to`` — extra query heads have zero output projection, so the
function is exact while every matmul stays evenly shardable. KV heads are
never padded (GQA repeat covers them); KV caches shard their *sequence*
axis instead (FlashDecoding-style split-KV across chips).

Caches:
  GQA full:  {'k','v': (B, Smax, Hkv, Dh), 'pos': ()}          (global)
  GQA ring:  same shapes with Smax == window (ring buffer)     (local)
  MLA:       {'ckv': (B, Smax, kv_lora), 'kr': (B, Smax, rope_dim),
              'pos': ()} — latent cache + weight absorption at decode.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.lora import LoRAConfig, linear_init, linear_apply, \
    linear_logical
from repro.models import layers as L
from repro.utils.pcontext import constrain as pconstrain

Array = jax.Array


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GQASpec:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    qk_norm: bool = False
    pad_heads_to: Optional[int] = None   # padded query-head count

    @property
    def hq(self) -> int:
        return self.pad_heads_to or self.n_heads

    @property
    def rep(self) -> int:
        assert self.hq % self.n_kv_heads == 0
        return self.hq // self.n_kv_heads


def gqa_init(key: Array, spec: GQASpec, mode: str, lora: LoRAConfig,
             stack: tuple[int, ...] = ()) -> tuple[dict, dict]:
    ks = jax.random.split(key, 4)
    d, hq, hkv, dh = spec.d_model, spec.hq, spec.n_kv_heads, spec.head_dim
    fz, tr = {}, {}
    for k_, nm, dout in ((ks[0], "wq", hq * dh), (ks[1], "wk", hkv * dh),
                         (ks[2], "wv", hkv * dh), (ks[3], "wo", None)):
        if nm == "wo":
            f, t = linear_init(k_, hq * dh, d, mode, lora, stack)
        else:
            f, t = linear_init(k_, d, dout, mode, lora, stack)
        if f:
            fz[nm] = f
        if t:
            tr[nm] = t
    if spec.pad_heads_to and spec.pad_heads_to > spec.n_heads:
        # zero the padded heads' input to wo and output of wq so padding
        # is exact: mask applied in apply() (cheaper than editing weights
        # and keeps init distribution clean for real heads).
        pass
    if spec.qkv_bias:
        tr["bq"] = jnp.zeros((*stack, hq * dh), jnp.float32)
        tr["bk"] = jnp.zeros((*stack, hkv * dh), jnp.float32)
        tr["bv"] = jnp.zeros((*stack, hkv * dh), jnp.float32)
    if spec.qk_norm:
        tr["q_norm"] = L.rmsnorm_init(dh, stack)
        tr["k_norm"] = L.rmsnorm_init(dh, stack)
    return fz, tr


def gqa_logical(spec: GQASpec, mode: str, stack: bool) -> tuple[dict, dict]:
    fz, tr = {}, {}
    for nm, dims in (("wq", ("fsdp", "heads")), ("wk", ("fsdp", "kv_proj")),
                     ("wv", ("fsdp", "kv_proj")), ("wo", ("heads", "fsdp"))):
        f, t = linear_logical(*dims, mode, stack)
        if f:
            fz[nm] = f
        if t:
            tr[nm] = t
    pre = ("layers",) if stack else ()
    if spec.qkv_bias:
        tr["bq"] = (*pre, "heads")
        tr["bk"] = (*pre, "kv_proj")
        tr["bv"] = (*pre, "kv_proj")
    if spec.qk_norm:
        tr["q_norm"] = {"scale": (*pre, None)}
        tr["k_norm"] = {"scale": (*pre, None)}
    return fz, tr


def _head_mask(spec: GQASpec, dtype) -> Optional[Array]:
    if not spec.pad_heads_to or spec.pad_heads_to == spec.n_heads:
        return None
    m = jnp.zeros((spec.hq,), dtype).at[: spec.n_heads].set(1.0)
    return m[None, None, :, None]


def _qkv(fz, tr, spec: GQASpec, x: Array, lora_scale: float, rope):
    b, s, _ = x.shape
    dh = spec.head_dim
    q = linear_apply(fz.get("wq", {}), tr.get("wq", {}), x, lora_scale)
    k = linear_apply(fz.get("wk", {}), tr.get("wk", {}), x, lora_scale)
    v = linear_apply(fz.get("wv", {}), tr.get("wv", {}), x, lora_scale)
    if spec.qkv_bias:
        q = q + tr["bq"].astype(q.dtype)
        k = k + tr["bk"].astype(k.dtype)
        v = v + tr["bv"].astype(v.dtype)
    q = q.reshape(b, s, spec.hq, dh)
    k = k.reshape(b, s, spec.n_kv_heads, dh)
    v = v.reshape(b, s, spec.n_kv_heads, dh)
    if spec.qk_norm:
        q = L.rmsnorm_apply(tr["q_norm"], q)
        k = L.rmsnorm_apply(tr["k_norm"], k)
    if rope is not None:
        cos, sin = rope
        q = L.apply_rope(q, cos, sin)
        k = L.apply_rope(k, cos, sin)
    hm = _head_mask(spec, q.dtype)
    if hm is not None:
        q = q * hm
    return q, k, v


def gqa_apply(fz: dict, tr: dict, spec: GQASpec, x: Array,
              lora_scale: float, rope, *,
              window: Optional[int] = None,
              causal: bool = True,
              prefix_len: Optional[Array] = None,
              kv_chunk: int = 1024) -> Array:
    """Training / prefill forward. Returns (B, S, d)."""
    b, s, _ = x.shape
    q, k, v = _qkv(fz, tr, spec, x, lora_scale, rope)
    if window is not None and window < s:
        o = L.local_attention_blocked(q, k, v, window=window)
    else:
        o = L.attention_chunked(q, k, v, causal=causal,
                                prefix_len=prefix_len, kv_chunk=kv_chunk)
    hm = _head_mask(spec, o.dtype)
    if hm is not None:
        o = o * hm
    o = o.reshape(b, s, spec.hq * spec.head_dim)
    return linear_apply(fz.get("wo", {}), tr.get("wo", {}), o, lora_scale)


def gqa_cache_init(spec: GQASpec, batch: int, max_seq: int,
                   window: Optional[int] = None,
                   dtype=jnp.bfloat16) -> dict:
    smax = min(window, max_seq) if window else max_seq
    shp = (batch, smax, spec.n_kv_heads, spec.head_dim)
    return {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype)}


def gqa_cache_logical() -> dict:
    return {"k": ("batch", "kv_seq", None, None),
            "v": ("batch", "kv_seq", None, None)}


def gqa_decode(fz: dict, tr: dict, spec: GQASpec, x: Array, cache: dict,
               pos: Array, lora_scale: float, rope, *,
               window: Optional[int] = None) -> tuple[Array, dict]:
    """x: (B, 1, d); pos: () current absolute position. Returns (y, cache')."""
    b = x.shape[0]
    q, k, v = _qkv(fz, tr, spec, x, lora_scale, rope)
    smax = cache["k"].shape[1]
    slot = (pos % smax) if window else pos
    kc = pconstrain(jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k.astype(cache["k"].dtype), slot, axis=1), "cache4")
    vc = pconstrain(jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v.astype(cache["v"].dtype), slot, axis=1), "cache4")
    length = jnp.minimum(pos + 1, smax)
    o = L.decode_attention(q, kc, vc, length)
    hm = _head_mask(spec, o.dtype)
    if hm is not None:
        o = o * hm
    o = o.reshape(b, 1, spec.hq * spec.head_dim)
    y = linear_apply(fz.get("wo", {}), tr.get("wo", {}), o, lora_scale)
    return y, {"k": kc, "v": vc}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MLASpec:
    d_model: int
    n_heads: int
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    @property
    def qk_dim(self) -> int:
        return self.qk_nope_dim + self.qk_rope_dim


def mla_init(key: Array, spec: MLASpec, mode: str, lora: LoRAConfig,
             stack: tuple[int, ...] = ()) -> tuple[dict, dict]:
    ks = jax.random.split(key, 6)
    h = spec.n_heads
    parts = {
        "q_a": (spec.d_model, spec.q_lora_rank),
        "q_b": (spec.q_lora_rank, h * spec.qk_dim),
        "kv_a": (spec.d_model, spec.kv_lora_rank + spec.qk_rope_dim),
        "k_b": (spec.kv_lora_rank, h * spec.qk_nope_dim),
        "v_b": (spec.kv_lora_rank, h * spec.v_head_dim),
        "wo": (h * spec.v_head_dim, spec.d_model),
    }
    fz, tr = {}, {}
    for k_, (nm, dims) in zip(ks, parts.items()):
        f, t = linear_init(k_, *dims, mode, lora, stack)
        if f:
            fz[nm] = f
        if t:
            tr[nm] = t
    tr["q_a_norm"] = L.rmsnorm_init(spec.q_lora_rank, stack)
    tr["kv_a_norm"] = L.rmsnorm_init(spec.kv_lora_rank, stack)
    return fz, tr


def mla_logical(spec: MLASpec, mode: str, stack: bool) -> tuple[dict, dict]:
    dims = {"q_a": ("fsdp", "kv_lora"), "q_b": ("kv_lora", "heads"),
            "kv_a": ("fsdp", "kv_lora"), "k_b": ("kv_lora", "heads"),
            "v_b": ("kv_lora", "heads"), "wo": ("heads", "fsdp")}
    fz, tr = {}, {}
    for nm, d in dims.items():
        f, t = linear_logical(*d, mode, stack)
        if f:
            fz[nm] = f
        if t:
            tr[nm] = t
    pre = ("layers",) if stack else ()
    tr["q_a_norm"] = {"scale": (*pre, None)}
    tr["kv_a_norm"] = {"scale": (*pre, None)}
    return fz, tr


def _mla_q(fz, tr, spec, x, lora_scale, rope):
    b, s, _ = x.shape
    h = spec.n_heads
    qa = linear_apply(fz.get("q_a", {}), tr.get("q_a", {}), x, lora_scale)
    qa = L.rmsnorm_apply(tr["q_a_norm"], qa)
    q = linear_apply(fz.get("q_b", {}), tr.get("q_b", {}), qa, lora_scale)
    q = q.reshape(b, s, h, spec.qk_dim)
    q_nope = q[..., : spec.qk_nope_dim]
    q_rope = L.apply_rope(q[..., spec.qk_nope_dim:], *rope)
    return q_nope, q_rope


def _mla_latent(fz, tr, spec, x, lora_scale, rope):
    kv = linear_apply(fz.get("kv_a", {}), tr.get("kv_a", {}), x, lora_scale)
    ckv = L.rmsnorm_apply(tr["kv_a_norm"], kv[..., : spec.kv_lora_rank])
    kr = kv[..., spec.kv_lora_rank:][:, :, None, :]      # single shared head
    kr = L.apply_rope(kr, *rope)[:, :, 0]
    return ckv, kr


def mla_apply(fz: dict, tr: dict, spec: MLASpec, x: Array,
              lora_scale: float, rope, *, kv_chunk: int = 1024) -> Array:
    """Training / prefill: materialize per-head K,V from the latent."""
    b, s, _ = x.shape
    h = spec.n_heads
    q_nope, q_rope = _mla_q(fz, tr, spec, x, lora_scale, rope)
    ckv, kr = _mla_latent(fz, tr, spec, x, lora_scale, rope)
    k_nope = linear_apply(fz.get("k_b", {}), tr.get("k_b", {}), ckv,
                          lora_scale).reshape(b, s, h, spec.qk_nope_dim)
    v = linear_apply(fz.get("v_b", {}), tr.get("v_b", {}), ckv,
                     lora_scale).reshape(b, s, h, spec.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kr[:, :, None], (b, s, h, spec.qk_rope_dim))],
        axis=-1)
    o = L.attention_chunked(q, k, v, causal=True, kv_chunk=kv_chunk,
                            scale=spec.qk_dim ** -0.5)
    o = o.reshape(b, s, h * spec.v_head_dim)
    return linear_apply(fz.get("wo", {}), tr.get("wo", {}), o, lora_scale)


def mla_cache_init(spec: MLASpec, batch: int, max_seq: int,
                   dtype=jnp.bfloat16) -> dict:
    return {"ckv": jnp.zeros((batch, max_seq, spec.kv_lora_rank), dtype),
            "kr": jnp.zeros((batch, max_seq, spec.qk_rope_dim), dtype)}


def mla_cache_logical() -> dict:
    return {"ckv": ("batch", "kv_seq", None),
            "kr": ("batch", "kv_seq", None)}


def mla_decode(fz: dict, tr: dict, spec: MLASpec, x: Array, cache: dict,
               pos: Array, lora_scale: float, rope) -> tuple[Array, dict]:
    """Latent-cache decode with weight absorption (O(S·kv_lora) per head)."""
    b = x.shape[0]
    h = spec.n_heads
    q_nope, q_rope = _mla_q(fz, tr, spec, x, lora_scale, rope)
    ckv_new, kr_new = _mla_latent(fz, tr, spec, x, lora_scale, rope)
    ckv = pconstrain(jax.lax.dynamic_update_slice_in_dim(
        cache["ckv"], ckv_new.astype(cache["ckv"].dtype), pos, axis=1),
        "cache3")
    kr = pconstrain(jax.lax.dynamic_update_slice_in_dim(
        cache["kr"], kr_new.astype(cache["kr"].dtype), pos, axis=1),
        "cache3")
    # absorb k_b into q:  q_abs[b,h,c] = sum_d q_nope[b,h,d] * k_b[c,(h d)]
    k_b = _eff_weight(fz.get("k_b", {}), tr.get("k_b", {}), lora_scale)
    v_b = _eff_weight(fz.get("v_b", {}), tr.get("v_b", {}), lora_scale)
    k_b = k_b.reshape(spec.kv_lora_rank, h, spec.qk_nope_dim)
    v_b = v_b.reshape(spec.kv_lora_rank, h, spec.v_head_dim)
    q_abs = jnp.einsum("bhd,chd->bhc", q_nope[:, 0].astype(jnp.float32),
                       k_b.astype(jnp.float32))
    sc = spec.qk_dim ** -0.5
    s_lat = jnp.einsum("bhc,bsc->bhs", q_abs.astype(jnp.bfloat16),
                       ckv.astype(jnp.bfloat16),
                       preferred_element_type=jnp.float32)
    s_rope = jnp.einsum("bhr,bsr->bhs", q_rope[:, 0].astype(jnp.bfloat16),
                        kr.astype(jnp.bfloat16),
                        preferred_element_type=jnp.float32)
    scores = (s_lat + s_rope) * sc
    smax = cache["ckv"].shape[1]
    mask = jnp.arange(smax)[None, None, :] <= pos
    scores = jnp.where(mask, scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhs,bsc->bhc", p.astype(jnp.bfloat16),
                     ckv.astype(jnp.bfloat16),
                     preferred_element_type=jnp.float32)
    o = jnp.einsum("bhc,chd->bhd", ctx, v_b.astype(jnp.float32))
    o = o.reshape(b, 1, h * spec.v_head_dim).astype(x.dtype)
    y = linear_apply(fz.get("wo", {}), tr.get("wo", {}), o, lora_scale)
    return y, {"ckv": ckv, "kr": kr}


def _eff_weight(fz: dict, tr: dict, lora_scale: float) -> Array:
    """Effective (merged) weight of a mixed-mode linear — used where
    absorption needs the matrix itself rather than its action."""
    if "w" in tr:
        w = tr["w"]
    else:
        from repro.core.lora import frozen_weight
        w = frozen_weight(fz)
    if "a" in tr:
        w = w.astype(jnp.float32) + lora_scale * (
            tr["a"].astype(jnp.float32) @ tr["b"].astype(jnp.float32))
    return w
