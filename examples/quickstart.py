"""Quickstart: FLoCoRA (paper Fig. 1) in ~40 lines.

Federates a ResNet-8 over 20 clients on a synthetic CIFAR-like task,
exchanging int8-quantized LoRA adapters, and prints the communication
saving vs FedAvg (paper Tables I/III).

    PYTHONPATH=src python examples/quickstart.py [--rounds 10]
"""
import argparse
import sys

import jax
import numpy as np

sys.path.insert(0, "src")

from repro.core import messages
from repro.core.flocora import FLoCoRAConfig
from repro.core.lora import LoRAConfig
from repro.core.quant import QuantConfig
from repro.data import SyntheticVision, lda_partition
from repro.fl import ClientConfig, FLServer, ServerConfig
from repro.models.resnet import ResNetConfig, init as resnet_init, loss_fn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=6)
    args = ap.parse_args()

    # data: 100 clients worth of non-IID (LDA 0.5) synthetic images
    rng = np.random.default_rng(0)
    sv = SyntheticVision(seed=0)
    y = rng.integers(0, 10, 2000)
    x = sv.sample(rng, y)
    parts = lda_partition(y, 20, alpha=0.5)
    data = [{"x": x[p], "y": y[p].astype(np.int32)} for p in parts]

    # model: frozen random ResNet-8 + rank-32 adapters (alpha = 16r)
    cfg = ResNetConfig(arch="resnet8", lora=LoRAConfig(rank=32, alpha=512.0))
    model = resnet_init(jax.random.PRNGKey(0), cfg)

    fedavg_bytes = messages.message_wire_bytes(
        resnet_init(jax.random.PRNGKey(0),
                    ResNetConfig(arch="resnet8", mode="fedavg"))["train"],
        QuantConfig())
    flocora_bytes = messages.message_wire_bytes(model["train"],
                                                QuantConfig(bits=8))
    print(f"message: FedAvg {fedavg_bytes/1e6:.2f} MB -> FLoCoRA+int8 "
          f"{flocora_bytes/1e6:.3f} MB "
          f"({fedavg_bytes/flocora_bytes:.1f}x smaller)")

    server = FLServer(
        model, lambda f, t, b: loss_fn(f, t, cfg, b), data,
        ServerConfig(rounds=args.rounds, n_clients=20, clients_per_round=5),
        ClientConfig(local_epochs=1, batch_size=32, lr=0.01),
        FLoCoRAConfig(rank=32, alpha=512.0, quant_bits=8))
    for h in server.run():
        print(h)


if __name__ == "__main__":
    main()
