"""Lazy client populations: million-client fleets without per-client state.

The engines' original client-identity layer was a materialized
``list[dict]`` of per-client datasets — every structure keyed by cid
(data shards, rank schedules, free-client lists) was O(fleet), which is
fine for dozens of simulated clients and impossible for the FedBuff
paper's operating point (buffers of K~10 drawn from MILLIONS of
concurrent devices). This module replaces that layer with a
:class:`Population`: every per-client property is a PURE FUNCTION of
``(seed, cid)``, computed on demand:

  * DEVICE TIERS (:class:`DeviceTier`) — the fleet is a mix of device
    classes (phones/laptops/workstations), each with an adapter rank,
    a population fraction, a mid-round churn probability and a diurnal
    availability profile. ``tier_for(cid)`` hashes the cid onto the
    cumulative fraction split, so tier membership needs no table;
  * LAZY DATA SHARDS — ``population[cid]`` generates client cid's
    synthetic shard from ``data/synthetic.py`` keyed ``(seed, cid)``
    (bit-identical on regeneration), held in a bounded LRU so peak
    resident data is O(cache), never O(fleet). ``peak_resident`` is the
    measured high-water mark the fleet benchmark asserts on;
  * LAZY SAMPLING — ``sample_cid(rng, busy)`` rejection-samples a
    dispatch candidate against the (tiny) busy set instead of
    enumerating the fleet's free clients.

``Population`` quacks like the engines' ``client_data`` list
(``__len__`` / ``__getitem__``), so both engines accept either.
:class:`PopulationTrace` composes a population with
:class:`~repro.fl.traces.FleetTrace`: availability windows and churn
probabilities resolve per TIER, while every draw stays keyed by
``(seed, cid, dispatch_idx)`` — deterministic replay and bit-exact
checkpoint/resume survive the tiering.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Callable, Optional

import numpy as np

from repro.data import synthetic
from repro.fl.client import ClientConfig
from repro.fl.traces import AvailabilityWindows, FleetTrace

# hash constant for tier assignment (Knuth multiplicative; same idiom as
# AvailabilityWindows.phase but a distinct stream: a client's tier and
# its availability phase must not correlate)
_TIER_HASH = 2246822519


@dataclasses.dataclass(frozen=True)
class DeviceTier:
    """One device class in the fleet mix.

    ``fraction`` is the tier's share of the population (fractions must
    sum to 1); ``rank`` its adapter rank tier; ``p_churn`` the
    probability a dispatched client of this tier drops mid-round;
    ``period_s``/``duty`` its diurnal availability profile (phones
    charge at night; 0/1.0 = always available)."""
    name: str
    rank: int
    fraction: float
    p_churn: float = 0.0
    period_s: float = 0.0
    duty: float = 1.0

    def __post_init__(self):
        if self.rank < 1:
            raise ValueError("tier rank must be >= 1")
        if not 0.0 < self.fraction <= 1.0:
            raise ValueError("tier fraction must be in (0, 1]")
        if not 0.0 <= self.p_churn < 1.0:
            raise ValueError("tier p_churn must be in [0, 1)")
        # delegate window validation
        AvailabilityWindows(self.period_s, self.duty)


def default_tiers() -> tuple[DeviceTier, ...]:
    """A production-shaped mix: mostly phones, some laptops, few
    workstations — diurnal phones churn, plugged-in machines don't."""
    return (
        DeviceTier("phone", rank=4, fraction=0.70, p_churn=0.08,
                   period_s=86400.0, duty=0.4),
        DeviceTier("laptop", rank=8, fraction=0.25, p_churn=0.03,
                   period_s=86400.0, duty=0.7),
        DeviceTier("workstation", rank=16, fraction=0.05),
    )


class Population:
    """A lazy fleet of ``n_clients`` simulated devices (see module
    docstring). ``shard_fn(seed, cid) -> dict`` generates one client's
    dataset on demand (default: :func:`repro.data.synthetic
    .client_shard` with ``shard_size`` samples); ``cache_clients``
    bounds how many generated shards stay resident."""

    def __init__(self, n_clients: int,
                 tiers: Optional[tuple[DeviceTier, ...]] = None,
                 seed: int = 0, shard_size: int = 64,
                 shard_fn: Optional[Callable[[int, int], dict]] = None,
                 cache_clients: int = 256):
        if n_clients < 1:
            raise ValueError("n_clients must be >= 1")
        if shard_size < 1:
            raise ValueError("shard_size must be >= 1")
        if cache_clients < 1:
            raise ValueError("cache_clients must be >= 1")
        tiers = default_tiers() if tiers is None else tuple(tiers)
        if not tiers:
            raise ValueError("population needs at least one tier")
        total = sum(t.fraction for t in tiers)
        if abs(total - 1.0) > 1e-6:
            raise ValueError(
                f"tier fractions must sum to 1, got {total}")
        self.n_clients = n_clients
        self.tiers = tiers
        self.seed = seed
        self.shard_size = shard_size
        self._shard_fn = shard_fn if shard_fn is not None else (
            lambda s, cid: synthetic.client_shard(s, cid, n=shard_size))
        self.cache_clients = cache_clients
        self._cache: OrderedDict[int, dict] = OrderedDict()
        self.peak_resident = 0
        # cumulative fraction boundaries for the tier hash
        cum = np.cumsum([t.fraction for t in tiers])
        cum[-1] = 1.0            # absorb fp rounding at the top edge
        self._cum = cum
        self._windows = tuple(AvailabilityWindows(t.period_s, t.duty)
                              for t in tiers)

    # -- tier properties (pure functions of cid) ----------------------------
    def tier_index(self, cid: int) -> int:
        if not 0 <= cid < self.n_clients:
            raise IndexError(f"cid {cid} outside fleet of "
                             f"{self.n_clients}")
        u = (((cid + self.seed + 1) * _TIER_HASH) % (1 << 32)) \
            / float(1 << 32)
        return int(np.searchsorted(self._cum, u, side="right")
                   .clip(0, len(self.tiers) - 1))

    def tier_for(self, cid: int) -> DeviceTier:
        return self.tiers[self.tier_index(cid)]

    def rank_for(self, cid: int) -> int:
        return self.tier_for(cid).rank

    def p_churn_for(self, cid: int) -> float:
        return self.tier_for(cid).p_churn

    def availability_for(self, cid: int) -> AvailabilityWindows:
        return self._windows[self.tier_index(cid)]

    @property
    def max_rank(self) -> int:
        return max(t.rank for t in self.tiers)

    @property
    def mixed_ranks(self) -> bool:
        return len({t.rank for t in self.tiers}) > 1

    @property
    def expected_churn(self) -> float:
        """Fleet-mean dispatch churn probability (fraction-weighted)."""
        return sum(t.fraction * t.p_churn for t in self.tiers)

    def tier_counts(self, sample: int = 10000) -> dict[str, int]:
        """Tier histogram over the first ``sample`` cids (diagnostics —
        the hash split approximates the configured fractions)."""
        n = min(sample, self.n_clients)
        out = {t.name: 0 for t in self.tiers}
        for cid in range(n):
            out[self.tier_for(cid).name] += 1
        return out

    # -- lazy data shards (bounded LRU, O(cache) resident) ------------------
    def __len__(self) -> int:
        return self.n_clients

    def __getitem__(self, cid: int) -> dict:
        if not 0 <= cid < self.n_clients:
            raise IndexError(cid)
        got = self._cache.get(cid)
        if got is not None:
            self._cache.move_to_end(cid)
            return got
        shard = self._shard_fn(self.seed, cid)
        self._cache[cid] = shard
        while len(self._cache) > self.cache_clients:
            self._cache.popitem(last=False)
        self.peak_resident = max(self.peak_resident, len(self._cache))
        return shard

    @property
    def resident_clients(self) -> int:
        return len(self._cache)

    def schedule_steps(self, ccfg: ClientConfig) -> int:
        """Fixed cohort-program schedule length: every shard has
        ``shard_size`` samples, so the fleet-wide natural step count is
        O(1) — no per-client scan (the eager path's ``cohort_steps``
        iterates the whole fleet)."""
        return max(1, self.shard_size // ccfg.batch_size) \
            * ccfg.local_epochs

    # -- lazy dispatch sampling ---------------------------------------------
    def sample_cid(self, rng: np.random.Generator,
                   busy: Optional[set] = None) -> Optional[int]:
        """One dispatch candidate, uniform over non-busy clients.

        Rejection-samples against the busy set — O(1) expected when
        ``len(busy) << n_clients`` (the async engine keeps
        O(concurrency) in flight over a fleet of millions). Falls back
        to an explicit scan only for toy fleets where the busy set is a
        large fraction of the population; returns None when every
        client is busy."""
        if not busy:
            return int(rng.integers(self.n_clients))
        if len(busy) >= self.n_clients:
            return None
        # expected tries = n / (n - busy); 64 tries fails with prob
        # <= (busy/n)^64, vanishing unless the fleet is nearly saturated
        for _ in range(64):
            cid = int(rng.integers(self.n_clients))
            if cid not in busy:
                return cid
        free = [c for c in range(self.n_clients) if c not in busy]
        return int(free[rng.integers(len(free))]) if free else None


@dataclasses.dataclass(frozen=True)
class PopulationTrace(FleetTrace):
    """A :class:`FleetTrace` whose availability windows and churn
    probabilities resolve per DEVICE TIER from a lazy population —
    phones are diurnal and flaky, workstations always-on — while every
    latency/churn draw stays keyed ``(seed, cid, dispatch_idx)``
    (deterministic replay; see traces.py)."""
    population: Optional[Population] = None

    def __post_init__(self):
        super().__post_init__()
        if self.population is None:
            raise ValueError("PopulationTrace requires a population")

    def availability_for(self, cid: int) -> AvailabilityWindows:
        return self.population.availability_for(cid)

    def p_churn_for(self, cid: int) -> float:
        return self.population.p_churn_for(cid)
