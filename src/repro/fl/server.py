"""FL server orchestration: FLoCoRA rounds with fault tolerance.

Production-shaped features:
  * client sampling (uniform over C clients, K' = oversample*K sampled);
  * STRAGGLER MITIGATION: K' > K clients are dispatched, the aggregation
    takes the first K arrivals (simulated latency ordering) — the paper's
    synchronous FedAvg becomes deadline-robust;
  * CLIENT DROPOUT: a failed client (prob p_fail) contributes nothing;
    aggregation weights renormalize over survivors — a round never blocks;
  * VMAPPED COHORT ENGINE: the surviving clients' local runs execute as
    ONE jitted vmapped program over stacked batches, not a sequential
    Python loop (see fl/client.py);
  * WIRE-TRUE quantized exchange per the paper: broadcast and uplink
    travel as PACKED messages (uint32 payloads + fp32 sidecars,
    core/messages.py) and the server aggregates the packed payloads on
    the fused dequant_agg kernel via a pluggable Aggregator strategy —
    with optional error feedback (beyond paper);
  * atomic checkpoint/resume of (round, global adapters, sampler RNG) —
    a restarted server continues the exact run; the RNG bit-generator
    state rides the JSON manifest directly;
  * TCC accounting per Eq. 2 (including the shared-once initial model).
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import flocora, messages
from repro.core.aggregation import Aggregator, ErrorFeedbackFedAvg, \
    FedAvgAggregator
from repro.core.flocora import FLoCoRAConfig
from repro.checkpoint import CheckpointManager
from repro.fl.client import ClientConfig, cohort_steps, \
    make_cohort_trainer, stack_cohort_batches
from repro.utils.tree import tree_bytes

Array = jax.Array


@dataclasses.dataclass
class ServerConfig:
    rounds: int = 100
    n_clients: int = 100
    clients_per_round: int = 10
    oversample: float = 1.0        # straggler mitigation: dispatch K'=o*K
    p_client_failure: float = 0.0  # simulated client dropout
    seed: int = 0
    eval_every: int = 5
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 25


class FLServer:
    """Simulates the paper's FL loop (Fig. 1) over arbitrary models.

    model: dict with 'frozen'/'train' trees (train = FLoCoRA adapters);
    loss_fn(frozen, train, batch); client_data: list of per-client dict
    datasets (numpy); eval_fn(frozen, train) -> metrics dict;
    aggregator: Aggregator strategy (defaults to FedAvg, or its
    EF-compensated variant when fcfg.error_feedback is set).
    """

    def __init__(self, model: dict, loss_fn: Callable,
                 client_data: list[dict], scfg: ServerConfig,
                 ccfg: ClientConfig, fcfg: FLoCoRAConfig,
                 eval_fn: Optional[Callable] = None,
                 aggregator: Optional[Aggregator] = None):
        self.frozen = model["frozen"]
        self.global_train = model["train"]
        self.loss_fn = loss_fn
        self.client_data = client_data
        self.scfg, self.ccfg, self.fcfg = scfg, ccfg, fcfg
        self.eval_fn = eval_fn
        self.rng = np.random.default_rng(scfg.seed)
        self.round = 0
        self.history: list[dict] = []
        self.trainer = make_cohort_trainer(loss_fn, ccfg)
        # fixed schedule length across ALL clients: the cohort program's
        # shape never changes between rounds (only distinct cohort sizes
        # K retrace), and small clients are masked, not over-trained
        self.cohort_schedule_steps = cohort_steps(client_data, ccfg)
        ef_wanted = fcfg.error_feedback and fcfg.qcfg.enabled
        if aggregator is None:
            aggregator = ErrorFeedbackFedAvg(fcfg.qcfg) if ef_wanted \
                else FedAvgAggregator(fcfg.qcfg)
        elif ef_wanted != isinstance(aggregator, ErrorFeedbackFedAvg):
            # the uplink encode (fcfg.error_feedback) and the residual
            # store (aggregator type) must agree, or EF silently degrades
            # to plain RTN / maintains dead residuals
            raise ValueError(
                "error_feedback={} (quant {}) requires {} aggregator, got "
                "{}".format(fcfg.error_feedback,
                            "on" if fcfg.qcfg.enabled else "off",
                            "an ErrorFeedbackFedAvg" if ef_wanted
                            else "a non-EF",
                            type(aggregator).__name__))
        self.aggregator = aggregator
        self.ckpt = CheckpointManager(scfg.checkpoint_dir) \
            if scfg.checkpoint_dir else None
        one_way = messages.message_wire_bytes(self.global_train, fcfg.qcfg)
        self.round_bytes_per_client = 2 * one_way
        self.initial_model_bytes = tree_bytes(self.frozen)
        self._up_bytes_measured: Optional[int] = None

    # -- fault tolerance ----------------------------------------------------
    def save(self):
        if self.ckpt is None:
            return
        # bit-generator state is a plain dict of ints/strings — it rides
        # the JSON manifest as-is (no repr/eval round-trip)
        self.ckpt.save(self.round, {"train": self.global_train},
                       metadata={"round": self.round,
                                 "rng_state": self.rng.bit_generator.state})

    def try_resume(self) -> bool:
        if self.ckpt is None:
            return False
        got = self.ckpt.restore_latest({"train": self.global_train})
        if got is None:
            return False
        step, trees, man = got
        self.global_train = trees["train"]
        self.round = man["metadata"]["round"]
        st = man["metadata"].get("rng_state")
        if isinstance(st, str):
            # legacy manifests stored repr(state); literal_eval migrates
            # them safely (plain dict of ints, never code)
            st = ast.literal_eval(st)
        if st:
            self.rng.bit_generator.state = st
        return True

    # -- one round (paper Fig. 1) --------------------------------------------
    def run_round(self) -> dict:
        scfg, fcfg = self.scfg, self.fcfg
        k_target = scfg.clients_per_round
        k_dispatch = max(k_target, int(round(scfg.oversample * k_target)))
        sampled = self.rng.choice(scfg.n_clients, size=k_dispatch,
                                  replace=False)

        # (1) broadcast: packed downlink; clients reconstruct the
        # quantized global adapters
        g_bcast = flocora.broadcast(self.global_train, fcfg)

        survivors = [int(cid) for cid in sampled
                     if self.rng.random() >= scfg.p_client_failure]
        if not survivors:
            self.round += 1
            return {"round": self.round, "n_agg": 0}

        # (2) local training: the whole surviving cohort runs as ONE
        # jitted vmapped program over stacked batches (fixed schedule
        # length; per-client n_steps mask)
        datas = [self.client_data[cid] for cid in survivors]
        batches, n_steps = stack_cohort_batches(
            self.rng, datas, self.ccfg, steps=self.cohort_schedule_steps)
        batches = jax.tree.map(jnp.asarray, batches)
        trained, losses = self.trainer(self.frozen, g_bcast, batches,
                                       jnp.asarray(n_steps))
        losses = np.asarray(losses)

        # (3) uplink: each client emits its PACKED wire message
        ef = isinstance(self.aggregator, ErrorFeedbackFedAvg)
        results = []
        for k, cid in enumerate(survivors):
            t_k = jax.tree.map(lambda x: x[k], trained)
            res = self.aggregator.residual(cid, t_k) if ef else None
            msg, res = flocora.client_uplink(t_k, fcfg, res)
            if ef:
                self.aggregator.store_residual(cid, res)
            latency = self.rng.exponential(1.0)  # simulated arrival time
            n_i = len(next(iter(datas[k].values())))
            results.append((latency, n_i, msg, float(losses[k])))

        # straggler policy: first K arrivals win
        results.sort(key=lambda r: r[0])
        kept = results[:k_target]
        weights = jnp.asarray([r[1] for r in kept], jnp.float32)
        # (4) aggregation strategy; packed inputs lower onto the fused
        # dequant+reduce kernel
        self.global_train = self.aggregator.aggregate(
            [r[2] for r in kept], weights)
        self.round += 1

        if self._up_bytes_measured is None and fcfg.qcfg.enabled:
            self._up_bytes_measured = messages.packed_wire_bytes(kept[0][2])
        rec = {"round": self.round, "n_agg": len(kept),
               "n_dropped": k_dispatch - len(results),
               "n_straggled": len(results) - len(kept),
               "client_loss": float(np.mean([r[3] for r in kept])),
               # Eq. 2 incl. the shared-once initial model
               "tcc_bytes": self.initial_model_bytes
               + self.round * self.round_bytes_per_client}
        if self._up_bytes_measured is not None:
            rec["up_bytes_measured"] = self._up_bytes_measured
        if self.eval_fn and self.round % self.scfg.eval_every == 0:
            rec.update(self.eval_fn(self.frozen, self.global_train))
        self.history.append(rec)
        if self.ckpt and self.round % self.scfg.checkpoint_every == 0:
            self.save()
        return rec

    def run(self, rounds: Optional[int] = None) -> list[dict]:
        for _ in range(rounds or self.scfg.rounds):
            self.run_round()
        return self.history
