import os
import sys

# single-device for unit tests — the 512-device mesh is exercised only by
# the dry-run (its own process sets the XLA flag before importing jax)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)

# the shared backend-compile counter fixture (``count_compiles``): any
# test may take it as an argument instead of importing repro.obs.compile
from repro.obs.compile import count_compiles_fixture  # noqa: E402,F401
