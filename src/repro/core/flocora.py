"""FLoCoRA high-level API (paper §III, Fig. 1).

One communication round:
  (1) server broadcasts global adapter tree  Δ̄_t L        (quantized)
  (2) each sampled client k trains locally   Δ^k_{t+1} L
  (3) client uploads its adapter tree                       (quantized)
  (4) server FedAvg-aggregates:  Δ̄_{t+1} L = Σ_k (n_k/n) Δ^k_{t+1} L

The base model W_initial is exchanged exactly once (round 0) and never
updated — that is the whole trick. ``server_round``/``broadcast`` are the
jittable pieces; orchestration (sampling, stragglers, faults) lives in
``repro.fl``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import aggregation, messages
from repro.core.quant import QuantConfig

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class FLoCoRAConfig:
    rank: int = 32
    alpha: float = 512.0            # paper default: alpha = 16 * r
    quant_bits: Optional[int] = None  # None | 8 | 4 | 2
    error_feedback: bool = False    # beyond-paper EF on the client uplink
    head_mode: str = "dense"        # 'dense' (paper) | 'lora' | 'frozen'

    @property
    def qcfg(self) -> QuantConfig:
        return QuantConfig(bits=self.quant_bits)

    @property
    def scale(self) -> float:
        return self.alpha / self.rank


def server_downlink(global_trainable: Any, cfg: FLoCoRAConfig) -> Any:
    """Step (1), wire form: the packed message the server broadcasts
    (uint32 payloads + fp32 sidecars; fp tree when quantization is off)."""
    if not cfg.qcfg.enabled:
        return global_trainable
    return messages.pack_message(global_trainable, cfg.qcfg)


def broadcast(global_trainable: Any, cfg: FLoCoRAConfig) -> Any:
    """Step (1): what clients reconstruct from the server message."""
    return messages.unpack_message(server_downlink(global_trainable, cfg))


def client_uplink(trainable: Any, cfg: FLoCoRAConfig,
                  ef_residual: Optional[Any] = None
                  ) -> tuple[Any, Optional[Any]]:
    """Step (3): one client's WIRE message (packed payloads when
    quantization is on; the raw fp tree otherwise).

    With error feedback enabled, the client compensates its own previous
    quantization error (beyond-paper option); pass the stored residual
    (``None`` initializes a zero residual). Returns (message, residual)."""
    if cfg.error_feedback and cfg.qcfg.enabled:
        if ef_residual is None:
            ef_residual = aggregation.ef_init(trainable)
        return aggregation.ef_encode_packed(trainable, ef_residual,
                                            cfg.qcfg)
    if not cfg.qcfg.enabled:
        return trainable, ef_residual
    return messages.pack_message(trainable, cfg.qcfg), ef_residual


def server_round(stacked_client_trainables: Any, weights: Array,
                 cfg: FLoCoRAConfig) -> Any:
    """Steps (3)+(4) fused: dequantize each client message and FedAvg.

    `stacked_client_trainables` leaves have a leading K (clients) dim and
    hold the *raw* client fp trees; quantization happens inside so the
    whole round jits into one program (and, on TPU, lowers onto the fused
    dequant+reduce Pallas kernel)."""
    return aggregation.fedavg_quantized(stacked_client_trainables, weights,
                                        cfg.qcfg)


def round_wire_bytes(trainable: Any, cfg: FLoCoRAConfig) -> dict:
    """Per-round, per-client message accounting (both directions equal)."""
    one_way = messages.message_wire_bytes(trainable, cfg.qcfg)
    return {"down_bytes": one_way, "up_bytes": one_way,
            "round_bytes": 2 * one_way}


def tcc(trainable: Any, cfg: FLoCoRAConfig, rounds: int) -> int:
    """Paper Eq. 2: total communication cost for one client, R rounds."""
    return messages.tcc_bytes(trainable, cfg.qcfg, rounds)
