"""Pure-jnp oracles for the Pallas kernels (the correctness contracts).

Layouts (kernel-facing, channel-FIRST 2D views — callers reshape):
  quant_pack:  x (C, N) -> packed (C, N*bits/32) uint32, scale (C,), zp (C,)
  dequant_agg: packed (K, C, Nw) uint32, scale/zp (K, C), weights (K,)
               -> out (C, N) fp32  = sum_k w_k * dequant_k
  lora_matmul: x (M, K), w (K, N), a (K, r), b (r, N), s
               -> x@w + s*(x@a)@b  (bf16 in, fp32 accum, bf16 out)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def _qparams_rowwise(x: Array, bits: int):
    qmax = (1 << bits) - 1
    xmin = jnp.minimum(jnp.min(x, axis=1), 0.0)
    xmax = jnp.maximum(jnp.max(x, axis=1), 0.0)
    rng = xmax - xmin
    # reciprocal multiply, matching the kernels bit-exactly (constant
    # divisions strength-reduce inconsistently across XLA programs)
    scale = jnp.where(rng > 0, rng * jnp.float32(1.0 / qmax), 1.0)
    zp = jnp.clip(jnp.round(-xmin / scale), 0, qmax)
    return scale, zp


def pack_words(levels: Array, bits: int) -> Array:
    """levels (C, N) uint32 -> (C, N*bits/32) uint32, little-endian."""
    per = 32 // bits
    c, n = levels.shape
    assert n % per == 0
    grp = levels.reshape(c, n // per, per).astype(jnp.uint32)
    shifts = (jnp.arange(per, dtype=jnp.uint32) * bits)[None, None, :]
    return jnp.sum(grp << shifts, axis=-1).astype(jnp.uint32)


def unpack_words(packed: Array, bits: int) -> Array:
    per = 32 // bits
    mask = jnp.uint32((1 << bits) - 1)
    shifts = (jnp.arange(per, dtype=jnp.uint32) * bits)
    lv = (packed[..., None] >> shifts) & mask
    return lv.reshape(*packed.shape[:-1], packed.shape[-1] * per)


def quant_pack_ref(x: Array, bits: int):
    """x (C, N) fp32. Returns (packed uint32 (C, N*bits/32), scale, zp)."""
    scale, zp = _qparams_rowwise(x.astype(jnp.float32), bits)
    qmax = (1 << bits) - 1
    q = jnp.clip(jnp.round(x / scale[:, None]) + zp[:, None], 0, qmax)
    return pack_words(q.astype(jnp.uint32), bits), scale, zp


def dequant_agg_ref(packed: Array, scale: Array, zp: Array,
                    weights: Array, bits: int) -> Array:
    """packed (K, C, Nw); scale/zp (K, C); weights (K,) -> (C, N) fp32."""
    lv = unpack_words(packed, bits).astype(jnp.float32)   # (K, C, N)
    deq = (lv - zp[..., None]) * scale[..., None]
    return jnp.einsum("k,kcn->cn", weights.astype(jnp.float32), deq)


def lora_matmul_ref(x: Array, w: Array, a: Array, b: Array,
                    s: float) -> Array:
    acc = x.astype(jnp.float32) @ w.astype(jnp.float32)
    h = x.astype(jnp.float32) @ a.astype(jnp.float32)
    acc = acc + s * (h @ b.astype(jnp.float32))
    return acc.astype(x.dtype)
