"""Kernel microbenchmarks: Pallas (interpret on CPU — correctness-path
timing only; TPU is the compile target) vs the jnp reference path that
XLA would otherwise run. The derived column reports reconstruction error
and wire-bytes ratios (the quantities that matter for FLoCoRA)."""
import jax
import jax.numpy as jnp

from benchmarks.common import time_us
from repro.kernels import ops, ref


def run() -> list[str]:
    rows = []
    k = jax.random.PRNGKey(0)

    # quant_pack: adapter-message shaped (r=32 channels x d=4096)
    x = jax.random.normal(k, (32, 4096))
    for bits in (8, 4, 2):
        f_ref = jax.jit(lambda x, b=bits: ref.quant_pack_ref(x, b))
        us_ref = time_us(f_ref, x, iters=10)
        us_ker = time_us(lambda x, b=bits: ops.quant_pack(x, b), x, iters=3)
        packed, s, z = ops.quant_pack(x, bits)
        ratio = x.size * 4 / (packed.size * 4 + s.size * 8)
        rows.append(f"kernel/quant_pack_int{bits},{us_ref:.1f},"
                    f"jnp-ref-us={us_ref:.1f} pallas-interpret-us="
                    f"{us_ker:.1f} wire_compression={ratio:.2f}x")

    # dequant_agg: K=10 clients, one adapter tensor
    kc, c, n, bits = 10, 32, 4096, 8
    xs = jax.random.normal(k, (kc, c, n))
    packs = [ref.quant_pack_ref(xs[i], bits) for i in range(kc)]
    packed = jnp.stack([p[0] for p in packs])
    sc = jnp.stack([p[1] for p in packs])
    zp = jnp.stack([p[2] for p in packs])
    w = jnp.ones(kc) / kc
    f_ref = jax.jit(lambda: ref.dequant_agg_ref(packed, sc, zp, w, bits))
    us_ref = time_us(f_ref, iters=10)
    us_ker = time_us(lambda: ops.dequant_agg(packed, sc, zp, w, bits),
                     iters=3)
    rows.append(f"kernel/dequant_agg_k{kc},{us_ref:.1f},"
                f"jnp-ref-us={us_ref:.1f} pallas-interpret-us={us_ker:.1f} "
                f"fp32-copies-avoided={kc}")

    # lora_matmul
    m, kd, n, r = 256, 512, 512, 32
    x = (jax.random.normal(k, (m, kd)) * 0.5).astype(jnp.bfloat16)
    wmat = (jax.random.normal(k, (kd, n)) * 0.1).astype(jnp.bfloat16)
    a = (jax.random.normal(k, (kd, r)) * 0.1).astype(jnp.bfloat16)
    b = (jax.random.normal(k, (r, n)) * 0.1).astype(jnp.bfloat16)
    f_ref = jax.jit(lambda: ref.lora_matmul_ref(x, wmat, a, b, 2.0))
    us_ref = time_us(f_ref, iters=10)
    us_ker = time_us(lambda: ops.lora_matmul(x, wmat, a, b, 2.0), iters=3)
    extra = 2 * m * r * (kd + n) / (2 * m * n * kd)
    rows.append(f"kernel/lora_matmul_r{r},{us_ref:.1f},"
                f"jnp-ref-us={us_ref:.1f} pallas-interpret-us={us_ker:.1f} "
                f"lora_flop_overhead={extra * 100:.1f}%")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
