"""FL round engine throughput: sequential per-client loop vs the vmapped
cohort engine, plus real bytes-on-wire per uplink message.

Two measurements per cohort size K (CPU-runnable; the deltas are the
point, absolute numbers scale with hardware):

  * clients/sec — K sequential ``make_local_trainer`` calls vs ONE
    ``make_cohort_trainer`` call over stacked (K, steps, B, ...) batches
    (steady-state, post-compile). On CPU the two are comparable (XLA CPU
    gains little from batching conv-heavy clients); the cohort engine's
    win is on accelerators, where one vectorized program replaces K
    sequential dispatches;
  * wire bytes — the MEASURED serialized size of one client's packed
    uplink message (``messages.packed_wire_bytes``, real buffers) for
    fp32 vs int8/4/2, cross-checked against the static accounting.

``--rank-profile r1,r2,...`` adds the RANK-BUCKETED engine sweep: the
cohort is split into rank tiers (round-robin), each bucket runs as one
jitted vmapped program over adapters truncated to its tier's rank, and
the sweep reports bucketed clients/sec vs everyone-at-max-rank plus the
measured per-tier wire bytes.

``--async`` runs the EVENT-DRIVEN FedBuff engine (fl/async_engine.py)
over a 2-tier fleet instead: steady-state arrivals/sec for
event-at-a-time vs micro-batched execution (shared compiled trainer, so
the delta is pure dispatch batching), compiled-program counts against
the #ranks x log2(micro-batch) bound, and the wall-clock-vs-bytes
trajectory (virtual seconds + measured TCC per flushed version).

``--sparse`` sweeps the SPARSE-DELTA wire (core/sparse.py): measured
uplink bytes for fp32 vs 2/4/8-bit dense vs 4-bit x density in
{0.25, 0.1, 0.05} (every row cross-checked against the static
accounting), plus steady-state aggregate timing of the scatter-add
sparse path vs the fused dense packed path over a K-client cohort.

``--flat`` sweeps the FLAT-TREE codec (core/flat.py) against the
per-leaf oracle: pack / serialize / aggregate wall time and compiled-
program counts at K in {4, 8, 16} — byte totals cross-checked identical
between the two codecs at every step.

``--agg-scale`` is the FLEET-SCALE aggregation sweep (BENCH_6.json):
serialize + per-leaf-vs-flat aggregate at K in {8, 16} (asserting the
K=16 speedup no longer decays below the K=8 figure and serialize stays
>= 1x), the K-tiled cohort reduction on a synthetic packed fleet at
K in {16, 64, 256, 1024, 10000} (single-device and sharded over the
8-fake-device ``clients`` mesh — forced via XLA_FLAGS before jax
initializes), and the streaming FedBuff per-arrival fold at
buffer_size in {10, 100, 1000} (asserting per-fold cost stays flat,
max/min <= 1.2, and steady-state folds compile 0 new programs).

``--fleet`` is the MILLION-CLIENT fleet-realism sweep (BENCH_9.json): a
lazy three-tier :class:`~repro.fl.population.Population` (diurnal
churning phones / laptops / workstations, per-cid shards generated on
demand behind a bounded LRU) drives the async FedBuff engine at its
millions-of-clients operating point — asserting peak resident
per-client state stays within the cache bound, reporting virtual time
and bytes to a target loss, realized churn rate and wasted bytes, then
re-running with DP-noised uplinks (clip + Gaussian before quantization)
and reporting the spent epsilon plus the quickstart-model accuracy
delta (asserted < 1%).

``--serve`` sweeps the MULTI-TENANT SERVING engine (src/repro/serve/,
BENCH_7.json): a 1024-adapter wire-format cache over 2 rank buckets
(4, 8), steady-state decode-step wall time for the fused
gather+dequant+matmul path vs the dequant-then-matmul baseline at
E=512 staged slots x M=64 rows (asserting fused >= baseline — the
baseline re-materializes the whole fp32 slab every step, the fused
path dequantizes only the M gathered adapters inside the matmul), a
0-new-programs steady-state check, and the continuous-batching
simulator's measured requests/sec + p50/p99 latency on both paths,
plus an eviction-churn run on a capacity-constrained cache.

``--json PATH`` additionally writes every sweep row as machine-readable
JSON ({"sweep", "args", "rows": [{"name", "time_us", ...metrics}]}), so
perf trajectories can be tracked across PRs (BENCH_5.json onward).

    PYTHONPATH=src python -m benchmarks.round_throughput \
        [--clients 8] [--samples 64] [--iters 3] [--json PATH] \
        [--rank-profile 4,8,16,32] | [--async [--arrivals 12]] | \
        [--sparse] | [--flat]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

# --agg-scale shards the cohort reduction over a multi-device client
# mesh; on a CPU host that means forced fake devices, and the flag only
# takes effect if set before jax initializes (first import locks the
# device count).
if "--agg-scale" in sys.argv and "xla_force_host_platform_device_count" \
        not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import flocora, lora, messages
from repro.core.flocora import FLoCoRAConfig, RankSchedule
from repro.core.lora import LoRAConfig
from repro.data import SyntheticVision, lda_partition
from repro.fl.client import ClientConfig, make_cohort_trainer, \
    make_local_trainer, stack_cohort_batches, stack_local_batches, \
    cohort_steps, pad_cohort_batches, pow2_pad
from repro.models.resnet import ResNetConfig, init as rinit, loss_fn

# compiled-program counter (the dispatch-count metric for --flat/--async):
# the process-wide jax.monitoring listener lives in repro.obs.compile now,
# shared with the tests' fixture and the engines' watchdogs
from repro.obs.compile import compile_count  # noqa: E402
from repro.obs.meta import run_meta  # noqa: E402


def row(name: str, time_us=None, **metrics) -> dict:
    """A bench row. ``time_us=None`` (counts, bytes, assert-style rows)
    OMITS the key entirely — downstream compare tooling must not mistake
    an untimed row for a 0us measurement."""
    r = {"name": name}
    if time_us is not None:
        r["time_us"] = round(float(time_us), 1)
    r.update(metrics)
    return r


def _fmt_val(v) -> str:
    if isinstance(v, bool):
        return str(v)
    if isinstance(v, float):
        return f"{v:.2f}"
    return str(v)


def format_row(r: dict) -> str:
    extras = " ".join(f"{k}={_fmt_val(v)}" for k, v in r.items()
                      if k not in ("name", "time_us"))
    t = f"{r['time_us']:.0f}" if "time_us" in r else "-"
    return f"{r['name']},{t},{extras}"


def _time(fn, iters: int) -> float:
    jax.block_until_ready(fn())          # compile + warmup
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def _setup_fl(n_clients: int, samples_per_client: int, rank: int):
    """Shared benchmark workload: LDA-partitioned synthetic vision data
    + frozen ResNet-8 with rank-``rank`` adapters (alpha = 16r)."""
    rng = np.random.default_rng(0)
    sv = SyntheticVision(seed=0)
    n = n_clients * samples_per_client
    y = rng.integers(0, 10, n)
    x = sv.sample(rng, y).astype(np.float32)
    parts = lda_partition(y, n_clients, alpha=0.5, seed=0)
    datas = [{"x": x[p], "y": y[p].astype(np.int32)} for p in parts]
    cfg = ResNetConfig(arch="resnet8",
                       lora=LoRAConfig(rank=rank, alpha=16.0 * rank))
    model = rinit(jax.random.PRNGKey(0), cfg)
    ccfg = ClientConfig(local_epochs=1, batch_size=16, lr=0.05)
    lfn = lambda f, t, b: loss_fn(f, t, cfg, b)
    return rng, datas, model, ccfg, lfn


def run(n_clients: int = 6, samples_per_client: int = 48,
        iters: int = 2) -> list[dict]:
    rows = []
    rng, datas, model, ccfg, lfn = _setup_fl(n_clients,
                                             samples_per_client, rank=8)

    # equalized schedules (all clients run the full `steps`, no masking)
    # so both engines do identical training work
    steps = cohort_steps(datas, ccfg)
    seq_batches = [jax.tree.map(jnp.asarray,
                                stack_local_batches(rng, d, ccfg,
                                                    steps=steps))
                   for d in datas]
    coh_stacked, _ = stack_cohort_batches(rng, datas, ccfg, steps=steps)
    coh_batches = jax.tree.map(jnp.asarray, coh_stacked)
    n_steps = jnp.full((n_clients,), steps, jnp.int32)

    seq = make_local_trainer(lfn, ccfg)
    coh = make_cohort_trainer(lfn, ccfg)
    frozen, train0 = model["frozen"], model["train"]

    def run_seq():
        outs = [seq(frozen, train0, b) for b in seq_batches]
        return outs[-1][0]

    def run_coh():
        return coh(frozen, train0, coh_batches, n_steps)[0]

    t_seq = _time(run_seq, iters)
    t_coh = _time(run_coh, iters)
    rows.append(row(f"round/seq_loop_k{n_clients}", t_seq * 1e6,
                    clients_per_sec=n_clients / t_seq))
    rows.append(row(f"round/vmap_cohort_k{n_clients}", t_coh * 1e6,
                    clients_per_sec=n_clients / t_coh,
                    speedup=t_seq / t_coh))

    # real bytes-on-wire per uplink message
    fp_bytes = messages.message_wire_bytes(
        train0, FLoCoRAConfig(rank=8, alpha=128.0).qcfg)
    rows.append(row("round/wire_fp32", bytes=fp_bytes))
    for bits in (8, 4, 2):
        fcfg = FLoCoRAConfig(rank=8, alpha=128.0, quant_bits=bits)
        msg, _ = flocora.client_uplink(train0, fcfg)
        measured = messages.packed_wire_bytes(msg)
        static = messages.message_wire_bytes(train0, fcfg.qcfg)
        assert measured == static, (measured, static)
        rows.append(row(f"round/wire_int{bits}", bytes=measured,
                        compression=fp_bytes / measured,
                        matches_static=measured == static))
    return rows


def run_rank_profile(profile: tuple[int, ...], n_clients: int = 6,
                     samples_per_client: int = 48,
                     iters: int = 2) -> list[dict]:
    """Rank-bucketed engine sweep: mixed-rank cohort clients/sec vs the
    everyone-at-max-rank baseline, plus measured per-tier wire bytes."""
    rows = []
    r_max = max(profile)
    rng, datas, model, ccfg, lfn = _setup_fl(n_clients,
                                             samples_per_client, r_max)
    coh = make_cohort_trainer(lfn, ccfg)
    frozen, train0 = model["frozen"], model["train"]
    sched = RankSchedule.tiered(profile, n_clients)
    steps = cohort_steps(datas, ccfg)

    # bucket the cohort by tier, pre-stage per-bucket batches + adapters
    buckets: dict[int, list[int]] = {}
    for cid, r in enumerate(sched.client_ranks):
        buckets.setdefault(r, []).append(cid)
    staged = []
    for r in sorted(buckets):
        cids = buckets[r]
        b, ns = stack_cohort_batches(rng, [datas[c] for c in cids], ccfg,
                                     steps=steps)
        b, ns = pad_cohort_batches(b, ns, pow2_pad(len(cids)))
        staged.append((jax.tree.map(jnp.asarray, b), jnp.asarray(ns),
                       lora.resize_tree_rank(train0, r)))
    base_b, base_ns = stack_cohort_batches(rng, datas, ccfg, steps=steps)
    base_b = jax.tree.map(jnp.asarray, base_b)
    base_ns = jnp.asarray(base_ns)

    def run_bucketed():
        outs = [coh(frozen, t0, b, ns) for b, ns, t0 in staged]
        return outs[-1][0]

    def run_uniform_max():
        return coh(frozen, train0, base_b, base_ns)[0]

    t_b = _time(run_bucketed, iters)
    t_u = _time(run_uniform_max, iters)
    tag = "x".join(str(r) for r in profile)
    rows.append(row(f"round/bucketed_r{tag}_k{n_clients}", t_b * 1e6,
                    clients_per_sec=n_clients / t_b,
                    buckets=len(buckets)))
    rows.append(row(f"round/uniform_r{r_max}_k{n_clients}", t_u * 1e6,
                    clients_per_sec=n_clients / t_u,
                    vs_bucketed=t_u / t_b))

    # measured wire bytes per tier (real packed buffers == static)
    fcfg = FLoCoRAConfig(rank=r_max, alpha=16.0 * r_max, quant_bits=8,
                         rank_schedule=sched)
    for r in sorted(buckets):
        msg = flocora.server_downlink(train0, fcfg, rank=r)
        measured = messages.packed_wire_bytes(msg)
        static = flocora.client_wire_bytes(train0, fcfg, r)
        assert measured == static, (measured, static)
        rows.append(row(f"round/wire_rank{r}", bytes=measured,
                        clients=len(buckets[r])))
    fleet = flocora.fleet_tcc_bytes(train0, fcfg, 1)
    rows.append(row("round/fleet_round_bytes", bytes=fleet))
    return rows


def run_async(n_clients: int = 8, samples_per_client: int = 48,
              arrivals: int = 12) -> list[dict]:
    """Async FedBuff engine throughput + wall-clock-vs-bytes trajectory
    on a 2-tier (r in {4, 8}) fleet."""
    from repro.fl import AsyncConfig, AsyncFLServer, FleetTrace, \
        LognormalLatency
    from repro.fl.client import make_staggered_cohort_trainer

    rows = []
    _, datas, model, ccfg, lfn = _setup_fl(n_clients, samples_per_client,
                                           rank=8)
    sched = RankSchedule.tiered((4, 8), n_clients)
    fcfg = FLoCoRAConfig(rank=8, alpha=128.0, quant_bits=8,
                         rank_schedule=sched)
    trace = FleetTrace(seed=0, latency=LognormalLatency(
        compute_median_s=30.0, network_mbps=20.0))
    # one shared compiled trainer: the eventwise/microbatch delta is
    # pure dispatch batching, and the timed pass is post-compile
    trainer = make_staggered_cohort_trainer(lfn, ccfg)

    def engine(window: float) -> AsyncFLServer:
        acfg = AsyncConfig(total_arrivals=arrivals, concurrency=4,
                           buffer_size=6, microbatch_window=window,
                           seed=0)
        return AsyncFLServer(model, lfn, datas, acfg, ccfg, fcfg,
                             trace=trace, trainer=trainer)

    engine(600.0).run()      # one warmup: compiles the program superset
    hist = None
    for name, window in (("eventwise", 0.0), ("microbatch", 600.0)):
        srv = engine(window)
        t0 = time.perf_counter()
        hist = srv.run()
        dt = time.perf_counter() - t0
        rows.append(row(f"round/async_{name}_n{arrivals}", dt * 1e6,
                        arrivals_per_sec=arrivals / dt,
                        programs=len(srv.program_keys),
                        versions=srv.version))
    # wall-clock-vs-bytes trajectory of the micro-batched run
    for h in hist:
        rows.append(row(f"round/async_v{h['version']}",
                        virtual_s=h["t_virtual"],
                        tcc_bytes=h["tcc_bytes"],
                        loss=h["client_loss"],
                        staleness_mean=h["staleness_mean"]))
    return rows


def run_sparse(n_clients: int = 6, samples_per_client: int = 48,
               iters: int = 2) -> list[dict]:
    """Sparse-delta wire sweep: measured bytes across bits x density +
    scatter-add vs fused-dense aggregate timing."""
    from repro.core.quant import QuantConfig
    from repro.core.aggregation import FedAvgAggregator
    from repro.core.sparse import SparsityConfig

    rows = []
    _, _, model, _, _ = _setup_fl(n_clients, samples_per_client, rank=8)
    train0 = model["train"]
    fp_bytes = messages.message_wire_bytes(train0, QuantConfig())
    rows.append(row("sparse/wire_fp32", bytes=fp_bytes))
    for bits in (8, 4, 2):
        dense = messages.message_wire_bytes(train0, QuantConfig(bits=bits))
        rows.append(row(f"sparse/wire_int{bits}_dense", bytes=dense,
                        compression=fp_bytes / dense))
    for density in (0.25, 0.1, 0.05):
        cfg = QuantConfig(bits=4)
        msg = messages.pack_message(train0, cfg, density=density)
        measured = messages.packed_wire_bytes(msg)
        static = messages.message_wire_bytes(train0, cfg, density)
        assert measured == static, (measured, static)
        rows.append(row(f"sparse/wire_int4_d{density}", bytes=measured,
                        compression=fp_bytes / measured,
                        matches_static=measured == static))

    # steady-state aggregation: K sparse scatter-add vs K fused dense
    qcfg = QuantConfig(bits=4)
    keys = jax.random.split(jax.random.PRNGKey(0), n_clients)
    trees = [jax.tree.map(
        lambda x, k=k: x + 0.01 * jax.random.normal(k, x.shape), train0)
        for k in keys]
    w = jnp.ones((n_clients,), jnp.float32)
    dense_msgs = [messages.pack_message(t, qcfg) for t in trees]
    sparse_msgs = [messages.pack_message(t, qcfg, density=0.1)
                   for t in trees]
    agg = FedAvgAggregator(qcfg)
    t_dense = _time(lambda: jax.tree.leaves(
        agg.aggregate(dense_msgs, w))[0], iters)
    t_sparse = _time(lambda: jax.tree.leaves(
        agg.aggregate(sparse_msgs, w))[0], iters)
    rows.append(row(f"sparse/agg_dense_k{n_clients}", t_dense * 1e6,
                    cohorts_per_sec=1 / t_dense))
    rows.append(row(f"sparse/agg_scatter_k{n_clients}", t_sparse * 1e6,
                    cohorts_per_sec=1 / t_sparse,
                    vs_dense=t_dense / t_sparse))

    # end-to-end round bytes of a sparse+EF config (accounting only)
    fcfg = FLoCoRAConfig(rank=8, alpha=128.0, quant_bits=4,
                         error_feedback=True,
                         sparsity=SparsityConfig(density=0.1))
    rb = flocora.round_wire_bytes(train0, fcfg)
    rows.append(row("sparse/round_bytes_ef_d0.1", down=rb["down_bytes"],
                    up=rb["up_bytes"], round=rb["round_bytes"]))
    return rows


def run_flat(n_clients: int = 6, samples_per_client: int = 48,
             iters: int = 3) -> list[dict]:
    """Flat-tree codec sweep: pack/serialize/aggregate wall time and
    compiled-program counts, per-leaf oracle vs flat, K in {4, 8, 16}.
    Byte totals are asserted identical between the codecs throughout."""
    from repro.core import aggregation
    from repro.core.quant import QuantConfig

    rows = []
    _, _, model, _, _ = _setup_fl(n_clients, samples_per_client, rank=8)
    train0 = model["train"]
    qcfg = QuantConfig(bits=4)
    k_max = 16
    keys = jax.random.split(jax.random.PRNGKey(1), k_max)
    trees = [jax.tree.map(
        lambda x, k=k: x + 0.01 * jax.random.normal(k, x.shape), train0)
        for k in keys]

    def _block(x):
        return jax.block_until_ready(jax.tree.leaves(
            x, is_leaf=messages.is_wire_leaf)[0])

    # cold pack: compiled programs per codec
    n0 = compile_count()
    msg_per = messages.pack_message(train0, qcfg)
    _block(msg_per)
    per_programs = compile_count() - n0
    n0 = compile_count()
    msg_flat = messages.pack_message(train0, qcfg, flat=True)
    _block(msg_flat)
    flat_programs = compile_count() - n0
    assert messages.packed_wire_bytes(msg_flat) == \
        messages.packed_wire_bytes(msg_per) == \
        messages.message_wire_bytes(train0, qcfg)

    # steady-state pack + serialize wall time
    t_pack_per = _time(
        lambda: _block(messages.pack_message(train0, qcfg)), iters)
    t_pack_flat = _time(
        lambda: _block(messages.pack_message(train0, qcfg, flat=True)),
        iters)
    rows.append(row("flat/pack_per_leaf", t_pack_per * 1e6,
                    programs=per_programs))
    rows.append(row("flat/pack_flat", t_pack_flat * 1e6,
                    programs=flat_programs,
                    speedup=t_pack_per / t_pack_flat))
    t_ser_per = _time(lambda: messages.message_to_wire(msg_per), iters)
    t_ser_flat = _time(lambda: messages.message_to_wire(msg_flat), iters)
    rows.append(row("flat/serialize_per_leaf", t_ser_per * 1e6,
                    bytes=messages.packed_wire_bytes(msg_per)))
    rows.append(row("flat/serialize_flat", t_ser_flat * 1e6,
                    bytes=messages.packed_wire_bytes(msg_flat),
                    speedup=t_ser_per / t_ser_flat))

    # aggregate across cohort sizes
    msgs_per = [messages.pack_message(t, qcfg) for t in trees]
    msgs_flat = [messages.pack_message(t, qcfg, flat=True)
                 for t in trees]
    for k in (4, 8, 16):
        w = jnp.ones((k,), jnp.float32)
        mp, mf = msgs_per[:k], msgs_flat[:k]
        n0 = compile_count()
        _block(aggregation.fedavg_packed(mp, w))
        agg_per_programs = compile_count() - n0
        n0 = compile_count()
        _block(aggregation.fedavg_packed(mf, w))
        agg_flat_programs = compile_count() - n0
        t_per = _time(
            lambda: _block(aggregation.fedavg_packed(mp, w)), iters)
        t_flat = _time(
            lambda: _block(aggregation.fedavg_packed(mf, w)), iters)
        rows.append(row(f"flat/agg_per_leaf_k{k}", t_per * 1e6,
                        programs=agg_per_programs,
                        cohorts_per_sec=1 / t_per))
        rows.append(row(f"flat/agg_flat_k{k}", t_flat * 1e6,
                        programs=agg_flat_programs,
                        cohorts_per_sec=1 / t_flat,
                        speedup=t_per / t_flat))
    return rows


def run_agg_scale(n_clients: int = 6, samples_per_client: int = 48,
                  iters: int = 3) -> list[dict]:
    """Fleet-scale aggregation sweep (BENCH_6.json).

    Three stages, each with its regression assert baked in:

      1. real-workload rows — serialize (flat >= per-leaf) and the
         per-leaf-vs-flat cohort aggregate at K in {8, 16}, asserting
         the K=16 flat speedup no longer decays below the K=8 figure;
      2. cohort reduction at K in {16, ..., 10000} on a synthetic
         packed fleet (16 real packed messages tiled to K): the
         K-tiled ``dequant_agg_rows`` single-device, plus the
         mesh-sharded reduction over the ``clients`` axis at the two
         largest K (numerics asserted against single-device);
      3. streaming FedBuff per-arrival folds at buffer_size in
         {10, 100, 1000}: per-fold wall time must stay flat
         (max/min <= 1.2 — O(1) folds don't grow with the buffer) and
         steady-state folds must compile 0 new programs.
    """
    from repro.core import aggregation
    from repro.core.quant import QuantConfig
    from repro.kernels import ops as kops
    from repro.launch.mesh import make_client_mesh

    rows = []
    _, _, model, _, _ = _setup_fl(n_clients, samples_per_client, rank=8)
    train0 = model["train"]
    qcfg = QuantConfig(bits=4)
    keys = jax.random.split(jax.random.PRNGKey(1), 16)
    trees = [jax.tree.map(
        lambda x, k=k: x + 0.01 * jax.random.normal(k, x.shape), train0)
        for k in keys]
    msgs_per = [messages.pack_message(t, qcfg) for t in trees]
    msgs_flat = [messages.pack_message(t, qcfg, flat=True)
                 for t in trees]

    def _block(x):
        return jax.block_until_ready(jax.tree.leaves(
            x, is_leaf=messages.is_wire_leaf)[0])

    # -- 1. real workload: serialize + per-leaf vs flat at K in {8, 16}
    t_ser_per = _time(lambda: messages.message_to_wire(msgs_per[0]),
                      iters)
    t_ser_flat = _time(lambda: messages.message_to_wire(msgs_flat[0]),
                       iters)
    ser_speedup = t_ser_per / t_ser_flat
    assert ser_speedup >= 1.0, \
        f"flat serialize regressed below per-leaf: {ser_speedup:.2f}x"
    rows.append(row("agg_scale/serialize_flat", t_ser_flat * 1e6,
                    per_leaf_us=round(t_ser_per * 1e6, 1),
                    speedup=ser_speedup))

    speedups = {}
    for k in (8, 16):
        w = jnp.ones((k,), jnp.float32)
        mp, mf = msgs_per[:k], msgs_flat[:k]
        t_per = _time(
            lambda: _block(aggregation.fedavg_packed(mp, w)), iters)
        t_flat = _time(
            lambda: _block(aggregation.fedavg_packed(mf, w)), iters)
        speedups[k] = t_per / t_flat
        rows.append(row(f"agg_scale/agg_per_leaf_k{k}", t_per * 1e6,
                        cohorts_per_sec=1 / t_per))
        rows.append(row(f"agg_scale/agg_flat_k{k}", t_flat * 1e6,
                        cohorts_per_sec=1 / t_flat,
                        speedup=speedups[k]))
    assert speedups[16] >= speedups[8], \
        f"flat aggregate speedup decays with K: {speedups}"

    # -- 2. cohort reduction to 10k clients (synthetic packed fleet) --
    # a compact adapter layout so the K=10000 stack stays in memory;
    # 16 real packed messages tile to each cohort size
    rng = np.random.default_rng(7)
    small = {"enc": {"a": rng.normal(size=(64, 8)).astype(np.float32),
                     "b": rng.normal(size=(8, 256)).astype(np.float32)},
             "bias": rng.normal(size=(64,)).astype(np.float32)}
    sm_msgs = [messages.pack_message(
        jax.tree.map(lambda x: x + 0.01 * i, small), qcfg, flat=True)
        for i in range(16)]
    lo = sm_msgs[0].layout
    nv = np.asarray(lo.n_valid_vec(), np.int32)
    P16 = np.stack([np.asarray(m.payload) for m in sm_msgs])
    S16 = np.stack([np.asarray(m.scale) for m in sm_msgs])
    Z16 = np.stack([np.asarray(m.zp) for m in sm_msgs])
    n_params = int(sum(s.rows * s.n_valid
                       for s in lo.leaves if s.quantized))
    mesh = make_client_mesh()
    n_dev = int(np.prod(mesh.devices.shape))
    for k in (16, 64, 256, 1024, 10000):
        reps = -(-k // 16)
        P = jnp.asarray(np.tile(P16, (reps, 1, 1))[:k])
        S = jnp.asarray(np.tile(S16, (reps, 1))[:k])
        Z = jnp.asarray(np.tile(Z16, (reps, 1))[:k])
        w = jnp.ones((k,), jnp.float32) / k
        t1 = _time(lambda: jax.block_until_ready(
            kops.dequant_agg_rows(P, S, Z, w, nv, lo.bits)), iters)
        rows.append(row(f"agg_scale/reduce_k{k}", t1 * 1e6,
                        params_per_sec=round(k * n_params / t1),
                        clients_per_sec=round(k / t1)))
        if k >= 1024 and n_dev > 1:
            ref_out = kops.dequant_agg_rows(P, S, Z, w, nv, lo.bits)
            sh_out = kops.dequant_agg_rows_sharded(P, S, Z, w, nv,
                                                   lo.bits, mesh)
            np.testing.assert_allclose(np.asarray(sh_out),
                                       np.asarray(ref_out),
                                       rtol=1e-5, atol=1e-6)
            t2 = _time(lambda: jax.block_until_ready(
                kops.dequant_agg_rows_sharded(P, S, Z, w, nv, lo.bits,
                                              mesh)), iters)
            rows.append(row(f"agg_scale/reduce_sharded_k{k}", t2 * 1e6,
                            devices=n_dev,
                            clients_per_sec=round(k / t2),
                            vs_single=t1 / t2))

    # -- 3. streaming FedBuff: per-arrival fold cost is O(1) ----------
    def fold_run(b: int) -> tuple[float, int]:
        agg = aggregation.FedBuffAggregator(streaming=True, r_target=8)
        # warm the fold program AND the fresh accumulator allocations
        # (first folds after a reset page-fault the fp32 sums into
        # existence) so the timed window is steady-state for every b
        for i in range(10):
            agg.add(msgs_flat[i], 1.0, 0.0)
        for st in agg.streams.values():
            jax.block_until_ready(st.acc)
        # chunks of 10 folds, keep the best sustained chunk: the O(1)
        # claim is that a fold late in a big buffer costs the same as
        # an early one, and the min filters 1-core timer jitter that
        # otherwise accumulates over a multi-second b=1000 run
        n0 = compile_count()
        best = float("inf")
        for c0 in range(0, b, 10):
            nf = min(10, b - c0)
            t0 = time.perf_counter()
            for i in range(c0, c0 + nf):
                agg.add(msgs_flat[i % len(msgs_flat)], 1.0,
                        float(i % 4))
            for st in agg.streams.values():  # folds dispatch async
                jax.block_until_ready(st.acc)
            best = min(best, (time.perf_counter() - t0) / nf)
        nc = compile_count() - n0
        _block(agg.flush())                  # untimed: flush is O(msg)
        return best, nc

    fold_run(4)                              # global jit warmup
    per_fold: dict[int, float] = {}
    compiles: dict[int, int] = {}
    for attempt in range(3):                 # re-measure on timer noise
        for b in (10, 100, 1000):
            # equalize chunk-sample counts: small buffers repeat so
            # every b gets ~the same number of quiet-window chances
            for _ in range(max(1, 200 // b)):
                t, nc = fold_run(b)
                per_fold[b] = min(per_fold.get(b, t), t)
                compiles[b] = nc
        if max(per_fold.values()) / min(per_fold.values()) <= 1.2:
            break
    flatness = max(per_fold.values()) / min(per_fold.values())
    assert flatness <= 1.2, \
        f"streaming fold cost grows with buffer_size: {per_fold}"
    for b in (10, 100, 1000):
        assert compiles[b] == 0, \
            f"steady-state folds compiled {compiles[b]} programs (b={b})"
        rows.append(row(f"agg_scale/fedbuff_fold_b{b}",
                        per_fold[b] * 1e6, programs=compiles[b],
                        folds_per_sec=round(1 / per_fold[b])))
    rows.append(row("agg_scale/fedbuff_fold_flatness",
                    flatness=flatness))
    return rows


def run_serve(iters: int = 3) -> list[dict]:
    """Multi-tenant serving sweep (BENCH_7.json): fused wire-format
    serving vs the dequant-then-matmul baseline over a 1024-adapter
    fleet, plus the continuous-batching simulator on both paths."""
    from repro import serve as S

    rows = []
    n_fleet, d = 1024, 256
    weights, store = S.make_store(n_clients=n_fleet, d_model=d,
                                  n_layers=2, ranks=(4, 8), bits=4,
                                  seed=0)
    total = sum(store.bytes_of(c) for c in store.cids)
    rows.append(row("serve/store", bytes=total, clients=n_fleet,
                    rank_buckets=2))

    # -- steady-state decode step: fused vs dequant-then-matmul -------
    # full fleet resident (wire-format at rest), E=512 slots/bucket
    cache = S.AdapterCache(capacity_bytes=2 * total, qcfg=store.qcfg)
    engines = {p: S.AdapterServingEngine(weights, 0.5, store.qcfg,
                                         cache, fetch=store.fetch,
                                         path=p, slab_slots=512)
               for p in ("fused", "dequant")}
    engines["fused"].admit(list(range(n_fleet)))
    rng = np.random.default_rng(0)
    m = 64
    cids = [int(c) for c in rng.integers(0, n_fleet, m)]
    x = jnp.asarray(rng.standard_normal((m, d)) * 0.5, jnp.float32)

    # numerics: fused vs the per-row merged dense oracle
    maxerr = float(jnp.max(jnp.abs(
        engines["fused"].step(x, cids)
        - engines["fused"].oracle_step(x, cids))))
    assert maxerr < 1e-4, f"fused path drifted from oracle: {maxerr}"
    rows.append(row("serve/oracle_check", maxerr=maxerr))

    ts = {}
    for p, eng in engines.items():
        jax.block_until_ready(eng.step(x, cids))     # warm
        ts[p] = _time(lambda: eng.step(x, cids), iters)
        rows.append(row(f"serve/step_{p}_e512_m{m}", ts[p] * 1e6,
                        rows_per_sec=round(m / ts[p])))
    speedup = ts["dequant"] / ts["fused"]
    assert speedup >= 1.0, \
        f"fused serving slower than dequant-then-matmul: {speedup:.2f}x"
    rows.append(row("serve/fused_vs_dequant", speedup=speedup))

    # -- steady state compiles nothing --------------------------------
    n0 = compile_count()
    for _ in range(5):
        jax.block_until_ready(engines["fused"].step(x, cids))
    n_programs = compile_count() - n0
    assert n_programs == 0, \
        f"steady-state decode compiled {n_programs} programs"
    rows.append(row("serve/steady_state_compiles", programs=n_programs))

    # -- continuous-batching simulator: measured requests/sec ---------
    wl = S.WorkloadConfig(n_requests=192, rate_rps=2000.0, gen_tokens=8,
                          max_batch=8, zipf_a=1.1, seed=0)
    sim = {}
    for p in ("fused", "dequant"):
        c = S.AdapterCache(capacity_bytes=2 * total, qcfg=store.qcfg)
        # slab floor >= the run's per-bucket working set: the serving
        # program shape is fixed from warmup on, so the measured run
        # has 0 slab-growth recompiles
        eng = S.AdapterServingEngine(weights, 0.5, store.qcfg, c,
                                     fetch=store.fetch, path=p,
                                     slab_slots=128)
        sim[p] = S.simulate(eng, store, wl)
        rows.append(row(f"serve/sim_{p}",
                        requests_per_sec=sim[p]["requests_per_s"],
                        tokens_per_sec=sim[p]["tokens_per_s"],
                        p50_ms=sim[p]["p50_ms"],
                        p99_ms=sim[p]["p99_ms"],
                        hit_rate=sim[p]["hit_rate"]))
    rows.append(row("serve/sim_fused_vs_dequant",
                    speedup=sim["dequant"]["p50_ms"]
                    / max(sim["fused"]["p50_ms"], 1e-9)))

    # -- eviction churn on a capacity-constrained cache ---------------
    c = S.AdapterCache(capacity_bytes=total // 16, qcfg=store.qcfg,
                       policy="clock")
    eng = S.AdapterServingEngine(weights, 0.5, store.qcfg, c,
                                 fetch=store.fetch)
    churn = S.simulate(eng, store, S.WorkloadConfig(
        n_requests=192, rate_rps=2000.0, gen_tokens=4, max_batch=8,
        zipf_a=1.0, seed=1))
    assert churn["evictions"] > 0
    rows.append(row("serve/sim_churn_cap1_16",
                    requests_per_sec=churn["requests_per_s"],
                    hit_rate=churn["hit_rate"],
                    evictions=churn["evictions"],
                    cache_entries=churn["cache_entries"]))
    return rows


def run_fleet(n_clients: int = 1_000_000, arrivals: int = 600,
              dp_rounds: int = 4) -> list[dict]:
    """A day in the life of a fleet (BENCH_9.json): FedBuff's
    millions-of-clients operating point on a lazy :class:`Population`.

    A 1M-device three-tier fleet (70% diurnal rank-4 phones that churn,
    25% rank-8 laptops, 5% always-on rank-16 workstations) feeds the
    event-driven async engine with buffers of K=10 — per-client shards
    generate on demand (``data.synthetic.linear_shard`` keyed
    ``(seed, cid)``) behind a bounded LRU, so peak resident per-client
    state is O(active clients), asserted here against the cache bound.
    Reports wall-clock arrival throughput, virtual time + total bytes to
    a target loss, the realized churn rate, and the wasted (churned)
    bytes. A second pass runs the same fleet with a DP-noised uplink
    (clip + Gaussian BEFORE quantization) and reports the spent epsilon;
    the quickstart-model accuracy delta at that operating point rides
    ``benchmarks.common.fl_experiment(dp=...)``.
    """
    from repro.core.lora import linear_apply, linear_init
    from repro.core.quant import DPConfig
    from repro.data.synthetic import linear_shard
    from repro.fl import AsyncConfig, AsyncFLServer, DeviceTier, \
        Population, PopulationTrace, time_to_target

    D, C, RANK = 16, 10, 16
    TARGET_LOSS = 1.0
    CACHE = 256

    def fleet_model():
        k = jax.random.PRNGKey(0)
        fz, tr = linear_init(k, D, C, "lora",
                             LoRAConfig(rank=RANK, alpha=float(RANK)),
                             base_dtype=jnp.float32)
        return {"frozen": {"lin": fz},
                "train": {"lin": tr, "bias": jnp.zeros((C,))}}

    def fleet_loss(frozen, train, batch):
        logits = linear_apply(frozen["lin"], train["lin"], batch["x"],
                              1.0, jnp.float32) + train["bias"]
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(
            logp, batch["y"][:, None], axis=1)), {}

    tiers = (DeviceTier("phone", rank=4, fraction=0.70, p_churn=0.08,
                        period_s=86400.0, duty=0.4),
             DeviceTier("laptop", rank=8, fraction=0.25, p_churn=0.03,
                        period_s=86400.0, duty=0.7),
             DeviceTier("workstation", rank=RANK, fraction=0.05))

    def build(dp=None):
        pop = Population(
            n_clients, tiers=tiers, seed=0, shard_size=24,
            shard_fn=lambda s, c: linear_shard(s, c, n=24, d=D),
            cache_clients=CACHE)
        acfg = AsyncConfig(total_arrivals=arrivals, concurrency=64,
                           buffer_size=10, streaming_agg=True,
                           microbatch_window=1200.0, seed=0)
        fcfg = FLoCoRAConfig(rank=RANK, alpha=float(RANK), quant_bits=8,
                             dp=dp)
        eng = AsyncFLServer(fleet_model(), fleet_loss, pop, acfg,
                            ClientConfig(local_epochs=2, batch_size=8,
                                         lr=0.1),
                            fcfg, trace=PopulationTrace(seed=0,
                                                        population=pop))
        return pop, eng

    rows = []
    pop, eng = build()
    print(f"# fleet: {n_clients} clients, {arrivals} arrivals ...",
          flush=True)
    t0 = time.perf_counter()
    hist = eng.run()
    dt = time.perf_counter() - t0
    print(f"# fleet: base pass done in {dt:.1f}s "
          f"(loss {hist[-1]['client_loss']:.3f})", flush=True)
    # the acceptance invariant: a 1M fleet never materializes more than
    # the LRU bound of per-client shards
    assert pop.peak_resident <= CACHE, \
        f"peak resident {pop.peak_resident} exceeds cache bound {CACHE}"
    last = hist[-1]
    rows.append(row(f"fleet/fedbuff_{n_clients}c", dt * 1e6,
                    arrivals=last["n_arrived"],
                    arrivals_per_sec=last["n_arrived"] / dt,
                    versions=eng.version,
                    n_churned=last["n_churned"],
                    churn_rate=last["n_churned"]
                    / max(eng.n_dispatched, 1),
                    peak_resident=pop.peak_resident,
                    cache_clients=CACHE,
                    virtual_s=last["t_virtual"],
                    tcc_bytes=last["tcc_bytes"],
                    wasted_bytes=last["wasted_bytes"],
                    final_loss=last["client_loss"]))
    tt = time_to_target(hist, "client_loss", TARGET_LOSS)
    assert tt is not None, \
        f"fleet run never reached loss {TARGET_LOSS}: " \
        f"{last['client_loss']}"
    rows.append(row("fleet/time_to_target",
                    target_loss=TARGET_LOSS,
                    virtual_s=tt["t_virtual"],
                    tcc_bytes=tt["tcc_bytes"],
                    version=tt["version"]))
    step = max(1, len(hist) // 8)
    for h in hist[::step]:
        rows.append(row(f"fleet/v{h['version']}",
                        virtual_s=h["t_virtual"],
                        tcc_bytes=h["tcc_bytes"],
                        loss=h["client_loss"],
                        staleness_mean=h["staleness_mean"]))

    # -- the same fleet with DP uplinks -------------------------------------
    dp = DPConfig(clip_norm=1.0, noise_multiplier=0.3)
    _, eng_dp = build(dp=dp)
    hist_dp = eng_dp.run()
    last_dp = hist_dp[-1]
    print(f"# fleet: DP pass done (eps {last_dp['dp_epsilon']:.2f})",
          flush=True)
    rows.append(row("fleet/fedbuff_dp",
                    noise_multiplier=dp.noise_multiplier,
                    clip_norm=dp.clip_norm,
                    dp_epsilon=last_dp["dp_epsilon"],
                    final_loss=last_dp["client_loss"],
                    loss_delta=last_dp["client_loss"]
                    - last["client_loss"]))

    # -- quickstart-model accuracy at the DP operating point ----------------
    # the quickstart ResNet stage is compile-dominated on small boxes:
    # a handful of rounds is enough to separate a harmful noise level
    # from a benign one, so dp_rounds stays small by default
    from benchmarks.common import fl_experiment
    print(f"# fleet: quickstart DP check ({dp_rounds} rounds x2, "
          "compile-heavy) ...", flush=True)
    base = fl_experiment(rounds=dp_rounds, n_clients=20,
                         clients_per_round=5, n_train=1000, rank=16,
                         quant_bits=8, eval_every=dp_rounds)
    print(f"# fleet: no-DP quickstart acc {base['final_acc']:.3f}",
          flush=True)
    priv = fl_experiment(rounds=dp_rounds, n_clients=20,
                         clients_per_round=5, n_train=1000, rank=16,
                         quant_bits=8, dp=dp, eval_every=dp_rounds)
    print(f"# fleet: DP quickstart acc {priv['final_acc']:.3f}",
          flush=True)
    delta = priv["final_acc"] - base["final_acc"]
    eps = [h["dp_epsilon"] for h in priv["history"]
           if "dp_epsilon" in h][-1]
    rows.append(row("fleet/quickstart_dp_acc",
                    acc_nodp=base["final_acc"],
                    acc_dp=priv["final_acc"],
                    acc_delta=delta,
                    dp_epsilon=eps))
    assert abs(delta) < 0.01, \
        f"DP accuracy delta {delta:+.4f} exceeds 1% at eps={eps:.1f}"
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=6)
    ap.add_argument("--samples", type=int, default=48)
    ap.add_argument("--iters", type=int, default=2)
    ap.add_argument("--rank-profile", type=str, default=None,
                    help="comma-separated rank tiers, e.g. 4,8,16,32: "
                         "sweep the rank-bucketed engine")
    ap.add_argument("--async", dest="async_", action="store_true",
                    help="event-driven FedBuff engine sweep")
    ap.add_argument("--sparse", action="store_true",
                    help="sparse-delta wire sweep (bytes + scatter-add)")
    ap.add_argument("--flat", action="store_true",
                    help="flat-tree codec sweep (pack/serialize/agg, "
                         "per-leaf vs fused flat)")
    ap.add_argument("--agg-scale", dest="agg_scale", action="store_true",
                    help="fleet-scale aggregation sweep: cohort "
                         "reduction to K=10000, sharded client mesh, "
                         "streaming FedBuff fold flatness (BENCH_6)")
    ap.add_argument("--serve", action="store_true",
                    help="multi-tenant serving sweep: fused wire-format "
                         "decode vs dequant-then-matmul over a "
                         "1024-adapter cache + request simulator "
                         "(BENCH_7)")
    ap.add_argument("--arrivals", type=int, default=12,
                    help="virtual arrivals for the --async sweep")
    ap.add_argument("--fleet", action="store_true",
                    help="million-client lazy-Population FedBuff sweep: "
                         "churn, deadline arrivals, DP uplinks, "
                         "time-to-target-loss (BENCH_9)")
    ap.add_argument("--fleet-clients", type=int, default=1_000_000,
                    help="fleet size for the --fleet sweep")
    ap.add_argument("--fleet-arrivals", type=int, default=600,
                    help="buffered arrivals for the --fleet sweep")
    ap.add_argument("--json", type=str, default=None, metavar="PATH",
                    help="also write the sweep rows as JSON to PATH")
    args = ap.parse_args()
    if args.clients < 1 or args.samples < 1 or args.iters < 1:
        ap.error("--clients/--samples/--iters must be >= 1")
    if args.arrivals < 1:
        ap.error("--arrivals must be >= 1")
    if args.fleet_clients < 1 or args.fleet_arrivals < 1:
        ap.error("--fleet-clients/--fleet-arrivals must be >= 1")
    if args.fleet:
        sweep = "fleet"
        rows = run_fleet(args.fleet_clients, args.fleet_arrivals)
    elif args.serve:
        sweep = "serve"
        rows = run_serve(args.iters)
    elif args.agg_scale:
        sweep = "agg_scale"
        rows = run_agg_scale(args.clients, args.samples, args.iters)
    elif args.flat:
        sweep = "flat"
        rows = run_flat(args.clients, args.samples, args.iters)
    elif args.sparse:
        sweep = "sparse"
        rows = run_sparse(args.clients, args.samples, args.iters)
    elif args.async_:
        sweep = "async"
        rows = run_async(args.clients, args.samples, args.arrivals)
    elif args.rank_profile:
        try:
            profile = tuple(int(t) for t in args.rank_profile.split(","))
        except ValueError:
            ap.error("--rank-profile must be comma-separated ints")
        if not profile or any(r < 1 for r in profile):
            ap.error("--rank-profile ranks must be >= 1")
        sweep = "rank_profile"
        rows = run_rank_profile(profile, args.clients, args.samples,
                                args.iters)
    else:
        sweep = "round"
        rows = run(args.clients, args.samples, args.iters)
    for r in rows:
        print(format_row(r))
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"sweep": sweep,
                       "args": {"clients": args.clients,
                                "samples": args.samples,
                                "iters": args.iters,
                                "arrivals": args.arrivals,
                                "fleet_clients": args.fleet_clients,
                                "fleet_arrivals": args.fleet_arrivals,
                                "rank_profile": args.rank_profile},
                       # backend/device/version provenance: the compare
                       # gate refuses cross-backend baselines on this
                       "meta": run_meta(),
                       "rows": rows}, f, indent=1)
        print(f"# wrote {len(rows)} rows to {args.json}")


if __name__ == "__main__":
    main()
