"""Unit + property tests for the affine quantization core."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:         # optional dep: property tests skip, rest runs
    given = settings = st = None

from repro.core import quant
from repro.core.quant import QuantConfig


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_roundtrip_error_bound(bits):
    """RTN error is bounded by scale/2 per channel."""
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 64)) * 3
    s, z = quant.affine_qparams(x, bits, channel_axis=0)
    q = quant.quantize(x, s, z, bits, channel_axis=0)
    xd = quant.dequantize(q, s, z, channel_axis=0)
    err = jnp.max(jnp.abs(x - xd), axis=1)
    assert bool(jnp.all(err <= s / 2 + 1e-6))


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_pack_unpack_exact(bits):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.integers(0, 1 << bits, size=937), jnp.uint8)
    p = quant.pack_levels(q, bits)
    assert p.size == -(-937 * bits // 8)
    u = quant.unpack_levels(p, bits, 937)
    np.testing.assert_array_equal(np.asarray(u), np.asarray(q))


def test_constant_channel_exact():
    """Degenerate channels (max == min) reconstruct exactly."""
    x = jnp.full((4, 32), 1.7)
    xd = quant.quant_dequant(x, QuantConfig(bits=4, channel_axis=0))
    # 0 must be representable; constant 1.7 quantizes to scale=1.7/qmax
    assert bool(jnp.all(jnp.abs(xd - x) <= 1.7 / 15 / 2 + 1e-6))


def test_zero_preserved():
    """Affine quantization represents 0 exactly (zero-point convention)."""
    x = jnp.asarray([[0.0, 1.0, 5.0, -3.0] * 8])
    xd = quant.quant_dequant(x, QuantConfig(bits=8, channel_axis=0))
    assert abs(float(xd[0, 0])) < 1e-6


if st is not None:
    @settings(max_examples=50, deadline=None)
    @given(
        bits=st.sampled_from([2, 4, 8]),
        rows=st.integers(1, 9),
        cols=st.integers(2, 65),
        scale=st.floats(1e-3, 1e3),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_property_quant_bound_and_monotonic(bits, rows, cols, scale,
                                                seed):
        """Property: (1) error bounded by scale/2; (2) dequant preserves
        channel-wise ordering up to one quantization step."""
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(rows, cols)) * scale, jnp.float32)
        s, z = quant.affine_qparams(x, bits, channel_axis=0)
        q = quant.quantize(x, s, z, bits, channel_axis=0)
        xd = quant.dequantize(q, s, z, channel_axis=0)
        err = np.asarray(jnp.abs(x - xd))
        bound = np.asarray(s)[:, None] / 2 + 1e-4 * scale
        assert (err <= bound).all()

    @settings(max_examples=30, deadline=None)
    @given(bits=st.sampled_from([2, 4, 8]), n=st.integers(1, 300),
           seed=st.integers(0, 2**31 - 1))
    def test_property_pack_roundtrip(bits, n, seed):
        rng = np.random.default_rng(seed)
        q = jnp.asarray(rng.integers(0, 1 << bits, size=n), jnp.uint8)
        u = quant.unpack_levels(quant.pack_levels(q, bits), bits, n)
        np.testing.assert_array_equal(np.asarray(u), np.asarray(q))


def test_symmetric_mode():
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 64))
    xd = quant.quant_dequant(x, QuantConfig(bits=8, channel_axis=0,
                                            symmetric=True))
    assert float(jnp.max(jnp.abs(x - xd))) < 0.1


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_symmetric_extremes_representable(bits):
    """REGRESSION (symmetric saturation): scale = 2*amax/qmax with
    zp=(qmax+1)//2 mapped +amax to level qmax+1 — the peak clipped and
    dequantized short by ~amax/qmax while -amax overshot. The fixed
    restricted-range grid represents BOTH extremes (and 0) exactly, so
    the symmetric path is no worse than the asymmetric path at the
    extremes."""
    amax = 1.0
    x = jnp.asarray([[-amax, -0.37, 0.0, 0.42, amax] * 8])
    cfg_s = QuantConfig(bits=bits, channel_axis=0, symmetric=True)
    cfg_a = QuantConfig(bits=bits, channel_axis=0, symmetric=False)
    dq_s = np.asarray(quant.quant_dequant(x, cfg_s))
    dq_a = np.asarray(quant.quant_dequant(x, cfg_a))
    # ±amax round-trip exactly (pre-fix: error ~ amax/qmax at both ends)
    assert abs(dq_s[0, 0] + amax) < 1e-6, dq_s[0, :5]
    assert abs(dq_s[0, 4] - amax) < 1e-6, dq_s[0, :5]
    # 0 stays exactly representable (integer zero-point)
    assert abs(dq_s[0, 2]) < 1e-6
    # at the extremes the symmetric path is now <= the asymmetric one
    ext = [0, 4]
    err_s = np.abs(dq_s[0, ext] - np.asarray(x)[0, ext]).max()
    err_a = np.abs(dq_a[0, ext] - np.asarray(x)[0, ext]).max()
    assert err_s <= err_a + 1e-6
    # no level ever lands outside the grid (the old peak clipped)
    s, z = quant.affine_qparams(x, bits, channel_axis=0, symmetric=True)
    q = np.asarray(jnp.round(x / s[:, None]) + z[:, None])
    assert q.min() >= 0 and q.max() <= cfg_s.qmax


if st is None:
    def test_property_quant_bound_and_monotonic():
        pytest.skip("hypothesis not installed")

    def test_property_pack_roundtrip():
        pytest.skip("hypothesis not installed")
