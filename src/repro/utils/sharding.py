"""Logical-axis sharding rules (MaxText-style, hand-rolled).

Every param leaf in the model zoo is annotated with a tuple of *logical*
axis names (one per dim, ``None`` for unsharded). A rules table maps
logical names to physical mesh axes. ``logical_to_spec`` resolves the
annotation into a ``PartitionSpec``, dropping any mapping whose mesh-axis
size does not divide the dim (best-effort sharding — indivisible dims
fall back to replication rather than erroring, which matters for LoRA
adapters whose rank dim is tiny).
"""
from __future__ import annotations

from typing import Any, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Default logical->physical rules for the (data, model) production mesh.
# 'fsdp' shards weights over the data axis (ZeRO-3 style); 'tensor' is TP.
DEFAULT_RULES: dict[str, Any] = {
    # activations
    "batch": ("pod", "data"),      # global batch over pod x data
    "seq": None,
    "act_embed": None,
    "act_heads": "model",
    "act_kv": None,
    # weights
    "embed": "model",              # d_model dim of weight matrices (TP)
    "vocab": "model",
    "mlp": "model",                # d_ff dim (TP)
    "heads": "model",              # attention head dim products
    "kv_heads": None,
    "qkv": "model",
    "expert": "model",             # MoE expert axis (EP)
    "fsdp": "data",                # the dim chosen for ZeRO-3 sharding
    "layers": None,                # scan axis, never sharded
    "lora_rank": None,             # rank r is tiny -> replicated
    "conv_in": None,
    "conv_out": "model",
    "kv_lora": None,
    "ssm_state": None,
    "ssm_inner": "model",
    "ssm_heads": "model",
    "kv_proj": None,            # kv heads are few; replicate projections
    "kv_seq": ("model", "data"),  # split-KV decode over chips
    "mlp_nosplit": None,        # per-expert ff dim (expert axis is EP)
    # fleet-scale cohort reduction: the flat wire buffer's K client dim
    # shards over the 1-D client mesh (launch.mesh.make_client_mesh /
    # kernels.ops.dequant_agg_rows_sharded)
    "clients": "clients",
}


def logical_to_spec(
    logical: Sequence[Optional[str]],
    shape: Sequence[int],
    mesh: Mesh,
    rules: Optional[dict] = None,
) -> P:
    """Resolve logical axis names into a PartitionSpec for `mesh`.

    Drops assignments where the mesh axis size does not divide the dim,
    and never assigns the same mesh axis twice (first dim wins).
    """
    rules = dict(DEFAULT_RULES if rules is None else rules)
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    used: set[str] = set()
    out = []
    for name, dim in zip(logical, shape):
        phys = rules.get(name) if name is not None else None
        if phys is None:
            out.append(None)
            continue
        cand = (phys,) if isinstance(phys, str) else tuple(phys)
        # keep only axes that exist in this mesh, are unused, and divide dim
        kept = []
        prod = 1
        for ax in cand:
            if ax not in axis_sizes or ax in used:
                continue
            if dim % (prod * axis_sizes[ax]) == 0:
                kept.append(ax)
                prod *= axis_sizes[ax]
        if not kept:
            out.append(None)
        elif len(kept) == 1:
            out.append(kept[0])
            used.add(kept[0])
        else:
            out.append(tuple(kept))
            used.update(kept)
    return P(*out)


def tree_shardings(
    logical_tree: Any,
    shape_tree: Any,
    mesh: Mesh,
    rules: Optional[dict] = None,
) -> Any:
    """Map a tree of logical annotations + a matching tree of shapes
    (ShapeDtypeStruct or arrays) to a tree of NamedShardings."""
    def _one(logical, arr):
        spec = logical_to_spec(logical, arr.shape, mesh, rules)
        return NamedSharding(mesh, spec)

    return jax.tree.map(
        _one, logical_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x
        ),
    )


def mesh_axis_size(mesh: Mesh, name: str) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get(name, 1)


def num_chips(mesh: Mesh) -> int:
    return int(np.prod(mesh.devices.shape))
