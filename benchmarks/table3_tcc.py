"""Paper Table III: total communication cost (TCC) of ResNet-8 for
FP/int8/int4/int2 over 100 rounds — byte-exact accounting."""
import jax

from repro.core import messages
from repro.core.lora import LoRAConfig
from repro.core.quant import QuantConfig
from repro.models.resnet import ResNetConfig, init as rinit

PAPER = {None: 205.47, 8: 55.56, 4: 30.15, 2: 17.44}


def run() -> list[str]:
    rows = []
    k = jax.random.PRNGKey(0)
    fedavg = rinit(k, ResNetConfig(arch="resnet8", mode="fedavg"))
    mb = messages.tcc_bytes(fedavg["train"], QuantConfig(), 100) / 1e6
    rows.append(f"table3/fedavg_fp,0,TCC={mb:.2f}MB (paper 982.07) "
                f"{'OK' if abs(mb - 982.07) < 0.02 else 'MISMATCH'}")
    flo = rinit(k, ResNetConfig(arch="resnet8",
                                lora=LoRAConfig(rank=32, alpha=512.0)))
    for bits, paper in PAPER.items():
        mb = messages.tcc_bytes(flo["train"], QuantConfig(bits=bits),
                                100) / 1e6
        tag = "fp" if bits is None else f"int{bits}"
        ok = abs(mb - paper) < 0.03
        rows.append(f"table3/flocora_{tag},0,TCC={mb:.2f}MB "
                    f"(paper {paper}) {'OK' if ok else 'MISMATCH'}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
