import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first
#   init. 512 host devices back the (2,16,16) multi-pod production mesh.
import argparse
import json
import sys

from repro.configs import registry


def main() -> int:
    p = argparse.ArgumentParser(
        description="AOT multi-pod dry-run: lower+compile every "
                    "(arch x shape x mesh) cell; record memory/cost/"
                    "collective analysis for the roofline.")
    p.add_argument("--arch", default=None, help="arch id (default: all)")
    p.add_argument("--shape", default=None,
                   choices=[None, *registry.SHAPES], help="shape cell")
    p.add_argument("--mesh", default="single",
                   choices=["single", "multi", "both"])
    p.add_argument("--tag", default="baseline")
    p.add_argument("--microbatch", type=int, default=None)
    p.add_argument("--seq-parallel", dest="sp", action="store_true",
                   default=None)
    p.add_argument("--no-seq-parallel", dest="sp", action="store_false")
    p.add_argument("--list", action="store_true", help="list cells")
    p.add_argument("--fl-round", action="store_true",
                   help="lower the multi-pod FL server round instead")
    p.add_argument("--bits", type=int, default=None)
    args = p.parse_args()

    if args.list:
        for c in registry.cells():
            print(c)
        return 0

    from repro.launch import dryrun_lib, steps as steps_lib

    if args.fl_round:
        failures = 0
        for bits in ([args.bits] if args.bits else [None, 8, 4, 2]):
            for arch in ([args.arch] if args.arch else ["minitron-4b"]):
                rec = dryrun_lib.run_fl_round(arch, bits=bits,
                                              tag=args.tag
                                              if args.tag != "baseline"
                                              else "fl_round")
                print(f"[fl_round b={bits}] {arch}: {rec['status']} "
                      + (f"coll={rec['collective_total']:.3e} "
                         f"u8_ag={rec['u8_allgather_ops']}"
                         if rec['status'] == 'ok'
                         else rec.get('error', '')[:200]), flush=True)
                failures += rec["status"] == "error"
        return 1 if failures else 0

    plan = None
    cells = [c for c in registry.cells()
             if (args.arch is None or c["arch"] == args.arch)
             and (args.shape is None or c["shape"] == args.shape)]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    failures = 0
    for c in cells:
        if args.microbatch is not None or args.sp is not None:
            base = steps_lib.plan_for(c["arch"], c["shape"])
            plan = steps_lib.CellPlan(
                microbatch=args.microbatch or base.microbatch,
                seq_parallel=base.seq_parallel if args.sp is None
                else args.sp)
        for mp in meshes:
            rec = dryrun_lib.run_cell(c["arch"], c["shape"], multi_pod=mp,
                                      plan=plan, tag=args.tag)
            status = rec["status"]
            extra = ""
            if status == "ok":
                m = rec["memory"]
                extra = (f" peak={m['peak_bytes']/2**30:.2f}GiB"
                         f" dominant={rec['roofline']['dominant']}"
                         f" compile={rec['compile_s']}s")
            elif status == "error":
                extra = " " + rec["error"][:200]
                failures += 1
            elif status == "skipped":
                extra = f" ({rec['skip_reason'][:60]})"
            print(f"[{rec['mesh']}] {c['arch']} x {c['shape']}: "
                  f"{status}{extra}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
