"""Pallas TPU kernels for FLoCoRA's compute hot-spots.

  quant_pack   — fused per-channel affine quantize + bit-pack (uplink)
  dequant_agg  — fused unpack + dequantize + weighted aggregate (server)
  lora_matmul  — fused y = x@W + (α/r)(x@a)@b (client forward)

Each has a pure-jnp oracle in ref.py; tests sweep shapes/dtypes/bits in
interpret mode (this container is CPU-only; TPU is the target).
"""
from repro.kernels.ops import quant_pack, quant_pack_rows, dequant_agg, \
    dequant_agg_rows, lora_matmul, multi_lora_matmul, \
    multi_lora_matmul_packed, to_channel_first_2d, from_channel_first_2d
from repro.kernels import ref
