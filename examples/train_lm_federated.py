"""End-to-end driver: federated FROM-SCRATCH training of a ~100M-param
decoder LM with FLoCoRA — frozen random base, LoRA adapters + norms
trained, int8 adapter exchange between 8 clients.

Default runs a reduced config for CI speed; ``--full`` uses the ~110M
config (12L x 768, 32k vocab) for a few hundred steps as in the
deliverable.

    PYTHONPATH=src python examples/train_lm_federated.py \
        [--rounds 4] [--local-steps 8] [--full]
"""
import argparse
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

from repro.core import aggregation, messages
from repro.core.flocora import FLoCoRAConfig
from repro.core.lora import LoRAConfig
from repro.core.quant import QuantConfig
from repro.data.synthetic import markov_lm_batch
from repro.models import lm as LM
from repro.optim import sgd
from repro.utils.tree import tree_size


def make_cfg(full: bool) -> LM.LMConfig:
    if full:   # ~110M params
        return LM.LMConfig(name="lm-110m", n_layers=12, d_model=768,
                           n_heads=12, n_kv_heads=4, head_dim=64,
                           d_ff=3072, vocab=32768,
                           lora=LoRAConfig(rank=16, alpha=256.0),
                           head_mode="lora")
    return LM.LMConfig(name="lm-tiny", n_layers=4, d_model=128, n_heads=4,
                       n_kv_heads=2, head_dim=32, d_ff=512, vocab=512,
                       lora=LoRAConfig(rank=8, alpha=128.0),
                       head_mode="lora")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=8)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    cfg = make_cfg(args.full)
    fcfg = FLoCoRAConfig(rank=cfg.lora.rank, alpha=cfg.lora.alpha,
                         quant_bits=8)
    params = LM.init(jax.random.PRNGKey(0), cfg)
    frozen, gtrain = params["frozen"], params["train"]
    n_total = tree_size(frozen) + tree_size(gtrain)
    n_train = tree_size(gtrain)
    msg = messages.message_wire_bytes(gtrain, fcfg.qcfg)
    full_msg = (n_total) * 4
    print(f"params: total={n_total/1e6:.1f}M trainable={n_train/1e6:.2f}M "
          f"({100*n_train/n_total:.1f}%)")
    print(f"round message: {msg/1e6:.2f} MB vs full-model "
          f"{full_msg/1e6:.1f} MB -> {full_msg/msg:.1f}x reduction")

    opt = sgd(momentum=0.9)

    @jax.jit
    def local_train(train0, tokens):
        state = opt.init(train0)

        def step(carry, batch):
            tr, st = carry
            loss, g = jax.value_and_grad(
                lambda t: LM.loss_fn(frozen, t, cfg, {"tokens": batch})[0]
            )(tr)
            tr, st = opt.update(g, st, tr, 0.05)
            return (tr, st), loss

        (tr, _), losses = jax.lax.scan(step, (train0, state), tokens)
        return tr, losses.mean()

    rng = np.random.default_rng(0)
    for rnd in range(args.rounds):
        g_bcast = messages.roundtrip(gtrain, fcfg.qcfg)   # server -> client
        client_trees, losses, sizes = [], [], []
        for c in range(args.clients):
            toks = np.stack([
                markov_lm_batch(rng, cfg.vocab, args.batch, args.seq,
                                seed=c)["tokens"]
                for _ in range(args.local_steps)])
            trained, loss = local_train(g_bcast, jnp.asarray(toks))
            client_trees.append(messages.roundtrip(trained, fcfg.qcfg))
            losses.append(float(loss))
            sizes.append(args.local_steps * args.batch * args.seq)
        stacked = aggregation.stack_trees(client_trees)
        gtrain = aggregation.fedavg(stacked, jnp.asarray(sizes, jnp.float32))
        print(f"round {rnd + 1}: mean client loss = {np.mean(losses):.4f} "
              f"(cumulative TCC {2 * (rnd + 1) * msg / 1e6:.2f} MB/client)")


if __name__ == "__main__":
    main()
