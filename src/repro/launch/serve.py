"""Serving driver: prefill + batched autoregressive decode with the
FLoCoRA adapters merged into the frozen base (zero added latency — the
LoRA property the paper inherits, §II-C). The token loop itself is the
shared ``serve.generate()``.

    PYTHONPATH=src python -m repro.launch.serve \
        --arch gemma3-4b --smoke --prompt-len 16 --gen 16
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.models import lm as LM
from repro.serve import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    entry = registry.get(args.arch)
    if entry.kind != "lm":
        raise SystemExit("serve.py drives decoder LMs; use examples/ for "
                         "the enc-dec path")
    cfg = entry.smoke() if args.smoke else entry.full()
    params = LM.init(jax.random.PRNGKey(0), cfg)
    frozen, train = params["frozen"], params["train"]

    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab,
                                      (args.batch, args.prompt_len)),
                         jnp.int32)

    toks, timing = generate(frozen, train, cfg, prompt, args.gen,
                            temperature=args.temperature, seed=0)
    print(f"prefill({args.prompt_len} tokens): "
          f"{timing['prefill_s']:.2f}s")
    dt = timing["decode_s"]
    print(f"decode: {timing['decode_steps']} steps in {dt:.2f}s "
          f"({timing['decode_steps'] * args.batch / max(dt, 1e-9):.1f} "
          f"tok/s)")
    for b in range(args.batch):
        print(f"  seq{b}: {list(np.asarray(toks[b]))}")


if __name__ == "__main__":
    main()
