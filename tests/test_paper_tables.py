"""Byte-exact reproduction of the paper's count/size tables.

Table I  — ResNet-8 trained/total params for r in {8,16,32,64,128}
Table III — ResNet-8 TCC for FP / int8 / int4 / int2 (R=100)
Table IV — ResNet-18 message sizes (r in {16,32,64}, FP & Q8) and
           FedAvg baseline 44.7 MB / 62.6 GB (R=700)
"""
import jax
import jax.numpy as jnp
import pytest

from repro.core import messages
from repro.core.lora import LoRAConfig
from repro.core.quant import QuantConfig
from repro.models.resnet import ResNetConfig, init as rinit
from repro.utils.tree import tree_size

K = jax.random.PRNGKey(0)


# ---- Table I -------------------------------------------------------------

@pytest.mark.parametrize("rank,trained,total", [
    (8, 69_450, 1_290_058),
    (16, 131_914, 1_352_522),
    (32, 256_842, 1_477_450),
    (64, 506_698, 1_727_306),
    (128, 1_006_410, 2_227_018),
])
def test_table1_param_counts(rank, trained, total):
    cfg = ResNetConfig(arch="resnet8",
                       lora=LoRAConfig(rank=rank, alpha=16.0 * rank))
    p = rinit(K, cfg)
    assert tree_size(p["train"]) == trained
    assert tree_size(p["train"]) + tree_size(p["frozen"]) == total


def test_fedavg_resnet8_params():
    p = rinit(K, ResNetConfig(arch="resnet8", mode="fedavg"))
    assert tree_size(p["train"]) == 1_227_594          # paper: 1.23M


# ---- Table III (TCC, MB = 1e6 bytes, R = 100) ------------------------------

def _tcc_mb(train_tree, bits, rounds=100):
    b = messages.tcc_bytes(train_tree, QuantConfig(bits=bits), rounds)
    return b / 1e6


def test_table3_tcc():
    fedavg = rinit(K, ResNetConfig(arch="resnet8", mode="fedavg"))
    assert abs(_tcc_mb(fedavg["train"], None) - 982.07) < 0.02

    flo = rinit(K, ResNetConfig(arch="resnet8",
                                lora=LoRAConfig(rank=32, alpha=512.0)))
    assert abs(_tcc_mb(flo["train"], None) - 205.47) < 0.02
    assert abs(_tcc_mb(flo["train"], 8) - 55.56) < 0.02
    assert abs(_tcc_mb(flo["train"], 4) - 30.15) < 0.03
    assert abs(_tcc_mb(flo["train"], 2) - 17.44) < 0.03


# ---- Table IV (ResNet-18, message sizes in MB, R = 700) --------------------

def test_table4_fedavg_baseline():
    p = rinit(K, ResNetConfig(arch="resnet18", mode="fedavg"))
    assert tree_size(p["train"]) == 11_173_962
    msg_mb = messages.message_wire_bytes(p["train"], QuantConfig()) / 1e6
    assert abs(msg_mb - 44.7) < 0.05                    # paper: 44.7 MB
    tcc_gb = messages.tcc_bytes(p["train"], QuantConfig(), 700) / 1e9
    assert abs(tcc_gb - 62.6) < 0.1                     # paper: 62.6 GB


@pytest.mark.parametrize("rank,fp_mb,q8_mb", [
    (64, 9.2, 2.4), (32, 4.6, 1.2), (16, 2.4, 0.7),
])
def test_table4_flocora_rows(rank, fp_mb, q8_mb):
    p = rinit(K, ResNetConfig(arch="resnet18",
                              lora=LoRAConfig(rank=rank, alpha=16.0 * rank)))
    fp = messages.message_wire_bytes(p["train"], QuantConfig()) / 1e6
    q8 = messages.message_wire_bytes(p["train"], QuantConfig(bits=8)) / 1e6
    assert abs(fp - fp_mb) < 0.06, fp
    assert abs(q8 - q8_mb) < 0.06, q8


def test_table2_vanilla_counts():
    """Table II: FLoCoRA Vanilla (stem+FC adapted, norms frozen) ~0.26M."""
    cfg = ResNetConfig(arch="resnet8", stem_mode="lora", fc_mode="lora",
                       norms_trained=False,
                       lora=LoRAConfig(rank=32, alpha=512.0))
    p = rinit(K, cfg)
    n = tree_size(p["train"])
    assert n == 261_280                                  # 0.26 M
