"""LDA (Dirichlet) client partitioning: determinism, exact coverage,
and bounded termination of the min_size retry loop."""
import numpy as np
import pytest

from repro.data import lda_partition


def _labels(n=600, n_classes=10, seed=0):
    return np.random.default_rng(seed).integers(0, n_classes, n)


def test_lda_seeded_determinism():
    y = _labels()
    a = lda_partition(y, 8, alpha=0.5, seed=7)
    b = lda_partition(y, 8, alpha=0.5, seed=7)
    assert len(a) == len(b) == 8
    for pa, pb in zip(a, b):
        np.testing.assert_array_equal(pa, pb)
    # a different seed gives a different split
    c = lda_partition(y, 8, alpha=0.5, seed=8)
    assert any(len(pa) != len(pc) or not np.array_equal(pa, pc)
               for pa, pc in zip(a, c))


@pytest.mark.parametrize("alpha", [0.1, 0.5, 10.0])
def test_lda_covers_every_index_exactly_once(alpha):
    y = _labels()
    parts = lda_partition(y, 12, alpha=alpha, seed=3)
    allidx = np.concatenate(parts)
    assert len(allidx) == len(y)
    np.testing.assert_array_equal(np.sort(allidx), np.arange(len(y)))


def test_lda_min_size_respected():
    y = _labels()
    parts = lda_partition(y, 10, alpha=0.5, seed=0, min_size=4)
    assert min(len(p) for p in parts) >= 4


def test_lda_adversarial_alpha_terminates():
    """Tiny alpha concentrates classes on single clients; the bounded
    retry loop must still return a full partition meeting the floor."""
    y = _labels(n=120, n_classes=3, seed=1)
    parts = lda_partition(y, 20, alpha=1e-4, seed=0, min_size=2,
                          max_retries=25)
    assert len(parts) == 20
    assert min(len(p) for p in parts) >= 2
    allidx = np.concatenate(parts)
    np.testing.assert_array_equal(np.sort(allidx), np.arange(len(y)))


def test_lda_infeasible_min_size_raises():
    y = _labels(n=30)
    with pytest.raises(ValueError):
        lda_partition(y, 20, alpha=0.5, min_size=2)
