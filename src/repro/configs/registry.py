"""Architecture registry: --arch <id> resolution, shape cells, and
ShapeDtypeStruct input specs for the dry-run (no allocation).

40 cells = 10 archs x 4 shapes. `long_500k` requires sub-quadratic
attention state: runnable for gemma3 (5:1 local:global), mamba2 (SSM),
zamba2 (hybrid); skipped for the 7 pure full-attention archs
(DESIGN.md §6) — skips are recorded, not silently dropped.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs import (deepseek_v2_236b, gemma3_4b, llama4_maverick_400b,
                           mamba2_370m, minitron_4b, nemotron_4_340b,
                           paligemma_3b, qwen15_110b, seamless_m4t_medium,
                           zamba2_2p7b)
from repro.models import encdec as ED
from repro.models import lm as LM

SHAPES: dict[str, dict] = {
    "train_4k": {"seq": 4096, "batch": 256, "step": "train"},
    "prefill_32k": {"seq": 32768, "batch": 32, "step": "prefill"},
    "decode_32k": {"seq": 32768, "batch": 128, "step": "decode"},
    "long_500k": {"seq": 524288, "batch": 1, "step": "decode"},
}


@dataclasses.dataclass(frozen=True)
class ArchEntry:
    arch_id: str
    kind: str                      # 'lm' | 'encdec'
    full: Callable[[], Any]
    smoke: Callable[[], Any]
    long_500k_ok: bool
    skip_reason: str = ""


REGISTRY: dict[str, ArchEntry] = {
    "minitron-4b": ArchEntry(
        "minitron-4b", "lm", minitron_4b.full, minitron_4b.smoke, False,
        "pure full attention — 500k decode cache is quadratic-history"),
    "qwen1.5-110b": ArchEntry(
        "qwen1.5-110b", "lm", qwen15_110b.full, qwen15_110b.smoke, False,
        "pure full attention"),
    "nemotron-4-340b": ArchEntry(
        "nemotron-4-340b", "lm", nemotron_4_340b.full,
        nemotron_4_340b.smoke, False, "pure full attention"),
    "gemma3-4b": ArchEntry(
        "gemma3-4b", "lm", gemma3_4b.full, gemma3_4b.smoke, True),
    "seamless-m4t-medium": ArchEntry(
        "seamless-m4t-medium", "encdec", seamless_m4t_medium.full,
        seamless_m4t_medium.smoke, False, "enc-dec full attention"),
    "paligemma-3b": ArchEntry(
        "paligemma-3b", "lm", paligemma_3b.full, paligemma_3b.smoke, False,
        "pure full attention"),
    "llama4-maverick-400b-a17b": ArchEntry(
        "llama4-maverick-400b-a17b", "lm", llama4_maverick_400b.full,
        llama4_maverick_400b.smoke, False,
        "full attention per assigned config"),
    "deepseek-v2-236b": ArchEntry(
        "deepseek-v2-236b", "lm", deepseek_v2_236b.full,
        deepseek_v2_236b.smoke, False,
        "MLA compresses KV width, not length — full-length per layer"),
    "mamba2-370m": ArchEntry(
        "mamba2-370m", "lm", mamba2_370m.full, mamba2_370m.smoke, True),
    "zamba2-2.7b": ArchEntry(
        "zamba2-2.7b", "lm", zamba2_2p7b.full, zamba2_2p7b.smoke, True),
}


def get(arch_id: str) -> ArchEntry:
    if arch_id not in REGISTRY:
        raise KeyError(f"unknown arch '{arch_id}'; known: "
                       f"{sorted(REGISTRY)}")
    return REGISTRY[arch_id]


def cells() -> list[dict]:
    """All 40 (arch x shape) cells with runnable/skip annotations."""
    out = []
    for aid, e in REGISTRY.items():
        for shape, info in SHAPES.items():
            skip = (shape == "long_500k" and not e.long_500k_ok)
            out.append({"arch": aid, "shape": shape, "step": info["step"],
                        "skip": skip,
                        "skip_reason": e.skip_reason if skip else ""})
    return out


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no device allocation)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(entry: ArchEntry, cfg: Any, shape_name: str) -> dict:
    """Returns {'batch': ..., 'caches': ...?} spec trees for the step."""
    info = SHAPES[shape_name]
    b, s = info["batch"], info["seq"]
    step = info["step"]
    if entry.kind == "encdec":
        if step == "train":
            return {"batch": {
                "src_embed": _sds((b, s, cfg.d_model), jnp.bfloat16),
                "tgt_tokens": _sds((b, s + 1), jnp.int32)}}
        if step == "prefill":
            return {"batch": {
                "src_embed": _sds((b, s, cfg.d_model), jnp.bfloat16)}}
        # decode: self cache of s, cross cache over s source frames
        self_c = jax.eval_shape(lambda: ED.self_cache_init(cfg, b, s))
        cross_c = {
            "k": _sds((cfg.n_dec_layers, b, s, cfg.n_kv_heads,
                       cfg.head_dim), jnp.bfloat16),
            "v": _sds((cfg.n_dec_layers, b, s, cfg.n_kv_heads,
                       cfg.head_dim), jnp.bfloat16)}
        return {"batch": {"token": _sds((b, 1), jnp.int32)},
                "self_caches": self_c, "cross_caches": cross_c}

    # decoder LM
    prefix = cfg.prefix_len if cfg.prefix_lm else 0
    if step == "train":
        out = {"batch": {"tokens": _sds((b, s - prefix + 1), jnp.int32)}}
        if prefix:
            out["batch"]["prefix_embed"] = _sds((b, prefix, cfg.d_model),
                                                jnp.bfloat16)
        return out
    if step == "prefill":
        out = {"batch": {"tokens": _sds((b, s - prefix), jnp.int32)}}
        if prefix:
            out["batch"]["prefix_embed"] = _sds((b, prefix, cfg.d_model),
                                                jnp.bfloat16)
        return out
    caches = jax.eval_shape(lambda: LM.cache_init(cfg, b, s))
    return {"batch": {"token": _sds((b, 1), jnp.int32)}, "caches": caches}
