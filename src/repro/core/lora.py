"""LoRA adapters for dense and convolution layers (FLoCoRA core).

Dense (Hu et al. '21): frozen ``W ∈ R^{d_in×d_out}``; trainable
``a ∈ R^{d_in×r}`` (Gaussian init) and ``b ∈ R^{r×d_out}`` (zeros init);
``y = x@W + (α/r)·(x@a)@b``. The output-side factor is zero-initialized so
the adapted model starts exactly equal to the frozen base.

Conv (Huh et al. TMLR'22, the decomposition the paper adopts): frozen
``P ∈ R^{O×I×K×K}``; adapter = conv with ``B ∈ R^{r×I×K×K}`` (Gaussian)
followed by 1×1 conv ``A ∈ R^{O×r×1×1}`` (zeros), same stride/padding on B,
stride 1 on A. We store conv kernels in HWIO layout for lax.conv.

``mode`` per layer: 'lora' (frozen base + adapter), 'dense' (fully
trained — the paper's norm/final-FC/stem rule), 'frozen' (shared once,
never updated — e.g. token embeddings at LM scale).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class LoRAConfig:
    rank: int = 32
    alpha: float = 512.0          # paper: alpha = 16*r for from-scratch
    dtype: jnp.dtype = jnp.float32

    @property
    def scale(self) -> float:
        return self.alpha / self.rank


# ---------------------------------------------------------------------------
# Dense
# ---------------------------------------------------------------------------

def dense_lora_init(key: Array, d_in: int, d_out: int, cfg: LoRAConfig,
                    stack: tuple[int, ...] = ()) -> dict:
    """Adapter params for a (stack of) dense layer(s).

    a: (*stack, d_in, r) ~ N(0, 1/d_in); b: (*stack, r, d_out) = 0.
    """
    a = jax.random.normal(key, (*stack, d_in, cfg.rank), cfg.dtype)
    a = a * (1.0 / jnp.sqrt(d_in)).astype(cfg.dtype)
    b = jnp.zeros((*stack, cfg.rank, d_out), cfg.dtype)
    return {"a": a, "b": b}


def dense_lora_apply(x: Array, a: Array, b: Array, scale: float,
                     compute_dtype=jnp.bfloat16) -> Array:
    """(α/r)·(x@a)@b — the low-rank side chain only."""
    h = jnp.einsum("...i,ir->...r", x.astype(compute_dtype),
                   a.astype(compute_dtype))
    y = jnp.einsum("...r,ro->...o", h, b.astype(compute_dtype))
    return (scale * y.astype(jnp.float32)).astype(x.dtype)


def dense_merge(w: Array, a: Array, b: Array, scale: float) -> Array:
    """W + (α/r)·a@b — serving-time merge (no added latency, paper §II-C)."""
    return (w.astype(jnp.float32)
            + scale * a.astype(jnp.float32) @ b.astype(jnp.float32)
            ).astype(w.dtype)


# ---------------------------------------------------------------------------
# Conv (HWIO kernels; NHWC activations)
# ---------------------------------------------------------------------------

def conv_lora_init(key: Array, kh: int, kw: int, c_in: int, c_out: int,
                   cfg: LoRAConfig) -> dict:
    """b_k: (kh, kw, c_in, r) Gaussian; a_k: (1, 1, r, c_out) zeros."""
    fan_in = kh * kw * c_in
    b_k = jax.random.normal(key, (kh, kw, c_in, cfg.rank), cfg.dtype)
    b_k = b_k * (jnp.sqrt(2.0 / fan_in)).astype(cfg.dtype)
    a_k = jnp.zeros((1, 1, cfg.rank, c_out), cfg.dtype)
    return {"b": b_k, "a": a_k}


def conv_lora_apply(x: Array, b_k: Array, a_k: Array, scale: float,
                    stride: tuple[int, int], padding) -> Array:
    """(α/r) · conv1x1(conv(x, B), A), stride/padding on the B conv."""
    dn = jax.lax.conv_dimension_numbers(x.shape, b_k.shape,
                                        ("NHWC", "HWIO", "NHWC"))
    h = jax.lax.conv_general_dilated(x, b_k.astype(x.dtype), stride, padding,
                                     dimension_numbers=dn)
    dn2 = jax.lax.conv_dimension_numbers(h.shape, a_k.shape,
                                         ("NHWC", "HWIO", "NHWC"))
    y = jax.lax.conv_general_dilated(h, a_k.astype(x.dtype), (1, 1), "VALID",
                                     dimension_numbers=dn2)
    return scale * y


def conv_merge(p: Array, b_k: Array, a_k: Array, scale: float) -> Array:
    """Fold the adapter back into the base kernel:
    P[h,w,i,o] + (α/r) · Σ_r B[h,w,i,r]·A[0,0,r,o]."""
    delta = jnp.einsum("hwir,ro->hwio", b_k.astype(jnp.float32),
                       a_k[0, 0].astype(jnp.float32))
    return (p.astype(jnp.float32) + scale * delta).astype(p.dtype)


# ---------------------------------------------------------------------------
# Mixed-mode linear helper used by the model zoo
# ---------------------------------------------------------------------------

def linear_init(key: Array, d_in: int, d_out: int, mode: str,
                cfg: Optional[LoRAConfig] = None,
                stack: tuple[int, ...] = (),
                base_dtype=jnp.bfloat16,
                w_init_scale: Optional[float] = None,
                ) -> tuple[dict, dict]:
    """Returns (frozen, trainable) param dicts for one (stacked) linear.

    mode='lora'  -> frozen {'w'}, trainable {'a','b'}
    mode='dense' -> frozen {},    trainable {'w'}
    mode='frozen'-> frozen {'w'}, trainable {}
    """
    kw, ka = jax.random.split(key)
    std = w_init_scale if w_init_scale is not None else (1.0 / (d_in ** 0.5))
    w = (jax.random.normal(kw, (*stack, d_in, d_out), jnp.float32)
         * std).astype(base_dtype)
    if mode == "lora":
        assert cfg is not None
        return {"w": w}, dense_lora_init(ka, d_in, d_out, cfg, stack)
    if mode == "dense":
        return {}, {"w": w.astype(jnp.float32)}
    if mode == "frozen":
        return {"w": w}, {}
    raise ValueError(f"unknown linear mode: {mode}")


def frozen_weight(frozen: dict, compute_dtype=jnp.bfloat16) -> Array:
    """Resolve a frozen linear's weight, dequantizing an int8 base
    (beyond-paper: the random frozen base tolerates symmetric per-channel
    int8 — halves FSDP all-gather bytes and weight HBM; see
    quantize_frozen_tree)."""
    if "w_q8" in frozen:
        return (frozen["w_q8"].astype(compute_dtype)
                * frozen["w_s"].astype(compute_dtype)[..., None, :])
    return frozen["w"].astype(compute_dtype)


def linear_apply(frozen: dict, trainable: dict, x: Array,
                 scale: float = 1.0,
                 compute_dtype=jnp.bfloat16) -> Array:
    """Apply a mixed-mode linear. Shapes: x (..., d_in) -> (..., d_out)."""
    if "w" in trainable:                       # dense-trained
        w = trainable["w"].astype(compute_dtype)
    else:
        w = frozen_weight(frozen, compute_dtype)
    y = jnp.einsum("...i,io->...o", x.astype(compute_dtype), w)
    if "a" in trainable:                       # lora side chain
        y = y + dense_lora_apply(x, trainable["a"], trainable["b"], scale,
                                 compute_dtype).astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# Beyond-paper: int8 frozen base (QLoRA-style, TPU-FSDP-native)
# ---------------------------------------------------------------------------

def quantize_frozen_tree(frozen) -> dict:
    """Replace every frozen linear {'w': (..,in,out)} with a symmetric
    per-output-channel int8 pack {'w_q8','w_s'}. The base is random and
    never updated (the paper's premise), so static int8 costs nothing in
    trainability while halving weight bytes on HBM and on the FSDP
    all-gather path (vs bf16)."""
    def walk(node):
        if isinstance(node, dict):
            if "w" in node and hasattr(node["w"], "ndim") \
                    and node["w"].ndim >= 2:
                w = node["w"].astype(jnp.float32)
                # reduce only the contracting (d_in) axis: scales keep the
                # (stack..., d_out) shape so layer-stacked leaves still
                # scan (leading L dim preserved)
                amax = jnp.max(jnp.abs(w), axis=-2)
                s = jnp.maximum(amax, 1e-8) / 127.0
                q = jnp.clip(jnp.round(w / s[..., None, :]), -127, 127
                             ).astype(jnp.int8)
                rest = {k: v for k, v in node.items() if k != "w"}
                return {"w_q8": q, "w_s": s.astype(jnp.float16),
                        **{k: walk(v) for k, v in rest.items()}}
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, list):
            return [walk(v) for v in node]
        return node

    return walk(frozen)


def quantize_frozen_logical(logical) -> dict:
    """Parallel transform of the logical-annotation tree."""
    def walk(node):
        if isinstance(node, dict):
            if "w" in node and isinstance(node["w"], tuple):
                ann = node["w"]
                rest = {k: v for k, v in node.items() if k != "w"}
                return {"w_q8": ann, "w_s": (*ann[:-2], ann[-1]),
                        **{k: walk(v) for k, v in rest.items()}}
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, list):
            return [walk(v) for v in node]
        return node

    return walk(logical)


def linear_logical(d_in_name: Optional[str], d_out_name: Optional[str],
                   mode: str, stack: bool = False) -> tuple[dict, dict]:
    """Logical-axis annotations matching linear_init's (frozen, trainable)."""
    pre = ("layers",) if stack else ()
    if mode == "lora":
        return ({"w": (*pre, d_in_name, d_out_name)},
                {"a": (*pre, d_in_name, "lora_rank"),
                 "b": (*pre, "lora_rank", d_out_name)})
    if mode == "dense":
        return {}, {"w": (*pre, d_in_name, d_out_name)}
    if mode == "frozen":
        return {"w": (*pre, d_in_name, d_out_name)}, {}
    raise ValueError(mode)
