"""FLoCoRA high-level API (paper §III, Fig. 1).

One communication round:
  (1) server broadcasts global adapter tree  Δ̄_t L        (quantized)
  (2) each sampled client k trains locally   Δ^k_{t+1} L
  (3) client uploads its adapter tree                       (quantized)
  (4) server FedAvg-aggregates:  Δ̄_{t+1} L = Σ_k (n_k/n) Δ^k_{t+1} L

The base model W_initial is exchanged exactly once (round 0) and never
updated — that is the whole trick. ``server_round``/``broadcast`` are the
jittable pieces; orchestration (sampling, stragglers, faults) lives in
``repro.fl``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import aggregation, lora, messages
from repro.core.quant import DPConfig, QuantConfig, dp_privatize
from repro.core.sparse import SparsityConfig

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class RankSchedule:
    """Per-client LoRA rank profile with optional round-wise annealing.

    ``client_ranks[cid]`` is client cid's base adapter rank (phones get
    r=4, workstations r=32, ...). With ``anneal_every > 0`` every
    client's rank is multiplied by ``anneal_factor`` each
    ``anneal_every`` rounds (floored at ``min_rank``) — late-training
    updates concentrate in fewer directions, so the wire shrinks as the
    run converges.

    The server holds the global adapters at ``max_rank``; broadcast
    truncates (slice) and uplinks arrive at each client's rank. The
    effective alpha/r scale is the SERVER config's and is shared by all
    clients, so mixed-rank products stay directly comparable."""
    client_ranks: tuple[int, ...]
    anneal_every: int = 0
    anneal_factor: float = 0.5
    min_rank: int = 2

    def __post_init__(self):
        if not self.client_ranks:
            raise ValueError("RankSchedule needs at least one client rank")
        if any(r < 1 for r in self.client_ranks):
            raise ValueError(f"ranks must be >= 1: {self.client_ranks}")
        if self.anneal_every < 0:
            raise ValueError("anneal_every must be >= 0")
        if not 0.0 < self.anneal_factor <= 1.0:
            raise ValueError("anneal_factor must be in (0, 1]")
        if self.min_rank < 1:
            raise ValueError("min_rank must be >= 1 (rank-0 adapters "
                             "cannot be packed)")

    @classmethod
    def uniform(cls, rank: int, n_clients: int, **kw) -> "RankSchedule":
        return cls(client_ranks=(rank,) * n_clients, **kw)

    @classmethod
    def tiered(cls, tiers: tuple[int, ...], n_clients: int,
               **kw) -> "RankSchedule":
        """Round-robin assignment of rank tiers over client ids."""
        ranks = tuple(tiers[i % len(tiers)] for i in range(n_clients))
        return cls(client_ranks=ranks, **kw)

    @property
    def n_clients(self) -> int:
        return len(self.client_ranks)

    @property
    def max_rank(self) -> int:
        return max(self.client_ranks)

    def rank_for(self, cid: int, rnd: int = 0) -> int:
        """Client cid's rank at round ``rnd``. The ``min_rank`` floor
        only applies to annealed shrinkage — a configured base rank
        below ``min_rank`` is honored as-is, so the effective floor is
        ``min(min_rank, base)``. With that floor the annealed rank can
        never exceed the base rank (anneal_factor <= 1, validated in
        ``__post_init__``), which the old trailing ``min(r, base)``
        clamp re-imposed redundantly."""
        r = self.client_ranks[cid]
        if self.anneal_every > 0:
            r = max(min(self.min_rank, r),
                    int(r * self.anneal_factor ** (rnd // self.anneal_every)))
        return r

    def ranks_at(self, rnd: int) -> tuple[int, ...]:
        return tuple(self.rank_for(c, rnd) for c in
                     range(len(self.client_ranks)))


@dataclasses.dataclass(frozen=True)
class FLoCoRAConfig:
    rank: int = 32
    alpha: float = 512.0            # paper default: alpha = 16 * r
    quant_bits: Optional[int] = None  # None | 8 | 4 | 2
    error_feedback: bool = False    # beyond-paper EF on the client uplink
    head_mode: str = "dense"        # 'dense' (paper) | 'lora' | 'frozen'
    # heterogeneous fleets: per-client rank profile (None = every client
    # trains at `rank`, the paper's uniform setting)
    rank_schedule: Optional[RankSchedule] = None
    # FLASC-style top-k sparsification of the client UPLINK (None = dense
    # wire, the paper's setting); downlinks always travel dense
    sparsity: Optional[SparsityConfig] = None
    # flat-tree wire codec (core/flat.py): pack/decode/aggregate each
    # DENSE quantized message in one fused kernel launch. Byte-identical
    # wire payloads; False selects the per-leaf oracle codec.
    flat_wire: bool = True
    # differential privacy on the uplink: clip the client's update DELTA
    # and add Gaussian noise BEFORE quantization (None = no DP, the
    # paper's setting). See core/quant.DPConfig.
    dp: Optional[DPConfig] = None

    def __post_init__(self):
        if self.dp is not None and self.dp.noise_multiplier > 0 \
                and self.error_feedback:
            raise ValueError(
                "dp noise and error_feedback are incompatible: the EF "
                "residual would accumulate (and compensate away) the DP "
                "noise across rounds, silently voiding the privacy "
                "guarantee")
        if self.rank_schedule is not None \
                and self.rank_schedule.max_rank > self.rank:
            raise ValueError(
                f"rank_schedule max rank {self.rank_schedule.max_rank} "
                f"exceeds the server rank {self.rank}")
        if self.sparsity is not None and self.sparsity.enabled \
                and self.sparsity.require_ef and not self.error_feedback:
            raise ValueError(
                "SparsityConfig(require_ef=True) needs error_feedback=True"
                " — FLASC keeps accuracy only when the dropped mass rides"
                " the EF residual; set require_ef=False to run sparse"
                " without EF (and accept the bias)")

    @property
    def qcfg(self) -> QuantConfig:
        return QuantConfig(bits=self.quant_bits)

    @property
    def sparsity_active(self) -> bool:
        """True when any round's uplink can be sparse."""
        return self.sparsity is not None and self.sparsity.enabled

    def uplink_density(self, rnd: int = 0) -> Optional[float]:
        """Round ``rnd``'s uplink density; None = dense wire."""
        if not self.sparsity_active:
            return None
        return self.sparsity.density_at(rnd)

    @property
    def scale(self) -> float:
        return self.alpha / self.rank



def server_downlink(global_trainable: Any, cfg: FLoCoRAConfig,
                    rank: Optional[int] = None) -> Any:
    """Step (1), wire form: the packed message the server broadcasts
    (uint32 payloads + fp32 sidecars; fp tree when quantization is off).

    ``rank`` truncates/pads the global adapters to the receiving
    client's rank before packing (slice truncation: after an SVD
    recombination the components are energy-ordered, and a fresh
    zero-product adapter keeps its nonzero down-projection)."""
    if rank is not None:
        global_trainable = lora.resize_tree_rank(global_trainable, rank,
                                                 method="slice")
    if not cfg.qcfg.enabled:
        return global_trainable
    return messages.pack_message(global_trainable, cfg.qcfg,
                                 flat=cfg.flat_wire)


def broadcast(global_trainable: Any, cfg: FLoCoRAConfig,
              rank: Optional[int] = None) -> Any:
    """Step (1): what clients reconstruct from the server message."""
    return messages.unpack_message(
        server_downlink(global_trainable, cfg, rank))


def client_uplink(trainable: Any, cfg: FLoCoRAConfig,
                  ef_residual: Optional[Any] = None,
                  rnd: int = 0, start: Optional[Any] = None,
                  dp_key: Optional[tuple] = None,
                  dp_seed: int = 0) -> tuple[Any, Optional[Any]]:
    """Step (3): one client's WIRE message (packed payloads when
    quantization is on, sparse top-k payloads when a ``sparsity``
    profile is set — ``rnd`` resolves the annealed density; the raw fp
    tree otherwise).

    With ``cfg.dp`` set, the client's update DELTA (``trainable -
    start``; ``start=None`` treats the base as zero) is clipped and
    Gaussian-noised BEFORE quantization — the wire carries
    ``start + privatized_delta``, so FedAvg over messages equals the
    global tree plus the mean privatized delta (``start`` is the public
    broadcast; adding it back is post-processing). ``dp_key`` keys the
    noise draw (defaults to ``(rnd,)``; pass dispatch-unique ids in
    async so two concurrent dispatches of one client never share
    noise); ``dp_seed`` is the engine seed.

    With error feedback enabled, the client compensates its own previous
    compression error — quantization noise AND top-k-dropped mass
    (beyond-paper option; REQUIRED by default for sparse uplinks); pass
    the stored residual (``None`` initializes a zero residual). Returns
    (message, residual)."""
    if cfg.dp is not None:
        key = dp_key if dp_key is not None else (rnd,)
        if start is not None:
            delta = jax.tree_util.tree_map(jnp.subtract, trainable, start)
            priv = dp_privatize(delta, cfg.dp, seed=dp_seed, key=key)
            trainable = jax.tree_util.tree_map(jnp.add, start, priv)
        else:
            trainable = dp_privatize(trainable, cfg.dp, seed=dp_seed,
                                     key=key)
    density = cfg.uplink_density(rnd)
    wire_on = cfg.qcfg.enabled or (density is not None and density < 1.0)
    if cfg.error_feedback and wire_on:
        if ef_residual is None:
            ef_residual = aggregation.ef_init(trainable)
        return aggregation.ef_encode_packed(trainable, ef_residual,
                                            cfg.qcfg, density=density,
                                            flat=cfg.flat_wire)
    if not wire_on:
        return trainable, ef_residual
    return messages.pack_message(trainable, cfg.qcfg, density=density,
                                 flat=cfg.flat_wire), ef_residual


def server_round(stacked_client_trainables: Any, weights: Array,
                 cfg: FLoCoRAConfig) -> Any:
    """Steps (3)+(4) fused: dequantize each client message and FedAvg.

    `stacked_client_trainables` leaves have a leading K (clients) dim and
    hold the *raw* client fp trees; quantization happens inside so the
    whole round jits into one program (and, on TPU, lowers onto the fused
    dequant+reduce Pallas kernel)."""
    return aggregation.fedavg_quantized(stacked_client_trainables, weights,
                                        cfg.qcfg)


def round_wire_bytes(trainable: Any, cfg: FLoCoRAConfig,
                     rank: Optional[int] = None, rnd: int = 0) -> dict:
    """Per-round, PER-CLIENT message accounting. The two directions are
    equal on a dense wire; with a sparsity profile the uplink shrinks to
    the round's density (downlinks always travel dense)."""
    down = client_wire_bytes(trainable, cfg, rank)
    up = client_wire_bytes(trainable, cfg, rank,
                           density=cfg.uplink_density(rnd))
    return {"down_bytes": down, "up_bytes": up,
            "round_bytes": down + up}


def client_wire_bytes(trainable: Any, cfg: FLoCoRAConfig,
                      rank: Optional[int] = None,
                      density: Optional[float] = None) -> int:
    """One direction of one round for a client at ``rank`` (static
    accounting over the resized adapter shapes). ``density`` selects the
    sparse-uplink accounting (None = dense)."""
    if rank is not None:
        trainable = lora.resize_tree_rank(trainable, rank, method="slice")
    return messages.message_wire_bytes(trainable, cfg.qcfg, density)


def tcc(trainable: Any, cfg: FLoCoRAConfig, rounds: int) -> int:
    """Paper Eq. 2: total communication cost for one client, R rounds."""
    return messages.tcc_bytes(trainable, cfg.qcfg, rounds)


def fleet_tcc_bytes(trainable: Any, cfg: FLoCoRAConfig, rounds: int) -> int:
    """Fleet-level TCC: heterogeneous uplinks+downlinks summed over every
    client and round of the schedule (replaces Eq. 2's uniform
    ``2 * one_way * rounds`` when a rank profile or a sparsity profile
    is set — sparse uplinks and dense downlinks are sized separately,
    per round so density annealing is honored)."""
    sched = cfg.rank_schedule
    if sched is None and not cfg.sparsity_active:
        return messages.tcc_bytes(trainable, cfg.qcfg, rounds)
    cache: dict[tuple, int] = {}

    def sized(r: Optional[int], density: Optional[float]) -> int:
        key = (r, density)
        if key not in cache:
            cache[key] = client_wire_bytes(trainable, cfg, r, density)
        return cache[key]

    total = 0
    for rnd in range(rounds):
        density = cfg.uplink_density(rnd)
        ranks = sched.ranks_at(rnd) if sched is not None else (None,)
        for r in ranks:
            total += sized(r, None) + sized(r, density)
    return total
