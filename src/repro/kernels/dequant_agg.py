"""Pallas TPU kernel: fused unpack + dequantize + weighted aggregate.

The FLoCoRA server hot loop: K quantized client messages -> one fp32
aggregated adapter tree, WITHOUT materializing K dequantized fp32 copies
(K x memory saved; the op is bandwidth-bound on the packed payload, which
is 4-16x smaller than fp32 — this fusion is what makes the paper's
quantization a server-side win too, not just a wire win).

Grid: (C/bc, K) with K innermost — each (bc, Nw) packed tile is unpacked,
dequantized with its (per-client, per-channel) scale/zp and accumulated
into the fp32 output block resident in VMEM across the K steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _dequant_agg_kernel(packed_ref, scale_ref, zp_ref, w_ref, out_ref, *,
                        bits: int):
    k = pl.program_id(1)
    per = 32 // bits
    words = packed_ref[0]                                  # (bc, Nw) uint32
    shifts = (jax.lax.broadcasted_iota(
        jnp.uint32, (*words.shape, per), 2) * jnp.uint32(bits))
    mask = jnp.uint32((1 << bits) - 1)
    lv = ((words[..., None] >> shifts) & mask).astype(jnp.float32)
    lv = lv.reshape(words.shape[0], words.shape[1] * per)  # (bc, N)
    scale = scale_ref[0]                                   # (bc, 1)
    zp = zp_ref[0]
    w = w_ref[0, 0]
    contrib = w * (lv - zp) * scale

    @pl.when(k == 0)
    def _init():
        out_ref[...] = contrib

    @pl.when(k > 0)
    def _acc():
        out_ref[...] += contrib


def dequant_agg_pallas(packed: Array, scale: Array, zp: Array,
                       weights: Array, bits: int, *, block_c: int = 8,
                       interpret: bool = False) -> Array:
    """packed (K, C, Nw) uint32; scale/zp (K, C); weights (K,).
    Returns (C, N) fp32 weighted sum of dequantized messages."""
    k, c, nw = packed.shape
    per = 32 // bits
    n = nw * per
    assert c % block_c == 0
    grid = (c // block_c, k)
    out = pl.pallas_call(
        functools.partial(_dequant_agg_kernel, bits=bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_c, nw), lambda i, kk: (kk, i, 0)),
            pl.BlockSpec((1, block_c, 1), lambda i, kk: (kk, i, 0)),
            pl.BlockSpec((1, block_c, 1), lambda i, kk: (kk, i, 0)),
            pl.BlockSpec((1, 1), lambda i, kk: (kk, 0)),
        ],
        out_specs=pl.BlockSpec((block_c, n), lambda i, kk: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((c, n), jnp.float32),
        interpret=interpret,
    )(packed, scale[..., None], zp[..., None], weights[:, None])
    return out
