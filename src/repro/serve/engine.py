"""Multi-tenant adapter serving engine: the FLoCoRA read path.

One frozen base (a chain of linear layers), thousands of per-client
adapters at rest in the wire-format :class:`~repro.serve.cache.
AdapterCache`. A decode micro-batch carries a PER-ROW client id; the
engine groups rows by pow2 rank bucket, stages each bucket's adapters
as packed slabs, and runs one fused program per bucket per layer chain:

  * ``path='fused'`` (production): ``multi_lora_matmul_packed`` —
    gather packed words by row id, dequant INSIDE the matmul. An
    uplinked adapter is servable without ever materializing an fp32
    adapter tree (the TensorRT-LLM weight-only-quant idiom).
  * ``path='dequant'`` (the baseline the benchmark beats): dequantize
    the staged slab to fp32 stacks in one program, then the fp
    multi-adapter matmul in a second — what serving looks like without
    the fusion.
  * :meth:`AdapterServingEngine.oracle_step` (numerics oracle): per-row
    ``dense_merge`` of the dequantized pair into the base — the merged
    serving the seed example did, kept as the correctness contract.

Cache lookups are counted at ADMISSION (:meth:`admit` — one per
request, optionally fetching a miss from the FL server's store); the
per-token :meth:`step` reads the cache uncounted. Batch rows pad to
pow2 (min 8) and slabs pad slots to pow2, so a steady-state decode
step re-dispatches already-compiled programs: 0 new compiles.

:func:`generate` is the shared LM prefill+decode loop used by
``launch/serve.py`` and ``examples/serve_quantized.py`` (merged-adapter
single-tenant serving — the zero-added-latency path of paper §II-C).
"""
from __future__ import annotations

import time
from functools import partial
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import lora
from repro.core.quant import QuantConfig
from repro.fl.client import pow2_pad
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.obs import trace as obst
from repro.obs.compile import CompileWatchdog
from repro.serve.cache import AdapterCache, StagedBucket, StagedLayer

Array = jax.Array

PATHS = ("fused", "dequant")


@partial(jax.jit, static_argnames=("s", "bits"))
def _fused_chain(x, ids, weights, layers, s: float, bits: int):
    """One bucket's whole layer chain, one jitted program: every layer
    is a fused gather+dequant+matmul over the packed slab."""
    for w, lyr in zip(weights, layers):
        x = kops.multi_lora_matmul_packed(
            x, w, lyr.aq, lyr.a_scale, lyr.a_zp, lyr.bq, lyr.b_scale,
            lyr.b_zp, ids, s, bits)
    return x


@partial(jax.jit, static_argnames=("bits", "k", "r"))
def _dequant_stacks(lyr: StagedLayer, bits: int, k: int, r: int):
    """Baseline program 1: materialize the staged slab as fp32 adapter
    stacks (E, K, R) / (E, R, N) — the cost the fused path avoids."""
    la = kref.unpack_words(lyr.aq, bits)[..., :k].astype(jnp.float32)
    adeq = (la - lyr.a_zp[..., None]) * lyr.a_scale[..., None]
    lb = kref.unpack_words(lyr.bq, bits)[..., :r].astype(jnp.float32)
    bdeq = (lb - lyr.b_zp[..., None]) * lyr.b_scale[..., None]
    return jnp.swapaxes(adeq, 1, 2), jnp.swapaxes(bdeq, 1, 2)


class AdapterServingEngine:
    """Serve ``weights`` (a chain of (d_in, d_out) frozen linears) with
    per-request adapters from ``cache``. ``fetch(cid) -> wire message``
    resolves admission misses from the adapter store (the FL server's
    registry); without it a miss raises."""

    def __init__(self, weights: Sequence[Array], scale: float,
                 qcfg: QuantConfig, cache: AdapterCache,
                 fetch: Optional[Callable[[int], Any]] = None,
                 path: str = "fused", slab_slots: int = 8,
                 strict_compiles: bool = False,
                 tracer: Optional[obst.Tracer] = None):
        if path not in PATHS:
            raise ValueError(f"path must be one of {PATHS}: {path!r}")
        self.weights = tuple(jnp.asarray(w, jnp.float32) for w in weights)
        self.scale = float(scale)
        self.qcfg = qcfg
        self.cache = cache
        self.fetch = fetch
        self.path = path
        # slab slot floor: buckets pad to >= this many slots so the
        # serving program's E dim is stable across batch compositions
        # (keep >= the largest micro-batch for 0 steady-state compiles)
        self.slab_slots = int(slab_slots)
        # staged slabs memo: bucket rank -> ((cids key, cache version),
        # StagedBucket); restages only when the working set changes
        self._staged: dict[int, tuple[tuple, StagedBucket]] = {}
        # opt-in runtime enforcement of the 0-steady-state-compile
        # contract: once a step SHAPE (batch rows x per-bucket split x
        # slab slots x path) has run, re-running it must compile
        # nothing — a retrace raises obs.CompileBudgetExceeded
        self.strict_compiles = bool(strict_compiles)
        self._warm_shapes: set[tuple] = set()
        self.tracer = obst.get_tracer(tracer)

    # -- admission (counted cache traffic) ----------------------------------

    def admit(self, cids: Sequence[int]) -> int:
        """One COUNTED cache lookup per request; misses fetch from the
        store and land in the cache in wire form. Returns #misses."""
        misses = 0
        for cid in cids:
            if self.cache.lookup(cid) is None:
                misses += 1
                if self.fetch is None:
                    raise KeyError(f"client {cid} not cached and no "
                                   "fetch callback configured")
                self.cache.put(cid, self.fetch(cid))
        return misses

    # -- decode -------------------------------------------------------------

    def step(self, x: Array, cids: Sequence[int]) -> Array:
        """One decode micro-batch: x (B, d_in), cids length B. Rows
        group by rank bucket; each bucket runs its own (already
        compiled) program over its staged slab."""
        cids = [int(c) for c in cids]
        if x.shape[0] != len(cids):
            raise ValueError(f"{x.shape[0]} rows vs {len(cids)} cids")
        groups: dict[int, list[int]] = {}
        for row, cid in enumerate(cids):
            e = self.cache.peek(cid)
            if e is None:
                raise KeyError(f"client {cid} not cached — admit() first")
            groups.setdefault(pow2_pad(e.rank), []).append(row)
        # staging first (slab growth/restage MAY compile — it is not
        # steady state); the compute below is watchdogged by shape
        staged_by = {rb: self._staged_for(rb, [cids[r] for r in rows])
                     for rb, rows in sorted(groups.items())}
        shape_key = (x.shape[0], self.path, tuple(
            (rb, len(rows), staged_by[rb].n_slots)
            for rb, rows in sorted(groups.items())))
        with self.tracer.span("serve/step", batch=len(cids),
                              buckets=len(groups), path=self.path):
            if self.strict_compiles and shape_key in self._warm_shapes:
                with CompileWatchdog(0, label="steady-state decode "
                                              f"{shape_key}"):
                    y = self._compute(x, cids, groups, staged_by)
            else:
                y = self._compute(x, cids, groups, staged_by)
                self._warm_shapes.add(shape_key)
        return y

    def _compute(self, x: Array, cids: list[int],
                 groups: dict[int, list[int]],
                 staged_by: dict[int, StagedBucket]) -> Array:
        n_out = self.weights[-1].shape[1]
        y = jnp.zeros((len(cids), n_out), jnp.float32)
        for rb, rows in sorted(groups.items()):
            staged = staged_by[rb]
            yb = self._bucket_step(
                x[jnp.asarray(rows)], staged,
                [staged.slots[cids[r]] for r in rows])
            y = y.at[jnp.asarray(rows)].set(yb)
        return y

    def _staged_for(self, rb: int, bucket_cids: list[int]) -> StagedBucket:
        """Working-set staging: the bucket's slab ACCUMULATES the
        clients it has served, so steady-state batches over resident
        adapters reuse the device slab with zero restaging/upload. A
        cache write (put/evict bumps ``version``) or an unstaged client
        rebuilds the slab from the still-cached working set plus the
        new arrivals; the slot count only ever pow2-grows, so slab
        recompiles are log-bounded."""
        need = set(bucket_cids)
        cur = self._staged.get(rb)
        if cur is not None and cur[0] == self.cache.version \
                and need <= cur[1].slots.keys():
            return cur[1]
        keep = [] if cur is None else [
            c for c in cur[1].slots
            if (e := self.cache.peek(c)) is not None
            and pow2_pad(e.rank) == rb]
        cids = keep + [c for c in bucket_cids if c not in set(keep)]
        staged = self.cache.stage(cids, min_slots=self.slab_slots)[rb]
        self._staged[rb] = (self.cache.version, staged)
        return staged

    def _bucket_step(self, xb: Array, staged: StagedBucket,
                     slots: list[int]) -> Array:
        m = xb.shape[0]
        mp = max(8, pow2_pad(m))
        xp = jnp.pad(xb, ((0, mp - m), (0, 0))) if mp != m else xb
        ids = jnp.asarray(slots + [0] * (mp - m), jnp.int32)
        bits = self.qcfg.bits
        if self.path == "fused":
            yp = _fused_chain(xp, ids, self.weights, staged.layers,
                              self.scale, bits)
        else:
            yp = xp
            for w, lyr in zip(self.weights, staged.layers):
                a_stack, b_stack = _dequant_stacks(
                    lyr, bits, w.shape[0], staged.rank)
                yp = kops.multi_lora_matmul(yp, w, a_stack, b_stack,
                                            ids, self.scale)
        return yp[:m]

    # -- numerics oracle ----------------------------------------------------

    def oracle_step(self, x: Array, cids: Sequence[int]) -> Array:
        """Per-row merged-dense serving (``dense_merge`` of the
        DEQUANTIZED pair into the base) — the slow exact reference the
        fused path is validated against. Test/debug only."""
        ys = []
        for row, cid in enumerate(cids):
            e = self.cache.peek(int(cid))
            if e is None:
                raise KeyError(f"client {cid} not cached")
            xv = x[row].astype(jnp.float32)
            for w, pair in zip(self.weights, e.pairs):
                a, b = pair.dequant()
                xv = xv @ lora.dense_merge(w, a, b, self.scale)
            ys.append(xv)
        return jnp.stack(ys)


# ---------------------------------------------------------------------------
# Shared single-tenant LM serving loop (merged adapters, paper §II-C)
# ---------------------------------------------------------------------------

def generate(frozen: Any, train: Any, cfg: Any, prompt: Array,
             gen: int, *, temperature: float = 0.0, seed: int = 0,
             max_seq: Optional[int] = None
             ) -> tuple[Array, dict[str, float]]:
    """Prefill + autoregressive decode for a decoder LM: the ONE
    serving loop ``launch/serve.py`` and ``examples/serve_quantized.py``
    both drive (greedy argmax, or categorical at ``temperature > 0``).

    Returns (tokens (B, gen) int32 — the prefill-argmax token plus
    ``gen - 1`` decode steps — and wall timings
    {'prefill_s', 'decode_s', 'decode_steps'})."""
    from repro.models import lm as LM
    if max_seq is None:
        max_seq = prompt.shape[1] + gen + \
            (cfg.prefix_len if getattr(cfg, "prefix_lm", False) else 0)

    prefill = jax.jit(lambda f, t, tok: LM.prefill(f, t, cfg, tok,
                                                   max_seq=max_seq))
    decode = jax.jit(lambda f, t, tok, c, pos: LM.decode_step(
        f, t, cfg, tok, c, pos))

    t0 = time.time()
    logits, caches, pos = prefill(frozen, train, prompt)
    jax.block_until_ready(logits)
    prefill_s = time.time() - t0

    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [tok]
    key = jax.random.PRNGKey(seed)
    t0 = time.time()
    for _ in range(gen - 1):
        logits, caches = decode(frozen, train, tok, caches, pos)
        if temperature > 0:
            key, sk = jax.random.split(key)
            tok = jax.random.categorical(
                sk, logits[:, 0] / temperature)[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, 0], -1)[:, None].astype(jnp.int32)
        pos = pos + 1
        out.append(tok)
    jax.block_until_ready(tok)
    decode_s = time.time() - t0
    return jnp.concatenate(out, axis=1), {
        "prefill_s": prefill_s, "decode_s": decode_s,
        "decode_steps": gen - 1}
