"""Unified telemetry layer: metrics, tracing, compile watchdog.

  * :mod:`repro.obs.metrics` — labeled counters/gauges/histograms in a
    registry (process-global default, disabled until opted in, or an
    injected instance);
  * :mod:`repro.obs.trace` — span tracer on wall OR virtual clocks,
    Chrome-trace JSON + JSONL export;
  * :mod:`repro.obs.compile` — the ONE ``jax.monitoring``
    backend-compile listener: measurement context, enforcing watchdog,
    pytest fixture;
  * :mod:`repro.obs.meta` — benchmark run fingerprints for
    ``bench_compare``'s cross-backend refusal.

Quick start (everything off by default, zero overhead until enabled)::

    from repro import obs
    reg, tracer = obs.enable()          # turn the process defaults on
    ... run a round / an async run / a serve simulation ...
    reg.dump()                          # metrics as one JSON dict
    tracer.export_chrome("trace.json")  # load in chrome://tracing
"""
from repro.obs.compile import (CompileBudgetExceeded, CompileWatchdog,
                               compile_count, count_compiles)
from repro.obs.meta import run_meta
from repro.obs.metrics import (MetricsRegistry, default_registry,
                               get_registry, set_default_registry)
from repro.obs.trace import (Tracer, default_tracer, get_tracer,
                             set_default_tracer)


def enable() -> tuple[MetricsRegistry, Tracer]:
    """Switch the process-global registry AND tracer on; returns both."""
    reg, tracer = default_registry(), default_tracer()
    reg.enabled = True
    tracer.enabled = True
    return reg, tracer


def disable() -> None:
    default_registry().enabled = False
    default_tracer().enabled = False


__all__ = [
    "CompileBudgetExceeded", "CompileWatchdog", "MetricsRegistry",
    "Tracer", "compile_count", "count_compiles", "default_registry",
    "default_tracer", "disable", "enable", "get_registry", "get_tracer",
    "run_meta", "set_default_registry", "set_default_tracer",
]
