"""Roofline analysis from compiled (AOT) artifacts — no hardware needed.

Terms per (arch x shape x mesh), all in seconds:
  t_compute    = HLO_FLOPs_per_chip / peak_FLOPs
  t_memory     = HLO_bytes_per_chip / HBM_bw
  t_collective = sum over collective ops of wire_bytes_per_chip / link_bw

cost_analysis() on an SPMD-partitioned module reports the PER-CHIP
program (each chip runs the same partitioned executable), so no division
by chip count is applied to its numbers.

collective_bytes parses the compiled HLO text: for every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute it takes
the op's result shape and its replica-group size g and charges ring-
algorithm wire bytes:
    all-gather      out * (g-1)/g          (out = gathered size)
    reduce-scatter  in  * (g-1)/g ~= out * (g-1)
    all-reduce      2 * size * (g-1)/g
    all-to-all      size * (g-1)/g
    collective-permute  size
Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI
(DCN for the 'pod' axis is charged at 25 GB/s per host link).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Optional

HW = {
    "peak_flops": 197e12,        # bf16
    "hbm_bw": 819e9,
    "ici_bw": 50e9,              # per link
    "dcn_bw": 25e9,
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 0.5, "u4": 0.5,
}

_COLL_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+)\[([\d,]*)\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

_TUPLE_ELT_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> float:
    nb = _DTYPE_BYTES.get(dtype)
    if nb is None:
        return 0.0
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n * nb


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return 2


def collective_bytes(hlo_text: str) -> dict:
    """Sums wire bytes per chip per collective kind over the HLO."""
    out = {"all-gather": 0.0, "all-reduce": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0, "total": 0.0,
           "n_ops": 0}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        tuple_body, dtype, dims, kind = m.groups()
        if tuple_body is not None:
            size = sum(_shape_bytes(dt, dm)
                       for dt, dm in _TUPLE_ELT_RE.findall(tuple_body))
        else:
            size = _shape_bytes(dtype, dims)
        g = _group_size(line)
        if g <= 1:
            continue
        if kind == "all-gather":
            wire = size * (g - 1) / g
        elif kind == "reduce-scatter":
            wire = size * (g - 1)          # size = scattered output
        elif kind == "all-reduce":
            wire = 2 * size * (g - 1) / g
        elif kind == "all-to-all":
            wire = size * (g - 1) / g
        else:                               # collective-permute
            wire = size
        out[kind] += wire
        out["total"] += wire
        out["n_ops"] += 1
    return out


def roofline_terms(cost: dict, coll: dict, *, chips: int,
                   link_bw: float = HW["ici_bw"]) -> dict:
    """cost: compiled.cost_analysis() dict (per-chip program)."""
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    t_compute = flops / HW["peak_flops"]
    t_memory = byts / HW["hbm_bw"]
    t_coll = coll["total"] / link_bw
    dominant = max(("compute", t_compute), ("memory", t_memory),
                   ("collective", t_coll), key=lambda kv: kv[1])[0]
    return {"t_compute_s": t_compute, "t_memory_s": t_memory,
            "t_collective_s": t_coll, "dominant": dominant,
            "hlo_flops_per_chip": flops, "hlo_bytes_per_chip": byts,
            "collective_wire_bytes_per_chip": coll["total"],
            "n_collectives": coll["n_ops"]}


# ---------------------------------------------------------------------------
# MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE): useful-compute yardstick
# ---------------------------------------------------------------------------

def model_flops(n_params_active: int, tokens: int, train: bool = True
                ) -> float:
    """6*N*D for a train step (fwd+bwd); 2*N*D for inference forward."""
    return (6.0 if train else 2.0) * n_params_active * tokens
