"""Lazy Population layer + fleet realism: tier hashing, bounded lazy
shards, churn-aware engines, keyed dropout/resume determinism, deadline
cohorts, DP noise-then-quantize uplinks, and the bit-exact kill/resume
of a population-scale fleet run."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:         # optional dep: property tests skip, rest runs
    given = settings = st = None

from repro.core.flocora import FLoCoRAConfig
from repro.core.lora import LoRAConfig, linear_apply, linear_init
from repro.core.quant import DPConfig, dp_privatize, gaussian_epsilon, \
    global_l2_norm
from repro.data.synthetic import client_shard, linear_shard
from repro.fl import AsyncConfig, AsyncFLServer, AvailabilityWindows, \
    ClientConfig, DeviceTier, FLServer, FleetTrace, LognormalLatency, \
    Population, PopulationTrace, ServerConfig
from repro.fl.client import cohort_steps


# ---------------------------------------------------------------------------
# tiny linear LoRA workload (mirrors test_async_engine: fast compiles)
# ---------------------------------------------------------------------------

def _lora_model(seed=0, rank=16):
    k = jax.random.PRNGKey(seed)
    fz, tr = linear_init(k, 16, 10, "lora",
                         LoRAConfig(rank=rank, alpha=float(rank)),
                         base_dtype=jnp.float32)
    return {"frozen": {"lin": fz},
            "train": {"lin": tr, "bias": jnp.zeros((10,))}}


def _lora_loss(frozen, train, batch):
    logits = linear_apply(frozen["lin"], train["lin"], batch["x"], 1.0,
                          jnp.float32) + train["bias"]
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, batch["y"][:, None],
                                         axis=1)), {}


def _pop(n=10_000, seed=1, cache=32, tiers=None):
    return Population(n, tiers=tiers, seed=seed, shard_size=24,
                      shard_fn=lambda s, c: linear_shard(s, c, n=24,
                                                         d=16),
                      cache_clients=cache)


TIERS = (DeviceTier("phone", rank=4, fraction=0.70, p_churn=0.10,
                    period_s=86400.0, duty=0.4),
         DeviceTier("laptop", rank=8, fraction=0.25, p_churn=0.02),
         DeviceTier("work", rank=16, fraction=0.05))

CCFG = ClientConfig(local_epochs=2, batch_size=8, lr=0.1)


# ---------------------------------------------------------------------------
# Population: tier hashing, lazy shards, sampling
# ---------------------------------------------------------------------------

def test_tier_assignment_pure_and_fractional():
    pop = _pop(tiers=TIERS)
    a = [pop.tier_index(c) for c in range(1000)]
    b = [pop.tier_index(c) for c in range(1000)]
    assert a == b                      # pure function of (seed, cid)
    counts = pop.tier_counts(10_000)
    assert abs(counts["phone"] / 10_000 - 0.70) < 0.03
    assert abs(counts["laptop"] / 10_000 - 0.25) < 0.03
    assert abs(counts["work"] / 10_000 - 0.05) < 0.02
    # tier properties route through the tier
    for c in range(50):
        t = pop.tier_for(c)
        assert pop.rank_for(c) == t.rank
        assert pop.p_churn_for(c) == t.p_churn


def test_lazy_shards_bit_identical_and_bounded():
    pop = _pop(cache=16)
    s = pop[4321]
    assert s["x"].shape == (24, 16) and s["y"].shape == (24,)
    # evict by touching > cache_clients other shards, then regenerate
    for c in range(20):
        pop[c]
    assert pop.resident_clients <= 16
    s2 = pop[4321]
    assert np.array_equal(s2["x"], s["x"])
    assert np.array_equal(s2["y"], s["y"])
    assert pop.peak_resident <= 16     # O(cache), never O(fleet)
    # vision shards too: pure function of (seed, cid), non-IID labels
    v1, v2 = client_shard(7, 99, n=16), client_shard(7, 99, n=16)
    assert np.array_equal(v1["x"], v2["x"])
    assert len(np.unique(v1["y"])) <= 3


def test_sample_cid_respects_busy():
    pop = _pop(n=50)
    rng = np.random.default_rng(0)
    busy = set(range(49))              # one free client
    for _ in range(5):
        assert pop.sample_cid(np.random.default_rng(3), busy) == 49
    assert pop.sample_cid(rng, set(range(50))) is None
    got = pop.sample_cid(rng, {1, 2, 3})
    assert got not in {1, 2, 3} and 0 <= got < 50


def test_population_validation():
    with pytest.raises(ValueError, match="sum to 1"):
        Population(10, tiers=(DeviceTier("a", 4, 0.5),))
    with pytest.raises(ValueError):
        DeviceTier("a", 0, 1.0)        # rank < 1
    with pytest.raises(ValueError):
        DeviceTier("a", 4, 1.0, p_churn=1.0)
    with pytest.raises(ValueError, match="requires a population"):
        PopulationTrace(seed=0)


def test_population_trace_tiered_hooks():
    pop = _pop(tiers=TIERS)
    tr = PopulationTrace(seed=1, population=pop)
    phone = next(c for c in range(100) if pop.tier_for(c).name == "phone")
    work = next(c for c in range(100) if pop.tier_for(c).name == "work")
    assert tr.p_churn_for(phone) == 0.10
    assert tr.p_churn_for(work) == 0.0
    assert tr.availability_for(phone).period_s == 86400.0
    assert tr.availability_for(work).period_s == 0.0
    # churn draws keyed (seed, cid, dispatch_idx): replay identical
    draws = [tr.churned(phone, i) for i in range(200)]
    assert draws == [tr.churned(phone, i) for i in range(200)]
    assert any(draws)                  # p=0.10 over 200 dispatches
    assert not any(tr.churned(work, i) for i in range(200))


def test_schedule_steps_matches_eager():
    pop = _pop(n=7)
    eager = [pop[c] for c in range(7)]
    assert pop.schedule_steps(CCFG) == cohort_steps(eager, CCFG)


# ---------------------------------------------------------------------------
# SATELLITE: LognormalLatency underflow guard + transfer model
# ---------------------------------------------------------------------------

def test_latency_underflow_raises():
    # 6-sigma jitter below 1 byte/s must fail at construction
    with pytest.raises(ValueError, match="jitter below 1 byte/s"):
        LognormalLatency(network_mbps=1e-6, network_sigma=2.0)
    # generous link: fine, and the floor is never the divisor
    lat = LognormalLatency(network_mbps=20.0, network_sigma=0.4)
    rng = np.random.default_rng(0)
    t_small = lat.sample(np.random.default_rng(1), 8, 10_000)
    t_big = lat.sample(np.random.default_rng(1), 8, 100_000_000)
    assert t_big > t_small             # bigger messages take longer


def test_latency_zero_sigma_deterministic_transfer():
    lat = LognormalLatency(compute_median_s=1.0, compute_sigma=0.0,
                           network_mbps=8.0, network_sigma=0.0,
                           rank_ref=8, rank_exp=0.0)
    # 8 Mbps = 1e6 bytes/s: 1e6 wire bytes -> exactly 1s transfer + 1s
    # compute
    got = lat.sample(np.random.default_rng(0), 8, 1_000_000)
    assert got == pytest.approx(2.0)


# ---------------------------------------------------------------------------
# SATELLITE: AvailabilityWindows property tests (hypothesis)
# ---------------------------------------------------------------------------

if st is not None:
    @settings(max_examples=200, deadline=None)
    @given(cid=st.integers(0, 2**31 - 1),
           t=st.floats(0.0, 1e7, allow_nan=False),
           period=st.floats(60.0, 1e5),
           duty=st.floats(0.05, 1.0, exclude_max=True))
    def test_next_available_properties(cid, t, period, duty):
        w = AvailabilityWindows(period_s=period, duty=duty)
        tol = 1e-6 * period
        t1 = w.next_available(cid, t)
        assert t1 >= t                              # never in the past
        # idempotent (up to float modulo wrap at the window edge)
        assert abs(w.next_available(cid, t1) - t1) <= tol
        # lands inside a duty window (pos ~ period is the wrapped edge)
        pos = (t1 - w.phase(cid)) % period
        assert pos < duty * period + tol or pos > period - tol
else:
    def test_next_available_properties():
        pytest.skip("hypothesis not installed")


def test_phase_staggering_spreads_fleet():
    """The Knuth-hash phase spreads clients across the period instead of
    synchronizing the fleet's windows."""
    w = AvailabilityWindows(period_s=1000.0, duty=0.25)
    phases = np.array([w.phase(c) for c in range(1000)])
    assert phases.min() < 100.0 and phases.max() > 900.0
    hist, _ = np.histogram(phases, bins=10, range=(0, 1000.0))
    assert (hist > 0).all()            # every decile occupied
    # consequence: at any instant a ~duty fraction is available
    avail = sum(w.next_available(c, 5000.0) == 5000.0
                for c in range(1000))
    assert 0.15 < avail / 1000 < 0.35


# ---------------------------------------------------------------------------
# SATELLITE: keyed dropout draws (resume determinism)
# ---------------------------------------------------------------------------

def _sync_server(data, p_fail=0.0, tmpdir=None, trace=None, dp=None,
                 rounds=4):
    return FLServer(
        _lora_model(rank=16), _lora_loss, data,
        ServerConfig(rounds=rounds, n_clients=len(data),
                     clients_per_round=4, oversample=1.5,
                     p_client_failure=p_fail, seed=3,
                     checkpoint_dir=tmpdir, checkpoint_every=1),
        CCFG,
        FLoCoRAConfig(rank=16, alpha=16.0, quant_bits=8, dp=dp),
        trace=trace)


def _lin_list(n_clients=10, seed=0):
    return [linear_shard(seed, c, n=24, d=16) for c in range(n_clients)]


def test_failure_draws_do_not_touch_sampler_stream():
    """REGRESSION: dropout draws are keyed (seed, round, cid) — they
    must never consume the mutable sampler stream (i.i.d. draws from
    ``self.rng`` made resumed runs diverge)."""
    srv = _sync_server(_lin_list(), p_fail=0.4)
    before = srv.rng.bit_generator.state
    for r in range(20):
        for c in range(10):
            srv._client_failed(r, c)
    assert srv.rng.bit_generator.state == before


def test_keyed_failure_pure_function():
    data = _lin_list()
    srv = _sync_server(data, p_fail=0.4)
    a = [srv._client_failed(r, c) for r in range(5) for c in range(10)]
    b = [srv._client_failed(r, c) for r in range(5) for c in range(10)]
    assert a == b and any(a) and not all(a)


def test_sync_resume_with_dropout_exact(tmp_path):
    """REGRESSION: a killed-and-resumed sync run with dropout + deadline
    cohorts reproduces the uninterrupted run's remaining rounds."""
    data = _lin_list()
    trace = FleetTrace(seed=3, latency=LognormalLatency(
        compute_median_s=10.0, network_mbps=20.0))
    srv_a = _sync_server(data, p_fail=0.3, tmpdir=str(tmp_path / "a"),
                         trace=trace)
    hist_a = srv_a.run(4)
    # kill after round 2: replay rounds 3-4 from the checkpoint
    shutil.copytree(str(tmp_path / "a"), str(tmp_path / "b"))
    for f in sorted(os.listdir(tmp_path / "b")):
        if f.startswith("ckpt_") and int(f[5:13]) > 2:
            os.remove(tmp_path / "b" / f)
    srv_b = _sync_server(data, p_fail=0.3, tmpdir=str(tmp_path / "b"),
                         trace=trace)
    assert srv_b.try_resume() and srv_b.round == 2
    hist_b = srv_b.run(2)
    assert hist_b == hist_a[2:]        # bit-exact continuation


# ---------------------------------------------------------------------------
# churn-aware async engine
# ---------------------------------------------------------------------------

def _acfg(**kw):
    kw.setdefault("total_arrivals", 24)
    kw.setdefault("concurrency", 6)
    kw.setdefault("buffer_size", 6)
    kw.setdefault("microbatch_window", 1e9)
    kw.setdefault("seed", 0)
    return AsyncConfig(**kw)


def test_async_churn_accounting_and_replay():
    data = _lin_list()
    trace = FleetTrace(seed=0, p_churn=0.3, latency=LognormalLatency(
        compute_median_s=10.0, network_mbps=20.0))

    def run():
        srv = AsyncFLServer(_lora_model(rank=16), _lora_loss, data,
                            _acfg(), CCFG,
                            FLoCoRAConfig(rank=16, alpha=16.0,
                                          quant_bits=8),
                            trace=trace)
        return srv, srv.run()

    srv, hist = run()
    last = hist[-1]
    assert last["n_arrived"] == 24     # churn never starves arrivals
    assert srv.n_churned > 0
    assert last["n_churned"] == srv.n_churned
    assert last["wasted_bytes"] > 0
    assert srv.wire.wasted == last["wasted_bytes"]
    # churned dispatches pulled replacement dispatches in (in-flight
    # remainder at shutdown is also counted)
    assert srv.n_dispatched >= 24 + srv.n_churned
    # deterministic replay: identical second run
    _, hist2 = run()
    assert hist == hist2


def test_async_population_lazy_end_to_end():
    """A 10k-client Population drives the async engine: O(cache) peak
    resident shards, tier-mixed ranks on the wire, loss improves."""
    pop = _pop(n=10_000, cache=32, tiers=TIERS)
    trace = PopulationTrace(seed=1, population=pop)
    srv = AsyncFLServer(_lora_model(rank=16), _lora_loss, pop,
                        _acfg(total_arrivals=30, concurrency=8,
                              buffer_size=10, seed=1),
                        CCFG,
                        FLoCoRAConfig(rank=16, alpha=16.0, quant_bits=8),
                        trace=trace)
    hist = srv.run()
    assert pop.peak_resident <= 32
    ranks = set()
    for h in hist:
        ranks |= {int(r) for r in h["flush_ranks"]}
    assert len(ranks) >= 2             # tier mix reached the wire
    assert hist[-1]["client_loss"] < hist[0]["client_loss"] * 1.2
    # in-flight state stayed O(concurrency)
    assert len(srv.inflight) <= 8


def test_population_rank_exceeding_server_rank_raises():
    pop = _pop(n=100, tiers=(DeviceTier("big", rank=32, fraction=1.0),))
    with pytest.raises(ValueError, match="exceeds the server rank"):
        AsyncFLServer(_lora_model(rank=16), _lora_loss, pop, _acfg(),
                      CCFG, FLoCoRAConfig(rank=16, alpha=16.0),
                      trace=PopulationTrace(seed=0, population=pop))


# ---------------------------------------------------------------------------
# DP noise-then-quantize uplinks
# ---------------------------------------------------------------------------

def test_dp_privatize_clips_and_is_keyed():
    tree = {"a": jnp.ones((8, 8)) * 5.0, "b": jnp.ones((4,)) * 3.0}
    cfg = DPConfig(clip_norm=1.0, noise_multiplier=0.0)
    clipped = dp_privatize(tree, cfg, seed=0, key=(0,))
    assert float(global_l2_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    # small trees pass through the clip untouched
    small = {"a": jnp.full((2,), 0.1)}
    out = dp_privatize(small, cfg, seed=0, key=(0,))
    np.testing.assert_allclose(np.asarray(out["a"]),
                               np.asarray(small["a"]), rtol=1e-6)
    # noise: pure function of (seed, key); distinct keys differ
    noisy = DPConfig(clip_norm=1.0, noise_multiplier=0.5)
    n1 = dp_privatize(tree, noisy, seed=0, key=(3, 7))
    n2 = dp_privatize(tree, noisy, seed=0, key=(3, 7))
    n3 = dp_privatize(tree, noisy, seed=0, key=(3, 8))
    assert all(np.array_equal(np.asarray(n1[k]), np.asarray(n2[k]))
               for k in n1)
    assert any(not np.array_equal(np.asarray(n1[k]), np.asarray(n3[k]))
               for k in n1)


def test_dp_error_feedback_incompatible():
    with pytest.raises(ValueError, match="error_feedback"):
        FLoCoRAConfig(rank=8, alpha=8.0, quant_bits=8,
                      error_feedback=True,
                      dp=DPConfig(clip_norm=1.0, noise_multiplier=0.5))
    # clip-only DP (no noise) composes with EF fine
    FLoCoRAConfig(rank=8, alpha=8.0, quant_bits=8, error_feedback=True,
                  dp=DPConfig(clip_norm=1.0, noise_multiplier=0.0))


def test_gaussian_epsilon_accountant():
    assert gaussian_epsilon(1.0, 0) == 0.0
    assert gaussian_epsilon(0.0, 10) == float("inf")
    e10 = gaussian_epsilon(1.0, 10)
    e100 = gaussian_epsilon(1.0, 100)
    assert 0 < e10 < e100              # more releases -> more epsilon
    assert gaussian_epsilon(2.0, 10) < e10   # more noise -> less


def test_dp_config_validation():
    with pytest.raises(ValueError):
        DPConfig(clip_norm=0.0)
    with pytest.raises(ValueError):
        DPConfig(noise_multiplier=-1.0)
    with pytest.raises(ValueError):
        DPConfig(delta=0.0)


def test_sync_dp_history_epsilon_and_learning():
    data = _lin_list()
    srv = _sync_server(data, dp=DPConfig(clip_norm=1.0,
                                         noise_multiplier=0.2))
    hist = srv.run(4)
    eps = [h["dp_epsilon"] for h in hist]
    assert all(np.isfinite(e) for e in eps)
    assert eps == sorted(eps) and eps[0] < eps[-1]   # accumulates
    # DP-noised training still learns on this task
    assert hist[-1]["client_loss"] < hist[0]["client_loss"] * 1.5


def test_async_dp_runs_and_reports_epsilon():
    """DP uplinks compose with the async engine (dispatch-unique dp_key
    keys every noise draw) and the flush history carries epsilon."""
    data = _lin_list(n_clients=3)
    trace = FleetTrace(seed=0, latency=LognormalLatency(
        compute_median_s=10.0, network_mbps=20.0))
    srv = AsyncFLServer(
        _lora_model(rank=16), _lora_loss, data,
        _acfg(total_arrivals=12, concurrency=3, buffer_size=12), CCFG,
        FLoCoRAConfig(rank=16, alpha=16.0, quant_bits=8,
                      dp=DPConfig(clip_norm=1.0, noise_multiplier=0.3)),
        trace=trace)
    hist = srv.run()
    assert "dp_epsilon" in hist[-1]
    assert np.isfinite(hist[-1]["dp_epsilon"])


# ---------------------------------------------------------------------------
# deadline cohorts (sync) over a trace
# ---------------------------------------------------------------------------

def test_sync_deadline_cohort_wasted_bytes():
    data = _lin_list()
    trace = FleetTrace(seed=3, latency=LognormalLatency(
        compute_median_s=10.0, network_mbps=20.0))
    srv = _sync_server(data, trace=trace)       # oversample=1.5: m > n
    hist = srv.run(3)
    assert all(h["n_agg"] == 4 for h in hist)
    assert any(h["n_straggled"] > 0 for h in hist)
    assert any(h["wasted_bytes"] > 0 for h in hist)
    # straggler waste is attributed in the shared WireAccounting
    assert srv.wire.wasted == sum(h["wasted_bytes"] for h in hist)
    # trace ordering is deterministic: same run, same stragglers
    srv2 = _sync_server(data, trace=trace)
    hist2 = srv2.run(3)
    assert [h["n_straggled"] for h in hist] == \
        [h["n_straggled"] for h in hist2]


# ---------------------------------------------------------------------------
# ACCEPTANCE (slow): bit-exact kill/resume of a population fleet run
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_fleet_resume_is_bit_exact(tmp_path):
    """ACCEPTANCE: a 100k-client Population FedBuff run (churn, diurnal
    tiers, DP uplinks) killed mid-run and resumed from its checkpoint
    reproduces the uninterrupted history AND final tree bit-exactly."""
    d_a, d_b = str(tmp_path / "a"), str(tmp_path / "b")

    def build(d):
        pop = _pop(n=100_000, seed=1, cache=64, tiers=TIERS)
        trace = PopulationTrace(seed=1, population=pop)
        acfg = AsyncConfig(total_arrivals=60, concurrency=16,
                           buffer_size=10, streaming_agg=True,
                           microbatch_window=1200.0, seed=1,
                           checkpoint_dir=d, checkpoint_every=1)
        fcfg = FLoCoRAConfig(rank=16, alpha=16.0, quant_bits=8,
                             dp=DPConfig(clip_norm=1.0,
                                         noise_multiplier=0.3))
        return pop, AsyncFLServer(_lora_model(rank=16), _lora_loss, pop,
                                  acfg, CCFG, fcfg, trace=trace)

    pop_a, srv_a = build(d_a)
    hist_a = srv_a.run()
    assert srv_a.n_churned > 0         # churn actually engaged
    assert pop_a.peak_resident <= 64   # O(active), not O(fleet)
    # "kill": keep only the OLDEST surviving checkpoint in a copy
    os.makedirs(d_b)
    for fn in os.listdir(d_a):
        shutil.copy(os.path.join(d_a, fn), d_b)
    steps = sorted(int(f[5:-5]) for f in os.listdir(d_b)
                   if f.endswith(".json"))
    assert len(steps) >= 2
    for s in steps[1:]:
        for ext in (".npz", ".json"):
            os.remove(os.path.join(d_b, f"ckpt_{s:08d}{ext}"))

    _, srv_b = build(d_b)
    assert srv_b.try_resume()
    assert srv_b.n_flushes == steps[0] < srv_a.n_flushes
    hist_b = srv_b.run()
    assert hist_a == hist_b            # bit-exact: dict/float equality
    for a, b in zip(jax.tree.leaves(jax.device_get(srv_a.global_train)),
                    jax.tree.leaves(jax.device_get(srv_b.global_train))):
        assert np.array_equal(np.asarray(a), np.asarray(b))
