"""Wire-true packed codec + Aggregator strategies + packed FL round.

Covers the acceptance contract of the packed pipeline:
  * pack->unpack identity vs the fp ``encode``/``decode`` oracle;
  * serialized wire size MEASURED from real buffers == the static
    ``message_wire_bytes`` accounting for bits in {8, 4, 2};
  * packed-path Aggregator == the fp ``fedavg_quantized`` reference;
  * ``FLServer`` exchanges packed payloads end-to-end (fast tiny-model
    twin of the slow-marked resnet system tests), incl. exact
    checkpoint/resume with the JSON RNG state.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregation, flocora, messages
from repro.core.aggregation import ErrorFeedbackFedAvg, FedAvgAggregator, \
    FedBuffAggregator
from repro.core.flocora import FLoCoRAConfig
from repro.core.quant import QuantConfig
from repro.fl import ClientConfig, FLServer, ServerConfig


def _tree(key, scale=1.0):
    ks = jax.random.split(key, 4)
    return {"a": jax.random.normal(ks[0], (6, 8)) * scale,
            "b": jax.random.normal(ks[1], (4, 3, 5)) * scale,
            "odd": jax.random.normal(ks[2], (7, 3)) * scale,
            "norm": jax.random.normal(ks[3], (7,)) * scale}


# ---------------------------------------------------------------------------
# codec
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [2, 4, 8])
def test_pack_unpack_matches_fp_oracle(bits):
    t = _tree(jax.random.PRNGKey(0), 2.0)
    cfg = QuantConfig(bits=bits)
    got = messages.unpack_message(messages.pack_message(t, cfg))
    ref = messages.roundtrip(t, cfg)
    for k in t:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(ref[k]),
                                   atol=1e-6)
    # 1-D leaves pass through untouched
    np.testing.assert_array_equal(np.asarray(got["norm"]),
                                  np.asarray(t["norm"]))


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_jnp_twin_matches_kernel_path(bits):
    t = _tree(jax.random.PRNGKey(1))
    cfg = QuantConfig(bits=bits)
    a = messages.unpack_message(messages.pack_message(t, cfg))
    b = messages.unpack_message(
        messages.pack_message(t, cfg, use_kernel=False))
    for k in t:
        np.testing.assert_allclose(np.asarray(a[k]), np.asarray(b[k]),
                                   atol=1e-6)


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("per_stack", [False, True])
def test_packed_wire_bytes_match_static_accounting(bits, per_stack):
    """Real serialized buffer sizes == the shape-math accounting."""
    t = _tree(jax.random.PRNGKey(2))
    cfg = QuantConfig(bits=bits, per_stack=per_stack)
    msg = messages.pack_message(t, cfg)
    assert messages.packed_wire_bytes(msg) == \
        messages.message_wire_bytes(t, cfg)


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_wire_serialization_roundtrip(bits):
    """to_wire -> from_wire reproduces the payload words byte-exactly."""
    t = _tree(jax.random.PRNGKey(3))
    msg = messages.pack_message(t, QuantConfig(bits=bits))
    for k in ("a", "b", "odd"):
        leaf = msg[k]
        bufs = leaf.to_wire()
        assert bufs["payload"].dtype == np.uint8
        assert bufs["payload"].nbytes == \
            (int(np.prod(leaf.shape)) * bits + 7) // 8
        back = messages.PackedLeaf.from_wire(bufs, leaf.shape, leaf.dtype,
                                             bits)
        np.testing.assert_array_equal(np.asarray(back.payload),
                                      np.asarray(leaf.payload))


# ---------------------------------------------------------------------------
# aggregation strategies
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [2, 4, 8])
def test_packed_fedavg_equals_fp_reference(bits):
    """Fused dequant_agg path == fedavg_quantized (fp roundtrip) ref."""
    trees = [_tree(jax.random.PRNGKey(i)) for i in range(5)]
    w = jnp.asarray([1.0, 2.0, 3.0, 1.5, 0.5])
    qcfg = QuantConfig(bits=bits)
    ref = aggregation.fedavg_quantized(aggregation.stack_trees(trees), w,
                                       qcfg)
    msgs = [messages.pack_message(t, qcfg) for t in trees]
    got = FedAvgAggregator(qcfg).aggregate(msgs, w)
    for k in ref:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(ref[k]),
                                   rtol=1e-5, atol=1e-5)


def test_fedavg_aggregator_fp_path():
    trees = [_tree(jax.random.PRNGKey(i)) for i in range(3)]
    w = jnp.asarray([1.0, 2.0, 1.0])
    got = FedAvgAggregator(QuantConfig()).aggregate(trees, w)
    ref = aggregation.fedavg(aggregation.stack_trees(trees), w)
    for k in ref:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(ref[k]),
                                   rtol=1e-6)


def test_fedbuff_aggregator_uniform_equals_fedavg():
    """With zero staleness the buffered rule reduces to FedAvg."""
    trees = [_tree(jax.random.PRNGKey(i)) for i in range(3)]
    w = jnp.asarray([1.0, 3.0, 2.0])
    got = FedBuffAggregator().aggregate(trees, w)
    ref = aggregation.fedavg(aggregation.stack_trees(trees), w)
    for k in ref:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(ref[k]),
                                   rtol=1e-5, atol=1e-6)


def test_ef_packed_uplink_preserves_dtype():
    """EF compensates in fp32 but the wire message must advertise the
    ORIGINAL adapter dtypes (and the aggregate must come back in them)."""
    cfg = FLoCoRAConfig(quant_bits=8, error_feedback=True)
    x = {"w": (jax.random.normal(jax.random.PRNGKey(0), (4, 64))
               ).astype(jnp.bfloat16),
         "norm": jnp.ones((5,), jnp.bfloat16)}
    msg, _ = flocora.client_uplink(x, cfg, None)
    out = messages.unpack_message(msg)
    assert out["w"].dtype == jnp.bfloat16
    assert out["norm"].dtype == jnp.bfloat16
    agg = FedAvgAggregator(cfg.qcfg).aggregate([msg, msg], jnp.ones(2))
    assert agg["w"].dtype == jnp.bfloat16


def test_aggregator_rejects_mismatched_bits():
    t = _tree(jax.random.PRNGKey(0))
    msgs = [messages.pack_message(t, QuantConfig(bits=4))]
    with pytest.raises(ValueError):
        FedAvgAggregator(QuantConfig(bits=8)).aggregate(msgs, jnp.ones(1))


def test_ef_packed_uplink_reduces_bias():
    """EF over the PACKED codec: time-averaged error decays vs RTN."""
    cfg = FLoCoRAConfig(quant_bits=2, error_feedback=True)
    x = {"w": jax.random.normal(jax.random.PRNGKey(0), (4, 64)) * 0.7}
    res, acc = None, jnp.zeros_like(x["w"])
    n = 16
    for _ in range(n):
        msg, res = flocora.client_uplink(x, cfg, res)
        acc = acc + messages.unpack_message(msg)["w"]
    bias_ef = float(jnp.mean(jnp.abs(acc / n - x["w"])))
    bias_rtn = float(jnp.mean(jnp.abs(
        messages.roundtrip(x, cfg.qcfg)["w"] - x["w"])))
    assert bias_ef < bias_rtn * 0.7 or bias_ef < 1e-3


# ---------------------------------------------------------------------------
# packed FL round end-to-end (tiny model; fast twin of the slow system tests)
# ---------------------------------------------------------------------------

def _tiny_setup(n=96, n_clients=4, seed=0):
    rng = np.random.default_rng(seed)
    w_true = rng.normal(size=(16, 10)).astype(np.float32)
    x = rng.normal(size=(n, 16)).astype(np.float32)
    y = np.argmax(x @ w_true + 0.1 * rng.normal(size=(n, 10)), axis=1)
    parts = np.array_split(rng.permutation(n), n_clients)
    data = [{"x": x[p], "y": y[p].astype(np.int32)} for p in parts]
    model = {"frozen": {"mu": jnp.zeros((16,))},
             "train": {"w": jnp.asarray(0.01 * rng.normal(size=(16, 10)),
                                        jnp.float32),
                       "b": jnp.zeros((10,), jnp.float32)}}
    return data, model


def _tiny_loss(frozen, train, batch):
    logits = (batch["x"] - frozen["mu"]) @ train["w"] + train["b"]
    logp = jax.nn.log_softmax(logits)
    loss = -jnp.mean(jnp.take_along_axis(logp, batch["y"][:, None],
                                         axis=1))
    return loss, {}


def _tiny_server(data, model, tmpdir=None, **fl_kw):
    return FLServer(
        model, _tiny_loss, data,
        ServerConfig(rounds=3, n_clients=len(data), clients_per_round=2,
                     checkpoint_dir=tmpdir, checkpoint_every=1, **fl_kw),
        ClientConfig(local_epochs=1, batch_size=8, lr=0.1),
        FLoCoRAConfig(rank=8, alpha=128.0, quant_bits=8))


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_server_round_packed_end_to_end(bits):
    """Uplink bytes measured from real buffers == static accounting, and
    the round trains (cohort engine + packed aggregation)."""
    data, model = _tiny_setup()
    srv = FLServer(
        model, _tiny_loss, data,
        ServerConfig(rounds=3, n_clients=4, clients_per_round=2),
        ClientConfig(local_epochs=2, batch_size=8, lr=0.2),
        FLoCoRAConfig(rank=8, alpha=128.0, quant_bits=bits))
    hist = srv.run(3)
    expected = messages.message_wire_bytes(srv.global_train, srv.fcfg.qcfg)
    assert all(h["up_bytes_measured"] == expected for h in hist)
    assert np.isfinite(hist[-1]["client_loss"])
    assert hist[-1]["client_loss"] < hist[0]["client_loss"] * 1.5


def test_server_tcc_includes_initial_model():
    """TCC sums MEASURED per-client message bytes over the fleet (each
    round: K clients x (down + up)), plus the shared-once initial model."""
    data, model = _tiny_setup()
    srv = _tiny_server(data, model)
    hist = srv.run(2)
    k = 2                                      # clients_per_round, no drop
    assert hist[0]["round_bytes"] == k * srv.round_bytes_per_client
    assert hist[0]["tcc_bytes"] == \
        srv.initial_model_bytes + k * srv.round_bytes_per_client
    assert hist[1]["tcc_bytes"] == \
        srv.initial_model_bytes + 2 * k * srv.round_bytes_per_client
    # cumulative over history: init + running sum of per-round bytes
    assert hist[1]["tcc_bytes"] == srv.initial_model_bytes + \
        sum(h["round_bytes"] for h in hist)


def test_server_checkpoint_resume_exact_with_json_rng(tmp_path):
    """Resume restores adapters AND the sampler RNG (JSON bit-generator
    state): the next round replays identically on both servers."""
    data, model = _tiny_setup()
    srv = _tiny_server(data, model, tmpdir=str(tmp_path))
    srv.run(2)
    srv2 = _tiny_server(data, model, tmpdir=str(tmp_path))
    assert srv2.try_resume()
    assert srv2.round == srv.round
    assert srv2.rng.bit_generator.state == srv.rng.bit_generator.state
    for a, b in zip(jax.tree.leaves(jax.device_get(srv.global_train)),
                    jax.tree.leaves(jax.device_get(srv2.global_train))):
        np.testing.assert_allclose(a, b, rtol=1e-6)
    r1, r2 = srv.run_round(), srv2.run_round()
    assert r1["client_loss"] == pytest.approx(r2["client_loss"], rel=1e-6)


def test_server_rejects_mismatched_ef_aggregator():
    """error_feedback and the aggregator type must agree (a mismatch
    would silently disable EF or maintain dead residuals)."""
    data, model = _tiny_setup()
    with pytest.raises(ValueError):
        FLServer(model, _tiny_loss, data,
                 ServerConfig(n_clients=4, clients_per_round=2),
                 ClientConfig(),
                 FLoCoRAConfig(quant_bits=4, error_feedback=True),
                 aggregator=FedAvgAggregator(QuantConfig(bits=4)))
    with pytest.raises(ValueError):
        FLServer(model, _tiny_loss, data,
                 ServerConfig(n_clients=4, clients_per_round=2),
                 ClientConfig(),
                 FLoCoRAConfig(quant_bits=4),
                 aggregator=ErrorFeedbackFedAvg(QuantConfig(bits=4)))


def test_server_error_feedback_aggregator_selected():
    data, model = _tiny_setup()
    srv = FLServer(
        model, _tiny_loss, data,
        ServerConfig(rounds=2, n_clients=4, clients_per_round=2),
        ClientConfig(local_epochs=1, batch_size=8, lr=0.1),
        FLoCoRAConfig(rank=8, alpha=128.0, quant_bits=4,
                      error_feedback=True))
    assert isinstance(srv.aggregator, ErrorFeedbackFedAvg)
    srv.run(2)
    assert len(srv.aggregator.residuals) >= 1
    assert np.isfinite(srv.history[-1]["client_loss"])
