"""Client-side local training (paper §IV setup).

Defaults match the paper: SGD momentum 0.9, lr 0.01, batch 32, 5 local
epochs. Two execution engines over the same local-run body:

  * ``make_local_trainer`` — one client per call; jits ONCE per
    (model, batch-shape) and is reused by every simulated client;
  * ``make_cohort_trainer`` — the VMAPPED COHORT ENGINE: K clients'
    local runs batch into ONE jitted program over stacked
    (K, steps, B, ...) batches. The K local scans execute as a single
    vectorized program — on accelerators every matmul carries the extra
    K dim instead of K sequential dispatches (see
    benchmarks/round_throughput.py for the clients/sec win).

Batches are pre-gathered host-side (``stack_local_batches`` /
``stack_cohort_batches``) and each local run is a lax.scan.

``fedprox_mu`` adds the FedProx proximal term — demonstrating the paper's
aggregation-agnostic claim (FLoCoRA composes with any FL optimizer
unchanged, §III).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import sgd

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ClientConfig:
    local_epochs: int = 5
    batch_size: int = 32
    lr: float = 0.01
    momentum: float = 0.9
    fedprox_mu: float = 0.0


def _local_run(loss_fn: Callable, cfg: ClientConfig):
    """Un-jitted single-client local run, shared by both engines.

    ``run(frozen, train0, batches) -> (train, mean_loss)`` where batches
    is a pytree with leading (steps, B) dims."""
    opt = sgd(momentum=cfg.momentum)

    def run(frozen, train0, batches):
        opt_state = opt.init(train0)

        def grad_loss(train, batch):
            loss, _ = loss_fn(frozen, train, batch)
            if cfg.fedprox_mu > 0.0:
                prox = sum(jnp.sum(jnp.square(
                    a.astype(jnp.float32) - b.astype(jnp.float32)))
                    for a, b in zip(jax.tree.leaves(train),
                                    jax.tree.leaves(train0)))
                loss = loss + 0.5 * cfg.fedprox_mu * prox
            return loss

        def step(carry, batch):
            train, opt_state = carry
            loss, grads = jax.value_and_grad(grad_loss)(train, batch)
            train, opt_state = opt.update(grads, opt_state, train, cfg.lr)
            return (train, opt_state), loss

        (train, _), losses = jax.lax.scan(step, (train0, opt_state), batches)
        return train, jnp.mean(losses)

    return run


def make_local_trainer(loss_fn: Callable, cfg: ClientConfig):
    """loss_fn(frozen, train, batch) -> (loss, metrics).

    Returns ``run(frozen, train0, batches) -> (train, mean_loss)``.
    Jitted once; sequential-baseline engine (one client per call)."""
    return jax.jit(_local_run(loss_fn, cfg))


def _masked_local_run(loss_fn: Callable, cfg: ClientConfig):
    """Single-client local run over a FIXED-length schedule with a
    per-client active step count: steps past ``n_steps`` are no-ops
    (params, momentum and loss untouched), so heterogeneous clients
    batch into one program without training small clients past their
    own local_epochs."""
    opt = sgd(momentum=cfg.momentum)

    def run(frozen, train0, batches, n_steps):
        opt_state = opt.init(train0)

        def grad_loss(train, batch):
            loss, _ = loss_fn(frozen, train, batch)
            if cfg.fedprox_mu > 0.0:
                prox = sum(jnp.sum(jnp.square(
                    a.astype(jnp.float32) - b.astype(jnp.float32)))
                    for a, b in zip(jax.tree.leaves(train),
                                    jax.tree.leaves(train0)))
                loss = loss + 0.5 * cfg.fedprox_mu * prox
            return loss

        def step(carry, inp):
            t, batch = inp
            train, opt_state = carry
            loss, grads = jax.value_and_grad(grad_loss)(train, batch)
            train2, opt2 = opt.update(grads, opt_state, train, cfg.lr)
            active = t < n_steps
            keep = lambda new, old: jax.tree.map(
                lambda a, b: jnp.where(active, a, b), new, old)
            return ((keep(train2, train), keep(opt2, opt_state)),
                    jnp.where(active, loss, 0.0))

        ts = jnp.arange(jax.tree.leaves(batches)[0].shape[0])
        (train, _), losses = jax.lax.scan(step, (train0, opt_state),
                                          (ts, batches))
        return train, jnp.sum(losses) / jnp.maximum(n_steps, 1)

    return run


def make_cohort_trainer(loss_fn: Callable, cfg: ClientConfig):
    """Vmapped cohort engine: K clients in one jitted program.

    Returns ``run(frozen, train0, batches, n_steps) -> (trained, losses)``
    where batches has leading (K, steps, B) dims, ``n_steps`` is the (K,)
    per-client active step count (masked no-ops beyond it), ``trained``
    leaves carry a leading K dim and ``losses`` is (K,).
    ``frozen``/``train0`` are shared (broadcast state) across the cohort.
    Compilation caches on (K, steps, B, ...): keep the schedule length
    fixed across rounds (see FLServer) so only distinct cohort sizes K
    retrace."""
    return jax.jit(jax.vmap(_masked_local_run(loss_fn, cfg),
                            in_axes=(None, None, 0, 0)))


def make_staggered_cohort_trainer(loss_fn: Callable, cfg: ClientConfig):
    """Async cohort engine: like ``make_cohort_trainer`` but ``train0``
    carries a leading K dim — each client starts from its OWN adapter
    tree (asynchronous arrivals trained from different global versions
    batch into one program; see fl/async_engine.py).

    Compilation caches on (adapter shapes, K, steps, B): the async
    engine groups arrivals by rank and pads each group's client dim to a
    pow2, so the compiled-program count stays bounded by
    #distinct-ranks x log2(max micro-batch)."""
    return jax.jit(jax.vmap(_masked_local_run(loss_fn, cfg),
                            in_axes=(None, 0, 0, 0)))


def stack_local_batches(rng: np.random.Generator, data: dict,
                        cfg: ClientConfig,
                        steps: Optional[int] = None) -> dict:
    """Host-side: pack a client's dataset into (steps, B, ...) batches,
    reshuffling each local epoch (with wraparound padding).

    ``steps`` overrides the natural step count (epochs are repeated /
    truncated to exactly that many batches) — the cohort engine equalizes
    step counts across clients this way."""
    n = len(next(iter(data.values())))
    per_epoch = max(1, n // cfg.batch_size)
    total = per_epoch * cfg.local_epochs if steps is None else steps
    idx_all = []
    got = 0
    while got < total:
        idx = rng.permutation(n)
        take = per_epoch * cfg.batch_size
        if take > n:
            idx = np.concatenate([idx, rng.integers(0, n, take - n)])
        idx_all.append(idx[:take].reshape(per_epoch, cfg.batch_size))
        got += per_epoch
    idx_all = np.concatenate(idx_all, axis=0)[:total]
    return {k: v[idx_all] for k, v in data.items()}


def natural_steps(data: dict, cfg: ClientConfig) -> int:
    """One client's paper-faithful local schedule length."""
    n = len(next(iter(data.values())))
    return max(1, n // cfg.batch_size) * cfg.local_epochs


def cohort_steps(datas: list[dict], cfg: ClientConfig) -> int:
    """Fixed schedule length for a cohort engine program: the largest
    client's natural schedule. Clients with fewer steps are MASKED past
    their own count (see make_cohort_trainer), not over-trained."""
    return max(natural_steps(d, cfg) for d in datas)


def pow2_pad(k: int) -> int:
    """Next power of two >= k. The rank-bucketed engine pads each
    bucket's client dim to a pow2 so the per-bucket compiled-program
    count is bounded by #distinct-ranks x log2(max cohort) instead of
    #ranks x #bucket-sizes."""
    p = 1
    while p < k:
        p *= 2
    return p


def pad_cohort_batches(batches: dict, n_steps: np.ndarray, k_pad: int
                       ) -> tuple[dict, np.ndarray]:
    """Pad the leading client dim of a stacked cohort to ``k_pad`` by
    repeating client 0's batches with ``n_steps = 0``: padded rows run
    fully masked (no parameter updates) and their outputs are
    discarded."""
    k = int(n_steps.shape[0])
    if k_pad <= k:
        return batches, n_steps
    reps = k_pad - k
    out = {key: np.concatenate([v, np.repeat(v[:1], reps, axis=0)],
                               axis=0)
           for key, v in batches.items()}
    return out, np.concatenate([n_steps,
                                np.zeros(reps, np.int32)]).astype(np.int32)


def stack_cohort_batches(rng: np.random.Generator, datas: list[dict],
                         cfg: ClientConfig,
                         steps: Optional[int] = None
                         ) -> tuple[dict, np.ndarray]:
    """Host-side: gather K clients' local schedules into one
    (K, steps, B, ...) stack for the cohort engine.

    Returns (stacked batches, (K,) int32 per-client active step counts).
    Pass a server-wide ``steps`` (>= every client's natural count) to pin
    the compiled program shape across rounds."""
    if steps is None:
        steps = cohort_steps(datas, cfg)
    n_steps = np.asarray([min(natural_steps(d, cfg), steps)
                          for d in datas], np.int32)
    per = [stack_local_batches(rng, d, cfg, steps=steps) for d in datas]
    return ({k: np.stack([p[k] for p in per], axis=0) for k in per[0]},
            n_steps)
