"""Quickstart: FLoCoRA (paper Fig. 1) in ~40 lines.

Federates a ResNet-8 over 20 clients on a synthetic CIFAR-like task,
exchanging int8-quantized LoRA adapters, and prints the communication
saving vs FedAvg (paper Tables I/III).

``--hetero`` runs the heterogeneous fleet instead: 10 clients in three
rank tiers (r in {4, 8, 16} — phones, laptops, workstations), trained
end-to-end by the rank-bucketed engine with per-client truncated
broadcasts and measured mixed-rank TCC.

``--async`` drops round lockstep entirely: the same three-tier fleet
runs through the EVENT-DRIVEN FedBuff engine (fl/async_engine.py) — a
virtual clock schedules each client's dispatch/arrival from a lognormal
latency trace, arrivals buffer with staleness-discounted weights, and
every ``--buffer`` arrivals flush into a new global version. Prints the
per-version (virtual time, loss, staleness, TCC) trajectory.

``--sparse`` runs the FLASC-style sparse-delta uplink (core/sparse.py):
clients top-k sparsify their adapter deltas to 10% density, survivors
quantize to 4 bits, and error feedback re-ships each round's dropped
mass — prints fp32 vs int4 vs int4+10% message sizes and the asymmetric
down/up byte trajectory.

``--dp [NOISE]`` privatizes the uniform quickstart's uplinks: each
client's adapter delta is clipped to L2 norm 1 and Gaussian-noised at
``NOISE`` x clip (default 0.3) BEFORE int8 quantization
(core/quant.DPConfig — quantization is post-processing, so the wire is
already private), and every round's history row carries the cumulative
``dp_epsilon`` spent.

    PYTHONPATH=src python examples/quickstart.py [--rounds 10] \
        [--hetero | --async [--arrivals 90] | --sparse [--density 0.1] \
         | --dp [0.3]]
"""
import argparse
import sys

import jax
import numpy as np

sys.path.insert(0, "src")

from repro.core import messages
from repro.core.flocora import FLoCoRAConfig, RankSchedule
from repro.core.lora import LoRAConfig
from repro.core.quant import QuantConfig
from repro.data import SyntheticVision, lda_partition
from repro.fl import ClientConfig, FLServer, ServerConfig
from repro.models.resnet import ResNetConfig, init as resnet_init, loss_fn


def run_uniform(rounds: int, dp_noise=None):
    # data: 20 clients worth of non-IID (LDA 0.5) synthetic images
    rng = np.random.default_rng(0)
    sv = SyntheticVision(seed=0)
    y = rng.integers(0, 10, 2000)
    x = sv.sample(rng, y)
    parts = lda_partition(y, 20, alpha=0.5)
    data = [{"x": x[p], "y": y[p].astype(np.int32)} for p in parts]

    # model: frozen random ResNet-8 + rank-32 adapters (alpha = 16r)
    cfg = ResNetConfig(arch="resnet8", lora=LoRAConfig(rank=32, alpha=512.0))
    model = resnet_init(jax.random.PRNGKey(0), cfg)

    fedavg_bytes = messages.message_wire_bytes(
        resnet_init(jax.random.PRNGKey(0),
                    ResNetConfig(arch="resnet8", mode="fedavg"))["train"],
        QuantConfig())
    flocora_bytes = messages.message_wire_bytes(model["train"],
                                                QuantConfig(bits=8))
    print(f"message: FedAvg {fedavg_bytes/1e6:.2f} MB -> FLoCoRA+int8 "
          f"{flocora_bytes/1e6:.3f} MB "
          f"({fedavg_bytes/flocora_bytes:.1f}x smaller)")

    dp = None
    if dp_noise is not None:
        from repro.core.quant import DPConfig
        dp = DPConfig(clip_norm=1.0, noise_multiplier=dp_noise)
        print(f"dp: clip L2 to {dp.clip_norm}, noise {dp.noise_multiplier}"
              f" x clip before int8 quantization (delta={dp.delta:g})")
    server = FLServer(
        model, lambda f, t, b: loss_fn(f, t, cfg, b), data,
        ServerConfig(rounds=rounds, n_clients=20, clients_per_round=5),
        ClientConfig(local_epochs=1, batch_size=32, lr=0.01),
        FLoCoRAConfig(rank=32, alpha=512.0, quant_bits=8, dp=dp))
    for h in server.run():
        print(h)


def run_hetero(rounds: int):
    """Mixed-rank fleet: 10 clients in three rank tiers, end-to-end."""
    from repro.core import flocora

    rng = np.random.default_rng(0)
    sv = SyntheticVision(seed=0)
    y = rng.integers(0, 10, 1000)
    x = sv.sample(rng, y)
    parts = lda_partition(y, 10, alpha=0.5)
    data = [{"x": x[p], "y": y[p].astype(np.int32)} for p in parts]

    # three device classes: phones r=4, laptops r=8, workstations r=16;
    # the server holds rank-16 globals and truncates each broadcast
    sched = RankSchedule.tiered((4, 8, 16), n_clients=10)
    cfg = ResNetConfig(arch="resnet8", lora=LoRAConfig(rank=16, alpha=256.0))
    model = resnet_init(jax.random.PRNGKey(0), cfg)
    fcfg = FLoCoRAConfig(rank=16, alpha=256.0, quant_bits=8,
                         rank_schedule=sched)

    for r in (4, 8, 16):
        kb = flocora.client_wire_bytes(model["train"], fcfg, r) / 1e3
        n = sum(1 for cr in sched.client_ranks if cr == r)
        print(f"tier r={r:2d}: {n} clients, {kb:7.1f} kB one-way")

    server = FLServer(
        model, lambda f, t, b: loss_fn(f, t, cfg, b), data,
        ServerConfig(rounds=rounds, n_clients=10, clients_per_round=6),
        ClientConfig(local_epochs=1, batch_size=32, lr=0.01),
        fcfg)
    for h in server.run():
        print({k: h[k] for k in ("round", "n_agg", "client_loss",
                                 "cohort_ranks", "round_bytes",
                                 "tcc_bytes") if k in h})


def run_async(arrivals: int, buffer_size: int):
    """Three-tier fleet, no rounds: event-driven staleness-aware FedBuff
    over the packed wire, on a virtual clock."""
    from repro.core import flocora
    from repro.fl import AsyncConfig, AsyncFLServer, AvailabilityWindows, \
        FleetTrace, LognormalLatency, time_to_target

    rng = np.random.default_rng(0)
    sv = SyntheticVision(seed=0)
    y = rng.integers(0, 10, 1000)
    x = sv.sample(rng, y)
    parts = lda_partition(y, 12, alpha=0.5)
    data = [{"x": x[p], "y": y[p].astype(np.int32)} for p in parts]

    sched = RankSchedule.tiered((4, 8, 16), n_clients=12)
    cfg = ResNetConfig(arch="resnet8", lora=LoRAConfig(rank=16, alpha=256.0))
    model = resnet_init(jax.random.PRNGKey(0), cfg)
    fcfg = FLoCoRAConfig(rank=16, alpha=256.0, quant_bits=8,
                         rank_schedule=sched)
    # phones train ~45 s (median, heavier tiers longer), uplink over a
    # jittery 20 Mb/s link, and each client is only available 80% of a
    # 10-minute duty cycle
    trace = FleetTrace(seed=0,
                       latency=LognormalLatency(compute_median_s=45.0,
                                                network_mbps=20.0),
                       availability=AvailabilityWindows(period_s=600.0,
                                                       duty=0.8))
    for r in (4, 8, 16):
        kb = flocora.client_wire_bytes(model["train"], fcfg, r) / 1e3
        print(f"tier r={r:2d}: {kb:7.1f} kB one-way")

    srv = AsyncFLServer(
        model, lambda f, t, b: loss_fn(f, t, cfg, b), data,
        AsyncConfig(total_arrivals=arrivals, concurrency=6,
                    buffer_size=buffer_size, half_life=4.0,
                    microbatch_window=60.0, seed=0),
        ClientConfig(local_epochs=1, batch_size=32, lr=0.01),
        fcfg, trace=trace)
    for h in srv.run():
        print({k: (round(v, 4) if isinstance(v, float) else v)
               for k, v in h.items()
               if k in ("version", "t_virtual", "n_arrived", "client_loss",
                        "staleness_mean", "flush_ranks", "tcc_bytes")})
    last = srv.history[-1]
    print(f"virtual {last['t_virtual'] / 60:.1f} min, "
          f"{last['tcc_bytes'] / 1e6:.2f} MB total")
    hit = time_to_target(srv.history, "client_loss",
                         1.5 * last["client_loss"])
    if hit:
        print(f"reached 1.5x final loss at {hit['t_virtual'] / 60:.1f} "
              f"min / {hit['tcc_bytes'] / 1e6:.2f} MB")


def run_sparse(rounds: int, density: float):
    """Sparse-delta uplink: top-k 10%-density 4-bit adapters with error
    feedback, over the same 20-client fleet as the uniform quickstart."""
    from repro.core.sparse import SparsityConfig

    rng = np.random.default_rng(0)
    sv = SyntheticVision(seed=0)
    y = rng.integers(0, 10, 2000)
    x = sv.sample(rng, y)
    parts = lda_partition(y, 20, alpha=0.5)
    data = [{"x": x[p], "y": y[p].astype(np.int32)} for p in parts]

    cfg = ResNetConfig(arch="resnet8", lora=LoRAConfig(rank=32, alpha=512.0))
    model = resnet_init(jax.random.PRNGKey(0), cfg)
    fcfg = FLoCoRAConfig(rank=32, alpha=512.0, quant_bits=4,
                         error_feedback=True,
                         sparsity=SparsityConfig(density=density))

    fp = messages.message_wire_bytes(model["train"], QuantConfig())
    q4 = messages.message_wire_bytes(model["train"], QuantConfig(bits=4))
    sp = messages.message_wire_bytes(model["train"], QuantConfig(bits=4),
                                     density)
    print(f"uplink: fp32 {fp / 1e3:.1f} kB -> int4 {q4 / 1e3:.1f} kB "
          f"-> int4+top-k({density:.0%}) {sp / 1e3:.1f} kB "
          f"({fp / sp:.1f}x smaller; EF re-ships the dropped mass)")

    server = FLServer(
        model, lambda f, t, b: loss_fn(f, t, cfg, b), data,
        ServerConfig(rounds=rounds, n_clients=20, clients_per_round=5),
        ClientConfig(local_epochs=1, batch_size=32, lr=0.01),
        fcfg)
    for h in server.run():
        print({k: h[k] for k in ("round", "n_agg", "client_loss",
                                 "uplink_density", "down_bytes",
                                 "up_bytes", "tcc_bytes") if k in h})
    hist = server.history
    print(f"round bytes down/up: {hist[-1]['down_bytes']} / "
          f"{hist[-1]['up_bytes']} "
          f"(dense wire would up {hist[-1]['down_bytes']})")
    # sanity: measured uplink == static sparse accounting
    assert hist[-1]["up_bytes_measured"] == sp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--hetero", action="store_true",
                    help="mixed-rank cohort (10 clients, 3 rank tiers)")
    ap.add_argument("--async", dest="async_", action="store_true",
                    help="event-driven FedBuff fleet (virtual clock)")
    ap.add_argument("--sparse", action="store_true",
                    help="FLASC-style top-k sparse uplink with EF")
    ap.add_argument("--density", type=float, default=0.1,
                    help="sparse: fraction of adapter entries uplinked")
    ap.add_argument("--arrivals", type=int, default=90,
                    help="async: total virtual arrivals")
    ap.add_argument("--buffer", type=int, default=6,
                    help="async: FedBuff buffer size")
    ap.add_argument("--dp", type=float, nargs="?", const=0.3,
                    default=None, metavar="NOISE",
                    help="uniform quickstart with DP uplinks: clip + "
                         "Gaussian noise at NOISE x clip (default 0.3)")
    args = ap.parse_args()
    if args.sparse and not 0.0 < args.density <= 1.0:
        ap.error("--density must be in (0, 1]")
    if args.sparse:
        run_sparse(args.rounds, args.density)
    elif args.async_:
        run_async(args.arrivals, args.buffer)
    elif args.hetero:
        run_hetero(args.rounds)
    else:
        run_uniform(args.rounds, dp_noise=args.dp)


if __name__ == "__main__":
    main()
