"""The paper's own models: ResNet-8 / ResNet-18 (CIFAR) with FLoCoRA."""
from repro.core.lora import LoRAConfig
from repro.models.resnet import ResNetConfig


def resnet8(rank: int = 32, alpha: float = None, mode: str = "flocora",
            **kw) -> ResNetConfig:
    return ResNetConfig(arch="resnet8", mode=mode,
                        lora=LoRAConfig(rank=rank,
                                        alpha=alpha or 16.0 * rank), **kw)


def resnet18(rank: int = 32, alpha: float = None, mode: str = "flocora",
             **kw) -> ResNetConfig:
    return ResNetConfig(arch="resnet18", mode=mode,
                        lora=LoRAConfig(rank=rank,
                                        alpha=alpha or 16.0 * rank), **kw)
