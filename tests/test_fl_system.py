"""End-to-end FL system behaviour: learning, fault tolerance, resume,
elastic re-mesh, FedProx composability (the paper's aggregation-agnostic
claim)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, save, restore, latest_step
from repro.core.flocora import FLoCoRAConfig
from repro.core.lora import LoRAConfig
from repro.data import SyntheticVision, lda_partition
from repro.fl import ClientConfig, FLServer, ServerConfig
from repro.fl.elastic import elastic_restore
from repro.models.resnet import ResNetConfig, init as rinit, loss_fn


def _setup(n=400, n_clients=8, alpha=0.5):
    rng = np.random.default_rng(0)
    sv = SyntheticVision(seed=0)
    y = rng.integers(0, 10, n)
    x = sv.sample(rng, y).astype(np.float32)
    parts = lda_partition(y, n_clients, alpha=alpha, seed=0)
    data = [{"x": x[p], "y": y[p].astype(np.int32)} for p in parts]
    return data


def _server(data, tmpdir=None, **fl_kw):
    cfg = ResNetConfig(arch="resnet8", lora=LoRAConfig(rank=8, alpha=128.0))
    model = rinit(jax.random.PRNGKey(0), cfg)
    return FLServer(
        model, lambda f, t, b: loss_fn(f, t, cfg, b), data,
        ServerConfig(rounds=3, n_clients=len(data), clients_per_round=3,
                     checkpoint_dir=tmpdir, checkpoint_every=1, **fl_kw),
        ClientConfig(local_epochs=1, batch_size=16, lr=0.05),
        FLoCoRAConfig(rank=8, alpha=128.0, quant_bits=8))


@pytest.mark.slow
def test_fl_loss_decreases():
    data = _setup()
    srv = _server(data)
    hist = srv.run(4)
    first, last = hist[0]["client_loss"], hist[-1]["client_loss"]
    assert last < first, (first, last)


@pytest.mark.slow
def test_fl_client_dropout_and_stragglers():
    data = _setup()
    srv = _server(data, p_client_failure=0.4, oversample=1.5)
    hist = srv.run(4)
    assert all(h["n_agg"] >= 1 for h in hist)
    assert any(h["n_dropped"] > 0 for h in hist) or \
        any(h["n_straggled"] > 0 for h in hist)


@pytest.mark.slow
def test_fl_checkpoint_resume_exact(tmp_path):
    data = _setup()
    srv = _server(data, tmpdir=str(tmp_path))
    srv.run(2)
    ref = jax.device_get(srv.global_train)
    # a fresh server resumes from the checkpoint and matches state
    srv2 = _server(data, tmpdir=str(tmp_path))
    assert srv2.try_resume()
    assert srv2.round == srv.round
    got = jax.device_get(srv2.global_train)
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
        np.testing.assert_allclose(a, b, rtol=1e-6)


@pytest.mark.slow
def test_fl_fedprox_composes():
    """FLoCoRA + FedProx (aggregation-agnostic claim, paper §III)."""
    data = _setup(n=200, n_clients=4)
    cfg = ResNetConfig(arch="resnet8", lora=LoRAConfig(rank=8, alpha=128.0))
    model = rinit(jax.random.PRNGKey(0), cfg)
    srv = FLServer(
        model, lambda f, t, b: loss_fn(f, t, cfg, b), data,
        ServerConfig(rounds=2, n_clients=4, clients_per_round=2),
        ClientConfig(local_epochs=1, batch_size=16, lr=0.05,
                     fedprox_mu=0.01),
        FLoCoRAConfig(rank=8, alpha=128.0, quant_bits=4))
    hist = srv.run(2)
    assert len(hist) == 2 and np.isfinite(hist[-1]["client_loss"])


# ---------------------------------------------------------------------------
# checkpoint substrate
# ---------------------------------------------------------------------------

def test_checkpoint_atomic_and_gc(tmp_path):
    d = str(tmp_path)
    tree = {"w": jnp.arange(6.0).reshape(2, 3)}
    mgr = CheckpointManager(d, keep_n=2)
    for s in (1, 2, 3):
        mgr.save(s, {"train": jax.tree.map(lambda x: x * s, tree)})
    assert latest_step(d) == 3
    steps = sorted(int(f[5:-5]) for f in os.listdir(d)
                   if f.endswith(".json"))
    assert steps == [2, 3]                      # keep_n gc
    got, man = restore(d, 3, {"train": tree})
    np.testing.assert_allclose(np.asarray(got["train"]["w"]),
                               np.asarray(tree["w"]) * 3)


def test_elastic_restore_onto_new_mesh(tmp_path):
    """Checkpoint saved logically restores onto a different mesh shape."""
    from jax.sharding import Mesh
    d = str(tmp_path)
    tree = {"w": jnp.arange(32.0).reshape(4, 8)}
    save(d, 5, {"train": tree})
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    got = elastic_restore(d, {"train": tree},
                          {"train": {"w": ("fsdp", "mlp")}}, mesh)
    assert got is not None
    step, trees, _ = got
    assert step == 5
    np.testing.assert_allclose(np.asarray(trees["train"]["w"]),
                               np.asarray(tree["w"]))


def test_elastic_restore_cross_mesh_shardings(tmp_path):
    """SATELLITE: a checkpoint written under a 1-device mesh restores
    onto a DIFFERENT mesh shape with leaf equality AND the new mesh's
    NamedSharding annotations on every restored leaf."""
    from jax.sharding import Mesh, NamedSharding
    from repro.utils.sharding import tree_shardings
    from repro.utils.tree import flatten_with_names
    d = str(tmp_path)
    tree = {"w": jnp.arange(32.0).reshape(4, 8), "b": jnp.arange(8.0)}
    logical = {"train": {"w": ("fsdp", "mlp"), "b": (None,)}}
    dev = np.asarray(jax.devices()[:1])
    # checkpoint under a 1-device ('data',) mesh (stored logically —
    # nothing about the file depends on this topology)
    with Mesh(dev.reshape(1), ("data",)):
        save(d, 5, {"train": tree})
    # restore onto a (1, 1) ('data', 'model') mesh — different shape
    mesh2 = Mesh(dev.reshape(1, 1), ("data", "model"))
    got = elastic_restore(d, {"train": tree}, logical, mesh2)
    assert got is not None
    step, trees, _ = got
    assert step == 5
    expect_sh = dict(flatten_with_names(
        tree_shardings(logical["train"], tree, mesh2)))
    restored = dict(flatten_with_names(trees["train"]))
    assert set(restored) == {"w", "b"}
    for name, leaf in restored.items():
        np.testing.assert_array_equal(np.asarray(leaf),
                                      np.asarray(tree[name]))
        assert isinstance(leaf.sharding, NamedSharding)
        assert leaf.sharding.mesh.shape == mesh2.shape
        assert leaf.sharding == expect_sh[name], (name, leaf.sharding)


# ---------------------------------------------------------------------------
# EF x straggler policy (regression: dropped uplinks must not advance
# the sender's residual as if they were delivered)
# ---------------------------------------------------------------------------

def test_ef_fold_dropped_recovers_lost_mass():
    """REGRESSION (unit): when an uplink is discarded, folding its
    reconstruction back into the residual makes the NEXT uplink carry
    the lost update — unbiased-in-time survives the straggler policy."""
    from repro.core import aggregation, messages
    from repro.core.quant import QuantConfig
    qcfg = QuantConfig(bits=8)
    x1 = {"w": jax.random.normal(jax.random.PRNGKey(0), (8, 16))}
    x2 = {"w": jax.random.normal(jax.random.PRNGKey(1), (8, 16))}
    msg1, res1 = aggregation.ef_encode_packed(
        x1, aggregation.ef_init(x1), qcfg)
    # res1 assumes delivery: it only holds the small quantization error
    assert float(jnp.max(jnp.abs(res1["w"]))) < 0.1
    # msg1 is DISCARDED -> fold the whole reconstruction back
    res1 = aggregation.ef_fold_dropped(res1, msg1)
    np.testing.assert_allclose(np.asarray(res1["w"]), np.asarray(x1["w"]),
                               atol=1e-5)
    msg2, _ = aggregation.ef_encode_packed(x2, res1, qcfg)
    recon2 = messages.unpack_message(msg2)["w"]
    # the second uplink re-ships the lost mass (up to one quant step)
    np.testing.assert_allclose(np.asarray(recon2),
                               np.asarray(x1["w"] + x2["w"]), atol=0.05)


def test_ef_residuals_commit_only_for_kept_clients():
    """REGRESSION (system): run_round used to store_residual for every
    survivor BEFORE the first-K straggler cut, so a dropped client's
    residual claimed its update was delivered. Post-fix the straggled
    client's residual holds its FULL update (folded message), which
    dwarfs the kept client's quantization-error-sized residual."""
    from repro.core.aggregation import ErrorFeedbackFedAvg
    data = _setup(n=100, n_clients=2)
    cfg = ResNetConfig(arch="resnet8", lora=LoRAConfig(rank=4, alpha=64.0))
    model = rinit(jax.random.PRNGKey(0), cfg)
    srv = FLServer(
        model, lambda f, t, b: loss_fn(f, t, cfg, b), data,
        ServerConfig(rounds=1, n_clients=2, clients_per_round=1,
                     oversample=2.0),           # both dispatched, 1 kept
        ClientConfig(local_epochs=1, batch_size=16, lr=0.05),
        FLoCoRAConfig(rank=4, alpha=64.0, quant_bits=8,
                      error_feedback=True))
    assert isinstance(srv.aggregator, ErrorFeedbackFedAvg)
    hist = srv.run(1)
    assert hist[0]["n_agg"] == 1 and hist[0]["n_straggled"] == 1
    norms = {}
    for cid, res in srv.aggregator.residuals.items():
        norms[cid] = float(np.sqrt(sum(
            float(jnp.sum(jnp.square(l))) for l in jax.tree.leaves(res))))
    assert len(norms) == 2
    hi, lo = max(norms.values()), min(norms.values())
    # pre-fix both residuals are quant-error-sized (ratio ~ 1)
    assert hi > 10 * lo, norms


def test_fl_tcc_accounting_matches_codec():
    data = _setup(n=100, n_clients=4)
    srv = _server(data)
    from repro.core import messages
    expected = 2 * messages.message_wire_bytes(
        srv.global_train, srv.fcfg.qcfg)
    assert srv.round_bytes_per_client == expected
