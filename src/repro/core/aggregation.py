"""Server-side aggregation for FLoCoRA.

FLoCoRA is aggregation-agnostic (paper §III): clients exchange *adapter
parameter trees*, so any parameter-averaging FL rule applies unchanged.
Implemented here:

  * ``fedavg``      — n_k/n weighted mean (paper's showcase, Eq. 1);
  * ``fedavg_quantized`` — the fp reference for the paper's pipeline: each
    client message is quantize->dequantize'd before the weighted mean;
  * ``fedavg_packed`` — the wire-true path: K PACKED client messages
    (uint32 payloads + sidecars) are unpacked, dequantized and reduced in
    one pass on the fused ``dequant_agg`` Pallas kernel — the K dequantized
    fp32 client trees are never materialized; SPARSE (FLASC top-k)
    uplinks scatter-add their dequantized survivors into one dense fp32
    accumulator per leaf instead;
  * ``fedbuff``     — beyond-paper async buffered aggregation with
    staleness discounting (Nguyen et al. '22 style);
  * ``ErrorFeedback`` — beyond-paper EF residual compensation making the
    quantizer unbiased-in-time (EF21-style memory).

The :class:`Aggregator` strategy protocol wraps these for the FL engine:
``FedAvgAggregator`` / ``FedBuffAggregator`` / ``ErrorFeedbackFedAvg`` all
consume a list of client messages (packed or fp trees), so ``FLServer``
is generic over the aggregation rule.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import flat as flatcodec
from repro.core import lora, messages
from repro.core.flat import FlatPackedMessage, is_flat_message
from repro.core.messages import is_packed_leaf, is_wire_leaf
from repro.core.quant import QuantConfig
from repro.core.sparse import is_sparse_leaf
from repro.kernels import ops as kops
from repro.obs.compile import CompileWatchdog

Array = jax.Array


def stack_trees(trees: list[Any]) -> Any:
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def fedavg(stacked: Any, weights: Array) -> Any:
    """Weighted mean over the leading client axis. weights sum to 1."""
    w = weights / jnp.sum(weights)

    def mean(x):
        wr = w.reshape((-1,) + (1,) * (x.ndim - 1)).astype(jnp.float32)
        return jnp.sum(x.astype(jnp.float32) * wr, axis=0).astype(x.dtype)

    return jax.tree.map(mean, stacked)


def fedavg_quantized(stacked: Any, weights: Array, qcfg: QuantConfig) -> Any:
    """Paper pipeline: dequantized-client-view weighted mean.

    `stacked` holds the raw fp client trees; each is passed through the
    RTN roundtrip (per-client qparams, as on the wire) before averaging.
    """
    if qcfg.enabled:
        stacked = jax.vmap(lambda t: messages.roundtrip(t, qcfg))(stacked)
    return fedavg(stacked, weights)


def fedavg_packed(msgs: list[Any], weights: Array) -> Any:
    """Weighted mean over K PACKED (or sparse) wire messages, fused.

    Per quantized leaf, the K (C, Nw) uint32 payloads are stacked and fed
    to the ``dequant_agg`` Pallas kernel with normalized weights: unpack +
    dequant + reduce happen in one VMEM pass, never materializing the K
    fp32 client trees. SPARSE leaves (FLASC top-k uplinks) dequantize
    their k survivors and SCATTER-ADD into a dense fp32 buffer — the
    dense K-client stack is never materialized either, only one dense
    accumulator per leaf. Unquantized (fp passthrough) leaves take the
    plain weighted mean. Numerically equal (fp32 tolerance) to
    ``fedavg_quantized`` on the same client trees (dense case).

    FLAT-TREE messages (``core/flat.py``) take the fast path: the WHOLE
    K-client cohort unpacks + dequantizes + reduces in ONE fused kernel
    launch over the shared flat layout. A mixed flat/per-leaf buffer
    falls back through ``as_tree`` (bit-identical payload slices).
    """
    if msgs and all(is_flat_message(m) for m in msgs) \
            and len({m.layout for m in msgs}) == 1:
        return flatcodec.fedavg_packed_flat(msgs, weights)
    if any(is_flat_message(m) for m in msgs):
        msgs = [m.as_tree() if is_flat_message(m) else m for m in msgs]
    w = weights / jnp.sum(weights)

    def agg(*leaves):
        if any(is_sparse_leaf(m) for m in leaves):
            # a buffer can MIX sparse and dense leaves at one position
            # (e.g. FedBuff spanning a density-annealing boundary):
            # all sparse clients land in ONE batched scatter-add over
            # their concatenated (index, pre-weighted value) lists;
            # dense stragglers add in full
            l0 = next(m for m in leaves if is_sparse_leaf(m))
            acc = jnp.zeros((l0.n,), jnp.float32)
            pairs = [(m.idx, w[i].astype(jnp.float32) * m.values())
                     for i, m in enumerate(leaves) if is_sparse_leaf(m)]
            acc = acc.at[jnp.concatenate([p[0] for p in pairs])].add(
                jnp.concatenate([p[1] for p in pairs]))
            for i, m in enumerate(leaves):
                if not is_sparse_leaf(m):
                    d = messages.unpack_message(m)
                    acc = acc + (w[i].astype(jnp.float32)
                                 * d.astype(jnp.float32).reshape(-1))
            return acc.reshape(l0.shape).astype(l0.dtype)
        if is_packed_leaf(leaves[0]):
            l0 = leaves[0]
            out = kops.dequant_agg(
                jnp.stack([m.payload for m in leaves]),
                jnp.stack([m.scale for m in leaves]),
                jnp.stack([m.zp for m in leaves]),
                w.astype(jnp.float32), l0.bits)          # (C, N_pad)
            x2d = out[:, : l0.n_per_channel]
            return kops.from_channel_first_2d(
                x2d, l0.shape, l0.per_stack).astype(l0.dtype)
        x = jnp.stack([m.astype(jnp.float32) for m in leaves])
        wr = w.reshape((-1,) + (1,) * (x.ndim - 1)).astype(jnp.float32)
        return jnp.sum(x * wr, axis=0).astype(leaves[0].dtype)

    return jax.tree.map(agg, *msgs, is_leaf=is_wire_leaf)


def message_is_packed(msg: Any) -> bool:
    """True if any leaf of `msg` is in wire form (packed or sparse)."""
    return any(is_wire_leaf(l) for l in
               jax.tree.leaves(msg, is_leaf=is_wire_leaf))


# ---------------------------------------------------------------------------
# Heterogeneous-rank aggregation (HetLoRA zero-pad / FLoRIST SVD)
# ---------------------------------------------------------------------------

def bucket_by_rank(msgs: list[Any]) -> dict[int, list[int]]:
    """Group message indices by adapter rank (shape-inspected, so packed
    and fp messages bucket alike). Messages without adapters land in
    bucket 0. Buckets are ordered by ascending rank."""
    buckets: dict[int, list[int]] = {}
    for i, m in enumerate(msgs):
        r = lora.tree_max_rank(m)
        buckets.setdefault(0 if r is None else int(r), []).append(i)
    return dict(sorted(buckets.items()))


def fedavg_hetero(msgs: list[Any], weights: Array, r_target: int) -> Any:
    """Zero-pad-to-max FedAvg over MIXED-rank client messages.

    Clients are grouped into rank buckets; each bucket's (uniform-shape)
    messages aggregate in one pass — packed buckets on the fused
    ``dequant_agg`` Pallas kernel — then every bucket mean is zero-padded
    to ``r_target`` and the bucket means combine with their weight-mass
    fractions. Padding is linear, so this equals padding every client to
    ``r_target`` first and running one global FedAvg."""
    w = jnp.asarray(weights, jnp.float32)
    total = jnp.sum(w)
    fracs, means = [], []
    for r, idxs in bucket_by_rank(msgs).items():
        bmsgs = [msgs[i] for i in idxs]
        bw = jnp.asarray([w[i] for i in idxs])
        # ANY wire-form message routes the bucket through the wire path
        # (fedavg_packed also absorbs raw fp trees leaf-wise, so a
        # density-annealing boundary inside one bucket is order-safe)
        if any(message_is_packed(m) for m in bmsgs):
            mean_b = fedavg_packed(bmsgs, bw)
        else:
            mean_b = fedavg(stack_trees(bmsgs), bw)
        if r:
            mean_b = lora.resize_tree_rank(mean_b, r_target,
                                           method="slice")
        fracs.append(jnp.sum(bw) / total)
        means.append(mean_b)
    if len(means) == 1:
        return means[0]

    def combine(*leaves):
        acc = sum(f * l.astype(jnp.float32)
                  for f, l in zip(fracs, leaves))
        return acc.astype(leaves[0].dtype)

    return jax.tree.map(combine, *means)


# ---------------------------------------------------------------------------
# Beyond-paper: async buffered aggregation (FedBuff).
# fedbuff_init/add/flush are the INCREMENTAL fp reference implementation
# of the buffered rule (one jittable add per arrival); the production
# path is FedBuffAggregator's rank-bucketed add/flush, which defers the
# reduction to one fused-kernel pass over the buffered packed messages.
# Tier-1 cross-checks the shared discount formula between the two.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FedBuffState:
    buffer: Any          # running weighted sum of updates
    weight: Array        # running sum of weights
    count: Array         # updates buffered so far (int32)


def fedbuff_init(like: Any) -> FedBuffState:
    return FedBuffState(
        buffer=jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), like),
        weight=jnp.zeros((), jnp.float32),
        count=jnp.zeros((), jnp.int32),
    )


def fedbuff_add(state: FedBuffState, update: Any, n_k: Array,
                staleness: Array, half_life: float) -> FedBuffState:
    """Add one async client update with the staleness-discounted weight

        w = n_k * 2^(-staleness / half_life)

    ``staleness`` is the server-version lag at arrival (global version
    when the update is buffered minus the version the client trained
    from); an update's influence HALVES for every ``half_life`` versions
    the server advanced while the client was training (s=0 => w=n_k).
    ``half_life`` has no default here — it is a config field, threaded
    from ``ServerConfig.fedbuff_half_life`` / ``AsyncConfig.half_life``
    through :class:`FedBuffAggregator`."""
    w = n_k.astype(jnp.float32) * jnp.exp2(-staleness.astype(jnp.float32)
                                           / half_life)
    buf = jax.tree.map(lambda b, u: b + w * u.astype(jnp.float32),
                       state.buffer, update)
    return FedBuffState(buf, state.weight + w, state.count + 1)


def fedbuff_flush(state: FedBuffState, like: Any) -> tuple[Any, FedBuffState]:
    """Produce the aggregated tree and reset the buffer.

    Raises on zero accumulated weight: the old ``1e-8`` floor silently
    returned a near-zero garbage tree scaled by 1e8 — an empty (or
    staleness-discounted-to-nothing) buffer is a caller bug, not a
    degenerate mean. Eager-only by design (the check reads the weight)."""
    if float(state.weight) <= 0.0:
        raise ValueError("FedBuff flush with zero accumulated weight "
                         f"(count={int(state.count)})")
    agg = jax.tree.map(
        lambda b, x: (b / state.weight).astype(x.dtype),
        state.buffer, like)
    return agg, fedbuff_init(like)


# ---------------------------------------------------------------------------
# Beyond-paper: error-feedback quantization (EF memory on the sender)
# ---------------------------------------------------------------------------

def ef_init(like: Any) -> Any:
    return jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), like)


def ef_encode(tree: Any, residual: Any, qcfg: QuantConfig
              ) -> tuple[Any, Any]:
    """Send Q(x + e); keep e' = (x + e) - Q(x + e).

    Returns (reconstruction_seen_by_receiver, new_residual)."""
    if not qcfg.enabled:
        return tree, residual
    comp = jax.tree.map(lambda x, e: x.astype(jnp.float32) + e,
                        tree, residual)
    recon = messages.roundtrip(comp, qcfg)
    new_res = jax.tree.map(lambda c, r: c - r.astype(jnp.float32),
                           comp, recon)
    recon = jax.tree.map(lambda r, x: r.astype(x.dtype), recon, tree)
    return recon, new_res


def ef_encode_packed(tree: Any, residual: Any, qcfg: QuantConfig,
                     density: Optional[float] = None,
                     flat: bool = False) -> tuple[Any, Any]:
    """Wire-true EF uplink: pack Q(x + e), keep e' = (x + e) - deq(msg).

    Returns (packed wire message, new_residual) — the client computes its
    residual from the same packed payload the server will dequantize, so
    compensation is exact w.r.t. the wire format. With a sparse wire
    (``density < 1``) the reconstruction is zero at the dropped
    positions, so e' automatically absorbs the FULL dropped mass on top
    of the survivors' quantization error (the FLASC EF rule).
    ``flat=True`` emits the flat-tree wire form (one fused pack)."""
    sparse_on = density is not None and density < 1.0
    if not qcfg.enabled and not sparse_on:
        return tree, residual
    comp = jax.tree.map(lambda x, e: x.astype(jnp.float32) + e,
                        tree, residual)
    msg = messages.pack_message(comp, qcfg, density=density, flat=flat)
    recon = messages.unpack_message(msg)
    new_res = jax.tree.map(lambda c, r: c - r.astype(jnp.float32),
                           comp, recon)

    # the wire message must advertise the ORIGINAL adapter dtypes (comp is
    # fp32), or the aggregated global tree silently promotes to fp32
    if is_flat_message(msg):
        return msg.replace_dtypes(tree), new_res

    def redtype(m, x):
        if is_wire_leaf(m):
            return dataclasses.replace(m, dtype=x.dtype)
        return m.astype(x.dtype)

    msg = jax.tree.map(redtype, msg, tree, is_leaf=is_wire_leaf)
    return msg, new_res


def ef_fold_dropped(residual: Any, msg: Any) -> Any:
    """Fold an UNDELIVERED uplink back into its sender's EF residual.

    After ``ef_encode_packed`` the stored residual is
    ``e' = (x + e) - deq(msg)`` — it presumes ``msg`` was delivered. If
    the server discards the message (straggler policy), the correct
    memory is the full compensated signal ``x + e = e' + deq(msg)``, so
    the client's NEXT uplink re-ships the lost mass and the quantizer
    stays unbiased-in-time."""
    return jax.tree.map(
        lambda e, m: e + m.astype(jnp.float32),
        residual, messages.unpack_message(msg))


# ---------------------------------------------------------------------------
# Aggregator strategy protocol (paper §III: FLoCoRA is aggregation-agnostic)
# ---------------------------------------------------------------------------

@runtime_checkable
class Aggregator(Protocol):
    """Server-side aggregation rule over one round's client messages.

    ``msgs`` is a list of K client messages — either packed wire messages
    (PackedLeaf trees, the production path) or raw fp trees (the
    simulation path); ``weights`` are the n_k sample counts."""

    def aggregate(self, msgs: list[Any], weights: Array) -> Any:
        ...


@dataclasses.dataclass
class FedAvgAggregator:
    """Paper Eq. 1, generalized to heterogeneous ranks. Packed inputs
    lower onto the fused dequant_agg kernel (after a bit-width sanity
    check against ``qcfg``) — per rank bucket when the cohort is mixed,
    with zero-pad-to-``r_target`` recombination; fp inputs reproduce
    ``fedavg`` over the stacked trees. ``r_target`` is the LOWER bound
    of the aggregated tree's rank (zero-pad semantics: a cohort whose
    max client rank exceeds it still pads to that max, never truncates);
    None pads to the round's max client rank. ``FLServer`` pins it to
    the server rank, which its config validates as >= every scheduled
    client rank."""
    qcfg: QuantConfig = dataclasses.field(default_factory=QuantConfig)
    r_target: Optional[int] = None

    def _check_bits(self, msg: Any) -> None:
        if message_is_packed(msg) and self.qcfg.enabled:
            for leaf in jax.tree.leaves(msg, is_leaf=is_wire_leaf):
                if is_wire_leaf(leaf) and leaf.bits != self.qcfg.bits:
                    raise ValueError(
                        f"aggregator configured for {self.qcfg.bits}-"
                        f"bit messages, got {leaf.bits}-bit payload")

    def _round_rank(self, msgs: list[Any]) -> tuple[Optional[int], bool]:
        """(target rank, heterogeneous?) for this round's messages."""
        ranks = {r for m in msgs
                 if (r := lora.tree_max_rank(m)) is not None}
        if not ranks:
            return None, False
        target = max(self.r_target or 0, max(ranks))
        return target, (len(ranks) > 1 or ranks != {target})

    def aggregate(self, msgs: list[Any], weights: Array) -> Any:
        self._check_bits(msgs[0])
        target, hetero = self._round_rank(msgs)
        if hetero:
            return fedavg_hetero(msgs, weights, target)
        if any(message_is_packed(m) for m in msgs):
            return fedavg_packed(msgs, weights)
        return fedavg(stack_trees(msgs), weights)


@dataclasses.dataclass
class SVDRecombinationAggregator(FedAvgAggregator):
    """FLoRIST-style server recombination for (mixed-rank) LoRA fleets.

    Non-adapter leaves take the rank-bucketed FedAvg path (fused
    dequant_agg kernel per bucket). Each adapter pair is recombined from
    the PRODUCT side: the weighted mean delta ``Σ_k w̄_k · down_k @ up_k``
    (rank-free shape, so clients of any rank mix exactly) is thin-SVD'd
    and singular values are thresholded at ``energy`` cumulative mass to
    pick the SERVED rank — at most the round's max client rank — then the
    balanced factors are zero-padded back to the global tree's rank.
    Unlike factor averaging, this is exact on the aggregated delta up to
    the discarded singular-value tail.

    ``served_ranks`` records {adapter path: served rank} of the last
    round (observability + the rank-annealing signal)."""
    energy: float = 0.99
    served_ranks: dict = dataclasses.field(default_factory=dict)

    def aggregate(self, msgs: list[Any], weights: Array) -> Any:
        # the base pass also averages the adapter leaves we are about to
        # recombine — accepted redundancy: it keeps this class a pure
        # override of the FedAvg result (base supplies the non-adapter
        # leaves plus each pair's shape/dtype template)
        base = super().aggregate(msgs, weights)
        ranks = [lora.tree_max_rank(m) for m in msgs]
        if all(r is None for r in ranks):
            return base                       # no adapters to recombine
        cap = max(r for r in ranks if r is not None)
        # dequantize ONLY the adapter pairs (the recombination inputs);
        # every other leaf keeps the fused-kernel result from `base` and
        # the K full fp32 client trees are never materialized (flat
        # messages re-expose their per-leaf tree as payload slices first)
        trees = [m.as_tree() if is_flat_message(m) else m for m in msgs]
        trees = [lora._walk_pairs(m, messages.unpack_message)
                 if message_is_packed(m) else m for m in trees]
        w = jnp.asarray(weights, jnp.float32)
        w = w / jnp.sum(w)
        self.served_ranks = {}

        def recombine(path: str, node: Any, clients: list[Any]) -> Any:
            if isinstance(node, dict):
                if lora.is_adapter_pair(node):
                    return self._recombine_pair(path, node, clients, w,
                                                cap)
                return {k: recombine(f"{path}/{k}", v,
                                     [c[k] for c in clients])
                        for k, v in node.items()}
            if isinstance(node, (list, tuple)):
                out = [recombine(f"{path}/{i}", v,
                                 [c[i] for c in clients])
                       for i, v in enumerate(node)]
                return type(node)(out) if isinstance(node, tuple) else out
            return node

        return recombine("", base, trees)

    def _recombine_pair(self, path: str, base_pair: dict,
                        client_pairs: list[dict], w: Array,
                        cap: int) -> dict:
        delta = None
        for wk, pair in zip(w, client_pairs):
            down, up, _ = lora._dense_factors(pair)
            d = wk * (down.astype(jnp.float32) @ up.astype(jnp.float32))
            delta = d if delta is None else delta + d
        u, s, vh = jnp.linalg.svd(delta, full_matrices=False)
        r_served = min(lora.svd_energy_rank(s, self.energy), cap)
        self.served_ranks[path.lstrip("/")] = r_served
        root = jnp.sqrt(s[..., :r_served])
        down_s = u[..., :, :r_served] * root[..., None, :]
        up_s = root[..., :, None] * vh[..., :r_served, :]
        _, _, kind = lora._dense_factors(base_pair)
        served = lora._rebuild_pair(down_s, up_s, kind, base_pair)
        return lora.pad_adapter(served, lora.adapter_rank(base_pair))


@dataclasses.dataclass
class StreamingFlatAccumulator:
    """O(1)-memory streaming aggregation of flat wire messages.

    Instead of buffering K pending messages and reducing at flush, each
    arrived :class:`~repro.core.flat.FlatPackedMessage` folds into a
    running fp32 sum at ARRIVAL time — one fused K=1 ``dequant_agg_rows``
    pass over the ``(C_total, N_max)`` accumulator
    (``flat._fold_flat_impl``) — and the flush is an O(message)
    normalize (``flat._flat_mean_from_sum_impl``), independent of how
    many clients folded. Server memory: ONE accumulator per layout,
    never the K-message buffer. Weight/count ride on the host so the
    fold program never retraces (weak-typed scalar weight).
    """
    layout: Any               # flat.TreeLayout (one accumulator each)
    acc: Array                # (C_total, N_max) fp32 running sum
    fp_acc: tuple             # fp32 running sums of fp passthrough leaves
    weight: float = 0.0       # accumulated (discounted) weight
    count: int = 0            # messages folded since the last reset
    # opt-in runtime enforcement of the zero-steady-state-compile
    # invariant: every fold after the first (per reset cycle, which
    # re-pages the accumulators) must re-dispatch the compiled fold
    # program — a retrace raises obs.CompileBudgetExceeded
    strict_compiles: bool = False

    @classmethod
    def for_layout(cls, layout: Any,
                   strict_compiles: bool = False
                   ) -> "StreamingFlatAccumulator":
        acc = jnp.zeros((layout.c_total, layout.n_max), jnp.float32)
        fp = tuple(jnp.zeros(s.shape, jnp.float32)
                   for s in layout.leaves if not s.quantized)
        return cls(layout, acc, fp, strict_compiles=strict_compiles)

    def fold(self, msg: FlatPackedMessage, w: float) -> None:
        if msg.layout != self.layout:
            raise ValueError("flat message layout does not match the "
                             "streaming accumulator's")
        if self.strict_compiles and self.count > 0:
            with CompileWatchdog(0, label="streaming flat fold "
                                          f"#{self.count}"):
                self._fold(msg, w)
        else:
            self._fold(msg, w)
        self.weight += float(w)
        self.count += 1

    def _fold(self, msg: FlatPackedMessage, w: float) -> None:
        self.acc, self.fp_acc = flatcodec._fold_flat_impl(
            self.acc, self.fp_acc, msg.payload, msg.scale, msg.zp,
            msg.fp_leaves, float(w), self.layout)

    def mean(self) -> Any:
        """The aggregated fp tree (original structure/dtypes)."""
        if self.count == 0:
            raise ValueError("streaming flush with an empty accumulator")
        if self.weight <= 0.0:
            raise ValueError("streaming flush with zero accumulated "
                             f"weight (count={self.count})")
        return flatcodec._flat_mean_from_sum_impl(
            self.acc, self.fp_acc, 1.0 / self.weight, self.layout)

    def reset(self) -> None:
        self.acc = jnp.zeros_like(self.acc)
        self.fp_acc = tuple(jnp.zeros_like(x) for x in self.fp_acc)
        self.weight = 0.0
        self.count = 0

    def shape_tree(self) -> Any:
        """Shape/dtype view with the original tree structure (rank
        detection without touching the accumulator)."""
        return jax.tree_util.tree_unflatten(
            self.layout.treedef,
            [jax.ShapeDtypeStruct(s.shape, s.dtype)
             for s in self.layout.leaves])

    # -- checkpointable state (host arrays; layout is rebuilt by caller) ----
    def state(self) -> dict:
        return {"acc": np.asarray(self.acc),
                "fp_acc": [np.asarray(x) for x in self.fp_acc],
                "weight": float(self.weight), "count": int(self.count)}

    @classmethod
    def from_state(cls, layout: Any,
                   state: dict) -> "StreamingFlatAccumulator":
        return cls(layout, jnp.asarray(state["acc"], jnp.float32),
                   tuple(jnp.asarray(x, jnp.float32)
                         for x in state["fp_acc"]),
                   float(state["weight"]), int(state["count"]))


FEDBUFF_HALF_LIFE = 4.0   # fallback when no engine config threads one


@dataclasses.dataclass
class FedBuffAggregator:
    """Buffered aggregation with staleness discounting (Nguyen et al.
    '22). The discount is ``w = n_k * 2^(-staleness / half_life)``: an
    update's influence halves for every ``half_life`` global versions of
    server lag. ``half_life=None`` defers to the engine config
    (``ServerConfig.fedbuff_half_life`` / ``AsyncConfig.half_life``) —
    both engines thread it at construction.

    Two interfaces over the same rule, both RANK-BUCKETED (mixed-rank
    fleets bucket by adapter rank; packed buckets aggregate on the fused
    ``dequant_agg`` kernel and zero-pad to ``r_target``):

      * ``aggregate(msgs, weights)`` — the sync-round adapter: with
        ``rank_staleness`` the arrival order WITHIN each rank bucket
        plays the staleness role (straggler-rank staleness per bucket);
      * ``add(msg, n_k, staleness)`` / ``flush()`` — the async buffered
        interface driven by ``fl/async_engine.py``: packed wire messages
        buffer with their discounted weights and one flush performs the
        buffered packed sum in a single rank-bucketed fused pass.

    With ``streaming=True`` flat wire messages never buffer: each
    ``add`` folds the arrival into a :class:`StreamingFlatAccumulator`
    (one per layout — layouts double as rank buckets) and ``flush``
    normalizes the running sums in O(message) — flush cost and server
    memory become independent of ``buffer_size``. Non-flat messages
    (sparse uplinks, raw fp trees) still buffer in ``pending``; a mixed
    flush combines stream means and pending-bucket means by weight-mass
    fraction, exactly mirroring ``fedavg_hetero``'s recombination.
    """
    half_life: Optional[float] = None
    rank_staleness: bool = False   # sync rounds: discount late arrivals
    r_target: Optional[int] = None  # zero-pad target (engines pin this)
    pending: list = dataclasses.field(default_factory=list)
    streaming: bool = False        # fold flat arrivals at add time
    streams: dict = dataclasses.field(default_factory=dict)
    # threaded into every StreamingFlatAccumulator this aggregator
    # creates: steady-state folds that retrace raise (obs watchdog)
    strict_compiles: bool = False

    def resolved_half_life(self) -> float:
        return FEDBUFF_HALF_LIFE if self.half_life is None \
            else float(self.half_life)

    def discounted_weight(self, n_k: float, staleness: float) -> float:
        """w = n_k * 2^(-staleness / half_life)."""
        return float(n_k) * 2.0 ** (-float(staleness)
                                    / self.resolved_half_life())

    def _combine(self, msgs: list[Any], weights: Any) -> Any:
        """Rank-bucketed discounted-weight mean over buffered messages."""
        w = jnp.asarray(np.asarray(weights, np.float32))
        ranks = {r for m in msgs
                 if (r := lora.tree_max_rank(m)) is not None}
        if ranks:
            target = max(self.r_target or 0, max(ranks))
            if len(ranks) > 1 or ranks != {target}:
                return fedavg_hetero(msgs, w, target)
        # ANY wire-form message selects the wire path: a FedBuff buffer
        # spanning a density-annealing boundary can hold a raw fp tree
        # (density 1.0, quant off) FIRST and sparse messages later
        if any(message_is_packed(m) for m in msgs):
            return fedavg_packed(msgs, w)
        return fedavg(stack_trees(msgs), w)

    def aggregate(self, msgs: list[Any], weights: Array) -> Any:
        stale = np.zeros(len(msgs), np.float32)
        if self.rank_staleness:
            for idxs in bucket_by_rank(msgs).values():
                for pos, i in enumerate(idxs):
                    stale[i] = float(pos)
        w = np.asarray(weights, np.float32) \
            * np.exp2(-stale / self.resolved_half_life())
        return self._combine(msgs, w)

    # -- async buffered interface (fl/async_engine.py) ----------------------
    @property
    def buffered(self) -> int:
        """Arrivals absorbed since the last flush (pending + streamed)."""
        return len(self.pending) + sum(s.count
                                       for s in self.streams.values())

    @property
    def buffered_weight(self) -> float:
        """Total discounted weight absorbed since the last flush."""
        return (sum(wt for _, wt in self.pending)
                + sum(s.weight for s in self.streams.values()))

    def add(self, msg: Any, n_k: float, staleness: float) -> int:
        """Absorb one arrived (packed or fp) message with its
        staleness-discounted weight; returns the buffer fill count.
        Streaming mode folds flat messages immediately (O(1) server
        memory); everything else buffers for the batched flush."""
        w = self.discounted_weight(n_k, staleness)
        if self.streaming and is_flat_message(msg):
            st = self.streams.get(msg.layout)
            if st is None:
                st = StreamingFlatAccumulator.for_layout(
                    msg.layout, strict_compiles=self.strict_compiles)
                self.streams[msg.layout] = st
            st.fold(msg, w)
        else:
            self.pending.append((msg, w))
        return self.buffered

    def flush(self) -> Any:
        """Aggregate and clear the buffer. Pending messages reduce in
        one rank-bucketed fused pass; streaming accumulators normalize
        in O(message). Mixed parts recombine like ``fedavg_hetero``:
        bucket means zero-pad to the target rank and combine with their
        weight-mass fractions."""
        if self.buffered == 0:
            raise ValueError("FedBuff flush with an empty buffer")
        parts: list[tuple[int, float, Any]] = []   # (rank, mass, mean)
        for st in self.streams.values():
            if st.count == 0:
                continue
            r = lora.tree_max_rank(st.shape_tree())
            parts.append((0 if r is None else int(r), st.weight,
                          st.mean()))
            st.reset()
        msgs = [m for m, _ in self.pending]
        wts = [wt for _, wt in self.pending]
        self.pending = []
        for r, idxs in bucket_by_rank(msgs).items():
            bmsgs = [msgs[i] for i in idxs]
            bw = jnp.asarray([wts[i] for i in idxs], jnp.float32)
            if any(message_is_packed(m) for m in bmsgs):
                mean_b = fedavg_packed(bmsgs, bw)
            else:
                mean_b = fedavg(stack_trees(bmsgs), bw)
            parts.append((r, float(sum(wts[i] for i in idxs)), mean_b))
        total = sum(mass for _, mass, _ in parts)
        if total <= 0.0:
            raise ValueError("FedBuff flush with zero accumulated "
                             f"weight ({self.buffered} buffered)")
        ranks = {r for r, _, _ in parts if r}
        target = max(self.r_target or 0, max(ranks)) if ranks else 0
        means = [lora.resize_tree_rank(m, target, method="slice")
                 if r and r != target else m for r, _, m in parts]
        if len(means) == 1:
            return means[0]
        fracs = [mass / total for _, mass, _ in parts]

        def combine(*leaves):
            acc = sum(f * l.astype(jnp.float32)
                      for f, l in zip(fracs, leaves))
            return acc.astype(leaves[0].dtype)

        return jax.tree.map(combine, *means)


@dataclasses.dataclass
class ErrorFeedbackFedAvg(FedAvgAggregator):
    """EF-compensated FedAvg: owns the per-client residual memory; the
    uplink encode routes through ``ef_encode_packed`` so each client sends
    Q(x + e) and the quantizer becomes unbiased-in-time."""
    residuals: dict = dataclasses.field(default_factory=dict)

    def residual(self, cid: int, like: Any) -> Any:
        res = self.residuals.get(int(cid))
        if res is None:
            return ef_init(like)
        # a rank-annealed client's adapter shapes change between rounds;
        # a stale residual must restart rather than desync the encode
        like_leaves = jax.tree.leaves(like)
        res_leaves = jax.tree.leaves(res)
        if len(res_leaves) != len(like_leaves) or any(
                tuple(np.shape(a)) != tuple(np.shape(b))
                for a, b in zip(res_leaves, like_leaves)):
            return ef_init(like)
        return res

    def store_residual(self, cid: int, res: Any) -> None:
        # host numpy: one fp32 adapter tree per client ever sampled must
        # not accumulate in accelerator memory
        self.residuals[int(cid)] = jax.device_get(res)
