"""Paper Fig. 3: convergence of FedAvg vs FLoCoRA (r=32, alpha=512) and
its 8/4/2-bit quantized variants on the synthetic task."""
import sys

from benchmarks.common import fl_experiment


def run(rounds: int = 10) -> list[str]:
    rows = []
    for name, kw in [
        ("fedavg", dict(mode="fedavg")),
        ("flocora_fp", dict(rank=32, alpha=512.0)),
        ("flocora_int8", dict(rank=32, alpha=512.0, quant_bits=8)),
        ("flocora_int4", dict(rank=32, alpha=512.0, quant_bits=4)),
        ("flocora_int2", dict(rank=32, alpha=512.0, quant_bits=2)),
        # beyond-paper: error feedback rescues int2
        ("flocora_int2_ef", dict(rank=32, alpha=512.0, quant_bits=2,
                                 error_feedback=True)),
    ]:
        res = fl_experiment(arch="resnet8", rounds=rounds, **kw)
        curve = [h.get("test_acc") for h in res["history"]
                 if "test_acc" in h]
        rows.append(f"fig3/{name},0,best_acc={res['best_acc']} "
                    f"curve={curve} tcc_mb={res['tcc_bytes'] / 1e6:.2f}")
    return rows


if __name__ == "__main__":
    r = 10
    if "--rounds" in sys.argv:
        r = int(sys.argv[sys.argv.index("--rounds") + 1])
    print("\n".join(run(r)))
