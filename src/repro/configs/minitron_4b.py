"""minitron-4b [dense]: 32L d_model=3072 24H (GQA kv=8) d_ff=9216
vocab=256000 — pruned nemotron, squared-ReLU MLP [arXiv:2407.14679]."""
from repro.core.lora import LoRAConfig
from repro.models.lm import LMConfig


def full() -> LMConfig:
    return LMConfig(
        name="minitron-4b", n_layers=32, d_model=3072, n_heads=24,
        n_kv_heads=8, head_dim=128, d_ff=9216, vocab=256000,
        mlp_kind="sqrelu", rope_base=1e4,
        pad_heads_to=32,              # 24 -> 32 so heads shard 16-way
        lora=LoRAConfig(rank=32, alpha=512.0), head_mode="lora")


def smoke() -> LMConfig:
    return LMConfig(
        name="minitron-4b-smoke", n_layers=4, d_model=96, n_heads=6,
        n_kv_heads=2, head_dim=16, d_ff=288, vocab=512,
        mlp_kind="sqrelu", pad_heads_to=8,
        lora=LoRAConfig(rank=4, alpha=64.0), head_mode="lora")
