"""qwen1.5-110b [dense]: 80L d_model=8192 64H (GQA kv=8) d_ff=49152
vocab=152064 — QKV bias [hf:Qwen/Qwen1.5-110B]."""
from repro.core.lora import LoRAConfig
from repro.models.lm import LMConfig


def full() -> LMConfig:
    return LMConfig(
        name="qwen1.5-110b", n_layers=80, d_model=8192, n_heads=64,
        n_kv_heads=8, head_dim=128, d_ff=49152, vocab=152064,
        mlp_kind="swiglu", qkv_bias=True, rope_base=1e6,
        lora=LoRAConfig(rank=32, alpha=512.0), head_mode="lora")


def smoke() -> LMConfig:
    return LMConfig(
        name="qwen1.5-110b-smoke", n_layers=4, d_model=128, n_heads=8,
        n_kv_heads=2, head_dim=16, d_ff=384, vocab=512,
        mlp_kind="swiglu", qkv_bias=True,
        lora=LoRAConfig(rank=4, alpha=64.0), head_mode="lora")
