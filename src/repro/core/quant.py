"""Affine (asymmetric) round-to-nearest quantization for FLoCoRA messages.

Implements the paper's scheme (§IV, following Nagel et al. "A white paper
on neural network quantization"): per-channel scale + zero-point for conv
tensors (channel = dim 0 of the message tensor), per-column for FC, RTN,
2/4/8-bit unsigned levels, fp32 scale/zero-point sidecar. Norm layers are
never quantized.

Bit-packing: sub-byte levels are packed little-endian into uint8 words
(int4 -> 2/byte, int2 -> 4/byte) so message sizes match the wire format
used in the paper's TCC accounting (Eq. 2 + sidecar overhead).

All functions are jit-friendly (bits is static). The Pallas kernels in
``repro.kernels`` implement fused versions of ``quantize``+``pack_levels``
and ``unpack_levels``+``dequantize``; this module is the reference oracle.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Quantization config for FLoCoRA messages.

    bits: 2, 4, 8 or None (None = fp32 passthrough, the paper's "FP" rows).
    channel_axis: axis along which scale/zero-point are computed.
    symmetric: beyond-paper option (zero-point fixed at mid-level).
    """
    bits: Optional[int] = None
    channel_axis: int = 0
    symmetric: bool = False
    # per_stack=True: separate qparams per leading-stack slice (finer, for
    # stacked LM layer tensors); False (default) matches the paper exactly:
    # channel = last axis, all other dims flattened.
    per_stack: bool = False

    @property
    def enabled(self) -> bool:
        return self.bits is not None

    @property
    def qmax(self) -> int:
        assert self.bits is not None
        return (1 << self.bits) - 1


def _moveaxis_flat(x: Array, axis: int) -> Array:
    """(..., C, ...) -> (C, rest) with channel first."""
    x = jnp.moveaxis(x, axis, 0)
    return x.reshape(x.shape[0], -1)


def affine_qparams(x: Array, bits: int, channel_axis: int = 0,
                   symmetric: bool = False) -> tuple[Array, Array]:
    """Per-channel (scale, zero_point). zero_point is an integer level.

    Asymmetric: levels q in [0, 2^bits-1]; x ~= scale * (q - zp).
    Degenerate channels (max == min) get scale = 1 so dequant returns the
    constant exactly (q == zp everywhere).
    """
    qmax = (1 << bits) - 1
    xf = _moveaxis_flat(x.astype(jnp.float32), channel_axis)
    xmin = jnp.min(xf, axis=1)
    xmax = jnp.max(xf, axis=1)
    if symmetric:
        # restricted-range symmetric: levels [0, qmax-1] centred on the
        # integer zero-point (qmax-1)/2, so 0 AND BOTH extremes ±amax are
        # exactly representable. The naive scale = 2*amax/qmax maps +amax
        # to level qmax+1 (clipped: the peak dequantizes short by
        # ~amax/qmax while -amax overshoots) — one top level is the price
        # of a saturation-free grid.
        amax = jnp.maximum(jnp.abs(xmin), jnp.abs(xmax))
        scale = jnp.where(amax > 0, (2.0 * amax) / (qmax - 1), 1.0)
        zp = jnp.full_like(scale, (qmax - 1) // 2)
    else:
        # make sure 0 is representable (standard affine convention)
        xmin = jnp.minimum(xmin, 0.0)
        xmax = jnp.maximum(xmax, 0.0)
        rng = xmax - xmin
        scale = jnp.where(rng > 0, rng / qmax, 1.0)
        zp = jnp.clip(jnp.round(-xmin / scale), 0, qmax)
    return scale, zp


def quantize(x: Array, scale: Array, zp: Array, bits: int,
             channel_axis: int = 0) -> Array:
    """fp -> unsigned levels (stored as uint8), RTN."""
    qmax = (1 << bits) - 1
    shape = [1] * x.ndim
    shape[channel_axis] = x.shape[channel_axis]
    s = scale.reshape(shape)
    z = zp.reshape(shape)
    q = jnp.round(x.astype(jnp.float32) / s) + z
    return jnp.clip(q, 0, qmax).astype(jnp.uint8)


def dequantize(q: Array, scale: Array, zp: Array,
               channel_axis: int = 0,
               dtype: jnp.dtype = jnp.float32) -> Array:
    shape = [1] * q.ndim
    shape[channel_axis] = q.shape[channel_axis]
    s = scale.reshape(shape)
    z = zp.reshape(shape)
    return ((q.astype(jnp.float32) - z) * s).astype(dtype)


def quant_dequant(x: Array, cfg: QuantConfig) -> Array:
    """RTN round-trip — what the receiving end sees. fp passthrough if
    quantization is disabled."""
    if not cfg.enabled:
        return x
    scale, zp = affine_qparams(x, cfg.bits, cfg.channel_axis, cfg.symmetric)
    q = quantize(x, scale, zp, cfg.bits, cfg.channel_axis)
    return dequantize(q, scale, zp, cfg.channel_axis, x.dtype)


# ---------------------------------------------------------------------------
# Bit packing (wire format)
# ---------------------------------------------------------------------------

def pack_levels(q: Array, bits: int) -> Array:
    """Pack uint8 levels (< 2^bits) into a flat uint8 array, little-endian
    within each byte. Pads the flattened tail with zeros."""
    assert bits in (2, 4, 8)
    flat = q.reshape(-1)
    if bits == 8:
        return flat
    per = 8 // bits
    pad = (-flat.shape[0]) % per
    flat = jnp.pad(flat, (0, pad))
    grp = flat.reshape(-1, per).astype(jnp.uint32)
    shifts = jnp.arange(per, dtype=jnp.uint32) * bits
    word = jnp.sum(grp << shifts[None, :], axis=1)
    return word.astype(jnp.uint8)


def unpack_levels(packed: Array, bits: int, n: int) -> Array:
    """Inverse of pack_levels; returns first ``n`` levels as uint8."""
    assert bits in (2, 4, 8)
    if bits == 8:
        return packed[:n]
    per = 8 // bits
    mask = (1 << bits) - 1
    w = packed.astype(jnp.uint32)
    shifts = jnp.arange(per, dtype=jnp.uint32) * bits
    lv = (w[:, None] >> shifts[None, :]) & mask
    return lv.reshape(-1)[:n].astype(jnp.uint8)


# ---------------------------------------------------------------------------
# Differential privacy: clip + Gaussian noise BEFORE quantization
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DPConfig:
    """DP-FedAvg style uplink privatization (Abadi et al. moments
    accounting; McMahan et al. DP-FedAvg clipping).

    The client's update DELTA is clipped to ``clip_norm`` in global L2
    norm, then Gaussian noise with std ``noise_multiplier * clip_norm``
    is added — BEFORE affine quantization, so the quantizer's
    per-channel range adapts to the noised tensor and the wire carries
    an already-private message (quantization is post-processing: it
    cannot weaken the DP guarantee).

    ``delta`` is the target failure probability for the epsilon
    accountant (:func:`gaussian_epsilon`).
    """
    clip_norm: float = 1.0
    noise_multiplier: float = 0.0
    delta: float = 1e-5

    def __post_init__(self):
        if self.clip_norm <= 0:
            raise ValueError("clip_norm must be positive")
        if self.noise_multiplier < 0:
            raise ValueError("noise_multiplier must be >= 0")
        if not 0.0 < self.delta < 1.0:
            raise ValueError("delta must be in (0, 1)")


def global_l2_norm(tree) -> Array:
    """Global L2 norm across every leaf of a pytree."""
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def dp_privatize(tree, cfg: DPConfig, *, seed: int, key: tuple):
    """Clip a client's update tree to ``cfg.clip_norm`` (global L2) and
    add Gaussian noise of std ``noise_multiplier * clip_norm`` per
    coordinate.

    ``key`` is a tuple of simulation ids (e.g. ``(round, cid)`` for the
    sync engine, ``(version, cid, dispatch_idx)`` for async) — the noise
    is a pure function of ``(seed, *key)``, so deterministic replay and
    bit-exact checkpoint/resume survive privatization. Noise is drawn in
    numpy (keyed ``default_rng``, matching the trace/sampler idiom) and
    applied leaf-wise.
    """
    factor = jnp.minimum(
        1.0, cfg.clip_norm / jnp.maximum(global_l2_norm(tree), 1e-12))
    clipped = jax.tree_util.tree_map(
        lambda l: (l.astype(jnp.float32) * factor).astype(l.dtype), tree)
    if cfg.noise_multiplier <= 0.0:
        return clipped
    rng = np.random.default_rng([seed, TAG_DP, *[int(k) for k in key]])
    sigma = cfg.noise_multiplier * cfg.clip_norm

    def _noise(l):
        n = rng.normal(scale=sigma, size=l.shape).astype(np.float32)
        return (l.astype(jnp.float32) + n).astype(l.dtype)

    return jax.tree_util.tree_map(_noise, clipped)


# rng key domain for DP noise draws (disjoint from trace/engine tags)
TAG_DP = 0xD9


def gaussian_epsilon(noise_multiplier: float, steps: int,
                     delta: float = 1e-5) -> float:
    """(eps, delta)-DP spent after ``steps`` Gaussian-mechanism releases
    at noise std ``noise_multiplier`` x sensitivity, via Renyi-DP
    composition (Mironov 2017): the Gaussian mechanism is
    (alpha, alpha/(2 sigma^2))-RDP, T-fold composition scales linearly,
    and conversion to (eps, delta) minimizes over an alpha grid:

        eps = min_alpha [ T * alpha / (2 sigma^2) + ln(1/delta)/(alpha-1) ]

    Without subsampling amplification this is a conservative upper
    bound for the fleet setting (each round samples a small cohort);
    tight enough for the benchmark's reported epsilon. Returns ``inf``
    when ``noise_multiplier == 0``.
    """
    if steps <= 0:
        return 0.0
    if noise_multiplier <= 0:
        return float("inf")
    sigma2 = noise_multiplier ** 2
    alphas = np.concatenate([np.linspace(1.01, 64.0, 512),
                             np.linspace(65.0, 1024.0, 192)])
    eps = steps * alphas / (2.0 * sigma2) \
        + np.log(1.0 / delta) / (alphas - 1.0)
    return float(eps.min())


# ---------------------------------------------------------------------------
# Byte accounting (paper Eq. 2 + sidecar overhead; validated against
# Tables III / IV — see benchmarks/table3_tcc.py)
# ---------------------------------------------------------------------------

FP_BYTES = 4  # paper communicates fp32


def quantized_tensor_bytes(shape: tuple[int, ...], bits: int,
                           channel_axis: int = 0) -> int:
    """Wire bytes for one quantized tensor: packed payload (ceil per
    tensor) + per-channel fp32 scale and zero-point."""
    n = int(np.prod(shape))
    channels = shape[channel_axis]
    payload = (n * bits + 7) // 8
    sidecar = channels * 2 * FP_BYTES
    return payload + sidecar


def fp_tensor_bytes(shape: tuple[int, ...]) -> int:
    return int(np.prod(shape)) * FP_BYTES


def tcc_bytes(message_bytes: int, rounds: int) -> int:
    """DEPRECATED shim: the canonical TCC accounting is
    ``repro.core.messages.tcc_bytes(tree, cfg, rounds)`` (tree-level,
    same Eq. 2 formula). This scalar variant survives for old callers
    only and will be removed."""
    import warnings
    warnings.warn(
        "repro.core.quant.tcc_bytes is deprecated; use "
        "repro.core.messages.tcc_bytes(tree, cfg, rounds)",
        DeprecationWarning, stacklevel=2)
    return 2 * rounds * message_bytes
