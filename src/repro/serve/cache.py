"""Wire-format adapter cache for the multi-tenant serving engine.

A serving node hosts ONE frozen base and thousands-to-millions of
per-client adapters. Keeping every adapter dequantized would multiply
the paper's 4.8-18.6x wire win away at rest — so the cache stores each
client's adapters EXACTLY as they arrived on the wire: compact uint32
packed rows + fp32 scale/zp sidecars (the ``quant_pack`` / flat-codec
channel-first layout). Dequant happens inside the fused serving matmul
(``kernels.ops.multi_lora_matmul_packed``); the cache never holds an
fp32 adapter tree.

Three pieces:

  * :class:`PackedPair` — one adapter pair of one client in compact
    wire rows (host numpy; the at-rest form);
  * :class:`AdapterCache` — LRU or clock(second-chance) eviction keyed
    by client id, capacity in MEASURED wire bytes
    (``messages.message_wire_bytes`` accounting), hit/miss/eviction
    counters;
  * :meth:`AdapterCache.stage` — the host->device staging path: groups
    the requested clients by pow2 RANK BUCKET (the hetero-rank cohort
    convention from ``core/lora.py`` / ``fl/server.py``) and uploads
    each bucket's adapters as ONE stacked slab per buffer, slots padded
    to pow2 so steady-state decode shapes are stable (0 recompiles).

Rank-bucket padding is exact: a rank-r adapter in a rank-rb bucket pads
its A rows with scale=0 sidecars (dequant -> exact 0, so the extra
h-lanes are zero) and its B words with zero words (their dequant value
is multiplied by those zero h-lanes). The padding contributes exactly
zero; outputs match serving at the true rank up to the dot reduction
order of the differently-shaped program (~1 ulp).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lora, messages
from repro.core.flat import is_flat_message
from repro.core.quant import QuantConfig
from repro.fl.client import pow2_pad
from repro.kernels import ref as kref
from repro.obs import metrics as obsm

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class PackedPair:
    """One dense LoRA pair in compact wire rows (channel-first):
    ``aq`` (r, KW) uint32 — A's r channel rows of d_in levels;
    ``bq`` (d_out, RW) uint32 — B's d_out channel rows of r levels;
    fp32 scale/zp sidecars per channel row. KW = ceil(d_in/per),
    RW = ceil(r/per); word tails past the valid levels are zero (the
    codec's packing contract, which bucket padding relies on)."""
    aq: np.ndarray
    a_scale: np.ndarray
    a_zp: np.ndarray
    bq: np.ndarray
    b_scale: np.ndarray
    b_zp: np.ndarray
    d_in: int
    d_out: int
    rank: int
    bits: int

    def dequant(self) -> tuple[Array, Array]:
        """-> fp32 (a (d_in, r), b (r, d_out)) — the ``unpack_message``
        formula. ORACLE/TEST use only: the serving path never calls
        this (dequant lives inside the fused matmul)."""
        la = kref.unpack_words(jnp.asarray(self.aq),
                               self.bits)[:, :self.d_in]
        a2d = (la.astype(jnp.float32) - jnp.asarray(self.a_zp)[:, None]) \
            * jnp.asarray(self.a_scale)[:, None]
        lb = kref.unpack_words(jnp.asarray(self.bq),
                               self.bits)[:, :self.rank]
        b2d = (lb.astype(jnp.float32) - jnp.asarray(self.b_zp)[:, None]) \
            * jnp.asarray(self.b_scale)[:, None]
        return a2d.T, b2d.T


@dataclasses.dataclass
class CacheEntry:
    cid: int
    rank: int
    nbytes: int
    pairs: tuple[PackedPair, ...]
    ref: bool = True              # clock second-chance bit


class StagedLayer(NamedTuple):
    """One layer of one rank bucket's device-resident adapter slab
    (a pytree — rides straight into the jitted serving chain)."""
    aq: Array        # (E, rb, KW) uint32
    a_scale: Array   # (E, rb) fp32
    a_zp: Array
    bq: Array        # (E, d_out, RWb) uint32
    b_scale: Array   # (E, d_out) fp32
    b_zp: Array


@dataclasses.dataclass
class StagedBucket:
    rank: int                     # pow2 bucket rank rb
    slots: dict[int, int]         # cid -> slot index in the slab
    layers: tuple[StagedLayer, ...]
    n_slots: int                  # pow2-padded E dim


def extract_pairs(msg: Any, bits: int) -> tuple[int, tuple[PackedPair, ...]]:
    """Wire message (PackedLeaf tree or flat-tree message) -> compact
    host-side pairs in flatten order. Payload bits are copied verbatim
    (compact word slice of the lane-padded kernel rows); nothing is
    dequantized. Returns (adapter rank, pairs)."""
    if is_flat_message(msg):
        msg = msg.as_tree()
    found: list[dict] = []
    lora._walk_pairs(msg, lambda p: (found.append(p), p)[1])
    if not found:
        raise ValueError("message carries no adapter pairs")
    per = 32 // bits
    pairs = []
    for p in found:
        a, b = p["a"], p["b"]
        if lora.adapter_kind(a, b) != "dense":
            raise ValueError("the serving cache handles dense adapter "
                             f"pairs; got a{tuple(a.shape)} "
                             f"b{tuple(b.shape)}")
        if not (messages.is_packed_leaf(a) and messages.is_packed_leaf(b)):
            raise ValueError("adapters must arrive in wire form "
                             "(pack_message) — the cache stores packed "
                             "payloads only, never fp32")
        d_in, r = a.shape
        d_out = b.shape[1]
        kw = -(-d_in // per)
        rw = -(-r // per)
        pairs.append(PackedPair(
            aq=np.asarray(jax.device_get(a.payload))[:, :kw],
            a_scale=np.asarray(jax.device_get(a.scale), np.float32),
            a_zp=np.asarray(jax.device_get(a.zp), np.float32),
            bq=np.asarray(jax.device_get(b.payload))[:, :rw],
            b_scale=np.asarray(jax.device_get(b.scale), np.float32),
            b_zp=np.asarray(jax.device_get(b.zp), np.float32),
            d_in=d_in, d_out=d_out, rank=r, bits=bits))
    ranks = {p.rank for p in pairs}
    if len(ranks) != 1:
        raise ValueError(f"mixed ranks within one message: {ranks}")
    return ranks.pop(), tuple(pairs)


def wire_bytes_of(msg: Any, qcfg: QuantConfig) -> int:
    """Static ``message_wire_bytes`` accounting for a WIRE message: the
    packed leaves are walked by their original fp shapes (shape-only,
    no payload touch)."""
    if is_flat_message(msg):
        return messages.message_wire_bytes(msg.shape_tree(), qcfg)

    def proxy(t):
        if messages.is_wire_leaf(t):
            return jax.ShapeDtypeStruct(tuple(t.shape), jnp.float32)
        return t

    tree = jax.tree.map(proxy, msg, is_leaf=messages.is_wire_leaf)
    return messages.message_wire_bytes(tree, qcfg)


class AdapterCache:
    """LRU / clock adapter cache keyed by client id, wire-format at
    rest, capacity in wire bytes. ``lookup`` counts hits/misses (call
    it at request ADMISSION, one count per request); ``peek`` is the
    uncounted read the decode loop uses."""

    def __init__(self, capacity_bytes: int, qcfg: QuantConfig,
                 policy: str = "lru",
                 registry: Optional[obsm.MetricsRegistry] = None):
        if policy not in ("lru", "clock"):
            raise ValueError(f"unknown eviction policy: {policy!r}")
        if not qcfg.enabled:
            raise ValueError("the serving cache stores the packed wire "
                             "form — quantization must be on")
        self.capacity_bytes = int(capacity_bytes)
        self.qcfg = qcfg
        self.policy = policy
        # metrics ride the obs registry (labeled by eviction policy);
        # the plain-int attributes below stay the per-instance
        # source of truth for stats()/hit_rate and remain resettable
        self.registry = obsm.get_registry(registry)
        self._entries: "collections.OrderedDict[int, CacheEntry]" = \
            collections.OrderedDict()
        self._bytes = 0
        self._bytes_memo: dict[int, int] = {}
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        # monotonically bumped on put/evict; stale staged slabs key off it
        self.version = 0
        # in-flight refcounts: pinned entries are never evicted (a
        # request's adapter must survive until its last decode step)
        self._pins: collections.Counter = collections.Counter()

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, cid: int) -> bool:
        return cid in self._entries

    @property
    def nbytes(self) -> int:
        return self._bytes

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    def stats(self) -> dict:
        return {"entries": len(self._entries), "bytes": self._bytes,
                "capacity_bytes": self.capacity_bytes, "hits": self.hits,
                "misses": self.misses, "evictions": self.evictions,
                "hit_rate": self.hit_rate}

    # -- reads --------------------------------------------------------------

    def lookup(self, cid: int) -> Optional[CacheEntry]:
        e = self._entries.get(cid)
        if e is None:
            self.misses += 1
            self.registry.inc("serve.cache.misses", policy=self.policy)
            return None
        self.hits += 1
        self.registry.inc("serve.cache.hits", policy=self.policy)
        self._touch(e)
        return e

    def peek(self, cid: int) -> Optional[CacheEntry]:
        return self._entries.get(cid)

    def _touch(self, e: CacheEntry) -> None:
        if self.policy == "lru":
            self._entries.move_to_end(e.cid)
        else:
            e.ref = True

    # -- pinning ------------------------------------------------------------

    def pin(self, cid: int) -> None:
        """Refcounted eviction shield for an in-flight request's
        adapter; pair every pin with an unpin at request completion."""
        if cid not in self._entries:
            raise KeyError(f"cannot pin uncached client {cid}")
        self._pins[cid] += 1
        self.registry.inc("serve.cache.pins")
        self.registry.set("serve.cache.pinned", len(self._pins))

    def unpin(self, cid: int) -> None:
        self._pins[cid] -= 1
        if self._pins[cid] <= 0:
            del self._pins[cid]
        self.registry.inc("serve.cache.unpins")
        self.registry.set("serve.cache.pinned", len(self._pins))

    def _pinned(self, cid: int) -> bool:
        return self._pins.get(cid, 0) > 0

    # -- writes -------------------------------------------------------------

    def put(self, cid: int, msg: Any) -> CacheEntry:
        """Insert/replace one client's WIRE message; evicts until the
        byte budget holds."""
        rank, pairs = extract_pairs(msg, self.qcfg.bits)
        if rank not in self._bytes_memo:
            self._bytes_memo[rank] = wire_bytes_of(msg, self.qcfg)
        nbytes = self._bytes_memo[rank]
        if cid in self._entries:
            self._bytes -= self._entries.pop(cid).nbytes
        e = CacheEntry(cid=cid, rank=rank, nbytes=nbytes, pairs=pairs)
        self._entries[cid] = e
        self._bytes += nbytes
        self.version += 1
        self.registry.inc("serve.cache.puts", rank=rank)
        self.registry.inc("serve.cache.put_bytes", nbytes, rank=rank)
        while self._bytes > self.capacity_bytes and len(self._entries) > 1:
            if not self._evict_one(keep=cid):
                break       # everything pinned: run over budget briefly
        self._gauges()
        return e

    def _evict_one(self, keep: int) -> bool:
        """Evict one entry, never ``keep`` or a pinned cid. Returns
        False when no entry is evictable."""
        skip = lambda c: c == keep or self._pinned(c)
        if all(skip(c) for c in self._entries):
            return False
        if self.policy == "lru":
            victim = next(c for c in self._entries if not skip(c))
        else:
            # clock / second-chance: sweep in insertion order, clearing
            # ref bits until an unreferenced evictable entry comes up
            victim = None
            while victim is None:
                cid, e = next(iter(self._entries.items()))
                if not skip(cid) and not e.ref:
                    victim = cid
                else:
                    e.ref = False
                    self._entries.move_to_end(cid)
        self._bytes -= self._entries.pop(victim).nbytes
        self.evictions += 1
        self.version += 1
        self.registry.inc("serve.cache.evictions", policy=self.policy)
        return True

    def _gauges(self) -> None:
        self.registry.set("serve.cache.bytes", self._bytes)
        self.registry.set("serve.cache.entries", len(self._entries))

    # -- host -> device staging --------------------------------------------

    def stage(self, cids: Sequence[int],
              min_slots: int = 1) -> dict[int, StagedBucket]:
        """Stage the given clients' adapters for a decode micro-batch:
        group by pow2 rank bucket, build each bucket's per-layer stacked
        slabs host-side, and upload each buffer ONCE (uploads batch per
        bucket, not per client). Slots pad to pow2, and at least
        ``min_slots`` (the engine passes its micro-batch width), so the
        slab E dim — and with it the serving program's shape — is
        STABLE across batch compositions; padded slots are all-zero and
        never referenced."""
        buckets: dict[int, list[CacheEntry]] = {}
        for cid in dict.fromkeys(cids):         # de-dupe, keep order
            e = self._entries.get(cid)
            if e is None:
                raise KeyError(f"client {cid} is not cached — admit() "
                               "before staging")
            buckets.setdefault(pow2_pad(e.rank), []).append(e)
        out = {}
        for rb, entries in sorted(buckets.items()):
            out[rb] = self._stage_bucket(rb, entries, min_slots)
        return out

    def _stage_bucket(self, rb: int, entries: list[CacheEntry],
                      min_slots: int = 1) -> StagedBucket:
        per = 32 // self.qcfg.bits
        n_slots = max(pow2_pad(len(entries)), pow2_pad(max(min_slots, 1)))
        rwb = -(-rb // per)
        layers = []
        n_layers = len(entries[0].pairs)
        for li in range(n_layers):
            p0 = entries[0].pairs[li]
            kw = p0.aq.shape[1]
            aq = np.zeros((n_slots, rb, kw), np.uint32)
            a_s = np.zeros((n_slots, rb), np.float32)
            a_z = np.zeros((n_slots, rb), np.float32)
            bq = np.zeros((n_slots, p0.d_out, rwb), np.uint32)
            b_s = np.zeros((n_slots, p0.d_out), np.float32)
            b_z = np.zeros((n_slots, p0.d_out), np.float32)
            for slot, e in enumerate(entries):
                p = e.pairs[li]
                aq[slot, :p.rank, :] = p.aq
                a_s[slot, :p.rank] = p.a_scale
                a_z[slot, :p.rank] = p.a_zp
                bq[slot, :, :p.bq.shape[1]] = p.bq
                b_s[slot] = p.b_scale
                b_z[slot] = p.b_zp
            layers.append(StagedLayer(
                jnp.asarray(aq), jnp.asarray(a_s), jnp.asarray(a_z),
                jnp.asarray(bq), jnp.asarray(b_s), jnp.asarray(b_z)))
        return StagedBucket(rank=rb,
                            slots={e.cid: i for i, e in enumerate(entries)},
                            layers=tuple(layers), n_slots=n_slots)
