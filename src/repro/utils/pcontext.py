"""Trace-time sharding-constraint context.

The model code is mesh-agnostic; the launcher installs a constraint
callback around tracing (jit caches the traced graph, so a context
manager at trace time is enough). Layers call ``constrain(x, kind)`` at
the points where XLA's sharding propagation is known to drop shardings
(scan xs/ys buffers, gather/scatter outputs) — without a callback these
are no-ops, so unit tests and the 1-device path are untouched.

Kinds (see launch.steps.make_constrain):
  residual    (B, S, D)      batch x [seq-parallel] x -
  heads       (B, S, H, Dh)  batch x - x model x -
  kv_chunks   (N, B, C, H, D) - x batch x - x model x -
  tokens      (T, D)         batch x -
  expert      (E, C, D)      model x - x -
  cache4      (B, S, Hkv, D) batch x model-on-seq x - x -
  cache3      (B, S, C)      batch x model-on-seq x -
"""
from __future__ import annotations

import contextlib
from typing import Callable, Optional

_current: Optional[Callable] = None


@contextlib.contextmanager
def use(fn: Callable):
    global _current
    prev = _current
    _current = fn
    try:
        yield
    finally:
        _current = prev


def constrain(x, kind: str):
    if _current is None:
        return x
    return _current(x, kind)
