"""Optimizer math + data pipeline invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:         # optional dep: property tests skip, rest runs
    given = settings = st = None

from repro.data import SyntheticVision, lda_partition, markov_lm_batch
from repro.optim import adamw, clip_by_global_norm, sgd
from repro.optim.schedule import cosine_warmup


def test_sgd_momentum_matches_manual():
    opt = sgd(momentum=0.9)
    p = {"w": jnp.asarray([1.0, 2.0])}
    g = {"w": jnp.asarray([0.5, -1.0])}
    s = opt.init(p)
    p1, s1 = opt.update(g, s, p, 0.1)
    np.testing.assert_allclose(np.asarray(p1["w"]),
                               [1.0 - 0.05, 2.0 + 0.1], rtol=1e-6)
    p2, _ = opt.update(g, s1, p1, 0.1)
    # mu2 = 0.9*0.5 + 0.5 = 0.95 ; w = 0.95 - 0.1*0.95
    np.testing.assert_allclose(np.asarray(p2["w"])[0],
                               0.95 - 0.1 * 0.95, rtol=1e-6)


def test_adamw_first_step_is_lr_signed():
    opt = adamw(b1=0.9, b2=0.999, eps=1e-12)
    p = {"w": jnp.zeros(3)}
    g = {"w": jnp.asarray([1.0, -2.0, 0.5])}
    s = opt.init(p)
    p1, _ = opt.update(g, s, p, 0.01)
    np.testing.assert_allclose(np.asarray(p1["w"]),
                               [-0.01, 0.01, -0.01], atol=1e-6)


def test_clip_global_norm():
    g = {"a": jnp.full(4, 3.0), "b": jnp.full(9, 2.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    total = np.sqrt(sum(float(jnp.sum(x ** 2))
                        for x in jax.tree.leaves(clipped)))
    assert abs(total - 1.0) < 1e-5
    assert float(gn) > 1.0


def test_cosine_schedule_endpoints():
    f = cosine_warmup(1.0, warmup=10, total=110, floor=0.1)
    assert float(f(0)) == 0.0
    assert abs(float(f(10)) - 1.0) < 1e-6
    assert abs(float(f(110)) - 0.1) < 1e-6


if st is not None:
    @settings(max_examples=20, deadline=None)
    @given(alpha=st.floats(0.1, 10.0), n_clients=st.integers(2, 30),
           seed=st.integers(0, 1000))
    def test_property_lda_partition_covers_all(alpha, n_clients, seed):
        rng = np.random.default_rng(seed)
        labels = rng.integers(0, 10, 500)
        parts = lda_partition(labels, n_clients, alpha, seed=seed)
        allidx = np.concatenate(parts)
        assert len(allidx) == 500
        assert len(np.unique(allidx)) == 500      # exact cover, no dupes
        assert min(len(p) for p in parts) >= 2


def test_lda_skew_increases_as_alpha_drops():
    rng = np.random.default_rng(0)
    labels = rng.integers(0, 10, 2000)

    def skew(alpha):
        parts = lda_partition(labels, 10, alpha, seed=1)
        stds = []
        for p in parts:
            hist = np.bincount(labels[p], minlength=10) / len(p)
            stds.append(hist.std())
        return np.mean(stds)

    assert skew(0.1) > skew(100.0)


def test_markov_lm_is_learnable_structure():
    rng = np.random.default_rng(0)
    b = markov_lm_batch(rng, vocab=64, batch=16, seq=32, seed=0)
    assert b["tokens"].shape == (16, 33)
    # next-token entropy is far below uniform: count distinct successors
    nxt, w = None, None
    from repro.data.synthetic import _markov_tables
    nxt, w = _markov_tables(64, 0)
    assert nxt.shape[1] == 8                       # sparse support


def test_synthetic_vision_classes_separable():
    sv = SyntheticVision(seed=0)
    rng = np.random.default_rng(0)
    y = np.arange(10).repeat(8)
    x = sv.sample(rng, y)
    # nearest-template classification should beat chance by a wide margin
    # (shift+noise keeps it below ceiling; a CNN learns invariances on top)
    t = sv.templates.reshape(10, -1)
    d = ((x.reshape(len(y), -1)[:, None] - t[None]) ** 2).sum(-1)
    acc = (d.argmin(1) == y).mean()
    assert acc > 0.6, acc


if st is None:
    def test_property_lda_partition_covers_all():
        pytest.skip("hypothesis not installed")
