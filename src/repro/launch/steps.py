"""Builds jittable, sharded step programs per (arch x shape x mesh).

For each cell the builder returns (fn, arg_specs, in_shardings,
out_shardings, donate) ready for jax.jit(...).lower(*arg_specs) — the
dry-run compiles them AOT with ShapeDtypeStructs (no allocation) and the
real trainer calls them with materialized params.

Plans (memory policy) per cell:
  * microbatch gradient accumulation (lax.scan) — scales activation
    memory down by M for the big-arch train cells;
  * seq_parallel — Megatron-SP-style residual-stream constraint
    P(batch=('pod','data'), seq='model') so remat-saved activations are
    sharded 16x on the tensor axis (required for nemotron-340b train);
  * donate params/opt-state/caches for in-place update buffers.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.registry import SHAPES, ArchEntry, input_specs
from repro.models import encdec as ED
from repro.models import lm as LM
from repro.optim import adamw
from repro.utils import pcontext
from repro.utils.sharding import tree_shardings, DEFAULT_RULES

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class CellPlan:
    microbatch: int = 1
    seq_parallel: bool = False
    rules: Optional[dict] = None       # sharding-rule overrides (perf iter)
    kv_cache_dtype: Any = jnp.bfloat16
    quantize_base: bool = False        # int8 frozen base (beyond paper)
    cfg_updates: Optional[dict] = None  # dataclasses.replace overrides


# default memory plans per (arch, shape); anything absent -> CellPlan()
DEFAULT_PLANS: dict[tuple[str, str], CellPlan] = {
    ("nemotron-4-340b", "train_4k"): CellPlan(microbatch=8,
                                              seq_parallel=True),
    ("qwen1.5-110b", "train_4k"): CellPlan(microbatch=8, seq_parallel=True),
    ("llama4-maverick-400b-a17b", "train_4k"): CellPlan(microbatch=16,
                                                        seq_parallel=True),
    ("deepseek-v2-236b", "train_4k"): CellPlan(microbatch=16,
                                               seq_parallel=True),
    ("minitron-4b", "train_4k"): CellPlan(microbatch=4),
    ("gemma3-4b", "train_4k"): CellPlan(microbatch=4),
    ("paligemma-3b", "train_4k"): CellPlan(microbatch=4),
    ("zamba2-2.7b", "train_4k"): CellPlan(microbatch=4),
    ("mamba2-370m", "train_4k"): CellPlan(microbatch=4),
    ("seamless-m4t-medium", "train_4k"): CellPlan(microbatch=8),
    ("nemotron-4-340b", "prefill_32k"): CellPlan(seq_parallel=True),
    ("qwen1.5-110b", "prefill_32k"): CellPlan(seq_parallel=True),
}


def plan_for(arch: str, shape: str) -> CellPlan:
    return DEFAULT_PLANS.get((arch, shape), CellPlan())


def make_constrain(mesh: Mesh, plan: CellPlan) -> Callable:
    """Kind-dispatching sharding constraint (see utils.pcontext).

    Every rule is best-effort: a dim that the target axis size does not
    divide falls back to unsharded rather than erroring."""
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bsz = _size(mesh, batch_axes)
    msz = _size(mesh, ("model",))
    sp = plan.seq_parallel and "model" in mesh.axis_names

    def _c(x, spec):
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    def div(n, size):
        return size > 1 and n % size == 0

    def constrain(x, kind: str = "residual"):
        if kind == "residual" and x.ndim == 3:
            if not div(x.shape[0], bsz):
                return x
            seq = "model" if (sp and div(x.shape[1], msz)) else None
            return _c(x, P(batch_axes, seq, None))
        if kind == "heads" and x.ndim == 4:
            if not div(x.shape[0], bsz):
                return x
            hd = "model" if div(x.shape[2], msz) else None
            return _c(x, P(batch_axes, None, hd, None))
        if kind == "kv_chunks" and x.ndim == 5:
            if not div(x.shape[1], bsz):
                return x
            hd = "model" if div(x.shape[3], msz) else None
            return _c(x, P(None, batch_axes, None, hd, None))
        if kind == "tokens" and x.ndim == 2:
            # token rows shard over batch AND model axes (1M-token MoE
            # dispatch buffers must not hold 16-way-only shards)
            if div(x.shape[0], bsz * msz):
                return _c(x, P(batch_axes + ("model",), None))
            if div(x.shape[0], bsz):
                return _c(x, P(batch_axes, None))
            return x
        if kind == "expert" and x.ndim == 3:
            if not div(x.shape[0], msz):
                return x
            cap = "data" if div(x.shape[1], _size(mesh, ("data",))) \
                else None
            return _c(x, P("model", cap, None))
        if kind == "cache4" and x.ndim == 4:
            b = batch_axes if div(x.shape[0], bsz) else ()
            seq = "model" if div(x.shape[1], msz) else None
            if not b and seq is None:
                return x
            return _c(x, P(b or None, seq, None, None))
        if kind == "cache3" and x.ndim == 3:
            b = batch_axes if div(x.shape[0], bsz) else ()
            seq = "model" if div(x.shape[1], msz) else None
            if not b and seq is None:
                return x
            return _c(x, P(b or None, seq, None))
        if kind == "cache_stack" and x.ndim >= 3:
            # (layers, B, S, ...) preallocated prefill cache
            b = batch_axes if div(x.shape[1], bsz) else ()
            seq = "model" if div(x.shape[2], msz) else None
            if not b and seq is None:
                return x
            rest = (None,) * (x.ndim - 3)
            return _c(x, P(None, b or None, seq, *rest))
        return x

    return constrain


def _size(mesh: Mesh, axes) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = 1
    for a in axes:
        n *= sizes.get(a, 1)
    return n


def _batch_sharding(mesh: Mesh, spec_tree: Any) -> Any:
    """Shard leading batch dim of every batch leaf over (pod, data);
    leaves whose batch dim is indivisible stay replicated."""
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bsz = _size(mesh, batch_axes)

    def one(x):
        # microbatched leaves are (M, B, ...): shard dim 1, else dim 0
        if x.ndim >= 2 and x.shape[0] < x.shape[1] and x.shape[1] % bsz == 0 \
                and x.shape[0] <= 64:
            return NamedSharding(mesh, P(None, batch_axes))
        if x.shape[0] % bsz == 0:
            return NamedSharding(mesh, P(batch_axes))
        return NamedSharding(mesh, P())

    return jax.tree.map(one, spec_tree)


def _opt_shardings(mesh: Mesh, sh_train: Any) -> dict:
    return {"mu": sh_train, "nu": sh_train,
            "count": NamedSharding(mesh, P())}


def build_cell(entry: ArchEntry, shape_name: str, mesh: Mesh,
               plan: Optional[CellPlan] = None,
               cfg_override: Any = None) -> dict:
    """Returns dict(fn, args, in_shardings, out_shardings, donate)."""
    plan = plan or plan_for(entry.arch_id, shape_name)
    cfg = cfg_override or entry.full()
    if plan.cfg_updates:
        cfg = dataclasses.replace(cfg, **plan.cfg_updates)
    rules = dict(DEFAULT_RULES)
    if plan.rules:
        rules.update(plan.rules)
    step = SHAPES[shape_name]["step"]
    mod = ED if entry.kind == "encdec" else LM
    key = jax.random.PRNGKey(0)
    shapes = jax.eval_shape(
        lambda k: {g: t for g, t in mod.init(k, cfg).items()
                   if g in ("frozen", "train")}, key)
    logical = mod.logical(cfg)
    if plan.quantize_base:
        from repro.core.lora import quantize_frozen_tree, \
            quantize_frozen_logical
        shapes = {"frozen": jax.eval_shape(quantize_frozen_tree,
                                           shapes["frozen"]),
                  "train": shapes["train"]}
        logical = {"frozen": quantize_frozen_logical(logical["frozen"]),
                   "train": logical["train"]}
    sh_frozen = tree_shardings(logical["frozen"], shapes["frozen"], mesh,
                               rules)
    sh_train = tree_shardings(logical["train"], shapes["train"], mesh,
                              rules)
    constrain = make_constrain(mesh, plan)
    specs = input_specs(entry, cfg, shape_name)

    if step == "train":
        return _build_train(entry, cfg, mesh, plan, shapes, sh_frozen,
                            sh_train, constrain, specs, mod)
    if step == "prefill":
        return _build_prefill(entry, cfg, mesh, plan, shapes, sh_frozen,
                              sh_train, constrain, specs, mod)
    return _build_decode(entry, cfg, mesh, plan, shapes, sh_frozen,
                         sh_train, constrain, specs, mod, shape_name)


# ---------------------------------------------------------------------------

def _micro_reshape(specs: Any, m: int) -> Any:
    def one(x):
        assert x.shape[0] % m == 0, (x.shape, m)
        return jax.ShapeDtypeStruct((m, x.shape[0] // m) + x.shape[1:],
                                    x.dtype)
    return jax.tree.map(one, specs)


def _build_train(entry, cfg, mesh, plan, shapes, sh_frozen, sh_train,
                 constrain, specs, mod):
    opt = adamw(weight_decay=0.0)
    opt_shapes = jax.eval_shape(opt.init, shapes["train"])
    sh_opt = _opt_shardings(mesh, sh_train)
    m = plan.microbatch
    batch_specs = _micro_reshape(specs["batch"], m) if m > 1 \
        else specs["batch"]
    sh_batch = _batch_sharding(mesh, batch_specs)

    loss_fn = mod.loss_fn

    def train_step(frozen, train, opt_state, batch):
        def one_micro(tr, mb):
            with pcontext.use(constrain):
                loss, metrics = loss_fn(frozen, tr, cfg, mb,
                                        lambda x: constrain(x, "residual"))
            return loss, metrics

        if m == 1:
            (loss, metrics), grads = jax.value_and_grad(
                one_micro, has_aux=True)(train, batch)
        else:
            def acc_body(carry, mb):
                gsum, lsum = carry
                (l, _), g = jax.value_and_grad(one_micro, has_aux=True)(
                    train, mb)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + l), None

            g0 = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32),
                              train)
            (grads, lsum), _ = jax.lax.scan(
                acc_body, (g0, jnp.zeros((), jnp.float32)), batch)
            grads = jax.tree.map(lambda g: g / m, grads)
            loss = lsum / m
        train, opt_state = opt.update(grads, opt_state, train, 3e-4)
        return train, opt_state, {"loss": loss}

    args = (shapes["frozen"], shapes["train"], opt_shapes, batch_specs)
    in_sh = (sh_frozen, sh_train, sh_opt, sh_batch)
    out_sh = (sh_train, sh_opt, None)
    return {"fn": train_step, "args": args, "in_shardings": in_sh,
            "out_shardings": out_sh, "donate": (1, 2), "cfg": cfg,
            "plan": plan}


def _build_prefill(entry, cfg, mesh, plan, shapes, sh_frozen, sh_train,
                   constrain, specs, mod):
    sh_batch = _batch_sharding(mesh, specs["batch"])

    if entry.kind == "encdec":
        def prefill_step(frozen, train, batch):
            with pcontext.use(constrain):
                memory = ED.encode(frozen, train, cfg, batch["src_embed"],
                                   lambda x: constrain(x, "residual"))
                cross = ED.cross_cache(frozen, train, cfg, memory)
                cross = jax.tree.map(
                    lambda c: constrain(c, "cache4") if c.ndim == 5 else c,
                    cross)
            return cross

        args = (shapes["frozen"], shapes["train"], specs["batch"])
    else:
        def prefill_step(frozen, train, batch):
            with pcontext.use(constrain):
                logits, caches, pos = LM.prefill(
                    frozen, train, cfg, batch["tokens"],
                    batch.get("prefix_embed"),
                    lambda x: constrain(x, "residual"))
            return logits, caches, pos

        args = (shapes["frozen"], shapes["train"], specs["batch"])
    return {"fn": prefill_step, "args": args,
            "in_shardings": (sh_frozen, sh_train, sh_batch),
            "out_shardings": None, "donate": (), "cfg": cfg, "plan": plan}


def _build_decode(entry, cfg, mesh, plan, shapes, sh_frozen, sh_train,
                  constrain, specs, mod, shape_name):
    rules = dict(DEFAULT_RULES)
    rules["kv_seq"] = ("model", "data")    # split-KV decode (DESIGN.md §3)
    if plan.rules:
        rules.update(plan.rules)
    sh_batch = _batch_sharding(mesh, specs["batch"])
    pos_spec = jax.ShapeDtypeStruct((), jnp.int32)
    pos_sh = NamedSharding(mesh, P())

    if entry.kind == "encdec":
        from repro.models import attention as A
        log_one = jax.tree.map(
            lambda t: ("layers",) + t, A.gqa_cache_logical(),
            is_leaf=lambda t: isinstance(t, tuple) and all(
                isinstance(e, (str, type(None))) for e in t))
        sh_self = tree_shardings(log_one, specs["self_caches"], mesh, rules)
        log_cross = {"k": ("layers", "batch", "kv_seq", None, None),
                     "v": ("layers", "batch", "kv_seq", None, None)}
        sh_cross = tree_shardings(log_cross, specs["cross_caches"], mesh,
                                  rules)

        def decode_step(frozen, train, batch, self_caches, cross_caches,
                        pos):
            with pcontext.use(constrain):
                return ED.decode_step(frozen, train, cfg, batch["token"],
                                      self_caches, cross_caches, pos)

        args = (shapes["frozen"], shapes["train"], specs["batch"],
                specs["self_caches"], specs["cross_caches"], pos_spec)
        in_sh = (sh_frozen, sh_train, sh_batch, sh_self, sh_cross, pos_sh)
        return {"fn": decode_step, "args": args, "in_shardings": in_sh,
                "out_shardings": None, "donate": (3,), "cfg": cfg,
                "plan": plan}

    log_caches = LM.cache_logical(cfg)
    sh_caches = tree_shardings(log_caches, specs["caches"], mesh, rules)

    def decode_step(frozen, train, batch, caches, pos):
        with pcontext.use(constrain):
            return LM.decode_step(frozen, train, cfg, batch["token"],
                                  caches, pos)

    args = (shapes["frozen"], shapes["train"], specs["batch"],
            specs["caches"], pos_spec)
    in_sh = (sh_frozen, sh_train, sh_batch, sh_caches, pos_sh)
    return {"fn": decode_step, "args": args, "in_shardings": in_sh,
            "out_shardings": None, "donate": (3,), "cfg": cfg, "plan": plan}
