"""Client latency/availability/churn traces for the federation engines.

A production fleet of millions of devices is not round-lockstep: a
client's update arrives whenever its compute + network latency and its
availability windows allow — if it arrives at all (devices churn
mid-round: the app is closed, the phone unplugs, the uplink dies). This
module supplies the PLUGGABLE timing models that ``fl/async_engine.py``
schedules dispatch/arrival events with (and that ``fl/server.py`` orders
deadline cohorts by):

  * :class:`LognormalLatency` — lognormal compute time scaled by the
    client's adapter-rank tier (a rank-32 workstation trains longer than
    a rank-4 phone per step, but the tier also proxies device speed via
    ``rank_exp``) plus wire-transfer time at a lognormal-jittered
    throughput, so bigger messages genuinely take longer;
  * :class:`AvailabilityWindows` — periodic per-client availability
    (phones charge at night): a dispatch outside the client's window
    waits for the next one;
  * :class:`FleetTrace` — composes the two, adds mid-round CHURN
    (``p_churn``: a dispatched client drops before its uplink lands),
    and owns DETERMINISTIC REPLAY: every latency and churn draw is
    keyed by ``(seed, cid, dispatch_idx)`` through a fresh
    ``np.random.Generator``, so the trace is a pure function of those
    ids — independent of event processing order and of
    checkpoint/resume boundaries. Replaying a run (or resuming a killed
    one) reproduces every arrival time and churn outcome bit-exactly.

The per-client hooks (``availability_for`` / ``p_churn_for``) make the
trace composable with a lazy :class:`~repro.fl.population.Population`:
``PopulationTrace`` overrides them to read each client's DEVICE TIER
(diurnal window, churn rate) without materializing any per-client state.

All times are VIRTUAL seconds on the simulator clock.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

# rng key domains for trace draws (the engines use their own domains for
# client sampling, batch shuffling and failure draws; disjoint first
# keys keep every stream independent under the shared seed)
TAG_LATENCY = 0xA1
TAG_CHURN = 0xA2

# __post_init__ rejects throughput configs whose jittered draw could
# plausibly underflow the 1 byte/s floor in ``sample``: lognormal(0, s)
# stays above exp(-_JITTER_LOG_RANGE * s) except with probability
# ~1e-9 (the 6-sigma left tail), so any config passing the check never
# actually hits the floor in a simulated fleet's lifetime.
_JITTER_LOG_RANGE = 6.0


@dataclasses.dataclass(frozen=True)
class LognormalLatency:
    """Per-arrival latency = compute + transfer.

    Transfer-time model: the configured link rate ``network_mbps``
    (megaBITS per second) converts to bytes/s, one lognormal draw
    jitters the WHOLE transfer (per-arrival congestion, not per-packet),
    and the message pays ``wire_bytes / (bytes_per_s * jitter)``
    seconds:

        compute  ~ compute_median_s * lognormal(0, compute_sigma)
                   * (rank / rank_ref) ** rank_exp
        bytes_per_s = network_mbps * 1e6 / 8 * lognormal(0, network_sigma)
        transfer = wire_bytes / bytes_per_s

    ``rank_exp > 0`` makes higher-rank tiers slower (more adapter math
    per step); 0 decouples compute time from the tier.

    ``__post_init__`` rejects configs whose jittered throughput could
    plausibly underflow 1 byte/s (the numeric floor in :meth:`sample`):
    the floor exists only as a division guard, and silently flooring a
    *configured* sub-byte/s link would make transfers FASTER than
    configured — fail loudly at construction instead.
    """
    compute_median_s: float = 30.0
    compute_sigma: float = 0.6
    network_mbps: float = 20.0
    network_sigma: float = 0.4
    rank_ref: int = 8
    rank_exp: float = 1.0

    def __post_init__(self):
        if self.compute_median_s <= 0 or self.network_mbps <= 0:
            raise ValueError("latency medians must be positive")
        if self.compute_sigma < 0 or self.network_sigma < 0:
            raise ValueError("sigmas must be >= 0")
        if self.rank_ref < 1:
            raise ValueError("rank_ref must be >= 1")
        worst_bps = self.network_mbps * 1e6 / 8.0 \
            * math.exp(-_JITTER_LOG_RANGE * self.network_sigma)
        if worst_bps < 1.0:
            raise ValueError(
                f"network_mbps={self.network_mbps} with network_sigma="
                f"{self.network_sigma} can jitter below 1 byte/s "
                f"(6-sigma draw: {worst_bps:.3g} B/s) — the sample-time "
                "floor would silently speed such transfers up; raise "
                "network_mbps or lower network_sigma")

    def sample(self, rng: np.random.Generator, rank: int,
               wire_bytes: int) -> float:
        comp = (self.compute_median_s
                * rng.lognormal(0.0, self.compute_sigma)
                * (max(rank, 1) / self.rank_ref) ** self.rank_exp)
        # max() is a pure division guard: __post_init__ rejects any
        # config that could plausibly reach it (see class docstring)
        bps = self.network_mbps * 1e6 / 8.0 \
            * rng.lognormal(0.0, self.network_sigma)
        return comp + wire_bytes / max(bps, 1.0)


@dataclasses.dataclass(frozen=True)
class AvailabilityWindows:
    """Periodic per-client availability: client ``cid`` is available for
    the first ``duty`` fraction of every ``period_s`` window, with a
    deterministic per-client phase (a Knuth-hash spread, so the fleet's
    windows are staggered instead of synchronized). ``period_s = 0`` or
    ``duty >= 1`` means always available."""
    period_s: float = 0.0
    duty: float = 1.0

    def __post_init__(self):
        if self.period_s < 0:
            raise ValueError("period_s must be >= 0")
        if not 0.0 < self.duty <= 1.0:
            raise ValueError("duty must be in (0, 1]")

    def phase(self, cid: int) -> float:
        if self.period_s <= 0:
            return 0.0
        return ((cid * 2654435761) % (1 << 32)) / float(1 << 32) \
            * self.period_s

    def next_available(self, cid: int, t: float) -> float:
        """Earliest time >= t at which client cid is available."""
        if self.period_s <= 0 or self.duty >= 1.0:
            return t
        pos = (t - self.phase(cid)) % self.period_s
        if pos < self.duty * self.period_s:
            return t
        return t + (self.period_s - pos)


@dataclasses.dataclass(frozen=True)
class FleetTrace:
    """Deterministic-replay fleet timing model.

    ``arrival(cid, dispatch_idx, rank, wire_bytes, t_dispatch)`` returns
    the virtual time at which that dispatch's update reaches the server:
    availability wait, then the sampled compute+transfer latency.
    ``churned(cid, dispatch_idx)`` decides whether that dispatch DROPS
    mid-round (the downlink was spent, the uplink never lands). Both
    draws are pure functions of ``(seed, cid, dispatch_idx)`` — see the
    module docstring for why that makes runs replayable.

    Subclasses (e.g. ``PopulationTrace``) override the per-client hooks
    ``availability_for`` / ``p_churn_for`` to model heterogeneous
    device tiers without per-client state."""
    seed: int = 0
    latency: LognormalLatency = dataclasses.field(
        default_factory=LognormalLatency)
    availability: AvailabilityWindows = dataclasses.field(
        default_factory=AvailabilityWindows)
    p_churn: float = 0.0

    def __post_init__(self):
        if not 0.0 <= self.p_churn < 1.0:
            raise ValueError("p_churn must be in [0, 1)")

    # -- per-client hooks (uniform here; tiered in PopulationTrace) ---------
    def availability_for(self, cid: int) -> AvailabilityWindows:
        return self.availability

    def p_churn_for(self, cid: int) -> float:
        return self.p_churn

    def arrival(self, cid: int, dispatch_idx: int, rank: int,
                wire_bytes: int, t_dispatch: float) -> float:
        rng = np.random.default_rng(
            [self.seed, TAG_LATENCY, cid, dispatch_idx])
        t0 = self.availability_for(cid).next_available(cid, t_dispatch)
        return t0 + self.latency.sample(rng, rank, wire_bytes)

    def churned(self, cid: int, dispatch_idx: int) -> bool:
        """True when this dispatch drops mid-round. Keyed like the
        latency draw, so replay/resume reproduces every churn outcome."""
        p = self.p_churn_for(cid)
        if p <= 0.0:
            return False
        rng = np.random.default_rng(
            [self.seed, TAG_CHURN, cid, dispatch_idx])
        return bool(rng.random() < p)
