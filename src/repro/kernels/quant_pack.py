"""Pallas TPU kernel: fused per-channel affine quantize + bit-pack.

One VMEM pass per channel block: row min/max -> (scale, zp) -> RTN levels
-> little-endian pack into uint32 words. Replaces three XLA passes
(reduce, elementwise, gather/shift) with one streaming kernel — the
client-uplink hot loop is memory-bound, so the win is touching HBM once.

The valid-column count is PER ROW: ``n_valid`` rides as a tiny (C, 1)
int32 sidecar input (the SMEM-scalar-prefetch equivalent of the flat
codec's row-length vector) and masks both the qparam min/max reduction
and the packed tail of each row. A uniform tensor passes a constant
vector; the FLAT-TREE codec (core/flat.py) packs EVERY leaf of a message
as one ragged (C_total, N_max) buffer in a single launch, each row
masked to its own leaf's true length.

Tiling: grid over channel blocks; each step holds an (BC, N) fp32 tile
plus its (BC, N/per) uint32 output in VMEM. BC=8 sublanes; N padded to a
multiple of 128*per by the wrapper (ops.py) so lanes stay aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

Array = jax.Array


def _quant_pack_kernel(x_ref, nv_ref, packed_ref, scale_ref, zp_ref, *,
                       bits: int):
    x = x_ref[...].astype(jnp.float32)                    # (bc, N)
    n = x.shape[1]
    qmax = (1 << bits) - 1
    per = 32 // bits
    # mask each row's padded tail out of the min/max (pad value 0 is safe
    # for the affine range because 0 is always included, but stay exact)
    nv = nv_ref[...]                                      # (bc, 1) int32
    col = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    valid = col < nv
    big = jnp.float32(3.4e38)
    xmin = jnp.minimum(jnp.min(jnp.where(valid, x, big), axis=1), 0.0)
    xmax = jnp.maximum(jnp.max(jnp.where(valid, x, -big), axis=1), 0.0)
    rng = xmax - xmin
    # multiply by the f32 reciprocal constant instead of dividing by
    # qmax: XLA strength-reduces constant divisions inconsistently
    # across programs, and the flat codec's jnp twin must reproduce the
    # kernel's scale BIT-exactly
    scale = jnp.where(rng > 0, rng * jnp.float32(1.0 / qmax), 1.0)
    zp = jnp.clip(jnp.round(-xmin / scale), 0, qmax)
    q = jnp.round(x / scale[:, None]) + zp[:, None]
    # canonical zero padding past each row's n_valid: packed words are
    # byte-identical to the host/wire re-packing paths (messages/flat)
    q = jnp.where(valid, jnp.clip(q, 0, qmax), 0)
    q = q.astype(jnp.uint32)
    # pack `per` levels into each uint32 word (little-endian)
    grp = q.reshape(q.shape[0], n // per, per)
    shifts = (jax.lax.broadcasted_iota(jnp.uint32, grp.shape, 2)
              * jnp.uint32(bits))
    packed_ref[...] = jnp.sum(grp << shifts, axis=-1).astype(jnp.uint32)
    scale_ref[...] = scale[:, None]
    zp_ref[...] = zp[:, None]


def quant_pack_pallas(x: Array, bits: int, *,
                      n_valid: int | Array | None = None,
                      block_c: int = 8, interpret: bool = False):
    """x: (C, N) fp32, N % (32/bits * 128) == 0 (wrapper pads).

    ``n_valid`` is the true (unpadded) column count — a scalar for a
    uniform tensor or a (C,) vector for a ragged flat-tree buffer.
    Columns past each row's count are excluded from the min/max and
    packed as level 0 (rows with ``n_valid == 0`` emit all-zero words
    with scale 1, zp 0 — the degenerate-channel convention).

    Returns (packed (C, N*bits/32) uint32, scale (C,), zp (C,))."""
    c, n = x.shape
    per = 32 // bits
    assert c % block_c == 0 and n % per == 0
    if n_valid is None:
        n_valid = n
    if isinstance(n_valid, (int, np.integer)):
        assert 0 < n_valid <= n
        nv = jnp.full((c, 1), n_valid, jnp.int32)
    else:
        nv = jnp.asarray(n_valid, jnp.int32).reshape(c, 1)
    nw = n // per
    grid = (c // block_c,)
    packed, scale, zp = pl.pallas_call(
        functools.partial(_quant_pack_kernel, bits=bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_c, n), lambda i: (i, 0)),
            pl.BlockSpec((block_c, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_c, nw), lambda i: (i, 0)),
            pl.BlockSpec((block_c, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_c, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((c, nw), jnp.uint32),
            jax.ShapeDtypeStruct((c, 1), jnp.float32),
            jax.ShapeDtypeStruct((c, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x, nv)
    return packed, scale[:, 0], zp[:, 0]
