"""Message codec + aggregation semantics (the FL round math)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:         # optional dep: property tests skip, rest runs
    given = settings = st = None

from repro.core import aggregation, flocora, messages
from repro.core.flocora import FLoCoRAConfig
from repro.core.quant import QuantConfig


def _tree(key, scale=1.0):
    ks = jax.random.split(key, 3)
    return {"a": jax.random.normal(ks[0], (6, 8)) * scale,
            "b": jax.random.normal(ks[1], (4, 3, 5)) * scale,
            "norm": jax.random.normal(ks[2], (7,)) * scale}


def test_codec_roundtrip_shapes_and_error():
    t = _tree(jax.random.PRNGKey(0), 2.0)
    for bits in (2, 4, 8):
        rt = messages.roundtrip(t, QuantConfig(bits=bits))
        assert jax.tree.all(jax.tree.map(
            lambda a, b: a.shape == b.shape, t, rt))
        # 1-D leaves pass through exactly (norms not quantized)
        np.testing.assert_array_equal(np.asarray(rt["norm"]),
                                      np.asarray(t["norm"]))
        err = float(jnp.max(jnp.abs(rt["a"] - t["a"])))
        assert err < 8.0 / ((1 << bits) - 1)


def test_fedavg_weighted_mean():
    trees = [_tree(jax.random.PRNGKey(i)) for i in range(4)]
    w = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    stacked = aggregation.stack_trees(trees)
    agg = aggregation.fedavg(stacked, w)
    manual = sum((wi / 10.0) * t["a"] for wi, t in zip([1, 2, 3, 4], trees))
    np.testing.assert_allclose(np.asarray(agg["a"]), np.asarray(manual),
                               rtol=1e-5, atol=1e-6)


def test_server_round_quantized_close_to_fp():
    trees = [_tree(jax.random.PRNGKey(i)) for i in range(5)]
    w = jnp.ones(5)
    stacked = aggregation.stack_trees(trees)
    fp = aggregation.fedavg(stacked, w)
    q8 = flocora.server_round(stacked, w, FLoCoRAConfig(quant_bits=8))
    err = float(jnp.max(jnp.abs(fp["a"] - q8["a"])))
    assert 0 < err < 0.05


def test_error_feedback_reduces_bias():
    """EF: time-averaged quantization error decays vs plain RTN."""
    cfg = QuantConfig(bits=2)
    x = {"w": jax.random.normal(jax.random.PRNGKey(0), (4, 64)) * 0.7}
    res = aggregation.ef_init(x)
    recon_sum_ef = jnp.zeros_like(x["w"])
    recon_sum_rtn = jnp.zeros_like(x["w"])
    n = 24
    for _ in range(n):
        recon, res = aggregation.ef_encode(x, res, cfg)
        recon_sum_ef += recon["w"]
        recon_sum_rtn += messages.roundtrip(x, cfg)["w"]
    bias_ef = float(jnp.mean(jnp.abs(recon_sum_ef / n - x["w"])))
    bias_rtn = float(jnp.mean(jnp.abs(recon_sum_rtn / n - x["w"])))
    assert bias_ef < bias_rtn * 0.7 or bias_ef < 1e-3


def test_fedbuff_staleness_weighting():
    like = {"w": jnp.zeros((2, 2))}
    st_ = aggregation.fedbuff_init(like)
    u1 = {"w": jnp.ones((2, 2))}
    u2 = {"w": 3 * jnp.ones((2, 2))}
    st_ = aggregation.fedbuff_add(st_, u1, jnp.asarray(1.0),
                                  jnp.asarray(0.0), half_life=1.0)
    st_ = aggregation.fedbuff_add(st_, u2, jnp.asarray(1.0),
                                  jnp.asarray(1.0), half_life=1.0)
    agg, st2 = aggregation.fedbuff_flush(st_, like)
    # weights 1 and 0.5 -> (1*1 + 0.5*3) / 1.5 = 5/3
    np.testing.assert_allclose(np.asarray(agg["w"]),
                               np.full((2, 2), 5 / 3), rtol=1e-5)
    assert int(st2.count) == 0


if st is not None:
    @settings(max_examples=25, deadline=None)
    @given(bits=st.sampled_from([4, 8]), k=st.integers(2, 6),
           seed=st.integers(0, 2**31 - 1))
    def test_property_quantized_fedavg_error_bounded(bits, k, seed):
        """Aggregated quantization error <= max client scale/2."""
        keys = jax.random.split(jax.random.PRNGKey(seed), k)
        trees = [{"w": jax.random.normal(kk, (3, 32))} for kk in keys]
        w = jnp.ones(k)
        stacked = aggregation.stack_trees(trees)
        fp = aggregation.fedavg(stacked, w)
        q = aggregation.fedavg_quantized(stacked, w, QuantConfig(bits=bits))
        err = float(jnp.max(jnp.abs(fp["w"] - q["w"])))
        from repro.core.quant import affine_qparams
        smax = max(float(jnp.max(affine_qparams(t["w"], bits, 1)[0]))
                   for t in trees)
        assert err <= smax / 2 + 1e-5


def test_wire_bytes_accounting_manual():
    t = {"m": jnp.zeros((10, 6)), "v": jnp.zeros((5,))}
    # int8: 60 payload + 6 ch * 8 sidecar + 5*4 fp = 60+48+20 = 128
    assert messages.message_wire_bytes(t, QuantConfig(bits=8)) == 128
    # int4: ceil(60/2)=30 + 48 + 20 = 98
    assert messages.message_wire_bytes(t, QuantConfig(bits=4)) == 98
    # fp: (60+5)*4 = 260
    assert messages.message_wire_bytes(t, QuantConfig()) == 260


if st is None:
    def test_property_quantized_fedavg_error_bounded():
        pytest.skip("hypothesis not installed")
