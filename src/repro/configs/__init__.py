from repro.configs.registry import REGISTRY, SHAPES, ArchEntry, get, \
    cells, input_specs
