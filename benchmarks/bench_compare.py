"""Bench regression gate: diff a fresh ``--json`` run against a
committed baseline (BENCH_5.json / BENCH_7.json / ...).

Rows are matched BY NAME. For each row present in both files:

  * ``time_us`` — gated on the ratio new/old against a threshold
    (default ``--threshold 1.5``: generous, because the committed
    baselines and CI runners are noisy shared-CPU boxes; tighten with
    per-row overrides ``--row-threshold name=ratio`` for rows known to
    be stable). Rows missing ``time_us`` on either side are skipped for
    timing (untimed rows omit the key by design — see
    ``round_throughput.row``);
  * ``bytes`` — wire sizes are DETERMINISTIC: any change is reported as
    a regression (byte drift means the codec changed, which is a
    correctness event, not noise);
  * counter-like fields (``programs``, ``compiles``) — an INCREASE is a
    regression (more compiled programs = a retracing leak).

Rows only in the baseline are reported missing (a renamed/deleted
measurement should update the baseline deliberately); rows only in the
new run are informational.

Cross-backend comparisons are refused via the ``meta`` block
(``repro.obs.meta.comparable``: backend / device kind / jax version
must agree) unless ``--allow-cross-backend`` — a CPU baseline says
nothing about a GPU regression. Baselines predating the meta block
compare without the check.

Exit status: 0 when clean (or ``--warn-only``), 1 on any regression.

    PYTHONPATH=src python -m benchmarks.bench_compare \
        BENCH_5.json bench_flat.json [--threshold 1.5] \
        [--row-threshold flat/agg_flat_k16=1.3] [--warn-only]
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.obs.meta import comparable

# fields where MORE is a regression regardless of timing noise
COUNTER_KEYS = ("programs", "compiles")


def load(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    if "rows" not in doc:
        raise SystemExit(f"{path}: not a bench JSON (no 'rows')")
    return doc


def index_rows(doc: dict) -> dict[str, dict]:
    out: dict[str, dict] = {}
    for r in doc["rows"]:
        # later duplicates win (sweeps may re-emit a row per K; names
        # embed K so real sweeps never collide)
        out[r["name"]] = r
    return out


def compare(base: dict, new: dict, threshold: float,
            row_thresholds: dict[str, float]) -> tuple[list[str],
                                                       list[str]]:
    """Returns (regressions, notes) as printable strings."""
    b_rows, n_rows = index_rows(base), index_rows(new)
    regressions: list[str] = []
    notes: list[str] = []
    for name, b in b_rows.items():
        n = n_rows.get(name)
        if n is None:
            regressions.append(f"{name}: row missing from new run")
            continue
        if "time_us" in b and "time_us" in n:
            t0, t1 = float(b["time_us"]), float(n["time_us"])
            lim = row_thresholds.get(name, threshold)
            ratio = t1 / t0 if t0 > 0 else float("inf")
            if t0 > 0 and ratio > lim:
                regressions.append(
                    f"{name}: time_us {t0:.0f} -> {t1:.0f} "
                    f"({ratio:.2f}x > {lim:.2f}x)")
            else:
                notes.append(f"{name}: time_us {t0:.0f} -> {t1:.0f} "
                             f"({ratio:.2f}x)")
        if "bytes" in b and "bytes" in n and b["bytes"] != n["bytes"]:
            regressions.append(
                f"{name}: bytes {b['bytes']} -> {n['bytes']} "
                "(wire sizes are deterministic; update the baseline "
                "only with a deliberate codec change)")
        for k in COUNTER_KEYS:
            if k in b and k in n and float(n[k]) > float(b[k]):
                regressions.append(
                    f"{name}: {k} {b[k]} -> {n[k]} (compile/program "
                    "count increased)")
    for name in n_rows:
        if name not in b_rows:
            notes.append(f"{name}: new row (not in baseline)")
    return regressions, notes


def parse_row_thresholds(pairs: list[str]) -> dict[str, float]:
    out: dict[str, float] = {}
    for p in pairs:
        name, _, val = p.rpartition("=")
        if not name:
            raise SystemExit(f"--row-threshold wants name=ratio, got {p!r}")
        out[name] = float(val)
    return out


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline", help="committed bench JSON")
    ap.add_argument("new", help="fresh --json run to gate")
    ap.add_argument("--threshold", type=float, default=1.5,
                    help="default max time_us ratio new/old (1.5)")
    ap.add_argument("--row-threshold", action="append", default=[],
                    metavar="NAME=RATIO",
                    help="per-row time_us ratio override (repeatable)")
    ap.add_argument("--warn-only", action="store_true",
                    help="report regressions but exit 0")
    ap.add_argument("--allow-cross-backend", action="store_true",
                    help="compare despite backend/device/jax mismatch")
    args = ap.parse_args(argv)

    base, new = load(args.baseline), load(args.new)
    if base.get("sweep") != new.get("sweep"):
        raise SystemExit(
            f"sweep mismatch: baseline={base.get('sweep')!r} "
            f"new={new.get('sweep')!r}")
    ok, mismatched = comparable(base.get("meta", {}),
                                new.get("meta", {}))
    if not ok:
        msg = ("refusing cross-backend comparison; mismatched meta: "
               + ", ".join(
                   f"{k} {base['meta'].get(k)!r} != {new['meta'].get(k)!r}"
                   for k in mismatched))
        if not args.allow_cross_backend:
            raise SystemExit(msg)
        print(f"# WARNING: {msg} (continuing: --allow-cross-backend)")

    regressions, notes = compare(
        base, new, args.threshold,
        parse_row_thresholds(args.row_threshold))
    for ln in notes:
        print(f"  ok   {ln}")
    for ln in regressions:
        print(f"  REGR {ln}")
    print(f"# {len(regressions)} regression(s), "
          f"{len(notes)} row(s) compared clean "
          f"({args.baseline} vs {args.new})")
    if regressions and args.warn_only:
        print("# --warn-only: exiting 0 despite regressions")
        return 0
    return 1 if regressions else 0


if __name__ == "__main__":
    sys.exit(main())
