"""zamba2-2.7b [hybrid]: 54 Mamba2 layers d_model=2560 (d_inner=5120,
headdim=64, ssm_state=64) + ONE shared GQA attention block (32H kv=32,
head_dim 80) invoked every 6 layers with per-invocation LoRA adapters —
the Zamba2 trick IS the paper's adapter mechanism [arXiv:2411.15242]."""
from repro.core.lora import LoRAConfig
from repro.models.lm import LMConfig
from repro.models.ssm import MambaSpec


def full() -> LMConfig:
    return LMConfig(
        name="zamba2-2.7b", n_layers=54, d_model=2560, n_heads=32,
        n_kv_heads=32, head_dim=80, d_ff=10240, vocab=32000,
        mlp_kind="gelu",
        mamba=MambaSpec(d_model=2560, d_inner=5120, head_dim=64,
                        d_state=64, n_groups=1, conv_kernel=4, chunk=256),
        shared_attn_every=6,
        lora=LoRAConfig(rank=32, alpha=512.0), head_mode="lora")


def smoke() -> LMConfig:
    return LMConfig(
        name="zamba2-smoke", n_layers=4, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=128, vocab=512, mlp_kind="gelu",
        mamba=MambaSpec(d_model=64, d_inner=128, head_dim=16, d_state=16,
                        n_groups=1, conv_kernel=4, chunk=16),
        shared_attn_every=2,
        lora=LoRAConfig(rank=4, alpha=64.0), head_mode="lora")
