"""FL server orchestration: FLoCoRA rounds with fault tolerance.

Production-shaped features:
  * client sampling (uniform over C clients, K' = oversample*K sampled);
  * STRAGGLER MITIGATION: K' > K clients are dispatched, the aggregation
    takes the first K arrivals (simulated latency ordering) — the paper's
    synchronous FedAvg becomes deadline-robust;
  * CLIENT DROPOUT: a failed client (prob p_fail) contributes nothing;
    aggregation weights renormalize over survivors — a round never blocks;
  * RANK-BUCKETED COHORT ENGINE: with a heterogeneous rank profile
    (``FLoCoRAConfig.rank_schedule``) the surviving clients are grouped
    by adapter rank and each bucket runs as ONE jitted vmapped program
    (bucket sizes pad to pow2, so the compile count is bounded by
    #distinct-ranks x log2(max cohort)); uniform fleets keep the single
    vmapped cohort program (see fl/client.py);
  * WIRE-TRUE quantized exchange per the paper: broadcast truncates the
    global adapters to each client's rank, messages travel PACKED (uint32
    payloads + fp32 sidecars + rank-tagged header, core/messages.py) and
    the server aggregates the packed payloads on the fused dequant_agg
    kernel — per rank bucket when mixed — via a pluggable Aggregator
    strategy (zero-pad FedAvg, FLoRIST-style SVD recombination, FedBuff,
    optional error feedback). With ``FLoCoRAConfig.flat_wire`` (default)
    the dense quantized exchange rides the FLAT-TREE codec
    (core/flat.py): each uplink packs and each cohort aggregates in ONE
    fused kernel launch regardless of the adapter tree's leaf count,
    with byte-identical wire payloads;
  * atomic checkpoint/resume of (round, global adapters, sampler RNG) —
    a restarted server continues the exact run; the RNG bit-generator
    state rides the JSON manifest directly;
  * TCC accounting derived from MEASURED emitted message sizes (cached
    per rank): heterogeneous fleets sum per-client uplinks/downlinks
    instead of Eq. 2's uniform ``2 * one_way * rounds``, and the
    shared-once initial model is included.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import flocora, messages
from repro.core.aggregation import Aggregator, ErrorFeedbackFedAvg, \
    FedAvgAggregator, FedBuffAggregator, ef_fold_dropped
from repro.core.flocora import FLoCoRAConfig
from repro.core.quant import gaussian_epsilon
from repro.checkpoint import CheckpointManager
from repro.fl.client import ClientConfig, cohort_steps, \
    make_cohort_trainer, pad_cohort_batches, pow2_pad, stack_cohort_batches
from repro.fl.traces import FleetTrace
from repro.obs import metrics as obsm
from repro.obs import trace as obst
from repro.utils.tree import tree_bytes

Array = jax.Array

# rng key domain for client dropout draws: keyed by (seed, round, cid)
# like the trace latency draws, so a killed-and-resumed run reproduces
# every failure outcome (the draws never touch the mutable sampler
# stream). traces.py owns 0xA1/0xA2.
TAG_FAILURE = 0xA3


@dataclasses.dataclass
class ServerConfig:
    rounds: int = 100
    n_clients: int = 100
    clients_per_round: int = 10
    oversample: float = 1.0        # straggler mitigation: dispatch K'=o*K
    p_client_failure: float = 0.0  # simulated client dropout
    seed: int = 0
    eval_every: int = 5
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 25
    # FedBuff staleness discount half-life (in staleness units: straggler
    # arrival rank for sync rounds, global-version lag for async);
    # threaded into a FedBuffAggregator whose half_life is unset
    fedbuff_half_life: float = 4.0


class WireAccounting:
    """Measured wire-byte cache, shared by the sync (:class:`FLServer`)
    and async (``fl/async_engine.AsyncFLServer``) engines. Message size
    is determined by (rank, uplink density), so ONE measured emission
    per key is exact for the whole run; the uplink re-measure
    cross-checks that EF/quant/rank/sparsity changes never desynchronize
    the accounting. Downlinks always travel dense, so their cache keys
    stay per-rank.

    ``record_down``/``record_up`` additionally emit each ACTUAL
    transfer as labeled obs counters (``wire.down_bytes`` /
    ``wire.up_bytes`` by rank and uplink density) — the engines call
    them once per dispatched/surviving client, so the registry's view
    matches the cumulative TCC accounting."""

    def __init__(self, fcfg: FLoCoRAConfig,
                 registry: Optional[obsm.MetricsRegistry] = None,
                 hetero: bool = False):
        self.fcfg = fcfg
        self.registry = obsm.get_registry(registry)
        # hetero=True forces per-rank broadcast truncation even without a
        # RankSchedule — a lazy Population carries its rank tiers itself
        self.hetero = hetero
        self.down: dict[int, int] = {}
        self.up: dict[tuple[int, Optional[float]], int] = {}
        self.wasted = 0          # bytes spent on transfers that never
        #                          contributed (churned or straggled)

    def bcast_rank(self, rank: int) -> Optional[int]:
        """None keeps the uniform fleet's broadcast byte-identical to the
        classic path (no resize walk)."""
        if self.hetero or self.fcfg.rank_schedule is not None:
            return rank
        return None

    def downlink_bytes(self, global_train: Any, rank: int) -> int:
        got = self.down.get(rank)
        if got is None:
            msg = flocora.server_downlink(global_train, self.fcfg,
                                          self.bcast_rank(rank))
            got = messages.packed_wire_bytes(msg)
            self.down[rank] = got
        return got

    def uplink_bytes(self, rank: int, msg: Any = None,
                     density: Optional[float] = None) -> Optional[int]:
        """None when no uplink was emitted at this (rank, density) yet
        (callers fall back to the symmetric downlink size)."""
        got = self.up.get((rank, density))
        if got is None and msg is not None:
            got = messages.packed_wire_bytes(msg)
            self.up[(rank, density)] = got
        return got

    # -- labeled transfer counters (one call per actual transfer) -----------
    def record_down(self, rank: int, nbytes: int) -> None:
        self.registry.inc("wire.down_bytes", nbytes, rank=rank)
        self.registry.inc("wire.downlinks", rank=rank)

    def record_up(self, rank: int, nbytes: int,
                  density: Optional[float] = None) -> None:
        self.registry.inc("wire.up_bytes", nbytes, rank=rank,
                          density=density)
        self.registry.inc("wire.uplinks", rank=rank, density=density)

    def record_wasted(self, rank: int, nbytes: int,
                      reason: str = "straggled") -> None:
        """Bytes that were genuinely transferred but never contributed
        to the global model: a straggler's discarded round trip, a
        churned client's spent downlink. Already counted in
        down/up_bytes — this is the waste-attribution view."""
        self.wasted += nbytes
        self.registry.inc("wire.wasted_bytes", nbytes, rank=rank,
                          reason=reason)


class FLServer:
    """Simulates the paper's FL loop (Fig. 1) over arbitrary models.

    model: dict with 'frozen'/'train' trees (train = FLoCoRA adapters);
    loss_fn(frozen, train, batch); client_data: list of per-client dict
    datasets (numpy); eval_fn(frozen, train) -> metrics dict;
    aggregator: Aggregator strategy (defaults to FedAvg, or its
    EF-compensated variant when fcfg.error_feedback is set).
    """

    def __init__(self, model: dict, loss_fn: Callable,
                 client_data: list[dict], scfg: ServerConfig,
                 ccfg: ClientConfig, fcfg: FLoCoRAConfig,
                 eval_fn: Optional[Callable] = None,
                 aggregator: Optional[Aggregator] = None,
                 trace: Optional[FleetTrace] = None,
                 registry: Optional[obsm.MetricsRegistry] = None,
                 tracer: Optional[obst.Tracer] = None):
        self.frozen = model["frozen"]
        self.global_train = model["train"]
        self.loss_fn = loss_fn
        self.client_data = client_data
        self.scfg, self.ccfg, self.fcfg = scfg, ccfg, fcfg
        self.eval_fn = eval_fn
        # deadline cohorts: when a FleetTrace is given, straggler
        # ordering uses TRACE arrival times (keyed by (seed, cid, round),
        # resume-deterministic) instead of the mutable sampler stream
        self.trace = trace
        # telemetry: None means the process defaults (disabled unless
        # obs.enable() ran) — both are injectable per server
        self.registry = obsm.get_registry(registry)
        self.tracer = obst.get_tracer(tracer)
        self.rng = np.random.default_rng(scfg.seed)
        self.round = 0
        self.history: list[dict] = []
        self.trainer = make_cohort_trainer(loss_fn, ccfg)
        # fixed schedule length across ALL clients: the cohort program's
        # shape never changes between rounds (only distinct cohort sizes
        # K retrace), and small clients are masked, not over-trained.
        # A lazy Population knows its own (O(1)) schedule; the eager path
        # scans the materialized shards.
        self.cohort_schedule_steps = client_data.schedule_steps(ccfg) \
            if hasattr(client_data, "schedule_steps") \
            else cohort_steps(client_data, ccfg)
        self.rank_schedule = fcfg.rank_schedule
        # lazy Population fleets carry their own rank tiers (per device
        # tier); a RankSchedule overrides when both are present
        self._pop_ranks = None
        if self.rank_schedule is None \
                and hasattr(client_data, "rank_for"):
            if client_data.max_rank > fcfg.rank:
                raise ValueError(
                    f"population max tier rank {client_data.max_rank} "
                    f"exceeds the server rank {fcfg.rank}")
            self._pop_ranks = client_data
        if self.rank_schedule is not None \
                and self.rank_schedule.n_clients != scfg.n_clients:
            raise ValueError(
                f"rank_schedule covers {self.rank_schedule.n_clients} "
                f"clients, server has {scfg.n_clients}")
        # EF engages when the uplink is actually lossy: quantized and/or
        # sparse (a sparse-only fp wire still drops mass to compensate)
        ef_wanted = fcfg.error_feedback and (fcfg.qcfg.enabled
                                             or fcfg.sparsity_active)
        if aggregator is None:
            aggregator = ErrorFeedbackFedAvg(fcfg.qcfg, fcfg.rank) \
                if ef_wanted else FedAvgAggregator(fcfg.qcfg, fcfg.rank)
        elif ef_wanted != isinstance(aggregator, ErrorFeedbackFedAvg):
            # the uplink encode (fcfg.error_feedback) and the residual
            # store (aggregator type) must agree, or EF silently degrades
            # to plain RTN / maintains dead residuals
            raise ValueError(
                "error_feedback={} (quant {}) requires {} aggregator, got "
                "{}".format(fcfg.error_feedback,
                            "on" if fcfg.qcfg.enabled else "off",
                            "an ErrorFeedbackFedAvg" if ef_wanted
                            else "a non-EF",
                            type(aggregator).__name__))
        if isinstance(aggregator, FedBuffAggregator) \
                and aggregator.half_life is None:
            # half_life is a config field, not a hard-coded default:
            # thread it from ServerConfig (copy, so the caller's instance
            # stays reusable; the pending buffer must not alias)
            aggregator = dataclasses.replace(
                aggregator, half_life=scfg.fedbuff_half_life,
                pending=list(aggregator.pending))
        sched = fcfg.rank_schedule
        if sched is not None:
            mixed = (len(set(sched.client_ranks)) > 1
                     or sched.max_rank != fcfg.rank
                     or sched.anneal_every > 0)
            if mixed and not isinstance(
                    aggregator, (FedAvgAggregator, FedBuffAggregator)):
                # only aggregators with a rank-bucketed path may see a
                # mixed-rank cohort: fail at config time, not with a
                # shape error mid-round
                raise ValueError(
                    f"{type(aggregator).__name__} has no rank-bucketed "
                    "aggregation path for mixed-rank cohorts; use "
                    "FedAvgAggregator (or a subclass such as "
                    "SVDRecombinationAggregator) or FedBuffAggregator")
            explicit = getattr(aggregator, "r_target", None)
            if explicit is not None and explicit < sched.max_rank:
                # a target below a scheduled client rank would let the
                # global tree's shape float with each round's cohort
                raise ValueError(
                    f"aggregator r_target={explicit} is below the rank "
                    f"schedule's max rank {sched.max_rank}")
        if getattr(aggregator, "r_target", 0) is None:
            # pin the global tree's rank on a copy so the caller's
            # instance stays reusable across servers — mutable stores
            # (EF residuals, served ranks) must not alias the copy
            fields: dict[str, Any] = {"r_target": fcfg.rank}
            if hasattr(aggregator, "residuals"):
                fields["residuals"] = dict(aggregator.residuals)
            if hasattr(aggregator, "served_ranks"):
                fields["served_ranks"] = dict(aggregator.served_ranks)
            if hasattr(aggregator, "pending"):
                fields["pending"] = list(aggregator.pending)
            aggregator = dataclasses.replace(aggregator, **fields)
        self.aggregator = aggregator
        self.ckpt = CheckpointManager(scfg.checkpoint_dir) \
            if scfg.checkpoint_dir else None
        # TCC is derived from MEASURED emitted message sizes, cached per
        # client rank by the shared WireAccounting (also used by the
        # async engine)
        hetero = self._pop_ranks is not None \
            and self._pop_ranks.mixed_ranks
        self.wire = WireAccounting(fcfg, registry=self.registry,
                                   hetero=hetero)
        self.initial_model_bytes = tree_bytes(self.frozen)
        self._tcc_cum = self.initial_model_bytes

    @property
    def round_bytes_per_client(self) -> int:
        """2x the MEASURED one-way message size at the server rank
        (lazy: the first access emits and measures a downlink)."""
        return 2 * self._downlink_bytes(self.fcfg.rank)

    # -- per-rank wire accounting (measured, not shape math) ----------------
    def _rank_for(self, cid: int, rnd: int) -> int:
        if self.rank_schedule is not None:
            return self.rank_schedule.rank_for(cid, rnd)
        if self._pop_ranks is not None:
            return self._pop_ranks.rank_for(cid)
        return self.fcfg.rank

    def _client_failed(self, rnd: int, cid: int) -> bool:
        """Keyed dropout draw — a pure function of (seed, round, cid),
        independent of the sampler stream and of checkpoint boundaries
        (i.i.d. draws from ``self.rng`` made resumed runs diverge)."""
        p = self.scfg.p_client_failure
        if p <= 0.0:
            return False
        rng = np.random.default_rng(
            [self.scfg.seed, TAG_FAILURE, rnd, cid])
        return bool(rng.random() < p)

    def _bcast_rank(self, rank: int) -> Optional[int]:
        return self.wire.bcast_rank(rank)

    def _downlink_bytes(self, rank: int) -> int:
        return self.wire.downlink_bytes(self.global_train, rank)

    def _uplink_bytes(self, rank: int, msg: Any = None,
                      density: Optional[float] = None) -> int:
        got = self.wire.uplink_bytes(rank, msg, density)
        if got is None:               # no uplink emitted yet at this rank
            return self._downlink_bytes(rank)
        return got

    # -- fault tolerance ----------------------------------------------------
    def save(self):
        if self.ckpt is None:
            return
        # bit-generator state is a plain dict of ints/strings — it rides
        # the JSON manifest as-is (no repr/eval round-trip)
        self.ckpt.save(self.round, {"train": self.global_train},
                       metadata={"round": self.round,
                                 "tcc_bytes": self._tcc_cum,
                                 "rng_state": self.rng.bit_generator.state})

    def try_resume(self) -> bool:
        if self.ckpt is None:
            return False
        got = self.ckpt.restore_latest({"train": self.global_train})
        if got is None:
            return False
        step, trees, man = got
        self.global_train = trees["train"]
        self.round = man["metadata"]["round"]
        # legacy manifests predate measured TCC: rebuild per Eq. 2
        self._tcc_cum = man["metadata"].get(
            "tcc_bytes",
            self.initial_model_bytes
            + self.round * self.scfg.clients_per_round
            * self.round_bytes_per_client)
        st = man["metadata"].get("rng_state")
        if isinstance(st, str):
            # legacy manifests stored repr(state); literal_eval migrates
            # them safely (plain dict of ints, never code)
            st = ast.literal_eval(st)
        if st:
            self.rng.bit_generator.state = st
        return True

    # -- one round (paper Fig. 1) --------------------------------------------
    def run_round(self) -> dict:
        scfg, fcfg = self.scfg, self.fcfg
        rnd = self.round                      # schedules are 0-based
        k_target = scfg.clients_per_round
        k_dispatch = max(k_target, int(round(scfg.oversample * k_target)))
        sampled = self.rng.choice(scfg.n_clients, size=k_dispatch,
                                  replace=False)
        rank_of = {int(cid): self._rank_for(int(cid), rnd)
                   for cid in sampled}
        density = fcfg.uplink_density(rnd)
        self.registry.inc("fl.rounds")
        # (1) broadcast precedes failure: downlink bytes are spent for
        # every dispatched client, at that client's rank
        down_bytes = 0
        for r in rank_of.values():
            b = self._downlink_bytes(r)
            down_bytes += b
            self.wire.record_down(r, b)

        survivors = [cid for cid in (int(c) for c in sampled)
                     if not self._client_failed(rnd, cid)]
        self.registry.inc("fl.clients_dropped",
                          k_dispatch - len(survivors))
        self.registry.observe("fl.cohort_size", len(survivors))
        # a dropped client's downlink was spent for nothing
        wasted_bytes = 0
        for cid in sampled:
            cid = int(cid)
            if cid not in survivors:
                b = self._downlink_bytes(rank_of[cid])
                wasted_bytes += b
                self.wire.record_wasted(rank_of[cid], b,
                                        reason="dropped")
        if not survivors:
            # an all-dropout round still consumed its downlinks; record
            # it so history (and TCC curves) never have gaps — with the
            # SAME key set as an aggregating round (schema-asserted in
            # tests/test_obs.py)
            self.round += 1
            self._tcc_cum += down_bytes
            rec = {"round": self.round, "n_agg": 0,
                   "n_dropped": k_dispatch, "n_straggled": 0,
                   "client_loss": float("nan"), "cohort_ranks": {},
                   "down_bytes": down_bytes, "up_bytes": 0,
                   "round_bytes": down_bytes, "tcc_bytes": self._tcc_cum,
                   "wasted_bytes": wasted_bytes,
                   "uplink_density": density}
            if fcfg.dp is not None:
                rec["dp_epsilon"] = gaussian_epsilon(
                    fcfg.dp.noise_multiplier, self.round, fcfg.dp.delta)
            self.history.append(rec)
            if self.ckpt and self.round % self.scfg.checkpoint_every == 0:
                self.save()
            return rec

        # (2)+(3) RANK-BUCKETED ENGINE: survivors group by adapter rank;
        # each bucket's local runs execute as ONE jitted vmapped program
        # (pow2-padded client dim, per-client n_steps mask), then every
        # client emits its PACKED wire message at its own rank
        buckets: dict[int, list[int]] = {}
        for cid in survivors:
            buckets.setdefault(rank_of[cid], []).append(cid)
        if self.trace is not None:
            # DEADLINE COHORTS: arrival order comes from the fleet trace
            # (availability wait + compute + transfer at the client's
            # rank and measured message size), keyed (seed, cid, round) —
            # a pure function of simulation ids, so straggler outcomes
            # survive kill/resume bit-exactly
            latency = {cid: self.trace.arrival(
                cid, rnd, rank_of[cid],
                2 * self._downlink_bytes(rank_of[cid]), 0.0)
                for cid in survivors}
        else:
            latency = {cid: self.rng.exponential(1.0)
                       for cid in survivors}
        ef = isinstance(self.aggregator, ErrorFeedbackFedAvg)
        results = []
        for r in sorted(buckets):
            cids = buckets[r]
            with self.tracer.span("fl/broadcast", track="fl/round",
                                  round=rnd, rank=r, clients=len(cids)):
                g_bcast = flocora.broadcast(self.global_train, fcfg,
                                            rank=self._bcast_rank(r))
                datas = [self.client_data[cid] for cid in cids]
                batches, n_steps = stack_cohort_batches(
                    self.rng, datas, self.ccfg,
                    steps=self.cohort_schedule_steps)
                if self.rank_schedule is not None:
                    # pow2-padded buckets bound compile count for mixed
                    # fleets; uniform fleets keep the exact-K classic
                    # shape
                    batches, n_steps = pad_cohort_batches(
                        batches, n_steps, pow2_pad(len(cids)))
                batches = jax.tree.map(jnp.asarray, batches)
            with self.tracer.span("fl/client_train", track="fl/round",
                                  round=rnd, rank=r, clients=len(cids)):
                trained, losses = self.trainer(self.frozen, g_bcast,
                                               batches,
                                               jnp.asarray(n_steps))
                losses = np.asarray(losses)
            with self.tracer.span("fl/pack", track="fl/round",
                                  round=rnd, rank=r, clients=len(cids)):
                for k, cid in enumerate(cids):
                    t_k = jax.tree.map(lambda x: x[k], trained)
                    res = self.aggregator.residual(cid, t_k) \
                        if ef else None
                    # start/dp_key engage only when fcfg.dp is set: the
                    # client's DELTA vs its broadcast is clipped+noised
                    # (keyed (round, cid)) before quantization
                    msg, res = flocora.client_uplink(
                        t_k, fcfg, res, rnd=rnd, start=g_bcast,
                        dp_key=(rnd, cid), dp_seed=self.scfg.seed)
                    n_i = len(next(iter(datas[k].values())))
                    results.append((latency[cid], n_i, msg,
                                    float(losses[k]), r, cid, res))

        # every survivor transmitted its uplink (stragglers included)
        with self.tracer.span("fl/uplink", track="fl/round", round=rnd,
                              clients=len(results)):
            up_bytes = 0
            for r_i in results:
                b = self._uplink_bytes(r_i[4], r_i[2], density)
                up_bytes += b
                self.wire.record_up(r_i[4], b, density)

        # straggler policy: first K arrivals win; a straggler's whole
        # round trip (downlink + discarded uplink) was wasted
        results.sort(key=lambda r: r[0])
        kept = results[:k_target]
        self.registry.inc("fl.clients_straggled",
                          len(results) - len(kept))
        for r_i in results[k_target:]:
            b = self._downlink_bytes(r_i[4]) \
                + self._uplink_bytes(r_i[4], density=density)
            wasted_bytes += b
            self.wire.record_wasted(r_i[4], b, reason="straggled")
        if ef:
            # residuals commit AFTER the straggler cut: a kept client's
            # residual assumes delivery (e' = comp - deq(msg)); a
            # straggled client's message was DISCARDED, so its whole
            # reconstruction folds back into the residual and the next
            # uplink re-ships the lost mass (unbiased-in-time)
            for rec_i in kept:
                self.aggregator.store_residual(rec_i[5], rec_i[6])
            for rec_i in results[k_target:]:
                self.aggregator.store_residual(
                    rec_i[5], ef_fold_dropped(rec_i[6], rec_i[2]))
        weights = jnp.asarray([r[1] for r in kept], jnp.float32)
        # (4) aggregation strategy; packed inputs lower onto the fused
        # dequant+reduce kernel, per rank bucket when the cohort is mixed
        with self.tracer.span("fl/aggregate", track="fl/round",
                              round=rnd, n_agg=len(kept)):
            self.global_train = self.aggregator.aggregate(
                [r[2] for r in kept], weights)
        self.round += 1

        self._tcc_cum += down_bytes + up_bytes
        kept_ranks: dict[int, int] = {}
        for r in kept:
            kept_ranks[r[4]] = kept_ranks.get(r[4], 0) + 1
        rec = {"round": self.round, "n_agg": len(kept),
               "n_dropped": k_dispatch - len(results),
               "n_straggled": len(results) - len(kept),
               "client_loss": float(np.mean([r[3] for r in kept])),
               "cohort_ranks": kept_ranks,
               "down_bytes": down_bytes, "up_bytes": up_bytes,
               "round_bytes": down_bytes + up_bytes,
               # measured heterogeneous sums, incl. the shared-once
               # initial model (replaces Eq. 2's 2 * one_way * rounds)
               "tcc_bytes": self._tcc_cum,
               # dropout downlinks + straggler round trips this round
               "wasted_bytes": wasted_bytes,
               # always present (None = dense uplink) so the history
               # schema is uniform across sparse/dense/all-dropout rounds
               "uplink_density": density}
        if fcfg.dp is not None:
            # conservative RDP composition over the rounds so far (one
            # Gaussian release per participating client per round)
            eps = gaussian_epsilon(fcfg.dp.noise_multiplier, self.round,
                                   fcfg.dp.delta)
            rec["dp_epsilon"] = eps
            self.registry.set("fl.dp_epsilon", eps)
        if fcfg.qcfg.enabled or density is not None:
            rec["up_bytes_measured"] = self._uplink_bytes(
                max(kept_ranks, key=kept_ranks.get), density=density)
            rec["up_bytes_by_rank"] = {
                r: b for (r, d), b in self.wire.up.items() if d == density}
        if self.eval_fn and self.round % self.scfg.eval_every == 0:
            rec.update(self.eval_fn(self.frozen, self.global_train))
        self.history.append(rec)
        if self.ckpt and self.round % self.scfg.checkpoint_every == 0:
            self.save()
        return rec

    def run(self, rounds: Optional[int] = None) -> list[dict]:
        for _ in range(rounds or self.scfg.rounds):
            self.run_round()
        return self.history
