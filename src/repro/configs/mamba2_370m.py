"""mamba2-370m [ssm]: 48L d_model=1024, attn-free, d_inner=2048,
headdim=64 (32 SSM heads), ssm_state=128, vocab=50280 — SSD
[arXiv:2405.21060]."""
from repro.core.lora import LoRAConfig
from repro.models.lm import LMConfig
from repro.models.ssm import MambaSpec


def full() -> LMConfig:
    return LMConfig(
        name="mamba2-370m", n_layers=48, d_model=1024, n_heads=32,
        n_kv_heads=32, head_dim=32, d_ff=0, vocab=50280,
        attn_kind="none",
        mamba=MambaSpec(d_model=1024, d_inner=2048, head_dim=64,
                        d_state=128, n_groups=1, conv_kernel=4, chunk=256),
        lora=LoRAConfig(rank=32, alpha=512.0), head_mode="lora")


def smoke() -> LMConfig:
    return LMConfig(
        name="mamba2-370m-smoke", n_layers=4, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=0, vocab=512,
        attn_kind="none",
        mamba=MambaSpec(d_model=64, d_inner=128, head_dim=16, d_state=16,
                        n_groups=1, conv_kernel=4, chunk=16),
        lora=LoRAConfig(rank=4, alpha=64.0), head_mode="lora")
