"""Fills EXPERIMENTS.md placeholders from the results caches:
TABLE_ROOFLINE_SINGLE, PERF_SECTION, FL_ROUND_TABLE."""
import json
import os


def load(tag=None):
    recs = []
    for fn in sorted(os.listdir("results/dryrun")):
        if fn.endswith(".json"):
            r = json.load(open(os.path.join("results/dryrun", fn)))
            if tag is None or r.get("tag") == tag:
                recs.append(r)
    return recs


def roofline_table() -> str:
    from benchmarks.roofline_report import fmt_table
    return "\n".join(fmt_table(load("baseline"), "pod16x16"))


def _fmt(r):
    t = r["roofline"]
    return (f"peak {r['memory']['peak_bytes'] / 2**30:.2f} GiB | "
            f"t_c {t['t_compute_s']:.2e} | t_m {t['t_memory_s']:.2e} | "
            f"t_coll {t['t_collective_s']:.2e} | {t['dominant']}")


def perf_section() -> str:
    recs = load()
    by = {}
    for r in recs:
        if r["status"] == "ok" and r["mesh"] == "pod16x16":
            by[(r["arch"], r["shape"], r["tag"])] = r
    out = []
    for arch, shape, variants in [
        ("nemotron-4-340b", "train_4k",
         ["int8_base", "micro_half", "micro_half_int8", "xent2048",
          "int8_xent2048"]),
        ("deepseek-v2-236b", "prefill_32k",
         ["int8_base", "cap1.0", "cap1.0_int8", "kvchunk4096"]),
        ("minitron-4b", "train_4k",
         ["int8_base", "xent2048", "micro_half", "int8_xent2048",
          "kvchunk4096"]),
        ("llama4-maverick-400b-a17b", "prefill_32k", ["int8_base"]),
    ]:
        base = by.get((arch, shape, "baseline"))
        if not base:
            continue
        out.append(f"\n**{arch} × {shape}**\n")
        out.append(f"- baseline: {_fmt(base)}")
        bdom = max(base["roofline"]["t_compute_s"],
                   base["roofline"]["t_memory_s"],
                   base["roofline"]["t_collective_s"])
        for v in variants:
            r = by.get((arch, shape, v))
            if not r:
                continue
            vdom = max(r["roofline"]["t_compute_s"],
                       r["roofline"]["t_memory_s"],
                       r["roofline"]["t_collective_s"])
            delta = (bdom - vdom) / bdom * 100
            out.append(f"- {v}: {_fmt(r)}  (dominant-term Δ "
                       f"{delta:+.1f}%)")
    return "\n".join(out)


def fl_round_table() -> str:
    rows = ["| exchange | total collective wire bytes/chip | "
            "u8 all-gathers | Δ vs fp32 |", "|---|---|---|---|"]
    recs = {r["shape"]: r for r in load("fl_round")
            if r["status"] == "ok"}
    base = recs.get("fl_round_bNone")
    for name, key in [("fp32", "fl_round_bNone"), ("int8", "fl_round_b8"),
                      ("int4", "fl_round_b4"), ("int2", "fl_round_b2")]:
        r = recs.get(key)
        if not r:
            continue
        d = ""
        if base and key != "fl_round_bNone":
            d = f"−{(base['collective_total'] - r['collective_total']) / 1e6:.0f} MB"
        rows.append(f"| {name} | {r['collective_total']:.3e} |"
                    f" {r['u8_allgather_ops']} | {d} |")
    return "\n".join(rows)


def main():
    with open("EXPERIMENTS.md") as f:
        doc = f.read()
    doc = doc.replace("TABLE_ROOFLINE_SINGLE", roofline_table())
    doc = doc.replace("PERF_SECTION_TABLES", perf_section())
    doc = doc.replace("PERF_SECTION", perf_section())
    doc = doc.replace("FL_ROUND_TABLE", fl_round_table())
    with open("EXPERIMENTS.md", "w") as f:
        f.write(doc)
    print("rendered EXPERIMENTS.md")


if __name__ == "__main__":
    main()
