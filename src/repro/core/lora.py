"""LoRA adapters for dense and convolution layers (FLoCoRA core).

Dense (Hu et al. '21): frozen ``W ∈ R^{d_in×d_out}``; trainable
``a ∈ R^{d_in×r}`` (Gaussian init) and ``b ∈ R^{r×d_out}`` (zeros init);
``y = x@W + (α/r)·(x@a)@b``. The output-side factor is zero-initialized so
the adapted model starts exactly equal to the frozen base.

Conv (Huh et al. TMLR'22, the decomposition the paper adopts): frozen
``P ∈ R^{O×I×K×K}``; adapter = conv with ``B ∈ R^{r×I×K×K}`` (Gaussian)
followed by 1×1 conv ``A ∈ R^{O×r×1×1}`` (zeros), same stride/padding on B,
stride 1 on A. We store conv kernels in HWIO layout for lax.conv.

``mode`` per layer: 'lora' (frozen base + adapter), 'dense' (fully
trained — the paper's norm/final-FC/stem rule), 'frozen' (shared once,
never updated — e.g. token embeddings at LM scale).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class LoRAConfig:
    rank: int = 32
    alpha: float = 512.0          # paper: alpha = 16*r for from-scratch
    dtype: jnp.dtype = jnp.float32

    @property
    def scale(self) -> float:
        return self.alpha / self.rank


# ---------------------------------------------------------------------------
# Dense
# ---------------------------------------------------------------------------

def dense_lora_init(key: Array, d_in: int, d_out: int, cfg: LoRAConfig,
                    stack: tuple[int, ...] = ()) -> dict:
    """Adapter params for a (stack of) dense layer(s).

    a: (*stack, d_in, r) ~ N(0, 1/d_in); b: (*stack, r, d_out) = 0.
    """
    a = jax.random.normal(key, (*stack, d_in, cfg.rank), cfg.dtype)
    a = a * (1.0 / jnp.sqrt(d_in)).astype(cfg.dtype)
    b = jnp.zeros((*stack, cfg.rank, d_out), cfg.dtype)
    return {"a": a, "b": b}


def dense_lora_apply(x: Array, a: Array, b: Array, scale: float,
                     compute_dtype=jnp.bfloat16) -> Array:
    """(α/r)·(x@a)@b — the low-rank side chain only."""
    h = jnp.einsum("...i,ir->...r", x.astype(compute_dtype),
                   a.astype(compute_dtype))
    y = jnp.einsum("...r,ro->...o", h, b.astype(compute_dtype))
    return (scale * y.astype(jnp.float32)).astype(x.dtype)


def dense_merge(w: Array, a: Array, b: Array, scale: float) -> Array:
    """W + (α/r)·a@b — serving-time merge (no added latency, paper §II-C)."""
    return (w.astype(jnp.float32)
            + scale * a.astype(jnp.float32) @ b.astype(jnp.float32)
            ).astype(w.dtype)


# ---------------------------------------------------------------------------
# Conv (HWIO kernels; NHWC activations)
# ---------------------------------------------------------------------------

def conv_lora_init(key: Array, kh: int, kw: int, c_in: int, c_out: int,
                   cfg: LoRAConfig) -> dict:
    """b_k: (kh, kw, c_in, r) Gaussian; a_k: (1, 1, r, c_out) zeros."""
    fan_in = kh * kw * c_in
    b_k = jax.random.normal(key, (kh, kw, c_in, cfg.rank), cfg.dtype)
    b_k = b_k * (jnp.sqrt(2.0 / fan_in)).astype(cfg.dtype)
    a_k = jnp.zeros((1, 1, cfg.rank, c_out), cfg.dtype)
    return {"b": b_k, "a": a_k}


def conv_lora_apply(x: Array, b_k: Array, a_k: Array, scale: float,
                    stride: tuple[int, int], padding) -> Array:
    """(α/r) · conv1x1(conv(x, B), A), stride/padding on the B conv."""
    dn = jax.lax.conv_dimension_numbers(x.shape, b_k.shape,
                                        ("NHWC", "HWIO", "NHWC"))
    h = jax.lax.conv_general_dilated(x, b_k.astype(x.dtype), stride, padding,
                                     dimension_numbers=dn)
    dn2 = jax.lax.conv_dimension_numbers(h.shape, a_k.shape,
                                         ("NHWC", "HWIO", "NHWC"))
    y = jax.lax.conv_general_dilated(h, a_k.astype(x.dtype), (1, 1), "VALID",
                                     dimension_numbers=dn2)
    return scale * y


def conv_merge(p: Array, b_k: Array, a_k: Array, scale: float) -> Array:
    """Fold the adapter back into the base kernel:
    P[h,w,i,o] + (α/r) · Σ_r B[h,w,i,r]·A[0,0,r,o]."""
    delta = jnp.einsum("hwir,ro->hwio", b_k.astype(jnp.float32),
                       a_k[0, 0].astype(jnp.float32))
    return (p.astype(jnp.float32) + scale * delta).astype(p.dtype)


# ---------------------------------------------------------------------------
# Mixed-mode linear helper used by the model zoo
# ---------------------------------------------------------------------------

def linear_init(key: Array, d_in: int, d_out: int, mode: str,
                cfg: Optional[LoRAConfig] = None,
                stack: tuple[int, ...] = (),
                base_dtype=jnp.bfloat16,
                w_init_scale: Optional[float] = None,
                ) -> tuple[dict, dict]:
    """Returns (frozen, trainable) param dicts for one (stacked) linear.

    mode='lora'  -> frozen {'w'}, trainable {'a','b'}
    mode='dense' -> frozen {},    trainable {'w'}
    mode='frozen'-> frozen {'w'}, trainable {}
    """
    kw, ka = jax.random.split(key)
    std = w_init_scale if w_init_scale is not None else (1.0 / (d_in ** 0.5))
    w = (jax.random.normal(kw, (*stack, d_in, d_out), jnp.float32)
         * std).astype(base_dtype)
    if mode == "lora":
        assert cfg is not None
        return {"w": w}, dense_lora_init(ka, d_in, d_out, cfg, stack)
    if mode == "dense":
        return {}, {"w": w.astype(jnp.float32)}
    if mode == "frozen":
        return {"w": w}, {}
    raise ValueError(f"unknown linear mode: {mode}")


def frozen_weight(frozen: dict, compute_dtype=jnp.bfloat16) -> Array:
    """Resolve a frozen linear's weight, dequantizing an int8 base
    (beyond-paper: the random frozen base tolerates symmetric per-channel
    int8 — halves FSDP all-gather bytes and weight HBM; see
    quantize_frozen_tree)."""
    if "w_q8" in frozen:
        return (frozen["w_q8"].astype(compute_dtype)
                * frozen["w_s"].astype(compute_dtype)[..., None, :])
    return frozen["w"].astype(compute_dtype)


def linear_apply(frozen: dict, trainable: dict, x: Array,
                 scale: float = 1.0,
                 compute_dtype=jnp.bfloat16) -> Array:
    """Apply a mixed-mode linear. Shapes: x (..., d_in) -> (..., d_out)."""
    if "w" in trainable:                       # dense-trained
        w = trainable["w"].astype(compute_dtype)
    else:
        w = frozen_weight(frozen, compute_dtype)
    y = jnp.einsum("...i,io->...o", x.astype(compute_dtype), w)
    if "a" in trainable:                       # lora side chain
        y = y + dense_lora_apply(x, trainable["a"], trainable["b"], scale,
                                 compute_dtype).astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# Beyond-paper: int8 frozen base (QLoRA-style, TPU-FSDP-native)
# ---------------------------------------------------------------------------

def quantize_frozen_tree(frozen) -> dict:
    """Replace every frozen linear {'w': (..,in,out)} with a symmetric
    per-output-channel int8 pack {'w_q8','w_s'}. The base is random and
    never updated (the paper's premise), so static int8 costs nothing in
    trainability while halving weight bytes on HBM and on the FSDP
    all-gather path (vs bf16)."""
    def walk(node):
        if isinstance(node, dict):
            if "w" in node and hasattr(node["w"], "ndim") \
                    and node["w"].ndim >= 2:
                w = node["w"].astype(jnp.float32)
                # reduce only the contracting (d_in) axis: scales keep the
                # (stack..., d_out) shape so layer-stacked leaves still
                # scan (leading L dim preserved)
                amax = jnp.max(jnp.abs(w), axis=-2)
                s = jnp.maximum(amax, 1e-8) / 127.0
                q = jnp.clip(jnp.round(w / s[..., None, :]), -127, 127
                             ).astype(jnp.int8)
                rest = {k: v for k, v in node.items() if k != "w"}
                return {"w_q8": q, "w_s": s.astype(jnp.float16),
                        **{k: walk(v) for k, v in rest.items()}}
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, list):
            return [walk(v) for v in node]
        return node

    return walk(frozen)


def quantize_frozen_logical(logical) -> dict:
    """Parallel transform of the logical-annotation tree."""
    def walk(node):
        if isinstance(node, dict):
            if "w" in node and isinstance(node["w"], tuple):
                ann = node["w"]
                rest = {k: v for k, v in node.items() if k != "w"}
                return {"w_q8": ann, "w_s": (*ann[:-2], ann[-1]),
                        **{k: walk(v) for k, v in rest.items()}}
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, list):
            return [walk(v) for v in node]
        return node

    return walk(logical)


# ---------------------------------------------------------------------------
# Heterogeneous-rank utilities (HetLoRA / FLoRIST-style federation)
#
# An adapter PAIR is a dict {'a', 'b'} holding the two low-rank factors.
# Rank-axis conventions (set by the init functions above):
#   dense:  a (*stack, d_in, r)  [down, rank LAST],  b (*stack, r, d_out)
#           [up, rank at -2];
#   conv:   b (kh, kw, c_in, r)  [down, rank LAST],  a (1, 1, r, c_out)
#           [up, rank at dim 2].
# All helpers below work on anything exposing ``.shape`` (jax arrays,
# numpy, or wire-form PackedLeaf), so rank detection runs on fp trees and
# packed messages alike. Resizing preserves the adapter PRODUCT a@b:
# zero-padding exactly, slicing/SVD by truncation — and since this
# codebase applies a fixed alpha/r scale from the server config (not from
# the tree's rank), resized adapters stay directly comparable across
# clients.
# ---------------------------------------------------------------------------

def adapter_kind(a, b) -> Optional[str]:
    """'conv' | 'dense' | None from the two factors' shapes alone.

    Conv is checked first: its up-factor carries the (1, 1) spatial dims
    of the 1x1 recombination conv. (A *stacked* dense adapter whose stack
    dims are exactly (1, 1) and whose d_in == d_out is indistinguishable
    by shape and would be read as conv — no model in this repo builds
    such a tree.)"""
    ash, bsh = tuple(a.shape), tuple(b.shape)
    if (len(ash) == 4 and len(bsh) == 4 and ash[0] == ash[1] == 1
            and ash[2] == bsh[3]):
        return "conv"
    if (len(ash) >= 2 and len(bsh) >= 2 and ash[-1] == bsh[-2]
            and ash[:-2] == bsh[:-2]):
        return "dense"
    return None


def is_adapter_pair(node: Any) -> bool:
    """True for a dict {'a','b'} whose factors form a LoRA pair."""
    if not (isinstance(node, dict) and set(node) >= {"a", "b"}):
        return False
    a, b = node["a"], node["b"]
    if not (hasattr(a, "shape") and hasattr(b, "shape")):
        return False
    return adapter_kind(a, b) is not None


def adapter_rank(node: dict) -> int:
    """Rank of a LoRA pair (the contracted low-rank dimension)."""
    kind = adapter_kind(node["a"], node["b"])
    if kind == "conv":
        return node["a"].shape[2]
    if kind == "dense":
        return node["a"].shape[-1]
    raise ValueError("not a LoRA adapter pair: "
                     f"a{tuple(node['a'].shape)} b{tuple(node['b'].shape)}")


def _walk_pairs(tree: Any, fn):
    """Rebuild `tree`, applying ``fn(pair_dict)`` to every adapter pair.

    Hand-rolled walk (not jax.tree.map) so wire-form leaves like
    PackedLeaf — themselves pytrees — are treated as leaves."""
    if isinstance(tree, dict):
        if is_adapter_pair(tree):
            return fn(tree)
        return {k: _walk_pairs(v, fn) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        out = [_walk_pairs(v, fn) for v in tree]
        return type(tree)(out) if isinstance(tree, tuple) else out
    return tree


def tree_ranks(tree: Any) -> tuple[int, ...]:
    """Sorted distinct adapter ranks found in a (fp or packed) tree.
    A flat-tree wire message walks through its shape-only view (rank
    detection never touches a payload)."""
    if hasattr(tree, "shape_tree"):          # FlatPackedMessage
        tree = tree.shape_tree()
    found: set[int] = set()

    def rec(pair):
        found.add(int(adapter_rank(pair)))
        return pair

    _walk_pairs(tree, rec)
    return tuple(sorted(found))


def tree_max_rank(tree: Any) -> Optional[int]:
    """Max adapter rank in the tree, or None if it holds no adapters."""
    rs = tree_ranks(tree)
    return rs[-1] if rs else None


def _dense_factors(pair: dict) -> tuple[Array, Array, str]:
    """(down, up, kind) in matrix orientation: down (..., m, r),
    up (..., r, n). Conv factors are reshaped to 2-D."""
    kind = adapter_kind(pair["a"], pair["b"])
    if kind == "dense":
        return pair["a"], pair["b"], kind
    b, a = pair["b"], pair["a"]                     # conv: b=down, a=up
    kh, kw, cin, r = b.shape
    return b.reshape(kh * kw * cin, r), a.reshape(r, a.shape[3]), kind


def _rebuild_pair(down: Array, up: Array, kind: str, like: dict) -> dict:
    if kind == "dense":
        return {**like, "a": down.astype(like["a"].dtype),
                "b": up.astype(like["b"].dtype)}
    kh, kw, cin, _ = like["b"].shape
    r = down.shape[-1]
    return {**like,
            "b": down.reshape(kh, kw, cin, r).astype(like["b"].dtype),
            "a": up.reshape(1, 1, r, up.shape[-1]).astype(like["a"].dtype)}


def pad_adapter(pair: dict, r_target: int) -> dict:
    """Zero-pad both factors' rank dims up to ``r_target``.

    Exact: the padded components contribute 0 to the product a@b."""
    down, up, kind = _dense_factors(pair)
    r = down.shape[-1]
    if r > r_target:
        raise ValueError(f"pad_adapter: rank {r} > target {r_target}")
    if r == r_target:
        return pair
    pd = [(0, 0)] * down.ndim
    pd[-1] = (0, r_target - r)
    pu = [(0, 0)] * up.ndim
    pu[-2] = (0, r_target - r)
    return _rebuild_pair(jnp.pad(down, pd), jnp.pad(up, pu), kind, pair)


def slice_adapter(pair: dict, r_target: int) -> dict:
    """Keep the leading ``r_target`` rank components (HetLoRA-style
    truncation). After an SVD recombination the components are ordered by
    singular value, so slicing keeps the top-energy directions; it also
    inverts ``pad_adapter`` exactly."""
    down, up, kind = _dense_factors(pair)
    if down.shape[-1] < r_target:
        raise ValueError(f"slice_adapter: rank {down.shape[-1]} < target "
                         f"{r_target}")
    return _rebuild_pair(down[..., :r_target],
                         up[..., :r_target, :], kind, pair)


def truncate_adapter(a: Array, b: Array, r_target: int
                     ) -> tuple[Array, Array]:
    """SVD re-projection: the best rank-``r_target`` factorization of the
    product ``a @ b`` (dense orientation, stacked dims batched).

    Returns (a', b') with balanced factors a' = U·√S, b' = √S·Vᵀ and rank
    dims exactly ``r_target`` (zero-padded when the product's intrinsic
    rank is smaller). Any adapter can be resized without re-init."""
    m = a.astype(jnp.float32) @ b.astype(jnp.float32)
    u, s, vh = jnp.linalg.svd(m, full_matrices=False)
    k = min(r_target, s.shape[-1])
    root = jnp.sqrt(s[..., :k])
    a_t = u[..., :, :k] * root[..., None, :]
    b_t = root[..., :, None] * vh[..., :k, :]
    if k < r_target:
        pa = [(0, 0)] * a_t.ndim
        pa[-1] = (0, r_target - k)
        pb = [(0, 0)] * b_t.ndim
        pb[-2] = (0, r_target - k)
        a_t, b_t = jnp.pad(a_t, pa), jnp.pad(b_t, pb)
    return a_t.astype(a.dtype), b_t.astype(b.dtype)


def svd_adapter(pair: dict, r_target: int) -> dict:
    """``truncate_adapter`` applied to a pair dict (conv handled)."""
    down, up, kind = _dense_factors(pair)
    d_t, u_t = truncate_adapter(down, up, r_target)
    return _rebuild_pair(d_t, u_t, kind, pair)


def resize_adapter(pair: dict, r_target: int, method: str = "slice") -> dict:
    """Resize one adapter pair to ``r_target``: zero-pad when growing;
    ``method`` ('slice' | 'svd') when shrinking. 'slice' (default, the
    broadcast path) keeps leading components — crucial for fresh
    adapters whose product is still zero, where an SVD would return
    all-zero factors and kill the gradient; 'svd' is the
    energy-optimal truncation for trained adapters."""
    r = adapter_rank(pair)
    if r == r_target:
        return pair
    if r < r_target:
        return pad_adapter(pair, r_target)
    if method == "slice":
        return slice_adapter(pair, r_target)
    if method == "svd":
        return svd_adapter(pair, r_target)
    raise ValueError(f"unknown resize method: {method}")


def resize_tree_rank(tree: Any, r_target: int,
                     method: str = "slice") -> Any:
    """Resize every adapter pair in a trainable tree to ``r_target``;
    non-adapter leaves (norms, dense weights, biases) pass through
    untouched — their shapes are rank-independent."""
    return _walk_pairs(tree, lambda p: resize_adapter(p, r_target, method))


def svd_energy_rank(s: Array, energy: float) -> int:
    """Smallest k with cumsum(s²)/sum(s²) >= energy (FLoRIST singular-
    value thresholding). Batched inputs take the max over the batch so a
    stacked adapter serves one uniform rank. Returns >= 1."""
    s2 = jnp.square(s.astype(jnp.float32))
    tot = jnp.sum(s2, axis=-1, keepdims=True)
    frac = jnp.cumsum(s2, axis=-1) / jnp.maximum(tot, 1e-30)
    need = jnp.sum(frac < energy, axis=-1) + 1
    # an all-zero slice (e.g. one fresh layer in a stacked adapter) has
    # frac == 0 everywhere; rank 1 serves it exactly — don't let it
    # force the full rank through the batch max
    need = jnp.where(tot[..., 0] > 0, need, 1)
    k = int(jnp.max(need))
    return max(1, min(k, s.shape[-1]))


def linear_logical(d_in_name: Optional[str], d_out_name: Optional[str],
                   mode: str, stack: bool = False) -> tuple[dict, dict]:
    """Logical-axis annotations matching linear_init's (frozen, trainable)."""
    pre = ("layers",) if stack else ()
    if mode == "lora":
        return ({"w": (*pre, d_in_name, d_out_name)},
                {"a": (*pre, d_in_name, "lora_rank"),
                 "b": (*pre, "lora_rank", d_out_name)})
    if mode == "dense":
        return {}, {"w": (*pre, d_in_name, d_out_name)}
    if mode == "frozen":
        return {"w": (*pre, d_in_name, d_out_name)}, {}
    raise ValueError(mode)
