from repro.utils.tree import (
    tree_size,
    tree_bytes,
    tree_map_with_path,
    tree_zeros_like,
    tree_add,
    tree_sub,
    tree_scale,
    tree_weighted_sum,
    flatten_with_names,
)
