"""Production training driver.

Materializes sharded params for an --arch on the selected mesh, runs
FLoCoRA train steps (frozen base + adapter optimizer) with checkpointing
and automatic resume. On this CPU container use --mesh host --smoke for a
real end-to-end run; on a TPU pod the same code path runs the production
mesh (the dry-run proves every cell compiles there).

    PYTHONPATH=src python -m repro.launch.train \
        --arch minitron-4b --smoke --steps 20 --mesh host
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import registry
from repro.data.synthetic import markov_lm_batch
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import encdec as ED
from repro.models import lm as LM
from repro.optim import adamw
from repro.utils.sharding import tree_shardings
from repro.utils.tree import tree_size


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mesh", default="host",
                    choices=["host", "single", "multi"])
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=10)
    args = ap.parse_args()

    entry = registry.get(args.arch)
    cfg = entry.smoke() if args.smoke else entry.full()
    mesh = {"host": make_host_mesh,
            "single": lambda: make_production_mesh(multi_pod=False),
            "multi": lambda: make_production_mesh(multi_pod=True)}[
        args.mesh]()
    mod = ED if entry.kind == "encdec" else LM

    params = mod.init(jax.random.PRNGKey(0), cfg)
    logical = mod.logical(cfg)
    sh_f = tree_shardings(logical["frozen"], params["frozen"], mesh)
    sh_t = tree_shardings(logical["train"], params["train"], mesh)
    frozen = jax.device_put(params["frozen"], sh_f)
    train = jax.device_put(params["train"], sh_t)
    print(f"{cfg.name}: total={tree_size(params['frozen']) + tree_size(params['train']):,} "
          f"trainable={tree_size(params['train']):,}")

    opt = adamw()
    opt_state = opt.init(train)
    ckpt = CheckpointManager(args.checkpoint_dir) if args.checkpoint_dir \
        else None
    start = 0
    if ckpt:
        got = ckpt.restore_latest({"train": train, "opt": opt_state})
        if got:
            start, trees, _ = got
            train, opt_state = trees["train"], trees["opt"]
            print(f"resumed from step {start}")

    @jax.jit
    def train_step(train, opt_state, batch):
        (loss, m), grads = jax.value_and_grad(
            lambda t: mod.loss_fn(frozen, t, cfg, batch), has_aux=True)(
            train)
        train, opt_state = opt.update(grads, opt_state, train, args.lr)
        return train, opt_state, loss

    rng = np.random.default_rng(0)
    with mesh:
        for step in range(start, args.steps):
            if entry.kind == "encdec":
                batch = {
                    "src_embed": jnp.asarray(rng.normal(size=(
                        args.batch, args.seq, cfg.d_model)), jnp.bfloat16),
                    "tgt_tokens": jnp.asarray(markov_lm_batch(
                        rng, cfg.vocab, args.batch, args.seq)["tokens"])}
            else:
                batch = {"tokens": jnp.asarray(markov_lm_batch(
                    rng, cfg.vocab, args.batch, args.seq)["tokens"])}
                if cfg.prefix_lm:
                    batch["prefix_embed"] = jnp.asarray(rng.normal(size=(
                        args.batch, cfg.prefix_len, cfg.d_model)),
                        jnp.bfloat16)
            t0 = time.time()
            train, opt_state, loss = train_step(train, opt_state, batch)
            loss = float(loss)
            print(f"step {step + 1}: loss={loss:.4f} "
                  f"({time.time() - t0:.2f}s)", flush=True)
            if ckpt and (step + 1) % args.checkpoint_every == 0:
                ckpt.save(step + 1, {"train": train, "opt": opt_state})
    print("done")


if __name__ == "__main__":
    main()
