"""FLoCoRA message codec: trainable tree <-> quantized wire message.

Quantization rules (paper §IV, validated byte-exact against Tables III/IV):
  * tensors with ndim >= 2 are quantized per *output channel* = last axis
    (conv "per channel", FC "per column" in the paper's storage order);
  * tensors with a leading layer-stack dim (ndim >= 3) get per-(layer,
    channel) qparams via vmap — strictly better accuracy, same wire format;
  * 1-D tensors (norm scales/biases, SSM vectors) are never quantized and
    travel in fp32 — the paper's "normalization layers are not quantized";
  * scale and zero-point travel as fp32 sidecars (2 * 4 bytes / channel).

``encode``/``decode`` are jit-friendly; ``wire_bytes`` is the static
accounting used by the TCC benchmarks.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.core.quant import QuantConfig

Array = jax.Array

CHANNEL_AXIS = -1   # output channel == last axis in this codebase's layouts


@dataclasses.dataclass
class EncodedLeaf:
    q: Array              # uint8 levels (unpacked; packing is wire-only)
    scale: Array
    zp: Array
    dtype: Any            # original dtype


def _encode_leaf(x: Array, bits: int, per_stack: bool):
    def enc2d(t):
        s, z = quant.affine_qparams(t, bits, channel_axis=t.ndim - 1)
        q = quant.quantize(t, s, z, bits, channel_axis=t.ndim - 1)
        return q, s, z

    if per_stack and x.ndim >= 3:
        # per-(stack, channel) qparams (stacked LM layer tensors)
        q, s, z = jax.vmap(enc2d)(x)
    else:
        q, s, z = enc2d(x)
    return {"q": q, "scale": s, "zp": z}


def _decode_leaf(enc: dict, ndim: int, dtype, per_stack: bool) -> Array:
    def dec2d(q, s, z):
        return quant.dequantize(q, s, z, channel_axis=q.ndim - 1, dtype=dtype)

    if per_stack and ndim >= 3:
        return jax.vmap(dec2d)(enc["q"], enc["scale"], enc["zp"])
    return dec2d(enc["q"], enc["scale"], enc["zp"])


def quantizable(x) -> bool:
    """Paper rule: >=2-D tensors are quantized; vectors stay fp."""
    return x.ndim >= 2


def encode(tree: Any, cfg: QuantConfig) -> Any:
    """Trainable tree -> message tree. Unquantized leaves pass through."""
    if not cfg.enabled:
        return tree

    def enc(x):
        if not quantizable(x):
            return x
        return _encode_leaf(x, cfg.bits, cfg.per_stack)

    return jax.tree.map(enc, tree)


def decode(msg: Any, cfg: QuantConfig, like: Any) -> Any:
    """Message tree -> fp tree with the dtypes/structure of `like`."""
    if not cfg.enabled:
        return msg

    def dec(ref, m):
        if not quantizable(ref):
            return m
        return _decode_leaf(m, ref.ndim, ref.dtype, cfg.per_stack)

    return jax.tree.map(dec, like, msg,
                        is_leaf=lambda t: isinstance(t, dict) and "q" in t)


def roundtrip(tree: Any, cfg: QuantConfig) -> Any:
    """Quantize+dequantize: what the receiver reconstructs."""
    if not cfg.enabled:
        return tree
    return decode(encode(tree, cfg), cfg, tree)


# ---------------------------------------------------------------------------
# Wire-byte accounting (static; shapes only)
# ---------------------------------------------------------------------------

def leaf_wire_bytes(shape: tuple[int, ...], bits: Optional[int],
                    per_stack: bool = False) -> int:
    n = int(np.prod(shape))
    if bits is None or len(shape) < 2:
        return n * quant.FP_BYTES
    if per_stack and len(shape) >= 3:
        channels = int(np.prod(shape[:-2])) * shape[-1]
    else:
        channels = shape[-1]          # paper rule: channel = last axis
    payload = (n * bits + 7) // 8
    return payload + channels * 2 * quant.FP_BYTES


def message_wire_bytes(tree: Any, cfg: QuantConfig) -> int:
    """Bytes for one direction of one round (paper's message size)."""
    bits = cfg.bits if cfg.enabled else None
    return sum(leaf_wire_bytes(tuple(x.shape), bits, cfg.per_stack)
               for x in jax.tree.leaves(tree))


def tcc_bytes(tree: Any, cfg: QuantConfig, rounds: int) -> int:
    """Paper Eq. 2 generalized: 2 * R * message_bytes."""
    return 2 * rounds * message_wire_bytes(tree, cfg)
