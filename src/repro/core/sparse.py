"""FLASC-style sparse-delta wire format: top-k over the packed codec.

"Federated LoRA with Sparse Communication" (Kuo et al. 2024) shows that
TOP-K sparsifying the LoRA adapter deltas composes multiplicatively with
affine quantization: the surviving values still quantize to 2/4/8-bit
levels, and only the surviving positions travel. This module supplies
the pieces the codec (``core/messages.py``) and the aggregators
(``core/aggregation.py``) assemble into the end-to-end sparse uplink:

  * :class:`SparsityConfig` — density (fraction of entries kept per
    tensor), optional round-wise annealing, and the FLASC EF-required
    flag (sparse uplinks keep accuracy only when the dropped mass is
    routed into the error-feedback residual);
  * :func:`sparsify_leaf` — per-tensor magnitude top-k of one message
    tensor; the surviving values run through the SAME affine quantizer
    as the dense codec (the ``quant_pack`` kernel path), so sparsity and
    2/4/8-bit quantization compose;
  * :class:`SparseLeaf` — the wire form: sorted uint32 flat indices (or
    an n-bit occupancy bitmap, whichever is smaller) + the quantized
    value payload + fp32 sidecars. ``to_wire``/``from_wire`` serialize
    to exactly :func:`sparse_leaf_wire_bytes` bytes.

Quantization of the survivors is PER-TENSOR (one scale/zero-point pair
per leaf): top-k destroys the channel structure the dense codec's
per-channel qparams rely on, and the k survivors of one tensor share a
magnitude range by construction. ``per_stack`` therefore does not apply
to sparse leaves.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.kernels import ops as kops
from repro.kernels import ref as kref

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SparsityConfig:
    """Top-k sparsification of the client UPLINK (FLASC-style).

    ``density`` is the fraction of entries kept per (>= 2-D) message
    tensor; 1-D leaves always travel dense, like the dense codec's
    norm-layer rule. With ``anneal_every > 0`` the density is multiplied
    by ``anneal_factor`` every ``anneal_every`` rounds (floored at
    ``min_density``) — late-training updates concentrate, so the uplink
    shrinks as the run converges. ``density == 1.0`` (and no annealing)
    is the EXACT-PARITY fallback: messages take the dense packed path
    byte-for-byte.

    ``require_ef`` (default True) makes the config refuse to run without
    error feedback: FLASC keeps accuracy only when each round's dropped
    mass enters the client's EF residual and ships later. Set it to
    False only for engines that cannot maintain residuals (e.g. the
    async engine) and accept the bias."""
    density: float = 1.0
    anneal_every: int = 0
    anneal_factor: float = 0.5
    min_density: float = 0.01
    require_ef: bool = True

    def __post_init__(self):
        if not 0.0 < self.density <= 1.0:
            raise ValueError(f"density must be in (0, 1]: {self.density}")
        if self.anneal_every < 0:
            raise ValueError("anneal_every must be >= 0")
        if not 0.0 < self.anneal_factor <= 1.0:
            raise ValueError("anneal_factor must be in (0, 1]")
        if not 0.0 < self.min_density <= 1.0:
            raise ValueError("min_density must be in (0, 1]")

    @property
    def enabled(self) -> bool:
        """True when any round's uplink can actually be sparse."""
        return self.density < 1.0 or self.anneal_every > 0

    def density_at(self, rnd: int) -> float:
        """Uplink density at round ``rnd``. The ``min_density`` floor
        only binds annealed shrinkage — a configured base density below
        the floor is honored as-is (effective floor
        ``min(min_density, density)``, mirroring RankSchedule)."""
        d = self.density
        if self.anneal_every > 0:
            d = max(min(self.min_density, d),
                    d * self.anneal_factor ** (rnd // self.anneal_every))
        return d


def keep_count(n: int, density: float) -> int:
    """Survivors of a ``density`` top-k over ``n`` entries (>= 1)."""
    return max(1, int(np.ceil(density * n)))


def sparse_leaf_wire_bytes(shape: tuple[int, ...], bits: Optional[int],
                           density: float) -> int:
    """Static wire accounting for one sparse leaf.

    indices: min(4k uint32 index bytes, ceil(n/8) bitmap bytes) — the
    serializer picks whichever is smaller, deterministically from the
    shape; values: ceil(k*bits/8) + one per-tensor (scale, zp) fp32
    sidecar pair, or 4k bytes when fp."""
    n = int(np.prod(shape))
    k = keep_count(n, density)
    idx_bytes = min(4 * k, (n + 7) // 8)
    if bits is None:
        return idx_bytes + k * quant.FP_BYTES
    return idx_bytes + (k * bits + 7) // 8 + 2 * quant.FP_BYTES


def _pack_row(vals: Array, bits: int, use_kernel: bool):
    """(k,) fp32 survivors -> ((1, Nw) uint32 words, scale (1,), zp (1,))
    in the kernel layout. ``use_kernel=False`` is the vmap-safe jnp twin
    (same contract as ``messages._pack_2d_jnp``: word-granular padding
    only; consumers slice to the first k levels)."""
    v2d = vals.reshape(1, -1).astype(jnp.float32)
    if use_kernel:
        return kops.quant_pack(v2d, bits)
    scale, zp = kref._qparams_rowwise(v2d, bits)
    qmax = (1 << bits) - 1
    q = jnp.clip(jnp.round(v2d / scale[:, None]) + zp[:, None], 0, qmax)
    per = 32 // bits
    qp = jnp.pad(q.astype(jnp.uint32),
                 ((0, 0), (0, (-v2d.shape[1]) % per)))
    return kref.pack_words(qp, bits), scale, zp


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SparseLeaf:
    """One top-k-sparsified tensor in wire form.

    ``idx`` holds the k surviving FLAT indices into ``shape``, sorted
    ascending (so the bitmap encoding and the index encoding agree on
    value order); ``payload`` is the survivors' quantized word row in
    the ``quant_pack`` kernel layout ((1, Nw) uint32) or, when ``bits``
    is None, the raw fp32 values (k,). ``shape`` exposes the ORIGINAL
    tensor shape, so shape-only walks (adapter-pair/rank detection in
    ``core/lora.py``) work on sparse trees without touching a payload.
    """
    idx: Array                    # (k,) int32, ascending flat indices
    payload: Array                # (1, Nw) uint32 words | (k,) fp32
    scale: Optional[Array]        # (1,) fp32, None when bits is None
    zp: Optional[Array]           # (1,) fp32, None when bits is None
    shape: tuple                  # static: original tensor shape
    dtype: Any                    # static: original dtype
    bits: Optional[int]           # static: None = fp survivors
    density: float = 1.0          # static: configured density (header)

    def tree_flatten(self):
        return ((self.idx, self.payload, self.scale, self.zp),
                (self.shape, self.dtype, self.bits, self.density))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    @property
    def k(self) -> int:
        return int(self.idx.shape[0])

    @property
    def n(self) -> int:
        return int(np.prod(self.shape))

    def values(self) -> Array:
        """The k surviving values, dequantized to fp32."""
        if self.bits is None:
            return self.payload.astype(jnp.float32)
        lv = kref.unpack_words(self.payload, self.bits)[:, : self.k]
        return ((lv.astype(jnp.float32) - self.zp[:, None])
                * self.scale[:, None]).reshape(-1)

    def densify(self) -> Array:
        """Scatter the survivors into a dense tensor (zeros elsewhere)."""
        dense = jnp.zeros((self.n,), jnp.float32).at[self.idx].set(
            self.values())
        return dense.reshape(self.shape).astype(self.dtype)

    # -- serialization (the actual bytes on the wire) -----------------------
    def _use_bitmap(self) -> bool:
        """Bitmap wins once density crosses 1/32 (4k > n/8 bytes)."""
        return 4 * self.k > (self.n + 7) // 8

    def to_wire(self) -> dict[str, np.ndarray]:
        """Host-side buffers as sent; ``sum(nbytes)`` equals
        :func:`sparse_leaf_wire_bytes` for this leaf's shape/density."""
        if self._use_bitmap():
            mask = np.zeros(self.n, np.bool_)
            mask[np.asarray(self.idx)] = True
            out = {"bitmap": np.packbits(mask)}
        else:
            out = {"idx": np.asarray(self.idx, np.uint32)}
        if self.bits is None:
            out["values"] = np.asarray(self.payload, np.float32)
            return out
        lv = kref.unpack_words(self.payload, self.bits)[:, : self.k]
        out["payload"] = np.asarray(
            quant.pack_levels(lv.reshape(-1).astype(jnp.uint8), self.bits))
        out["scale"] = np.asarray(self.scale, np.float32)
        out["zp"] = np.asarray(self.zp, np.float32)
        return out

    @classmethod
    def from_wire(cls, buffers: dict, shape: tuple, dtype,
                  bits: Optional[int], density: float = 1.0
                  ) -> "SparseLeaf":
        """Rebuild the kernel-layout leaf from serialized wire buffers."""
        n = int(np.prod(shape))
        if "bitmap" in buffers:
            mask = np.unpackbits(np.asarray(buffers["bitmap"],
                                            np.uint8))[:n]
            idx = np.flatnonzero(mask)
        else:
            idx = np.asarray(buffers["idx"], np.int64)
        idx = jnp.asarray(idx, jnp.int32)
        k = int(idx.shape[0])
        if bits is None:
            return cls(idx, jnp.asarray(buffers["values"], jnp.float32),
                       None, None, tuple(shape), dtype, None, density)
        lv = quant.unpack_levels(jnp.asarray(buffers["payload"]), bits, k)
        # reproduce the kernel layout bit-exactly: zero levels padded to
        # the lane multiple, as quant_pack emits
        lane = kops.lane_levels(bits)
        lvp = jnp.pad(lv.astype(jnp.uint32), (0, (-k) % lane))
        payload = kref.pack_words(lvp.reshape(1, -1), bits)
        return cls(idx, payload, jnp.asarray(buffers["scale"]),
                   jnp.asarray(buffers["zp"]), tuple(shape), dtype, bits,
                   density)

    def wire_bytes(self) -> int:
        """Real serialized size (measured from the buffers)."""
        return sum(b.nbytes for b in self.to_wire().values())


def is_sparse_leaf(t: Any) -> bool:
    return isinstance(t, SparseLeaf)


def sparsify_leaf(x: Array, density: float, bits: Optional[int],
                  use_kernel: bool = True) -> SparseLeaf:
    """Per-tensor magnitude top-k -> :class:`SparseLeaf`.

    Keeps the ``keep_count(n, density)`` largest-|x| entries; survivors
    quantize per-tensor through the same affine RTN as the dense codec
    (``quant_pack`` kernel path) when ``bits`` is set."""
    n = int(np.prod(x.shape))
    k = keep_count(n, density)
    flat = x.reshape(-1).astype(jnp.float32)
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    idx = jnp.sort(idx).astype(jnp.int32)   # ascending: bitmap-compatible
    vals = jnp.take(flat, idx)
    if bits is None:
        return SparseLeaf(idx, vals, None, None, tuple(x.shape), x.dtype,
                          None, density)
    payload, scale, zp = _pack_row(vals, bits, use_kernel)
    return SparseLeaf(idx, payload, scale, zp, tuple(x.shape), x.dtype,
                      bits, density)
