"""Mamba-2 (SSD — state-space duality) mixer block.

Implements the chunked SSD algorithm of Dao & Gu (arXiv:2405.21060):
within-chunk quadratic (attention-like with a 1-semiseparable decay mask)
plus an inter-chunk state recurrence (lax.scan over chunks). Decode is the
O(1) recurrent step with an SSM-state cache and a rolling conv cache.

Projections are split per component (z/x/B/C/dt) instead of one fused
in_proj — identical math, but each output dim then shards cleanly on the
tensor axis (DESIGN.md §3). z/x/out projections are FLoCoRA LoRA targets;
B/C/dt projections, the depthwise conv, A_log/D/dt_bias vectors and the
gated norm are trained densely (the paper's "norm-layer" category).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.lora import LoRAConfig, linear_init, linear_apply, \
    linear_logical
from repro.models import layers as L

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class MambaSpec:
    d_model: int
    d_inner: int              # expand * d_model
    head_dim: int = 64        # P
    d_state: int = 128        # N
    n_groups: int = 1         # G
    conv_kernel: int = 4
    chunk: int = 256

    @property
    def n_heads(self) -> int:
        assert self.d_inner % self.head_dim == 0
        return self.d_inner // self.head_dim


def mamba_init(key: Array, spec: MambaSpec, mode: str, lora: LoRAConfig,
               stack: tuple[int, ...] = ()) -> tuple[dict, dict]:
    ks = jax.random.split(key, 8)
    gn = spec.n_groups * spec.d_state
    fz, tr = {}, {}
    for k_, nm, dout, m in (
            (ks[0], "wz", spec.d_inner, mode),
            (ks[1], "wx", spec.d_inner, mode),
            (ks[2], "wb", gn, "dense"),
            (ks[3], "wc", gn, "dense"),
            (ks[4], "wdt", spec.n_heads, "dense")):
        f, t = linear_init(k_, spec.d_model, dout, m, lora, stack)
        if f:
            fz[nm] = f
        if t:
            tr[nm] = t
    f, t = linear_init(ks[5], spec.d_inner, spec.d_model, mode, lora, stack)
    if f:
        fz["wo"] = f
    if t:
        tr["wo"] = t
    convdim = spec.d_inner + 2 * gn
    tr["conv"] = {"w": jax.random.normal(
        ks[6], (*stack, spec.conv_kernel, convdim), jnp.float32) * 0.1,
        "b": jnp.zeros((*stack, convdim), jnp.float32)}
    h = spec.n_heads
    tr["A_log"] = jnp.log(jnp.broadcast_to(
        jnp.linspace(1.0, 16.0, h), (*stack, h)).astype(jnp.float32))
    tr["D"] = jnp.ones((*stack, h), jnp.float32)
    tr["dt_bias"] = jnp.broadcast_to(
        jnp.log(jnp.expm1(jnp.full((h,), 0.01))), (*stack, h)
    ).astype(jnp.float32)
    tr["norm"] = L.rmsnorm_init(spec.d_inner, stack)
    return fz, tr


def mamba_logical(spec: MambaSpec, mode: str, stack: bool
                  ) -> tuple[dict, dict]:
    pre = ("layers",) if stack else ()
    fz, tr = {}, {}
    for nm, dims, m in (("wz", ("fsdp", "ssm_inner"), mode),
                        ("wx", ("fsdp", "ssm_inner"), mode),
                        ("wb", ("fsdp", None), "dense"),
                        ("wc", ("fsdp", None), "dense"),
                        ("wdt", ("fsdp", None), "dense"),
                        ("wo", ("ssm_inner", "fsdp"), mode)):
        f, t = linear_logical(*dims, m, stack)
        if f:
            fz[nm] = f
        if t:
            tr[nm] = t
    tr["conv"] = {"w": (*pre, None, "ssm_inner"), "b": (*pre, "ssm_inner")}
    tr["A_log"] = (*pre, None)
    tr["D"] = (*pre, None)
    tr["dt_bias"] = (*pre, None)
    tr["norm"] = {"scale": (*pre, "ssm_inner")}
    return fz, tr


def _proj(fz, tr, nm, x, scale):
    return linear_apply(fz.get(nm, {}), tr.get(nm, {}), x, scale)


def _causal_depthwise_conv(xbc: Array, w: Array, b: Array,
                           state: Array | None = None):
    """xbc: (B, S, C); w: (K, C). Returns (y, new_state (B, K-1, C))."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)
    y = sum(xp[:, i:i + xbc.shape[1]] * w[i].astype(xbc.dtype)
            for i in range(k))
    y = jax.nn.silu((y + b.astype(y.dtype)).astype(jnp.float32)
                    ).astype(xbc.dtype)
    new_state = xp[:, xbc.shape[1]:]
    return y, new_state


def mamba_apply(fz: dict, tr: dict, spec: MambaSpec, x: Array,
                lora_scale: float) -> Array:
    """Training / prefill forward: (B, S, d) -> (B, S, d), chunked SSD."""
    bsz, s0, _ = x.shape
    h, p, n, g = spec.n_heads, spec.head_dim, spec.d_state, spec.n_groups
    lc = min(spec.chunk, s0)
    pad = (-s0) % lc
    if pad:                      # causal: tail padding never affects [:s0]
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    s = s0 + pad
    nc = s // lc

    z = _proj(fz, tr, "wz", x, lora_scale)
    xs = _proj(fz, tr, "wx", x, lora_scale)
    bmat = _proj(fz, tr, "wb", x, lora_scale)
    cmat = _proj(fz, tr, "wc", x, lora_scale)
    dt = _proj(fz, tr, "wdt", x, lora_scale).astype(jnp.float32)

    xbc = jnp.concatenate([xs, bmat, cmat], axis=-1)
    xbc, _ = _causal_depthwise_conv(xbc, tr["conv"]["w"], tr["conv"]["b"])
    xs = xbc[..., : spec.d_inner]
    bmat = xbc[..., spec.d_inner: spec.d_inner + g * n]
    cmat = xbc[..., spec.d_inner + g * n:]

    dt = jax.nn.softplus(dt + tr["dt_bias"])               # (B,S,H)
    a = -jnp.exp(tr["A_log"].astype(jnp.float32))          # (H,)

    xh = xs.reshape(bsz, nc, lc, h, p)
    bh = bmat.reshape(bsz, nc, lc, g, n)
    ch = cmat.reshape(bsz, nc, lc, g, n)
    dth = dt.reshape(bsz, nc, lc, h)
    da = dth * a                                            # (B,nc,Lc,H)
    cum = jnp.cumsum(da, axis=2)

    # ---- intra-chunk (diagonal block): decay mask L[i,j] = exp(cum_i-cum_j)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]     # (B,nc,Lc,Ls,H)
    ii, jj = jnp.arange(lc)[:, None], jnp.arange(lc)[None, :]
    tril = (ii >= jj)[None, None, :, :, None]
    decay = jnp.where(tril, jnp.exp(seg), 0.0)
    cb = jnp.einsum("bclgn,bcsgn->bcls", ch.astype(jnp.float32),
                    bh.astype(jnp.float32))                 # g == 1
    scores = cb[..., None] * decay * dth[:, :, None, :, :]  # (B,nc,Lc,Ls,H)
    y_intra = jnp.einsum("bclsh,bcshp->bclhp",
                         scores.astype(jnp.bfloat16),
                         xh.astype(jnp.bfloat16)).astype(jnp.float32)

    # ---- chunk states and inter-chunk recurrence
    last = cum[:, :, -1:, :]                                # (B,nc,1,H)
    wdecay = jnp.exp(last - cum) * dth                      # (B,nc,Lc,H)
    states = jnp.einsum("bclgn,bclh,bclhp->bchpn",
                        bh.astype(jnp.float32), wdecay,
                        xh.astype(jnp.float32))             # (B,nc,H,P,N)
    chunk_decay = jnp.exp(last[:, :, 0])                    # (B,nc,H)

    def scan_fn(hprev, inp):
        st, cd = inp
        hnew = hprev * cd[..., None, None] + st
        return hnew, hprev

    h0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    _, hprevs = jax.lax.scan(
        scan_fn, h0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    hprevs = hprevs.transpose(1, 0, 2, 3, 4)                # (B,nc,H,P,N)

    outdecay = jnp.exp(cum)                                 # (B,nc,Lc,H)
    y_inter = jnp.einsum("bclgn,bchpn,bclh->bclhp",
                         ch.astype(jnp.float32), hprevs, outdecay)

    y = (y_intra + y_inter).reshape(bsz, s, h, p)
    y = y + tr["D"].astype(jnp.float32)[None, None, :, None] \
        * xs.reshape(bsz, s, h, p).astype(jnp.float32)
    y = y.reshape(bsz, s, spec.d_inner).astype(x.dtype)
    y = L.rmsnorm_apply(tr["norm"],
                        y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype))
    if pad:
        y = y[:, :s0]
    return _proj(fz, tr, "wo", y, lora_scale)


def mamba_cache_init(spec: MambaSpec, batch: int, dtype=jnp.float32) -> dict:
    gn = spec.n_groups * spec.d_state
    return {
        "ssm": jnp.zeros((batch, spec.n_heads, spec.head_dim, spec.d_state),
                         jnp.float32),
        "conv": jnp.zeros((batch, spec.conv_kernel - 1,
                           spec.d_inner + 2 * gn), dtype),
    }


def mamba_cache_logical() -> dict:
    return {"ssm": ("batch", "ssm_heads", None, None),
            "conv": ("batch", None, "ssm_inner")}


def mamba_decode(fz: dict, tr: dict, spec: MambaSpec, x: Array,
                 cache: dict, lora_scale: float) -> tuple[Array, dict]:
    """x: (B, 1, d). O(1) recurrent step."""
    bsz = x.shape[0]
    h, p, n, g = spec.n_heads, spec.head_dim, spec.d_state, spec.n_groups
    z = _proj(fz, tr, "wz", x, lora_scale)
    xs = _proj(fz, tr, "wx", x, lora_scale)
    bmat = _proj(fz, tr, "wb", x, lora_scale)
    cmat = _proj(fz, tr, "wc", x, lora_scale)
    dt = _proj(fz, tr, "wdt", x, lora_scale).astype(jnp.float32)

    xbc = jnp.concatenate([xs, bmat, cmat], axis=-1)
    xbc, conv_state = _causal_depthwise_conv(
        xbc, tr["conv"]["w"], tr["conv"]["b"], cache["conv"])
    xs = xbc[..., : spec.d_inner][:, 0]                     # (B, d_inner)
    bvec = xbc[..., spec.d_inner: spec.d_inner + g * n][:, 0]
    cvec = xbc[..., spec.d_inner + g * n:][:, 0]

    dt = jax.nn.softplus(dt[:, 0] + tr["dt_bias"])          # (B,H)
    a = -jnp.exp(tr["A_log"].astype(jnp.float32))
    da = jnp.exp(dt * a)                                    # (B,H)
    xh = xs.reshape(bsz, h, p).astype(jnp.float32)
    bn = bvec.reshape(bsz, g, n).astype(jnp.float32)[:, 0]  # (B,N)
    cn = cvec.reshape(bsz, g, n).astype(jnp.float32)[:, 0]

    ssm = cache["ssm"] * da[..., None, None] \
        + (dt[..., None] * xh)[..., None] * bn[:, None, None, :]
    y = jnp.einsum("bhpn,bn->bhp", ssm, cn) \
        + tr["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(bsz, 1, spec.d_inner).astype(x.dtype)
    y = L.rmsnorm_apply(tr["norm"],
                        y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype))
    return _proj(fz, tr, "wo", y, lora_scale), \
        {"ssm": ssm, "conv": conv_state}
