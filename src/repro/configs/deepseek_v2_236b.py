"""deepseek-v2-236b [moe]: 60L d_model=5120 128H MLA (kv_lora=512,
q_lora=1536, nope 128 + rope 64, v 128) d_ff=1536/expert vocab=102400;
MoE 160 routed top-6 + 2 shared [arXiv:2405.04434].

Deviation noted in DESIGN.md: the real model's first layer is dense; we
scan 60 uniform MoE layers (the assignment line specifies the MoE only).
MLA is itself a low-rank factorization — FLoCoRA adapters attach to the
factor matrices (q_a/q_b/kv_a/k_b/v_b), a natural fit."""
from repro.core.lora import LoRAConfig
from repro.models.attention import MLASpec
from repro.models.lm import LMConfig
from repro.models.moe import MoESpec


def full() -> LMConfig:
    return LMConfig(
        name="deepseek-v2-236b", n_layers=60, d_model=5120, n_heads=128,
        n_kv_heads=128, head_dim=128, d_ff=1536, vocab=102400,
        mlp_kind="swiglu", attn_kind="mla",
        mla=MLASpec(d_model=5120, n_heads=128, q_lora_rank=1536,
                    kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
                    v_head_dim=128),
        moe=MoESpec(d_model=5120, d_ff=1536, n_experts=160, top_k=6,
                    n_shared=2, mlp_kind="swiglu"),
        moe_every=1,
        lora=LoRAConfig(rank=32, alpha=512.0), head_mode="lora")


def smoke() -> LMConfig:
    return LMConfig(
        name="deepseek-v2-smoke", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=64, vocab=512,
        mlp_kind="swiglu", attn_kind="mla",
        mla=MLASpec(d_model=64, n_heads=4, q_lora_rank=32,
                    kv_lora_rank=16, qk_nope_dim=16, qk_rope_dim=8,
                    v_head_dim=16),
        moe=MoESpec(d_model=64, d_ff=64, n_experts=8, top_k=2,
                    n_shared=2, mlp_kind="swiglu"),
        moe_every=1,
        lora=LoRAConfig(rank=4, alpha=64.0), head_mode="lora")
