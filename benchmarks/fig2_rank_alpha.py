"""Paper Fig. 2: rank r x scaling alpha (2r vs 16r) vs FedAvg on the
synthetic task — reproduces the paper's claim that alpha=16r beats
alpha=2r for from-scratch small-model FL."""
import sys

from benchmarks.common import fl_experiment


def run(rounds: int = 10, ranks=(8, 32)) -> list[str]:
    rows = []
    base = fl_experiment(arch="resnet8", mode="fedavg", rounds=rounds)
    rows.append(f"fig2/fedavg,0,best_acc={base['best_acc']}")
    for r in ranks:
        for mult in (2, 16):
            res = fl_experiment(arch="resnet8", rank=r,
                                alpha=float(mult * r), rounds=rounds)
            rows.append(f"fig2/r{r}_alpha{mult}r,0,"
                        f"best_acc={res['best_acc']} "
                        f"msg_bytes={res['round_bytes'] // 2}")
    return rows


if __name__ == "__main__":
    r = 10
    if "--rounds" in sys.argv:
        r = int(sys.argv[sys.argv.index("--rounds") + 1])
    print("\n".join(run(r)))
