"""FL server orchestration: FLoCoRA rounds with fault tolerance.

Production-shaped features:
  * client sampling (uniform over C clients, K' = oversample*K sampled);
  * STRAGGLER MITIGATION: K' > K clients are dispatched, the aggregation
    takes the first K arrivals (simulated latency ordering) — the paper's
    synchronous FedAvg becomes deadline-robust;
  * CLIENT DROPOUT: a failed client (prob p_fail) contributes nothing;
    aggregation weights renormalize over survivors — a round never blocks;
  * quantized broadcast + uplink per the paper (both directions, RTN) with
    optional error feedback (beyond paper);
  * atomic checkpoint/resume of (round, global adapters, sampler RNG,
    EF residuals) — a restarted server continues the exact run;
  * TCC accounting per Eq. 2 (including the shared-once initial model).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation, flocora, messages
from repro.core.flocora import FLoCoRAConfig
from repro.checkpoint import CheckpointManager
from repro.fl.client import ClientConfig, make_local_trainer, \
    stack_local_batches
from repro.utils.tree import tree_bytes

Array = jax.Array


@dataclasses.dataclass
class ServerConfig:
    rounds: int = 100
    n_clients: int = 100
    clients_per_round: int = 10
    oversample: float = 1.0        # straggler mitigation: dispatch K'=o*K
    p_client_failure: float = 0.0  # simulated client dropout
    seed: int = 0
    eval_every: int = 5
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 25


class FLServer:
    """Simulates the paper's FL loop (Fig. 1) over arbitrary models.

    model: dict with 'frozen'/'train' trees (train = FLoCoRA adapters);
    loss_fn(frozen, train, batch); client_data: list of per-client dict
    datasets (numpy); eval_fn(frozen, train) -> metrics dict.
    """

    def __init__(self, model: dict, loss_fn: Callable,
                 client_data: list[dict], scfg: ServerConfig,
                 ccfg: ClientConfig, fcfg: FLoCoRAConfig,
                 eval_fn: Optional[Callable] = None):
        self.frozen = model["frozen"]
        self.global_train = model["train"]
        self.loss_fn = loss_fn
        self.client_data = client_data
        self.scfg, self.ccfg, self.fcfg = scfg, ccfg, fcfg
        self.eval_fn = eval_fn
        self.rng = np.random.default_rng(scfg.seed)
        self.round = 0
        self.history: list[dict] = []
        self.trainer = make_local_trainer(loss_fn, ccfg)
        self.ef_residuals: dict[int, Any] = {}
        self.ckpt = CheckpointManager(scfg.checkpoint_dir) \
            if scfg.checkpoint_dir else None
        one_way = messages.message_wire_bytes(self.global_train, fcfg.qcfg)
        self.round_bytes_per_client = 2 * one_way
        self.initial_model_bytes = tree_bytes(self.frozen)

    # -- fault tolerance ----------------------------------------------------
    def save(self):
        if self.ckpt is None:
            return
        self.ckpt.save(self.round, {"train": self.global_train},
                       metadata={"round": self.round,
                                 "rng_state": repr(
                                     self.rng.bit_generator.state)})

    def try_resume(self) -> bool:
        if self.ckpt is None:
            return False
        got = self.ckpt.restore_latest({"train": self.global_train})
        if got is None:
            return False
        step, trees, man = got
        self.global_train = trees["train"]
        self.round = man["metadata"]["round"]
        st = man["metadata"].get("rng_state")
        if st:
            self.rng.bit_generator.state = eval(st)  # trusted local manifest
        return True

    # -- one round (paper Fig. 1) --------------------------------------------
    def run_round(self) -> dict:
        scfg, fcfg = self.scfg, self.fcfg
        k_target = scfg.clients_per_round
        k_dispatch = max(k_target, int(round(scfg.oversample * k_target)))
        sampled = self.rng.choice(scfg.n_clients, size=k_dispatch,
                                  replace=False)

        # (1) broadcast: clients reconstruct the quantized global adapters
        g_bcast = flocora.broadcast(self.global_train, fcfg)

        results = []
        for cid in sampled:
            if self.rng.random() < scfg.p_client_failure:
                continue                        # client died mid-round
            data = self.client_data[int(cid)]
            batches = stack_local_batches(self.rng, data, self.ccfg)
            batches = jax.tree.map(jnp.asarray, batches)
            # (2) local training from the broadcast state
            trained, local_loss = self.trainer(self.frozen, g_bcast, batches)
            # (3) uplink: quantize (optionally with error feedback)
            if fcfg.error_feedback and fcfg.qcfg.enabled:
                res = self.ef_residuals.get(
                    int(cid), aggregation.ef_init(trained))
                recon, res = aggregation.ef_encode(trained, res, fcfg.qcfg)
                self.ef_residuals[int(cid)] = jax.device_get(res)
                recon = jax.tree.map(lambda r, x: r.astype(x.dtype),
                                     recon, trained)
            else:
                recon = messages.roundtrip(trained, fcfg.qcfg)
            latency = self.rng.exponential(1.0)  # simulated arrival time
            n_i = len(next(iter(data.values())))
            results.append((latency, n_i, recon, float(local_loss)))

        if not results:
            self.round += 1
            return {"round": self.round, "n_agg": 0}

        # straggler policy: first K arrivals win
        results.sort(key=lambda r: r[0])
        kept = results[:k_target]
        weights = jnp.asarray([r[1] for r in kept], jnp.float32)
        stacked = aggregation.stack_trees([r[2] for r in kept])
        # (4) FedAvg over dequantized client messages
        self.global_train = aggregation.fedavg(stacked, weights)
        self.round += 1

        rec = {"round": self.round, "n_agg": len(kept),
               "n_dropped": k_dispatch - len(results),
               "n_straggled": len(results) - len(kept),
               "client_loss": float(np.mean([r[3] for r in kept])),
               "tcc_bytes": self.round * self.round_bytes_per_client}
        if self.eval_fn and self.round % self.scfg.eval_every == 0:
            rec.update(self.eval_fn(self.frozen, self.global_train))
        self.history.append(rec)
        if self.ckpt and self.round % self.scfg.checkpoint_every == 0:
            self.save()
        return rec

    def run(self, rounds: Optional[int] = None) -> list[dict]:
        for _ in range(rounds or self.scfg.rounds):
            self.run_round()
        return self.history
