"""Server-side aggregation for FLoCoRA.

FLoCoRA is aggregation-agnostic (paper §III): clients exchange *adapter
parameter trees*, so any parameter-averaging FL rule applies unchanged.
Implemented here:

  * ``fedavg``      — n_k/n weighted mean (paper's showcase, Eq. 1);
  * ``fedavg_quantized`` — the paper's full pipeline: each client message
    is quantize->dequantize'd before the weighted mean (server sees RTN
    reconstructions); server->client broadcast is quantized again by the
    caller via ``messages.roundtrip``;
  * ``fedbuff``     — beyond-paper async buffered aggregation with
    staleness discounting (Nguyen et al. '22 style);
  * ``ErrorFeedback`` — beyond-paper EF residual compensation making the
    quantizer unbiased-in-time (EF21-style memory).

All functions operate on stacked client trees: every leaf carries a
leading K (clients) dim, so the whole aggregation jits into a single
fused reduce (see kernels/agg for the Pallas version).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import messages
from repro.core.quant import QuantConfig

Array = jax.Array


def stack_trees(trees: list[Any]) -> Any:
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def fedavg(stacked: Any, weights: Array) -> Any:
    """Weighted mean over the leading client axis. weights sum to 1."""
    w = weights / jnp.sum(weights)

    def mean(x):
        wr = w.reshape((-1,) + (1,) * (x.ndim - 1)).astype(jnp.float32)
        return jnp.sum(x.astype(jnp.float32) * wr, axis=0).astype(x.dtype)

    return jax.tree.map(mean, stacked)


def fedavg_quantized(stacked: Any, weights: Array, qcfg: QuantConfig) -> Any:
    """Paper pipeline: dequantized-client-view weighted mean.

    `stacked` holds the raw fp client trees; each is passed through the
    RTN roundtrip (per-client qparams, as on the wire) before averaging.
    """
    if qcfg.enabled:
        stacked = jax.vmap(lambda t: messages.roundtrip(t, qcfg))(stacked)
    return fedavg(stacked, weights)


# ---------------------------------------------------------------------------
# Beyond-paper: async buffered aggregation (FedBuff)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FedBuffState:
    buffer: Any          # running weighted sum of updates
    weight: Array        # running sum of weights
    count: Array         # updates buffered so far (int32)


def fedbuff_init(like: Any) -> FedBuffState:
    return FedBuffState(
        buffer=jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), like),
        weight=jnp.zeros((), jnp.float32),
        count=jnp.zeros((), jnp.int32),
    )


def fedbuff_add(state: FedBuffState, update: Any, n_k: Array,
                staleness: Array, half_life: float = 4.0) -> FedBuffState:
    """Add one async client update with staleness-discounted weight
    w = n_k * 2^(-staleness/half_life)."""
    w = n_k.astype(jnp.float32) * jnp.exp2(-staleness.astype(jnp.float32)
                                           / half_life)
    buf = jax.tree.map(lambda b, u: b + w * u.astype(jnp.float32),
                       state.buffer, update)
    return FedBuffState(buf, state.weight + w, state.count + 1)


def fedbuff_flush(state: FedBuffState, like: Any) -> tuple[Any, FedBuffState]:
    """Produce the aggregated tree and reset the buffer."""
    agg = jax.tree.map(
        lambda b, x: (b / jnp.maximum(state.weight, 1e-8)).astype(x.dtype),
        state.buffer, like)
    return agg, fedbuff_init(like)


# ---------------------------------------------------------------------------
# Beyond-paper: error-feedback quantization (EF memory on the sender)
# ---------------------------------------------------------------------------

def ef_init(like: Any) -> Any:
    return jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), like)


def ef_encode(tree: Any, residual: Any, qcfg: QuantConfig
              ) -> tuple[Any, Any]:
    """Send Q(x + e); keep e' = (x + e) - Q(x + e).

    Returns (reconstruction_seen_by_receiver, new_residual)."""
    if not qcfg.enabled:
        return tree, residual
    comp = jax.tree.map(lambda x, e: x.astype(jnp.float32) + e,
                        tree, residual)
    recon = messages.roundtrip(comp, qcfg)
    new_res = jax.tree.map(lambda c, r: c - r.astype(jnp.float32),
                           comp, recon)
    recon = jax.tree.map(lambda r, x: r.astype(x.dtype), recon, tree)
    return recon, new_res
