"""Client latency/availability traces for the async federation engine.

A production fleet of millions of devices is not round-lockstep: a
client's update arrives whenever its compute + network latency and its
availability windows allow. This module supplies the PLUGGABLE timing
models that ``fl/async_engine.py`` schedules dispatch/arrival events
with:

  * :class:`LognormalLatency` — lognormal compute time scaled by the
    client's adapter-rank tier (a rank-32 workstation trains longer than
    a rank-4 phone per step, but the tier also proxies device speed via
    ``rank_exp``) plus wire-transfer time at a lognormal-jittered
    throughput, so bigger messages genuinely take longer;
  * :class:`AvailabilityWindows` — periodic per-client availability
    (phones charge at night): a dispatch outside the client's window
    waits for the next one;
  * :class:`FleetTrace` — composes the two and owns DETERMINISTIC
    REPLAY: every latency draw is keyed by ``(seed, cid,
    dispatch_idx)`` through a fresh ``np.random.Generator``, so the
    trace is a pure function of those ids — independent of event
    processing order and of checkpoint/resume boundaries. Replaying a
    run (or resuming a killed one) reproduces every arrival time
    bit-exactly.

All times are VIRTUAL seconds on the simulator clock.
"""
from __future__ import annotations

import dataclasses

import numpy as np

# rng key domain for latency draws (the engine uses its own domains for
# client sampling and batch shuffling; disjoint first keys keep every
# stream independent under the shared seed)
TAG_LATENCY = 0xA1


@dataclasses.dataclass(frozen=True)
class LognormalLatency:
    """Per-arrival latency = compute + transfer.

    compute  ~ compute_median_s * lognormal(0, compute_sigma)
               * (rank / rank_ref) ** rank_exp
    transfer = wire_bytes / (network_mbps * lognormal(0, network_sigma))

    ``rank_exp > 0`` makes higher-rank tiers slower (more adapter math
    per step); 0 decouples compute time from the tier.
    """
    compute_median_s: float = 30.0
    compute_sigma: float = 0.6
    network_mbps: float = 20.0
    network_sigma: float = 0.4
    rank_ref: int = 8
    rank_exp: float = 1.0

    def __post_init__(self):
        if self.compute_median_s <= 0 or self.network_mbps <= 0:
            raise ValueError("latency medians must be positive")
        if self.compute_sigma < 0 or self.network_sigma < 0:
            raise ValueError("sigmas must be >= 0")
        if self.rank_ref < 1:
            raise ValueError("rank_ref must be >= 1")

    def sample(self, rng: np.random.Generator, rank: int,
               wire_bytes: int) -> float:
        comp = (self.compute_median_s
                * rng.lognormal(0.0, self.compute_sigma)
                * (max(rank, 1) / self.rank_ref) ** self.rank_exp)
        bps = self.network_mbps * 1e6 / 8.0 \
            * rng.lognormal(0.0, self.network_sigma)
        return comp + wire_bytes / max(bps, 1.0)


@dataclasses.dataclass(frozen=True)
class AvailabilityWindows:
    """Periodic per-client availability: client ``cid`` is available for
    the first ``duty`` fraction of every ``period_s`` window, with a
    deterministic per-client phase (a Knuth-hash spread, so the fleet's
    windows are staggered instead of synchronized). ``period_s = 0`` or
    ``duty >= 1`` means always available."""
    period_s: float = 0.0
    duty: float = 1.0

    def __post_init__(self):
        if self.period_s < 0:
            raise ValueError("period_s must be >= 0")
        if not 0.0 < self.duty <= 1.0:
            raise ValueError("duty must be in (0, 1]")

    def phase(self, cid: int) -> float:
        if self.period_s <= 0:
            return 0.0
        return ((cid * 2654435761) % (1 << 32)) / float(1 << 32) \
            * self.period_s

    def next_available(self, cid: int, t: float) -> float:
        """Earliest time >= t at which client cid is available."""
        if self.period_s <= 0 or self.duty >= 1.0:
            return t
        pos = (t - self.phase(cid)) % self.period_s
        if pos < self.duty * self.period_s:
            return t
        return t + (self.period_s - pos)


@dataclasses.dataclass(frozen=True)
class FleetTrace:
    """Deterministic-replay fleet timing model.

    ``arrival(cid, dispatch_idx, rank, wire_bytes, t_dispatch)`` returns
    the virtual time at which that dispatch's update reaches the server:
    availability wait, then the sampled compute+transfer latency. The
    latency draw is a pure function of ``(seed, cid, dispatch_idx)`` —
    see the module docstring for why that makes runs replayable."""
    seed: int = 0
    latency: LognormalLatency = dataclasses.field(
        default_factory=LognormalLatency)
    availability: AvailabilityWindows = dataclasses.field(
        default_factory=AvailabilityWindows)

    def arrival(self, cid: int, dispatch_idx: int, rank: int,
                wire_bytes: int, t_dispatch: float) -> float:
        rng = np.random.default_rng(
            [self.seed, TAG_LATENCY, cid, dispatch_idx])
        t0 = self.availability.next_available(cid, t_dispatch)
        return t0 + self.latency.sample(rng, rank, wire_bytes)
