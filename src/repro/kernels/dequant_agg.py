"""Pallas TPU kernel: fused unpack + dequantize + weighted aggregate.

The FLoCoRA server hot loop: K quantized client messages -> one fp32
aggregated adapter tree, WITHOUT materializing K dequantized fp32 copies
(K x memory saved; the op is bandwidth-bound on the packed payload, which
is 4-16x smaller than fp32 — this fusion is what makes the paper's
quantization a server-side win too, not just a wire win).

Like ``quant_pack``, the valid-column count is PER ROW: a (C, 1) int32
sidecar masks each row's tail so a whole flat-tree message (every leaf's
channel rows stacked into one ragged buffer, core/flat.py) aggregates a
K-client cohort in ONE launch — contributions past a row's length are
forced to exact zero, so flat rows slice apart cleanly.

Grid: (C/bc, K) with K innermost — each (bc, Nw) packed tile is unpacked,
dequantized with its (per-client, per-channel) scale/zp and accumulated
into the fp32 output block resident in VMEM across the K steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

Array = jax.Array


def _dequant_agg_kernel(packed_ref, scale_ref, zp_ref, w_ref, nv_ref,
                        out_ref, *, bits: int):
    k = pl.program_id(1)
    per = 32 // bits
    words = packed_ref[0]                                  # (bc, Nw) uint32
    shifts = (jax.lax.broadcasted_iota(
        jnp.uint32, (*words.shape, per), 2) * jnp.uint32(bits))
    mask = jnp.uint32((1 << bits) - 1)
    lv = ((words[..., None] >> shifts) & mask).astype(jnp.float32)
    lv = lv.reshape(words.shape[0], words.shape[1] * per)  # (bc, N)
    scale = scale_ref[0]                                   # (bc, 1)
    zp = zp_ref[0]
    w = w_ref[0, 0]
    nv = nv_ref[...]                                       # (bc, 1) int32
    col = jax.lax.broadcasted_iota(jnp.int32, lv.shape, 1)
    contrib = jnp.where(col < nv, w * (lv - zp) * scale, 0.0)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = contrib

    @pl.when(k > 0)
    def _acc():
        out_ref[...] += contrib


def _dequant_agg_rows_kernel(packed_ref, scale_ref, zp_ref, w_ref, nv_ref,
                             out_ref, *, bits: int):
    """Flat-tree variant: the WHOLE K client dim rides in the block (the
    packed payload is 4-16x smaller than fp32, so K tiles fit VMEM) and
    the grid walks channel blocks only — one launch, one output pass."""
    per = 32 // bits
    words = packed_ref[...]                          # (K, bc, Nw) uint32
    shifts = (jax.lax.broadcasted_iota(
        jnp.uint32, (*words.shape, per), 3) * jnp.uint32(bits))
    msk = jnp.uint32((1 << bits) - 1)
    lv = ((words[..., None] >> shifts) & msk).astype(jnp.float32)
    lv = lv.reshape(*words.shape[:2], words.shape[2] * per)  # (K, bc, N)
    deq = (lv - zp_ref[...]) * scale_ref[...]        # sidecars (K, bc, 1)
    acc = jnp.sum(w_ref[...][..., None] * deq, axis=0)       # (bc, N)
    nv = nv_ref[...]                                 # (bc, 1) int32
    col = jax.lax.broadcasted_iota(jnp.int32, acc.shape, 1)
    out_ref[...] = jnp.where(col < nv, acc, 0.0)


def dequant_agg_rows_pallas(packed: Array, scale: Array, zp: Array,
                            weights: Array, n_valid: Array, bits: int, *,
                            block_c: int = 8,
                            interpret: bool = False) -> Array:
    """packed (K, C, Nw) uint32; scale/zp (K, C); weights (K,);
    n_valid (C,) per-row true lengths. One launch aggregates the whole
    flat-tree cohort; tails past each row's length are exact zeros.
    Returns (C, N) fp32."""
    k, c, nw = packed.shape
    per = 32 // bits
    n = nw * per
    assert c % block_c == 0
    nv = jnp.asarray(n_valid, jnp.int32).reshape(c, 1)
    grid = (c // block_c,)
    out = pl.pallas_call(
        functools.partial(_dequant_agg_rows_kernel, bits=bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((k, block_c, nw), lambda i: (0, i, 0)),
            pl.BlockSpec((k, block_c, 1), lambda i: (0, i, 0)),
            pl.BlockSpec((k, block_c, 1), lambda i: (0, i, 0)),
            pl.BlockSpec((k, 1), lambda i: (0, 0)),
            pl.BlockSpec((block_c, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_c, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((c, n), jnp.float32),
        interpret=interpret,
    )(packed, scale[..., None], zp[..., None], weights[:, None], nv)
    return out


def dequant_agg_pallas(packed: Array, scale: Array, zp: Array,
                       weights: Array, bits: int, *,
                       n_valid: int | Array | None = None,
                       block_c: int = 8,
                       interpret: bool = False) -> Array:
    """packed (K, C, Nw) uint32; scale/zp (K, C); weights (K,).

    ``n_valid`` (scalar or (C,) vector, default N) zeroes each row's
    tail past its true length — shared by all K clients, since the row
    layout is a property of the message structure, not the sender.

    Returns (C, N) fp32 weighted sum of dequantized messages."""
    k, c, nw = packed.shape
    per = 32 // bits
    n = nw * per
    assert c % block_c == 0
    if n_valid is None:
        n_valid = n
    if isinstance(n_valid, (int, np.integer)):
        nv = jnp.full((c, 1), n_valid, jnp.int32)
    else:
        nv = jnp.asarray(n_valid, jnp.int32).reshape(c, 1)
    grid = (c // block_c, k)
    out = pl.pallas_call(
        functools.partial(_dequant_agg_kernel, bits=bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_c, nw), lambda i, kk: (kk, i, 0)),
            pl.BlockSpec((1, block_c, 1), lambda i, kk: (kk, i, 0)),
            pl.BlockSpec((1, block_c, 1), lambda i, kk: (kk, i, 0)),
            pl.BlockSpec((1, 1), lambda i, kk: (kk, 0)),
            pl.BlockSpec((block_c, 1), lambda i, kk: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_c, n), lambda i, kk: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((c, n), jnp.float32),
        interpret=interpret,
    )(packed, scale[..., None], zp[..., None], weights[:, None], nv)
    return out
