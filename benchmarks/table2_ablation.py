"""Paper Table II: which layers must be trained densely alongside the
LoRA adapters. Synthetic-data reproduction of the ablation's ORDERING
(vanilla << +norms << +final-FC); absolute CIFAR-10 numbers are offline-
unreachable (EXPERIMENTS.md §Repro-validity)."""
import sys

from benchmarks.common import fl_experiment

CONFIGS = [
    ("vanilla", dict(stem_mode="lora", fc_mode="lora",
                     norms_trained=False)),
    ("plus_norms", dict(stem_mode="lora", fc_mode="lora",
                        norms_trained=True)),
    ("plus_final_fc", dict(stem_mode="dense", fc_mode="dense",
                           norms_trained=True)),
]


def run(rounds: int = 10) -> list[str]:
    rows = []
    accs = {}
    for name, kw in CONFIGS:
        res = fl_experiment(arch="resnet8", rank=32, alpha=512.0,
                            rounds=rounds, **kw)
        accs[name] = res["best_acc"]
        rows.append(f"table2/{name},0,best_acc={res['best_acc']}")
    ordered = (accs["vanilla"] <= accs["plus_final_fc"] + 0.02)
    rows.append(f"table2/ordering,0,"
                f"vanilla<=final_fc={'OK' if ordered else 'UNEXPECTED'}")
    return rows


if __name__ == "__main__":
    r = 10
    if "--rounds" in sys.argv:
        r = int(sys.argv[sys.argv.index("--rounds") + 1])
    print("\n".join(run(r)))
