"""Pytree utilities used across the framework.

Params everywhere in this codebase are plain nested dicts of jnp arrays
(no flax). These helpers give the few tree algebra ops the FL runtime and
optimizers need, plus name-aware iteration for sharding-rule matching.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


def tree_size(tree: Any) -> int:
    """Total number of elements across all leaves."""
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_bytes(tree: Any) -> int:
    """Total bytes across all leaves (by dtype itemsize)."""
    return sum(
        int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
        for x in jax.tree.leaves(tree)
    )


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def tree_map_with_path(fn: Callable[[str, Any], Any], tree: Any) -> Any:
    """Map ``fn(name, leaf)`` over the tree, where name is 'a/b/c'."""
    return jax.tree_util.tree_map_with_path(
        lambda path, x: fn(_path_str(path), x), tree
    )


def flatten_with_names(tree: Any) -> list[tuple[str, Any]]:
    """Flatten into [(path_string, leaf), ...] in deterministic order."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(_path_str(path), leaf) for path, leaf in flat]


def tree_zeros_like(tree: Any) -> Any:
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a: Any, b: Any) -> Any:
    return jax.tree.map(lambda x, y: x + y, a, b)


def tree_sub(a: Any, b: Any) -> Any:
    return jax.tree.map(lambda x, y: x - y, a, b)


def tree_scale(a: Any, s) -> Any:
    return jax.tree.map(lambda x: x * s, a)


def tree_weighted_sum(trees: list[Any], weights) -> Any:
    """sum_i weights[i] * trees[i], leafwise. weights: 1-D array-like."""
    weights = jnp.asarray(weights)

    def _leafsum(*leaves):
        stacked = jnp.stack(leaves, axis=0)
        w = weights.reshape((-1,) + (1,) * (stacked.ndim - 1))
        return jnp.sum(stacked * w.astype(stacked.dtype), axis=0)

    return jax.tree.map(_leafsum, *trees)
