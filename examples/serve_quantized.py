"""Serving with quantized FLoCoRA adapters: the server ships int8/int4
adapter messages to an edge inference node, which dequantizes, MERGES
them into the frozen base (W* = W + (α/r)·AB — zero added latency,
paper §II-C) and serves.

Also demonstrates the fused Pallas lora_matmul path (unmerged serving,
e.g. when one base hosts many adapters) against the merged oracle.

    PYTHONPATH=src python examples/serve_quantized.py
"""
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

from repro.core import messages
from repro.core.lora import LoRAConfig, dense_merge
from repro.core.quant import QuantConfig
from repro.kernels import ops
from repro.models import lm as LM


def main():
    cfg = LM.LMConfig(name="edge-lm", n_layers=4, d_model=128, n_heads=4,
                      n_kv_heads=2, head_dim=32, d_ff=512, vocab=512,
                      lora=LoRAConfig(rank=8, alpha=128.0),
                      head_mode="lora")
    params = LM.init(jax.random.PRNGKey(0), cfg)
    frozen, train = params["frozen"], params["train"]
    # pretend the adapters were trained: give them nonzero values
    train = jax.tree.map(
        lambda x: x + 0.02 * jax.random.normal(jax.random.PRNGKey(1),
                                               x.shape, x.dtype), train)

    # --- the wire: server -> edge, int4 ---------------------------------
    qcfg = QuantConfig(bits=4)
    wire_bytes = messages.message_wire_bytes(train, qcfg)
    fp_bytes = messages.message_wire_bytes(train, QuantConfig())
    print(f"adapter download: {wire_bytes / 1e3:.1f} KB int4 "
          f"(vs {fp_bytes / 1e3:.1f} KB fp32, "
          f"{fp_bytes / wire_bytes:.1f}x)")
    train_edge = messages.roundtrip(train, qcfg)   # what the edge decodes

    # --- generate with the dequantized adapters -------------------------
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (2, 12)), jnp.int32)
    logits, caches, pos = jax.jit(
        lambda f, t, tok: LM.prefill(f, t, cfg, tok, max_seq=32))(
        frozen, train_edge, prompt)
    decode = jax.jit(lambda f, t, tok, c, p: LM.decode_step(
        f, t, cfg, tok, c, p))
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    toks = [tok]
    for _ in range(8):
        logits, caches = decode(frozen, train_edge, tok, caches, pos)
        tok = jnp.argmax(logits[:, 0], -1)[:, None].astype(jnp.int32)
        pos = pos + 1
        toks.append(tok)
    print("generated:", np.asarray(jnp.concatenate(toks, 1)))

    # --- merged vs fused-kernel serving equivalence ---------------------
    w = frozen["groups"][0][0]["mlp"]["wi"]["w"][0]          # (d, ff)
    a = train_edge["groups"][0][0]["mlp"]["wi"]["a"][0]
    b = train_edge["groups"][0][0]["mlp"]["wi"]["b"][0]
    x = (jax.random.normal(jax.random.PRNGKey(2), (16, cfg.d_model)) * 0.5
         ).astype(jnp.bfloat16)
    y_merged = x @ dense_merge(w, a, b, cfg.lora.scale)
    y_fused = ops.lora_matmul(x, w, a.astype(jnp.bfloat16),
                              b.astype(jnp.bfloat16), cfg.lora.scale)
    err = float(jnp.max(jnp.abs(y_merged.astype(jnp.float32)
                                - y_fused.astype(jnp.float32))))
    print(f"fused lora_matmul vs merged-weights: maxerr={err:.4f} (bf16)")


if __name__ == "__main__":
    main()
