"""Elastic scaling: restore a logical checkpoint onto a DIFFERENT mesh.

Checkpoints store unsharded host arrays (repro.checkpoint); a restarted
job builds its own mesh (any shape whose axes divide the dims per the
best-effort rules) and re-device_puts every leaf with the new
NamedShardings derived from the same logical annotations. Nothing about
the checkpoint depends on the old topology — scale 256 -> 512 chips (or
down to 1 for a laptop repro) without conversion."""
from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import Mesh

from repro.checkpoint import restore, latest_step
from repro.utils.sharding import tree_shardings


def elastic_restore(directory: str, like: dict[str, Any],
                    logical: dict[str, Any], mesh: Mesh,
                    rules: Optional[dict] = None,
                    step: Optional[int] = None):
    """like/logical: {'group': tree} / {'group': logical-annotation tree}.
    Groups present in `logical` get mesh shardings; others land on host."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            return None
    shardings = {g: tree_shardings(logical[g], like[g], mesh, rules)
                 for g in logical}
    trees, man = restore(directory, step, like, shardings)
    return step, trees, man
