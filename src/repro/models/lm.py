"""Unified decoder-LM builder covering 8 of the 10 assigned architectures
(minitron, qwen1.5-110b, nemotron-4-340b, gemma3-4b, paligemma-3b,
llama4-maverick, deepseek-v2, mamba2-370m, zamba2-2.7b; seamless is the
separate enc-dec builder).

Layer stacking: the per-arch layer sequence is resolved into *scan
groups* — (pattern, repeats) pairs where `pattern` is a short tuple of
LayerSpecs and params are stacked over `repeats` (vmapped init, lax.scan
apply, jax.checkpoint remat). This keeps HLO size ~O(|pattern|) per group
regardless of depth (96-layer nemotron compiles as one scan), while
heterogeneous stacks (gemma3's 5 local : 1 global, llama4's dense/MoE
interleave, zamba2's shared-attention-every-6) stay expressible.

Zamba2's shared attention block has ONE frozen param set reused at every
invocation with *per-invocation LoRA adapters* (stacked over repeats) —
exactly the paper's adapter mechanism, applied to weight sharing.

Param bundles:  frozen / train trees with parallel 'logical' annotation
trees for the sharding rules (utils.sharding).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.lora import LoRAConfig, linear_init, linear_apply, \
    linear_logical
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.utils.pcontext import constrain as pconstrain

Array = jax.Array


# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str                   # 'gqa' | 'mla' | 'mamba' | 'shared_gqa'
    ffn: str                     # 'dense' | 'moe' | 'none'
    window: Optional[int] = None
    global_rope: bool = False    # use rope_base_global


@dataclasses.dataclass(frozen=True)
class Group:
    pattern: tuple[LayerSpec, ...]
    repeats: int


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    mlp_kind: str = "swiglu"
    qkv_bias: bool = False
    qk_norm: bool = False
    pad_heads_to: Optional[int] = None
    rope_base: float = 1e4
    rope_base_global: Optional[float] = None
    window: Optional[int] = None
    window_pattern: Optional[int] = None   # every Nth layer is global
    attn_kind: str = "gqa"                 # 'gqa' | 'mla' | 'none'
    mla: Optional[A.MLASpec] = None
    moe: Optional[MOE.MoESpec] = None
    moe_every: int = 1
    mamba: Optional[SSM.MambaSpec] = None
    shared_attn_every: Optional[int] = None   # zamba2
    prefix_lm: bool = False
    prefix_len: int = 0
    embed_scale: bool = False
    # FLoCoRA
    lora: LoRAConfig = LoRAConfig()
    head_mode: str = "lora"                 # 'dense'|'lora'|'frozen'
    # memory policy
    remat: bool = True
    kv_chunk: int = 1024
    xent_chunk: int = 512

    @property
    def gqa(self) -> A.GQASpec:
        return A.GQASpec(self.d_model, self.n_heads, self.n_kv_heads,
                         self.head_dim, self.qkv_bias, self.qk_norm,
                         self.pad_heads_to)


def resolve_groups(cfg: LMConfig) -> list[Group]:
    if cfg.shared_attn_every:                      # zamba2
        ev = cfg.shared_attn_every
        assert cfg.n_layers % ev == 0
        pat = (LayerSpec("shared_gqa", "dense"),) + \
            (LayerSpec("mamba", "none"),) * ev
        return [Group(pat, cfg.n_layers // ev)]
    if cfg.mamba is not None and cfg.attn_kind == "none":  # mamba2
        return [Group((LayerSpec("mamba", "none"),), cfg.n_layers)]
    mixer = "mla" if cfg.attn_kind == "mla" else "gqa"
    if cfg.window_pattern:                          # gemma3: N-1 local, 1 global
        n = cfg.window_pattern
        pat = tuple(LayerSpec(mixer, "dense", window=cfg.window)
                    for _ in range(n - 1)) + \
            (LayerSpec(mixer, "dense", window=None, global_rope=True),)
        full = cfg.n_layers // n
        groups = [Group(pat, full)]
        rem = cfg.n_layers - full * n
        if rem:
            groups.append(Group(
                (LayerSpec(mixer, "dense", window=cfg.window),), rem))
        return groups
    if cfg.moe is not None:
        if cfg.moe_every == 1:
            return [Group((LayerSpec(mixer, "moe"),), cfg.n_layers)]
        assert cfg.n_layers % cfg.moe_every == 0
        pat = (LayerSpec(mixer, "dense"),) * (cfg.moe_every - 1) + \
            (LayerSpec(mixer, "moe"),)
        return [Group(pat, cfg.n_layers // cfg.moe_every)]
    return [Group((LayerSpec(mixer, "dense", window=cfg.window),),
                  cfg.n_layers)]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _layer_init(key: Array, cfg: LMConfig, spec: LayerSpec,
                stack: tuple[int, ...], shared_fz: Optional[dict]
                ) -> tuple[dict, dict]:
    """One pattern position. Returns (frozen, trainable); for shared
    mixers the frozen part comes from `shared_fz` and is returned empty."""
    ks = jax.random.split(key, 4)
    fz: dict = {}
    tr: dict = {"norm1": L.rmsnorm_init(cfg.d_model, stack)}
    if spec.mixer == "gqa":
        f, t = A.gqa_init(ks[0], cfg.gqa, "lora", cfg.lora, stack)
        fz["attn"], tr["attn"] = f, t
    elif spec.mixer == "shared_gqa":
        # frozen base initialized ONCE by caller; here only the stacked
        # per-invocation trainables.
        f, t = A.gqa_init(ks[0], cfg.gqa, "lora", cfg.lora, stack)
        tr["attn"] = t
    elif spec.mixer == "mla":
        f, t = A.mla_init(ks[0], cfg.mla, "lora", cfg.lora, stack)
        fz["attn"], tr["attn"] = f, t
    elif spec.mixer == "mamba":
        f, t = SSM.mamba_init(ks[0], cfg.mamba, "lora", cfg.lora, stack)
        fz["mix"], tr["mix"] = f, t
    else:
        raise ValueError(spec.mixer)
    if spec.ffn == "dense":
        tr["norm2"] = L.rmsnorm_init(cfg.d_model, stack)
        f, t = L.mlp_init(ks[1], L.MLPSpec(cfg.mlp_kind, cfg.d_model,
                                           cfg.d_ff), "lora", cfg.lora, stack)
        if f:
            fz["mlp"] = f
        if t:
            tr["mlp"] = t
    elif spec.ffn == "moe":
        tr["norm2"] = L.rmsnorm_init(cfg.d_model, stack)
        f, t = MOE.moe_init(ks[1], cfg.moe, "lora", cfg.lora, stack)
        if f:
            fz["moe"] = f
        if t:
            tr["moe"] = t
    return fz, tr


def _layer_logical(cfg: LMConfig, spec: LayerSpec, stack: bool
                   ) -> tuple[dict, dict]:
    pre = ("layers",) if stack else ()
    fz: dict = {}
    tr: dict = {"norm1": {"scale": (*pre, None)}}
    if spec.mixer in ("gqa", "shared_gqa"):
        f, t = A.gqa_logical(cfg.gqa, "lora", stack)
        tr["attn"] = t
        if spec.mixer == "gqa":
            fz["attn"] = f
    elif spec.mixer == "mla":
        f, t = A.mla_logical(cfg.mla, "lora", stack)
        fz["attn"], tr["attn"] = f, t
    elif spec.mixer == "mamba":
        f, t = SSM.mamba_logical(cfg.mamba, "lora", stack)
        fz["mix"], tr["mix"] = f, t
    if spec.ffn == "dense":
        tr["norm2"] = {"scale": (*pre, None)}
        f, t = L.mlp_logical(L.MLPSpec(cfg.mlp_kind, cfg.d_model, cfg.d_ff),
                             "lora", stack)
        if f:
            fz["mlp"] = f
        if t:
            tr["mlp"] = t
    elif spec.ffn == "moe":
        tr["norm2"] = {"scale": (*pre, None)}
        f, t = MOE.moe_logical(cfg.moe, "lora", stack)
        if f:
            fz["moe"] = f
        if t:
            tr["moe"] = t
    return fz, tr


def init(key: Array, cfg: LMConfig) -> dict:
    """Returns {'frozen','train','logical_frozen','logical_train'}."""
    groups = resolve_groups(cfg)
    k_embed, k_head, k_shared, *k_groups = jax.random.split(
        key, 3 + len(groups))
    frozen: dict = {}
    train: dict = {}
    lf: dict = {}
    lt: dict = {}

    # embeddings: frozen (random, shared once — DESIGN.md §5)
    frozen["embed"] = {"w": (jax.random.normal(
        k_embed, (cfg.vocab, cfg.d_model), jnp.float32)).astype(jnp.bfloat16)}
    lf["embed"] = {"w": ("vocab", "fsdp")}

    # head
    hf, ht = linear_init(k_head, cfg.d_model, cfg.vocab, cfg.head_mode,
                         cfg.lora, w_init_scale=cfg.d_model ** -0.5)
    hlf, hlt = linear_logical("fsdp", "vocab", cfg.head_mode)
    if hf:
        frozen["head"] = hf
        lf["head"] = hlf
    if ht:
        train["head"] = ht
        lt["head"] = hlt

    train["final_norm"] = L.rmsnorm_init(cfg.d_model)
    lt["final_norm"] = {"scale": (None,)}

    # shared mixer frozen base (zamba2)
    shared_specs = {s.mixer for g in groups for s in g.pattern
                    if s.mixer.startswith("shared")}
    if shared_specs:
        f, _ = A.gqa_init(k_shared, cfg.gqa, "lora", cfg.lora)
        frozen["shared_attn"] = f
        flog, _ = A.gqa_logical(cfg.gqa, "lora", stack=False)
        lf["shared_attn"] = flog

    frozen["groups"] = []
    train["groups"] = []
    lf["groups"] = []
    lt["groups"] = []
    for gi, g in enumerate(groups):
        kp = jax.random.split(k_groups[gi], len(g.pattern))
        gfz, gtr, glf, glt = [], [], [], []
        for pi, spec in enumerate(g.pattern):
            keys = jax.random.split(kp[pi], g.repeats)
            f, t = jax.vmap(
                lambda k_: _layer_init(k_, cfg, spec, (), None))(keys)
            gfz.append(f)
            gtr.append(t)
            flog, tlog = _layer_logical(cfg, spec, stack=True)
            glf.append(flog)
            glt.append(tlog)
        frozen["groups"].append(gfz)
        train["groups"].append(gtr)
        lf["groups"].append(glf)
        lt["groups"].append(glt)

    return {"frozen": frozen, "train": train,
            "logical_frozen": lf, "logical_train": lt}


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _rope_for(cfg: LMConfig, spec: LayerSpec, positions: Array):
    if spec.mixer == "mamba":
        return None
    base = cfg.rope_base_global if (spec.global_rope and
                                    cfg.rope_base_global) else cfg.rope_base
    dim = (cfg.mla.qk_rope_dim if spec.mixer == "mla" else cfg.head_dim)
    return L.rope_for_positions(positions, dim, base)


def _apply_layer(cfg: LMConfig, spec: LayerSpec, fz: dict, tr: dict,
                 shared_fz: Optional[dict], x: Array, positions: Array,
                 prefix_len: Optional[Array], constrain: Callable
                 ) -> tuple[Array, Array]:
    """Returns (x_out, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    sc = cfg.lora.scale
    h = L.rmsnorm_apply(tr["norm1"], x)
    rope = _rope_for(cfg, spec, positions)
    if spec.mixer in ("gqa", "shared_gqa"):
        afz = shared_fz if spec.mixer == "shared_gqa" else fz["attn"]
        h = A.gqa_apply(afz, tr["attn"], cfg.gqa, h, sc, rope,
                        window=spec.window, causal=True,
                        prefix_len=prefix_len, kv_chunk=cfg.kv_chunk)
    elif spec.mixer == "mla":
        h = A.mla_apply(fz["attn"], tr["attn"], cfg.mla, h, sc, rope,
                        kv_chunk=cfg.kv_chunk)
    elif spec.mixer == "mamba":
        h = SSM.mamba_apply(fz["mix"], tr["mix"], cfg.mamba, h, sc)
    x = constrain(x + h)
    if spec.ffn != "none":
        h = L.rmsnorm_apply(tr["norm2"], x)
        if spec.ffn == "dense":
            h = L.mlp_apply(fz.get("mlp", {}), tr.get("mlp", {}),
                            L.MLPSpec(cfg.mlp_kind, cfg.d_model, cfg.d_ff),
                            h, sc)
        else:
            h, aux = MOE.moe_apply(fz.get("moe", {}), tr.get("moe", {}),
                                   cfg.moe, h, sc)
        x = constrain(x + h)
    return x, aux


def forward(frozen: dict, train: dict, cfg: LMConfig, tokens: Array,
            prefix_embed: Optional[Array] = None,
            constrain: Optional[Callable] = None
            ) -> tuple[Array, Array]:
    """tokens: (B, S). Optional prefix_embed (B, P, d) is prepended
    (PaliGemma stub frontend). Returns (hidden (B, S_total, d), aux)."""
    constrain = constrain or (lambda x: x)
    x = _embed_lookup(frozen, tokens)
    if cfg.embed_scale:
        x = (x.astype(jnp.float32) * (cfg.d_model ** 0.5)).astype(x.dtype)
    prefix_len = None
    if prefix_embed is not None:
        x = jnp.concatenate([prefix_embed.astype(x.dtype), x], axis=1)
        prefix_len = jnp.full((x.shape[0],), prefix_embed.shape[1],
                              jnp.int32)
    elif cfg.prefix_lm and cfg.prefix_len:
        prefix_len = jnp.full((x.shape[0],), cfg.prefix_len, jnp.int32)
    x = constrain(x)
    s = x.shape[1]
    positions = jnp.arange(s)[None, :]
    aux_total = jnp.zeros((), jnp.float32)

    groups = resolve_groups(cfg)
    for gi, g in enumerate(groups):
        gfz = frozen["groups"][gi]
        gtr = train["groups"][gi]
        shared_fz = frozen.get("shared_attn")

        def body(carry, xs):
            xc, auxc = carry
            for pi, spec in enumerate(g.pattern):
                xc, a = _apply_layer(cfg, spec, xs[0][pi], xs[1][pi],
                                     shared_fz, xc, positions, prefix_len,
                                     constrain)
                auxc = auxc + a
            return (xc, auxc), None

        if cfg.remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        (x, aux_total), _ = jax.lax.scan(
            body, (x, aux_total), (gfz, gtr), length=g.repeats)

    x = L.rmsnorm_apply(train["final_norm"], x)
    return x, aux_total


def loss_fn(frozen: dict, train: dict, cfg: LMConfig, batch: dict,
            constrain: Optional[Callable] = None) -> tuple[Array, dict]:
    """batch: {'tokens': (B, S+1) int32, optional 'prefix_embed'}."""
    tokens = batch["tokens"][:, :-1]
    labels = batch["tokens"][:, 1:]
    h, aux = forward(frozen, train, cfg, tokens,
                     batch.get("prefix_embed"), constrain)
    if batch.get("prefix_embed") is not None:
        h = h[:, batch["prefix_embed"].shape[1]:]
    hf = frozen.get("head", {})
    ht = train.get("head", {})
    xent = L.chunked_xent(h, hf, ht, labels, cfg.lora.scale,
                          chunk=cfg.xent_chunk,
                          mask=batch.get("loss_mask"))
    loss = xent + 0.01 * aux
    return loss, {"xent": xent, "aux": aux}


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------

def _layer_cache_init(cfg: LMConfig, spec: LayerSpec, batch: int,
                      max_seq: int) -> dict:
    if spec.mixer in ("gqa", "shared_gqa"):
        return A.gqa_cache_init(cfg.gqa, batch, max_seq, spec.window)
    if spec.mixer == "mla":
        return A.mla_cache_init(cfg.mla, batch, max_seq)
    if spec.mixer == "mamba":
        return SSM.mamba_cache_init(cfg.mamba, batch)
    raise ValueError(spec.mixer)


def _layer_cache_logical(cfg: LMConfig, spec: LayerSpec) -> dict:
    if spec.mixer in ("gqa", "shared_gqa"):
        base = A.gqa_cache_logical()
    elif spec.mixer == "mla":
        base = A.mla_cache_logical()
    else:
        base = SSM.mamba_cache_logical()
    # add the leading layer-stack axis
    return jax.tree.map(lambda t: ("layers",) + t, base,
                        is_leaf=lambda t: isinstance(t, tuple) and all(
                            isinstance(e, (str, type(None))) for e in t))


def cache_init(cfg: LMConfig, batch: int, max_seq: int) -> list:
    """Stacked cache tree parallel to groups: leaves (repeats, B, ...)."""
    groups = resolve_groups(cfg)
    out = []
    for g in groups:
        pos_caches = []
        for spec in g.pattern:
            c = _layer_cache_init(cfg, spec, batch, max_seq)
            c = jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (g.repeats,) + x.shape),
                c)
            pos_caches.append(c)
        out.append(pos_caches)
    return out


def cache_logical(cfg: LMConfig) -> list:
    groups = resolve_groups(cfg)
    return [[_layer_cache_logical(cfg, spec) for spec in g.pattern]
            for g in groups]


def _decode_layer(cfg: LMConfig, spec: LayerSpec, fz: dict, tr: dict,
                  shared_fz: Optional[dict], x: Array, cache: dict,
                  pos: Array) -> tuple[Array, dict]:
    sc = cfg.lora.scale
    h = L.rmsnorm_apply(tr["norm1"], x)
    rope = _rope_for(cfg, spec, jnp.broadcast_to(pos, (x.shape[0], 1)))
    if spec.mixer in ("gqa", "shared_gqa"):
        afz = shared_fz if spec.mixer == "shared_gqa" else fz["attn"]
        h, cache = A.gqa_decode(afz, tr["attn"], cfg.gqa, h, cache, pos,
                                sc, rope, window=spec.window)
    elif spec.mixer == "mla":
        h, cache = A.mla_decode(fz["attn"], tr["attn"], cfg.mla, h, cache,
                                pos, sc, rope)
    elif spec.mixer == "mamba":
        h, cache = SSM.mamba_decode(fz["mix"], tr["mix"], cfg.mamba, h,
                                    cache, sc)
    x = x + h
    if spec.ffn != "none":
        h = L.rmsnorm_apply(tr["norm2"], x)
        if spec.ffn == "dense":
            h = L.mlp_apply(fz.get("mlp", {}), tr.get("mlp", {}),
                            L.MLPSpec(cfg.mlp_kind, cfg.d_model, cfg.d_ff),
                            h, sc)
        else:
            h, _ = MOE.moe_apply(fz.get("moe", {}), tr.get("moe", {}),
                                 cfg.moe, h, sc)
        x = x + h
    return x, cache


def _embed_lookup(frozen: dict, tokens: Array) -> Array:
    e = frozen["embed"]
    if "w_q8" in e:
        return (e["w_q8"][tokens].astype(jnp.bfloat16)
                * e["w_s"].astype(jnp.bfloat16))
    return e["w"][tokens]


def decode_step(frozen: dict, train: dict, cfg: LMConfig, token: Array,
                caches: list, pos: Array) -> tuple[Array, list]:
    """token: (B, 1) int32; pos: () int32 — absolute position of `token`.
    Returns (logits (B, 1, V), new caches)."""
    x = _embed_lookup(frozen, token)
    if cfg.embed_scale:
        x = (x.astype(jnp.float32) * (cfg.d_model ** 0.5)).astype(x.dtype)
    groups = resolve_groups(cfg)
    new_caches = []
    for gi, g in enumerate(groups):
        gfz = frozen["groups"][gi]
        gtr = train["groups"][gi]
        shared_fz = frozen.get("shared_attn")

        def body(carry, xs):
            # caches ride in the CARRY and are updated in place per
            # layer — scan xs/ys would double-buffer the whole KV cache
            # (2x HBM on the 340B decode cells)
            xc, cache_g = carry
            fzs, trs, i = xs
            new_cs = []
            for pi, spec in enumerate(g.pattern):
                c_i = jax.tree.map(
                    lambda c: jax.lax.dynamic_index_in_dim(
                        c, i, 0, keepdims=False), cache_g[pi])
                xc, c_new = _decode_layer(cfg, spec, fzs[pi], trs[pi],
                                          shared_fz, xc, c_i, pos)
                new_cs.append(c_new)
            cache_g = [jax.tree.map(
                lambda full, new: jax.lax.dynamic_update_index_in_dim(
                    full, new.astype(full.dtype), i, 0),
                cache_g[pi], new_cs[pi]) for pi in range(len(g.pattern))]
            return (xc, cache_g), None

        (x, nc), _ = jax.lax.scan(
            body, (x, caches[gi]),
            (gfz, gtr, jnp.arange(g.repeats)), length=g.repeats)
        new_caches.append(nc)
    x = L.rmsnorm_apply(train["final_norm"], x)
    logits = linear_apply(frozen.get("head", {}), train.get("head", {}),
                          x, cfg.lora.scale).astype(jnp.float32)
    return logits, new_caches


def prefill(frozen: dict, train: dict, cfg: LMConfig, tokens: Array,
            prefix_embed: Optional[Array] = None,
            constrain: Optional[Callable] = None,
            max_seq: Optional[int] = None
            ) -> tuple[Array, list, Array]:
    """Forward over the prompt, building caches sized `max_seq`
    (default: prompt length — enough for the dry-run cells; generation
    passes prompt+headroom). Returns (last_logits (B, V), caches,
    next_pos ())."""
    constrain = constrain or (lambda x: x)
    x = _embed_lookup(frozen, tokens)
    if cfg.embed_scale:
        x = (x.astype(jnp.float32) * (cfg.d_model ** 0.5)).astype(x.dtype)
    prefix_len = None
    if prefix_embed is not None:
        x = jnp.concatenate([prefix_embed.astype(x.dtype), x], axis=1)
        prefix_len = jnp.full((x.shape[0],), prefix_embed.shape[1],
                              jnp.int32)
    x = constrain(x)
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    groups = resolve_groups(cfg)
    caches = []
    total_seq = s if max_seq is None else max(max_seq, s)
    for gi, g in enumerate(groups):
        gfz = frozen["groups"][gi]
        gtr = train["groups"][gi]
        shared_fz = frozen.get("shared_attn")
        # preallocate this group's stacked caches (constrained) and fill
        # them in place as the scan walks the layers — a scan-ys cache
        # would double-buffer (DESIGN.md §7 memory notes)
        cache_g0 = []
        for spec in g.pattern:
            c = jax.eval_shape(lambda: _layer_cache_init(
                cfg, spec, b, total_seq))
            c = jax.tree.map(
                lambda sd: pconstrain(jnp.zeros(
                    (g.repeats,) + sd.shape, sd.dtype), "cache_stack"), c)
            cache_g0.append(c)

        def body(carry, xs):
            xc, cache_g = carry
            fzs, trs, i = xs
            new_cs = []
            for pi, spec in enumerate(g.pattern):
                xc, c = _prefill_layer(cfg, spec, fzs[pi], trs[pi],
                                       shared_fz, xc, positions, prefix_len,
                                       constrain, total_seq)
                new_cs.append(c)
            cache_g = [jax.tree.map(
                lambda full, new: jax.lax.dynamic_update_index_in_dim(
                    full, new.astype(full.dtype), i, 0),
                cache_g[pi], new_cs[pi]) for pi in range(len(g.pattern))]
            return (xc, cache_g), None

        if cfg.remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        (x, cs), _ = jax.lax.scan(
            body, (x, cache_g0), (gfz, gtr, jnp.arange(g.repeats)),
            length=g.repeats)
        caches.append(cs)
    x = L.rmsnorm_apply(train["final_norm"], x)
    last = x[:, -1]
    logits = linear_apply(frozen.get("head", {}), train.get("head", {}),
                          last, cfg.lora.scale).astype(jnp.float32)
    return logits, caches, jnp.asarray(s, jnp.int32)


def _prefill_layer(cfg, spec, fz, tr, shared_fz, x, positions, prefix_len,
                   constrain, max_seq=None):
    """Like _apply_layer but also materializes this layer's cache."""
    sc = cfg.lora.scale
    b, s, _ = x.shape
    h = L.rmsnorm_apply(tr["norm1"], x)
    rope = _rope_for(cfg, spec, positions)
    if spec.mixer in ("gqa", "shared_gqa"):
        afz = shared_fz if spec.mixer == "shared_gqa" else fz["attn"]
        q, k, v = A._qkv(afz, tr["attn"], cfg.gqa, h, sc, rope)
        if spec.window is not None and spec.window < s:
            o = L.local_attention_blocked(q, k, v, window=spec.window)
            w = spec.window
            # ring cache holds the last `w` tokens
            kc = k[:, -w:] if s >= w else jnp.pad(k, ((0, 0), (0, w - s),
                                                      (0, 0), (0, 0)))
            vc = v[:, -w:] if s >= w else jnp.pad(v, ((0, 0), (0, w - s),
                                                      (0, 0), (0, 0)))
            if s >= w:
                # ring alignment: slot of token t is t % w
                shift = s % w
                kc = jnp.roll(kc, shift, axis=1)
                vc = jnp.roll(vc, shift, axis=1)
            cache = {"k": pconstrain(kc.astype(jnp.bfloat16), "cache4"),
                     "v": pconstrain(vc.astype(jnp.bfloat16), "cache4")}
        else:
            o = L.attention_chunked(q, k, v, causal=True,
                                    prefix_len=prefix_len,
                                    kv_chunk=cfg.kv_chunk)
            hw = max(0, (max_seq or s) - s)
            cache = {"k": pconstrain(
                jnp.pad(k, ((0, 0), (0, hw), (0, 0), (0, 0))
                        ).astype(jnp.bfloat16), "cache4"),
                "v": pconstrain(
                jnp.pad(v, ((0, 0), (0, hw), (0, 0), (0, 0))
                        ).astype(jnp.bfloat16), "cache4")}
        hm = A._head_mask(cfg.gqa, o.dtype)
        if hm is not None:
            o = o * hm
        o = o.reshape(b, s, cfg.gqa.hq * cfg.head_dim)
        h = linear_apply(afz.get("wo", {}), tr["attn"].get("wo", {}), o, sc)
    elif spec.mixer == "mla":
        h2 = h
        ckv, kr = A._mla_latent(fz["attn"], tr["attn"], cfg.mla, h2, sc,
                                rope)
        h = A.mla_apply(fz["attn"], tr["attn"], cfg.mla, h2, sc, rope,
                        kv_chunk=cfg.kv_chunk)
        hw = max(0, (max_seq or h2.shape[1]) - h2.shape[1])
        cache = {"ckv": pconstrain(
            jnp.pad(ckv, ((0, 0), (0, hw), (0, 0))).astype(jnp.bfloat16),
            "cache3"),
            "kr": pconstrain(
            jnp.pad(kr, ((0, 0), (0, hw), (0, 0))).astype(jnp.bfloat16),
            "cache3")}
    elif spec.mixer == "mamba":
        # prefill for SSM: run the train path, then recompute the final
        # state via a short decode tail is avoided — instead we run the
        # chunked SSD and extract the final state by one extra chunk scan.
        h, cache = _mamba_prefill(fz["mix"], tr["mix"], cfg.mamba, h, sc)
    x = constrain(x + h)
    if spec.ffn != "none":
        h = L.rmsnorm_apply(tr["norm2"], x)
        if spec.ffn == "dense":
            h = L.mlp_apply(fz.get("mlp", {}), tr.get("mlp", {}),
                            L.MLPSpec(cfg.mlp_kind, cfg.d_model, cfg.d_ff),
                            h, sc)
        else:
            h, _ = MOE.moe_apply(fz.get("moe", {}), tr.get("moe", {}),
                                 cfg.moe, h, sc)
        x = constrain(x + h)
    return x, cache


def _mamba_prefill(fz, tr, spec, x, sc):
    """SSD forward + final-state extraction for the decode cache."""
    y = SSM.mamba_apply(fz, tr, spec, x, sc)
    b, s, _ = x.shape
    # final conv state: last K-1 pre-conv features; final ssm state:
    # recompute cheaply from the last chunk (exact because chunk states
    # compose; we rerun the last chunk's recurrence only).
    # For simplicity and exactness we recompute states over the full
    # sequence in chunch-scan form (same cost class as the forward).
    cache = _mamba_final_state(fz, tr, spec, x, sc)
    return y, cache


def _mamba_final_state(fz, tr, spec, x, sc):
    bsz, s, _ = x.shape
    h, p, n, g = spec.n_heads, spec.head_dim, spec.d_state, spec.n_groups
    xs = SSM._proj(fz, tr, "wx", x, sc)
    bmat = SSM._proj(fz, tr, "wb", x, sc)
    cmat = SSM._proj(fz, tr, "wc", x, sc)
    dt = SSM._proj(fz, tr, "wdt", x, sc).astype(jnp.float32)
    xbc = jnp.concatenate([xs, bmat, cmat], axis=-1)
    conv_state = xbc[:, -(spec.conv_kernel - 1):].astype(jnp.bfloat16)
    xbc2, _ = SSM._causal_depthwise_conv(xbc, tr["conv"]["w"],
                                         tr["conv"]["b"])
    xs = xbc2[..., : spec.d_inner]
    bmat = xbc2[..., spec.d_inner: spec.d_inner + g * n]
    dt = jax.nn.softplus(dt + tr["dt_bias"])
    a = -jnp.exp(tr["A_log"].astype(jnp.float32))
    lc = min(spec.chunk, s)
    nc = s // lc
    xh = xs.reshape(bsz, nc, lc, h, p)
    bh = bmat.reshape(bsz, nc, lc, g, n)
    dth = dt.reshape(bsz, nc, lc, h)
    da = dth * a
    cum = jnp.cumsum(da, axis=2)
    last = cum[:, :, -1:, :]
    wdecay = jnp.exp(last - cum) * dth
    states = jnp.einsum("bclgn,bclh,bclhp->bchpn", bh.astype(jnp.float32),
                        wdecay, xh.astype(jnp.float32))
    chunk_decay = jnp.exp(last[:, :, 0])

    def scan_fn(hprev, inp):
        st, cd = inp
        return hprev * cd[..., None, None] + st, None

    h0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    hfinal, _ = jax.lax.scan(scan_fn, h0,
                             (states.transpose(1, 0, 2, 3, 4),
                              chunk_decay.transpose(1, 0, 2)))
    return {"ssm": hfinal, "conv": conv_state}


def logical(cfg: LMConfig) -> dict:
    """Logical-axis annotation trees parallel to init()'s frozen/train —
    pure python (no arrays), usable with jax.eval_shape outputs."""
    groups = resolve_groups(cfg)
    lf: dict = {"embed": {"w": ("vocab", "fsdp")}}
    lt: dict = {"final_norm": {"scale": (None,)}}
    hlf, hlt = linear_logical("fsdp", "vocab", cfg.head_mode)
    if hlf:
        lf["head"] = hlf
    if hlt:
        lt["head"] = hlt
    if any(s.mixer.startswith("shared") for g in groups for s in g.pattern):
        flog, _ = A.gqa_logical(cfg.gqa, "lora", stack=False)
        lf["shared_attn"] = flog
    lf["groups"] = []
    lt["groups"] = []
    for g in groups:
        glf, glt = [], []
        for spec in g.pattern:
            flog, tlog = _layer_logical(cfg, spec, stack=True)
            glf.append(flog)
            glt.append(tlog)
        lf["groups"].append(glf)
        lt["groups"].append(glt)
    return {"frozen": lf, "train": lt}
