"""Heterogeneous-rank federation: rank resize utilities, the rank-tagged
wire header, rank-bucketed aggregation (zero-pad FedAvg on the fused
kernel per bucket + FLoRIST-style SVD recombination), and the
rank-bucketed FL engine end-to-end on a mixed r in {4, 8, 16, 32}
cohort."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregation, flocora, lora, messages
from repro.core.aggregation import ErrorFeedbackFedAvg, FedAvgAggregator, \
    SVDRecombinationAggregator
from repro.core.flocora import FLoCoRAConfig, RankSchedule
from repro.core.lora import LoRAConfig, linear_apply, linear_init
from repro.core.quant import QuantConfig
from repro.fl import ClientConfig, FLServer, ServerConfig
from repro.fl.client import pad_cohort_batches, pow2_pad

TIERS = (4, 8, 16, 32)


def _dense_pair(seed, rank, d_in=16, d_out=12):
    k = jax.random.PRNGKey(seed)
    ad = lora.dense_lora_init(k, d_in, d_out,
                              LoRAConfig(rank=rank, alpha=16.0 * rank))
    return {"a": ad["a"],
            "b": jax.random.normal(jax.random.fold_in(k, 1),
                                   ad["b"].shape) * 0.1}


def _conv_pair(seed, rank, cin=5, cout=7):
    k = jax.random.PRNGKey(seed)
    ad = lora.conv_lora_init(k, 3, 3, cin, cout,
                             LoRAConfig(rank=rank, alpha=16.0 * rank))
    return {"b": ad["b"],
            "a": jax.random.normal(jax.random.fold_in(k, 1),
                                   ad["a"].shape) * 0.1}


def _client_tree(seed, rank):
    return {"lin": _dense_pair(seed, rank),
            "conv": _conv_pair(seed + 100, rank),
            "norm": jax.random.normal(jax.random.PRNGKey(seed + 200), (5,))}


# ---------------------------------------------------------------------------
# resize utilities
# ---------------------------------------------------------------------------

def test_pad_preserves_product_dense_and_conv():
    d = _dense_pair(0, 8)
    p = lora.pad_adapter(d, 32)
    assert lora.adapter_rank(p) == 32
    np.testing.assert_allclose(np.asarray(p["a"] @ p["b"]),
                               np.asarray(d["a"] @ d["b"]), atol=1e-6)
    c = _conv_pair(0, 8)
    pc = lora.pad_adapter(c, 32)
    ref = jnp.einsum("hwir,xyro->hwio", c["b"], c["a"])
    got = jnp.einsum("hwir,xyro->hwio", pc["b"], pc["a"])
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-6)


def test_slice_inverts_pad():
    d = _dense_pair(1, 8)
    back = lora.slice_adapter(lora.pad_adapter(d, 16), 8)
    np.testing.assert_array_equal(np.asarray(back["a"]),
                                  np.asarray(d["a"]))
    np.testing.assert_array_equal(np.asarray(back["b"]),
                                  np.asarray(d["b"]))


def test_truncate_adapter_is_best_rank_r_approx():
    d = _dense_pair(2, 16)
    a_t, b_t = lora.truncate_adapter(d["a"], d["b"], 4)
    assert a_t.shape == (16, 4) and b_t.shape == (4, 12)
    u, s, vh = np.linalg.svd(np.asarray(d["a"] @ d["b"]),
                             full_matrices=False)
    best = (u[:, :4] * s[:4]) @ vh[:4]
    np.testing.assert_allclose(np.asarray(a_t @ b_t), best, atol=1e-5)


def test_truncate_beyond_intrinsic_rank_pads_zero():
    """r_target above min(d_in, d_out): extra components are zero and
    the product is reproduced exactly."""
    d = _dense_pair(3, 32)                     # product rank <= 12
    a_t, b_t = lora.truncate_adapter(d["a"], d["b"], 16)
    assert a_t.shape == (16, 16) and b_t.shape == (16, 12)
    np.testing.assert_allclose(np.asarray(a_t @ b_t),
                               np.asarray(d["a"] @ d["b"]), atol=1e-4)


def test_resize_tree_walks_pairs_only():
    t = _client_tree(0, 8)
    up = lora.resize_tree_rank(t, 32)
    assert lora.tree_ranks(up) == (32,)
    np.testing.assert_array_equal(np.asarray(up["norm"]),
                                  np.asarray(t["norm"]))
    down = lora.resize_tree_rank(up, 8)
    np.testing.assert_allclose(np.asarray(down["lin"]["a"]),
                               np.asarray(t["lin"]["a"]), atol=1e-6)


def test_svd_energy_rank_ignores_zero_stack_slices():
    """A fresh (all-zero delta) layer inside a stacked adapter must not
    force the served rank to full through the batch max."""
    sv = jnp.asarray([[10.0, 1.0, 0.01], [0.0, 0.0, 0.0]])
    assert lora.svd_energy_rank(sv, 0.995) == 2
    assert lora.svd_energy_rank(jnp.zeros((2, 3)), 0.99) == 1


def test_resize_zero_product_slice_keeps_gradient_path():
    """Fresh adapters (b = 0) must NOT truncate to all-zero factors —
    an SVD of the zero product would; slicing keeps a's columns."""
    k = jax.random.PRNGKey(0)
    fresh = lora.dense_lora_init(k, 16, 12, LoRAConfig(rank=32, alpha=512.0))
    cut = lora.resize_adapter(fresh, 4, method="slice")
    assert float(jnp.max(jnp.abs(cut["a"]))) > 0.0


# ---------------------------------------------------------------------------
# rank schedule + wire header
# ---------------------------------------------------------------------------

def test_rank_schedule_tiered_and_annealing():
    s = RankSchedule.tiered(TIERS, 10)
    assert s.client_ranks[:5] == (4, 8, 16, 32, 4)
    assert s.max_rank == 32
    sa = RankSchedule.tiered((8, 32), 4, anneal_every=3,
                             anneal_factor=0.5, min_rank=2)
    assert sa.ranks_at(0) == (8, 32, 8, 32)
    assert sa.ranks_at(3) == (4, 16, 4, 16)
    assert sa.ranks_at(30) == (2, 2, 2, 2)     # floored at min_rank
    # the floor only binds annealed shrinkage, not configured base ranks
    assert RankSchedule.uniform(1, 2).rank_for(0) == 1
    with pytest.raises(ValueError):
        RankSchedule(client_ranks=())
    with pytest.raises(ValueError):
        RankSchedule(client_ranks=(4, 0))
    with pytest.raises(ValueError):             # rank-0 floor under anneal
        RankSchedule(client_ranks=(4, 8), anneal_every=1, min_rank=0)
    with pytest.raises(ValueError):             # schedule above server rank
        FLoCoRAConfig(rank=8, rank_schedule=RankSchedule.uniform(16, 4))


def test_wire_header_carries_rank():
    t = _client_tree(0, 16)
    msg = messages.pack_message(t, QuantConfig(bits=4))
    wire = messages.message_to_wire(msg)
    name, bufs = wire[0]
    assert name == messages.HEADER_KEY
    assert bufs["header"].nbytes == messages.HEADER_BYTES
    hdr = messages.parse_wire_header(bufs["header"])
    assert hdr["rank"] == 16 and hdr["bits"] == 4
    # fp message: rank still tagged, bits is None
    hdr_fp = messages.parse_wire_header(
        messages.message_to_wire(t)[0][1]["header"])
    assert hdr_fp["rank"] == 16 and hdr_fp["bits"] is None
    with pytest.raises(ValueError):
        messages.parse_wire_header(np.zeros(4, np.uint32))
    # the header is framing: payload accounting is unchanged
    assert messages.packed_wire_bytes(msg) == \
        messages.message_wire_bytes(t, QuantConfig(bits=4))


def test_client_wire_bytes_scales_with_rank():
    g = _client_tree(0, 32)
    cfg = FLoCoRAConfig(rank=32, alpha=512.0, quant_bits=8)
    sizes = [flocora.client_wire_bytes(g, cfg, r) for r in TIERS]
    assert sizes == sorted(sizes) and sizes[0] < sizes[-1]
    sched = RankSchedule.tiered(TIERS, 8)
    hcfg = FLoCoRAConfig(rank=32, alpha=512.0, quant_bits=8,
                         rank_schedule=sched)
    fleet = flocora.fleet_tcc_bytes(g, hcfg, 3)
    per = [flocora.client_wire_bytes(g, hcfg, r)
           for r in sched.client_ranks]
    assert fleet == 2 * 3 * sum(per)


# ---------------------------------------------------------------------------
# rank-bucketed aggregation
# ---------------------------------------------------------------------------

def _mixed_cohort(ranks=(4, 8, 8, 16, 32)):
    trees = [_client_tree(i, r) for i, r in enumerate(ranks)]
    w = jnp.asarray([1.0, 2.0, 3.0, 1.5, 0.5][: len(ranks)])
    return trees, w


def test_bucket_by_rank():
    trees, _ = _mixed_cohort()
    assert aggregation.bucket_by_rank(trees) == {4: [0], 8: [1, 2],
                                                 16: [3], 32: [4]}


def test_hetero_fedavg_fp_equals_zero_pad_reference():
    trees, w = _mixed_cohort()
    got = FedAvgAggregator(QuantConfig(), r_target=32).aggregate(trees, w)
    padded = [lora.resize_tree_rank(t, 32) for t in trees]
    ref = aggregation.fedavg(aggregation.stack_trees(padded), w)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("bits", [8, 4])
def test_hetero_fedavg_packed_equals_fp_reference(bits):
    """ACCEPTANCE: per-bucket packed aggregation (fused dequant_agg
    kernel per rank bucket) is numerically equal to the fp reference
    (dequantized zero-padded weighted mean)."""
    trees, w = _mixed_cohort()
    qcfg = QuantConfig(bits=bits)
    msgs = [messages.pack_message(t, qcfg) for t in trees]
    got = FedAvgAggregator(qcfg, r_target=32).aggregate(msgs, w)
    rts = [lora.resize_tree_rank(messages.unpack_message(m), 32)
           for m in msgs]
    ref = aggregation.fedavg(aggregation.stack_trees(rts), w)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_svd_recombination_served_rank_and_reconstruction():
    """ACCEPTANCE: served rank <= max client rank; the served factors
    reconstruct the aggregated delta within the energy tolerance."""
    trees, w = _mixed_cohort()
    qcfg = QuantConfig(bits=8)
    msgs = [messages.pack_message(t, qcfg) for t in trees]
    agg = SVDRecombinationAggregator(qcfg, r_target=32, energy=0.999)
    got = agg.aggregate(msgs, w)
    assert set(agg.served_ranks) == {"lin", "conv"}
    assert all(1 <= r <= 32 for r in agg.served_ranks.values())
    # global tree shape pinned at r_target
    assert lora.tree_ranks(got) == (32,)
    # reconstruction: served product ~= weighted mean of client products
    wn = np.asarray(w / jnp.sum(w))
    rts = [messages.unpack_message(m) for m in msgs]
    ref = sum(wk * np.asarray(t["lin"]["a"].astype(jnp.float32)
                              @ t["lin"]["b"].astype(jnp.float32))
              for wk, t in zip(wn, rts))
    got_d = np.asarray(got["lin"]["a"] @ got["lin"]["b"])
    err = np.abs(got_d - ref).max()
    assert err <= max(1e-5, 0.05 * np.abs(ref).max()), err
    # non-adapter leaves match the plain weighted mean
    ref_norm = sum(wk * np.asarray(t["norm"]) for wk, t in zip(wn, rts))
    np.testing.assert_allclose(np.asarray(got["norm"]), ref_norm,
                               rtol=1e-5, atol=1e-6)


def test_uniform_cohort_keeps_fast_path():
    """A uniform-rank cohort must reproduce the classic (non-bucketed)
    packed FedAvg bit-for-bit."""
    trees = [_client_tree(i, 8) for i in range(3)]
    w = jnp.asarray([1.0, 2.0, 1.0])
    qcfg = QuantConfig(bits=8)
    msgs = [messages.pack_message(t, qcfg) for t in trees]
    got = FedAvgAggregator(qcfg, r_target=8).aggregate(msgs, w)
    ref = aggregation.fedavg_packed(msgs, w)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_ef_residual_reinit_on_rank_change():
    agg = ErrorFeedbackFedAvg(QuantConfig(bits=8), r_target=16)
    t8 = _client_tree(0, 8)
    agg.store_residual(3, jax.tree.map(
        lambda x: jnp.ones_like(x, jnp.float32), t8))
    # same shapes -> stored residual comes back
    got = agg.residual(3, t8)
    assert float(jnp.max(jax.tree.leaves(got)[0])) == 1.0
    # rank annealed 8 -> 4: stale residual must restart at zero
    t4 = lora.resize_tree_rank(t8, 4)
    got4 = agg.residual(3, t4)
    assert all(float(jnp.max(jnp.abs(l))) == 0.0
               for l in jax.tree.leaves(got4))


# ---------------------------------------------------------------------------
# rank-bucketed FL engine end-to-end
# ---------------------------------------------------------------------------

SCALE = 1.0


def _lora_model(seed=0, rank=32):
    k = jax.random.PRNGKey(seed)
    fz, tr = linear_init(k, 16, 10, "lora",
                         LoRAConfig(rank=rank, alpha=float(rank)),
                         base_dtype=jnp.float32)
    return {"frozen": {"lin": fz},
            "train": {"lin": tr, "bias": jnp.zeros((10,))}}


def _lora_loss(frozen, train, batch):
    logits = linear_apply(frozen["lin"], train["lin"], batch["x"], SCALE,
                          jnp.float32) + train["bias"]
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, batch["y"][:, None],
                                         axis=1)), {}


def _lin_data(n=240, n_clients=10, seed=0):
    rng = np.random.default_rng(seed)
    w_true = rng.normal(size=(16, 10)).astype(np.float32)
    x = rng.normal(size=(n, 16)).astype(np.float32)
    y = np.argmax(x @ w_true + 0.1 * rng.normal(size=(n, 10)),
                  axis=1).astype(np.int32)
    parts = np.array_split(rng.permutation(n), n_clients)
    return [{"x": x[p], "y": y[p]} for p in parts]


def _hetero_server(data, sched, rank=32, **kw):
    fcfg = FLoCoRAConfig(rank=rank, alpha=float(rank), quant_bits=8,
                         rank_schedule=sched, **kw)
    return FLServer(_lora_model(rank=rank), _lora_loss, data,
                    ServerConfig(rounds=3, n_clients=len(data),
                                 clients_per_round=6),
                    ClientConfig(local_epochs=2, batch_size=8, lr=0.1),
                    fcfg)


def test_mixed_rank_cohort_trains_end_to_end():
    """ACCEPTANCE: a mixed r in {4, 8, 16, 32} cohort trains end-to-end
    through the packed wire path; tcc_bytes equals the running sum of
    measured per-client packed message sizes."""
    data = _lin_data()
    srv = _hetero_server(data, RankSchedule.tiered(TIERS, 10))
    hist = srv.run(3)
    assert any(len(h["cohort_ranks"]) > 1 for h in hist)
    assert hist[-1]["client_loss"] < hist[0]["client_loss"]
    # the global tree stays at the server rank
    assert lora.tree_ranks(srv.global_train) == (32,)
    # measured per-rank uplink sizes match an independently-built packed
    # message of that rank
    for r, got in hist[-1]["up_bytes_by_rank"].items():
        g_r = lora.resize_tree_rank(jax.device_get(srv.global_train), r)
        expect = messages.packed_wire_bytes(
            messages.pack_message(g_r, srv.fcfg.qcfg))
        assert got == expect, (r, got, expect)
    # TCC is the running sum of measured round bytes + initial model
    assert hist[-1]["tcc_bytes"] == srv.initial_model_bytes + \
        sum(h["round_bytes"] for h in hist)


def test_full_cohort_tcc_equals_per_client_measured_sum():
    """With every client dispatched, one round's down/up bytes are the
    sums over the schedule's per-client measured message sizes."""
    data = _lin_data()
    sched = RankSchedule.tiered(TIERS, 10)
    srv = _hetero_server(data, sched)
    srv.scfg = ServerConfig(rounds=1, n_clients=10, clients_per_round=10)
    rec = srv.run_round()
    per_client = [
        messages.packed_wire_bytes(flocora.server_downlink(
            srv.global_train, srv.fcfg, rank=r))
        for r in sched.client_ranks]
    assert rec["down_bytes"] == sum(per_client)
    assert rec["up_bytes"] == sum(per_client)


def test_svd_recombination_server_end_to_end():
    data = _lin_data()
    sched = RankSchedule.tiered(TIERS, 10)
    fcfg = FLoCoRAConfig(rank=32, alpha=32.0, quant_bits=8,
                         rank_schedule=sched)
    srv = FLServer(_lora_model(rank=32), _lora_loss, data,
                   ServerConfig(rounds=3, n_clients=10,
                                clients_per_round=6),
                   ClientConfig(local_epochs=2, batch_size=8, lr=0.1),
                   fcfg,
                   aggregator=SVDRecombinationAggregator(
                       QuantConfig(bits=8), energy=0.99))
    hist = srv.run(3)
    assert srv.aggregator.served_ranks
    assert all(1 <= r <= 32 for r in srv.aggregator.served_ranks.values())
    assert hist[-1]["client_loss"] < hist[0]["client_loss"]


def test_rank_annealing_shrinks_wire():
    data = _lin_data()
    sched = RankSchedule.tiered((16, 32), 10, anneal_every=2,
                                anneal_factor=0.5, min_rank=4)
    srv = _hetero_server(data, sched)
    hist = srv.run(4)
    first = max(max(h["cohort_ranks"]) for h in hist[:2])
    last = max(max(h["cohort_ranks"]) for h in hist[-2:])
    assert last < first
    assert hist[-1]["round_bytes"] < hist[0]["round_bytes"]
    assert np.isfinite(hist[-1]["client_loss"])


def test_all_dropout_round_recorded():
    """SATELLITE: an all-dropout round appends a history record with
    n_agg=0 and correct (downlink-only) TCC — no gaps."""
    data = _lin_data()
    srv = _hetero_server(data, RankSchedule.tiered(TIERS, 10))
    srv.scfg = ServerConfig(rounds=2, n_clients=10, clients_per_round=4,
                            p_client_failure=1.0)
    hist = srv.run(2)
    assert len(srv.history) == 2
    assert all(h["n_agg"] == 0 and h["up_bytes"] == 0 for h in hist)
    assert all(h["down_bytes"] > 0 for h in hist)
    # schema matches normal records: loss is NaN (no data), ranks empty
    assert all(np.isnan(h["client_loss"]) and h["cohort_ranks"] == {}
               for h in hist)
    assert hist[1]["tcc_bytes"] == srv.initial_model_bytes + \
        hist[0]["round_bytes"] + hist[1]["round_bytes"]


def test_mixed_schedule_aggregator_validation():
    """FedBuff now HAS a rank-bucketed path, so a mixed-rank schedule is
    accepted (with the config half_life threaded in); an aggregator
    without one is still rejected at construction, not with a shape
    error mid-round."""
    from repro.core.aggregation import FedBuffAggregator, fedavg, \
        stack_trees
    data = _lin_data()
    fcfg = FLoCoRAConfig(rank=32, alpha=32.0, quant_bits=8,
                         rank_schedule=RankSchedule.tiered(TIERS, 10))
    srv = FLServer(_lora_model(rank=32), _lora_loss, data,
                   ServerConfig(rounds=1, n_clients=10,
                                clients_per_round=4),
                   ClientConfig(), fcfg, aggregator=FedBuffAggregator())
    assert srv.aggregator.r_target == 32
    assert srv.aggregator.half_life == srv.scfg.fedbuff_half_life

    class PlainMean:                  # no rank-bucketed path
        def aggregate(self, msgs, weights):
            return fedavg(stack_trees(msgs), jnp.asarray(weights))

    with pytest.raises(ValueError, match="rank-bucketed"):
        FLServer(_lora_model(rank=32), _lora_loss, data,
                 ServerConfig(rounds=1, n_clients=10,
                              clients_per_round=4),
                 ClientConfig(), fcfg, aggregator=PlainMean())
    # explicit r_target below the schedule max would let the global
    # tree's rank float round-to-round — also rejected at init
    with pytest.raises(ValueError):
        FLServer(_lora_model(rank=32), _lora_loss, data,
                 ServerConfig(rounds=1, n_clients=10,
                              clients_per_round=4),
                 ClientConfig(), fcfg,
                 aggregator=FedAvgAggregator(QuantConfig(bits=8),
                                             r_target=4))


def test_server_copy_does_not_alias_caller_aggregator():
    """Pinning r_target must not mutate (or alias mutable state of) a
    caller-provided aggregator instance."""
    data = _lin_data()
    caller = ErrorFeedbackFedAvg(QuantConfig(bits=8))
    assert caller.r_target is None
    fcfg = FLoCoRAConfig(rank=8, alpha=8.0, quant_bits=8,
                         error_feedback=True)
    srv = FLServer(_lora_model(rank=8), _lora_loss, data,
                   ServerConfig(rounds=1, n_clients=10,
                                clients_per_round=3),
                   ClientConfig(local_epochs=1, batch_size=8, lr=0.1),
                   fcfg, aggregator=caller)
    srv.run(1)
    assert caller.r_target is None          # caller untouched
    assert srv.aggregator.residuals and not caller.residuals


def test_pow2_padding_helpers():
    assert [pow2_pad(k) for k in (1, 2, 3, 5, 8, 9)] == [1, 2, 4, 8, 8, 16]
    batches = {"x": np.ones((3, 4, 2)), "y": np.zeros((3, 4), np.int32)}
    n_steps = np.asarray([4, 2, 4], np.int32)
    pb, pn = pad_cohort_batches(batches, n_steps, 4)
    assert pb["x"].shape == (4, 4, 2) and pn.tolist() == [4, 2, 4, 0]
    np.testing.assert_array_equal(pb["x"][3], pb["x"][0])
    # no-op when already big enough
    pb2, pn2 = pad_cohort_batches(batches, n_steps, 2)
    assert pb2 is batches and pn2 is n_steps


def test_resume_restores_cumulative_tcc(tmp_path):
    """Measured TCC must survive checkpoint/resume: a restarted server's
    history continues the byte counter instead of restarting it."""
    data = _lin_data()
    sched = RankSchedule.tiered(TIERS, 10)
    fcfg = FLoCoRAConfig(rank=32, alpha=32.0, quant_bits=8,
                         rank_schedule=sched)
    scfg = ServerConfig(rounds=2, n_clients=10, clients_per_round=6,
                        checkpoint_dir=str(tmp_path), checkpoint_every=1)
    ccfg = ClientConfig(local_epochs=1, batch_size=8, lr=0.1)
    srv = FLServer(_lora_model(rank=32), _lora_loss, data, scfg, ccfg,
                   fcfg)
    hist = srv.run(2)
    srv2 = FLServer(_lora_model(rank=32), _lora_loss, data, scfg, ccfg,
                    fcfg)
    assert srv2.try_resume()
    rec = srv2.run_round()
    assert rec["tcc_bytes"] == hist[-1]["tcc_bytes"] + rec["round_bytes"]


def test_uniform_server_unchanged_by_refactor():
    """No rank_schedule: the classic single-program cohort engine and
    per-round accounting still hold (regression guard)."""
    data = _lin_data()
    fcfg = FLoCoRAConfig(rank=8, alpha=8.0, quant_bits=8)
    srv = FLServer(_lora_model(rank=8), _lora_loss, data,
                   ServerConfig(rounds=2, n_clients=10,
                                clients_per_round=4),
                   ClientConfig(local_epochs=1, batch_size=8, lr=0.1),
                   fcfg)
    hist = srv.run(2)
    one_way = messages.message_wire_bytes(srv.global_train, fcfg.qcfg)
    assert srv.round_bytes_per_client == 2 * one_way
    assert all(h["cohort_ranks"] == {8: 4} for h in hist)
    assert all(h["round_bytes"] == 4 * 2 * one_way for h in hist)
