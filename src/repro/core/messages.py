"""FLoCoRA message codec: trainable tree <-> quantized wire message.

Quantization rules (paper §IV, validated byte-exact against Tables III/IV):
  * tensors with ndim >= 2 are quantized per *output channel* = last axis
    (conv "per channel", FC "per column" in the paper's storage order);
  * tensors with a leading layer-stack dim (ndim >= 3) get per-(layer,
    channel) qparams via vmap — strictly better accuracy, same wire format;
  * 1-D tensors (norm scales/biases, SSM vectors) are never quantized and
    travel in fp32 — the paper's "normalization layers are not quantized";
  * scale and zero-point travel as fp32 sidecars (2 * 4 bytes / channel).

Two codecs share the quantization math:

  * ``encode``/``decode``: the fp-simulation view (unpacked uint8 levels)
    used as the numerical reference oracle;
  * ``pack_message``/``unpack_message``: the WIRE-TRUE view — each
    quantized leaf becomes a :class:`PackedLeaf` holding uint32-word
    payloads (the Pallas ``quant_pack`` layout) + fp32 sidecars, and
    serializes to exactly ``message_wire_bytes`` bytes via ``to_wire``.
    With ``flat=True`` the whole message instead packs as ONE
    :class:`~repro.core.flat.FlatPackedMessage` buffer in a single
    fused kernel launch (``core/flat.py``) — byte-identical wire form,
    O(1) dispatches; the engines route their dense quantized exchanges
    through it via ``FLoCoRAConfig.flat_wire`` (default on).

Sparse uplinks (wire v3, FLASC-style — see ``core/sparse.py``): with a
``density < 1`` the quantizable leaves become :class:`SparseLeaf`
instead — per-tensor magnitude top-k indices + the survivors run through
the SAME affine quantizer — and every accounting/serialization helper
here handles both leaf kinds. ``density=None`` (or 1.0) is the exact
dense path, byte-for-byte.

``wire_bytes`` is the static accounting used by the TCC benchmarks; the
packed codec is validated against it buffer-for-buffer (tier-1 tests).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import flat as flatcodec
from repro.core import quant, sparse
from repro.core.flat import FlatPackedMessage, is_flat_message
from repro.core.quant import QuantConfig
from repro.core.sparse import SparseLeaf, is_sparse_leaf
from repro.kernels import ops as kops
from repro.kernels import ref as kref

Array = jax.Array

CHANNEL_AXIS = -1   # output channel == last axis in this codebase's layouts


@dataclasses.dataclass
class EncodedLeaf:
    q: Array              # uint8 levels (unpacked; packing is wire-only)
    scale: Array
    zp: Array
    dtype: Any            # original dtype


def _encode_leaf(x: Array, bits: int, per_stack: bool):
    def enc2d(t):
        s, z = quant.affine_qparams(t, bits, channel_axis=t.ndim - 1)
        q = quant.quantize(t, s, z, bits, channel_axis=t.ndim - 1)
        return q, s, z

    if per_stack and x.ndim >= 3:
        # per-(stack, channel) qparams (stacked LM layer tensors)
        q, s, z = jax.vmap(enc2d)(x)
    else:
        q, s, z = enc2d(x)
    return {"q": q, "scale": s, "zp": z}


def _decode_leaf(enc: dict, ndim: int, dtype, per_stack: bool) -> Array:
    def dec2d(q, s, z):
        return quant.dequantize(q, s, z, channel_axis=q.ndim - 1, dtype=dtype)

    if per_stack and ndim >= 3:
        return jax.vmap(dec2d)(enc["q"], enc["scale"], enc["zp"])
    return dec2d(enc["q"], enc["scale"], enc["zp"])


def quantizable(x) -> bool:
    """Paper rule: >=2-D tensors are quantized; vectors stay fp."""
    return x.ndim >= 2


def encode(tree: Any, cfg: QuantConfig) -> Any:
    """Trainable tree -> message tree. Unquantized leaves pass through."""
    if not cfg.enabled:
        return tree

    def enc(x):
        if not quantizable(x):
            return x
        return _encode_leaf(x, cfg.bits, cfg.per_stack)

    return jax.tree.map(enc, tree)


def decode(msg: Any, cfg: QuantConfig, like: Any) -> Any:
    """Message tree -> fp tree with the dtypes/structure of `like`."""
    if not cfg.enabled:
        return msg

    def dec(ref, m):
        if not quantizable(ref):
            return m
        return _decode_leaf(m, ref.ndim, ref.dtype, cfg.per_stack)

    return jax.tree.map(dec, like, msg,
                        is_leaf=lambda t: isinstance(t, dict) and "q" in t)


def roundtrip(tree: Any, cfg: QuantConfig) -> Any:
    """Quantize+dequantize: what the receiver reconstructs."""
    if not cfg.enabled:
        return tree
    return decode(encode(tree, cfg), cfg, tree)


# ---------------------------------------------------------------------------
# Wire-byte accounting (static; shapes only)
# ---------------------------------------------------------------------------

def leaf_wire_bytes(shape: tuple[int, ...], bits: Optional[int],
                    per_stack: bool = False) -> int:
    n = int(np.prod(shape))
    if bits is None or len(shape) < 2:
        return n * quant.FP_BYTES
    if per_stack and len(shape) >= 3:
        channels = int(np.prod(shape[:-2])) * shape[-1]
    else:
        channels = shape[-1]          # paper rule: channel = last axis
    payload = (n * bits + 7) // 8
    return payload + channels * 2 * quant.FP_BYTES


def message_wire_bytes(tree: Any, cfg: QuantConfig,
                       density: Optional[float] = None) -> int:
    """Bytes for one direction of one round (paper's message size).

    ``density < 1`` switches the quantizable (>= 2-D) leaves to the
    sparse accounting (``sparse.sparse_leaf_wire_bytes``); 1-D leaves
    always travel dense fp32, mirroring ``pack_message``."""
    bits = cfg.bits if cfg.enabled else None
    sparse_on = density is not None and density < 1.0
    total = 0
    for x in jax.tree.leaves(tree):
        if sparse_on and quantizable(x):
            total += sparse.sparse_leaf_wire_bytes(tuple(x.shape), bits,
                                                   density)
        else:
            total += leaf_wire_bytes(tuple(x.shape), bits, cfg.per_stack)
    return total


def tcc_bytes(tree: Any, cfg: QuantConfig, rounds: int) -> int:
    """Paper Eq. 2 generalized: 2 * R * message_bytes.

    This is the CANONICAL total-communication-cost helper; the scalar
    variant in ``repro.core.quant`` is a deprecated shim over the same
    formula."""
    return 2 * rounds * message_wire_bytes(tree, cfg)


# ---------------------------------------------------------------------------
# Packed wire codec (real uint32 payloads, not fp simulation)
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PackedLeaf:
    """One quantized tensor in wire form.

    ``payload`` uses the Pallas kernel layout: one row of little-endian
    uint32 words per channel, columns padded to the kernel lane multiple
    (32/bits * 128 levels). The valid levels are the first
    ``n_per_channel`` of each row; ``to_wire`` strips the padding so the
    serialized payload is exactly ``ceil(numel * bits / 8)`` bytes.
    """
    payload: Array        # (channels, Nw) uint32 words
    scale: Array          # (channels,) fp32 sidecar
    zp: Array             # (channels,) fp32 sidecar
    shape: tuple          # static: original tensor shape
    dtype: Any            # static: original dtype
    bits: int             # static
    per_stack: bool = False   # static: per-(stack, channel) qparams

    def tree_flatten(self):
        return ((self.payload, self.scale, self.zp),
                (self.shape, self.dtype, self.bits, self.per_stack))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    @property
    def channels(self) -> int:
        if self.per_stack and len(self.shape) >= 3:
            return int(np.prod(self.shape[:-2])) * self.shape[-1]
        return self.shape[-1]

    @property
    def n_per_channel(self) -> int:
        return int(np.prod(self.shape)) // self.channels

    # -- serialization (the actual bytes on the wire) -----------------------
    def to_wire(self) -> dict[str, np.ndarray]:
        """Host-side buffers as sent: exact payload bytes + fp32 sidecars.

        The payload re-packs the valid levels of every channel contiguously
        (no lane/word padding), so ``sum(buf.nbytes) == leaf_wire_bytes``.
        Padding is stripped with vectorized host-side word/bit ops
        (``flat.strip_row_padding``) — no unpack-and-repack round trip
        through the device."""
        words = np.asarray(jax.device_get(self.payload))
        return {"payload": flatcodec.strip_row_padding(
                    words, self.bits, self.n_per_channel),
                "scale": np.asarray(self.scale, np.float32),
                "zp": np.asarray(self.zp, np.float32)}

    @classmethod
    def from_wire(cls, buffers: dict, shape: tuple, dtype, bits: int,
                  per_stack: bool = False) -> "PackedLeaf":
        """Rebuild the kernel-layout leaf from serialized wire buffers."""
        leaf = cls(None, jnp.asarray(buffers["scale"]),
                   jnp.asarray(buffers["zp"]), tuple(shape), dtype, bits,
                   per_stack)
        n = int(np.prod(shape))
        lv = quant.unpack_levels(jnp.asarray(buffers["payload"]), bits, n)
        lv = lv.reshape(leaf.channels, leaf.n_per_channel)
        leaf.payload = _pack_rows(lv, bits)
        return leaf

    def wire_bytes(self) -> int:
        """Real serialized size (measured from the buffers)."""
        bufs = self.to_wire()
        return sum(b.nbytes for b in bufs.values())


_lane = kops.lane_levels      # kernel column alignment (single source)


def _pack_rows(levels: Array, bits: int) -> Array:
    """(C, n) uint8 levels -> (C, Nw) uint32 kernel-layout words."""
    per = 32 // bits
    pad = (-levels.shape[1]) % _lane(bits)
    lv = jnp.pad(levels.astype(jnp.uint32), ((0, 0), (0, pad)))
    return kref.pack_words(lv, bits)


# The channel-first-2D view helpers live next to the kernels they feed:
# ``kops.to_channel_first_2d`` / ``kops.from_channel_first_2d`` are the
# single canonical pair (the old ``messages._to_channel_2d`` twins are
# gone).

def _pack_2d_jnp(x2d: Array, bits: int):
    """Pure-jnp twin of ``kernels.ops.quant_pack``; vmap-safe, used where
    a pallas_call can't be batched (e.g. per-pod packing under vmap).

    Pads columns to WORD granularity only (ceil(n*bits/32) words/channel),
    not the kernel's 128-lane multiple — a collective over this payload
    carries ~exactly the wire bytes. Unpack/aggregate consumers slice to
    ``n_per_channel``, so the two paddings interoperate."""
    scale, zp = kref._qparams_rowwise(x2d.astype(jnp.float32), bits)
    qmax = (1 << bits) - 1
    q = jnp.clip(jnp.round(x2d.astype(jnp.float32) / scale[:, None])
                 + zp[:, None], 0, qmax)
    per = 32 // bits
    qp = jnp.pad(q.astype(jnp.uint32),
                 ((0, 0), (0, (-x2d.shape[1]) % per)))
    return kref.pack_words(qp, bits), scale, zp


def is_packed_leaf(t: Any) -> bool:
    return isinstance(t, PackedLeaf)


def is_wire_leaf(t: Any) -> bool:
    """True for any wire-form leaf (dense packed, sparse top-k, or a
    whole flat-tree message)."""
    return isinstance(t, (PackedLeaf, SparseLeaf, FlatPackedMessage))


def pack_message(tree: Any, cfg: QuantConfig, *,
                 use_kernel: bool = True,
                 density: Optional[float] = None,
                 flat: bool = False) -> Any:
    """Trainable tree -> wire message with real packed payloads.

    Quantizable leaves become :class:`PackedLeaf` (uint32 words + fp32
    sidecars via the fused Pallas ``quant_pack``); 1-D leaves pass through
    in fp32. ``use_kernel=False`` selects the pure-jnp twin (identical
    output; needed under vmap, e.g. the per-pod packing in launch).

    ``flat=True`` selects the FLAT-TREE codec (``core/flat.py``): the
    WHOLE message packs as one :class:`~repro.core.flat.FlatPackedMessage`
    in a single fused kernel launch — byte-identical wire payloads, O(1)
    dispatches/compiles instead of O(#leaves). The per-leaf path stays
    as the oracle. Flat implies the kernel path and applies to the dense
    quantized wire only (the sparse wire is per-tensor by construction).

    ``density < 1`` selects the FLASC-style sparse wire instead: each
    quantizable leaf becomes a :class:`SparseLeaf` (per-tensor top-k
    indices + the survivors through the same quantizer — per-tensor
    qparams, so ``per_stack`` does not apply). ``density`` of None or
    1.0 is the exact dense fallback.
    """
    sparse_on = density is not None and density < 1.0
    if not cfg.enabled and not sparse_on:
        return tree
    if flat and cfg.enabled and not sparse_on:
        return flatcodec.pack_flat(tree, cfg.bits, cfg.per_stack)

    def pk(x):
        if not quantizable(x):
            return x
        if sparse_on:
            return sparse.sparsify_leaf(x, density,
                                        cfg.bits if cfg.enabled else None,
                                        use_kernel=use_kernel)
        x2d = kops.to_channel_first_2d(x, cfg.per_stack)
        if use_kernel:
            payload, scale, zp = kops.quant_pack(x2d, cfg.bits)
        else:
            payload, scale, zp = _pack_2d_jnp(x2d, cfg.bits)
        return PackedLeaf(payload, scale, zp, tuple(x.shape), x.dtype,
                          cfg.bits, cfg.per_stack)

    return jax.tree.map(pk, tree)


def unpack_message(msg: Any) -> Any:
    """Wire message -> fp tree (shape/dtype recorded in each leaf).
    Sparse leaves densify (zeros at the dropped positions); a flat-tree
    message decodes in one fused program."""
    if is_flat_message(msg):
        return msg.unpack()

    def up(t):
        if is_flat_message(t):     # nested flat messages decode too
            return t.unpack()
        if is_sparse_leaf(t):
            return t.densify()
        if not is_packed_leaf(t):
            return t
        lv = kref.unpack_words(t.payload, t.bits)[:, :t.n_per_channel]
        x2d = (lv.astype(jnp.float32) - t.zp[:, None]) * t.scale[:, None]
        return kops.from_channel_first_2d(
            x2d, t.shape, t.per_stack).astype(t.dtype)

    return jax.tree.map(up, msg, is_leaf=is_wire_leaf)


# ---------------------------------------------------------------------------
# Wire header: every serialized message leads with a fixed 20-byte header
# carrying the sender's adapter RANK and the message DENSITY, so a
# heterogeneous-rank server can route a message to the right aggregation
# bucket (and pick the sparse decode path) before deserializing a single
# payload. The header is a fixed transport framing cost and is NOT
# part of ``message_wire_bytes``/``packed_wire_bytes`` — those reproduce
# the paper's payload accounting (Tables III/IV) byte-exactly.
# ---------------------------------------------------------------------------

WIRE_MAGIC = 0x464C4F43          # "FLOC"
WIRE_VERSION = 3                 # v3: + density field (sparse-delta wire)
HEADER_KEY = "__header__"
HEADER_BYTES = 20        # 5 x uint32: magic, version, rank, bits, density
DENSITY_ONE = 1_000_000          # density is carried in parts-per-million


def message_rank(msg: Any) -> int:
    """Max adapter rank of a (fp or packed) message; 0 if it carries no
    LoRA pairs (rank detection is shape-only, so it works on PackedLeaf
    trees without touching a payload)."""
    from repro.core import lora
    r = lora.tree_max_rank(msg)
    return 0 if r is None else int(r)


def message_density(msg: Any) -> float:
    """Density advertised by a wire message: the configured density of
    its sparse leaves, 1.0 for dense (packed or fp) messages."""
    for leaf in jax.tree.leaves(msg, is_leaf=is_wire_leaf):
        if is_sparse_leaf(leaf):
            return float(leaf.density)
    return 1.0


def wire_header(rank: int, bits: Optional[int],
                density: float = 1.0) -> np.ndarray:
    """The leading uint32[5] buffer of a serialized message."""
    return np.asarray([WIRE_MAGIC, WIRE_VERSION, rank, bits or 0,
                       int(round(density * DENSITY_ONE))], np.uint32)


def parse_wire_header(buf: np.ndarray) -> dict:
    """Validate + decode the header ->
    {'rank': int, 'bits': int|None, 'density': float}.

    Accepts the 16-byte v2 form (no density word -> density 1.0), so
    pre-sparse senders interoperate."""
    h = np.asarray(buf, np.uint32).reshape(-1)
    if h.shape[0] not in (4, 5) or int(h[0]) != WIRE_MAGIC:
        raise ValueError("not a FLoCoRA wire message (bad magic)")
    if int(h[1]) > WIRE_VERSION:
        raise ValueError(f"wire version {int(h[1])} is newer than this "
                         f"codec (v{WIRE_VERSION})")
    bits = int(h[3])
    density = int(h[4]) / DENSITY_ONE if h.shape[0] == 5 else 1.0
    return {"version": int(h[1]), "rank": int(h[2]),
            "bits": bits if bits else None, "density": density}


def message_to_wire(msg: Any, include_header: bool = True
                    ) -> list[tuple[str, dict]]:
    """Serialize a packed/sparse message to named host buffers (uplink
    form).

    The first entry is the rank+density-tagged wire header
    (``HEADER_KEY``) unless ``include_header=False``. A flat-tree
    message serializes from ONE device->host transfer to the SAME named
    entries (byte-identical buffers) as the per-leaf codec."""
    from repro.utils.tree import _path_str
    if is_flat_message(msg):
        out = []
        if include_header:
            out.append((HEADER_KEY,
                        {"header": wire_header(message_rank(msg),
                                               msg.bits)}))
        out.extend(msg.to_wire_entries())
        return out
    flat, _ = jax.tree_util.tree_flatten_with_path(
        msg, is_leaf=is_wire_leaf)
    out = []
    if include_header:
        bits = next((leaf.bits for _, leaf in flat
                     if is_wire_leaf(leaf) and leaf.bits is not None),
                    None)
        out.append((HEADER_KEY,
                    {"header": wire_header(message_rank(msg), bits,
                                           message_density(msg))}))
    for path, leaf in flat:
        if is_wire_leaf(leaf):
            out.append((_path_str(path), leaf.to_wire()))
        else:
            out.append((_path_str(path),
                        {"payload": np.asarray(leaf, np.float32)}))
    return out


def message_from_wire(entries: list[tuple[str, dict]], like: Any) -> Any:
    """Rebuild a wire message from ``message_to_wire`` buffers.

    ``like`` is a template message with the same structure (its leaves
    supply the static shape/dtype/bits/per_stack/density metadata; its
    array contents are ignored). The inverse of ``message_to_wire`` up
    to the header entry, which is validated and discarded."""
    from repro.utils.tree import _path_str
    bufs = dict(entries)
    if HEADER_KEY in bufs:
        parse_wire_header(bufs[HEADER_KEY]["header"])
    if is_flat_message(like):
        return FlatPackedMessage.from_wire_entries(
            [(n, b) for n, b in entries if n != HEADER_KEY], like.layout)
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        like, is_leaf=is_wire_leaf)
    leaves = []
    for path, leaf in flat:
        b = bufs[_path_str(path)]
        if is_packed_leaf(leaf):
            leaves.append(PackedLeaf.from_wire(
                b, leaf.shape, leaf.dtype, leaf.bits, leaf.per_stack))
        elif is_sparse_leaf(leaf):
            leaves.append(SparseLeaf.from_wire(
                b, leaf.shape, leaf.dtype, leaf.bits, leaf.density))
        else:
            leaves.append(jnp.asarray(b["payload"]).reshape(
                leaf.shape).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def packed_wire_bytes(msg: Any) -> int:
    """Payload bytes on the wire, MEASURED from the real serialized
    buffers (not shape math) — the cross-check for
    ``message_wire_bytes``. Excludes the fixed 20-byte header, matching
    the paper's accounting."""
    total = 0
    for name, bufs in message_to_wire(msg):
        if name == HEADER_KEY:
            continue
        total += sum(b.nbytes for b in bufs.values())
    return total
