"""Mixture-of-Experts layer with sort-based static-capacity dispatch.

TPU adaptation (DESIGN.md §3): instead of a GPU block-sparse grouped GEMM
(MegaBlocks) or a GShard (T, E, C) one-hot dispatch einsum, tokens are
argsorted by expert id and scattered into a static (E, C+1, d) buffer
(row C is the drop slot), giving one batched GEMM per weight — static
shapes, MXU-friendly, and the expert axis shards over the `model` mesh
axis (EP). Capacity C = ceil(T·k/E · capacity_factor) rounded to 8.

Experts are FLoCoRA targets: frozen (E, d, f) banks + stacked per-expert
LoRA adapters (E, d, r)/(E, r, f). The router and shared experts follow
the usual rules (router trained dense — small and sensitive).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.lora import LoRAConfig, linear_init, linear_apply, \
    linear_logical
from repro.models.layers import MLPSpec, mlp_init, mlp_apply, mlp_logical
from repro.utils.pcontext import constrain as pconstrain

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class MoESpec:
    d_model: int
    d_ff: int                 # per-expert hidden
    n_experts: int
    top_k: int
    n_shared: int = 0         # shared experts (fused into one wide MLP)
    capacity_factor: float = 1.25
    mlp_kind: str = "swiglu"
    # dispatch token-chunking: bounds the (E, C, d) buffer and the
    # gather/scatter transients that GSPMD replicates for cross-shard
    # scatters — a 1M-token prefill dispatches in ~64k-token chunks.
    max_chunk_tokens: int = 65536


def _cap(spec: MoESpec, tokens: int) -> int:
    c = int(tokens * spec.top_k * spec.capacity_factor / spec.n_experts) + 1
    return max(8, -(-c // 8) * 8)


def moe_init(key: Array, spec: MoESpec, mode: str, lora: LoRAConfig,
             stack: tuple[int, ...] = ()) -> tuple[dict, dict]:
    ks = jax.random.split(key, 5)
    e = spec.n_experts
    fz, tr = {}, {}
    # router: trained dense (small, sensitive)
    tr["router"] = {"w": (jax.random.normal(
        ks[0], (*stack, spec.d_model, e), jnp.float32)
        * (spec.d_model ** -0.5))}
    names = ["wi", "wg", "wo"] if spec.mlp_kind in ("swiglu", "geglu") \
        else ["wi", "wo"]
    dims = {"wi": (spec.d_model, spec.d_ff), "wg": (spec.d_model, spec.d_ff),
            "wo": (spec.d_ff, spec.d_model)}
    for i, nm in enumerate(names):
        f, t = linear_init(ks[1 + i], *dims[nm], mode, lora,
                           stack=(*stack, e))
        if f:
            fz[nm] = f
        if t:
            tr[nm] = t
    if spec.n_shared:
        sh = MLPSpec(spec.mlp_kind, spec.d_model,
                     spec.d_ff * spec.n_shared)
        sfz, str_ = mlp_init(ks[4], sh, mode, lora, stack)
        if sfz:
            fz["shared"] = sfz
        if str_:
            tr["shared"] = str_
    return fz, tr


def moe_logical(spec: MoESpec, mode: str, stack: bool) -> tuple[dict, dict]:
    pre = ("layers",) if stack else ()
    fz, tr = {}, {}
    tr["router"] = {"w": (*pre, "fsdp", None)}
    names = ["wi", "wg", "wo"] if spec.mlp_kind in ("swiglu", "geglu") \
        else ["wi", "wo"]
    dims = {"wi": ("fsdp", "mlp_nosplit"), "wg": ("fsdp", "mlp_nosplit"),
            "wo": ("mlp_nosplit", "fsdp")}
    for nm in names:
        f, t = linear_logical(*dims[nm], mode, stack)
        # inject the expert axis after the optional layer-stack axis
        ins = (lambda tup: tup[: len(pre)] + ("expert",) + tup[len(pre):])
        if f:
            fz[nm] = {k: ins(v) for k, v in f.items()}
        if t:
            tr[nm] = {k: ins(v) for k, v in t.items()}
    if spec.n_shared:
        sh = MLPSpec(spec.mlp_kind, spec.d_model, spec.d_ff * spec.n_shared)
        sfz, str_ = mlp_logical(sh, mode, stack)
        if sfz:
            fz["shared"] = sfz
        if str_:
            tr["shared"] = str_
    return fz, tr


def _expert_ffn(fz: dict, tr: dict, spec: MoESpec, buf: Array,
                lora_scale: float) -> Array:
    """buf: (E, C, d) -> (E, C, d), batched over experts."""
    def bank(nm, x):
        if nm in fz and ("w" in fz[nm] or "w_q8" in fz[nm]):
            from repro.core.lora import frozen_weight
            w = frozen_weight(fz[nm])
        else:
            w = tr[nm]["w"].astype(jnp.bfloat16)
        y = jnp.einsum("ecd,edf->ecf", x.astype(jnp.bfloat16), w)
        t = tr.get(nm, {})
        if "a" in t:
            h = jnp.einsum("ecd,edr->ecr", x.astype(jnp.bfloat16),
                           t["a"].astype(jnp.bfloat16))
            y = y + lora_scale * jnp.einsum(
                "ecr,erf->ecf", h, t["b"].astype(jnp.bfloat16))
        return y

    if spec.mlp_kind == "swiglu":
        h = jax.nn.silu(bank("wg", buf).astype(jnp.float32)).astype(
            buf.dtype) * bank("wi", buf)
    elif spec.mlp_kind == "geglu":
        h = jax.nn.gelu(bank("wg", buf).astype(jnp.float32),
                        approximate=True).astype(buf.dtype) * bank("wi", buf)
    elif spec.mlp_kind == "sqrelu":
        h = jax.nn.relu(bank("wi", buf))
        h = h * h
    else:
        h = jax.nn.gelu(bank("wi", buf).astype(jnp.float32)).astype(buf.dtype)
    return bank("wo", h)


def _dispatch_chunk(fz, tr, spec: MoESpec, xt: Array, gates: Array,
                    idx: Array, lora_scale: float) -> Array:
    """Sort-dispatch one token chunk through the expert banks."""
    t, d = xt.shape
    tk = t * spec.top_k
    flat_e = idx.reshape(tk)
    flat_g = gates.reshape(tk)
    flat_tok = jnp.repeat(jnp.arange(t), spec.top_k)
    order = jnp.argsort(flat_e)                            # stable
    se, st, sg = flat_e[order], flat_tok[order], flat_g[order]
    counts = jnp.bincount(flat_e, length=spec.n_experts)
    offsets = jnp.cumsum(counts) - counts
    pos = jnp.arange(tk) - offsets[se]
    cap = _cap(spec, t)
    keep = pos < cap
    pos_c = jnp.where(keep, pos, cap)                      # drop slot = cap

    buf = jnp.zeros((spec.n_experts, cap + 1, d), xt.dtype)
    gathered = pconstrain(xt[st], "tokens")
    buf = pconstrain(buf.at[se, pos_c].set(gathered), "expert")
    out = pconstrain(
        _expert_ffn(fz, tr, spec, buf[:, :cap], lora_scale), "expert")
    out = jnp.pad(out, ((0, 0), (0, 1), (0, 0)))
    contrib = pconstrain(
        out[se, pos_c] * (sg * keep)[:, None].astype(out.dtype), "tokens")
    y = jnp.zeros((t, d), contrib.dtype).at[st].add(contrib)
    return pconstrain(y, "tokens")


def moe_apply(fz: dict, tr: dict, spec: MoESpec, x: Array,
              lora_scale: float) -> tuple[Array, Array]:
    """x: (B, S, d). Returns (y, aux_loss)."""
    b, s, d = x.shape
    t = b * s
    xt = pconstrain(x.reshape(t, d), "tokens")
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        tr["router"]["w"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, spec.top_k)          # (T, k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch): E * sum_e f_e * P_e
    pe = jnp.mean(probs, axis=0)
    fe = jnp.mean(
        jnp.sum(jax.nn.one_hot(idx, spec.n_experts, dtype=jnp.float32),
                axis=1), axis=0)
    aux = spec.n_experts * jnp.sum(pe * fe)

    n_chunks = max(1, -(-t // spec.max_chunk_tokens))
    while t % n_chunks:
        n_chunks += 1
    if n_chunks == 1:
        y = _dispatch_chunk(fz, tr, spec, xt, gates, idx, lora_scale)
    else:
        tc = t // n_chunks

        def body(_, args):
            xc, gc, ic = args
            return None, _dispatch_chunk(fz, tr, spec, xc, gc, ic,
                                         lora_scale)

        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
        _, yc = jax.lax.scan(
            body, None,
            (xt.reshape(n_chunks, tc, d),
             gates.reshape(n_chunks, tc, spec.top_k),
             idx.reshape(n_chunks, tc, spec.top_k)))
        y = yc.reshape(t, d)

    if spec.n_shared:
        sh = MLPSpec(spec.mlp_kind, d, spec.d_ff * spec.n_shared)
        y = y + mlp_apply(fz.get("shared", {}), tr.get("shared", {}),
                          sh, xt, lora_scale)
    return y.reshape(b, s, d).astype(x.dtype), aux
