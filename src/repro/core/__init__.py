"""FLoCoRA core: LoRA adapters, affine message quantization, aggregation.

Public API re-exports.
"""
from repro.core.flocora import FLoCoRAConfig, broadcast, client_uplink, \
    server_downlink, server_round, round_wire_bytes, tcc
from repro.core.aggregation import Aggregator, FedAvgAggregator, \
    FedBuffAggregator, ErrorFeedbackFedAvg, fedavg_packed
from repro.core.messages import PackedLeaf, pack_message, unpack_message, \
    packed_wire_bytes, message_wire_bytes
from repro.core.lora import LoRAConfig, dense_lora_init, dense_lora_apply, \
    dense_merge, conv_lora_init, conv_lora_apply, conv_merge, linear_init, \
    linear_apply, linear_logical
from repro.core.quant import QuantConfig, affine_qparams, quantize, \
    dequantize, quant_dequant, pack_levels, unpack_levels
from repro.core import messages, aggregation
