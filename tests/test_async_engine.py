"""Async federation engine: deterministic fleet traces, the rank-bucketed
staleness-discounted FedBuff buffer, the event-driven engine end-to-end
(history/TCC integrity, compile-count bound), sync-baseline parity and
bit-exact killed-then-resumed replay."""
import math
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregation, lora, messages
from repro.core.aggregation import FedBuffAggregator
from repro.core.flocora import FLoCoRAConfig, RankSchedule
from repro.core.lora import LoRAConfig, linear_apply, linear_init
from repro.core.quant import QuantConfig
from repro.fl import AsyncConfig, AsyncFLServer, AvailabilityWindows, \
    ClientConfig, FLServer, FleetTrace, LognormalLatency, ServerConfig, \
    time_to_target
from repro.fl.traces import TAG_LATENCY


# ---------------------------------------------------------------------------
# tiny LoRA workload (mirrors test_hetero_rank: fast compiles, real ranks)
# ---------------------------------------------------------------------------

SCALE = 1.0


def _lora_model(seed=0, rank=16):
    k = jax.random.PRNGKey(seed)
    fz, tr = linear_init(k, 16, 10, "lora",
                         LoRAConfig(rank=rank, alpha=float(rank)),
                         base_dtype=jnp.float32)
    return {"frozen": {"lin": fz},
            "train": {"lin": tr, "bias": jnp.zeros((10,))}}


def _lora_loss(frozen, train, batch):
    logits = linear_apply(frozen["lin"], train["lin"], batch["x"], SCALE,
                          jnp.float32) + train["bias"]
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, batch["y"][:, None],
                                         axis=1)), {}


def _lin_data(n=240, n_clients=10, seed=0):
    rng = np.random.default_rng(seed)
    w_true = rng.normal(size=(16, 10)).astype(np.float32)
    x = rng.normal(size=(n, 16)).astype(np.float32)
    y = np.argmax(x @ w_true + 0.1 * rng.normal(size=(n, 10)),
                  axis=1).astype(np.int32)
    parts = np.array_split(rng.permutation(n), n_clients)
    return [{"x": x[p], "y": y[p]} for p in parts], {"x": x, "y": y}


def _trace():
    return FleetTrace(seed=0, latency=LognormalLatency(
        compute_median_s=10.0, network_mbps=20.0))


def _engine(data, acfg, fcfg, trace=None, **kw):
    return AsyncFLServer(_lora_model(rank=fcfg.rank), _lora_loss, data,
                         acfg, ClientConfig(local_epochs=2, batch_size=8,
                                            lr=0.1),
                         fcfg, trace=trace or _trace(), **kw)


HCFG = FLoCoRAConfig(rank=16, alpha=16.0, quant_bits=8,
                     rank_schedule=RankSchedule.tiered((8, 16), 10))


# ---------------------------------------------------------------------------
# traces: deterministic replay, availability windows
# ---------------------------------------------------------------------------

def test_trace_deterministic_replay():
    """A latency draw is a pure function of (seed, cid, dispatch_idx):
    same key -> bit-identical arrival regardless of call order."""
    tr = _trace()
    a1 = tr.arrival(3, 7, 8, 10_000, 5.0)
    _ = tr.arrival(4, 8, 16, 20_000, 9.0)      # unrelated draw between
    a2 = tr.arrival(3, 7, 8, 10_000, 5.0)
    assert a1 == a2
    assert a1 > 5.0
    # different dispatch of the same client draws fresh latency
    assert tr.arrival(3, 8, 8, 10_000, 5.0) != a1
    # a different seed changes the whole trace
    assert FleetTrace(seed=1).arrival(3, 7, 8, 10_000, 5.0) != a1


def test_trace_latency_scales_with_rank_and_bytes():
    lat = LognormalLatency(compute_median_s=10.0, compute_sigma=0.0,
                           network_mbps=8.0, network_sigma=0.0,
                           rank_ref=8, rank_exp=1.0)
    rng = np.random.default_rng(0)
    t_r8 = lat.sample(rng, 8, 1_000_000)
    assert t_r8 == pytest.approx(10.0 + 1.0)        # 1 MB at 1 MB/s
    assert lat.sample(rng, 16, 1_000_000) == pytest.approx(20.0 + 1.0)
    assert lat.sample(rng, 8, 2_000_000) == pytest.approx(10.0 + 2.0)


def test_availability_windows():
    av = AvailabilityWindows(period_s=100.0, duty=0.5)
    ph = av.phase(5)
    assert 0.0 <= ph < 100.0
    assert av.next_available(5, ph + 10.0) == ph + 10.0     # inside
    t_closed = ph + 60.0                                    # outside
    nxt = av.next_available(5, t_closed)
    assert nxt == pytest.approx(ph + 100.0)                 # next window
    # always-available configs are the identity
    assert AvailabilityWindows().next_available(5, 42.0) == 42.0
    # per-client phases are staggered, not synchronized
    assert av.phase(5) != av.phase(6)


def test_trace_rng_domain_disjoint_from_engine():
    """TAG_LATENCY must not collide with the engine's key domains."""
    from repro.fl.async_engine import TAG_BATCH, TAG_SAMPLE
    assert len({TAG_LATENCY, TAG_SAMPLE, TAG_BATCH}) == 3


# ---------------------------------------------------------------------------
# FedBuff: rank-bucketed add/flush + per-bucket sync staleness
# ---------------------------------------------------------------------------

def _client_tree(seed, rank):
    k = jax.random.PRNGKey(seed)
    ad = lora.dense_lora_init(k, 16, 12, LoRAConfig(rank=rank,
                                                    alpha=16.0 * rank))
    return {"lin": {"a": ad["a"],
                    "b": jax.random.normal(jax.random.fold_in(k, 1),
                                           ad["b"].shape) * 0.1},
            "norm": jax.random.normal(jax.random.fold_in(k, 2), (5,))}


def test_fedbuff_bucketed_add_flush_matches_reference():
    """Buffered packed messages of MIXED rank flush in one rank-bucketed
    fused pass; result equals the manual staleness-discounted weighted
    mean over zero-padded dequantized trees."""
    qcfg = QuantConfig(bits=8)
    ranks = (4, 4, 8)
    stales = (0.0, 1.0, 2.0)
    n_k = (10.0, 20.0, 30.0)
    trees = [_client_tree(i, r) for i, r in enumerate(ranks)]
    msgs = [messages.pack_message(t, qcfg) for t in trees]
    agg = FedBuffAggregator(half_life=2.0, r_target=8)
    for m, n, s in zip(msgs, n_k, stales):
        agg.add(m, n, s)
    assert len(agg.pending) == 3
    got = agg.flush()
    assert not agg.pending
    # manual reference: dequantize, pad to rank 8, discounted mean
    w = np.asarray([n * 2.0 ** (-s / 2.0) for n, s in zip(n_k, stales)])
    recon = [lora.resize_tree_rank(messages.unpack_message(m), 8)
             for m in msgs]
    ref = jax.tree.map(
        lambda *xs: sum(float(wi) * x for wi, x in zip(w / w.sum(), xs)),
        *recon)
    assert lora.tree_max_rank(got) == 8
    for ka in ("lin", "norm"):
        for a, b in zip(jax.tree.leaves(got[ka]),
                        jax.tree.leaves(ref[ka])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-5)


def test_fedbuff_sync_rank_staleness_per_bucket():
    """In the sync adapter, arrival order WITHIN each rank bucket plays
    the staleness role — bucket-leading arrivals are undiscounted."""
    ranks = (4, 8, 4, 8)
    trees = [_client_tree(i, r) for i, r in enumerate(ranks)]
    w = np.asarray([1.0, 1.0, 1.0, 1.0], np.float32)
    agg = FedBuffAggregator(half_life=1.0, rank_staleness=True,
                            r_target=8)
    got = agg.aggregate(trees, jnp.asarray(w))
    # manual: in-bucket positions -> staleness (0, 0, 1, 1), hl=1
    disc = w * np.exp2(-np.asarray([0.0, 0.0, 1.0, 1.0]))
    padded = [lora.resize_tree_rank(t, 8) for t in trees]
    ref = jax.tree.map(
        lambda *xs: sum(float(wi) * x
                        for wi, x in zip(disc / disc.sum(), xs)),
        *padded)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_fedbuff_discount_formula():
    """w = n_k * 2^(-staleness / half_life), documented + threaded."""
    agg = FedBuffAggregator(half_life=4.0)
    assert agg.discounted_weight(8.0, 0.0) == 8.0
    assert agg.discounted_weight(8.0, 4.0) == pytest.approx(4.0)
    assert agg.discounted_weight(8.0, 8.0) == pytest.approx(2.0)
    # unset half_life resolves to the module default until threaded
    assert FedBuffAggregator().resolved_half_life() == \
        aggregation.FEDBUFF_HALF_LIFE


def test_fedbuff_incremental_reference_matches_buffered_path():
    """The incremental fp reference (fedbuff_init/add/flush) and the
    production buffered path (FedBuffAggregator.add/flush) implement the
    SAME discounted rule — keep them consistent."""
    trees = [_client_tree(i, 8) for i in range(3)]
    n_k = (4.0, 2.0, 6.0)
    stales = (0.0, 1.0, 3.0)
    hl = 2.0
    st = aggregation.fedbuff_init(trees[0])
    for t, n, s in zip(trees, n_k, stales):
        st = aggregation.fedbuff_add(st, t, jnp.asarray(n),
                                     jnp.asarray(s), half_life=hl)
    ref, _ = aggregation.fedbuff_flush(st, trees[0])
    agg = FedBuffAggregator(half_life=hl, r_target=8)
    for t, n, s in zip(trees, n_k, stales):
        agg.add(t, n, s)
    got = agg.flush()
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_fedbuff_half_life_threaded_from_configs():
    """SATELLITE: half_life is a config field, threaded by both engines
    into an aggregator that did not pin one explicitly."""
    data, _ = _lin_data()
    srv = FLServer(_lora_model(rank=16), _lora_loss, data,
                   ServerConfig(rounds=1, n_clients=10,
                                clients_per_round=4,
                                fedbuff_half_life=2.5),
                   ClientConfig(), HCFG, aggregator=FedBuffAggregator())
    assert srv.aggregator.half_life == 2.5
    # an explicit half_life wins over the config
    srv2 = FLServer(_lora_model(rank=16), _lora_loss, data,
                    ServerConfig(rounds=1, n_clients=10,
                                 clients_per_round=4,
                                 fedbuff_half_life=2.5),
                    ClientConfig(), HCFG,
                    aggregator=FedBuffAggregator(half_life=7.0))
    assert srv2.aggregator.half_life == 7.0
    asrv = _engine(data, AsyncConfig(total_arrivals=4, concurrency=2,
                                     buffer_size=2, half_life=3.0), HCFG)
    assert asrv.aggregator.half_life == 3.0
    assert asrv.aggregator.r_target == 16


def test_sync_server_accepts_fedbuff_for_mixed_ranks():
    """SATELLITE: the construction-time rejection is gone — a mixed-rank
    schedule trains through FedBuff's rank-bucketed path end-to-end."""
    data, _ = _lin_data()
    srv = FLServer(_lora_model(rank=16), _lora_loss, data,
                   ServerConfig(rounds=1, n_clients=10,
                                clients_per_round=6),
                   ClientConfig(local_epochs=1, batch_size=8, lr=0.1),
                   HCFG,
                   aggregator=FedBuffAggregator(rank_staleness=True))
    rec = srv.run_round()
    assert np.isfinite(rec["client_loss"])
    assert lora.tree_ranks(srv.global_train) == (16,)


def test_sync_server_still_rejects_bucketless_aggregators():
    """Only truly unsupported combos keep the config-validation error:
    an aggregator with no rank-bucketed path + a mixed schedule."""

    class PlainMean:
        def aggregate(self, msgs, weights):
            return aggregation.fedavg(aggregation.stack_trees(msgs),
                                      jnp.asarray(weights))

    data, _ = _lin_data()
    with pytest.raises(ValueError, match="rank-bucketed"):
        FLServer(_lora_model(rank=16), _lora_loss, data,
                 ServerConfig(rounds=1, n_clients=10,
                              clients_per_round=4),
                 ClientConfig(), HCFG, aggregator=PlainMean())


def test_quant_tcc_bytes_shim_deprecated():
    """SATELLITE: the scalar quant.tcc_bytes survives as a deprecation
    shim over the canonical messages.tcc_bytes formula."""
    from repro.core import quant
    tree = {"w": jnp.zeros((8, 8))}
    cfg = QuantConfig(bits=8)
    with pytest.warns(DeprecationWarning):
        legacy = quant.tcc_bytes(messages.message_wire_bytes(tree, cfg),
                                 rounds=7)
    assert legacy == messages.tcc_bytes(tree, cfg, rounds=7)


# ---------------------------------------------------------------------------
# the engine: config validation, end-to-end smoke, compile bound
# ---------------------------------------------------------------------------

def test_async_config_validation():
    with pytest.raises(ValueError):
        AsyncConfig(buffer_size=0)
    with pytest.raises(ValueError):
        AsyncConfig(half_life=0.0)
    with pytest.raises(ValueError):
        AsyncConfig(microbatch_window=-1.0)
    data, _ = _lin_data()
    with pytest.raises(ValueError, match="error feedback"):
        _engine(data, AsyncConfig(total_arrivals=4),
                FLoCoRAConfig(rank=16, alpha=16.0, quant_bits=8,
                              error_feedback=True))
    with pytest.raises(ValueError, match="FedBuffAggregator"):
        _engine(data, AsyncConfig(total_arrivals=4), HCFG,
                aggregator=aggregation.FedAvgAggregator())
    with pytest.raises(ValueError, match="rank_schedule"):
        _engine(data[:4], AsyncConfig(total_arrivals=4), HCFG)
    # an explicit r_target off the server rank would shape-error the
    # delta flush mid-run: rejected at config time
    with pytest.raises(ValueError, match="r_target"):
        _engine(data, AsyncConfig(total_arrivals=4), HCFG,
                aggregator=FedBuffAggregator(r_target=8))
    with pytest.raises(ValueError):
        AsyncConfig(eval_every=0)


def test_async_engine_end_to_end():
    """40 arrivals over a 2-tier fleet: versions advance, loss falls,
    staleness is tracked, TCC sums measured wire bytes, and the compiled
    program count respects the #ranks x log2(microbatch) bound."""
    data, full = _lin_data()

    def eval_fn(frozen, train):
        return {"eval_loss": float(_lora_loss(frozen, train, full)[0])}

    acfg = AsyncConfig(total_arrivals=40, concurrency=4, buffer_size=5,
                       microbatch_window=8.0, seed=0, eval_every=4)
    srv = _engine(data, acfg, HCFG, eval_fn=eval_fn)
    hist = srv.run()
    assert len(hist) == 8 and srv.version == 8
    assert [h["version"] for h in hist] == list(range(1, 9))
    assert all(h["n_flushed"] == 5 for h in hist)
    # virtual clock is monotone; staleness bounded by version depth
    ts = [h["t_virtual"] for h in hist]
    assert ts == sorted(ts) and ts[0] > 0.0
    assert all(h["staleness_mean"] >= 0.0 for h in hist)
    # both tiers flushed at some point (str keys: history is JSON-safe)
    seen_ranks = set().union(*(h["flush_ranks"] for h in hist))
    assert seen_ranks == {"8", "16"}
    # TCC = shared-once initial model + measured down/uplinks, monotone
    assert hist[-1]["tcc_bytes"] == srv.tcc_bytes
    assert hist[-1]["tcc_bytes"] == srv.initial_model_bytes \
        + hist[-1]["down_bytes"] + hist[-1]["up_bytes"]
    tccs = [h["tcc_bytes"] for h in hist]
    assert tccs == sorted(tccs)
    # it learns
    assert hist[-1]["client_loss"] < hist[0]["client_loss"]
    assert "eval_loss" in hist[3]
    # ACCEPTANCE: recompiles bounded by #ranks x log2(max micro-batch)
    bound = 2 * (int(math.log2(acfg.concurrency)) + 1)
    assert len(srv.program_keys) <= bound
    assert {r for r, _ in srv.program_keys} == {8, 16}
    # time/bytes-to-target metric finds the trajectory point
    hit = time_to_target(hist, "client_loss", hist[-1]["client_loss"],
                         mode="min")
    assert hit is not None and hit["tcc_bytes"] <= hist[-1]["tcc_bytes"]


def test_async_engine_fp_uniform_fleet():
    """Quantization off + uniform ranks: fp messages traverse the same
    event loop (single-tier program cache)."""
    data, _ = _lin_data()
    fcfg = FLoCoRAConfig(rank=8, alpha=8.0)
    acfg = AsyncConfig(total_arrivals=10, concurrency=3, buffer_size=5,
                       seed=1)
    srv = _engine(data, acfg, fcfg)
    hist = srv.run()
    assert len(hist) == 2
    assert {r for r, _ in srv.program_keys} == {8}
    assert hist[-1]["up_bytes"] > 0


def test_async_fresh_buffer_equals_fedavg_of_buffer():
    """With every buffered update fresh (staleness 0), server_lr 1 and
    quantization OFF (so each client's start IS the server tree), one
    flush reproduces the plain FedAvg of the buffered messages — the
    delta-apply rule reduces to the sync aggregation. (With quantization
    on, deltas are measured against the DEQUANTIZED broadcast the client
    actually received, which differs from the server tree by the
    broadcast's bounded quantization error.)"""
    data, _ = _lin_data()
    fcfg = FLoCoRAConfig(rank=8, alpha=8.0)
    # concurrency == buffer_size: every arrival in a flush was
    # dispatched from the same version -> staleness 0
    acfg = AsyncConfig(total_arrivals=4, concurrency=4, buffer_size=4,
                       microbatch_window=1e9, seed=0)
    srv = _engine(data, acfg, fcfg)
    # capture the buffered messages + weights at flush time
    captured = {}
    orig_flush = srv.aggregator.flush

    def spy_flush():
        captured["msgs"] = [m for m, _ in srv.aggregator.pending]
        captured["w"] = [w for _, w in srv.aggregator.pending]
        return orig_flush()

    srv.aggregator.flush = spy_flush
    hist = srv.run()
    assert hist[-1]["staleness_max"] == 0
    ref = aggregation.fedavg(aggregation.stack_trees(captured["msgs"]),
                             jnp.asarray(captured["w"]))
    for a, b in zip(jax.tree.leaves(jax.device_get(srv.global_train)),
                    jax.tree.leaves(jax.device_get(ref))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# acceptance: sync parity + bit-exact resume
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_async_reaches_sync_baseline_loss():
    """ACCEPTANCE: >= 200 virtual arrivals over >= 2 rank tiers reach
    within 2% of the sync baseline's final loss (same update budget:
    20 rounds x 10 clients)."""
    data, full = _lin_data()

    def eval_fn(frozen, train):
        return {"eval_loss": float(_lora_loss(frozen, train, full)[0])}

    ccfg = ClientConfig(local_epochs=2, batch_size=8, lr=0.1)
    srv = FLServer(_lora_model(rank=16), _lora_loss, data,
                   ServerConfig(rounds=20, n_clients=10,
                                clients_per_round=10, eval_every=20),
                   ccfg, HCFG, eval_fn=eval_fn)
    sync_loss = srv.run()[-1]["eval_loss"]

    acfg = AsyncConfig(total_arrivals=200, concurrency=8, buffer_size=10,
                       microbatch_window=8.0, seed=0)
    asrv = AsyncFLServer(_lora_model(rank=16), _lora_loss, data, acfg,
                         ccfg, HCFG, trace=_trace(), eval_fn=eval_fn)
    asrv.run()
    async_loss = eval_fn(asrv.frozen, asrv.global_train)["eval_loss"]
    assert asrv.version == 20
    assert async_loss <= 1.02 * sync_loss, (async_loss, sync_loss)
    bound = 2 * (int(math.log2(acfg.concurrency)) + 1)
    assert len(asrv.program_keys) <= bound


@pytest.mark.slow
def test_async_resume_is_bit_exact(tmp_path):
    """ACCEPTANCE: a killed-then-resumed run reproduces the
    uninterrupted run's history AND final global tree bit-exactly."""
    data, _ = _lin_data()
    ccfg = ClientConfig(local_epochs=2, batch_size=8, lr=0.1)
    d_a, d_b = str(tmp_path / "a"), str(tmp_path / "b")

    def acfg(d):
        return AsyncConfig(total_arrivals=40, concurrency=4,
                           buffer_size=5, microbatch_window=8.0, seed=0,
                           checkpoint_dir=d, checkpoint_every=2)

    srv_a = AsyncFLServer(_lora_model(rank=16), _lora_loss, data,
                          acfg(d_a), ccfg, HCFG, trace=_trace())
    hist_a = srv_a.run()
    # "kill": keep only the OLDEST surviving checkpoint in a copy
    os.makedirs(d_b)
    for fn in os.listdir(d_a):
        shutil.copy(os.path.join(d_a, fn), d_b)
    steps = sorted(int(f[5:-5]) for f in os.listdir(d_b)
                   if f.endswith(".json"))
    assert len(steps) >= 2        # resume point strictly mid-run
    for s in steps[1:]:
        for ext in (".npz", ".json"):
            os.remove(os.path.join(d_b, f"ckpt_{s:08d}{ext}"))

    srv_b = AsyncFLServer(_lora_model(rank=16), _lora_loss, data,
                          acfg(d_b), ccfg, HCFG, trace=_trace())
    assert srv_b.try_resume()
    assert srv_b.n_flushes == steps[0] < srv_a.n_flushes
    assert srv_b.inflight          # mid-run state restored
    hist_b = srv_b.run()
    assert hist_a == hist_b        # bit-exact: dict/float equality
    for a, b in zip(jax.tree.leaves(jax.device_get(srv_a.global_train)),
                    jax.tree.leaves(jax.device_get(srv_b.global_train))):
        assert np.array_equal(np.asarray(a), np.asarray(b))
