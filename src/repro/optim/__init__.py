from repro.optim.optimizers import Optimizer, sgd, adamw, clip_by_global_norm
from repro.optim.schedule import constant, cosine_warmup
