"""Bench run metadata: who/what produced a measurement.

Every ``--json`` sweep written by ``benchmarks/round_throughput.py``
carries a ``meta`` block from :func:`run_meta`, so
``benchmarks/bench_compare.py`` can refuse to diff a CPU run against a
TPU baseline (or jax versions apart) instead of reporting phantom
regressions. The run id is random and HOSTNAME-FREE — the JSON is
committed/uploaded, and machine names don't belong in the repo.
"""
from __future__ import annotations

import platform
import secrets

import jax


def run_meta() -> dict:
    """Environment fingerprint of one benchmark run."""
    dev = jax.devices()[0]
    return {
        "backend": jax.default_backend(),
        "device_kind": str(getattr(dev, "device_kind", dev.platform)),
        "n_devices": jax.device_count(),
        "jax_version": jax.__version__,
        "python_version": platform.python_version(),
        # random, not host-derived: uploaded artifacts stay anonymous
        "run_id": secrets.token_hex(8),
    }


# meta keys that must MATCH for two runs to be comparable; the rest
# (n_devices, python patch level, run_id) only annotate
COMPARABLE_KEYS = ("backend", "device_kind", "jax_version")


def comparable(a: dict, b: dict) -> tuple[bool, list[str]]:
    """Can run ``a`` be diffed against run ``b``? Returns (ok,
    mismatched keys); missing meta on either side compares as unknown
    (ok=True, caller warns)."""
    if not a or not b:
        return True, []
    bad = [k for k in COMPARABLE_KEYS
           if k in a and k in b and a[k] != b[k]]
    return not bad, bad
