"""seamless-m4t-medium [audio]: enc-dec, 12L each, d_model=1024 16H
(kv=16) d_ff=4096 vocab=256206 [arXiv:2308.11596]. The speech frontend is
a STUB: input_specs provides precomputed frame embeddings."""
from repro.core.lora import LoRAConfig
from repro.models.encdec import EncDecConfig


def full() -> EncDecConfig:
    return EncDecConfig(
        name="seamless-m4t-medium", n_enc_layers=12, n_dec_layers=12,
        d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64, d_ff=4096,
        vocab=256206, mlp_kind="gelu",
        lora=LoRAConfig(rank=32, alpha=512.0), head_mode="lora")


def smoke() -> EncDecConfig:
    return EncDecConfig(
        name="seamless-m4t-medium-smoke", n_enc_layers=2, n_dec_layers=2,
        d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
        vocab=512, mlp_kind="gelu",
        lora=LoRAConfig(rank=4, alpha=64.0), head_mode="lora")
