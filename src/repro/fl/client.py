"""Client-side local training (paper §IV setup).

Defaults match the paper: SGD momentum 0.9, lr 0.01, batch 32, 5 local
epochs. The local loop jits ONCE per (model, batch-shape) and is reused
by every simulated client: batches are pre-gathered host-side into a
(steps, B, ...) stack and the whole local run is a lax.scan.

``fedprox_mu`` adds the FedProx proximal term — demonstrating the paper's
aggregation-agnostic claim (FLoCoRA composes with any FL optimizer
unchanged, §III).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import sgd

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ClientConfig:
    local_epochs: int = 5
    batch_size: int = 32
    lr: float = 0.01
    momentum: float = 0.9
    fedprox_mu: float = 0.0


def make_local_trainer(loss_fn: Callable, cfg: ClientConfig):
    """loss_fn(frozen, train, batch) -> (loss, metrics).

    Returns ``run(frozen, train0, batches) -> (train, mean_loss)`` where
    batches is a pytree with leading (steps, B) dims. Jitted once."""
    opt = sgd(momentum=cfg.momentum)

    @jax.jit
    def run(frozen, train0, batches):
        opt_state = opt.init(train0)

        def grad_loss(train, batch):
            loss, _ = loss_fn(frozen, train, batch)
            if cfg.fedprox_mu > 0.0:
                prox = sum(jnp.sum(jnp.square(
                    a.astype(jnp.float32) - b.astype(jnp.float32)))
                    for a, b in zip(jax.tree.leaves(train),
                                    jax.tree.leaves(train0)))
                loss = loss + 0.5 * cfg.fedprox_mu * prox
            return loss

        def step(carry, batch):
            train, opt_state = carry
            loss, grads = jax.value_and_grad(grad_loss)(train, batch)
            train, opt_state = opt.update(grads, opt_state, train, cfg.lr)
            return (train, opt_state), loss

        (train, _), losses = jax.lax.scan(step, (train0, opt_state), batches)
        return train, jnp.mean(losses)

    return run


def stack_local_batches(rng: np.random.Generator, data: dict,
                        cfg: ClientConfig) -> dict:
    """Host-side: pack a client's dataset into (steps, B, ...) batches,
    reshuffling each local epoch (with wraparound padding)."""
    n = len(next(iter(data.values())))
    per_epoch = max(1, n // cfg.batch_size)
    idx_all = []
    for _ in range(cfg.local_epochs):
        idx = rng.permutation(n)
        take = per_epoch * cfg.batch_size
        if take > n:
            idx = np.concatenate([idx, rng.integers(0, n, take - n)])
        idx_all.append(idx[:take].reshape(per_epoch, cfg.batch_size))
    idx_all = np.concatenate(idx_all, axis=0)
    return {k: v[idx_all] for k, v in data.items()}
