"""Renders the §Dry-run / §Roofline tables for EXPERIMENTS.md from the
results/dryrun JSON cache (written by repro.launch.dryrun)."""
import json
import os
import sys


def load(results_dir: str = "results/dryrun", tag: str = "baseline"):
    recs = []
    if not os.path.isdir(results_dir):
        return recs
    for fn in sorted(os.listdir(results_dir)):
        if fn.endswith(".json"):
            r = json.load(open(os.path.join(results_dir, fn)))
            if r.get("tag", "baseline") == tag:
                recs.append(r)
    return recs


def fmt_table(recs, mesh="pod16x16") -> list[str]:
    lines = ["| arch | shape | step | peak GiB/chip | t_compute | t_memory"
             " | t_collective | dominant | useful-FLOP ratio |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — |"
                         f" — | SKIP | {r['skip_reason'][:40]} |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['step']} |"
                         f" ERROR | | | | | {r['error'][:40]} |")
            continue
        t = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['step']} |"
            f" {r['memory']['peak_bytes'] / 2**30:.2f} |"
            f" {t['t_compute_s']:.3e} | {t['t_memory_s']:.3e} |"
            f" {t['t_collective_s']:.3e} | {t['dominant']} |"
            f" {r['useful_flops_ratio']:.2f} |")
    return lines


def run() -> list[str]:
    recs = load()
    rows = []
    ok = sum(1 for r in recs if r["status"] == "ok")
    skip = sum(1 for r in recs if r["status"] == "skipped")
    err = sum(1 for r in recs if r["status"] == "error")
    over = sum(1 for r in recs if r["status"] == "ok"
               and r["memory"]["peak_bytes"] > 16 * 2**30)
    rows.append(f"roofline/cells,0,ok={ok} skipped={skip} errors={err} "
                f"over_16GiB={over}")
    for r in recs:
        if r["status"] == "ok" and r["mesh"] == "pod16x16":
            t = r["roofline"]
            dom = max(t["t_compute_s"], t["t_memory_s"],
                      t["t_collective_s"])
            frac = t["t_compute_s"] / dom if dom else 0
            rows.append(f"roofline/{r['arch']}_{r['shape']},0,"
                        f"dominant={t['dominant']} "
                        f"compute_fraction={frac:.3f} "
                        f"peak_gib={r['memory']['peak_bytes'] / 2**30:.2f}")
    return rows


if __name__ == "__main__":
    if "--markdown" in sys.argv:
        mesh = "pod2x16x16" if "--multi" in sys.argv else "pod16x16"
        print("\n".join(fmt_table(load(), mesh)))
    else:
        print("\n".join(run()))
