"""Pallas TPU kernel: fused unpack + dequantize + weighted aggregate.

The FLoCoRA server hot loop: K quantized client messages -> one fp32
aggregated adapter tree, WITHOUT materializing K dequantized fp32 copies
(K x memory saved; the op is bandwidth-bound on the packed payload, which
is 4-16x smaller than fp32 — this fusion is what makes the paper's
quantization a server-side win too, not just a wire win).

Like ``quant_pack``, the valid-column count is PER ROW: a (C, 1) int32
sidecar masks each row's tail so a whole flat-tree message (every leaf's
channel rows stacked into one ragged buffer, core/flat.py) aggregates a
K-client cohort in ONE launch — contributions past a row's length are
forced to exact zero, so flat rows slice apart cleanly.

Two grid shapes over the same fold:

  * small cohorts — grid ``(C/bc,)``, the WHOLE K client dim rides in
    the block (the packed payload is 4-16x smaller than fp32, so modest
    K tiles fit VMEM);
  * fleet cohorts — grid ``(C/bc, K/bk)`` with K innermost: each step
    folds a ``bk``-client tile into the fp32 output block resident in
    VMEM across the K walk (the ``_dequant_agg_kernel`` idiom), so the
    working set is bounded by ``bk`` and throughput is flat in K.
    ``pick_block_k`` sizes ``bk`` from a VMEM budget.

Both kernels accumulate clients STRICTLY SEQUENTIALLY (k=0..K-1): fp
addition is non-associative, so the tiled walk is bit-identical to
itself for EVERY ``bk`` — tiling the cohort never changes the result.
Production calls always take the tiled program (one tile when the
cohort fits); the whole-K kernel stays as the independently-shaped
numerics oracle (``whole_k=True``), cross-checked at tolerance — the
backend's FMA instruction selection differs ~1 ulp between the two
program shapes, so cross-PROGRAM bit identity is not promised, only
cross-``bk`` bit identity within the tiled program.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

Array = jax.Array

# VMEM working-set budget for auto-picked client tiles (~half a v5e
# core's 16 MiB VMEM, leaving room for double buffering)
VMEM_BUDGET_BYTES = 8 * 1024 * 1024


def pick_block_k(k: int, nw: int, bits: int, block_c: int = 8,
                 vmem_bytes: int = VMEM_BUDGET_BYTES) -> int:
    """Largest pow2 client tile whose per-step working set — the packed
    ``(bk, bc, Nw)`` tile plus the fp32 unpack/contribution intermediates
    and the resident ``(bc, N)`` output block — fits the VMEM budget."""
    per = 32 // bits
    n = nw * per
    per_client = block_c * (nw * 4 + 2 * n * 4)
    out_bytes = block_c * n * 4
    bk = max(1, (vmem_bytes - out_bytes) // max(per_client, 1))
    bk = 1 << (int(bk).bit_length() - 1)
    return int(min(bk, max(int(k), 1)))


def _seq_fold(acc, words, scale, zp, w, bits: int):
    """Fold a (kb, bc, Nw) packed tile into the (bc, N) accumulator,
    one client at a time in index order (see module docstring: the
    sequential order is the bit-parity contract across tile sizes)."""
    per = 32 // bits
    shifts = (jax.lax.broadcasted_iota(
        jnp.uint32, (*words.shape, per), 3) * jnp.uint32(bits))
    msk = jnp.uint32((1 << bits) - 1)
    lv = ((words[..., None] >> shifts) & msk).astype(jnp.float32)
    lv = lv.reshape(*words.shape[:2], words.shape[2] * per)  # (kb, bc, N)
    contrib = w[..., None] * ((lv - zp) * scale)   # sidecars (kb, bc, 1)
    for i in range(words.shape[0]):
        acc = acc + contrib[i]
    return acc


def _dequant_agg_kernel(packed_ref, scale_ref, zp_ref, w_ref, nv_ref,
                        out_ref, *, bits: int):
    k = pl.program_id(1)
    per = 32 // bits
    words = packed_ref[0]                                  # (bc, Nw) uint32
    shifts = (jax.lax.broadcasted_iota(
        jnp.uint32, (*words.shape, per), 2) * jnp.uint32(bits))
    mask = jnp.uint32((1 << bits) - 1)
    lv = ((words[..., None] >> shifts) & mask).astype(jnp.float32)
    lv = lv.reshape(words.shape[0], words.shape[1] * per)  # (bc, N)
    scale = scale_ref[0]                                   # (bc, 1)
    zp = zp_ref[0]
    w = w_ref[0, 0]
    nv = nv_ref[...]                                       # (bc, 1) int32
    col = jax.lax.broadcasted_iota(jnp.int32, lv.shape, 1)
    contrib = jnp.where(col < nv, w * (lv - zp) * scale, 0.0)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = contrib

    @pl.when(k > 0)
    def _acc():
        out_ref[...] += contrib


def _dequant_agg_rows_kernel(packed_ref, scale_ref, zp_ref, w_ref, nv_ref,
                             out_ref, *, bits: int):
    """Flat-tree small-cohort variant: the WHOLE K client dim rides in
    the block and the grid walks channel blocks only — one launch, one
    output pass. The bit-parity oracle for the K-tiled walk below."""
    acc = _seq_fold(jnp.zeros(out_ref.shape, jnp.float32),
                    packed_ref[...], scale_ref[...], zp_ref[...],
                    w_ref[...], bits)
    nv = nv_ref[...]                                 # (bc, 1) int32
    col = jax.lax.broadcasted_iota(jnp.int32, acc.shape, 1)
    out_ref[...] = jnp.where(col < nv, acc, 0.0)


def _dequant_agg_rows_ktiled_kernel(packed_ref, scale_ref, zp_ref, w_ref,
                                    nv_ref, out_ref, *, bits: int):
    """Fleet-cohort variant: grid (C/bc, K/bk), K innermost. The fp32
    output block stays resident in VMEM across the K walk; each step
    folds a bk-client tile into it. Row tails accumulate the same
    garbage as the whole-K kernel and are masked once on the last tile,
    so the result is bit-identical to ``_dequant_agg_rows_kernel``."""
    kt = pl.program_id(1)
    nt = pl.num_programs(1)

    @pl.when(kt == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    acc = _seq_fold(out_ref[...], packed_ref[...], scale_ref[...],
                    zp_ref[...], w_ref[...], bits)

    @pl.when(kt < nt - 1)
    def _carry():
        out_ref[...] = acc

    @pl.when(kt == nt - 1)
    def _final():
        nv = nv_ref[...]
        col = jax.lax.broadcasted_iota(jnp.int32, acc.shape, 1)
        out_ref[...] = jnp.where(col < nv, acc, 0.0)


def _pad_rows(packed, scale, zp, n_valid, c_pad: int):
    """Transparent C-padding: zero word rows with n_valid=0 (and scale 0)
    aggregate to exact zero and are sliced off by the caller."""
    packed = jnp.pad(packed, ((0, 0), (0, c_pad), (0, 0)))
    scale = jnp.pad(scale, ((0, 0), (0, c_pad)))
    zp = jnp.pad(zp, ((0, 0), (0, c_pad)))
    n_valid = jnp.pad(n_valid, (0, c_pad))
    return packed, scale, zp, n_valid


def dequant_agg_rows_pallas(packed: Array, scale: Array, zp: Array,
                            weights: Array, n_valid: Array, bits: int, *,
                            block_c: int = 8,
                            block_k: int | None = None,
                            whole_k: bool = False,
                            interpret: bool = False) -> Array:
    """packed (K, C, Nw) uint32; scale/zp (K, C); weights (K,);
    n_valid (C,) per-row true lengths. One launch aggregates the whole
    flat-tree cohort; tails past each row's length are exact zeros.
    Arbitrary C is padded transparently to ``block_c``. ``block_k``
    (default: VMEM-budget auto-pick) sizes the K tile; small cohorts
    ride in ONE tile (grid (C/bc, 1) — the whole-K fast path, identical
    work to the single-pass oracle kernel). ``whole_k=True`` forces the
    original whole-K kernel program — the numerics oracle the tiled
    walk is cross-checked against in tests (tolerance-level: backend
    FMA instruction selection differs ~1 ulp between the two program
    shapes; the tiled kernel itself is bit-identical across every
    ``bk``). Returns (C, N) fp32."""
    k, c, nw = packed.shape
    per = 32 // bits
    n = nw * per
    nv = jnp.asarray(n_valid, jnp.int32).reshape(c)
    c_pad = (-c) % block_c
    if c_pad:
        packed, scale, zp, nv = _pad_rows(packed, scale, zp, nv, c_pad)
    cq = c + c_pad
    nv = nv.reshape(cq, 1)
    bk = pick_block_k(k, nw, bits, block_c) if block_k is None \
        else int(block_k)
    if whole_k:
        out = pl.pallas_call(
            functools.partial(_dequant_agg_rows_kernel, bits=bits),
            grid=(cq // block_c,),
            in_specs=[
                pl.BlockSpec((k, block_c, nw), lambda i: (0, i, 0)),
                pl.BlockSpec((k, block_c, 1), lambda i: (0, i, 0)),
                pl.BlockSpec((k, block_c, 1), lambda i: (0, i, 0)),
                pl.BlockSpec((k, 1), lambda i: (0, 0)),
                pl.BlockSpec((block_c, 1), lambda i: (i, 0)),
            ],
            out_specs=pl.BlockSpec((block_c, n), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((cq, n), jnp.float32),
            interpret=interpret,
        )(packed, scale[..., None], zp[..., None], weights[:, None], nv)
        return out[:c]
    bk = min(bk, k)
    k_pad = (-k) % bk
    if k_pad:
        # zero-weight phantom clients (scale 0 -> contribution exactly
        # +0.0) appended AFTER the real fold sequence: bit parity holds
        packed = jnp.pad(packed, ((0, k_pad), (0, 0), (0, 0)))
        scale = jnp.pad(scale, ((0, k_pad), (0, 0)))
        zp = jnp.pad(zp, ((0, k_pad), (0, 0)))
        weights = jnp.pad(weights, (0, k_pad))
    kq = k + k_pad
    out = pl.pallas_call(
        functools.partial(_dequant_agg_rows_ktiled_kernel, bits=bits),
        grid=(cq // block_c, kq // bk),          # K innermost: the out
        in_specs=[                               # block accumulates
            pl.BlockSpec((bk, block_c, nw), lambda i, t: (t, i, 0)),
            pl.BlockSpec((bk, block_c, 1), lambda i, t: (t, i, 0)),
            pl.BlockSpec((bk, block_c, 1), lambda i, t: (t, i, 0)),
            pl.BlockSpec((bk, 1), lambda i, t: (t, 0)),
            pl.BlockSpec((block_c, 1), lambda i, t: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_c, n), lambda i, t: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((cq, n), jnp.float32),
        interpret=interpret,
    )(packed, scale[..., None], zp[..., None], weights[:, None], nv)
    return out[:c]


def dequant_agg_pallas(packed: Array, scale: Array, zp: Array,
                       weights: Array, bits: int, *,
                       n_valid: int | Array | None = None,
                       block_c: int = 8,
                       interpret: bool = False) -> Array:
    """packed (K, C, Nw) uint32; scale/zp (K, C); weights (K,).

    ``n_valid`` (scalar or (C,) vector, default N) zeroes each row's
    tail past its true length — shared by all K clients, since the row
    layout is a property of the message structure, not the sender.
    Arbitrary C is padded transparently to ``block_c``.

    Returns (C, N) fp32 weighted sum of dequantized messages."""
    k, c, nw = packed.shape
    per = 32 // bits
    n = nw * per
    if n_valid is None:
        n_valid = n
    if isinstance(n_valid, (int, np.integer)):
        nv = jnp.full((c,), n_valid, jnp.int32)
    else:
        nv = jnp.asarray(n_valid, jnp.int32).reshape(c)
    c_pad = (-c) % block_c
    if c_pad:
        packed, scale, zp, nv = _pad_rows(packed, scale, zp, nv, c_pad)
    cq = c + c_pad
    nv = nv.reshape(cq, 1)
    grid = (cq // block_c, k)
    out = pl.pallas_call(
        functools.partial(_dequant_agg_kernel, bits=bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_c, nw), lambda i, kk: (kk, i, 0)),
            pl.BlockSpec((1, block_c, 1), lambda i, kk: (kk, i, 0)),
            pl.BlockSpec((1, block_c, 1), lambda i, kk: (kk, i, 0)),
            pl.BlockSpec((1, 1), lambda i, kk: (kk, 0)),
            pl.BlockSpec((block_c, 1), lambda i, kk: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_c, n), lambda i, kk: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((cq, n), jnp.float32),
        interpret=interpret,
    )(packed, scale[..., None], zp[..., None], weights[:, None], nv)
    return out[:c]
