"""llama4-maverick-400b-a17b [moe]: 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048; MoE 128 experts top-1 + 1 shared expert,
interleaved dense/MoE every other layer [hf:meta-llama/Llama-4-Maverick]."""
from repro.core.lora import LoRAConfig
from repro.models.lm import LMConfig
from repro.models.moe import MoESpec


def full() -> LMConfig:
    return LMConfig(
        name="llama4-maverick-400b-a17b", n_layers=48, d_model=5120,
        n_heads=40, n_kv_heads=8, head_dim=128, d_ff=8192, vocab=202048,
        mlp_kind="swiglu", rope_base=5e5,
        moe=MoESpec(d_model=5120, d_ff=8192, n_experts=128, top_k=1,
                    n_shared=1, mlp_kind="swiglu"),
        moe_every=2,
        pad_heads_to=48,              # 40 -> 48 so heads shard 16-way
        lora=LoRAConfig(rank=32, alpha=512.0), head_mode="lora")


def smoke() -> LMConfig:
    return LMConfig(
        name="llama4-maverick-smoke", n_layers=4, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab=512,
        mlp_kind="swiglu",
        moe=MoESpec(d_model=64, d_ff=128, n_experts=8, top_k=1,
                    n_shared=1, mlp_kind="swiglu"),
        moe_every=2,
        lora=LoRAConfig(rank=4, alpha=64.0), head_mode="lora")
