"""Sparse-delta wire format (FLASC-style top-k over the packed codec).

Tentpole acceptance contract:
  * per-tensor magnitude top-k keeps the largest-|x| entries; density
    1.0 is the byte-exact DENSE fallback (PackedLeaf path);
  * measured sparse wire bytes (real serialized buffers, index AND
    bitmap encodings) == the static ``sparse_leaf_wire_bytes``
    accounting for fp and 2/4/8-bit survivors;
  * a 4-bit, 10%-density uplink of the quickstart (ResNet-8 rank-32)
    model measures < 0.15x the fp32 message;
  * scatter-add aggregation (FedAvg + FedBuff, rank-bucketed included)
    == the densified weighted-mean reference;
  * error feedback absorbs the top-k-dropped mass, and a sparse+EF run
    at density=1.0 matches the dense-EF reference exactly;
  * codec degenerate cases (constant channels, negative-only channels,
    ``per_stack`` stacked tensors, sparse leaves) round-trip BIT-EXACTLY
    through pack_message -> to_wire -> from_wire -> unpack_message.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:         # optional dep: property tests skip, rest runs
    given = settings = st = None

from repro.core import aggregation, flocora, lora, messages, sparse
from repro.core.aggregation import ErrorFeedbackFedAvg, FedAvgAggregator, \
    FedBuffAggregator
from repro.core.flocora import FLoCoRAConfig, RankSchedule
from repro.core.lora import LoRAConfig
from repro.core.quant import QuantConfig
from repro.core.sparse import SparseLeaf, SparsityConfig
from repro.fl import AsyncConfig, AsyncFLServer, ClientConfig, FLServer, \
    FleetTrace, LognormalLatency, ServerConfig


def _tree(key, scale=1.0):
    ks = jax.random.split(key, 4)
    return {"a": jax.random.normal(ks[0], (6, 8)) * scale,
            "b": jax.random.normal(ks[1], (4, 3, 5)) * scale,
            "odd": jax.random.normal(ks[2], (7, 3)) * scale,
            "norm": jax.random.normal(ks[3], (7,)) * scale}


# ---------------------------------------------------------------------------
# SparsityConfig
# ---------------------------------------------------------------------------

def test_sparsity_config_validation_and_annealing():
    with pytest.raises(ValueError):
        SparsityConfig(density=0.0)
    with pytest.raises(ValueError):
        SparsityConfig(density=1.5)
    with pytest.raises(ValueError):
        SparsityConfig(anneal_every=-1)
    with pytest.raises(ValueError):
        SparsityConfig(anneal_factor=0.0)
    assert not SparsityConfig(density=1.0).enabled
    assert SparsityConfig(density=0.5).enabled
    assert SparsityConfig(density=1.0, anneal_every=2).enabled
    s = SparsityConfig(density=0.4, anneal_every=2, anneal_factor=0.5,
                       min_density=0.05, require_ef=False)
    assert s.density_at(0) == 0.4
    assert s.density_at(1) == 0.4
    assert s.density_at(2) == pytest.approx(0.2)
    assert s.density_at(4) == pytest.approx(0.1)
    assert s.density_at(40) == pytest.approx(0.05)    # floored
    # the floor binds annealed shrinkage only: a base density below
    # min_density is honored as-is (mirrors RankSchedule.rank_for)
    lo = SparsityConfig(density=0.005, anneal_every=5, require_ef=False)
    assert lo.density_at(0) == 0.005


def test_sparsity_requires_ef_at_config_time():
    """FLASC keeps accuracy only with EF: require_ef=True (the default)
    refuses a config without error feedback."""
    with pytest.raises(ValueError, match="require_ef"):
        FLoCoRAConfig(quant_bits=4, sparsity=SparsityConfig(density=0.1))
    # explicit opt-out runs sparse without EF
    cfg = FLoCoRAConfig(quant_bits=4,
                        sparsity=SparsityConfig(density=0.1,
                                                require_ef=False))
    assert cfg.uplink_density(0) == 0.1
    # density=1.0 never sparsifies, so EF is not forced
    cfg1 = FLoCoRAConfig(quant_bits=4, sparsity=SparsityConfig())
    assert cfg1.uplink_density(0) is None


# ---------------------------------------------------------------------------
# top-k selection + the dense fallback
# ---------------------------------------------------------------------------

def test_topk_keeps_largest_magnitude():
    x = jnp.asarray([[0.1, -5.0, 0.2, 3.0], [0.0, -0.3, 4.0, 0.05]])
    leaf = sparse.sparsify_leaf(x, density=3 / 8, bits=None)
    assert leaf.k == 3
    dense = np.asarray(leaf.densify())
    ref = np.zeros((2, 4), np.float32)
    ref[0, 1], ref[0, 3], ref[1, 2] = -5.0, 3.0, 4.0   # top-3 by |x|
    np.testing.assert_array_equal(dense, ref)
    # ascending flat indices (bitmap-compatible order)
    idx = np.asarray(leaf.idx)
    assert (np.diff(idx) > 0).all()


def test_density_one_is_byte_exact_dense_fallback():
    t = _tree(jax.random.PRNGKey(0))
    cfg = QuantConfig(bits=4)
    dense = messages.pack_message(t, cfg)
    via_sparse = messages.pack_message(t, cfg, density=1.0)
    for k in ("a", "b", "odd"):
        assert messages.is_packed_leaf(via_sparse[k])
        np.testing.assert_array_equal(np.asarray(dense[k].payload),
                                      np.asarray(via_sparse[k].payload))
    assert messages.message_wire_bytes(t, cfg, 1.0) == \
        messages.message_wire_bytes(t, cfg)


def test_keep_count_floor():
    assert sparse.keep_count(1000, 0.1) == 100
    assert sparse.keep_count(3, 0.01) == 1          # never zero survivors
    assert sparse.keep_count(7, 1.0) == 7


# ---------------------------------------------------------------------------
# wire bytes: measured == static, index/bitmap crossover
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [None, 2, 4, 8])
@pytest.mark.parametrize("density", [0.02, 0.1, 0.5])
def test_sparse_wire_bytes_match_static(bits, density):
    t = _tree(jax.random.PRNGKey(2))
    cfg = QuantConfig(bits=bits)
    msg = messages.pack_message(t, cfg, density=density)
    assert messages.packed_wire_bytes(msg) == \
        messages.message_wire_bytes(t, cfg, density)
    # per-leaf measured == per-leaf static
    for k in ("a", "b", "odd"):
        leaf = msg[k]
        assert isinstance(leaf, SparseLeaf)
        assert leaf.wire_bytes() == sparse.sparse_leaf_wire_bytes(
            leaf.shape, bits, density)
    # 1-D leaves travel dense fp
    assert not isinstance(msg["norm"], SparseLeaf)


def test_index_bitmap_crossover():
    """The serializer picks uint32 indices below ~1/32 density and the
    n-bit bitmap above, matching the min() in the static accounting."""
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 64))   # n = 4096
    lo = sparse.sparsify_leaf(x, 0.01, 4)     # 4k=164 < n/8=512 -> idx
    hi = sparse.sparsify_leaf(x, 0.5, 4)      # 4k=8192 > 512 -> bitmap
    assert "idx" in lo.to_wire() and "bitmap" not in lo.to_wire()
    assert "bitmap" in hi.to_wire() and "idx" not in hi.to_wire()
    for leaf in (lo, hi):
        assert leaf.wire_bytes() == sparse.sparse_leaf_wire_bytes(
            leaf.shape, 4, leaf.density)


def test_quickstart_model_4bit_10pct_under_15pct_of_fp32():
    """ACCEPTANCE: measured packed_wire_bytes of a 4-bit, 10%-density
    uplink < 0.15x the fp32 message for the quickstart model."""
    from repro.models.resnet import ResNetConfig, init as rinit
    cfg = ResNetConfig(arch="resnet8",
                       lora=LoRAConfig(rank=32, alpha=512.0))
    train = rinit(jax.random.PRNGKey(0), cfg)["train"]
    fp = messages.message_wire_bytes(train, QuantConfig())
    msg = messages.pack_message(train, QuantConfig(bits=4), density=0.1)
    meas = messages.packed_wire_bytes(msg)
    assert meas == messages.message_wire_bytes(train, QuantConfig(bits=4),
                                               0.1)
    assert meas < 0.15 * fp, (meas, fp)


# ---------------------------------------------------------------------------
# serialization round-trips (incl. the degenerate codec cases)
# ---------------------------------------------------------------------------

def _assert_wire_roundtrip_bit_exact(t, cfg, density=None):
    """pack -> to_wire -> from_wire -> unpack must reproduce the direct
    unpack BIT-exactly, and measured bytes must match the accounting."""
    msg = messages.pack_message(t, cfg, density=density)
    wire = messages.message_to_wire(msg)
    back = messages.message_from_wire(wire, msg)
    direct = messages.unpack_message(msg)
    rebuilt = messages.unpack_message(back)
    for a, b in zip(jax.tree.leaves(direct), jax.tree.leaves(rebuilt)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert messages.packed_wire_bytes(msg) == \
        messages.message_wire_bytes(t, cfg, density)


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("density", [None, 0.25])
def test_codec_degenerate_constant_and_negative_channels(bits, density):
    t = {"const": jnp.full((4, 32), 1.7),
         "zeros": jnp.zeros((3, 16)),
         "neg": -jnp.abs(jax.random.normal(jax.random.PRNGKey(0),
                                           (5, 24))) - 0.5,
         "norm": jnp.linspace(-1.0, 1.0, 9)}
    _assert_wire_roundtrip_bit_exact(t, QuantConfig(bits=bits), density)


@pytest.mark.parametrize("density", [None, 0.2])
def test_codec_degenerate_per_stack(density):
    t = {"stacked": jax.random.normal(jax.random.PRNGKey(3), (3, 4, 6)),
         "deep": jax.random.normal(jax.random.PRNGKey(4), (2, 2, 5, 7))}
    _assert_wire_roundtrip_bit_exact(t, QuantConfig(bits=4, per_stack=True),
                                     density)


def test_codec_sparse_fp_survivors_roundtrip():
    """Sparse without quantization: fp32 survivors + indices."""
    t = _tree(jax.random.PRNGKey(5))
    _assert_wire_roundtrip_bit_exact(t, QuantConfig(), 0.15)
    msg = messages.pack_message(t, QuantConfig(), density=0.15)
    # fp survivors reconstruct EXACTLY at the kept positions
    dense = np.asarray(messages.unpack_message(msg)["a"])
    orig = np.asarray(t["a"])
    kept = np.flatnonzero(dense.ravel())
    np.testing.assert_array_equal(dense.ravel()[kept],
                                  orig.ravel()[kept])


def test_sparse_leaf_from_wire_rebuilds_payload_bit_exact():
    x = jax.random.normal(jax.random.PRNGKey(6), (16, 48))
    for density in (0.02, 0.4):            # index and bitmap encodings
        leaf = sparse.sparsify_leaf(x, density, 4)
        back = SparseLeaf.from_wire(leaf.to_wire(), leaf.shape,
                                    leaf.dtype, leaf.bits, density)
        np.testing.assert_array_equal(np.asarray(back.idx),
                                      np.asarray(leaf.idx))
        np.testing.assert_array_equal(np.asarray(back.payload),
                                      np.asarray(leaf.payload))


def test_wire_header_v3_carries_density():
    t = _tree(jax.random.PRNGKey(7))
    msg = messages.pack_message(t, QuantConfig(bits=4), density=0.1)
    name, bufs = messages.message_to_wire(msg)[0]
    assert name == messages.HEADER_KEY
    assert bufs["header"].nbytes == messages.HEADER_BYTES == 20
    hdr = messages.parse_wire_header(bufs["header"])
    assert hdr["version"] == 3 and hdr["bits"] == 4
    assert hdr["density"] == pytest.approx(0.1)
    # dense message advertises density 1.0
    dense_hdr = messages.parse_wire_header(messages.message_to_wire(
        messages.pack_message(t, QuantConfig(bits=4)))[0][1]["header"])
    assert dense_hdr["density"] == 1.0
    # a 16-byte v2 header (no density word) still parses
    v2 = np.asarray([messages.WIRE_MAGIC, 2, 8, 4], np.uint32)
    got = messages.parse_wire_header(v2)
    assert got == {"version": 2, "rank": 8, "bits": 4, "density": 1.0}


if st is not None:
    @settings(max_examples=40, deadline=None)
    @given(bits=st.sampled_from([None, 2, 4, 8]),
           rows=st.integers(2, 12), cols=st.integers(2, 40),
           density=st.floats(0.01, 1.0), seed=st.integers(0, 2**31 - 1))
    def test_property_sparse_accounting_and_roundtrip(bits, rows, cols,
                                                      density, seed):
        """Property: for any shape/density/bits, measured wire bytes ==
        static accounting and serialization round-trips bit-exactly."""
        rng = np.random.default_rng(seed)
        t = {"w": jnp.asarray(rng.normal(size=(rows, cols)), jnp.float32)}
        _assert_wire_roundtrip_bit_exact(t, QuantConfig(bits=bits),
                                         density)
else:
    def test_property_sparse_accounting_and_roundtrip():
        pytest.skip("hypothesis not installed")


# ---------------------------------------------------------------------------
# scatter-add aggregation
# ---------------------------------------------------------------------------

def test_scatter_add_fedavg_matches_densified_reference():
    qcfg = QuantConfig(bits=4)
    trees = [_tree(jax.random.PRNGKey(i)) for i in range(5)]
    w = jnp.asarray([1.0, 2.0, 3.0, 1.5, 0.5])
    msgs = [messages.pack_message(t, qcfg, density=0.2) for t in trees]
    got = FedAvgAggregator(qcfg).aggregate(msgs, w)
    wn = np.asarray(w) / float(np.sum(np.asarray(w)))
    for k in trees[0]:
        ref = sum(wn[i] * np.asarray(messages.unpack_message(msgs[i])[k])
                  for i in range(5))
        np.testing.assert_allclose(np.asarray(got[k]), ref,
                                   rtol=1e-5, atol=1e-5)


def test_scatter_add_mixed_rank_buckets():
    """Sparse uplinks at mixed adapter ranks route through the
    rank-bucketed path and zero-pad like the dense packed wire."""
    def pair_tree(seed, rank):
        k = jax.random.PRNGKey(seed)
        ad = lora.dense_lora_init(k, 16, 12,
                                  LoRAConfig(rank=rank, alpha=16.0 * rank))
        b = jax.random.normal(jax.random.fold_in(k, 1), ad["b"].shape)
        return {"lin": {"a": ad["a"], "b": b * 0.1}}

    qcfg = QuantConfig(bits=8)
    ranks = (4, 8, 8, 16)
    trees = [pair_tree(i, r) for i, r in enumerate(ranks)]
    w = jnp.asarray([1.0, 2.0, 1.0, 0.5])
    msgs = [messages.pack_message(t, qcfg, density=0.25) for t in trees]
    assert lora.tree_max_rank(msgs[0]) == 4     # shape-only detection
    got = FedAvgAggregator(qcfg, r_target=16).aggregate(msgs, w)
    assert lora.tree_ranks(got) == (16,)
    padded = [lora.resize_tree_rank(messages.unpack_message(m), 16)
              for m in msgs]
    ref = aggregation.fedavg(aggregation.stack_trees(padded), w)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


def test_scatter_add_mixed_density_buffer():
    """A FedBuff buffer spanning a density-annealing boundary mixes
    dense-packed and sparse leaves at the same position; the scatter
    branch must aggregate both against the densified reference."""
    qcfg = QuantConfig(bits=8)
    trees = [_tree(jax.random.PRNGKey(i)) for i in range(3)]
    w = jnp.asarray([1.0, 2.0, 1.5])
    msgs = [messages.pack_message(trees[0], qcfg),             # dense
            messages.pack_message(trees[1], qcfg, density=0.3),
            messages.pack_message(trees[2], qcfg, density=0.1)]
    got = aggregation.fedavg_packed(msgs, w)
    wn = np.asarray(w) / float(np.sum(np.asarray(w)))
    for k in trees[0]:
        ref = sum(wn[i] * np.asarray(messages.unpack_message(msgs[i])[k])
                  for i in range(3))
        np.testing.assert_allclose(np.asarray(got[k]), ref,
                                   rtol=1e-5, atol=1e-5)


def test_fedbuff_mixed_fp_and_sparse_buffer_order_safe():
    """Quant off + density annealing crossing 1.0: the buffer holds a
    RAW fp tree and sparse messages. Flushing must not depend on which
    arrived first (routing keys off ANY wire-form message)."""
    qcfg = QuantConfig()
    trees = [_tree(jax.random.PRNGKey(i)) for i in range(3)]
    fp_msg = trees[0]                                     # density 1.0
    sp_msgs = [messages.pack_message(t, qcfg, density=0.3)
               for t in trees[1:]]
    for order in ([fp_msg] + sp_msgs, sp_msgs + [fp_msg]):
        agg = FedBuffAggregator(half_life=4.0)
        for m in order:
            agg.add(m, n_k=1.0, staleness=0.0)
        got = agg.flush()
        for k in trees[0]:
            ref = (np.asarray(fp_msg[k], np.float32) + sum(
                np.asarray(messages.unpack_message(m)[k], np.float32)
                for m in sp_msgs)) / 3.0
            np.testing.assert_allclose(np.asarray(got[k]), ref,
                                       rtol=1e-5, atol=1e-5)


def test_rank_for_floor_binds_anneal_only():
    """REGRESSION (review): with annealing on, a configured base rank
    BELOW min_rank must stay honored as-is — the floor is
    min(min_rank, base), never a raise above the validated base."""
    s = RankSchedule(client_ranks=(1, 8), anneal_every=4, min_rank=2)
    assert s.rank_for(0, 0) == 1
    assert s.rank_for(0, 100) == 1
    assert s.rank_for(1, 0) == 8
    assert s.rank_for(1, 8) == 2          # 8 * 0.5^2, floored at 2
    assert s.rank_for(1, 100) == 2


def test_fedbuff_sparse_add_flush():
    qcfg = QuantConfig(bits=4)
    trees = [_tree(jax.random.PRNGKey(i)) for i in range(3)]
    msgs = [messages.pack_message(t, qcfg, density=0.3) for t in trees]
    agg = FedBuffAggregator(half_life=4.0)
    for i, m in enumerate(msgs):
        agg.add(m, n_k=10.0, staleness=float(i))
    got = agg.flush()
    wts = np.asarray([10.0 * 2.0 ** (-i / 4.0) for i in range(3)])
    wn = wts / wts.sum()
    for k in trees[0]:
        ref = sum(wn[i] * np.asarray(messages.unpack_message(msgs[i])[k])
                  for i in range(3))
        np.testing.assert_allclose(np.asarray(got[k]), ref,
                                   rtol=1e-5, atol=1e-5)
    assert not agg.pending


# ---------------------------------------------------------------------------
# error feedback over the sparse wire
# ---------------------------------------------------------------------------

def test_ef_sparse_residual_absorbs_dropped_mass():
    """e' = (x+e) - deq(msg): zero reconstruction at dropped positions
    means the residual carries the FULL dropped values."""
    qcfg = QuantConfig(bits=8)
    x = {"w": jax.random.normal(jax.random.PRNGKey(0), (8, 16))}
    res0 = aggregation.ef_init(x)
    msg, res = aggregation.ef_encode_packed(x, res0, qcfg, density=0.25)
    recon = np.asarray(messages.unpack_message(msg)["w"])
    np.testing.assert_allclose(np.asarray(res["w"]),
                               np.asarray(x["w"]) - recon, atol=1e-6)
    dropped = recon.ravel() == 0.0
    np.testing.assert_allclose(np.asarray(res["w"]).ravel()[dropped],
                               np.asarray(x["w"]).ravel()[dropped],
                               atol=1e-6)


def test_ef_sparse_uplink_unbiased_in_time():
    """Time-averaged sparse+EF reconstruction converges to x (every
    position eventually ships), unlike EF-free top-k which never sends
    the small entries."""
    cfg = FLoCoRAConfig(quant_bits=8, error_feedback=True,
                        sparsity=SparsityConfig(density=0.25))
    x = {"w": jax.random.normal(jax.random.PRNGKey(0), (6, 16)) * 0.7}
    res, acc = None, jnp.zeros_like(x["w"])
    n = 16
    for _ in range(n):
        msg, res = flocora.client_uplink(x, cfg, res)
        acc = acc + messages.unpack_message(msg)["w"]
    bias_ef = float(jnp.mean(jnp.abs(acc / n - x["w"])))
    no_ef = messages.unpack_message(
        messages.pack_message(x, cfg.qcfg, density=0.25))["w"]
    bias_topk = float(jnp.mean(jnp.abs(no_ef - x["w"])))
    assert bias_ef < 0.5 * bias_topk, (bias_ef, bias_topk)


# ---------------------------------------------------------------------------
# FL engine end-to-end
# ---------------------------------------------------------------------------

def _tiny_setup(n=96, n_clients=4, seed=0):
    rng = np.random.default_rng(seed)
    w_true = rng.normal(size=(16, 10)).astype(np.float32)
    x = rng.normal(size=(n, 16)).astype(np.float32)
    y = np.argmax(x @ w_true + 0.1 * rng.normal(size=(n, 10)), axis=1)
    parts = np.array_split(rng.permutation(n), n_clients)
    data = [{"x": x[p], "y": y[p].astype(np.int32)} for p in parts]
    model = {"frozen": {"mu": jnp.zeros((16,))},
             "train": {"w": jnp.asarray(0.01 * rng.normal(size=(16, 10)),
                                        jnp.float32),
                       "b": jnp.zeros((10,), jnp.float32)}}
    return data, model


def _tiny_loss(frozen, train, batch):
    logits = (batch["x"] - frozen["mu"]) @ train["w"] + train["b"]
    logp = jax.nn.log_softmax(logits)
    loss = -jnp.mean(jnp.take_along_axis(logp, batch["y"][:, None],
                                         axis=1))
    return loss, {}


def _tiny_server(data, model, fcfg, rounds=3):
    return FLServer(
        model, _tiny_loss, data,
        ServerConfig(rounds=rounds, n_clients=len(data),
                     clients_per_round=2),
        ClientConfig(local_epochs=1, batch_size=8, lr=0.1), fcfg)


def test_server_sparse_round_accounting():
    """Sparse uplinks: measured up_bytes == static sparse accounting,
    downlinks stay dense, density lands in the history record."""
    data, model = _tiny_setup()
    fcfg = FLoCoRAConfig(rank=8, alpha=128.0, quant_bits=4,
                         error_feedback=True,
                         sparsity=SparsityConfig(density=0.2))
    srv = _tiny_server(data, model, fcfg)
    hist = srv.run(3)
    expect_up = messages.message_wire_bytes(model["train"], fcfg.qcfg, 0.2)
    expect_down = messages.message_wire_bytes(model["train"], fcfg.qcfg)
    for h in hist:
        assert h["up_bytes_measured"] == expect_up
        assert h["uplink_density"] == 0.2
        assert h["up_bytes"] == 2 * expect_up       # 2 kept clients
        assert h["down_bytes"] == 2 * expect_down
    assert np.isfinite(hist[-1]["client_loss"])
    assert expect_up < expect_down


def test_server_density_annealing_changes_uplink_bytes():
    data, model = _tiny_setup()
    fcfg = FLoCoRAConfig(rank=8, alpha=128.0, quant_bits=8,
                         error_feedback=True,
                         sparsity=SparsityConfig(density=0.8,
                                                 anneal_every=2,
                                                 anneal_factor=0.25))
    srv = _tiny_server(data, model, fcfg, rounds=4)
    hist = srv.run(4)
    assert hist[0]["uplink_density"] == 0.8
    assert hist[2]["uplink_density"] == pytest.approx(0.2)
    assert hist[2]["up_bytes_measured"] < hist[0]["up_bytes_measured"]


def test_sparse_ef_density_one_matches_dense_ef_run():
    """ACCEPTANCE (exact-parity fallback): a sparse+EF run at
    density=1.0 aggregates IDENTICALLY to the dense-EF reference."""
    data, model = _tiny_setup()
    dense = _tiny_server(data, model,
                         FLoCoRAConfig(rank=8, alpha=128.0, quant_bits=4,
                                       error_feedback=True))
    sparse1 = _tiny_server(data, model,
                           FLoCoRAConfig(rank=8, alpha=128.0, quant_bits=4,
                                         error_feedback=True,
                                         sparsity=SparsityConfig(
                                             density=1.0)))
    dense.run(3)
    sparse1.run(3)
    for a, b in zip(jax.tree.leaves(jax.device_get(dense.global_train)),
                    jax.tree.leaves(jax.device_get(sparse1.global_train))):
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-7)


def test_async_engine_sparse_uplinks():
    """The async engine ships sparse uplinks (require_ef=False) and
    accounts the measured sparse bytes."""
    rng = np.random.default_rng(0)
    data, model = _tiny_setup(n=120, n_clients=6)
    fcfg = FLoCoRAConfig(rank=8, alpha=128.0, quant_bits=8,
                         sparsity=SparsityConfig(density=0.2,
                                                 require_ef=False))
    trace = FleetTrace(seed=0, latency=LognormalLatency(
        compute_median_s=10.0, network_mbps=20.0))
    srv = AsyncFLServer(model, _tiny_loss, data,
                        AsyncConfig(total_arrivals=8, concurrency=3,
                                    buffer_size=4, seed=0),
                        ClientConfig(local_epochs=1, batch_size=8, lr=0.1),
                        fcfg, trace=trace)
    hist = srv.run()
    assert hist and np.isfinite(hist[-1]["client_loss"])
    up_one = messages.message_wire_bytes(model["train"], fcfg.qcfg, 0.2)
    down_one = messages.message_wire_bytes(model["train"], fcfg.qcfg)
    assert hist[-1]["up_bytes"] == srv.n_arrived * up_one
    assert hist[-1]["down_bytes"] == srv.n_dispatched * down_one


@pytest.mark.slow
def test_sparse_smoke_resnet_system():
    """SPARSE SMOKE (CI job): ResNet-8 fleet over the 4-bit 10%-density
    wire with EF — short rounds, interpret-mode kernels."""
    from repro.data import SyntheticVision, lda_partition
    from repro.models.resnet import ResNetConfig, init as rinit, loss_fn
    rng = np.random.default_rng(0)
    sv = SyntheticVision(seed=0)
    y = rng.integers(0, 10, 200)
    x = sv.sample(rng, y).astype(np.float32)
    parts = lda_partition(y, 4, alpha=0.5, seed=0)
    data = [{"x": x[p], "y": y[p].astype(np.int32)} for p in parts]
    cfg = ResNetConfig(arch="resnet8", lora=LoRAConfig(rank=8,
                                                       alpha=128.0))
    model = rinit(jax.random.PRNGKey(0), cfg)
    fcfg = FLoCoRAConfig(rank=8, alpha=128.0, quant_bits=4,
                         error_feedback=True,
                         sparsity=SparsityConfig(density=0.1))
    srv = FLServer(model, lambda f, t, b: loss_fn(f, t, cfg, b), data,
                   ServerConfig(rounds=2, n_clients=4,
                                clients_per_round=2),
                   ClientConfig(local_epochs=1, batch_size=16, lr=0.05),
                   fcfg)
    hist = srv.run(2)
    assert np.isfinite(hist[-1]["client_loss"])
    fp = messages.message_wire_bytes(model["train"], QuantConfig())
    assert hist[-1]["up_bytes_measured"] < 0.15 * fp
