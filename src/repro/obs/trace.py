"""Span tracer: wall- or virtual-clock timelines, Chrome-trace export.

A :class:`Tracer` records complete spans (``ph: "X"``) and instant
events (``ph: "i"``) onto one in-memory timeline and exports it two
ways:

  * ``export_chrome(path)`` — the Chrome trace-event JSON format
    (load in ``chrome://tracing`` / Perfetto): one ``traceEvents``
    array of ``{name, ph, ts, dur, pid, tid, args}`` records with
    microsecond timestamps;
  * ``export_jsonl(path)`` — one JSON object per line, for grep/pandas.

CLOCKS. ``Tracer(clock=...)`` takes any zero-arg callable returning
SECONDS. The default is ``time.perf_counter`` (wall time). The async
engine and the serving simulator instead pass their VIRTUAL clock
(``lambda: self.clock``), so spans line up on simulated fleet time; and
events whose begin/end the caller already knows in virtual time go
through :meth:`Tracer.event` with explicit ``ts``/``dur`` — e.g. one
dispatch->arrival span per in-flight client update.

Like the metrics registry, a disabled tracer records nothing and costs
one attribute check per call; ``default_tracer()`` is the process-global
instance (disabled until someone opts in) and engines take
``tracer=None`` meaning that default.
"""
from __future__ import annotations

import contextlib
import json
import time
from typing import Any, Callable, Iterator, Optional


class Tracer:
    """In-memory span recorder. ``tid`` groups events into named
    tracks (Chrome renders one row per tid)."""

    def __init__(self, enabled: bool = True,
                 clock: Optional[Callable[[], float]] = None,
                 process: str = "repro"):
        self.enabled = enabled
        self.clock = clock if clock is not None else time.perf_counter
        self.process = process
        self.events: list[dict] = []
        self._tids: dict[str, int] = {}

    def _tid(self, track: str) -> int:
        tid = self._tids.get(track)
        if tid is None:
            tid = len(self._tids)
            self._tids[track] = tid
        return tid

    # -- recording ---------------------------------------------------------
    @contextlib.contextmanager
    def span(self, name: str, track: str = "main",
             **args) -> Iterator[None]:
        """``with tracer.span("fl/aggregate", rank=8): ...`` — a
        complete event from entry to exit on this tracer's clock."""
        if not self.enabled:
            yield
            return
        t0 = self.clock()
        try:
            yield
        finally:
            self.event(name, ts=t0, dur=self.clock() - t0, track=track,
                       **args)

    def event(self, name: str, ts: float, dur: float = 0.0,
              track: str = "main", **args) -> None:
        """An explicitly-timestamped complete span: ``ts``/``dur`` in
        the tracer's clock domain (SECONDS — virtual engines pass their
        own event times here)."""
        if not self.enabled:
            return
        self.events.append({"name": name, "ph": "X",
                            "ts": ts * 1e6, "dur": dur * 1e6,
                            "tid": self._tid(track), "args": args})

    def instant(self, name: str, track: str = "main",
                ts: Optional[float] = None, **args) -> None:
        if not self.enabled:
            return
        t = self.clock() if ts is None else ts
        self.events.append({"name": name, "ph": "i", "ts": t * 1e6,
                            "s": "t", "tid": self._tid(track),
                            "args": args})

    def with_clock(self, clock: Callable[[], float]) -> "Tracer":
        """A view of this tracer on another clock: shares the event
        buffer and reads the enable flag LIVE (enabling the parent
        after the view was made still turns the view on). The async
        engine uses this to put its spans on virtual time without the
        caller wiring a separate tracer."""
        return _TracerView(self, clock)

    # -- export ------------------------------------------------------------
    def chrome_trace(self) -> dict:
        meta = [{"name": "process_name", "ph": "M", "pid": 0, "tid": 0,
                 "args": {"name": self.process}}]
        meta += [{"name": "thread_name", "ph": "M", "pid": 0, "tid": tid,
                  "args": {"name": track}}
                 for track, tid in sorted(self._tids.items(),
                                          key=lambda kv: kv[1])]
        evs = [dict(e, pid=0) for e in self.events]
        return {"traceEvents": meta + evs, "displayTimeUnit": "ms"}

    def export_chrome(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)

    def export_jsonl(self, path: str) -> None:
        inv = {tid: track for track, tid in self._tids.items()}
        with open(path, "w") as f:
            for e in self.events:
                rec = dict(e, track=inv.get(e["tid"], str(e["tid"])))
                f.write(json.dumps(rec) + "\n")

    def reset(self) -> None:
        self.events.clear()
        self._tids.clear()


class _TracerView(Tracer):
    """Same-buffer tracer on a different clock (see ``with_clock``).
    ``enabled``/``events``/``_tids`` delegate to the parent, so the
    view tracks the parent's state live."""

    def __init__(self, parent: Tracer, clock: Callable[[], float]):
        self._parent = parent
        self.clock = clock
        self.process = parent.process

    enabled = property(lambda self: self._parent.enabled)
    events = property(lambda self: self._parent.events)
    _tids = property(lambda self: self._parent._tids)


# -- process-global default (disabled until someone opts in) ---------------
_DEFAULT = Tracer(enabled=False)


def default_tracer() -> Tracer:
    return _DEFAULT


def set_default_tracer(tr: Tracer) -> Tracer:
    global _DEFAULT
    prev, _DEFAULT = _DEFAULT, tr
    return prev


def get_tracer(tr: Optional[Tracer]) -> Tracer:
    """Injection helper mirroring ``metrics.get_registry``."""
    return _DEFAULT if tr is None else tr
