"""Compile watchdog: ONE ``jax.monitoring`` backend-compile listener.

Four copies of the same listener used to live in test_flat_codec.py,
test_serve.py, test_streaming_agg.py and benchmarks/round_throughput.py
— this module registers it once at import and exposes the count three
ways:

  * :func:`compile_count` — the monotonic process total;
  * :class:`count_compiles` — ``with count_compiles() as c: ...;
    c.count`` measurement context (what the tests and the bench use);
  * :class:`CompileWatchdog` — an ENFORCING context: raises
    :class:`CompileBudgetExceeded` when the block compiles more than
    ``max_compiles`` programs. The serving engine
    (``AdapterServingEngine(strict_compiles=True)``) and the streaming
    accumulator (``StreamingFlatAccumulator(strict_compiles=True)``)
    wrap their steady-state paths in a zero-budget watchdog, so the
    zero-steady-state-compile invariant is a runtime guarantee, not
    just a test assertion.

Every compile also feeds the default metrics registry when it is
enabled (``jax.backend_compiles`` counter, ``jax.backend_compile_secs``
sum), so compile counts show up in the same metrics dump as bytes and
staleness.

The pytest fixture ``count_compiles_fixture`` (registered by
tests/conftest.py) hands tests the context-manager class under the name
``count_compiles``; the bench imports the class directly.
"""
from __future__ import annotations

import jax

from repro.obs import metrics as _metrics

_EVENT = "/jax/core/compile/backend_compile_duration"
_COMPILES = [0]


def _on_event(event, duration, **kw):
    if event == _EVENT:
        _COMPILES[0] += 1
        reg = _metrics.default_registry()
        if reg.enabled:
            reg.inc("jax.backend_compiles")
            reg.inc("jax.backend_compile_secs", float(duration))


# registered exactly once per process, at first import
jax.monitoring.register_event_duration_secs_listener(_on_event)


def compile_count() -> int:
    """Monotonic count of backend compiles since process start."""
    return _COMPILES[0]


class count_compiles:
    """``with count_compiles() as c: ...; c.count`` — programs compiled
    inside the block (eager ops and jit cache misses both count)."""

    def __enter__(self) -> "count_compiles":
        self.start = _COMPILES[0]
        return self

    def __exit__(self, *exc) -> None:
        self.count = _COMPILES[0] - self.start

    @property
    def so_far(self) -> int:
        return _COMPILES[0] - self.start


class CompileBudgetExceeded(RuntimeError):
    """A watchdog-guarded block compiled more programs than allowed."""


class CompileWatchdog(count_compiles):
    """Enforcing variant of :class:`count_compiles`: on exit (without a
    pending exception) raises :class:`CompileBudgetExceeded` when the
    block compiled more than ``max_compiles`` programs.

    >>> with CompileWatchdog(0, label="steady-state decode"):
    ...     engine.step(x, cids)     # must re-dispatch compiled programs
    """

    def __init__(self, max_compiles: int = 0, label: str = ""):
        self.max_compiles = int(max_compiles)
        self.label = label

    def __exit__(self, exc_type, *exc) -> None:
        self.count = _COMPILES[0] - self.start
        if exc_type is None and self.count > self.max_compiles:
            what = f" [{self.label}]" if self.label else ""
            raise CompileBudgetExceeded(
                f"compile watchdog{what}: {self.count} backend "
                f"compile(s) in a block budgeted for "
                f"{self.max_compiles}")


try:        # pragma: no cover - exercised through the test suite
    import pytest

    @pytest.fixture(name="count_compiles")
    def count_compiles_fixture():
        """The measurement context as a fixture: tests take
        ``count_compiles`` as an argument and use it exactly like the
        class (``with count_compiles() as c: ...``)."""
        return count_compiles
except ImportError:                       # bench runs without pytest
    pass
