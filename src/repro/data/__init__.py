from repro.data.synthetic import SyntheticVision, client_shard, \
    linear_shard, markov_lm_batch, synthetic_lm_batch
from repro.data.partition import lda_partition
