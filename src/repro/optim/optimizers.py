"""Hand-rolled optimizers (no optax in the container).

Interface mirrors optax minimally: ``opt.init(params) -> state``;
``opt.update(grads, state, params, lr) -> (new_params, new_state)``.
Optimizer state exists only for the *trainable* tree — the frozen base
carries no momenta (the paper's training-memory reduction).

The paper's client optimizer is SGD with momentum 0.9, lr 0.01.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[..., tuple[Any, Any]]


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn


def sgd(momentum: float = 0.9, nesterov: bool = False,
        weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return {}
        return {"mu": jax.tree.map(
            lambda p: jnp.zeros_like(p, jnp.float32), params)}

    def update(grads, state, params, lr):
        if weight_decay:
            grads = jax.tree.map(
                lambda g, p: g + weight_decay * p.astype(g.dtype),
                grads, params)
        if momentum == 0.0:
            new_params = jax.tree.map(
                lambda p, g: (p.astype(jnp.float32)
                              - lr * g.astype(jnp.float32)).astype(p.dtype),
                params, grads)
            return new_params, state
        mu = jax.tree.map(
            lambda m, g: momentum * m + g.astype(jnp.float32),
            state["mu"], grads)
        step_dir = jax.tree.map(
            lambda m, g: momentum * m + g.astype(jnp.float32), mu, grads) \
            if nesterov else mu
        new_params = jax.tree.map(
            lambda p, d: (p.astype(jnp.float32) - lr * d).astype(p.dtype),
            params, step_dir)
        return new_params, {"mu": mu}

    return Optimizer(init, update)


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return {"mu": jax.tree.map(z, params),
                "nu": jax.tree.map(z, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        c = state["count"] + 1
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(
            jnp.float32), state["mu"], grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(
            g.astype(jnp.float32)), state["nu"], grads)
        bc1 = 1 - b1 ** c.astype(jnp.float32)
        bc2 = 1 - b2 ** c.astype(jnp.float32)

        def upd(p, m, v):
            step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                step = step + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, {"mu": mu, "nu": nu, "count": c}

    return Optimizer(init, update)
