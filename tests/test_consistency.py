"""Serving-path equivalence: prefill+decode must reproduce the training
forward (teacher forcing) for every attention family, and chunked SSD
must equal the sequential recurrence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.lora import LoRAConfig, linear_apply
from repro.models import encdec as ED
from repro.models import lm as LM
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.attention import MLASpec
from repro.models.ssm import MambaSpec

LORA = LoRAConfig(rank=4, alpha=64)
TOL = 5e-2   # bf16 end-to-end logits tolerance


def _check_decode_matches_forward(cfg, seed=0):
    k = jax.random.PRNGKey(seed)
    p = LM.init(k, cfg)
    toks = jax.random.randint(k, (2, 17), 0, cfg.vocab)
    lp, caches, pos = jax.jit(
        lambda f, t, tok: LM.prefill(f, t, cfg, tok, max_seq=24))(
        p["frozen"], p["train"], toks[:, :16])
    ld, _ = jax.jit(
        lambda f, t, tok, c, pos: LM.decode_step(f, t, cfg, tok, c, pos))(
        p["frozen"], p["train"], toks[:, 16:17], caches, pos)
    h, _ = LM.forward(p["frozen"], p["train"], cfg, toks)
    fl = linear_apply(p["frozen"].get("head", {}), p["train"].get("head", {}),
                      h, cfg.lora.scale)
    err_prefill = float(jnp.max(jnp.abs(lp - fl[:, 15])))
    err_decode = float(jnp.max(jnp.abs(ld[:, 0] - fl[:, 16])))
    assert err_prefill < TOL, f"prefill {err_prefill}"
    assert err_decode < TOL, f"decode {err_decode}"


def test_gqa_decode_consistency():
    _check_decode_matches_forward(LM.LMConfig(
        name="t", n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab=256, lora=LORA))


def test_gqa_padded_heads_decode_consistency():
    _check_decode_matches_forward(LM.LMConfig(
        name="t", n_layers=2, d_model=48, n_heads=3, n_kv_heads=1,
        head_dim=16, d_ff=96, vocab=128, pad_heads_to=4, lora=LORA))


def test_mla_decode_consistency():
    _check_decode_matches_forward(LM.LMConfig(
        name="t", n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=128, vocab=256, attn_kind="mla",
        mla=MLASpec(d_model=64, n_heads=4, q_lora_rank=32, kv_lora_rank=16,
                    qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16),
        lora=LORA))


def test_sliding_window_ring_cache_consistency():
    _check_decode_matches_forward(LM.LMConfig(
        name="t", n_layers=7, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab=256, window=8, window_pattern=3,
        rope_base_global=1e5, qk_norm=True, lora=LORA))


def test_mamba_decode_consistency():
    _check_decode_matches_forward(LM.LMConfig(
        name="t", n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=0, vocab=256, attn_kind="none",
        mamba=MambaSpec(d_model=64, d_inner=128, head_dim=16, d_state=16,
                        chunk=8), lora=LORA))


def test_zamba_shared_attn_decode_consistency():
    _check_decode_matches_forward(LM.LMConfig(
        name="t", n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=128, vocab=256,
        mamba=MambaSpec(d_model=64, d_inner=128, head_dim=16, d_state=16,
                        chunk=8),
        shared_attn_every=2, lora=LORA))


def test_ssd_equals_sequential_recurrence():
    spec = MambaSpec(d_model=32, d_inner=64, head_dim=16, d_state=8,
                     chunk=8)
    fz, tr = S.mamba_init(jax.random.PRNGKey(0), spec, "lora", LORA)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32)) * 0.5
    y_ssd = S.mamba_apply(fz, tr, spec, x, LORA.scale)
    c = S.mamba_cache_init(spec, 2)
    ys = []
    for t in range(32):
        y, c = S.mamba_decode(fz, tr, spec, x[:, t:t + 1], c, LORA.scale)
        ys.append(y)
    y_seq = jnp.concatenate(ys, axis=1)
    err = float(jnp.max(jnp.abs(y_ssd.astype(jnp.float32)
                                - y_seq.astype(jnp.float32))))
    assert err < 1e-2


def test_local_attention_equals_masked_full():
    q = jax.random.normal(jax.random.PRNGKey(0), (2, 24, 4, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 2, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 24, 2, 16))
    w = 8
    o = L.local_attention_blocked(q, k, v, window=w)
    kr, vr = L._repeat_kv(k, 2), L._repeat_kv(v, 2)
    s_ = jnp.einsum("bqhd,bkhd->bhqk", q * 16 ** -0.5, kr)
    qp, kp = jnp.arange(24)[:, None], jnp.arange(24)[None, :]
    mask = (kp <= qp) & (kp > qp - w)
    s_ = jnp.where(mask[None, None], s_, -jnp.inf)
    o_ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s_, -1), vr)
    assert float(jnp.max(jnp.abs(o - o_ref))) < 2e-2


def test_chunked_attention_equals_full_softmax():
    q = jax.random.normal(jax.random.PRNGKey(0), (2, 20, 4, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 20, 4, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 20, 4, 16))
    o = L.attention_chunked(q, k, v, causal=True, kv_chunk=7)
    s_ = jnp.einsum("bqhd,bkhd->bhqk", q * 16 ** -0.5, k)
    qp, kp = jnp.arange(20)[:, None], jnp.arange(20)[None, :]
    s_ = jnp.where((kp <= qp)[None, None], s_, -jnp.inf)
    o_ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s_, -1), v)
    assert float(jnp.max(jnp.abs(o - o_ref))) < 2e-2


def test_encdec_stepwise_equals_teacher_forcing():
    cfg = ED.EncDecConfig(name="t", n_enc_layers=2, n_dec_layers=2,
                          d_model=32, n_heads=4, n_kv_heads=4, head_dim=8,
                          d_ff=64, vocab=128, lora=LORA)
    p = ED.init(jax.random.PRNGKey(0), cfg)
    src = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 32))
    tgt = jax.random.randint(jax.random.PRNGKey(2), (2, 9), 0, 128)
    mem = ED.encode(p["frozen"], p["train"], cfg, src)
    cc = ED.cross_cache(p["frozen"], p["train"], cfg, mem)
    c = ED.self_cache_init(cfg, 2, 16)
    outs = []
    step = jax.jit(lambda tok, c, pos: ED.decode_step(
        p["frozen"], p["train"], cfg, tok, c, cc, pos))
    for t in range(9):
        lg, c = step(tgt[:, t:t + 1], c, jnp.asarray(t, jnp.int32))
        outs.append(lg)
    ld = jnp.concatenate(outs, 1)
    h = ED.decode_train(p["frozen"], p["train"], cfg, tgt, mem)
    fl = linear_apply(p["frozen"].get("head", {}),
                      p["train"].get("head", {}), h, cfg.lora.scale)
    assert float(jnp.max(jnp.abs(ld - fl))) < TOL
