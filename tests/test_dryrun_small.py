"""Multi-device dry-run machinery test (8 fake host devices, reduced
configs — the production 512-device sweep runs via launch/dryrun.py).

Runs in a SUBPROCESS because the XLA device count locks at first jax
init and the rest of the suite needs 1 device."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.configs import registry
    from repro.launch import steps as steps_lib
    from repro.roofline.hlo_cost import analyze_hlo

    registry.SHAPES.update({
        "train_4k": {"seq": 64, "batch": 8, "step": "train"},
        "decode_32k": {"seq": 128, "batch": 8, "step": "decode"},
    })
    mesh = Mesh(np.asarray(jax.devices()[:8]).reshape(2, 2, 2),
                ("pod", "data", "model"))
    checks = [("minitron-4b", "train_4k"),
              ("deepseek-v2-236b", "train_4k"),
              ("gemma3-4b", "decode_32k"),
              ("mamba2-370m", "train_4k")]
    for arch, shape in checks:
        e = registry.get(arch)
        plan = steps_lib.CellPlan(microbatch=2 if shape == "train_4k"
                                  else 1)
        built = steps_lib.build_cell(e, shape, mesh, plan=plan,
                                     cfg_override=e.smoke())
        with mesh:
            c = jax.jit(built["fn"], in_shardings=built["in_shardings"],
                        out_shardings=built["out_shardings"],
                        donate_argnums=built["donate"] or ()
                        ).lower(*built["args"]).compile()
        la = analyze_hlo(c.as_text())
        assert la["flops"] > 0, arch
        assert c.memory_analysis().temp_size_in_bytes >= 0
        print(f"OK {arch} {shape} flops={la['flops']:.2e} "
              f"coll={la['collective_total']:.2e}")
    print("ALL_OK")
""")

FL_ROUND_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.configs import registry
    import repro.configs.minitron_4b as m
    from repro.launch.fl_round import build_fl_round
    from repro.roofline.hlo_cost import analyze_hlo
    mesh = Mesh(np.asarray(jax.devices()[:8]).reshape(2, 2, 2),
                ("pod", "data", "model"))
    entry = registry.ArchEntry("minitron-4b", "lm", m.smoke, m.smoke,
                               False)
    totals = {}
    for bits in (None, 8, 2):
        built = build_fl_round(entry, mesh, clients_per_pod=2, bits=bits)
        with mesh:
            c = jax.jit(built["fn"], in_shardings=built["in_shardings"]
                        ).lower(*built["args"]).compile()
        totals[bits] = analyze_hlo(c.as_text())["collective_total"]
    # quantized cross-pod exchange must beat fp32, and int2 beat int8
    assert totals[8] < totals[None], totals
    assert totals[2] < totals[8], totals
    print("ALL_OK", totals)
""")


@pytest.mark.slow
def test_dryrun_cells_small_mesh():
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert "ALL_OK" in out.stdout, out.stdout[-2000:] + out.stderr[-2000:]


@pytest.mark.slow
def test_fl_round_multi_pod_compression():
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", FL_ROUND_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert "ALL_OK" in out.stdout, out.stdout[-2000:] + out.stderr[-2000:]
