from repro.fl.client import ClientConfig, make_local_trainer, \
    make_cohort_trainer, make_staggered_cohort_trainer, \
    stack_local_batches, stack_cohort_batches, pad_cohort_batches, pow2_pad
from repro.fl.server import ServerConfig, FLServer, WireAccounting
from repro.fl.async_engine import AsyncConfig, AsyncFLServer, \
    time_to_target
from repro.fl.traces import AvailabilityWindows, FleetTrace, \
    LognormalLatency
from repro.fl.population import DeviceTier, Population, PopulationTrace, \
    default_tiers
from repro.fl.elastic import elastic_restore
