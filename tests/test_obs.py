"""Unified telemetry layer (src/repro/obs/).

The acceptance contract:
  * the metrics registry records labeled counters/gauges/histograms,
    no-ops (and allocates nothing) when disabled, and is injectable —
    two instances never see each other's counts;
  * the tracer spans wall time OR an engine's virtual clock, and both
    exports (Chrome trace JSON, JSONL) round-trip through json.load;
  * repro.obs.compile is the ONE backend-compile listener: the
    ``count_compiles`` fixture measures, ``CompileWatchdog`` enforces
    (raises on a fresh compile inside a zero-budget block), and the
    serving engine / streaming accumulator runtime invariants ride it;
  * HISTORY SCHEMA: every sync ``run_round`` record — including an
    all-dropout round — and every async flush record carries the full
    key set (bytes, density, rank breakdown, staleness);
  * END TO END: one FL round + one async run + one serve simulation
    with obs enabled produce a loadable Chrome trace and a metrics dump
    covering wire bytes, staleness, cache hit rate and compile counts.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs, serve
from repro.core.flocora import FLoCoRAConfig, RankSchedule
from repro.core.lora import LoRAConfig, linear_apply, linear_init
from repro.core.aggregation import FedBuffAggregator, \
    StreamingFlatAccumulator
from repro.core import messages
from repro.core.quant import QuantConfig
from repro.fl import AsyncConfig, AsyncFLServer, ClientConfig, FLServer, \
    FleetTrace, LognormalLatency, ServerConfig
from repro.obs import metrics as obsm
from repro.obs import trace as obst
from repro.obs.compile import CompileBudgetExceeded, CompileWatchdog

# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_registry_labeled_counters_gauges_histograms():
    reg = obsm.MetricsRegistry()
    reg.inc("wire.up_bytes", 100, rank=8, density=0.1)
    reg.inc("wire.up_bytes", 50, rank=8, density=0.1)
    reg.inc("wire.up_bytes", 7, rank=4, density=None)
    assert reg.counter_value("wire.up_bytes") == 157
    assert reg.counter_value("wire.up_bytes", rank=8, density=0.1) == 150
    # label order does not matter: one canonical key
    assert reg.counter_value("wire.up_bytes", density=0.1, rank=8) == 150
    reg.set("fl.inflight", 3)
    reg.set("fl.inflight", 5)
    assert reg.gauge("fl.inflight").get() == 5
    for v in (0, 1, 1, 3, 100):
        reg.observe("fl.staleness", v)
    st = reg.histogram("fl.staleness").get()
    assert st.count == 5 and st.min == 0 and st.max == 100
    assert reg.histogram("fl.staleness").mean() == pytest.approx(21.0)
    d = reg.dump()
    assert d["counters"]["wire.up_bytes"]["density=0.1,rank=8"] == 150
    assert "fl.staleness" in d["histograms"]
    json.dumps(d)                      # the dump is plain JSON


def test_registry_disabled_is_a_noop_and_instances_are_isolated():
    off = obsm.MetricsRegistry(enabled=False)
    off.inc("x", 5)
    off.observe("h", 1.0)
    off.set("g", 2.0)
    assert off.dump() == {"counters": {}, "gauges": {}, "histograms": {}}
    a, b = obsm.MetricsRegistry(), obsm.MetricsRegistry()
    a.inc("x", 1)
    assert b.counter_value("x") == 0
    # get_registry: explicit instance wins, None -> process default
    assert obsm.get_registry(a) is a
    assert obsm.get_registry(None) is obsm.default_registry()
    assert not obsm.default_registry().enabled  # off unless opted in


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------


def test_tracer_wall_and_virtual_clocks(tmp_path):
    tr = obst.Tracer()
    with tr.span("work", track="t0", k=1):
        pass
    vclock = [12.5]
    view = tr.with_clock(lambda: vclock[0])
    with view.span("virtual_work", track="t1"):
        vclock[0] = 14.0               # the span reads the fake clock
    tr.event("explicit", ts=3.0, dur=2.0, track="t1", cid=7)
    tr.instant("flush", track="t1", ts=20.0)
    names = [e["name"] for e in tr.events]
    assert names == ["work", "virtual_work", "explicit", "flush"]
    vw = tr.events[1]
    assert vw["ts"] == pytest.approx(12.5e6)
    assert vw["dur"] == pytest.approx(1.5e6)

    chrome = tmp_path / "trace.json"
    tr.export_chrome(str(chrome))
    doc = json.load(open(chrome))
    assert doc["traceEvents"]
    tracks = {e["args"]["name"] for e in doc["traceEvents"]
              if e["name"] == "thread_name"}
    assert {"t0", "t1"} <= tracks
    jl = tmp_path / "trace.jsonl"
    tr.export_jsonl(str(jl))
    lines = [json.loads(ln) for ln in open(jl)]
    assert {ln["track"] for ln in lines} == {"t0", "t1"}


def test_tracer_view_tracks_parent_enable_live():
    tr = obst.Tracer(enabled=False)
    view = tr.with_clock(lambda: 1.0)
    view.event("dropped", ts=0.0)
    assert tr.events == []
    tr.enabled = True                  # enabling the parent enables views
    view.event("kept", ts=0.0)
    assert [e["name"] for e in tr.events] == ["kept"]


# ---------------------------------------------------------------------------
# compile counting + watchdog
# ---------------------------------------------------------------------------


def test_count_compiles_fixture_and_watchdog(count_compiles):
    @jax.jit
    def f(x):
        return x * 2 + 1

    x = jnp.arange(17.0)               # odd length: a fresh shape
    with count_compiles() as c:
        jax.block_until_ready(f(x))
    assert c.count >= 1
    with count_compiles() as c:        # steady state: cached program
        jax.block_until_ready(f(x))
    assert c.count == 0
    with CompileWatchdog(0, label="steady"):   # budget met: no raise
        jax.block_until_ready(f(x))
    with pytest.raises(CompileBudgetExceeded, match="fresh"):
        with CompileWatchdog(0, label="fresh"):
            jax.block_until_ready(f(jnp.arange(19.0)))
    # a user exception propagates un-masked even over budget
    with pytest.raises(ZeroDivisionError):
        with CompileWatchdog(0):
            jax.block_until_ready(f(jnp.arange(23.0)))
            1 / 0


def test_compiles_feed_enabled_default_registry():
    reg = obsm.MetricsRegistry()
    prev = obsm.set_default_registry(reg)
    try:
        jax.block_until_ready(
            jax.jit(lambda x: x - 3)(jnp.arange(29.0)))
    finally:
        obsm.set_default_registry(prev)
    assert reg.counter_value("jax.backend_compiles") >= 1
    assert reg.counter_value("jax.backend_compile_secs") > 0


# ---------------------------------------------------------------------------
# tiny LoRA workload (mirrors test_async_engine: fast compiles)
# ---------------------------------------------------------------------------


def _lora_model(seed=0, rank=8):
    k = jax.random.PRNGKey(seed)
    fz, tr = linear_init(k, 16, 10, "lora",
                         LoRAConfig(rank=rank, alpha=float(rank)),
                         base_dtype=jnp.float32)
    return {"frozen": {"lin": fz},
            "train": {"lin": tr, "bias": jnp.zeros((10,))}}


def _lora_loss(frozen, train, batch):
    logits = linear_apply(frozen["lin"], train["lin"], batch["x"], 1.0,
                          jnp.float32) + train["bias"]
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, batch["y"][:, None],
                                         axis=1)), {}


def _lin_data(n=120, n_clients=6, seed=0):
    rng = np.random.default_rng(seed)
    w_true = rng.normal(size=(16, 10)).astype(np.float32)
    x = rng.normal(size=(n, 16)).astype(np.float32)
    y = np.argmax(x @ w_true, axis=1).astype(np.int32)
    parts = np.array_split(rng.permutation(n), n_clients)
    return [{"x": x[p], "y": y[p]} for p in parts]


def _sync_server(data, p_fail=0.0, **fkw):
    scfg = ServerConfig(rounds=2, n_clients=len(data),
                        clients_per_round=3, p_client_failure=p_fail,
                        seed=0)
    ccfg = ClientConfig(local_epochs=1, batch_size=8, lr=0.1)
    fcfg = FLoCoRAConfig(rank=8, alpha=8.0, quant_bits=8, **fkw)
    return FLServer(_lora_model(rank=8), _lora_loss, data, scfg, ccfg,
                    fcfg)


SYNC_KEYS = {"round", "n_agg", "n_dropped", "n_straggled", "client_loss",
             "cohort_ranks", "down_bytes", "up_bytes", "round_bytes",
             "tcc_bytes", "uplink_density"}
ASYNC_KEYS = {"version", "t_virtual", "n_arrived", "n_flushed",
              "client_loss", "staleness_mean", "staleness_max",
              "flush_ranks", "down_bytes", "up_bytes", "tcc_bytes",
              "uplink_density"}


# ---------------------------------------------------------------------------
# history record schema completeness
# ---------------------------------------------------------------------------


def test_sync_history_schema_complete_even_on_all_dropout():
    data = _lin_data()
    srv = _sync_server(data)
    rec = srv.run_round()
    assert SYNC_KEYS <= rec.keys(), SYNC_KEYS - rec.keys()
    assert rec["uplink_density"] is None     # dense uplink, key present
    assert rec["down_bytes"] > 0 and rec["up_bytes"] > 0

    srv_dead = _sync_server(data, p_fail=1.0)
    rec0 = srv_dead.run_round()
    assert rec0["n_agg"] == 0                # every client dropped
    assert SYNC_KEYS <= rec0.keys(), SYNC_KEYS - rec0.keys()
    assert rec0["down_bytes"] > 0 and rec0["up_bytes"] == 0


def test_async_flush_schema_complete():
    data = _lin_data()
    acfg = AsyncConfig(total_arrivals=8, concurrency=3, buffer_size=4,
                       seed=0)
    srv = AsyncFLServer(_lora_model(rank=8), _lora_loss, data, acfg,
                        ClientConfig(local_epochs=1, batch_size=8,
                                     lr=0.1),
                        FLoCoRAConfig(rank=8, alpha=8.0, quant_bits=8),
                        trace=FleetTrace(seed=0, latency=LognormalLatency(
                            compute_median_s=5.0, network_mbps=20.0)))
    hist = srv.run()
    assert hist
    for rec in hist:
        assert ASYNC_KEYS <= rec.keys(), ASYNC_KEYS - rec.keys()


# ---------------------------------------------------------------------------
# runtime zero-steady-state-compile enforcement
# ---------------------------------------------------------------------------


def _flat_msgs(n, bits=4, rank=8):
    qcfg = QuantConfig(bits=bits)
    out = []
    for i in range(n):
        k = jax.random.PRNGKey(i)
        ks = jax.random.split(k, 2)
        tree = {"a": jax.random.normal(ks[0], (13, rank)),
                "b": jax.random.normal(ks[1], (rank, 21))}
        out.append(messages.pack_message(tree, qcfg, flat=True))
    return out


def test_streaming_accumulator_strict_compiles():
    msgs = _flat_msgs(4)
    st = StreamingFlatAccumulator.for_layout(msgs[0].layout,
                                             strict_compiles=True)
    for m in msgs:                     # first fold may compile; rest not
        st.fold(m, 1.0)
    jax.block_until_ready(st.acc)
    # a cleared compile cache makes the next steady-state fold retrace,
    # which the watchdog must surface instead of silently recompiling
    jax.clear_caches()
    with pytest.raises(CompileBudgetExceeded, match="streaming"):
        st.fold(msgs[0], 1.0)
    # threaded through the aggregator config field
    agg = FedBuffAggregator(streaming=True, strict_compiles=True)
    agg.add(msgs[0], 1.0, 0.0)
    assert next(iter(agg.streams.values())).strict_compiles


def test_serve_engine_strict_compiles_steady_state():
    weights, store = serve.make_store(n_clients=8, d_model=32,
                                      n_layers=2, ranks=(4, 8), bits=4,
                                      seed=0)
    cache = serve.AdapterCache(capacity_bytes=1 << 20, qcfg=store.qcfg)
    eng = serve.AdapterServingEngine(weights, scale=0.5, qcfg=store.qcfg,
                                     cache=cache, fetch=store.fetch,
                                     strict_compiles=True)
    cids = [0, 1, 2, 3]                # both rank buckets
    eng.admit(cids)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((4, 32)), jnp.float32)
    for _ in range(2):                 # warm (first sight of the shape)
        jax.block_until_ready(eng.step(x, cids))
    for _ in range(3):                 # steady state: watchdogged, clean
        jax.block_until_ready(eng.step(x, cids))
    jax.clear_caches()                 # force a retrace on a warm shape
    with pytest.raises(CompileBudgetExceeded, match="steady-state"):
        eng.step(x, cids)


# ---------------------------------------------------------------------------
# end to end: one round + one async run + one serve sim, obs enabled
# ---------------------------------------------------------------------------


def test_end_to_end_trace_and_metrics_dump(tmp_path):
    reg = obsm.MetricsRegistry(enabled=False)
    tracer = obst.Tracer(enabled=False)
    prev_r = obsm.set_default_registry(reg)
    prev_t = obst.set_default_tracer(tracer)
    try:
        obs.enable()
        data = _lin_data()
        # sync: one round (mixed ranks so wire counters get labels)
        srv = FLServer(
            _lora_model(rank=8), _lora_loss, data,
            ServerConfig(rounds=1, n_clients=len(data),
                         clients_per_round=3, seed=0),
            ClientConfig(local_epochs=1, batch_size=8, lr=0.1),
            FLoCoRAConfig(rank=8, alpha=8.0, quant_bits=8,
                          rank_schedule=RankSchedule.tiered(
                              (4, 8), len(data))))
        srv.run_round()
        # async: a short run (staleness + virtual-clock spans)
        asrv = AsyncFLServer(
            _lora_model(rank=8), _lora_loss, data,
            AsyncConfig(total_arrivals=6, concurrency=3, buffer_size=3,
                        seed=0),
            ClientConfig(local_epochs=1, batch_size=8, lr=0.1),
            FLoCoRAConfig(rank=8, alpha=8.0, quant_bits=8),
            trace=FleetTrace(seed=0, latency=LognormalLatency(
                compute_median_s=5.0, network_mbps=20.0)))
        asrv.run()
        # serve: a small simulated workload (cache hit rate)
        weights, store = serve.make_store(n_clients=8, d_model=32,
                                          ranks=(4, 8), bits=4, seed=0)
        eng = serve.AdapterServingEngine(
            weights, scale=0.5, qcfg=store.qcfg,
            cache=serve.AdapterCache(capacity_bytes=1 << 20,
                                     qcfg=store.qcfg),
            fetch=store.fetch)
        serve.simulate(eng, store,
                       serve.WorkloadConfig(n_requests=12, rate_rps=500.0,
                                            gen_tokens=2, max_batch=4,
                                            seed=0))
    finally:
        obs.disable()
        obsm.set_default_registry(prev_r)
        obst.set_default_tracer(prev_t)

    # the metrics dump covers bytes, staleness, hit rate, compiles
    d = reg.dump()
    assert sum(reg.counter("wire.down_bytes").values.values()) > 0
    assert sum(reg.counter("wire.up_bytes").values.values()) > 0
    # per-rank labels from the tiered sync fleet
    assert any("rank=" in k for k in
               reg.counter("wire.up_bytes").values)
    assert reg.histogram("fl.staleness").get() is not None
    hits = reg.counter_value("serve.cache.hits")
    misses = reg.counter_value("serve.cache.misses")
    assert hits + misses > 0 and misses > 0   # cold cache missed first
    assert reg.counter_value("jax.backend_compiles") > 0
    assert reg.counter_value("fl.rounds") == 1
    assert reg.counter_value("fl.flushes") >= 1
    dump_path = tmp_path / "metrics.json"
    reg.dump_json(str(dump_path))
    json.load(open(dump_path))

    # the trace covers all three engines and loads as Chrome JSON
    path = tmp_path / "trace.json"
    tracer.export_chrome(str(path))
    doc = json.load(open(path))
    names = {e["name"] for e in doc["traceEvents"]}
    assert {"fl/broadcast", "fl/client_train", "fl/pack", "fl/uplink",
            "fl/aggregate"} <= names, names
    assert {"fl/inflight", "fl/flush"} <= names
    assert {"serve/decode_step", "serve/request"} <= names
    # async spans sit on VIRTUAL time: dispatch->arrival durations are
    # fleet-scale seconds, far beyond the wall time this test ran for
    inflight = [e for e in doc["traceEvents"]
                if e["name"] == "fl/inflight"]
    assert inflight and all(e["dur"] >= 1e6 for e in inflight)
    assert all("staleness" in e["args"] for e in inflight)


def test_disabled_obs_records_nothing_through_engines():
    """Engines built with the (disabled) process defaults must leave no
    telemetry behind — the <2% overhead contract starts with zero
    recording."""
    data = _lin_data()
    srv = _sync_server(data)
    srv.run_round()
    assert obsm.default_registry().dump() == {
        "counters": {}, "gauges": {}, "histograms": {}}
    assert obst.default_tracer().events == []
