"""nemotron-4-340b [dense]: 96L d_model=18432 96H (GQA kv=8) d_ff=73728
vocab=256000 — GQA, squared-ReLU [arXiv:2402.16819]. head_dim = 192."""
from repro.core.lora import LoRAConfig
from repro.models.lm import LMConfig


def full() -> LMConfig:
    return LMConfig(
        name="nemotron-4-340b", n_layers=96, d_model=18432, n_heads=96,
        n_kv_heads=8, head_dim=192, d_ff=73728, vocab=256000,
        mlp_kind="sqrelu", rope_base=1e4,
        lora=LoRAConfig(rank=32, alpha=512.0), head_mode="lora")


def smoke() -> LMConfig:
    return LMConfig(
        name="nemotron-4-340b-smoke", n_layers=4, d_model=96, n_heads=6,
        n_kv_heads=2, head_dim=16, d_ff=384, vocab=512,
        mlp_kind="sqrelu", pad_heads_to=8,
        lora=LoRAConfig(rank=4, alpha=64.0), head_mode="lora")
