"""Paper-faithful ResNet-8 / ResNet-18 (CIFAR variants) with FLoCoRA.

Structure reverse-engineered to byte-exactness against the paper's
Tables I/III/IV (see tests/test_paper_tables.py):
  * ResNet-8: 3x3 stem conv 3->64 + GN; one basic block per stage with
    widths (64, 128, 256), stride-2 + 1x1 downsample on stages 2/3; GAP;
    FC 256->10 (bias). Base params: 1,227,594 (paper: 1.23M; TCC 982.07MB).
  * ResNet-18: 3x3 stem 3->64; two basic blocks per stage, widths
    (64, 128, 256, 512); 1x1 downsample on first block of stages 2-4;
    FC 512->10. Base params: 11,173,962 (paper: 44.7 MB messages).

FLoCoRA rules that reproduce Table I exactly (69,450 trained @ r=8):
stem conv TRAINED DENSE (rank would be capped at I*K^2=27 — adapting a
3-channel input conv is pointless), every other conv (incl. 1x1
downsamples) gets the Huh-decomposition LoRA adapter, GroupNorms and the
final FC are trained densely. Table II's ablation modes are exposed via
``stem_mode`` / ``fc_mode`` / ``norms_trained``.

Activations NHWC; conv kernels HWIO. GroupNorm (32 groups) replaces
BatchNorm per the paper (Hsu et al. non-IID rule).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.lora import LoRAConfig, conv_lora_init, conv_lora_apply
from repro.models import layers as L

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ResNetConfig:
    arch: str = "resnet8"            # 'resnet8' | 'resnet18'
    n_classes: int = 10
    gn_groups: int = 32
    lora: LoRAConfig = LoRAConfig(rank=32, alpha=512.0)
    # modes: 'fedavg' trains everything densely (no adapters);
    # FLoCoRA final config: conv lora, stem dense, fc dense, norms trained
    mode: str = "flocora"            # 'fedavg' | 'flocora'
    stem_mode: str = "dense"         # 'dense' | 'lora'   (Table II ablation)
    fc_mode: str = "dense"           # 'dense' | 'lora' | 'frozen'
    norms_trained: bool = True

    @property
    def stages(self) -> tuple:
        if self.arch == "resnet8":
            return ((64, 1, 1), (128, 1, 2), (256, 1, 2))
        if self.arch == "resnet18":
            return ((64, 2, 1), (128, 2, 2), (256, 2, 2), (512, 2, 2))
        raise ValueError(self.arch)

    @property
    def final_width(self) -> int:
        return self.stages[-1][0]


def _conv_init(key, kh, kw, cin, cout, mode, lora):
    fan = kh * kw * cin
    w = (jax.random.normal(key, (kh, kw, cin, cout), jnp.float32)
         * (2.0 / fan) ** 0.5)
    if mode == "dense":
        return {}, {"w": w}
    if mode == "frozen":
        return {"w": w}, {}
    ad = conv_lora_init(jax.random.fold_in(key, 1), kh, kw, cin, cout, lora)
    return {"w": w}, ad


def _conv_apply(fz, tr, x, stride, lora_scale, padding="SAME"):
    w = tr["w"] if "w" in tr else fz["w"]
    dn = jax.lax.conv_dimension_numbers(x.shape, w.shape,
                                        ("NHWC", "HWIO", "NHWC"))
    y = jax.lax.conv_general_dilated(x, w.astype(x.dtype), stride, padding,
                                     dimension_numbers=dn)
    if "b" in tr and "a" in tr:       # conv-LoRA side chain
        y = y + conv_lora_apply(x, tr["b"], tr["a"], lora_scale, stride,
                                padding)
    return y


def _norm_init(c, trained):
    p = L.groupnorm_init(c)
    return ({}, p) if trained else (p, {})


def init(key: Array, cfg: ResNetConfig) -> dict:
    lora = cfg.lora
    conv_mode = "dense" if cfg.mode == "fedavg" else "lora"
    stem_mode = "dense" if cfg.mode == "fedavg" else cfg.stem_mode
    fc_mode = "dense" if cfg.mode == "fedavg" else cfg.fc_mode
    norms_tr = True if cfg.mode == "fedavg" else cfg.norms_trained

    keys = iter(jax.random.split(key, 64))
    frozen: dict = {}
    train: dict = {}

    f, t = _conv_init(next(keys), 3, 3, 3, 64, stem_mode, lora)
    nf, nt = _norm_init(64, norms_tr)
    frozen["stem"] = {"conv": f, "norm": nf}
    train["stem"] = {"conv": t, "norm": nt}

    fb, tb = [], []
    cin = 64
    for width, n_blocks, stride in cfg.stages:
        for b in range(n_blocks):
            s = stride if b == 0 else 1
            blk_f, blk_t = {}, {}
            f, t = _conv_init(next(keys), 3, 3, cin, width, conv_mode, lora)
            nf, nt = _norm_init(width, norms_tr)
            blk_f["conv1"], blk_t["conv1"] = f, t
            blk_f["norm1"], blk_t["norm1"] = nf, nt
            f, t = _conv_init(next(keys), 3, 3, width, width, conv_mode,
                              lora)
            nf, nt = _norm_init(width, norms_tr)
            blk_f["conv2"], blk_t["conv2"] = f, t
            blk_f["norm2"], blk_t["norm2"] = nf, nt
            if s != 1 or cin != width:
                f, t = _conv_init(next(keys), 1, 1, cin, width, conv_mode,
                                  lora)
                nf, nt = _norm_init(width, norms_tr)
                blk_f["ds"], blk_t["ds"] = f, t
                blk_f["ds_norm"], blk_t["ds_norm"] = nf, nt
            fb.append(blk_f)
            tb.append(blk_t)
            cin = width
    frozen["blocks"] = fb
    train["blocks"] = tb

    kfc = next(keys)
    w = jax.random.normal(kfc, (cfg.final_width, cfg.n_classes),
                          jnp.float32) * (cfg.final_width ** -0.5)
    bias = jnp.zeros((cfg.n_classes,), jnp.float32)
    if fc_mode == "dense":
        frozen["fc"] = {}
        train["fc"] = {"w": w, "b": bias}
    elif fc_mode == "frozen":
        frozen["fc"] = {"w": w, "b": bias}
        train["fc"] = {}
    else:  # lora on FC (Table II "vanilla")
        from repro.core.lora import dense_lora_init
        ad = dense_lora_init(jax.random.fold_in(kfc, 1), cfg.final_width,
                             cfg.n_classes, lora)
        frozen["fc"] = {"w": w, "b": bias}
        train["fc"] = ad
    return {"frozen": frozen, "train": train}


def apply(frozen: dict, train: dict, cfg: ResNetConfig, x: Array) -> Array:
    """x: (N, 32, 32, 3) -> logits (N, n_classes)."""
    sc = cfg.lora.scale
    g = cfg.gn_groups

    def norm(fz, tr, h):
        p = tr if tr else fz
        return L.groupnorm_apply(p, h, groups=g)

    h = _conv_apply(frozen["stem"]["conv"], train["stem"]["conv"], x,
                    (1, 1), sc)
    h = jax.nn.relu(norm(frozen["stem"]["norm"], train["stem"]["norm"], h))

    bi = 0
    cin = 64
    for width, n_blocks, stride in cfg.stages:
        for b in range(n_blocks):
            s = stride if b == 0 else 1
            fz, tr = frozen["blocks"][bi], train["blocks"][bi]
            idn = h
            y = _conv_apply(fz["conv1"], tr["conv1"], h, (s, s), sc)
            y = jax.nn.relu(norm(fz["norm1"], tr["norm1"], y))
            y = _conv_apply(fz["conv2"], tr["conv2"], y, (1, 1), sc)
            y = norm(fz["norm2"], tr["norm2"], y)
            if "ds" in fz or "ds" in tr:
                idn = _conv_apply(fz.get("ds", {}), tr.get("ds", {}), idn,
                                  (s, s), sc)
                idn = norm(fz.get("ds_norm", {}), tr.get("ds_norm", {}), idn)
            h = jax.nn.relu(y + idn)
            bi += 1
            cin = width

    h = jnp.mean(h, axis=(1, 2))                     # GAP
    fz, tr = frozen["fc"], train["fc"]
    if "w" in tr:
        logits = h @ tr["w"] + tr["b"]
    elif "a" in tr:                                   # lora fc
        wall = fz["w"] + sc * (tr["a"] @ tr["b"])
        logits = h @ wall + fz["b"]
    else:
        logits = h @ fz["w"] + fz["b"]
    return logits


def loss_fn(frozen: dict, train: dict, cfg: ResNetConfig,
            batch: dict) -> tuple[Array, dict]:
    logits = apply(frozen, train, cfg, batch["x"])
    labels = batch["y"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32))
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
    loss = jnp.mean(nll)
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return loss, {"acc": acc}
