"""The paper's technique AT SCALE: a jittable multi-pod FL server round.

Hierarchical aggregation mapped onto the production mesh (DESIGN.md §3):

  stage 1 (intra-pod, ICI): each pod holds its cohort's client adapter
    trees stacked (P, Kp, ...) — P sharded over 'pod', Kp over 'data'.
    Client messages are RTN-dequantized (the uplink view) and
    n_k-weighted-averaged; the reduction over Kp lowers to an in-pod
    all-reduce over the cheap ICI 'data' axis only.

  stage 2 (cross-pod, DCN): each pod QUANTIZES its partial aggregate via
    the shared wire codec (core/messages.pack_message) and the pods
    exchange the *packed uint32 words + fp32 sidecars* — the sharding
    constraint forces an all-gather of the packed payloads over the
    'pod' axis, so the compiled collective schedule itself carries
    FLoCoRA-compressed traffic across the slow inter-pod links (4x for
    int8, 16x for int2 vs fp32 exchange). Both pods dequantize and
    average.

``build_fl_round`` returns the jit-ready pieces; the dry-run lowers it on
the 2x16x16 mesh and the roofline records the cross-pod wire bytes for
fp32 vs int8 vs int2 exchange (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.registry import ArchEntry
from repro.core import messages
from repro.core.quant import QuantConfig
from repro.models import encdec as ED
from repro.models import lm as LM
from repro.utils.sharding import tree_shardings, DEFAULT_RULES

Array = jax.Array


def _stack_spec(x: jax.ShapeDtypeStruct, p: int, kp: int
                ) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct((p, kp) + x.shape, x.dtype)


def build_fl_round(entry: ArchEntry, mesh: Mesh, *, clients_per_pod: int = 16,
                   bits: Optional[int] = 8,
                   staleness_half_life: Optional[float] = None) -> dict:
    """``staleness_half_life`` switches the jittable round into the
    ASYNC engine's multi-pod flush: ``fl_round`` takes an extra
    (P, Kp) ``staleness`` operand and FedBuff-discounts each client's
    weight by ``2^(-staleness / half_life)`` BEFORE the in-pod
    reduction — the sync and async engines share the identical
    broadcast/uplink codec path (stage-2 packed exchange included);
    only the weighting differs."""
    cfg = entry.full()
    mod = ED if entry.kind == "encdec" else LM
    shapes = jax.eval_shape(
        lambda k: {g: t for g, t in mod.init(k, cfg).items()
                   if g in ("frozen", "train")}, jax.random.PRNGKey(0))
    train_shapes = shapes["train"]
    logical = mod.logical(cfg)["train"]

    n_pods = dict(zip(mesh.axis_names, mesh.devices.shape)).get("pod", 1)
    kp = clients_per_pod
    qcfg = QuantConfig(bits=bits) if bits else QuantConfig()

    stacked_shapes = jax.tree.map(lambda x: _stack_spec(x, n_pods, kp),
                                  train_shapes)

    # shardings: client axes (pod, data); param dims follow the model's
    # own logical rules shifted by the two stack dims
    def stack_shard(logical_leaf, x):
        from repro.utils.sharding import logical_to_spec
        spec = logical_to_spec(("__pod", "__kp") + tuple(logical_leaf),
                               x.shape, mesh,
                               {**DEFAULT_RULES, "__pod": "pod",
                                "__kp": "data"})
        return NamedSharding(mesh, spec)

    sh_stacked = jax.tree.map(
        stack_shard, logical, stacked_shapes,
        is_leaf=lambda t: isinstance(t, tuple) and all(
            isinstance(e, (str, type(None))) for e in t))
    from repro.utils.sharding import logical_to_spec
    w_spec = jax.ShapeDtypeStruct((n_pods, kp), jnp.float32)
    sh_w = NamedSharding(mesh, logical_to_spec(
        ("__pod", "__kp"), (n_pods, kp), mesh,
        {"__pod": "pod", "__kp": "data"}))

    def _round_core(stacked_clients: Any, weights: Array) -> Any:
        # ---- stage 1: uplink dequant + in-pod weighted mean ------------
        recon = jax.vmap(jax.vmap(lambda t: messages.roundtrip(t, qcfg)))(
            stacked_clients)
        wsum = jnp.sum(weights, axis=1, keepdims=True)
        wn = weights / jnp.maximum(wsum, 1e-8)

        def pod_mean(x):
            wr = wn.reshape(wn.shape + (1,) * (x.ndim - 2))
            return jnp.sum(x.astype(jnp.float32) * wr, axis=1)  # (P, ...)

        partial_ = jax.tree.map(pod_mean, recon)
        if n_pods == 1:
            return jax.tree.map(lambda x: x[0], partial_)

        # ---- stage 2: quantized cross-pod exchange ---------------------
        # the SHARED wire codec packs each pod's partial aggregate into
        # uint32 words + fp32 sidecars (the pure-jnp twin: pallas_call
        # can't batch under this vmap); static leaf metadata rides the
        # PackedLeaf pytree aux, so no shape side-channel is needed
        enc = jax.vmap(
            lambda t: messages.pack_message(t, qcfg, use_kernel=False))(
            partial_)
        # the barrier pins quantize+pack BEFORE the cross-pod gather (XLA
        # would otherwise sink the dequant across the collective and
        # gather fp32)
        enc = jax.lax.optimization_barrier(enc)

        def expose(x):
            # force replication over 'pod' => all-gather of the packed
            # payload across DCN
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(*(None,) * x.ndim)))

        enc = jax.tree.map(expose, enc)
        enc = jax.lax.optimization_barrier(enc)
        dec = jax.vmap(messages.unpack_message)(enc)
        pod_w = wsum[:, 0] / jnp.sum(wsum)
        return jax.tree.map(
            lambda x: jnp.einsum("p...,p->...", x.astype(jnp.float32),
                                 pod_w),
            dec)

    if staleness_half_life is None:
        return {"fn": _round_core, "args": (stacked_shapes, w_spec),
                "in_shardings": (sh_stacked, sh_w), "out_shardings": None,
                "donate": (), "cfg": cfg}

    hl = float(staleness_half_life)

    def fl_round_async(stacked_clients: Any, weights: Array,
                       staleness: Array) -> Any:
        # FedBuff discount w = n_k * 2^(-s/hl) ahead of the in-pod
        # reduction; the quantized cross-pod exchange is unchanged
        return _round_core(stacked_clients,
                           weights * jnp.exp2(-staleness / hl))

    return {"fn": fl_round_async,
            "args": (stacked_shapes, w_spec, w_spec),
            "in_shardings": (sh_stacked, sh_w, sh_w),
            "out_shardings": None, "donate": (), "cfg": cfg}
