"""Serving driver: prefill + batched autoregressive decode with the
FLoCoRA adapters merged into the frozen base (zero added latency — the
LoRA property the paper inherits, §II-C).

    PYTHONPATH=src python -m repro.launch.serve \
        --arch gemma3-4b --smoke --prompt-len 16 --gen 16
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.models import lm as LM


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    entry = registry.get(args.arch)
    if entry.kind != "lm":
        raise SystemExit("serve.py drives decoder LMs; use examples/ for "
                         "the enc-dec path")
    cfg = entry.smoke() if args.smoke else entry.full()
    params = LM.init(jax.random.PRNGKey(0), cfg)
    frozen, train = params["frozen"], params["train"]

    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab,
                                      (args.batch, args.prompt_len)),
                         jnp.int32)
    max_seq = args.prompt_len + args.gen + \
        (cfg.prefix_len if cfg.prefix_lm else 0)

    prefill = jax.jit(lambda f, t, tok: LM.prefill(f, t, cfg, tok,
                                                   max_seq=max_seq))
    decode = jax.jit(lambda f, t, tok, c, pos: LM.decode_step(
        f, t, cfg, tok, c, pos))

    t0 = time.time()
    logits, caches, pos = prefill(frozen, train, prompt)
    jax.block_until_ready(logits)
    print(f"prefill({args.prompt_len} tokens): {time.time() - t0:.2f}s")

    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    key = jax.random.PRNGKey(0)
    for i in range(args.gen - 1):
        logits, caches = decode(frozen, train, tok, caches, pos)
        if args.temperature > 0:
            key, sk = jax.random.split(key)
            tok = jax.random.categorical(
                sk, logits[:, 0] / args.temperature)[:, None].astype(
                jnp.int32)
        else:
            tok = jnp.argmax(logits[:, 0], -1)[:, None].astype(jnp.int32)
        pos = pos + 1
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    toks = jnp.concatenate(out, axis=1)
    print(f"decode: {args.gen - 1} steps in {dt:.2f}s "
          f"({(args.gen - 1) * args.batch / max(dt, 1e-9):.1f} tok/s)")
    for b in range(args.batch):
        print(f"  seq{b}: {list(np.asarray(toks[b]))}")


if __name__ == "__main__":
    main()
