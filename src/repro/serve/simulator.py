"""Continuous-batching request simulator for the multi-tenant engine.

Drives :class:`~repro.serve.engine.AdapterServingEngine` with a Poisson
arrival trace over a Zipf-popular fleet of clients: requests are
admitted (one COUNTED cache lookup each; misses pay a fetch delay drawn
from the fleet timing model — the :class:`~repro.fl.traces.
LognormalLatency` compute+transfer draw, keyed exactly like
``FleetTrace.arrival``), then decode in micro-batches grouped by rank
bucket inside the engine. The virtual clock advances by the MEASURED
wall time of each engine step (this is a benchmark harness, not a pure
discrete-event model: compute cost is real, network cost is modeled),
so the reported requests/sec, tokens/sec and p50/p99 request latencies
are measured numbers for the chosen serving path.

Determinism mirrors ``fl/traces.py``: every draw is a pure function of
``(seed, TAG, ...)`` via ``np.random.default_rng([seed, TAG, ...])``,
so two simulations of the same workload replay the same arrivals,
clients and fetch delays regardless of batch composition.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import messages
from repro.core.quant import QuantConfig
from repro.fl.traces import LognormalLatency
from repro.obs import metrics as obsm
from repro.obs import trace as obst
from repro.serve.cache import wire_bytes_of
from repro.serve.engine import AdapterServingEngine

# rng stream tags (disjoint from fl/traces.py's TAG_LATENCY=0xA1 and
# the data-split tags): arrivals/popularity/inputs of the serving trace
TAG_ARRIVAL = 0xA7
TAG_FETCH = 0xA8

# a serving-node fetch is a datacenter RPC, not an edge training round:
# sub-ms median service time + wire transfer at NIC-ish rates
FETCH_LATENCY = LognormalLatency(compute_median_s=5e-4, compute_sigma=0.3,
                                 network_mbps=1000.0, network_sigma=0.2,
                                 rank_exp=0.0)


@dataclasses.dataclass(frozen=True)
class WorkloadConfig:
    """A simulated serving workload.

    ``zipf_a`` shapes client popularity (p ~ (i+1)^-a): larger -> a few
    hot adapters dominate -> higher cache hit rate. ``rate_rps`` is the
    Poisson arrival rate; ``gen_tokens`` decode steps per request;
    ``max_active`` caps concurrently-admitted (adapter-pinned)
    requests — arrivals beyond it queue unadmitted."""
    n_requests: int = 64
    rate_rps: float = 500.0
    gen_tokens: int = 8
    max_batch: int = 8
    max_active: int = 32
    zipf_a: float = 1.1
    seed: int = 0


@dataclasses.dataclass
class AdapterStore:
    """The serving node's upstream adapter registry (the FL server):
    per-client wire messages, fetched on cache miss."""
    msgs: dict[int, object]
    ranks: dict[int, int]
    qcfg: QuantConfig
    fetches: int = 0

    def fetch(self, cid: int):
        self.fetches += 1
        return self.msgs[cid]

    def rank_of(self, cid: int) -> int:
        return self.ranks[cid]

    def bytes_of(self, cid: int) -> int:
        return wire_bytes_of(self.msgs[cid], self.qcfg)

    @property
    def cids(self) -> list[int]:
        return sorted(self.msgs)


def make_store(n_clients: int, d_model: int, n_layers: int = 2,
               ranks: Sequence[int] = (4, 8), bits: int = 4,
               seed: int = 0) -> tuple[list[jax.Array], AdapterStore]:
    """Synthesize a fleet's uplinked adapters: ``n_clients`` wire
    messages over a shared ``n_layers``-deep chain of (d, d) frozen
    linears, rank tiered round-robin over ``ranks`` (the RankSchedule
    convention), packed with the REAL codec — even cids flat-tree, odd
    cids per-leaf, so both wire forms hit the cache's extract path.
    Returns (frozen weights, store)."""
    qcfg = QuantConfig(bits=bits)
    rng = np.random.default_rng([seed, TAG_FETCH, 0xF])
    weights = [jnp.asarray(rng.standard_normal((d_model, d_model)) * 0.05,
                           jnp.float32) for _ in range(n_layers)]
    msgs, rmap = {}, {}
    for cid in range(n_clients):
        r = int(ranks[cid % len(ranks)])
        crng = np.random.default_rng([seed, TAG_FETCH, cid])
        tree = {"layers": [
            {"a": jnp.asarray(crng.standard_normal((d_model, r)) * 0.1,
                              jnp.float32),
             "b": jnp.asarray(crng.standard_normal((r, d_model)) * 0.1,
                              jnp.float32)}
            for _ in range(n_layers)]}
        msgs[cid] = messages.pack_message(tree, qcfg, flat=(cid % 2 == 0))
        rmap[cid] = r
    return weights, AdapterStore(msgs=msgs, ranks=rmap, qcfg=qcfg)


@dataclasses.dataclass
class _Req:
    idx: int
    cid: int
    t_arrive: float
    ready: float = 0.0          # admission + (miss ? fetch delay : 0)
    left: int = 0
    t_done: Optional[float] = None


def _draw_requests(store: AdapterStore, wl: WorkloadConfig) -> list[_Req]:
    rng = np.random.default_rng([wl.seed, TAG_ARRIVAL])
    gaps = rng.exponential(1.0 / wl.rate_rps, wl.n_requests)
    t = np.cumsum(gaps)
    cids = store.cids
    p = (np.arange(len(cids)) + 1.0) ** -wl.zipf_a
    p /= p.sum()
    picks = rng.choice(len(cids), size=wl.n_requests, p=p)
    return [_Req(idx=i, cid=int(cids[picks[i]]), t_arrive=float(t[i]),
                 left=wl.gen_tokens) for i in range(wl.n_requests)]


def simulate(engine: AdapterServingEngine, store: AdapterStore,
             wl: WorkloadConfig, warmup: bool = True,
             registry: Optional[obsm.MetricsRegistry] = None,
             tracer: Optional[obst.Tracer] = None) -> dict:
    """Run the workload through the engine; returns measured stats.

    Admission and queue-depth telemetry rides the obs registry
    (``serve.sim.*`` counters/histograms), and each decode step plus
    each request's admit->done lifetime lands on the tracer as a
    VIRTUAL-TIME span (``ts`` = the simulator clock)."""
    reg = obsm.get_registry(registry)
    tr = obst.get_tracer(tracer)
    if engine.fetch is None:
        engine.fetch = store.fetch
    d_in = int(engine.weights[0].shape[0])
    reqs = _draw_requests(store, wl)
    xrng = np.random.default_rng([wl.seed, TAG_ARRIVAL, 1])
    # host-side inputs: each step device_puts its (m, d) micro-batch
    # (a transfer, not a compile — jnp.stack would compile per m)
    xs = (xrng.standard_normal((wl.n_requests, d_in)) * 0.5
          ).astype(np.float32)

    if warmup:
        # compile every steady-state program shape before the timed
        # loop: each rank tier's layer chain, plus the ragged
        # gather/scatter/pad programs of every (batch size, per-bucket
        # split) a mixed micro-batch can produce. Without this the
        # FIRST simulated path pays all the lazy op compiles and the
        # path comparison is order-biased.
        seen: dict[int, int] = {}
        for cid in store.cids:
            seen.setdefault(store.rank_of(cid), cid)
        tiers = list(seen.values())
        engine.admit(tiers)
        mmax = min(wl.max_batch, wl.n_requests)
        for m in range(1, mmax + 1):
            comps = [[t] * m for t in tiers]
            comps += [[tiers[0]] * m1 + [t] * (m - m1)
                      for t in tiers[1:] for m1 in range(1, m)]
            for comp in comps:
                jax.block_until_ready(
                    engine.step(jnp.asarray(xs[:m]), comp))
        c = engine.cache
        c.hits = c.misses = c.evictions = 0
        store.fetches = 0

    clock = 0.0
    pending = list(reqs)        # arrival order (t is already sorted)
    admitted: list[_Req] = []
    done: list[_Req] = []
    steps = 0
    while len(done) < wl.n_requests:
        # admit arrived requests up to the active cap (counted lookup;
        # a miss's modeled fetch delay gates that request's readiness,
        # not the node)
        while pending and pending[0].t_arrive <= clock \
                and len(admitted) < wl.max_active:
            r = pending.pop(0)
            missed = engine.admit([r.cid])
            reg.inc("serve.sim.admissions", hit=not missed)
            if missed:
                frng = np.random.default_rng(
                    [wl.seed, TAG_FETCH, r.cid, r.idx])
                fetch_s = FETCH_LATENCY.sample(
                    frng, store.rank_of(r.cid), store.bytes_of(r.cid))
                r.ready = clock + fetch_s
                reg.inc("serve.sim.fetch_bytes", store.bytes_of(r.cid))
                tr.event("serve/fetch", ts=clock, dur=fetch_s,
                         track="serve/fetch", cid=r.cid)
            else:
                r.ready = clock
            engine.cache.pin(r.cid)     # in-flight: evictable at done
            admitted.append(r)
        # queue depth at every scheduling decision: requests arrived
        # but not yet admitted (waiting on the max_active cap), plus
        # the admitted-but-running population
        n_waiting = sum(1 for p in pending if p.t_arrive <= clock)
        reg.observe("serve.sim.queue_depth", n_waiting)
        reg.observe("serve.sim.active", len(admitted))
        runnable = [r for r in admitted if r.ready <= clock][:wl.max_batch]
        if not runnable:
            # idle: fast-forward the clock to the next event (the next
            # arrival only counts if there is room to admit it)
            nxt = [r.ready for r in admitted]
            if pending and len(admitted) < wl.max_active:
                nxt.append(pending[0].t_arrive)
            clock = max(clock, min(nxt))
            continue
        rows = jnp.asarray(xs[[r.idx for r in runnable]])
        t0 = time.perf_counter()
        jax.block_until_ready(engine.step(rows, [r.cid for r in runnable]))
        dt = time.perf_counter() - t0
        tr.event("serve/decode_step", ts=clock, dur=dt,
                 track="serve/steps", rows=len(runnable),
                 path=engine.path)
        clock += dt
        steps += 1
        reg.observe("serve.sim.batch_rows", len(runnable))
        for r in runnable:
            r.left -= 1
            if r.left == 0:
                r.t_done = clock
                engine.cache.unpin(r.cid)
                admitted.remove(r)
                done.append(r)
                reg.inc("serve.sim.requests_done")
                tr.event("serve/request", ts=r.t_arrive,
                         dur=r.t_done - r.t_arrive,
                         track="serve/requests", cid=r.cid)

    lat_ms = np.asarray(
        sorted(1e3 * (r.t_done - r.t_arrive) for r in done))
    span = max(max(r.t_done for r in done), 1e-9)
    st = engine.cache.stats()
    return {
        "path": engine.path,
        "requests": wl.n_requests,
        "steps": steps,
        "wall_s": span,
        "requests_per_s": wl.n_requests / span,
        "tokens_per_s": wl.n_requests * wl.gen_tokens / span,
        "p50_ms": float(np.percentile(lat_ms, 50)),
        "p99_ms": float(np.percentile(lat_ms, 99)),
        "hit_rate": st["hit_rate"],
        "hits": st["hits"],
        "misses": st["misses"],
        "evictions": st["evictions"],
        "cache_bytes": st["bytes"],
        "cache_entries": st["entries"],
        "store_fetches": store.fetches,
    }
