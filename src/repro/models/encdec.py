"""Encoder-decoder transformer (seamless-m4t-medium backbone).

The audio frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings (B, S_src, d). Encoder = bidirectional
transformer stack; decoder = causal self-attention + cross-attention to
encoder output + FFN. All projections are FLoCoRA targets; norms and the
final projection follow the paper's dense rule (head configurable).

Serving: the encoder runs once; cross-attention K/V are precomputed per
layer ("cross cache", static during decode) alongside the usual growing
self-attention cache.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.lora import LoRAConfig, linear_init, linear_apply, \
    linear_logical
from repro.models import attention as A
from repro.models import layers as L

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    name: str
    n_enc_layers: int
    n_dec_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    mlp_kind: str = "gelu"
    rope_base: float = 1e4
    lora: LoRAConfig = LoRAConfig()
    head_mode: str = "lora"
    remat: bool = True
    kv_chunk: int = 1024
    xent_chunk: int = 512

    @property
    def gqa(self) -> A.GQASpec:
        return A.GQASpec(self.d_model, self.n_heads, self.n_kv_heads,
                         self.head_dim)


def _enc_layer_init(key, cfg: EncDecConfig, stack):
    ks = jax.random.split(key, 2)
    fz, tr = {}, {"norm1": L.rmsnorm_init(cfg.d_model, stack),
                  "norm2": L.rmsnorm_init(cfg.d_model, stack)}
    f, t = A.gqa_init(ks[0], cfg.gqa, "lora", cfg.lora, stack)
    fz["attn"], tr["attn"] = f, t
    f, t = L.mlp_init(ks[1], L.MLPSpec(cfg.mlp_kind, cfg.d_model, cfg.d_ff),
                      "lora", cfg.lora, stack)
    fz["mlp"], tr["mlp"] = f, t
    return fz, tr


def _dec_layer_init(key, cfg: EncDecConfig, stack):
    ks = jax.random.split(key, 3)
    fz, tr = _enc_layer_init(jax.random.fold_in(key, 7), cfg, stack)
    tr["norm_x"] = L.rmsnorm_init(cfg.d_model, stack)
    f, t = A.gqa_init(ks[2], cfg.gqa, "lora", cfg.lora, stack)
    fz["cross"], tr["cross"] = f, t
    return fz, tr


def _enc_layer_logical(cfg, stack):
    fz, tr = {}, {"norm1": {"scale": (("layers",) if stack else ()) + (None,)},
                  "norm2": {"scale": (("layers",) if stack else ()) + (None,)}}
    f, t = A.gqa_logical(cfg.gqa, "lora", stack)
    fz["attn"], tr["attn"] = f, t
    f, t = L.mlp_logical(L.MLPSpec(cfg.mlp_kind, cfg.d_model, cfg.d_ff),
                         "lora", stack)
    fz["mlp"], tr["mlp"] = f, t
    return fz, tr


def _dec_layer_logical(cfg, stack):
    fz, tr = _enc_layer_logical(cfg, stack)
    tr["norm_x"] = {"scale": (("layers",) if stack else ()) + (None,)}
    f, t = A.gqa_logical(cfg.gqa, "lora", stack)
    fz["cross"], tr["cross"] = f, t
    return fz, tr


def init(key: Array, cfg: EncDecConfig) -> dict:
    k_embed, k_head, k_enc, k_dec = jax.random.split(key, 4)
    frozen: dict = {"embed": {"w": jax.random.normal(
        k_embed, (cfg.vocab, cfg.d_model), jnp.float32).astype(jnp.bfloat16)}}
    lf: dict = {"embed": {"w": ("vocab", "fsdp")}}
    train: dict = {"final_norm": L.rmsnorm_init(cfg.d_model),
                   "enc_norm": L.rmsnorm_init(cfg.d_model)}
    lt: dict = {"final_norm": {"scale": (None,)},
                "enc_norm": {"scale": (None,)}}

    hf, ht = linear_init(k_head, cfg.d_model, cfg.vocab, cfg.head_mode,
                         cfg.lora, w_init_scale=cfg.d_model ** -0.5)
    hlf, hlt = linear_logical("fsdp", "vocab", cfg.head_mode)
    if hf:
        frozen["head"], lf["head"] = hf, hlf
    if ht:
        train["head"], lt["head"] = ht, hlt

    ke = jax.random.split(k_enc, cfg.n_enc_layers)
    f, t = jax.vmap(lambda k_: _enc_layer_init(k_, cfg, ()))(ke)
    frozen["enc"], train["enc"] = f, t
    lf["enc"], lt["enc"] = _enc_layer_logical(cfg, stack=True)

    kd = jax.random.split(k_dec, cfg.n_dec_layers)
    f, t = jax.vmap(lambda k_: _dec_layer_init(k_, cfg, ()))(kd)
    frozen["dec"], train["dec"] = f, t
    lf["dec"], lt["dec"] = _dec_layer_logical(cfg, stack=True)

    return {"frozen": frozen, "train": train,
            "logical_frozen": lf, "logical_train": lt}


def _cross_apply(fz, tr, spec, x, memory, sc, kv_chunk):
    """Cross-attention: queries from x, keys/values from memory (no rope)."""
    b, s, _ = x.shape
    dh = spec.head_dim
    q = linear_apply(fz.get("wq", {}), tr.get("wq", {}), x, sc)
    k = linear_apply(fz.get("wk", {}), tr.get("wk", {}), memory, sc)
    v = linear_apply(fz.get("wv", {}), tr.get("wv", {}), memory, sc)
    q = q.reshape(b, s, spec.hq, dh)
    k = k.reshape(b, memory.shape[1], spec.n_kv_heads, dh)
    v = v.reshape(b, memory.shape[1], spec.n_kv_heads, dh)
    o = L.attention_chunked(q, k, v, causal=False, kv_chunk=kv_chunk)
    o = o.reshape(b, s, spec.hq * dh)
    return linear_apply(fz.get("wo", {}), tr.get("wo", {}), o, sc)


def encode(frozen, train, cfg: EncDecConfig, src_embed: Array,
           constrain: Optional[Callable] = None) -> Array:
    constrain = constrain or (lambda x: x)
    x = constrain(src_embed.astype(jnp.bfloat16))
    s = x.shape[1]
    rope = L.rope_for_positions(jnp.arange(s), cfg.head_dim, cfg.rope_base)
    sc = cfg.lora.scale

    def body(xc, xs):
        fz, tr = xs
        h = L.rmsnorm_apply(tr["norm1"], xc)
        h = A.gqa_apply(fz["attn"], tr["attn"], cfg.gqa, h, sc, rope,
                        causal=False, kv_chunk=cfg.kv_chunk)
        xc = constrain(xc + h)
        h = L.rmsnorm_apply(tr["norm2"], xc)
        h = L.mlp_apply(fz["mlp"], tr["mlp"],
                        L.MLPSpec(cfg.mlp_kind, cfg.d_model, cfg.d_ff), h, sc)
        return constrain(xc + h), None

    if cfg.remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, (frozen["enc"], train["enc"]))
    return L.rmsnorm_apply(train["enc_norm"], x)


def decode_train(frozen, train, cfg: EncDecConfig, tgt: Array,
                 memory: Array, constrain: Optional[Callable] = None
                 ) -> Array:
    constrain = constrain or (lambda x: x)
    x = constrain(frozen["embed"]["w"][tgt])
    s = x.shape[1]
    rope = L.rope_for_positions(jnp.arange(s), cfg.head_dim, cfg.rope_base)
    sc = cfg.lora.scale

    def body(xc, xs):
        fz, tr = xs
        h = L.rmsnorm_apply(tr["norm1"], xc)
        h = A.gqa_apply(fz["attn"], tr["attn"], cfg.gqa, h, sc, rope,
                        causal=True, kv_chunk=cfg.kv_chunk)
        xc = constrain(xc + h)
        h = L.rmsnorm_apply(tr["norm_x"], xc)
        h = _cross_apply(fz["cross"], tr["cross"], cfg.gqa, h, memory, sc,
                         cfg.kv_chunk)
        xc = constrain(xc + h)
        h = L.rmsnorm_apply(tr["norm2"], xc)
        h = L.mlp_apply(fz["mlp"], tr["mlp"],
                        L.MLPSpec(cfg.mlp_kind, cfg.d_model, cfg.d_ff), h, sc)
        return constrain(xc + h), None

    if cfg.remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, (frozen["dec"], train["dec"]))
    return L.rmsnorm_apply(train["final_norm"], x)


def loss_fn(frozen, train, cfg: EncDecConfig, batch: dict,
            constrain: Optional[Callable] = None) -> tuple[Array, dict]:
    """batch: {'src_embed': (B, S_src, d), 'tgt_tokens': (B, S_tgt+1)}."""
    memory = encode(frozen, train, cfg, batch["src_embed"], constrain)
    tgt_in = batch["tgt_tokens"][:, :-1]
    labels = batch["tgt_tokens"][:, 1:]
    h = decode_train(frozen, train, cfg, tgt_in, memory, constrain)
    xent = L.chunked_xent(h, frozen.get("head", {}), train.get("head", {}),
                          labels, cfg.lora.scale, chunk=cfg.xent_chunk)
    return xent, {"xent": xent}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def cross_cache(frozen, train, cfg: EncDecConfig, memory: Array) -> dict:
    """Precompute per-layer cross K/V (static during decode).

    Stacked over decoder layers by vmapping the projections."""
    sc = cfg.lora.scale

    def one(fz, tr):
        b, s, _ = memory.shape
        k = linear_apply(fz["cross"].get("wk", {}),
                         tr["cross"].get("wk", {}), memory, sc)
        v = linear_apply(fz["cross"].get("wv", {}),
                         tr["cross"].get("wv", {}), memory, sc)
        return {"k": k.reshape(b, s, cfg.gqa.n_kv_heads, cfg.head_dim),
                "v": v.reshape(b, s, cfg.gqa.n_kv_heads, cfg.head_dim)}

    return jax.vmap(one, in_axes=(0, 0))(frozen["dec"], train["dec"])


def self_cache_init(cfg: EncDecConfig, batch: int, max_seq: int) -> dict:
    c = A.gqa_cache_init(cfg.gqa, batch, max_seq)
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (cfg.n_dec_layers,) + x.shape), c)


def decode_step(frozen, train, cfg: EncDecConfig, token: Array,
                self_caches: dict, cross_caches: dict, pos: Array
                ) -> tuple[Array, dict]:
    """token: (B,1). cross_caches: stacked per-layer static K/V."""
    x = frozen["embed"]["w"][token]
    sc = cfg.lora.scale
    rope = L.rope_for_positions(
        jnp.broadcast_to(pos, (x.shape[0], 1)), cfg.head_dim, cfg.rope_base)

    def body(xc, xs):
        fz, tr, cache, xk, xv = xs
        h = L.rmsnorm_apply(tr["norm1"], xc)
        h, cache = A.gqa_decode(fz["attn"], tr["attn"], cfg.gqa, h, cache,
                                pos, sc, rope)
        xc = xc + h
        h = L.rmsnorm_apply(tr["norm_x"], xc)
        b = h.shape[0]
        q = linear_apply(fz["cross"].get("wq", {}), tr["cross"].get("wq", {}),
                         h, sc).reshape(b, 1, cfg.gqa.hq, cfg.head_dim)
        o = L.decode_attention(q, xk, xv, xk.shape[1])
        o = o.reshape(b, 1, cfg.gqa.hq * cfg.head_dim)
        h = linear_apply(fz["cross"].get("wo", {}), tr["cross"].get("wo", {}),
                         o, sc)
        xc = xc + h
        h = L.rmsnorm_apply(tr["norm2"], xc)
        h = L.mlp_apply(fz["mlp"], tr["mlp"],
                        L.MLPSpec(cfg.mlp_kind, cfg.d_model, cfg.d_ff), h, sc)
        return xc + h, cache

    x, new_caches = jax.lax.scan(
        body, x, (frozen["dec"], train["dec"], self_caches,
                  cross_caches["k"], cross_caches["v"]))
    x = L.rmsnorm_apply(train["final_norm"], x)
    logits = linear_apply(frozen.get("head", {}), train.get("head", {}),
                          x, sc).astype(jnp.float32)
    return logits, new_caches


def logical(cfg: EncDecConfig) -> dict:
    lf: dict = {"embed": {"w": ("vocab", "fsdp")}}
    lt: dict = {"final_norm": {"scale": (None,)},
                "enc_norm": {"scale": (None,)}}
    hlf, hlt = linear_logical("fsdp", "vocab", cfg.head_mode)
    if hlf:
        lf["head"] = hlf
    if hlt:
        lt["head"] = hlt
    lf["enc"], lt["enc"] = _enc_layer_logical(cfg, stack=True)
    lf["dec"], lt["dec"] = _dec_layer_logical(cfg, stack=True)
    return {"frozen": lf, "train": lt}
