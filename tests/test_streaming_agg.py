"""Fleet-scale streaming aggregation (PR 6).

The acceptance contract of the streaming/K-tiled/sharded stack:
  * the K-tiled ``dequant_agg_rows`` kernel walk is BIT-IDENTICAL for
    every client-tile size ``block_k`` (the fp32 accumulator visits
    clients in the same order regardless of tiling); the whole-K
    single-pass kernel is an independently-shaped numerics oracle
    (FMA selection differs -> tolerance, not bit, comparison);
  * both pallas entry points transparently pad a channel count that
    does not divide ``block_c`` (no caller-side alignment contract);
  * a ``StreamingFlatAccumulator`` folding arrivals one at a time
    matches the batched FedBuff flush across bits x density x
    heterogeneous ranks, steady-state folds compile ZERO new
    programs, and its checkpoint state round-trips bit-exactly;
  * every zero-weight flush RAISES (functional ``fedbuff_flush``, the
    streaming accumulator, and the buffered aggregator) — the old
    1e-8 floor silently emitted garbage trees;
  * the engine-level streaming path reproduces the batched engine's
    event history and final global tree, and a killed-then-resumed
    streaming run is bit-exact (slow-marked, with the sharded
    cohort-reduction subprocess test).
"""
import os
import shutil
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregation, flat, lora, messages
from repro.core.aggregation import FedBuffAggregator, \
    StreamingFlatAccumulator, fedbuff_add, fedbuff_flush, fedbuff_init
from repro.core.flocora import FLoCoRAConfig, RankSchedule
from repro.core.lora import LoRAConfig, linear_apply, linear_init
from repro.core.quant import QuantConfig
from repro.fl import AsyncConfig, AsyncFLServer, ClientConfig, \
    FleetTrace, LognormalLatency
from repro.kernels import ref as kref
from repro.kernels.dequant_agg import dequant_agg_rows_pallas, \
    pick_block_k

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

# backend-compile counter: shared process-wide hook in repro.obs.compile
from repro.obs.compile import count_compiles  # noqa: E402


def _tree(seed: int, rank: int = 8, scale: float = 1.0):
    """Adapter-pair tree ({"a","b"} keys -> rank-bucketable) + an fp
    passthrough 1-D leaf, channel counts chosen NOT to divide 8."""
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 3)
    return {"blk": {"a": jax.random.normal(ks[0], (13, rank)) * scale,
                    "b": jax.random.normal(ks[1], (rank, 21)) * scale},
            "norm": jax.random.normal(ks[2], (7,)) * scale}


def _flat_msgs(n: int, bits: int, rank: int = 8):
    qcfg = QuantConfig(bits=bits)
    return [messages.pack_message(_tree(i, rank), qcfg, flat=True)
            for i in range(n)]


def _stack(msgs):
    P = jnp.stack([m.payload for m in msgs])
    S = jnp.stack([m.scale for m in msgs])
    Z = jnp.stack([m.zp for m in msgs])
    nv = jnp.asarray(msgs[0].layout.n_valid_vec(), jnp.int32)
    return P, S, Z, nv


def _ref_agg(P, S, Z, w, nv, bits):
    """Dense jnp oracle of the rows kernel (zp zeroed like ops does)."""
    zpz = jnp.where(S > 0, Z, 0.0)
    lv = kref.unpack_words(P, bits).astype(jnp.float32)
    deq = (lv - zpz[..., None]) * S[..., None]
    out = jnp.einsum("k,kcn->cn", w.astype(jnp.float32), deq)
    col = jax.lax.broadcasted_iota(jnp.int32, out.shape, 1)
    return jnp.where(col < nv[:, None], out, 0.0)


# ---------------------------------------------------------------------------
# K-tiled kernel: bit parity across tilings, whole-K oracle, C padding
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [2, 4, 8])
def test_ktiled_bitwise_identical_across_block_k(bits):
    """The streaming K-tile walk must not change numerics with the tile
    size: every block_k gives the SAME bits (same fp32 visit order)."""
    msgs = _flat_msgs(13, bits)
    P, S, Z, nv = _stack(msgs)
    w = jnp.linspace(0.5, 2.0, 13)
    zpz = jnp.where(S > 0, Z, 0.0)
    outs = {bk: np.asarray(dequant_agg_rows_pallas(
        P, S, zpz, w, nv, bits, block_k=bk, interpret=True))
        for bk in (1, 2, 4, 8, 13, 16)}
    base = outs[13]                       # single tile covering all K
    for bk, o in outs.items():
        assert np.array_equal(o, base), f"block_k={bk} changed bits"
    np.testing.assert_allclose(
        base, np.asarray(_ref_agg(P, S, Z, w, nv, bits)),
        rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("bits", [4, 8])
def test_whole_k_kernel_is_tolerance_oracle(bits):
    """The single-pass whole-K kernel has a different program shape
    (XLA may pick different FMA contractions) — it cross-checks the
    tiled production path at tolerance, not bit equality."""
    msgs = _flat_msgs(9, bits)
    P, S, Z, nv = _stack(msgs)
    w = jnp.linspace(0.5, 2.0, 9)
    zpz = jnp.where(S > 0, Z, 0.0)
    tiled = dequant_agg_rows_pallas(P, S, zpz, w, nv, bits,
                                    block_k=4, interpret=True)
    whole = dequant_agg_rows_pallas(P, S, zpz, w, nv, bits,
                                    whole_k=True, interpret=True)
    np.testing.assert_allclose(np.asarray(whole), np.asarray(tiled),
                               rtol=1e-5, atol=1e-6)


def test_rows_kernel_transparent_c_padding():
    """C_total = 13 + 8 + 7(fp skipped) -> quantized rows don't divide
    block_c=8; the entry point must pad transparently and still match
    the dense oracle (no caller-side alignment assert)."""
    msgs = _flat_msgs(5, 4)
    P, S, Z, nv = _stack(msgs)
    assert P.shape[1] % 8 != 0            # the padding path is live
    w = jnp.ones((5,)) / 5
    zpz = jnp.where(S > 0, Z, 0.0)
    out = dequant_agg_rows_pallas(P, S, zpz, w, nv, 4, interpret=True)
    assert out.shape == P.shape[1:2] + (P.shape[2] * 8,)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(_ref_agg(P, S, Z, w, nv, 4)),
        rtol=1e-5, atol=1e-6)


def test_pick_block_k_respects_vmem_budget():
    bk = pick_block_k(10_000, nw=32, bits=4)
    assert bk & (bk - 1) == 0             # pow2
    assert 1 <= bk <= 10_000
    # a tiny cohort never tiles past K
    assert pick_block_k(3, nw=32, bits=4) <= 3


# ---------------------------------------------------------------------------
# streaming accumulator vs batched flush: bits x density x hetero ranks
# ---------------------------------------------------------------------------

def _drive(agg: FedBuffAggregator, msgs, n_ks, stales):
    for m, n_k, s in zip(msgs, n_ks, stales):
        agg.add(m, n_k, s)
    return agg.flush()


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("ranks", [(8, 8, 8, 8), (4, 8, 4, 8)],
                         ids=["homo", "hetero"])
def test_streaming_matches_batched_flush(bits, ranks):
    """Per-arrival folds + O(1) normalize == buffered batched flush,
    for every wire width and across rank buckets (one stream per
    layout; layouts double as rank buckets)."""
    qcfg = QuantConfig(bits=bits)
    msgs = [messages.pack_message(_tree(i, r), qcfg, flat=True)
            for i, r in enumerate(ranks)]
    n_ks = [10.0, 20.0, 15.0, 5.0]
    stales = [0.0, 1.0, 3.0, 2.0]
    out_s = _drive(FedBuffAggregator(streaming=True, r_target=8),
                   [messages.pack_message(_tree(i, r), qcfg, flat=True)
                    for i, r in enumerate(ranks)], n_ks, stales)
    out_b = _drive(FedBuffAggregator(r_target=8), msgs, n_ks, stales)
    for a, b in zip(jax.tree.leaves(out_s), jax.tree.leaves(out_b)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-5, atol=1e-6)


def test_streaming_mixed_with_sparse_pending():
    """Sparse (density<1) uplinks are not flat messages: in streaming
    mode they still buffer in ``pending`` and a mixed flush recombines
    stream means and pending-bucket means by weight-mass fraction,
    matching the all-batched result."""
    qcfg = QuantConfig(bits=4)
    flat_m = [messages.pack_message(_tree(i), qcfg, flat=True)
              for i in range(2)]
    sparse_m = [messages.pack_message(_tree(i + 2), qcfg, density=0.5)
                for i in range(2)]
    msgs = [flat_m[0], sparse_m[0], flat_m[1], sparse_m[1]]
    n_ks = [10.0, 20.0, 15.0, 5.0]
    stales = [0.0, 1.0, 2.0, 0.0]
    s_agg = FedBuffAggregator(streaming=True, r_target=8)
    out_s = _drive(s_agg, msgs, n_ks, stales)
    assert not s_agg.pending and not s_agg.buffered
    out_b = _drive(FedBuffAggregator(r_target=8), msgs, n_ks, stales)
    for a, b in zip(jax.tree.leaves(out_s), jax.tree.leaves(out_b)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-5, atol=1e-6)


def test_streaming_folds_compile_zero_programs():
    """After the first fold compiles the per-layout program, further
    folds — with DIFFERENT weights and staleness — add nothing (the
    weight rides as a weak-typed traced scalar)."""
    msgs = _flat_msgs(6, 4)
    agg = FedBuffAggregator(streaming=True)
    agg.add(msgs[0], 1.0, 0.0)            # compiles the fold program
    jax.block_until_ready(next(iter(agg.streams.values())).acc)
    with count_compiles() as c:
        for i, m in enumerate(msgs[1:]):
            agg.add(m, 3.0 + i, float(i % 3))
        jax.block_until_ready(next(iter(agg.streams.values())).acc)
    assert c.count == 0
    assert agg.buffered == 6


def test_streaming_state_roundtrip_bit_exact():
    """Checkpointing the accumulator mid-buffer and restoring it must
    not perturb a single bit of the final mean."""
    msgs = _flat_msgs(5, 8)
    st = StreamingFlatAccumulator.for_layout(msgs[0].layout)
    for m in msgs[:3]:
        st.fold(m, 2.0)
    st2 = StreamingFlatAccumulator.from_state(msgs[0].layout, st.state())
    for s in (st, st2):
        for m in msgs[3:]:
            s.fold(m, 1.5)
    for a, b in zip(jax.tree.leaves(st.mean()),
                    jax.tree.leaves(st2.mean())):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# zero-weight flushes raise (the silent 1e-8 floor is gone)
# ---------------------------------------------------------------------------

def test_fedbuff_flush_zero_weight_raises():
    tree = _tree(0)
    state = fedbuff_init(tree)
    with pytest.raises(ValueError, match="zero accumulated weight"):
        fedbuff_flush(state, tree)
    # a weight-zero ADD (n_k=0) still leaves nothing to normalize by
    state = fedbuff_add(state, tree, jnp.asarray(0.0), jnp.asarray(0.0),
                        half_life=4.0)
    with pytest.raises(ValueError, match="zero accumulated weight"):
        fedbuff_flush(state, tree)


def test_streaming_accumulator_zero_weight_raises():
    msgs = _flat_msgs(1, 4)
    st = StreamingFlatAccumulator.for_layout(msgs[0].layout)
    with pytest.raises(ValueError, match="empty accumulator"):
        st.mean()
    st.fold(msgs[0], 0.0)
    with pytest.raises(ValueError, match="zero accumulated weight"):
        st.mean()


def test_aggregator_empty_and_zero_weight_flush_raise():
    agg = FedBuffAggregator(streaming=True)
    with pytest.raises(ValueError, match="empty buffer"):
        agg.flush()
    agg.add(_flat_msgs(1, 4)[0], 0.0, 0.0)     # discounted weight 0
    with pytest.raises(ValueError, match="zero accumulated weight"):
        agg.flush()


# ---------------------------------------------------------------------------
# engine level: streaming parity + bit-exact resume (slow)
# ---------------------------------------------------------------------------

SCALE = 1.0


def _lora_model(seed=0, rank=16):
    k = jax.random.PRNGKey(seed)
    fz, tr = linear_init(k, 16, 10, "lora",
                         LoRAConfig(rank=rank, alpha=float(rank)),
                         base_dtype=jnp.float32)
    return {"frozen": {"lin": fz},
            "train": {"lin": tr, "bias": jnp.zeros((10,))}}


def _lora_loss(frozen, train, batch):
    logits = linear_apply(frozen["lin"], train["lin"], batch["x"], SCALE,
                          jnp.float32) + train["bias"]
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, batch["y"][:, None],
                                         axis=1)), {}


def _lin_data(n=240, n_clients=10, seed=0):
    rng = np.random.default_rng(seed)
    w_true = rng.normal(size=(16, 10)).astype(np.float32)
    x = rng.normal(size=(n, 16)).astype(np.float32)
    y = np.argmax(x @ w_true + 0.1 * rng.normal(size=(n, 10)),
                  axis=1).astype(np.int32)
    parts = np.array_split(rng.permutation(n), n_clients)
    return [{"x": x[p], "y": y[p]} for p in parts]


def _trace():
    return FleetTrace(seed=0, latency=LognormalLatency(
        compute_median_s=10.0, network_mbps=20.0))


HCFG = FLoCoRAConfig(rank=16, alpha=16.0, quant_bits=8,
                     rank_schedule=RankSchedule.tiered((8, 16), 10))


def _async_engine(streaming: bool, ckpt_dir=None):
    acfg = AsyncConfig(total_arrivals=30, concurrency=4, buffer_size=5,
                       microbatch_window=8.0, seed=0,
                       streaming_agg=streaming,
                       checkpoint_dir=ckpt_dir, checkpoint_every=2)
    return AsyncFLServer(_lora_model(rank=16), _lora_loss, _lin_data(),
                         acfg, ClientConfig(local_epochs=2, batch_size=8,
                                            lr=0.1),
                         HCFG, trace=_trace())


@pytest.mark.slow
def test_engine_streaming_parity_with_batched():
    """streaming_agg=True reproduces the batched engine's event
    schedule exactly (versions, virtual clock, wire bytes, staleness)
    and its global tree to fp tolerance (summation order differs)."""
    h_b = _async_engine(streaming=False)
    h_s = _async_engine(streaming=True)
    hist_b, hist_s = h_b.run(), h_s.run()
    assert len(hist_b) == len(hist_s) > 0
    for eb, es in zip(hist_b, hist_s):
        for key in ("version", "t_virtual", "tcc_bytes",
                    "staleness_mean"):
            assert eb[key] == es[key], key
    for a, b in zip(jax.tree.leaves(jax.device_get(h_b.global_train)),
                    jax.tree.leaves(jax.device_get(h_s.global_train))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-5, atol=5e-6)


@pytest.mark.slow
def test_streaming_resume_is_bit_exact(tmp_path):
    """ACCEPTANCE: killed-then-resumed STREAMING run == uninterrupted
    streaming run, bit for bit (checkpoints align to flush boundaries,
    so the restored accumulators are empty and re-fold identically)."""
    d_a, d_b = str(tmp_path / "a"), str(tmp_path / "b")
    srv_a = _async_engine(True, ckpt_dir=d_a)
    hist_a = srv_a.run()
    os.makedirs(d_b)
    for fn in os.listdir(d_a):
        shutil.copy(os.path.join(d_a, fn), d_b)
    steps = sorted(int(f[5:-5]) for f in os.listdir(d_b)
                   if f.endswith(".json"))
    assert len(steps) >= 2            # resume point strictly mid-run
    for s in steps[1:]:
        for ext in (".npz", ".json"):
            os.remove(os.path.join(d_b, f"ckpt_{s:08d}{ext}"))
    srv_b = _async_engine(True, ckpt_dir=d_b)
    assert srv_b.try_resume()
    assert srv_b.aggregator.buffered == 0
    hist_b = srv_b.run()
    assert hist_a == hist_b
    for a, b in zip(jax.tree.leaves(jax.device_get(srv_a.global_train)),
                    jax.tree.leaves(jax.device_get(srv_b.global_train))):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# sharded cohort reduction (8 fake devices, subprocess — device count
# locks at first jax init and the rest of the suite needs 1 device)
# ---------------------------------------------------------------------------

SHARDED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    from repro.core import aggregation, flat, messages
    from repro.core.quant import QuantConfig
    from repro.kernels import ops as kops
    from repro.launch.mesh import make_client_mesh

    def tree(i, rank=8):
        k = jax.random.PRNGKey(i)
        ks = jax.random.split(k, 3)
        return {"blk": {"a": jax.random.normal(ks[0], (13, rank)),
                        "b": jax.random.normal(ks[1], (rank, 21))},
                "norm": jax.random.normal(ks[2], (7,))}

    mesh = make_client_mesh()
    assert int(np.prod(mesh.devices.shape)) == 8
    for bits in (2, 8):
        qcfg = QuantConfig(bits=bits)
        # K=13: not a multiple of the axis -> phantom zero-weight pad
        for k in (13, 16):
            msgs = [messages.pack_message(tree(i), qcfg, flat=True)
                    for i in range(k)]
            w = jnp.linspace(0.5, 2.0, k)
            ref = aggregation.fedavg_packed(msgs, w)
            out = flat.fedavg_packed_flat_sharded(msgs, w, mesh)
            for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(out)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=3e-5, atol=1e-6)
            # kernel-level entry: sharded == single-device
            P = jnp.stack([m.payload for m in msgs])
            S = jnp.stack([m.scale for m in msgs])
            Z = jnp.stack([m.zp for m in msgs])
            nv = jnp.asarray(msgs[0].layout.n_valid_vec(), jnp.int32)
            r1 = kops.dequant_agg_rows(P, S, Z, w, nv, bits)
            r2 = kops.dequant_agg_rows_sharded(P, S, Z, w, nv, bits,
                                               mesh)
            np.testing.assert_allclose(np.asarray(r2), np.asarray(r1),
                                       rtol=1e-5, atol=1e-6)
    print("ALL_OK")
""")


@pytest.mark.slow
def test_sharded_cohort_reduction_matches_single_device():
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", SHARDED_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert "ALL_OK" in out.stdout, out.stdout[-2000:] + out.stderr[-2000:]


# ---------------------------------------------------------------------------
# flat wire padding strip: aligned + unaligned rows vs naive reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits,n_valid", [(8, 5), (4, 6), (4, 7),
                                          (2, 12), (2, 13)])
def test_strip_row_padding_matches_naive(bits, n_valid):
    """The byte-view fast path (n_valid*bits % 8 == 0) and the bit
    repack slow path must agree with the naive per-bit reference, and
    ``rows_from_wire`` must invert both (with the canonical zero
    tail) — including input wider than the row needs."""
    rng = np.random.default_rng(3)
    c, nw = 9, 4                           # wider than the row needs
    nww = (n_valid * bits + 31) // 32
    words = np.zeros((c, nw), np.uint32)
    lv = rng.integers(0, 1 << bits, (c, n_valid), dtype=np.uint32)
    for j in range(n_valid):               # pack the valid levels
        words[:, j * bits // 32] |= lv[:, j] << ((j * bits) % 32)
    words[:, nww:] = rng.integers(0, 2**32, (c, nw - nww),
                                  dtype=np.uint32)   # garbage past row
    wire = flat.strip_row_padding(words, bits, n_valid)
    # naive reference: per-level bit concat, little-endian
    nbits = n_valid * bits
    ref_bits = np.zeros((c, nbits), np.uint8)
    for j in range(n_valid):
        for t in range(bits):
            ref_bits[:, j * bits + t] = (lv[:, j] >> t) & 1
    ref = np.packbits(ref_bits.reshape(-1), bitorder="little")
    assert np.array_equal(wire, ref)
    back = flat.rows_from_wire(wire, bits, c, n_valid, nw)
    clean = words.copy()
    clean[:, nww:] = 0                     # canonical zero tail
    assert np.array_equal(back, clean)
