from repro.fl.client import ClientConfig, make_local_trainer, \
    make_cohort_trainer, stack_local_batches, stack_cohort_batches, \
    pad_cohort_batches, pow2_pad
from repro.fl.server import ServerConfig, FLServer
from repro.fl.elastic import elastic_restore
