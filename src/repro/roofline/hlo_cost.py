"""Loop-aware cost analysis of optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE — for a
scan-over-layers model that undercounts flops/bytes/collectives by ~L
(xla known issue; verified empirically in EXPERIMENTS.md §Roofline).
This module re-derives the three roofline inputs from the compiled HLO
with ``known_trip_count`` multipliers applied:

  * FLOPs: every ``dot`` (2 * prod(out) * prod(contracting lhs dims)),
    including dots inside fusion subcomputations; ``convolution`` ops get
    2 * prod(out) * prod(kernel spatial) * Cin / groups.
  * HBM bytes: for every top-level op in a computation (post-fusion HLO),
    operand bytes + result bytes — fusion internals stay on-chip, so the
    fusion boundary IS the HBM traffic estimate. Pure aliasing ops
    (parameter/tuple/get-tuple-element/bitcast/constant) are free.
  * Collective wire bytes: ring-algorithm per-chip cost per op kind
    (see repro.roofline.analysis) — also multiplied through loops.

Recursion happens ONLY through while (x trip_count), conditional (max of
branches) and call (x1); fusion subcomputations are scanned for dots but
contribute no extra HBM traffic.
"""
from __future__ import annotations

import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 0.5, "u4": 0.5, "token": 0,
    "s2": 0.25, "u2": 0.25, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OP_RE = re.compile(r"^\s*(?:\(([^)]*)\)|(\w+)\[([\d,]*)\][^\s]*)\s+"
                    r"([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_WINDOW_SIZE_RE = re.compile(r"window=\{[^}]*size=([\dx]+)")
_FEATURE_GROUPS_RE = re.compile(r"feature_group_count=(\d+)")

_FREE_OPS = {"parameter", "tuple", "get-tuple-element", "bitcast",
             "constant", "after-all", "partition-id", "replica-id",
             "iota", "while", "conditional", "call"}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes_list(type_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d.strip()]


class _Op:
    __slots__ = ("name", "kind", "type_str", "operands", "line")

    def __init__(self, name, kind, type_str, operands, line):
        self.name, self.kind = name, kind
        self.type_str, self.operands, self.line = type_str, operands, line


def _parse(text: str) -> dict[str, list[_Op]]:
    comps: dict[str, list[_Op]] = {}
    cur: Optional[str] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR_RE.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = m.group(1)
                comps[cur] = []
            continue
        if line.strip() == "}":
            cur = None
            continue
        dm = _DEF_RE.match(line)
        if not dm:
            continue
        name, rest = dm.groups()
        om = _OP_RE.match(" " + rest)
        if not om:
            continue
        tuple_body, dtype, dims, kind = om.groups()
        type_str = f"({tuple_body})" if tuple_body is not None else \
            f"{dtype}[{dims}]"
        paren = rest.index("(", rest.index(kind))
        depth, j = 0, paren
        while j < len(rest):
            if rest[j] == "(":
                depth += 1
            elif rest[j] == ")":
                depth -= 1
                if depth == 0:
                    break
            j += 1
        operand_str = rest[paren:j + 1]
        operands = _OPERAND_RE.findall(operand_str)
        comps[cur].append(_Op(name, kind, type_str, operands, line))
    return comps


def _dot_flops(op: _Op, symtab: dict[str, str]) -> float:
    out = _dims(op.type_str)
    n = 1
    for d in out:
        n *= d
    lhs_type = symtab.get(op.operands[0]) if op.operands else None
    contract = 1
    m = _LHS_CONTRACT_RE.search(op.line)
    if lhs_type and m:
        ld = _dims(lhs_type)
        for idx in m.group(1).split(","):
            if idx.strip():
                i = int(idx)
                if i < len(ld):
                    contract *= ld[i]
    return 2.0 * n * contract


def _conv_flops(op: _Op, symtab: dict[str, str]) -> float:
    out = _dims(op.type_str)
    n = 1
    for d in out:
        n *= d
    ksize = 1
    m = _WINDOW_SIZE_RE.search(op.line)
    if m:
        for d in m.group(1).split("x"):
            ksize *= int(d)
    cin = 1
    if len(op.operands) >= 2:
        kdims = _dims(symtab.get(op.operands[1], ""))
        if kdims:
            # HWIO-ish: input features is the second-to-last dim in most
            # layouts xla emits; best-effort
            cin = kdims[-2] if len(kdims) >= 2 else 1
    g = 1
    m = _FEATURE_GROUPS_RE.search(op.line)
    if m:
        g = int(m.group(1))
    return 2.0 * n * ksize * cin / max(g, 1)


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return 1


def _collective_wire(op: _Op) -> tuple[str, float]:
    size = _shape_bytes_list(op.type_str)
    g = _group_size(op.line)
    if g <= 1:
        return op.kind, 0.0
    if op.kind == "all-gather":
        w = size * (g - 1) / g
    elif op.kind == "reduce-scatter":
        w = size * (g - 1)
    elif op.kind == "all-reduce":
        w = 2 * size * (g - 1) / g
    elif op.kind == "all-to-all":
        w = size * (g - 1) / g
    else:
        w = size
    return op.kind, w


def analyze_hlo(text: str) -> dict:
    comps = _parse(text)
    symtabs = {c: {op.name: op.type_str for op in ops}
               for c, ops in comps.items()}
    memo: dict[str, dict] = {}

    def comp_cost(cname: str) -> dict:
        if cname in memo:
            return memo[cname]
        memo[cname] = z = {"flops": 0.0, "bytes": 0.0,
                           **{k: 0.0 for k in COLLECTIVES}}
        ops = comps.get(cname, [])
        st = symtabs.get(cname, {})
        acc = {"flops": 0.0, "bytes": 0.0,
               **{k: 0.0 for k in COLLECTIVES}}
        for op in ops:
            base = op.kind.replace("-start", "") if op.kind.endswith(
                "-start") else op.kind
            if base == "dot":
                acc["flops"] += _dot_flops(op, st)
            elif base == "convolution":
                acc["flops"] += _conv_flops(op, st)
            elif base == "fusion":
                m = _CALLS_RE.search(op.line)
                if m:
                    sub = _fusion_dot_flops(m.group(1))
                    acc["flops"] += sub
            elif base in COLLECTIVES:
                kind, wire = _collective_wire(op)
                acc[kind] += wire
            elif base == "while":
                bm = _BODY_RE.search(op.line)
                tm_ = _TRIP_RE.search(op.line)
                trips = int(tm_.group(1)) if tm_ else 1
                if bm:
                    sub = comp_cost(bm.group(1))
                    for k in acc:
                        acc[k] += trips * sub[k]
                cm = _COND_RE.search(op.line)
                if cm:
                    sub = comp_cost(cm.group(1))
                    for k in acc:
                        acc[k] += trips * sub[k]
            elif base == "conditional":
                bm = _BRANCHES_RE.search(op.line)
                if bm:
                    branches = _OPERAND_RE.findall(bm.group(1))
                    if branches:
                        subs = [comp_cost(b) for b in branches]
                        best = max(subs, key=lambda s: s["flops"]
                                   + s["bytes"])
                        for k in acc:
                            acc[k] += best[k]
            elif base == "call":
                m = _CALLS_RE.search(op.line) or _OPERAND_RE.search(op.line)
                # jax rarely emits bare calls in optimized HLO; skip
            # HBM bytes: boundary traffic of every materializing op.
            # Slice-like ops read/write only their slice — charging the
            # full operand would overcount scan weight-indexing by ~L.
            if base not in _FREE_OPS:
                acc["bytes"] += _op_hbm_bytes(op, st, comps, symtabs)
        memo[cname].update(acc)
        return memo[cname]

    def _op_hbm_bytes(op, st, comps, symtabs) -> float:
        out_b = _shape_bytes_list(op.type_str)
        base = op.kind
        if base == "dynamic-slice":
            return 2 * out_b                       # read slice + write out
        if base == "dynamic-update-slice":
            upd = _shape_bytes_list(st.get(op.operands[1], "")) \
                if len(op.operands) > 1 else out_b
            return 2 * upd                          # in-place slice update
        if base == "fusion":
            m = _CALLS_RE.search(op.line)
            disc = _fusion_param_discounts(m.group(1)) if m else {}
            b = out_b
            for i, o in enumerate(op.operands):
                full = _shape_bytes_list(st.get(o, ""))
                b += min(full, disc[i]) if i in disc else full
            return b
        b = out_b
        for o in op.operands:
            b += _shape_bytes_list(st.get(o, ""))
        return b

    _disc_memo: dict[str, dict[int, float]] = {}

    def _fusion_param_discounts(cname: str) -> dict[int, float]:
        """Parameters consumed only via dynamic-slice inside the fusion
        are charged at their slice size."""
        if cname in _disc_memo:
            return _disc_memo[cname]
        ops_ = comps.get(cname, [])
        st_ = symtabs.get(cname, {})
        param_ids: dict[str, int] = {}
        uses: dict[str, list] = {}
        for o in ops_:
            if o.kind == "parameter":
                mm = re.search(r"parameter\((\d+)\)", o.line)
                if mm:
                    param_ids[o.name] = int(mm.group(1))
            for opd in o.operands:
                uses.setdefault(opd, []).append(o)
        disc: dict[int, float] = {}
        for pname, pid in param_ids.items():
            us = uses.get(pname, [])
            if us and all(u.kind in ("dynamic-slice", "bitcast",
                                     "copy", "reshape") for u in us):
                sliced = sum(_shape_bytes_list(u.type_str) for u in us
                             if u.kind == "dynamic-slice")
                if sliced:
                    disc[pid] = 2 * sliced
        _disc_memo[cname] = disc
        return disc

    def _fusion_dot_flops(cname: str) -> float:
        ops = comps.get(cname, [])
        st = symtabs.get(cname, {})
        total = 0.0
        for op in ops:
            if op.kind == "dot":
                total += _dot_flops(op, st)
            elif op.kind == "convolution":
                total += _conv_flops(op, st)
            elif op.kind == "fusion":
                m = _CALLS_RE.search(op.line)
                if m:
                    total += _fusion_dot_flops(m.group(1))
        return total

    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR_RE.match(line)
            if m:
                entry = m.group(1)
                break
    if entry is None:                       # fallback: biggest computation
        entry = max(comps, key=lambda c: len(comps[c])) if comps else ""
    cost = comp_cost(entry)
    coll_total = sum(cost[k] for k in COLLECTIVES)
    return {"flops": cost["flops"], "bytes": cost["bytes"],
            "collectives": {k: cost[k] for k in COLLECTIVES},
            "collective_total": coll_total}
