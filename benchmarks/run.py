"""Benchmark runner — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Fast checks (byte-exact table
reproductions, kernel micro, roofline summary) always run; the FL
training reproductions (Table II, Fig 2/3 — minutes of CPU) run with
``--train`` (and ``--rounds N`` to deepen them).

    PYTHONPATH=src python -m benchmarks.run [--train] [--rounds N]
"""
import sys
import traceback


def main() -> None:
    train = "--train" in sys.argv
    rounds = 10
    if "--rounds" in sys.argv:
        rounds = int(sys.argv[sys.argv.index("--rounds") + 1])

    sections = []
    from benchmarks import table1_params, table3_tcc, table4_comparison, \
        kernel_bench, roofline_report
    sections.append(("table1", table1_params.run))
    sections.append(("table3", table3_tcc.run))
    sections.append(("table4", lambda: table4_comparison.run(train=False)))
    sections.append(("kernels", kernel_bench.run))
    sections.append(("roofline", roofline_report.run))
    if train:
        from benchmarks import table2_ablation, fig2_rank_alpha, \
            fig3_convergence
        sections.append(("table2", lambda: table2_ablation.run(rounds)))
        sections.append(("fig2", lambda: fig2_rank_alpha.run(rounds)))
        sections.append(("fig3", lambda: fig3_convergence.run(rounds)))

    print("name,us_per_call,derived")
    failures = 0
    for name, fn in sections:
        try:
            for row in fn():
                print(row, flush=True)
        except Exception as e:
            failures += 1
            print(f"{name}/ERROR,0,{type(e).__name__}: {e}", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
