"""Paper Table IV: ResNet-18 message sizes / TCC (byte-exact) and —
with --train — the accuracy comparison on the synthetic task."""
import sys

import jax

from repro.core import messages
from repro.core.lora import LoRAConfig
from repro.core.quant import QuantConfig
from repro.models.resnet import ResNetConfig, init as rinit

PAPER_MSG = {("fedavg", None): 44.7,
             (64, None): 9.2, (32, None): 4.6, (16, None): 2.4,
             (64, 8): 2.4, (32, 8): 1.2, (16, 8): 0.7}


def run(train: bool = False, rounds: int = 12) -> list[str]:
    rows = []
    k = jax.random.PRNGKey(0)
    for (r, bits), paper in PAPER_MSG.items():
        if r == "fedavg":
            p = rinit(k, ResNetConfig(arch="resnet18", mode="fedavg"))
        else:
            p = rinit(k, ResNetConfig(
                arch="resnet18", lora=LoRAConfig(rank=r, alpha=16.0 * r)))
        mb = messages.message_wire_bytes(p["train"],
                                         QuantConfig(bits=bits)) / 1e6
        tcc_gb = messages.tcc_bytes(p["train"], QuantConfig(bits=bits),
                                    700) / 1e9
        tag = "fedavg" if r == "fedavg" else \
            f"r{r}" + ("" if bits is None else f"_q{bits}")
        ok = abs(mb - paper) < 0.06
        rows.append(f"table4/{tag},0,msg={mb:.2f}MB tcc={tcc_gb:.2f}GB "
                    f"(paper {paper}MB) {'OK' if ok else 'MISMATCH'}")
    if train:
        from benchmarks.common import fl_experiment
        for r, bits in ((64, None), (64, 8), (32, 8)):
            res = fl_experiment(arch="resnet18", rank=r, quant_bits=bits,
                                rounds=rounds, lda_alpha=1.0,
                                n_train=2000, n_clients=20,
                                clients_per_round=4)
            rows.append(f"table4/train_r{r}_q{bits},0,"
                        f"best_acc={res['best_acc']}")
    return rows


if __name__ == "__main__":
    print("\n".join(run(train="--train" in sys.argv)))
