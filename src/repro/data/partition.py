"""Non-IID client partitioning: Latent Dirichlet Allocation split
(Hsu et al. 2019), the paper's setting with alpha = 0.5 (ResNet-8 runs)
and alpha = 1.0 (ResNet-18 runs)."""
from __future__ import annotations

import numpy as np


def lda_partition(labels: np.ndarray, n_clients: int, alpha: float,
                  seed: int = 0, min_size: int = 2) -> list[np.ndarray]:
    """Returns per-client index arrays. Each class's examples are split
    across clients by a Dirichlet(alpha) draw."""
    rng = np.random.default_rng(seed)
    n_classes = int(labels.max()) + 1
    while True:
        buckets: list[list[int]] = [[] for _ in range(n_clients)]
        for c in range(n_classes):
            idx = np.where(labels == c)[0]
            rng.shuffle(idx)
            props = rng.dirichlet(np.full(n_clients, alpha))
            cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
            for b, part in zip(buckets, np.split(idx, cuts)):
                b.extend(part.tolist())
        sizes = [len(b) for b in buckets]
        if min(sizes) >= min_size:
            break
    out = []
    for b in buckets:
        arr = np.asarray(b, np.int64)
        rng.shuffle(arr)
        out.append(arr)
    return out
