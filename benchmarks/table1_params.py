"""Paper Table I: trainable/total params of ResNet-8 vs LoRA rank."""
import jax

from repro.core.lora import LoRAConfig
from repro.models.resnet import ResNetConfig, init as rinit
from repro.utils.tree import tree_size

PAPER = {8: (69_450, "69.45K"), 16: (131_914, "131.92K"),
         32: (256_842, "256.84K"), 64: (506_698, "506.70K"),
         128: (1_006_410, "1.00M")}


def run() -> list[str]:
    rows = []
    k = jax.random.PRNGKey(0)
    p = rinit(k, ResNetConfig(arch="resnet8", mode="fedavg"))
    n = tree_size(p["train"])
    rows.append(f"table1/fedavg,0,{n} trained (paper 1.23M) "
                f"{'OK' if n == 1_227_594 else 'MISMATCH'}")
    for r, (expect, label) in PAPER.items():
        cfg = ResNetConfig(arch="resnet8",
                           lora=LoRAConfig(rank=r, alpha=16.0 * r))
        p = rinit(k, cfg)
        n = tree_size(p["train"])
        tot = n + tree_size(p["frozen"])
        rows.append(f"table1/flocora_r{r},0,trained={n} total={tot} "
                    f"(paper {label}) {'OK' if n == expect else 'MISMATCH'}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
