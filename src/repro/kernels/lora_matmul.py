"""Pallas TPU kernel: fused LoRA matmul  y = x@W + s*(x@a)@b.

The FLoCoRA client forward hot loop. The low-rank correction distributes
over the K (contraction) grid axis:  (x@a)@b = sum_k (x_k @ a_k) @ b, so
each (bm, bn, bk) step adds  x_k@w_k + s*(x_k@a_k)@b_n  into the fp32
output block — no scratch, one epilogue-free accumulation loop, and the
rank-r side chain (r <= 128, one MXU pass) rides along with the dense
matmul instead of a separate XLA fusion with its own HBM round-trip.

Tiling: (M/bm, N/bn, K/bk) grid, K innermost; x (bm,bk), w (bk,bn),
a (bk,r), b (r,bn) tiles in VMEM; all matmul dims multiples of 128 for
the MXU (wrapper pads r up to 128 with zeros when needed).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _lora_matmul_kernel(x_ref, w_ref, a_ref, b_ref, out_ref, *, s: float):
    kk = pl.program_id(2)
    x = x_ref[...]
    acc = jnp.dot(x, w_ref[...], preferred_element_type=jnp.float32)
    h = jnp.dot(x, a_ref[...], preferred_element_type=jnp.float32)
    acc = acc + s * jnp.dot(h.astype(b_ref.dtype), b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(kk == 0)
    def _init():
        out_ref[...] = acc

    @pl.when(kk > 0)
    def _acc():
        out_ref[...] += acc


def lora_matmul_pallas(x: Array, w: Array, a: Array, b: Array, s: float, *,
                       block_m: int = 256, block_n: int = 256,
                       block_k: int = 512,
                       interpret: bool = False) -> Array:
    """x (M, K); w (K, N); a (K, r); b (r, N). Returns bf16 (M, N)."""
    m, k = x.shape
    n = w.shape[1]
    r = a.shape[1]
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    grid = (m // bm, n // bn, k // bk)
    out = pl.pallas_call(
        functools.partial(_lora_matmul_kernel, s=s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk, r), lambda i, j, kk: (kk, 0)),
            pl.BlockSpec((r, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, w, a, b)
    return out.astype(x.dtype)
