"""Multi-tenant adapter serving: the FLoCoRA read path.

  cache     — wire-format-at-rest adapter cache (LRU/clock) + per-rank-
              bucket host->device staging
  engine    — batched multi-adapter serving over the fused packed
              kernel (and the dequant-then-matmul baseline + merged
              dense oracle), plus the shared LM ``generate()`` loop
  simulator — continuous-batching Poisson/Zipf workload harness with
              measured requests/sec and p50/p99 latency
"""
from repro.serve.cache import (AdapterCache, CacheEntry, PackedPair,
                               StagedBucket, StagedLayer, extract_pairs,
                               wire_bytes_of)
from repro.serve.engine import AdapterServingEngine, generate
from repro.serve.simulator import (AdapterStore, WorkloadConfig,
                                   make_store, simulate)
