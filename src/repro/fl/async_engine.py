"""Event-driven ASYNCHRONOUS federation: staleness-aware FedBuff over the
packed wire (Nguyen et al. '22 buffered async aggregation composed with
FLoCoRA's quantized low-rank messages).

The paper's loop is synchronous; production fleets are not. This engine
replaces round lockstep with a VIRTUAL-CLOCK discrete-event simulation:

  * DISPATCH — the server samples an idle client, broadcasts the current
    global adapters truncated to the client's rank (shared codec path:
    ``flocora.server_downlink`` / ``broadcast``), and schedules the
    update's arrival with a pluggable :class:`~repro.fl.traces.FleetTrace`
    (lognormal compute+network latency per rank tier, periodic
    availability windows, deterministic replay from a seed);
  * ARRIVAL — the client's PACKED wire message (uint32 payloads + fp32
    sidecars, rank-tagged header; ``flocora.client_uplink``) enters a
    staleness-aware FedBuff buffer: its weight is discounted by
    ``2^(-staleness / half_life)`` where staleness is the number of
    global versions the server advanced since the client's dispatch;
  * FLUSH — every ``buffer_size`` arrivals the buffer aggregates into a
    new global version in ONE rank-bucketed pass on the fused
    ``dequant_agg`` kernel (:meth:`FedBuffAggregator.flush`); with
    ``FLoCoRAConfig.flat_wire`` (default) the buffered messages are
    FLAT-TREE wire leaves (core/flat.py), so a whole buffer's unpack +
    dequantize + staleness-weighted reduce is ONE fused kernel launch
    per rank bucket, not one per adapter leaf. FedBuff
    applies averaged client DELTAS, not averaged models: the new global
    is ``g + server_lr * (mean_u - mean_start)`` where ``mean_u`` is the
    fused buffered packed sum and ``mean_start`` the same
    discounted-weight mean over the broadcasts those clients trained
    from (both zero-padded to the server rank). A stale update therefore
    contributes exactly its LOCAL progress — its outdated base model
    cancels instead of dragging the global backward — and a buffer of
    all-fresh updates at ``server_lr=1`` reproduces the sync FedAvg of
    that buffer (exactly when quantization is off; with it, deltas are
    measured against the dequantized broadcast each client actually
    received, per the wire). The history records the
    (virtual time, client loss, TCC bytes) trajectory — plus bytes AND
    virtual seconds to a target metric via :func:`time_to_target`.

MICRO-BATCHED EXECUTION. Simulating one jitted program per arrival would
be dispatch-bound; instead, pending arrivals within a virtual-time
window (``microbatch_window`` after the earliest pending event) are
grouped BY RANK and each group trains as one vmapped program through
``make_staggered_cohort_trainer`` (per-client start trees — arrivals in
a group may have been dispatched from different global versions). Group
client dims pad to a pow2, so total recompiles are bounded by
#distinct-ranks x log2(max micro-batch) — never by #arrivals.

DETERMINISM AND RESUME. Every stochastic choice (client sampling, batch
shuffling, trace latency) is drawn from a generator keyed by
``(seed, domain, ids)`` — a pure function of the simulation state, with
no mutable RNG stream. Checkpoints (``repro.checkpoint``, atomic npz +
JSON manifest) therefore round-trip the FULL engine state — virtual
clock, global version, event queue, in-flight broadcasts and computed
uplinks, cumulative byte accounting, history — and a killed-then-resumed
run replays the remaining events BIT-EXACTLY (checkpoints align to flush
boundaries, so the FedBuff buffer is empty by construction; this is
asserted). ``try_resume`` restores everything.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, latest_step, restore
from repro.core import flocora, lora
from repro.core.aggregation import FedBuffAggregator
from repro.core.flocora import FLoCoRAConfig
from repro.core.quant import gaussian_epsilon
from repro.fl.client import ClientConfig, cohort_steps, natural_steps, \
    make_staggered_cohort_trainer, pad_cohort_batches, pow2_pad, \
    stack_local_batches
from repro.fl.server import WireAccounting
from repro.fl.traces import FleetTrace
from repro.obs import metrics as obsm
from repro.obs import trace as obst
from repro.utils.tree import tree_bytes

Array = jax.Array

# rng key domains (traces.py owns TAG_LATENCY = 0xA1)
TAG_SAMPLE = 0xB1     # which idle client to dispatch
TAG_BATCH = 0xB2      # a dispatched client's local batch shuffle


@dataclasses.dataclass(frozen=True)
class AsyncConfig:
    """Engine knobs for the asynchronous FedBuff loop."""
    total_arrivals: int = 200    # stop after this many buffered arrivals
    concurrency: int = 8         # clients kept in flight
    buffer_size: int = 10        # FedBuff K: flush every K arrivals
    streaming_agg: bool = False  # fold flat arrivals at add time (O(1)
    #                              flush cost/memory in buffer_size)
    half_life: float = 4.0       # staleness discount half-life (versions)
    server_lr: float = 1.0       # scale on the applied mean flush delta
    microbatch_window: float = 0.0  # virtual-seconds arrival grouping
    strict_compiles: bool = False  # raise if a steady-state streaming
    #                                fold recompiles (obs.CompileWatchdog)
    seed: int = 0
    eval_every: int = 5          # eval_fn every N flushes
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 10   # checkpoint every N flushes

    def __post_init__(self):
        if min(self.total_arrivals, self.concurrency, self.buffer_size,
               self.eval_every, self.checkpoint_every) < 1:
            raise ValueError("total_arrivals/concurrency/buffer_size/"
                             "eval_every/checkpoint_every must be >= 1")
        if self.half_life <= 0:
            raise ValueError("half_life must be > 0")
        if self.server_lr <= 0:
            raise ValueError("server_lr must be > 0")
        if self.microbatch_window < 0:
            raise ValueError("microbatch_window must be >= 0")


@dataclasses.dataclass
class _InFlight:
    """One dispatched-but-not-yet-buffered client update."""
    cid: int
    rank: int
    version: int          # global version the client trained from
    dispatch_idx: int     # global dispatch counter (rng/trace key)
    t_dispatch: float
    t_arrival: float
    n_k: int              # client sample count (aggregation weight)
    start: Any            # broadcast fp tree at `rank` (None if dropped)
    msg: Any = None       # computed packed uplink (micro-batch cache)
    loss: float = float("nan")
    # CHURN: decided at dispatch from the trace (keyed (seed, cid,
    # dispatch_idx), so it replays on resume). A dropped dispatch never
    # trains and never buffers — its downlink bytes were wasted, and the
    # server notices at t_arrival (the deadline a live client would
    # have hit), dispatching a replacement
    dropped: bool = False
    down: int = 0         # downlink bytes spent at dispatch


def time_to_target(history: list[dict], key: str, target: float,
                   mode: str = "min") -> Optional[dict]:
    """Bytes AND virtual seconds to a target metric: the first history
    record whose ``key`` reaches ``target`` (``mode='min'``: <=, for
    losses; ``'max'``: >=, for accuracies). Returns {'version',
    't_virtual', 'tcc_bytes'} or None if never reached."""
    for h in history:
        if key not in h:
            continue
        hit = h[key] <= target if mode == "min" else h[key] >= target
        if hit:
            return {"version": h["version"], "t_virtual": h["t_virtual"],
                    "tcc_bytes": h["tcc_bytes"]}
    return None


class AsyncFLServer:
    """Virtual-clock asynchronous FL server (see module docstring).

    Same model/loss/data/eval contract as the sync :class:`FLServer`;
    ``trace`` supplies the fleet timing model and ``aggregator`` (a
    :class:`FedBuffAggregator`, default-constructed when omitted) the
    buffered staleness-discounted rule. ``trainer`` may be passed to
    share a compiled staggered-cohort trainer across engine instances
    (same loss_fn/ccfg), e.g. for steady-state benchmarking.
    """

    def __init__(self, model: dict, loss_fn: Callable,
                 client_data: list[dict], acfg: AsyncConfig,
                 ccfg: ClientConfig, fcfg: FLoCoRAConfig,
                 trace: Optional[FleetTrace] = None,
                 eval_fn: Optional[Callable] = None,
                 aggregator: Optional[FedBuffAggregator] = None,
                 trainer: Optional[Callable] = None,
                 registry: Optional[obsm.MetricsRegistry] = None,
                 tracer: Optional[obst.Tracer] = None):
        self.frozen = model["frozen"]
        self.global_train = model["train"]
        self.loss_fn = loss_fn
        self.client_data = client_data
        self.acfg, self.ccfg, self.fcfg = acfg, ccfg, fcfg
        self.trace = trace if trace is not None \
            else FleetTrace(seed=acfg.seed)
        self.eval_fn = eval_fn
        # telemetry: spans land on the VIRTUAL clock (with_clock view),
        # so exported timelines read in simulated fleet seconds
        self.registry = obsm.get_registry(registry)
        self.tracer = obst.get_tracer(tracer).with_clock(
            lambda: self.clock)
        if fcfg.error_feedback:
            # an EF residual assumes the NEXT encode of the same client
            # compensates the previous one; async staleness breaks that
            # invariant, so fail loudly instead of silently degrading
            # (this also bars SparsityConfig(require_ef=True) profiles:
            # async sparse uplinks need require_ef=False, accepting the
            # top-k bias FLASC's EF would otherwise absorb)
            raise ValueError("error feedback is not supported by the "
                             "async engine")
        sched = fcfg.rank_schedule
        if sched is not None and sched.n_clients != len(client_data):
            raise ValueError(
                f"rank_schedule covers {sched.n_clients} clients, fleet "
                f"has {len(client_data)}")
        # lazy Population fleets (duck-typed: rank_for/sample_cid/
        # schedule_steps/shard_size) carry their own rank tiers; an
        # explicit RankSchedule overrides
        self._pop = client_data \
            if hasattr(client_data, "sample_cid") else None
        if self._pop is not None and sched is None \
                and self._pop.max_rank > fcfg.rank:
            raise ValueError(
                f"population max tier rank {self._pop.max_rank} "
                f"exceeds the server rank {fcfg.rank}")
        if aggregator is None:
            aggregator = FedBuffAggregator()
        if not isinstance(aggregator, FedBuffAggregator):
            raise ValueError(
                f"async engine requires a FedBuffAggregator, got "
                f"{type(aggregator).__name__}")
        if aggregator.r_target is not None \
                and aggregator.r_target != fcfg.rank:
            # the delta flush applies at the global tree's rank: any
            # other target would shape-error mid-run, so fail at config
            # time like the sync server does
            raise ValueError(
                f"async aggregator r_target={aggregator.r_target} must "
                f"match the server rank {fcfg.rank}")
        fields: dict[str, Any] = {"pending": list(aggregator.pending),
                                  "streams": dict(aggregator.streams)}
        if acfg.streaming_agg:
            fields["streaming"] = True
        if acfg.strict_compiles:
            # zero-steady-state-compile invariant, enforced at runtime:
            # every streaming fold after an accumulator's first raises
            # CompileBudgetExceeded if the backend compiled
            fields["strict_compiles"] = True
        if aggregator.half_life is None:
            fields["half_life"] = acfg.half_life    # config-threaded
        if aggregator.r_target is None:
            fields["r_target"] = fcfg.rank
        self.aggregator = dataclasses.replace(aggregator, **fields)
        self.trainer = trainer if trainer is not None \
            else make_staggered_cohort_trainer(loss_fn, ccfg)
        # fixed schedule length across the fleet: the staggered cohort
        # program's (steps, B) never changes, only (rank, pow2 K)
        # retrace. A Population knows its schedule in O(1); the eager
        # path scans the materialized shards.
        self.schedule_steps = client_data.schedule_steps(ccfg) \
            if self._pop is not None else cohort_steps(client_data, ccfg)
        hetero = self._pop is not None and sched is None \
            and self._pop.mixed_ranks
        self.wire = WireAccounting(fcfg, registry=self.registry,
                                   hetero=hetero)
        # -- simulation state (everything below round-trips checkpoints)
        self.clock = 0.0
        self.version = 0
        self.n_dispatched = 0
        self.n_arrived = 0
        self.n_churned = 0
        self._wasted_cum = 0
        self.n_flushes = 0
        self.inflight: dict[int, _InFlight] = {}   # dispatch_idx -> rec
        self.heap: list[tuple[float, int]] = []    # (t_arrival, idx)
        self._bcast_memo: dict[int, Any] = {}      # rank -> start tree
        self.history: list[dict] = []
        self._down_cum = 0
        self._up_cum = 0
        self._flush_stats: list[tuple[float, int, int]] = []
        self._flush_starts: list[Any] = []   # broadcast refs, || pending
        # streaming mode: running discounted-weight sum of the resized
        # start trees (mean_start's numerator), O(1) in buffer_size —
        # the streaming twin of _flush_starts
        self._start_sum: Any = None
        self._start_weight: float = 0.0
        self.initial_model_bytes = tree_bytes(self.frozen)
        self.program_keys: set[tuple[int, int]] = set()  # (rank, padK)
        self.ckpt = CheckpointManager(acfg.checkpoint_dir) \
            if acfg.checkpoint_dir else None

    # -- deterministic keyed randomness -------------------------------------
    def _rng(self, *key: int) -> np.random.Generator:
        """A fresh generator keyed by (seed, *key): every draw is a pure
        function of simulation ids, so resumed runs replay identically
        without serializing any RNG stream."""
        return np.random.default_rng([self.acfg.seed, *key])

    def _rank_for(self, cid: int) -> int:
        sched = self.fcfg.rank_schedule
        if sched is not None:
            return sched.rank_for(cid, self.version)   # versions anneal
        if self._pop is not None:
            return self._pop.rank_for(cid)             # device tier
        return self.fcfg.rank

    @property
    def tcc_bytes(self) -> int:
        """Shared-once initial model + every measured down/uplink."""
        return self.initial_model_bytes + self._down_cum + self._up_cum

    # -- dispatch -----------------------------------------------------------
    def _sample_cid(self, idx: int, busy: set) -> Optional[int]:
        """One dispatch candidate. A lazy Population rejection-samples
        against the (O(concurrency)) busy set — never enumerating the
        fleet; eager list fleets keep the explicit free-list draw."""
        if self._pop is not None:
            return self._pop.sample_cid(self._rng(TAG_SAMPLE, idx), busy)
        free = [c for c in range(len(self.client_data)) if c not in busy]
        if not free:
            return None
        return int(free[self._rng(TAG_SAMPLE, idx).integers(len(free))])

    def _dispatch_one(self) -> bool:
        """Sample an idle client, broadcast, schedule its arrival (or,
        for a churned dispatch, schedule the deadline at which the
        server will notice the update never came)."""
        busy = {f.cid for f in self.inflight.values()}
        idx = self.n_dispatched
        cid = self._sample_cid(idx, busy)
        if cid is None:
            return False
        rank = self._rank_for(cid)
        # churn is a trace draw keyed (seed, cid, dispatch_idx): known
        # at dispatch, replayed identically on resume
        dropped = self.trace.churned(cid, idx)
        start = None
        if not dropped:
            start = self._bcast_memo.get(rank)
            if start is None:
                # one pack+unpack per (version, rank): the memo is
                # cleared at every flush, and start trees are never
                # mutated, so in-flight records may share them
                start = flocora.broadcast(self.global_train, self.fcfg,
                                          rank=self.wire.bcast_rank(rank))
                self._bcast_memo[rank] = start
        down = self.wire.downlink_bytes(self.global_train, rank)
        self._down_cum += down
        self.wire.record_down(rank, down)
        # message sizes are symmetric, so the round trip on the trace's
        # wire is 2x the measured downlink
        t_arr = self.trace.arrival(cid, idx, rank, 2 * down, self.clock)
        if dropped or self._pop is None:
            # dropped dispatches never train, so their shard is never
            # materialized (n_k unused)
            n_k = 0 if dropped else \
                len(next(iter(self.client_data[cid].values())))
        else:
            n_k = self._pop.shard_size
        self.inflight[idx] = _InFlight(cid, rank, self.version, idx,
                                       self.clock, t_arr, n_k, start,
                                       dropped=dropped, down=down)
        heapq.heappush(self.heap, (t_arr, idx))
        self.n_dispatched += 1
        self.registry.set("fl.inflight", len(self.inflight))
        return True

    def _expected_arrivals(self) -> int:
        """Arrivals already buffered plus live (non-churned) dispatches
        still in flight — the dispatch guard, so churn pulls in extra
        dispatches instead of starving ``total_arrivals``."""
        return self.n_arrived + sum(1 for r in self.inflight.values()
                                    if not r.dropped)

    def _fill_pipeline(self) -> None:
        while (len(self.inflight) < self.acfg.concurrency
               and self._expected_arrivals() < self.acfg.total_arrivals):
            if not self._dispatch_one():
                break

    # -- micro-batched local training ---------------------------------------
    def _compute_microbatch(self) -> None:
        """Train every not-yet-computed in-flight update whose arrival
        falls within ``microbatch_window`` of the earliest pending
        event, grouped by rank — one staggered-cohort program per
        (rank, pow2 group)."""
        t0 = self.heap[0][0]
        horizon = t0 + self.acfg.microbatch_window
        by_rank: dict[int, list[int]] = {}
        for t, idx in self.heap:
            rec = self.inflight[idx]
            if t <= horizon and rec.msg is None and not rec.dropped:
                by_rank.setdefault(rec.rank, []).append(idx)
        for rank in sorted(by_rank):
            idxs = sorted(by_rank[rank],
                          key=lambda i: (self.inflight[i].t_arrival, i))
            self._train_group(rank, idxs)

    def _train_group(self, rank: int, idxs: list[int]) -> None:
        recs = [self.inflight[i] for i in idxs]
        datas = [self.client_data[r.cid] for r in recs]
        per = [stack_local_batches(self._rng(TAG_BATCH, r.cid,
                                             r.dispatch_idx),
                                   d, self.ccfg,
                                   steps=self.schedule_steps)
               for r, d in zip(recs, datas)]
        batches = {k: np.stack([p[k] for p in per]) for k in per[0]}
        n_steps = np.asarray(
            [min(natural_steps(d, self.ccfg), self.schedule_steps)
             for d in datas], np.int32)
        k_pad = pow2_pad(len(recs))
        batches, n_steps = pad_cohort_batches(batches, n_steps, k_pad)
        starts = jax.tree.map(
            lambda *xs: jnp.stack(xs, axis=0),
            *([r.start for r in recs]
              + [recs[0].start] * (k_pad - len(recs))))
        self.program_keys.add((rank, k_pad))
        trained, losses = self.trainer(self.frozen, starts,
                                       jax.tree.map(jnp.asarray, batches),
                                       jnp.asarray(n_steps))
        losses = np.asarray(losses)
        for k, rec in enumerate(recs):
            t_k = jax.tree.map(lambda x: x[k], trained)
            # density keys off the DISPATCH version (rec.version), a
            # pure function of checkpointed state — resumed runs emit
            # byte-identical uplinks. DP (when configured) privatizes
            # the delta vs rec.start with noise keyed by the dispatch
            # ids, so concurrent dispatches of one client never share a
            # noise draw and resume replays it bit-exactly
            rec.msg, _ = flocora.client_uplink(
                t_k, self.fcfg, rnd=rec.version, start=rec.start,
                dp_key=(rec.version, rec.cid, rec.dispatch_idx),
                dp_seed=self.acfg.seed)
            rec.loss = float(losses[k])

    # -- the event loop -----------------------------------------------------
    def step(self) -> Optional[dict]:
        """Process ONE event — an arrival, or a churned dispatch's
        deadline; returns the flush record when an arrival filled the
        buffer, else None."""
        if not self.heap:
            self._fill_pipeline()
            if not self.heap:
                raise RuntimeError("no events left "
                                   f"({self.n_arrived} arrivals done)")
        head = self.inflight[self.heap[0][1]]
        if head.msg is None and not head.dropped:
            self._compute_microbatch()
        t_arr, idx = heapq.heappop(self.heap)
        rec = self.inflight.pop(idx)
        self.clock = max(self.clock, t_arr)
        if rec.dropped:
            # CHURN: the update never arrives — the spent downlink was
            # wasted, the client slot frees, a replacement dispatches
            self.n_churned += 1
            self._wasted_cum += rec.down
            self.wire.record_wasted(rec.rank, rec.down, reason="churned")
            self.registry.inc("fl.clients_churned")
            self.registry.set("fl.inflight", len(self.inflight))
            self._fill_pipeline()
            return None
        staleness = self.version - rec.version
        density = self.fcfg.uplink_density(rec.version)
        up = self.wire.uplink_bytes(rec.rank, rec.msg, density) or 0
        self._up_cum += up
        self.wire.record_up(rec.rank, up, density)
        self.n_arrived += 1
        # one dispatch->arrival span per update, on VIRTUAL time
        self.tracer.event("fl/inflight", ts=rec.t_dispatch,
                          dur=t_arr - rec.t_dispatch, track="fl/async",
                          cid=rec.cid, rank=rec.rank,
                          version=rec.version, staleness=staleness)
        self.registry.observe("fl.staleness", staleness)
        self.registry.set("fl.inflight", len(self.inflight))
        self.aggregator.add(rec.msg, rec.n_k, staleness)
        self.registry.observe("fl.buffer_occupancy",
                              self.aggregator.buffered)
        if self.acfg.streaming_agg:
            self._fold_start(
                rec.start,
                self.aggregator.discounted_weight(rec.n_k, staleness))
        else:
            self._flush_starts.append(rec.start)
        self._flush_stats.append((rec.loss, staleness, rec.rank))
        out = None
        if self.aggregator.buffered >= self.acfg.buffer_size:
            out = self._flush()
        if self._expected_arrivals() < self.acfg.total_arrivals:
            self._dispatch_one()       # keep the pipeline full
        return out

    def _fold_start(self, start: Any, w: float) -> None:
        """Streaming twin of ``_flush_starts``: fold one arrival's
        broadcast into the running discounted-weight start sum, so
        mean_start at flush is an O(1) normalize like the uplink side."""
        target = self.aggregator.r_target or self.fcfg.rank
        s = lora.resize_tree_rank(start, target)
        if self._start_sum is None:
            self._start_sum = jax.tree.map(
                lambda x: w * x.astype(jnp.float32), s)
        else:
            self._start_sum = jax.tree.map(
                lambda a, x: a + w * x.astype(jnp.float32),
                self._start_sum, s)
        self._start_weight += float(w)

    def _apply_mean(self, mean_u: Any, mean_start: Any) -> None:
        """g <- g + server_lr * (mean_u - mean_start): the buffered
        updates contribute their LOCAL training progress relative to the
        broadcasts they each started from (see module docstring)."""
        lr = self.acfg.server_lr
        self.global_train = jax.tree.map(
            lambda g, mu, ms: (g.astype(jnp.float32)
                               + lr * (mu.astype(jnp.float32) - ms)
                               ).astype(g.dtype),
            self.global_train, mean_u, mean_start)

    def _apply_delta(self, mean_u: Any, weights: list[float]) -> None:
        w = np.asarray(weights, np.float32)
        wn = w / max(float(w.sum()), 1e-8)
        target = self.aggregator.r_target or self.fcfg.rank
        starts = [lora.resize_tree_rank(s, target)
                  for s in self._flush_starts]
        mean_start = jax.tree.map(
            lambda *xs: sum(float(a) * x.astype(jnp.float32)
                            for a, x in zip(wn, xs)), *starts)
        self._apply_mean(mean_u, mean_start)

    def _apply_delta_streaming(self, mean_u: Any) -> None:
        """O(1) flush apply: mean_start = start_sum / start_weight
        (mirrors the aggregator's zero-weight raise)."""
        if self._start_weight <= 0.0:
            raise ValueError("streaming flush with zero accumulated "
                             "start weight")
        inv = 1.0 / self._start_weight
        mean_start = jax.tree.map(lambda a: a * inv, self._start_sum)
        self._start_sum, self._start_weight = None, 0.0
        self._apply_mean(mean_u, mean_start)

    def _flush(self) -> dict:
        losses = [l for l, _, _ in self._flush_stats]
        stales = [s for _, s, _ in self._flush_stats]
        ranks: dict[str, int] = {}
        for _, _, r in self._flush_stats:
            ranks[str(r)] = ranks.get(str(r), 0) + 1
        n_buf = self.aggregator.buffered
        weights = [wt for _, wt in self.aggregator.pending]
        with self.tracer.span("fl/flush", track="fl/async",
                              version=self.version, n_flushed=n_buf):
            mean_u = self.aggregator.flush()  # fused buffered packed sum
            if self.acfg.streaming_agg:
                self._apply_delta_streaming(mean_u)
            else:
                self._apply_delta(mean_u, weights)
        self._flush_starts = []
        self._bcast_memo = {}          # broadcasts of the old version
        density = self.fcfg.uplink_density(self.version)
        self.version += 1
        self.n_flushes += 1
        self.registry.inc("fl.flushes")
        rec = {"version": self.version, "t_virtual": self.clock,
               "n_arrived": self.n_arrived, "n_flushed": n_buf,
               "n_churned": self.n_churned,
               "client_loss": float(np.mean(losses)),
               "staleness_mean": float(np.mean(stales)),
               "staleness_max": int(max(stales)),
               "flush_ranks": ranks,
               "down_bytes": self._down_cum, "up_bytes": self._up_cum,
               "tcc_bytes": self.tcc_bytes,
               # downlinks spent on dispatches that churned mid-round
               "wasted_bytes": self._wasted_cum,
               # schema-uniform with the sync history (None = dense);
               # the density of the version this flush advanced FROM
               "uplink_density": density}
        if self.fcfg.dp is not None:
            # each flush is one Gaussian release of the aggregate;
            # conservative RDP composition over versions so far
            eps = gaussian_epsilon(self.fcfg.dp.noise_multiplier,
                                   self.version, self.fcfg.dp.delta)
            rec["dp_epsilon"] = eps
            self.registry.set("fl.dp_epsilon", eps)
        self._flush_stats = []
        if self.eval_fn and self.n_flushes % self.acfg.eval_every == 0:
            rec.update({k: float(v) for k, v in
                        self.eval_fn(self.frozen,
                                     self.global_train).items()})
        self.history.append(rec)
        if self.ckpt and self.n_flushes % self.acfg.checkpoint_every == 0:
            self.save()
        return rec

    def run(self) -> list[dict]:
        """Drive the event loop to ``total_arrivals`` buffered arrivals
        (continuing from restored state after ``try_resume``), with a
        final partial flush so the history covers every update."""
        self._fill_pipeline()
        while self.n_arrived < self.acfg.total_arrivals:
            self.step()
        if self.aggregator.buffered:
            self._flush()
        return self.history

    # -- checkpoint/resume (full simulator state) ---------------------------
    def _start_template(self, rank: int) -> Any:
        """Shape/dtype template of a rank-``rank`` broadcast tree."""
        if self.wire.bcast_rank(rank) is None:
            return self.global_train
        return lora.resize_tree_rank(self.global_train, rank,
                                     method="slice")

    def _msg_template(self, rank: int, version: int = 0) -> Any:
        """Shape/dtype template of a rank-``rank`` packed/sparse uplink
        dispatched at global ``version`` (density annealing changes the
        sparse payload shapes between versions)."""
        zeros = jax.tree.map(jnp.zeros_like, self._start_template(rank))
        return flocora.client_uplink(zeros, self.fcfg, rnd=version)[0]

    def save(self) -> None:
        if self.ckpt is None:
            return
        # checkpoints align to flush boundaries: the FedBuff buffer is
        # empty by construction, so the buffered messages never need to
        # serialize — everything else does. The same alignment empties
        # the streaming accumulators (flush resets them) and the start
        # sum, so the streaming state checkpoints as its empty value;
        # mid-buffer accumulator round-trip is covered at unit level by
        # StreamingFlatAccumulator.state()/from_state.
        assert (not self.aggregator.pending and not self._flush_starts
                and self.aggregator.buffered == 0
                and self._start_sum is None), \
            "async checkpoint must align to a flush boundary"
        trees: dict[str, Any] = {"train": self.global_train}
        meta_if: dict[str, dict] = {}
        for idx, rec in self.inflight.items():
            if rec.start is not None:
                # churned dispatches carry no start tree (never train)
                trees[f"inflight_{idx}"] = rec.start
            if rec.msg is not None:
                # computed uplinks ride along so a resumed run never
                # recomputes them under a different micro-batch grouping
                trees[f"msg_{idx}"] = rec.msg
            meta_if[str(idx)] = {
                "cid": rec.cid, "rank": rec.rank, "version": rec.version,
                "t_dispatch": rec.t_dispatch, "t_arrival": rec.t_arrival,
                "n_k": rec.n_k, "has_msg": rec.msg is not None,
                "loss": rec.loss, "dropped": rec.dropped,
                "down": rec.down}
        self.ckpt.save(self.n_flushes, trees, metadata={
            "clock": self.clock, "version": self.version,
            "n_dispatched": self.n_dispatched,
            "n_arrived": self.n_arrived, "n_flushes": self.n_flushes,
            "n_churned": self.n_churned, "wasted_cum": self._wasted_cum,
            "down_cum": self._down_cum, "up_cum": self._up_cum,
            "heap": sorted(self.heap), "inflight": meta_if,
            "history": self.history})

    def try_resume(self) -> bool:
        if self.ckpt is None:
            return False
        step = latest_step(self.ckpt.directory)
        if step is None:
            return False
        # pass 1: the manifest metadata describes the in-flight trees'
        # ranks, from which the like-templates are rebuilt for pass 2
        _, man = restore(self.ckpt.directory, step,
                         {"train": self.global_train})
        meta = man["metadata"]
        like: dict[str, Any] = {"train": self.global_train}
        for s, m in meta["inflight"].items():
            if not m.get("dropped", False):
                like[f"inflight_{s}"] = self._start_template(m["rank"])
            if m["has_msg"]:
                like[f"msg_{s}"] = self._msg_template(m["rank"],
                                                      m["version"])
        trees, _ = restore(self.ckpt.directory, step, like)
        self.global_train = trees["train"]
        self.clock = meta["clock"]
        self.version = meta["version"]
        self.n_dispatched = meta["n_dispatched"]
        self.n_arrived = meta["n_arrived"]
        self.n_flushes = meta["n_flushes"]
        self.n_churned = meta.get("n_churned", 0)
        self._wasted_cum = meta.get("wasted_cum", 0)
        self._down_cum = meta["down_cum"]
        self._up_cum = meta["up_cum"]
        self.history = list(meta["history"])
        self._flush_stats = []
        self._start_sum, self._start_weight = None, 0.0
        for st in self.aggregator.streams.values():
            st.reset()      # checkpoint boundary == empty accumulators
        self.inflight = {}
        for s, m in meta["inflight"].items():
            idx = int(s)
            self.inflight[idx] = _InFlight(
                m["cid"], m["rank"], m["version"], idx, m["t_dispatch"],
                m["t_arrival"], m["n_k"], trees.get(f"inflight_{s}"),
                msg=trees.get(f"msg_{s}"), loss=m["loss"],
                dropped=m.get("dropped", False), down=m.get("down", 0))
        self.heap = [tuple(e) for e in meta["heap"]]
        heapq.heapify(self.heap)
        return True
